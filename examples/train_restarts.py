"""Fault-tolerance demo: inject two preemptions mid-training and watch the
supervisor restart from the last checkpoint with no loss-curve damage.

Run: PYTHONPATH=src python examples/train_restarts.py
"""
import tempfile

from repro.configs import registry
from repro.train.loop import SimulatedFailure, TrainJob, run_with_restarts


def main():
    cfg = registry.get_smoke_config("internlm2-1.8b").scaled(
        n_layers=2, d_model=64, vocab_size=512)
    with tempfile.TemporaryDirectory() as d:
        job = TrainJob(cfg=cfg, steps=60, batch=4, seq=32, ckpt_dir=d,
                       ckpt_every=10, lr=3e-3)
        failures = {
            17: SimulatedFailure("node 3 preempted"),
            41: SimulatedFailure("pod-2 power event"),
        }
        params, _, hist, restarts = run_with_restarts(job, failures=failures)
        print(f"finished 60 steps with {restarts} restarts")
        print(f"final loss {hist[-1]['loss']:.4f} at step {hist[-1]['step']}")
        redone = [h["step"] for h in hist]
        print(f"steps re-executed after restarts: "
              f"{len(redone) - len(set(redone))} (work lost, bounded by "
              f"ckpt_every=10)")


if __name__ == "__main__":
    main()
