"""End-to-end serving driver: FELARE routes real inference requests for two
REAL (reduced-config) models across a heterogeneous set of serving groups.

This is the paper's SmartSight scenario on the framework: task types are
architectures (a 'face recognition'-class dense LM and a 'speech
recognition'-class encoder-decoder), machines are device groups with
different simulated speed grades, and the Router (repro.cluster) makes the
ELARE/FELARE mapping decisions while actual `decode`/`prefill` steps execute
the requests. The simulated-time executor scales measured CPU latencies by
each machine's roofline speed factor so the heterogeneity is meaningful on a
single host.

Run: PYTHONPATH=src python examples/serve_edge.py [--requests 120] \
         [--heuristic FELARE] [--rate 20]
"""
import argparse
import heapq

import jax
import numpy as np

from repro.cluster.router import Request, Router
from repro.configs import registry
from repro.models import transformer as tf
from repro.train.steps import make_serve_steps


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--heuristic", default="FELARE",
                    choices=["FELARE", "ELARE", "MM", "MSD", "MMU"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)

    # two ML applications (task types)
    archs = ["qwen1.5-0.5b", "whisper-medium"]
    cfgs = [registry.get_smoke_config(a) for a in archs]
    params, steps = [], []
    for cfg in cfgs:
        p = tf.init(jax.random.PRNGKey(0), cfg)
        params.append(p)
        steps.append(make_serve_steps(cfg))

    # measure baseline CPU latency per task type once (the 'profiling' run)
    import time
    base_lat = []
    for cfg, p, (prefill, _) in zip(cfgs, params, steps):
        batch = _make_batch(cfg, rng)
        prefill(p, batch, max_seq=48)  # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(prefill(p, batch, max_seq=48))
        base_lat.append((time.perf_counter() - t0) / 3)

    # heterogeneous machines: speed factor + power (the fleet profile)
    speed = np.array([1.0, 2.5, 0.6, 1.4])
    p_dyn = np.array([170.0, 520.0, 80.0, 210.0], np.float32)
    p_idle = p_dyn * 0.1
    eet = np.asarray(base_lat, np.float32)[:, None] / speed[None, :]
    mean_e = eet.mean(axis=1)
    deadline_slack = mean_e + mean_e.mean()

    clock = SimClock()
    router = Router(eet, p_dyn, p_idle, heuristic=args.heuristic,
                    queue_size=2, now_fn=clock)

    # Poisson request stream
    events = []  # (time, kind, payload)
    t = 0.0
    for rid in range(args.requests):
        t += rng.exponential(1.0 / args.rate)
        tt = int(rng.integers(0, len(archs)))
        heapq.heappush(events, (t, 0, rid, tt))

    n_exec = 0
    while events:
        tm, kind, a, b = heapq.heappop(events)
        clock.t = tm
        if kind == 0:  # arrival
            rid, tt = a, b
            req = Request(rid=rid, task_type=tt, arrival=tm,
                          deadline=tm + float(deadline_slack[tt]))
            started = router.on_request(req)
        else:          # completion on machine a
            j = a
            req = router.running[j]
            lat = tm - req.start
            ok = tm <= req.deadline
            started = router.on_completion(j, success=ok, latency=lat)
            n_exec += 1
        for j, req in started:
            # EXECUTE the real model once (machine speed scales sim time)
            cfg, p, (prefill, _) = (cfgs[req.task_type],
                                    params[req.task_type],
                                    steps[req.task_type])
            jax.block_until_ready(
                prefill(p, _make_batch(cfg, rng), max_seq=48))
            sim_lat = float(base_lat[req.task_type] / speed[j]
                            * rng.uniform(0.9, 1.1))
            heapq.heappush(events, (clock.t + sim_lat, 1, j, 0))

    m = router.metrics()
    print(f"heuristic={args.heuristic} requests={args.requests} "
          f"rate={args.rate}/s")
    print(f"  completion rate : {m['collective_completion_rate']:.3f}")
    print(f"  per-type rates  : "
          + " ".join(f"{x:.2f}" for x in m["completion_rate_by_type"]))
    print(f"  Jain fairness   : {m['jain_fairness']:.3f}")
    print(f"  energy (J, sim) : {m['energy']:.1f} "
          f"(wasted {m['energy_wasted']:.1f})")
    print(f"  executed        : {n_exec} real inference calls")
    print(f"  adapted EET     :\n{np.round(m['eet'], 4)}")


def _make_batch(cfg, rng):
    import jax.numpy as jnp
    B, S = 1, 16
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32) * 0.1
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)),
            jnp.float32) * 0.1
    return b


if __name__ == "__main__":
    main()
