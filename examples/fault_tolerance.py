"""Fault-tolerance demo: a whole site dies mid-trace — who keeps their
deadlines?

Injects a scheduled :class:`~repro.core.faults.SiteOutage` (site 0 dark
for the middle quarter of the trace horizon) into a 4-site federation and
compares, on identical workloads (common random numbers):

  * ``sticky``       — hash-affinity dispatch, blind to health: tasks
                       keep landing on the dead site and orphan out;
  * ``fair_spill``   — fairness-aware spill, accidentally robust (the
                       suffering types spill off the dead site);
  * ``health_aware`` — sticky homes + heartbeat mask: admissions route
                       around the outage the moment it starts;
  * ``health_aware`` + ``with_backup(FELARE, k=1)`` — additionally
                       fails running orphans straight over to their
                       pre-nominated backup machine.

Run: PYTHONPATH=src python examples/fault_tolerance.py
"""
import jax
import numpy as np

from repro import scenarios
from repro.core import engine, faults, workload


def main():
    spec = scenarios.get_fleet("paper_x4").build()
    trace = workload.poisson_trace(
        jax.random.PRNGKey(0), n_tasks=400, arrival_rate=6.0, eet=spec.eet
    )
    outage = faults.SiteOutage(outages=((0, 0.25, 0.5),))

    def ontime(heuristic, dispatcher, dynamics):
        m, aux = engine.simulate(
            trace, spec, heuristic=heuristic, dispatcher=dispatcher,
            dynamics=dynamics, observers=("health",),
        )
        done = float(np.sum(np.asarray(m.completed_by_type)))
        arrived = float(np.sum(np.asarray(m.arrived_by_type)))
        orphans = int(np.asarray(aux["health"]["orphans"])[-1])
        return done / max(arrived, 1.0), orphans

    print("site 0 dark for the middle quarter of the horizon "
          "(paper_x4, 400 tasks @ 6/s, FELARE mapping):\n")
    base, _ = ontime("FELARE", "sticky", None)
    print(f"  {'no faults (reference)':42s} on-time {100 * base:5.1f}%")
    rows = [
        ("sticky (health-blind)", "FELARE", "sticky"),
        ("fair_spill", "FELARE", "fair_spill"),
        ("health_aware", "FELARE", "health_aware"),
        ("health_aware + backup k=1",
         faults.with_backup("FELARE", k=1), "health_aware"),
    ]
    for label, heuristic, dispatcher in rows:
        rate, orphans = ontime(heuristic, dispatcher, outage)
        print(f"  {label:42s} on-time {100 * rate:5.1f}%  "
              f"orphan re-dispatches {orphans:3d}")
    print("\nhealth-aware dispatch routes admissions around the dead site;"
          "\nbackups re-home the tasks the outage caught mid-run.")


if __name__ == "__main__":
    main()
