"""End-to-end training driver: train a small LM for a few hundred steps with
periodic async checkpoints, then resume from the checkpoint to prove
restart-continuity.

Default is a CPU-sized model so the example finishes in minutes; pass
--preset 100m for the ~100M-parameter configuration on real hardware.

Run: PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.configs import registry
from repro.train.loop import TrainJob, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    base = registry.get_smoke_config(args.arch)
    if args.preset == "100m":
        cfg = base.scaled(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                          d_ff=2048, vocab_size=32_000)
        batch, seq = 32, 512
    else:
        cfg = base.scaled(n_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
                          d_ff=352, vocab_size=2048)
        batch, seq = 8, 64

    ckpt_dir = args.ckpt or tempfile.mkdtemp(prefix="repro_ckpt_")
    job = TrainJob(cfg=cfg, steps=args.steps, batch=batch, seq=seq,
                   accum=2, lr=3e-3, ckpt_dir=ckpt_dir, ckpt_every=50)

    print(f"training {args.arch} ({args.preset}) for {args.steps} steps; "
          f"checkpoints -> {ckpt_dir}")

    def log(step, rec):
        if step % 20 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {rec['loss']:.4f} "
                  f"gnorm {rec['grad_norm']:.3f}")

    params, opt_state, hist = run(job, on_step=log)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")

    # resume from the final checkpoint for 10 extra steps (restart proof)
    job2 = TrainJob(cfg=cfg, steps=args.steps + 10, batch=batch, seq=seq,
                    accum=2, lr=3e-3, ckpt_dir=ckpt_dir, ckpt_every=50)
    _, _, hist2 = run(job2, on_step=None)
    print(f"resumed from step {hist2[0]['step']} "
          f"(loss {hist2[0]['loss']:.4f}) to step {hist2[-1]['step']}")
    if args.ckpt is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
