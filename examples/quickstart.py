"""Quickstart: reproduce the paper's headline result in ~a minute on CPU.

Simulates the Sec. VI synthetic HEC system (Table I EET, 4 machines x 4 task
types, Poisson arrivals) under MM / MSD / MMU / ELARE / FELARE and prints the
energy-latency trade-off plus the fairness picture — Figs. 3, 4, 6, 7 in
miniature. The whole (heuristic x rate x trace) grid runs as ONE jitted
batch via `repro.experiments`.

Run:  PYTHONPATH=src python examples/quickstart.py [--tasks 1000] [--traces 8]
      [--scenario bursty]   # any registered workload scenario
      [--observers timeline,fairness_trajectory]  # engine telemetry
"""
import argparse

import numpy as np

from repro import experiments, scenarios
from repro.core import observe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=800)
    ap.add_argument("--traces", type=int, default=8)
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[2.0, 4.0, 8.0])
    ap.add_argument("--scenario", default="poisson",
                    choices=scenarios.list_scenarios(),
                    help="workload scenario (default: the paper's "
                         "stationary Poisson)")
    ap.add_argument("--observers", default="",
                    help="comma list of engine observers to attach "
                         f"(registered: {','.join(observe.list_observers())})")
    args = ap.parse_args()
    observers = tuple(
        o.strip() for o in args.observers.split(",") if o.strip()
    )

    heuristics = ("MM", "MSD", "MMU", "ELARE", "FELARE")
    spec = experiments.SweepSpec(
        system=None,  # the scenario's own fleet, or the paper 4x4
        scenario=args.scenario,
        rates=tuple(args.rates),
        reps=args.traces,
        n_tasks=args.tasks,
        heuristics=heuristics,
        observers=observers,
    )
    res = experiments.run_sweep(spec)

    print(f"{'heuristic':9s} {'rate':>5s} {'ontime%':>8s} {'waste%':>7s} "
          f"{'cancel':>7s} {'miss':>6s}  per-type completion")
    for h_i, h in enumerate(heuristics):
        for r_i, rate in enumerate(spec.rates):
            m = res.metrics_for(h, rate)
            per_type = " ".join(
                f"{x:.2f}" for x in res.completion_rate_by_type[h_i, r_i])
            print(f"{h:9s} {rate:5.1f} "
                  f"{100 * res.completion_rate_pooled[h_i, r_i]:8.1f} "
                  f"{res.wasted_pct[h_i, r_i]:7.2f} "
                  f"{int(np.sum(m.cancelled_by_type)):7d} "
                  f"{int(np.sum(m.missed_by_type)):6d}  [{per_type}]")
        print()

    if "timeline" in res.aux:
        # a terminal-width sparkline of queue pressure over time, per
        # heuristic at the highest rate (replicate 0)
        blocks = " ▁▂▃▄▅▆▇█"
        print("queue occupancy over time (last rate, replicate 0):")
        for h_i, h in enumerate(heuristics):
            q = res.aux["timeline"]["qlen"][h_i, -1, 0]
            top = max(1, int(q.max()))
            line = "".join(
                blocks[min(8, int(8 * v / top))] for v in q)
            print(f"  {h:9s} |{line}| peak {int(q.max())}")
        print()
    if "fairness_trajectory" in res.aux:
        print("share of time with >=1 suffered task type (last rate):")
        for h_i, h in enumerate(heuristics):
            s = res.aux["fairness_trajectory"]["suffered"][h_i, -1]
            print(f"  {h:9s} {100 * float(s.any(-1).mean()):5.1f}%")
        print()

    print("Expected pattern (the paper's claims):")
    print("  * ELARE/FELARE: far lower waste% at low/moderate rates "
          "(proactive cancellation instead of deadline misses)")
    print("  * FELARE: per-type completion rates pulled together "
          "(fairness) at ~unchanged collective rate")


if __name__ == "__main__":
    main()
