"""Quickstart: reproduce the paper's headline result in ~a minute on CPU.

Simulates the Sec. VI synthetic HEC system (Table I EET, 4 machines x 4 task
types, Poisson arrivals) under MM / MSD / MMU / ELARE / FELARE and prints the
energy-latency trade-off plus the fairness picture — Figs. 3, 4, 6, 7 in
miniature.

Run:  PYTHONPATH=src python examples/quickstart.py [--tasks 1000] [--traces 8]
"""
import argparse

import numpy as np

from repro.core import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tasks", type=int, default=800)
    ap.add_argument("--traces", type=int, default=8)
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[2.0, 4.0, 8.0])
    args = ap.parse_args()

    spec = api.paper_system()
    heuristics = ["MM", "MSD", "MMU", "ELARE", "FELARE"]

    print(f"{'heuristic':9s} {'rate':>5s} {'ontime%':>8s} {'waste%':>7s} "
          f"{'cancel':>7s} {'miss':>6s}  per-type completion")
    for h in heuristics:
        results = api.run_study(h, args.rates, spec, n_traces=args.traces,
                                n_tasks=args.tasks)
        for r in results:
            m = r.metrics
            per_type = " ".join(
                f"{x:.2f}" for x in r.completion_rate_by_type)
            print(f"{h:9s} {r.arrival_rate:5.1f} "
                  f"{100*r.completion_rate:8.1f} "
                  f"{r.wasted_energy_pct:7.2f} "
                  f"{int(np.sum(m.cancelled_by_type)):7d} "
                  f"{int(np.sum(m.missed_by_type)):6d}  [{per_type}]")
        print()

    print("Expected pattern (the paper's claims):")
    print("  * ELARE/FELARE: far lower waste% at low/moderate rates "
          "(proactive cancellation instead of deadline misses)")
    print("  * FELARE: per-type completion rates pulled together "
          "(fairness) at ~unchanged collective rate")


if __name__ == "__main__":
    main()
