"""Benchmark orchestrator: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the harness contract, then a
human-readable block per figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4] [--full]
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks import ablations, paper_figures, roofline_report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (30 traces x 2000 tasks)")
    args = ap.parse_args()

    benches = dict(paper_figures.ALL)
    benches.update(ablations.ALL)
    benches["roofline_table"] = roofline_report.main

    print("name,us_per_call,derived")
    blocks = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows, derived = fn(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{json.dumps(derived, default=float)}", flush=True)
        blocks.append((name, rows, derived))

    for name, rows, derived in blocks:
        print(f"\n=== {name} ===")
        if rows:
            cols = list(rows[0].keys())
            print(" | ".join(f"{c:>12s}" for c in cols))
            for r in rows:
                print(" | ".join(f"{str(r.get(c, '')):>12s}" for c in cols))
        print(f"derived: {json.dumps(derived, default=float)}")

    n_fail = sum(1 for _, _, d in blocks if d.get("pass") is False)
    print(f"\n{len(blocks)} benchmarks; {n_fail} failed claims")


if __name__ == "__main__":
    main()
