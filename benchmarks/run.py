"""Benchmark orchestrator: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the harness contract, then a
human-readable block per figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4] [--full]

``--perf-out DIR`` instead runs the engine perf benchmarks (the hot
vmapped sweep with observers off/on, the federation compile/warm scaling
sweep over F, the tiered edge-cloud network sweep, and the lax-vs-fused
map-decision sweep over N x M) and appends a
``BENCH_<n>.json`` artifact under DIR
— one numbered file per run, so the directory accumulates the project's
wall-clock/compile-time trajectory over time. ``--perf-baseline PATH``
additionally compares the fresh warm times against a checked-in baseline
(``benchmarks/BENCH_1.json`` carries the current reference, including the
per-F federation rows) and *fails* — exit status 1, the blocking CI bench
step — when any warm time exceeds 1.5x its baseline.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import time


def perf_vmapped_sweep(*, reps: int = 4, n_tasks: int = 300,
                       rates=(2.0, 4.0)) -> dict:
    """Wall-clock + compile time of the hot vmapped-sweep path.

    Measures ``engine.simulate_batch`` (the cached ``_simulate_jit``
    entry: cold call = trace+compile+run, warm call = run only) for
    ELARE over a (rates x reps) CRN trace stack, with observers off and
    with the timeline+task_log observers attached, plus one end-to-end
    ``run_sweep`` wall-clock for scale.
    """
    import jax

    from repro import experiments
    from repro.core import api, engine
    from repro.datapipe import synthetic

    system = api.paper_system()
    stacked = synthetic.trace_stack(
        jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
    )
    flat = jax.tree.map(
        lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
    )

    results = []
    for observers in ((), ("timeline", "task_log")):
        # fresh observer instances would share the jit cache across rounds;
        # the cache key includes the observers tuple, so off/on differ.
        t0 = time.perf_counter()
        out = engine.simulate_batch(flat, system, "ELARE",
                                    observers=observers)
        jax.block_until_ready(out)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = engine.simulate_batch(flat, system, "ELARE",
                                    observers=observers)
        jax.block_until_ready(out)
        warm_s = time.perf_counter() - t0
        results.append({
            "observers": list(observers),
            "cold_s": round(cold_s, 4),
            "warm_s": round(warm_s, 4),
            "compile_s": round(cold_s - warm_s, 4),
        })

    spec = experiments.SweepSpec(
        rates=tuple(rates), reps=reps, n_tasks=n_tasks,
        heuristics=("MM", "ELARE", "FELARE"), seed=0,
    )
    t0 = time.perf_counter()
    experiments.run_sweep(spec)
    sweep_s = time.perf_counter() - t0

    return {
        "bench": "vmapped_sweep",
        "unix_time": round(time.time(), 1),
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "ELARE"},
        "simulate_batch": results,
        "run_sweep_3heuristics_s": round(sweep_s, 4),
    }


def perf_federation_scaling(*, site_counts=(1, 2, 8, 32), reps: int = 2,
                            n_tasks: int = 150, rates=(3.0,)) -> dict:
    """Compile/warm wall clock of the batched engine vs site count F.

    Per F, AOT-splits the batched simulator: ``trace_s`` (jaxpr trace +
    lowering), ``compile_s`` (XLA codegen), then a warm run of the
    compiled executable. The masked-vmap site loop (plus the
    block-diagonal reshape fast path for the uniform ``paper_xF`` fleets)
    keeps both flat in F — wider arrays, same program. The derived
    ``compile_ratio_f32_vs_f2`` (on trace+compile, the end-to-end cost of
    a fresh jit) is the ISSUE acceptance metric (<= 1.2, asserted
    wall-clock by ``tests/test_compile_flatness.py``). The F=1 row runs
    first and doubles as the jit/XLA init warmup, so later rows aren't
    credited for one-time setup the first row paid.

    Measured AOT (``jit(...).lower(flat).compile()``) rather than
    cold-minus-warm ``simulate_batch`` calls: first-run dispatch overhead
    pollutes the subtraction by several hundred ms at the large-F end.
    """
    import jax

    from repro import scenarios
    from repro.core import dispatch, engine, policy
    from repro.datapipe import synthetic

    rows = []
    for f_sites in site_counts:
        fleet = "paper" if f_sites == 1 else f"paper_x{f_sites}"
        system = scenarios.get_fleet(fleet).build()
        stacked = synthetic.trace_stack(
            jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
        )
        flat = jax.tree.map(
            lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
        )
        sim = engine.make_simulator(
            policy.get("ELARE"), system.as_jax(),
            queue_size=system.queue_size,
            fairness_factor=float(system.fairness_factor),
            dispatcher=(dispatch.resolve("round_robin")
                        if f_sites > 1 else None),
            site_of_machine=system.sites,
        )
        trace_s = compile_s = float("inf")
        for rep in range(2):
            # min-of-2 against scheduler noise; the second repeat trims a
            # task so its HLO differs, dodging the in-process executable
            # cache (an identical program would "compile" in ~0s).
            fr = (flat if rep == 0 else
                  jax.tree.map(lambda x: x[:, :-1] if x.ndim > 1 else x,
                               flat))
            t0 = time.perf_counter()
            lowered = jax.jit(jax.vmap(sim)).lower(fr)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
            trace_s = min(trace_s, t1 - t0)
            compile_s = min(compile_s, t2 - t1)
        jax.block_until_ready(compiled(fr))  # first run: alloc + dispatch
        t0w = time.perf_counter()
        jax.block_until_ready(compiled(fr))
        warm_s = time.perf_counter() - t0w
        rows.append({
            "n_sites": f_sites,
            "n_machines": system.n_machines,
            "trace_s": round(trace_s, 4),
            "compile_s": round(compile_s, 4),
            "warm_s": round(warm_s, 4),
        })
    by_f = {r["n_sites"]: r for r in rows}

    def total(r):
        return r["trace_s"] + r["compile_s"]

    ratio = (total(by_f[32]) / total(by_f[2])
             if 2 in by_f and 32 in by_f else None)
    return {
        "bench": "federation_scaling",
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "ELARE", "dispatcher": "round_robin"},
        "sites": rows,
        "compile_ratio_f32_vs_f2":
            None if ratio is None else round(ratio, 3),
    }


def perf_tiered_sweep(*, reps: int = 4, n_tasks: int = 300,
                      rates=(2.0, 4.0)) -> dict:
    """Warm/cold wall clock of the tiered edge-cloud network path.

    Same shape as :func:`perf_vmapped_sweep` but on the ``tiered_x4``
    fleet with the ``tiered`` network model and the ``tier_aware``
    dispatcher — the full per-link ready-time/energy machinery inside the
    single jit. Its warm row is gated against ``benchmarks/BENCH_1.json``
    like every other configuration.
    """
    import jax

    from repro import scenarios
    from repro.core import engine
    from repro.datapipe import synthetic

    system = scenarios.get_fleet("tiered_x4").build()
    stacked = synthetic.trace_stack(
        jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
    )
    flat = jax.tree.map(
        lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
    )
    t0 = time.perf_counter()
    out = engine.simulate_batch(flat, system, "FELARE",
                                dispatcher="tier_aware", network="tiered")
    jax.block_until_ready(out)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = engine.simulate_batch(flat, system, "FELARE",
                                dispatcher="tier_aware", network="tiered")
    jax.block_until_ready(out)
    warm_s = time.perf_counter() - t0
    return {
        "bench": "tiered_sweep",
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "FELARE", "fleet": "tiered_x4",
                   "dispatcher": "tier_aware", "network": "tiered"},
        "cold_s": round(cold_s, 4),
        "warm_s": round(warm_s, 4),
        "compile_s": round(cold_s - warm_s, 4),
    }


def _fused_map_pair(n_tasks, n_machines, *, interpret, seed=0,
                    heuristic="FELARE", n_types=4, queue_slots=2):
    """Jitted lax/fused select closures + their random raw inputs.

    Both closures rebuild the SchedContext from the same raw arrays, so
    timing them head-to-head isolates the map-decision math — Eq. 1/2
    grids, nomination, phase-2 keys, drops, the FELARE eviction stats —
    which is exactly what the fused kernel replaces.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import policy
    from repro.core.policy.context import MachineView, SchedContext
    from repro.core.types import SystemArrays

    r = np.random.default_rng(seed)
    n, m, s, q = n_tasks, n_machines, n_types, queue_slots
    raw = dict(
        now=jnp.float32(25.0),
        pending=jnp.asarray(r.integers(0, 2, n).astype(bool)),
        task_type=jnp.asarray(r.integers(0, s, n).astype(np.int32)),
        deadline=jnp.asarray(r.uniform(0, 120, n).astype(np.float32)),
        avail_base=jnp.asarray(r.uniform(0, 60, m).astype(np.float32)),
        queue=jnp.asarray(
            np.where(np.arange(q)[None, :] < r.integers(0, q + 1, m)[:, None],
                     r.integers(0, n, (m, q)), -1).astype(np.int32)),
        eet=jnp.asarray(r.uniform(0.5, 20, (s, m)).astype(np.float32)),
        p_dyn=jnp.asarray(r.uniform(1, 10, m).astype(np.float32)),
        p_idle=jnp.asarray(r.uniform(0.1, 1, m).astype(np.float32)),
        suffered=jnp.asarray(r.integers(0, 2, s).astype(bool)),
    )
    raw["qlen"] = (raw["queue"] >= 0).sum(axis=1).astype(jnp.int32)

    def make(pol):
        def f(now, pending, task_type, deadline, avail_base, queue, qlen,
              eet, p_dyn, p_idle, suffered):
            ctx = SchedContext(
                now=now, pending=pending, task_type=task_type,
                deadline=deadline,
                view=MachineView(avail_base, queue, qlen),
                sysarr=SystemArrays(eet=eet, p_dyn=p_dyn, p_idle=p_idle),
                suffered=suffered)
            act = pol.select(ctx)
            return act.assign, act.drop, act.queue_drop
        return jax.jit(f)

    order = ("now", "pending", "task_type", "deadline", "avail_base",
             "queue", "qlen", "eet", "p_dyn", "p_idle", "suffered")
    args = tuple(raw[k] for k in order)
    lax_fn = make(policy.get(heuristic))
    fused_fn = make(policy.with_pallas_map(heuristic, interpret=interpret))
    return lax_fn, fused_fn, args


def perf_fused_map(*, shapes=((100, 8), (1000, 64), (10000, 512))) -> dict:
    """Lax-vs-fused warm wall clock of the map decision over (N x M).

    Per shape, jits the full FELARE ``select`` (context rebuild + decision)
    both ways on identical random inputs, asserts output parity, then
    times warm calls. On CPU the fused path runs the Pallas kernels in
    interpret mode — parity is still asserted but the timing comparison
    would measure the interpreter, so rows carry ``status: "skipped"``
    and no speedup is claimed (the 1.5x gate only reads ``"ok"`` rows).
    """
    import time as _time

    import jax
    import numpy as np

    from repro.kernels import pallas_backend

    interpret = pallas_backend.default_interpret()
    mode = "interpret" if interpret else "compiled"
    rows = []
    for n, m in shapes:
        lax_fn, fused_fn, args = _fused_map_pair(n, m, interpret=interpret)
        out_lax = jax.block_until_ready(lax_fn(*args))
        out_fused = jax.block_until_ready(fused_fn(*args))
        parity = all(np.array_equal(np.asarray(a), np.asarray(b))
                     for a, b in zip(out_lax, out_fused))
        row = {"n_tasks": n, "n_machines": m, "mode": mode,
               "parity": bool(parity)}
        if interpret:
            row["status"] = "skipped"
        else:
            reps = max(3, min(100, int(2e6 / (n * m))))
            timed = {}
            for tag, fn in (("lax", lax_fn), ("fused", fused_fn)):
                jax.block_until_ready(fn(*args))
                t0 = _time.perf_counter()
                for _ in range(reps):
                    out = fn(*args)
                jax.block_until_ready(out)
                timed[tag] = (_time.perf_counter() - t0) / reps
            row.update({
                "status": "ok", "reps": reps,
                "lax_warm_s": round(timed["lax"], 6),
                "fused_warm_s": round(timed["fused"], 6),
                "speedup": round(timed["lax"] / timed["fused"], 3),
            })
        rows.append(row)
    return {
        "bench": "fused_map",
        "config": {"heuristic": "FELARE", "mode": mode},
        "shapes": rows,
        "parity_all": all(r["parity"] for r in rows),
    }


def fused_parity_smoke() -> bool:
    """Quick lax-vs-fused parity check (the CI pre-gate smoke).

    Select-level parity at two shapes plus a dispatcher balance-scan
    parity row; returns False on any mismatch. Runs in interpret mode on
    CPU so CI exercises the exact kernel bodies the compiled path runs.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.dispatch.base import DispatchContext, sequential_balance
    from repro.kernels import map_fused, pallas_backend

    interpret = pallas_backend.default_interpret()
    ok = True
    for n, m in ((100, 8), (130, 129)):
        lax_fn, fused_fn, args = _fused_map_pair(n, m, interpret=interpret,
                                                 seed=n)
        out_lax = jax.block_until_ready(lax_fn(*args))
        out_fused = jax.block_until_ready(fused_fn(*args))
        good = all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(out_lax, out_fused))
        print(f"  select parity N={n} M={m}: {'ok' if good else 'MISMATCH'}")
        ok = ok and good

    r = np.random.default_rng(7)
    n, m, f, s = 90, 12, 3, 4
    site = np.sort(np.r_[np.arange(f), r.integers(0, f, m - f)])
    ctx = DispatchContext(
        now=jnp.float32(10.0),
        unassigned=jnp.asarray(r.integers(0, 2, n).astype(bool)),
        task_type=jnp.asarray(r.integers(0, s, n).astype(np.int32)),
        deadline=jnp.asarray(r.uniform(0, 120, n).astype(np.float32)),
        qlen=jnp.asarray(r.integers(0, 3, m).astype(np.int32)),
        running=jnp.asarray(r.integers(0, 2, m).astype(bool)),
        completed=jnp.asarray(r.integers(0, 20, s).astype(np.int32)),
        arrived=jnp.asarray(r.integers(20, 40, s).astype(np.int32)),
        eet=jnp.asarray(r.uniform(0.5, 20, (s, m)).astype(np.float32)),
        site_of_machine=site,
        n_sites=f,
        fairness_factor=1.0,
        alive=None,
    )
    target = jnp.asarray(r.integers(0, 2, n).astype(bool))
    home = jnp.asarray(r.integers(0, f, n).astype(np.int32))
    want = np.asarray(sequential_balance(ctx, target, home))
    got = np.asarray(sequential_balance(
        ctx, target, home,
        lambda l0, un, tgt, hm: map_fused.balance_scan(
            l0, un, tgt, hm, interpret=interpret)))
    good = np.array_equal(want, got)
    print(f"  balance parity N={n} F={f}: {'ok' if good else 'MISMATCH'}")
    return ok and good


def write_perf_artifact(outdir, baseline=None,
                        allow_new_rows=False) -> pathlib.Path:
    """Run the perf benches and write the next ``BENCH_<n>.json`` in outdir.

    With ``baseline`` (a prior BENCH_*.json, e.g. the checked-in
    ``benchmarks/BENCH_1.json``), compares warm times per configuration
    and exits nonzero when any exceeds ``WARM_TOLERANCE`` x its baseline
    — the blocking CI perf gate.
    """
    outdir = pathlib.Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    seen = [int(m.group(1)) for p in outdir.glob("BENCH_*.json")
            if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))]
    path = outdir / f"BENCH_{max(seen, default=-1) + 1}.json"
    payload = perf_vmapped_sweep()
    payload["federation_scaling"] = perf_federation_scaling()
    payload["tiered_sweep"] = perf_tiered_sweep()
    payload["fused_map"] = perf_fused_map()
    path.write_text(json.dumps(payload, indent=2))
    print(json.dumps(payload, indent=2))
    print(f"wrote {path}")
    if not payload["fused_map"]["parity_all"]:
        print("FAIL: fused map kernel disagrees with the lax path")
        raise SystemExit(1)
    if baseline and not compare_to_baseline(payload, baseline,
                                            allow_new_rows=allow_new_rows):
        raise SystemExit(1)
    return path


#: Blocking warm-time regression tolerance vs the checked-in baseline.
WARM_TOLERANCE = 1.5


def compare_to_baseline(payload: dict, baseline,
                        allow_new_rows: bool = False) -> bool:
    """Compare warm times of ``payload`` vs a baseline BENCH JSON.

    Returns False (the CI-blocking verdict) when any matched
    configuration — observer rows of the vmapped sweep, per-F rows of the
    federation scaling bench, timed ``fused_map`` rows — regresses past
    ``WARM_TOLERANCE`` x its baseline warm time, or when a payload row
    has no baseline counterpart: a silently unmatched row is an ungated
    benchmark, so new rows fail loudly until either the baseline is
    refreshed or ``allow_new_rows`` opts them in (the ``--allow-new-rows``
    flag, for the PR that introduces a bench). A missing baseline file
    passes (first run on a fresh checkout).
    """
    baseline = pathlib.Path(baseline)
    if not baseline.exists():
        print(f"perf baseline {baseline} not found; skipping comparison")
        return True
    base = json.loads(baseline.read_text())
    ok = True
    new_rows = []

    def check(tag, warm, ref):
        nonlocal ok
        ref_warm = ref.get("warm_s") if ref else None
        if not ref_warm:
            new_rows.append(tag)
            return
        ratio = warm / ref_warm
        bad = ratio > WARM_TOLERANCE
        ok = ok and not bad
        print(f"  {tag:40s} {warm:.3f}s vs {ref_warm:.3f}s "
              f"({ratio:.2f}x){' REGRESSION' if bad else ''}")

    base_by_obs = {tuple(r["observers"]): r
                   for r in base.get("simulate_batch", ())}
    print(f"\nwarm-time vs baseline {baseline} "
          f"(blocking at {WARM_TOLERANCE}x):")
    for row in payload["simulate_batch"]:
        check("observers=" + (",".join(row["observers"]) or "off"),
              row["warm_s"], base_by_obs.get(tuple(row["observers"])))
    fed = payload.get("federation_scaling", {}).get("sites", ())
    base_by_f = {r["n_sites"]: r
                 for r in base.get("federation_scaling", {})
                             .get("sites", ())}
    for row in fed:
        check(f"federation F={row['n_sites']}", row["warm_s"],
              base_by_f.get(row["n_sites"]))
    tiered = payload.get("tiered_sweep")
    if tiered:
        check("tiered_x4 network=tiered", tiered["warm_s"],
              base.get("tiered_sweep"))
    base_by_nm = {(r["n_tasks"], r["n_machines"]): r
                  for r in base.get("fused_map", {}).get("shapes", ())
                  if r.get("status") == "ok"}
    for row in payload.get("fused_map", {}).get("shapes", ()):
        if row.get("status") != "ok":
            continue  # interpret-mode parity-only rows carry no timing
        key = (row["n_tasks"], row["n_machines"])
        check(f"fused_map N={key[0]} M={key[1]}", row["fused_warm_s"],
              base_by_nm.get(key))
    if new_rows and not allow_new_rows:
        ok = False
        for tag in new_rows:
            print(f"  {tag:40s} NO BASELINE ROW")
        print("FAIL: benchmark rows missing from the baseline — refresh "
              "the checked-in BENCH json or pass --allow-new-rows")
    if not ok:
        print(f"FAIL: perf gate vs {WARM_TOLERANCE}x baseline")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (30 traces x 2000 tasks)")
    ap.add_argument("--perf-out", default=None, metavar="DIR",
                    help="run only the engine perf benchmark and append a "
                         "BENCH_<n>.json artifact under DIR")
    ap.add_argument("--perf-baseline", default=None, metavar="PATH",
                    help="with --perf-out: compare warm times against this "
                         "prior BENCH_<n>.json (e.g. the checked-in "
                         "benchmarks/BENCH_1.json) and exit nonzero past "
                         f"{WARM_TOLERANCE}x (the blocking CI gate)")
    ap.add_argument("--allow-new-rows", action="store_true",
                    help="with --perf-baseline: tolerate payload rows with "
                         "no baseline counterpart (for the PR introducing a "
                         "bench) instead of failing loudly")
    ap.add_argument("--fused-parity-smoke", action="store_true",
                    help="run only the fused-vs-lax kernel parity smoke "
                         "(the CI step ahead of the blocking perf gate) and "
                         "exit nonzero on mismatch")
    args = ap.parse_args()

    if args.fused_parity_smoke:
        print("fused-vs-lax parity smoke:")
        if not fused_parity_smoke():
            raise SystemExit(1)
        return

    if args.perf_out:
        write_perf_artifact(args.perf_out, baseline=args.perf_baseline,
                            allow_new_rows=args.allow_new_rows)
        return

    from benchmarks import ablations, paper_figures, roofline_report

    benches = dict(paper_figures.ALL)
    benches.update(ablations.ALL)
    benches["roofline_table"] = roofline_report.main
    benches["roofline_map_stage"] = roofline_report.map_stage

    print("name,us_per_call,derived")
    blocks = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows, derived = fn(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{json.dumps(derived, default=float)}", flush=True)
        blocks.append((name, rows, derived))

    for name, rows, derived in blocks:
        print(f"\n=== {name} ===")
        if rows:
            cols = list(rows[0].keys())
            print(" | ".join(f"{c:>12s}" for c in cols))
            for r in rows:
                print(" | ".join(f"{str(r.get(c, '')):>12s}" for c in cols))
        print(f"derived: {json.dumps(derived, default=float)}")

    n_fail = sum(1 for _, _, d in blocks if d.get("pass") is False)
    print(f"\n{len(blocks)} benchmarks; {n_fail} failed claims")


if __name__ == "__main__":
    main()
