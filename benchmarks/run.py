"""Benchmark orchestrator: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the harness contract, then a
human-readable block per figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4] [--full]

``--perf-out DIR`` instead runs the engine perf benchmark (the hot
vmapped sweep, observers off/on) and appends a ``BENCH_<n>.json``
artifact under DIR — one numbered file per run, so the directory
accumulates the project's wall-clock/compile-time trajectory over time.
``--perf-baseline PATH`` additionally compares the fresh warm time
against a checked-in baseline (``benchmarks/BENCH_0.json`` is the first)
and prints the ratio — informational, never failing, matching the
non-blocking CI bench step.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import time


def perf_vmapped_sweep(*, reps: int = 4, n_tasks: int = 300,
                       rates=(2.0, 4.0)) -> dict:
    """Wall-clock + compile time of the hot vmapped-sweep path.

    Measures ``engine.simulate_batch`` (the cached ``_simulate_jit``
    entry: cold call = trace+compile+run, warm call = run only) for
    ELARE over a (rates x reps) CRN trace stack, with observers off and
    with the timeline+task_log observers attached, plus one end-to-end
    ``run_sweep`` wall-clock for scale.
    """
    import jax

    from repro import experiments
    from repro.core import api, engine
    from repro.datapipe import synthetic

    system = api.paper_system()
    stacked = synthetic.trace_stack(
        jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
    )
    flat = jax.tree.map(
        lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
    )

    results = []
    for observers in ((), ("timeline", "task_log")):
        # fresh observer instances would share the jit cache across rounds;
        # the cache key includes the observers tuple, so off/on differ.
        t0 = time.perf_counter()
        out = engine.simulate_batch(flat, system, "ELARE",
                                    observers=observers)
        jax.block_until_ready(out)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = engine.simulate_batch(flat, system, "ELARE",
                                    observers=observers)
        jax.block_until_ready(out)
        warm_s = time.perf_counter() - t0
        results.append({
            "observers": list(observers),
            "cold_s": round(cold_s, 4),
            "warm_s": round(warm_s, 4),
            "compile_s": round(cold_s - warm_s, 4),
        })

    spec = experiments.SweepSpec(
        rates=tuple(rates), reps=reps, n_tasks=n_tasks,
        heuristics=("MM", "ELARE", "FELARE"), seed=0,
    )
    t0 = time.perf_counter()
    experiments.run_sweep(spec)
    sweep_s = time.perf_counter() - t0

    return {
        "bench": "vmapped_sweep",
        "unix_time": round(time.time(), 1),
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "ELARE"},
        "simulate_batch": results,
        "run_sweep_3heuristics_s": round(sweep_s, 4),
    }


def write_perf_artifact(outdir, baseline=None) -> pathlib.Path:
    """Run the perf bench and write the next ``BENCH_<n>.json`` in outdir.

    With ``baseline`` (a prior BENCH_*.json, e.g. the checked-in
    ``benchmarks/BENCH_0.json``), prints a warm-time comparison per
    observer configuration — informational only, never raises.
    """
    outdir = pathlib.Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    seen = [int(m.group(1)) for p in outdir.glob("BENCH_*.json")
            if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))]
    path = outdir / f"BENCH_{max(seen, default=-1) + 1}.json"
    payload = perf_vmapped_sweep()
    path.write_text(json.dumps(payload, indent=2))
    print(json.dumps(payload, indent=2))
    print(f"wrote {path}")
    if baseline:
        compare_to_baseline(payload, baseline)
    return path


def compare_to_baseline(payload: dict, baseline) -> None:
    """Print warm-time ratios of ``payload`` vs a baseline BENCH JSON."""
    baseline = pathlib.Path(baseline)
    if not baseline.exists():
        print(f"perf baseline {baseline} not found; skipping comparison")
        return
    base = json.loads(baseline.read_text())
    base_by_obs = {tuple(r["observers"]): r
                   for r in base.get("simulate_batch", ())}
    print(f"\nwarm-time vs baseline {baseline}:")
    for row in payload["simulate_batch"]:
        ref = base_by_obs.get(tuple(row["observers"]))
        if not ref or not ref.get("warm_s"):
            continue
        ratio = row["warm_s"] / ref["warm_s"]
        tag = "observers=" + (",".join(row["observers"]) or "off")
        print(f"  {tag:40s} {row['warm_s']:.3f}s vs {ref['warm_s']:.3f}s "
              f"({ratio:.2f}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (30 traces x 2000 tasks)")
    ap.add_argument("--perf-out", default=None, metavar="DIR",
                    help="run only the engine perf benchmark and append a "
                         "BENCH_<n>.json artifact under DIR")
    ap.add_argument("--perf-baseline", default=None, metavar="PATH",
                    help="with --perf-out: compare warm times against this "
                         "prior BENCH_<n>.json (e.g. the checked-in "
                         "benchmarks/BENCH_0.json); informational only")
    args = ap.parse_args()

    if args.perf_out:
        write_perf_artifact(args.perf_out, baseline=args.perf_baseline)
        return

    from benchmarks import ablations, paper_figures, roofline_report

    benches = dict(paper_figures.ALL)
    benches.update(ablations.ALL)
    benches["roofline_table"] = roofline_report.main

    print("name,us_per_call,derived")
    blocks = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows, derived = fn(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{json.dumps(derived, default=float)}", flush=True)
        blocks.append((name, rows, derived))

    for name, rows, derived in blocks:
        print(f"\n=== {name} ===")
        if rows:
            cols = list(rows[0].keys())
            print(" | ".join(f"{c:>12s}" for c in cols))
            for r in rows:
                print(" | ".join(f"{str(r.get(c, '')):>12s}" for c in cols))
        print(f"derived: {json.dumps(derived, default=float)}")

    n_fail = sum(1 for _, _, d in blocks if d.get("pass") is False)
    print(f"\n{len(blocks)} benchmarks; {n_fail} failed claims")


if __name__ == "__main__":
    main()
