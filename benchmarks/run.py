"""Benchmark orchestrator: one function per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the harness contract, then a
human-readable block per figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4] [--full]

``--perf-out DIR`` instead runs the engine perf benchmarks (the hot
vmapped sweep with observers off/on, the federation compile/warm scaling
sweep over F, and the tiered edge-cloud network sweep) and appends a
``BENCH_<n>.json`` artifact under DIR
— one numbered file per run, so the directory accumulates the project's
wall-clock/compile-time trajectory over time. ``--perf-baseline PATH``
additionally compares the fresh warm times against a checked-in baseline
(``benchmarks/BENCH_1.json`` carries the current reference, including the
per-F federation rows) and *fails* — exit status 1, the blocking CI bench
step — when any warm time exceeds 1.5x its baseline.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import time


def perf_vmapped_sweep(*, reps: int = 4, n_tasks: int = 300,
                       rates=(2.0, 4.0)) -> dict:
    """Wall-clock + compile time of the hot vmapped-sweep path.

    Measures ``engine.simulate_batch`` (the cached ``_simulate_jit``
    entry: cold call = trace+compile+run, warm call = run only) for
    ELARE over a (rates x reps) CRN trace stack, with observers off and
    with the timeline+task_log observers attached, plus one end-to-end
    ``run_sweep`` wall-clock for scale.
    """
    import jax

    from repro import experiments
    from repro.core import api, engine
    from repro.datapipe import synthetic

    system = api.paper_system()
    stacked = synthetic.trace_stack(
        jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
    )
    flat = jax.tree.map(
        lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
    )

    results = []
    for observers in ((), ("timeline", "task_log")):
        # fresh observer instances would share the jit cache across rounds;
        # the cache key includes the observers tuple, so off/on differ.
        t0 = time.perf_counter()
        out = engine.simulate_batch(flat, system, "ELARE",
                                    observers=observers)
        jax.block_until_ready(out)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = engine.simulate_batch(flat, system, "ELARE",
                                    observers=observers)
        jax.block_until_ready(out)
        warm_s = time.perf_counter() - t0
        results.append({
            "observers": list(observers),
            "cold_s": round(cold_s, 4),
            "warm_s": round(warm_s, 4),
            "compile_s": round(cold_s - warm_s, 4),
        })

    spec = experiments.SweepSpec(
        rates=tuple(rates), reps=reps, n_tasks=n_tasks,
        heuristics=("MM", "ELARE", "FELARE"), seed=0,
    )
    t0 = time.perf_counter()
    experiments.run_sweep(spec)
    sweep_s = time.perf_counter() - t0

    return {
        "bench": "vmapped_sweep",
        "unix_time": round(time.time(), 1),
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "ELARE"},
        "simulate_batch": results,
        "run_sweep_3heuristics_s": round(sweep_s, 4),
    }


def perf_federation_scaling(*, site_counts=(1, 2, 8, 32), reps: int = 2,
                            n_tasks: int = 150, rates=(3.0,)) -> dict:
    """Compile/warm wall clock of the batched engine vs site count F.

    Per F, AOT-splits the batched simulator: ``trace_s`` (jaxpr trace +
    lowering), ``compile_s`` (XLA codegen), then a warm run of the
    compiled executable. The masked-vmap site loop (plus the
    block-diagonal reshape fast path for the uniform ``paper_xF`` fleets)
    keeps both flat in F — wider arrays, same program. The derived
    ``compile_ratio_f32_vs_f2`` (on trace+compile, the end-to-end cost of
    a fresh jit) is the ISSUE acceptance metric (<= 1.2, asserted
    wall-clock by ``tests/test_compile_flatness.py``). The F=1 row runs
    first and doubles as the jit/XLA init warmup, so later rows aren't
    credited for one-time setup the first row paid.

    Measured AOT (``jit(...).lower(flat).compile()``) rather than
    cold-minus-warm ``simulate_batch`` calls: first-run dispatch overhead
    pollutes the subtraction by several hundred ms at the large-F end.
    """
    import jax

    from repro import scenarios
    from repro.core import dispatch, engine, policy
    from repro.datapipe import synthetic

    rows = []
    for f_sites in site_counts:
        fleet = "paper" if f_sites == 1 else f"paper_x{f_sites}"
        system = scenarios.get_fleet(fleet).build()
        stacked = synthetic.trace_stack(
            jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
        )
        flat = jax.tree.map(
            lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
        )
        sim = engine.make_simulator(
            policy.get("ELARE"), system.as_jax(),
            queue_size=system.queue_size,
            fairness_factor=float(system.fairness_factor),
            dispatcher=(dispatch.resolve("round_robin")
                        if f_sites > 1 else None),
            site_of_machine=system.sites,
        )
        trace_s = compile_s = float("inf")
        for rep in range(2):
            # min-of-2 against scheduler noise; the second repeat trims a
            # task so its HLO differs, dodging the in-process executable
            # cache (an identical program would "compile" in ~0s).
            fr = (flat if rep == 0 else
                  jax.tree.map(lambda x: x[:, :-1] if x.ndim > 1 else x,
                               flat))
            t0 = time.perf_counter()
            lowered = jax.jit(jax.vmap(sim)).lower(fr)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            t2 = time.perf_counter()
            trace_s = min(trace_s, t1 - t0)
            compile_s = min(compile_s, t2 - t1)
        jax.block_until_ready(compiled(fr))  # first run: alloc + dispatch
        t0w = time.perf_counter()
        jax.block_until_ready(compiled(fr))
        warm_s = time.perf_counter() - t0w
        rows.append({
            "n_sites": f_sites,
            "n_machines": system.n_machines,
            "trace_s": round(trace_s, 4),
            "compile_s": round(compile_s, 4),
            "warm_s": round(warm_s, 4),
        })
    by_f = {r["n_sites"]: r for r in rows}

    def total(r):
        return r["trace_s"] + r["compile_s"]

    ratio = (total(by_f[32]) / total(by_f[2])
             if 2 in by_f and 32 in by_f else None)
    return {
        "bench": "federation_scaling",
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "ELARE", "dispatcher": "round_robin"},
        "sites": rows,
        "compile_ratio_f32_vs_f2":
            None if ratio is None else round(ratio, 3),
    }


def perf_tiered_sweep(*, reps: int = 4, n_tasks: int = 300,
                      rates=(2.0, 4.0)) -> dict:
    """Warm/cold wall clock of the tiered edge-cloud network path.

    Same shape as :func:`perf_vmapped_sweep` but on the ``tiered_x4``
    fleet with the ``tiered`` network model and the ``tier_aware``
    dispatcher — the full per-link ready-time/energy machinery inside the
    single jit. Its warm row is gated against ``benchmarks/BENCH_1.json``
    like every other configuration.
    """
    import jax

    from repro import scenarios
    from repro.core import engine
    from repro.datapipe import synthetic

    system = scenarios.get_fleet("tiered_x4").build()
    stacked = synthetic.trace_stack(
        jax.random.PRNGKey(0), tuple(rates), reps, n_tasks, system.eet
    )
    flat = jax.tree.map(
        lambda x: x.reshape((len(rates) * reps,) + x.shape[2:]), stacked
    )
    t0 = time.perf_counter()
    out = engine.simulate_batch(flat, system, "FELARE",
                                dispatcher="tier_aware", network="tiered")
    jax.block_until_ready(out)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = engine.simulate_batch(flat, system, "FELARE",
                                dispatcher="tier_aware", network="tiered")
    jax.block_until_ready(out)
    warm_s = time.perf_counter() - t0
    return {
        "bench": "tiered_sweep",
        "config": {"reps": reps, "n_tasks": n_tasks, "rates": list(rates),
                   "heuristic": "FELARE", "fleet": "tiered_x4",
                   "dispatcher": "tier_aware", "network": "tiered"},
        "cold_s": round(cold_s, 4),
        "warm_s": round(warm_s, 4),
        "compile_s": round(cold_s - warm_s, 4),
    }


def write_perf_artifact(outdir, baseline=None) -> pathlib.Path:
    """Run the perf benches and write the next ``BENCH_<n>.json`` in outdir.

    With ``baseline`` (a prior BENCH_*.json, e.g. the checked-in
    ``benchmarks/BENCH_1.json``), compares warm times per configuration
    and exits nonzero when any exceeds ``WARM_TOLERANCE`` x its baseline
    — the blocking CI perf gate.
    """
    outdir = pathlib.Path(outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    seen = [int(m.group(1)) for p in outdir.glob("BENCH_*.json")
            if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))]
    path = outdir / f"BENCH_{max(seen, default=-1) + 1}.json"
    payload = perf_vmapped_sweep()
    payload["federation_scaling"] = perf_federation_scaling()
    payload["tiered_sweep"] = perf_tiered_sweep()
    path.write_text(json.dumps(payload, indent=2))
    print(json.dumps(payload, indent=2))
    print(f"wrote {path}")
    if baseline and not compare_to_baseline(payload, baseline):
        raise SystemExit(1)
    return path


#: Blocking warm-time regression tolerance vs the checked-in baseline.
WARM_TOLERANCE = 1.5


def compare_to_baseline(payload: dict, baseline) -> bool:
    """Compare warm times of ``payload`` vs a baseline BENCH JSON.

    Returns False (the CI-blocking verdict) when any matched
    configuration — observer rows of the vmapped sweep, per-F rows of the
    federation scaling bench — regresses past ``WARM_TOLERANCE`` x its
    baseline warm time. A missing baseline file passes (first run on a
    fresh checkout).
    """
    baseline = pathlib.Path(baseline)
    if not baseline.exists():
        print(f"perf baseline {baseline} not found; skipping comparison")
        return True
    base = json.loads(baseline.read_text())
    ok = True

    def check(tag, warm, ref_warm):
        nonlocal ok
        if not ref_warm:
            return
        ratio = warm / ref_warm
        bad = ratio > WARM_TOLERANCE
        ok = ok and not bad
        print(f"  {tag:40s} {warm:.3f}s vs {ref_warm:.3f}s "
              f"({ratio:.2f}x){' REGRESSION' if bad else ''}")

    base_by_obs = {tuple(r["observers"]): r
                   for r in base.get("simulate_batch", ())}
    print(f"\nwarm-time vs baseline {baseline} "
          f"(blocking at {WARM_TOLERANCE}x):")
    for row in payload["simulate_batch"]:
        ref = base_by_obs.get(tuple(row["observers"]))
        if ref:
            check("observers=" + (",".join(row["observers"]) or "off"),
                  row["warm_s"], ref.get("warm_s"))
    fed = payload.get("federation_scaling", {}).get("sites", ())
    base_by_f = {r["n_sites"]: r
                 for r in base.get("federation_scaling", {})
                             .get("sites", ())}
    for row in fed:
        ref = base_by_f.get(row["n_sites"])
        if ref:
            check(f"federation F={row['n_sites']}", row["warm_s"],
                  ref.get("warm_s"))
    tiered = payload.get("tiered_sweep")
    base_tiered = base.get("tiered_sweep")
    if tiered and base_tiered:
        check("tiered_x4 network=tiered", tiered["warm_s"],
              base_tiered.get("warm_s"))
    if not ok:
        print(f"FAIL: warm time regressed past {WARM_TOLERANCE}x baseline")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (30 traces x 2000 tasks)")
    ap.add_argument("--perf-out", default=None, metavar="DIR",
                    help="run only the engine perf benchmark and append a "
                         "BENCH_<n>.json artifact under DIR")
    ap.add_argument("--perf-baseline", default=None, metavar="PATH",
                    help="with --perf-out: compare warm times against this "
                         "prior BENCH_<n>.json (e.g. the checked-in "
                         "benchmarks/BENCH_1.json) and exit nonzero past "
                         f"{WARM_TOLERANCE}x (the blocking CI gate)")
    args = ap.parse_args()

    if args.perf_out:
        write_perf_artifact(args.perf_out, baseline=args.perf_baseline)
        return

    from benchmarks import ablations, paper_figures, roofline_report

    benches = dict(paper_figures.ALL)
    benches.update(ablations.ALL)
    benches["roofline_table"] = roofline_report.main

    print("name,us_per_call,derived")
    blocks = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows, derived = fn(full=args.full)
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},{json.dumps(derived, default=float)}", flush=True)
        blocks.append((name, rows, derived))

    for name, rows, derived in blocks:
        print(f"\n=== {name} ===")
        if rows:
            cols = list(rows[0].keys())
            print(" | ".join(f"{c:>12s}" for c in cols))
            for r in rows:
                print(" | ".join(f"{str(r.get(c, '')):>12s}" for c in cols))
        print(f"derived: {json.dumps(derived, default=float)}")

    n_fail = sum(1 for _, _, d in blocks if d.get("pass") is False)
    print(f"\n{len(blocks)} benchmarks; {n_fail} failed claims")


if __name__ == "__main__":
    main()
