"""Beyond-paper ablations: fairness-factor aggressiveness (Eq. 3), local
queue depth, the widened heuristic pool, battery-lifetime analysis, and
fault tolerance under a mid-trace site outage."""
from __future__ import annotations

import numpy as np

from repro.core import api


def fairness_factor_sweep(full=False):
    """Eq. 3's f controls aggressiveness: f->large disables fairness (FELARE
    -> ELARE); f small over-triggers. Sweep f at the paper's fairness
    operating point (rate 5)."""
    rows = []
    spread = {}
    for f in (0.25, 0.5, 1.0, 2.0, 4.0):
        spec = api.paper_system(fairness_factor=f)
        res = api.run_study("FELARE", [5.0], spec,
                            n_traces=20 if full else 6,
                            n_tasks=2000 if full else 500)[0]
        cr = res.completion_rate_by_type
        rows.append({"fig": "ablation-f", "f": f,
                     "std": round(float(np.std(cr)), 4),
                     "collective": round(res.completion_rate, 3)})
        spread[f] = float(np.std(cr))
    derived = {
        "claim": "larger f = less aggressive fairness (Eq. 3 discussion)",
        "std_f025": round(spread[0.25], 4),
        "std_f4": round(spread[4.0], 4),
        "pass": spread[0.25] <= spread[4.0] + 0.02,
    }
    return rows, derived


def queue_depth_sweep(full=False):
    """Bounded local queues (Sec. III): deeper queues commit earlier to
    stale estimates; shallower ones keep the mapper reactive."""
    rows = []
    for q in (1, 2, 4, 8):
        spec = api.paper_system(queue_size=q)
        res = api.run_study("ELARE", [4.0], spec,
                            n_traces=12 if full else 5,
                            n_tasks=2000 if full else 500)[0]
        rows.append({"fig": "ablation-q", "queue": q,
                     "completion": round(res.completion_rate, 3),
                     "wasted_pct": round(res.wasted_energy_pct, 2)})
    derived = {"claim": "queue depth trades reactivity vs pipelining",
               "pass": True}
    return rows, derived


def heuristic_pool(full=False):
    """Widened baseline pool (MET / MCT / RANDOM added to the paper's
    MM / MSD / MMU): ELARE/FELARE should dominate all of them on waste."""
    spec = api.paper_system()
    rows, waste = [], {}
    pool = ("RANDOM", "MET", "MCT", "MM", "MSD", "MMU", "ELARE", "FELARE")
    for h in pool:
        res = api.run_study(h, [4.0], spec,
                            n_traces=12 if full else 5,
                            n_tasks=2000 if full else 500)[0]
        rows.append({"fig": "ablation-pool", "heuristic": h,
                     "completion": round(res.completion_rate, 3),
                     "wasted_pct": round(res.wasted_energy_pct, 2)})
        waste[h] = res.wasted_energy_pct
    best_base = min(waste[h] for h in pool[:6])
    derived = {
        "claim": "ELARE/FELARE waste less than every baseline",
        "elare_wasted": round(waste["ELARE"], 2),
        "best_baseline_wasted": round(best_base, 2),
        "pass": waste["ELARE"] <= best_base and waste["FELARE"] <= best_base,
    }
    return rows, derived


def battery_lifetime(full=False):
    """The motivating metric (Sec. I): how long does the battery last?

    lifetime ~= E0 / average draw; with the same request load served, lower
    waste => longer uptime. E0 normalized to 1 hour of full-load draw."""
    spec = api.paper_system()
    p_full = float(np.sum(spec.p_dyn))
    e0 = p_full * 3600.0
    rows = {}
    out = []
    for h in ("MM", "ELARE", "FELARE"):
        res = api.run_study(h, [4.0], spec,
                            n_traces=12 if full else 5,
                            n_tasks=2000 if full else 500)[0]
        m = res.metrics
        draw = float(np.mean(np.asarray(m.energy_dynamic)
                             + np.asarray(m.energy_idle)))
        span = float(np.mean(np.asarray(m.makespan)))
        avg_power = draw / max(span, 1e-9)
        life_h = e0 / avg_power / 3600.0
        served = res.completion_rate
        out.append({"fig": "ablation-battery", "heuristic": h,
                    "avg_power_p": round(avg_power, 2),
                    "lifetime_h": round(life_h, 2),
                    "completion": round(served, 3)})
        rows[h] = (life_h, served)
    derived = {
        "claim": "energy-aware mapping extends system uptime at equal or "
                 "better service (the SmartSight usability argument)",
        "mm_lifetime_h": round(rows["MM"][0], 2),
        "elare_lifetime_h": round(rows["ELARE"][0], 2),
        "pass": rows["ELARE"][0] >= rows["MM"][0]
        and rows["ELARE"][1] >= rows["MM"][1],
    }
    return out, derived


def fault_tolerance_outage(full=False):
    """Mid-trace site outage (faults subsystem): health-blind sticky
    dispatch keeps feeding the dead site; the health-masked dispatchers
    route around it. The checked-in reference numbers live in
    ``benchmarks/FAULTS_BASELINE.json`` (regenerate with
    ``python -m benchmarks.ablations``)."""
    from repro import scenarios
    from repro.core import faults, policy

    if not policy.is_registered("FELARE_B1"):
        policy.register("FELARE_B1", faults.with_backup("FELARE", k=1))
    spec = scenarios.get_fleet("paper_x4").build()
    outage = faults.SiteOutage(outages=((0, 0.25, 0.5),))
    rows, ontime = [], {}
    grid = [("sticky", "FELARE", None),
            ("sticky", "FELARE", outage),
            ("fair_spill", "FELARE", outage),
            ("health_aware", "FELARE", outage),
            ("health_aware", "FELARE_B1", outage)]
    for disp, heuristic, dyn in grid:
        res = api.run_study(heuristic, [6.0], spec,
                            n_traces=12 if full else 6,
                            n_tasks=2000 if full else 400,
                            dispatcher=disp,
                            dynamics=dyn if dyn is not None else "none")[0]
        tag = (f"{disp}+backup1" if heuristic == "FELARE_B1" else disp) + \
              ("" if dyn is None else "/outage")
        rows.append({"fig": "ablation-faults", "config": tag,
                     "completion": round(res.completion_rate, 4)})
        ontime[tag] = res.completion_rate
    derived = {
        "claim": "health-masked dispatch beats health-blind sticky under a "
                 "mid-trace site outage",
        "sticky_outage": round(ontime["sticky/outage"], 4),
        "fair_spill_outage": round(ontime["fair_spill/outage"], 4),
        "health_aware_outage": round(ontime["health_aware/outage"], 4),
        "pass": (ontime["health_aware/outage"] > ontime["sticky/outage"]
                 and ontime["fair_spill/outage"] > ontime["sticky/outage"]),
    }
    return rows, derived


def tiered_network(full=False):
    """Edge-cloud hierarchy (network subsystem): cross-tier links price the
    dispatch decision. Two claims: FELARE's fairness margin over ELARE must
    survive on a tiered fleet, and the network-blind ``fair_spill``
    dispatcher must lose on-time rate to the link-cost-aware ``tier_aware``
    one under cross-tier latency. The checked-in reference numbers live in
    ``benchmarks/TIERS_BASELINE.json`` (regenerate with
    ``python -m benchmarks.ablations``)."""
    from repro import scenarios
    from repro.core import network

    spec = scenarios.get_fleet("tiered_x4").build()
    # Latency-dominated regime: under the default matrices the half-speed
    # cloud is a net win even after the 1 s hop, so blind spilling is fine
    # there. Raising the cross-tier latencies past the deadline slack is
    # what separates link-cost-aware dispatch from network-blind dispatch.
    harsh = network.Tiered(
        latency=((0.05, 1.0, 6.0), (1.0, 0.05, 4.0), (6.0, 4.0, 0.0)),
        energy=((0.1, 0.5, 2.0), (0.5, 0.1, 1.0), (2.0, 1.0, 0.0)))
    rows = {}
    out = []
    grid = [("ELARE", "tier_aware"), ("FELARE", "tier_aware"),
            ("FELARE", "fair_spill")]
    for heuristic, disp in grid:
        res = api.run_study(heuristic, [6.0], spec,
                            n_traces=12 if full else 6,
                            n_tasks=2000 if full else 400,
                            dispatcher=disp, network=harsh)[0]
        cr = res.completion_rate_by_type
        tag = f"{heuristic}/{disp}"
        out.append({"fig": "ablation-tiers", "config": tag,
                    "completion": round(res.completion_rate, 4),
                    "fairness_std": round(float(np.std(cr)), 4)})
        rows[tag] = (res.completion_rate, float(np.std(cr)))
    derived = {
        "claim": "FELARE's fairness margin survives on a tiered fleet and "
                 "link-cost-aware dispatch beats network-blind spilling "
                 "under cross-tier latency",
        "felare_fairness_std": round(rows["FELARE/tier_aware"][1], 4),
        "elare_fairness_std": round(rows["ELARE/tier_aware"][1], 4),
        "tier_aware_ontime": round(rows["FELARE/tier_aware"][0], 4),
        "fair_spill_ontime": round(rows["FELARE/fair_spill"][0], 4),
        "pass": (rows["FELARE/tier_aware"][1]
                 <= rows["ELARE/tier_aware"][1] + 0.02
                 and rows["FELARE/tier_aware"][0]
                 > rows["FELARE/fair_spill"][0]),
    }
    return out, derived


ALL = {
    "ablation_fairness_factor": fairness_factor_sweep,
    "ablation_queue_depth": queue_depth_sweep,
    "ablation_heuristic_pool": heuristic_pool,
    "ablation_battery_lifetime": battery_lifetime,
    "ablation_fault_tolerance": fault_tolerance_outage,
    "ablation_tiered_network": tiered_network,
}


def main() -> None:
    """Write the checked-in fault-tolerance and tiered-network artifacts."""
    import json
    import pathlib

    failed = False
    for name, fn, fname in (
            ("fault_tolerance_outage", fault_tolerance_outage,
             "FAULTS_BASELINE.json"),
            ("tiered_network", tiered_network, "TIERS_BASELINE.json")):
        rows, derived = fn()
        payload = {"bench": name, "rows": rows, "derived": derived}
        path = pathlib.Path(__file__).parent / fname
        path.write_text(json.dumps(payload, indent=2) + "\n")
        print(json.dumps(payload, indent=2))
        print(f"wrote {path}")
        failed = failed or not derived["pass"]
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
