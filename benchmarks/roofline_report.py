"""Roofline table from the dry-run artifacts (results/dryrun/cells.jsonl)."""
from __future__ import annotations

import json
import pathlib

CELLS = pathlib.Path("results/dryrun/cells.jsonl")


def load(path=CELLS):
    recs = []
    if not pathlib.Path(path).exists():
        return recs
    for line in pathlib.Path(path).read_text().splitlines():
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    # last record per cell wins (re-runs supersede)
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    return list(by_key.values())


def table(mesh="pod", path=CELLS):
    rows = []
    for r in load(path):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "SKIP", "note": r["reason"][:40]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "FAIL", "note": r.get("error", "")[:40]})
            continue
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_comp_ms": round(ro["t_comp_s"] * 1e3, 2),
            "t_mem_ms": round(ro["t_mem_s"] * 1e3, 2),
            "t_coll_ms": round(ro["t_coll_s"] * 1e3, 2),
            "dominant": ro["dominant"],
            "useful_frac": round(ro["useful_frac"], 3),
            "mfu": round(ro["mfu"], 4),
        })
    return rows


def summary(path=CELLS):
    recs = load(path)
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skip")
    fail = sum(1 for r in recs if r["status"] == "fail")
    return {"fig": "roofline", "cells_ok": ok, "cells_skip": skip,
            "cells_fail": fail, "pass": fail == 0 and ok > 0}


def main(full=False):
    # prefer the optimized sweep when present; fall back to the baseline
    final = pathlib.Path("results/dryrun_final/cells.jsonl")
    path = final if final.exists() else CELLS
    rows = table("pod", path)
    derived = summary(path)
    derived["source"] = str(path)
    return rows, derived
