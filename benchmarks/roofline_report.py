"""Roofline table from the dry-run artifacts (results/dryrun/cells.jsonl)."""
from __future__ import annotations

import json
import pathlib

CELLS = pathlib.Path("results/dryrun/cells.jsonl")


def load(path=CELLS):
    recs = []
    if not pathlib.Path(path).exists():
        return recs
    for line in pathlib.Path(path).read_text().splitlines():
        try:
            recs.append(json.loads(line))
        except json.JSONDecodeError:
            pass
    # last record per cell wins (re-runs supersede)
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    return list(by_key.values())


def table(mesh="pod", path=CELLS):
    rows = []
    for r in load(path):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "SKIP", "note": r["reason"][:40]})
            continue
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "FAIL", "note": r.get("error", "")[:40]})
            continue
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_comp_ms": round(ro["t_comp_s"] * 1e3, 2),
            "t_mem_ms": round(ro["t_mem_s"] * 1e3, 2),
            "t_coll_ms": round(ro["t_coll_s"] * 1e3, 2),
            "dominant": ro["dominant"],
            "useful_frac": round(ro["useful_frac"], 3),
            "mfu": round(ro["mfu"], 4),
        })
    return rows


def summary(path=CELLS):
    recs = load(path)
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skip")
    fail = sum(1 for r in recs if r["status"] == "fail")
    return {"fig": "roofline", "cells_ok": ok, "cells_skip": skip,
            "cells_fail": fail, "pass": fail == 0 and ok > 0}


def main(full=False):
    # prefer the optimized sweep when present; fall back to the baseline
    final = pathlib.Path("results/dryrun_final/cells.jsonl")
    path = final if final.exists() else CELLS
    rows = table("pod", path)
    derived = summary(path)
    derived["source"] = str(path)
    return rows, derived


def map_stage(full=False):
    """Trip-exact FLOP/byte model of the fused map-decision kernel.

    Traces :func:`repro.kernels.map_fused.map_decide` (the single-pass
    Pallas decision kernel) through :func:`repro.roofline.jaxpr_cost` at
    representative (N tasks x M machines) grid shapes — the shared
    ``jaxpr_walk`` visitor descends into the ``pallas_call`` kernel body
    with the grid size as the trip multiplier, so the numbers cover the
    whole tiled sweep, not one tile. The derived arithmetic intensity
    (flops/byte) is what justifies the kernel's VMEM-residency claim:
    the EET grid is read once per decision, everything else is O(N + M).
    """
    import jax.numpy as jnp

    from repro.kernels import map_fused
    from repro.roofline.jaxpr_cost import jaxpr_cost

    shapes = [(100, 8), (1000, 64)] + ([(10000, 512)] if full else [])
    n_types = 4
    rows = []
    for n, m in shapes:
        cost = jaxpr_cost(
            map_fused.map_decide,
            jnp.float32(0.0),                      # now
            jnp.zeros((m,), jnp.float32),          # start
            jnp.ones((m,), jnp.float32),           # p_dyn
            jnp.ones((m,), bool),                  # qfree
            jnp.ones((n_types, m), jnp.float32),   # eet
            jnp.ones((n,), jnp.float32),           # deadline
            jnp.ones((n,), bool),                  # pending
            jnp.zeros((n,), jnp.int32),            # task_type
            jnp.zeros((n,), bool),                 # suffered_task
            nominator="min_energy_feasible", phase2_key="urgency",
            drop_rule="stale_hopeless", interpret=True,
        )
        rows.append({
            "n_tasks": n, "n_machines": m,
            "flops": cost["flops"], "bytes": cost["bytes"],
            "matmul_flops": cost["matmul_flops"],
            "ai_flops_per_byte": round(cost["flops"] / max(cost["bytes"], 1),
                                       3),
        })
    derived = {
        "fig": "map_stage_roofline", "shapes": len(rows),
        "pass": all(r["flops"] > 0 and r["bytes"] > 0 for r in rows),
    }
    return rows, derived
