"""One benchmark per paper figure/table.

Each function returns (rows, derived) where rows are CSV-ready dicts and
`derived` echoes the paper's headline claim next to our measurement.
Sizes are scaled (default 5 traces x 600 tasks vs the paper's 30 x 2000) to
finish on 1 CPU core; pass full=True for paper-scale runs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import api

HEURISTICS = ("MM", "MSD", "MMU", "ELARE", "FELARE")


def _study(h, rates, spec, full):
    return api.run_study(
        h, rates, spec,
        n_traces=30 if full else 5,
        n_tasks=2000 if full else 600,
    )


def fig3_pareto(full=False):
    """Energy vs deadline-miss-rate trade-off curves (Pareto front)."""
    spec = api.paper_system()
    rates = [1.0, 2.0, 3.0, 4.0, 6.0, 8.0]
    rows = []
    pts = {}
    for h in HEURISTICS:
        for r in _study(h, rates, spec, full):
            rows.append({
                "fig": "3", "heuristic": h, "rate": r.arrival_rate,
                "miss_rate": round(r.miss_rate, 4),
                "energy": round(r.energy_total, 1),
            })
            pts.setdefault(h, []).append((r.miss_rate, r.energy_total))
    # non-domination check: at each low/moderate rate, no baseline may have
    # both <= miss-rate and <= energy (strictly better in one). Cross-rate
    # comparisons are meaningless here (lower arrival rate => longer trace
    # => more idle energy at identical service), so we compare per rate —
    # the within-curve reading of the paper's Fig. 3.
    dominated = 0
    for ri in range(4):  # low-to-moderate rates
        for h in ("ELARE", "FELARE"):
            m, e = pts[h][ri]
            for h2 in ("MM", "MSD", "MMU"):
                m2, e2 = pts[h2][ri]
                if m2 <= m + 1e-9 and e2 <= e + 1e-9 and (
                        m2 < m - 1e-3 or e2 < e - 1e-3):
                    dominated += 1
    derived = {
        "claim": "ELARE/FELARE non-dominated at low-moderate rates",
        "dominated_points": dominated,
        "pass": dominated == 0,
    }
    return rows, derived


def fig4_wasted_energy(full=False):
    """Wasted energy vs arrival rate, all heuristics (synthetic system)."""
    spec = api.paper_system()
    rates = [1.0, 2.0, 3.0, 4.0, 6.0, 8.0]
    rows, waste = [], {}
    for h in HEURISTICS:
        for r in _study(h, rates, spec, full):
            w = r.wasted_energy_pct
            rows.append({"fig": "4", "heuristic": h, "rate": r.arrival_rate,
                         "wasted_pct": round(w, 2)})
            waste[(h, r.arrival_rate)] = w
    rel = (waste[("MM", 4.0)] - waste[("ELARE", 4.0)])
    derived = {
        "claim": "paper: ELARE ~12.6% less wasted energy than MM @rate 4",
        "measured_delta_pct_points": round(rel, 2),
        "pass": rel > 0,
    }
    return rows, derived


def fig5_aws_wasted(full=False):
    """AWS scenario (face/speech on t2.xlarge vs g3s.xlarge): wasted energy."""
    spec = api.aws_system()
    rates = [0.5, 1.0, 2.0, 3.0]
    rows, waste = [], {}
    for h in ("MM", "ELARE", "FELARE"):
        for r in _study(h, rates, spec, full):
            rows.append({"fig": "5", "heuristic": h, "rate": r.arrival_rate,
                         "wasted_pct": round(r.wasted_energy_pct, 2)})
            waste[(h, r.arrival_rate)] = r.wasted_energy_pct
    derived = {
        "claim": "AWS scenario agrees with synthetic (ELARE wastes less)",
        "mm_minus_elare_at_2": round(
            waste[("MM", 2.0)] - waste[("ELARE", 2.0)], 2),
        "pass": waste[("ELARE", 2.0)] <= waste[("MM", 2.0)],
    }
    return rows, derived


def fig6_unsuccessful(full=False):
    """Cancelled vs missed decomposition, MM vs ELARE (proactive dropping)."""
    spec = api.paper_system()
    rates = [2.0, 3.0, 4.0, 6.0, 8.0]
    rows, stats = [], {}
    for h in ("MM", "ELARE"):
        for r in _study(h, rates, spec, full):
            m = r.metrics
            arrived = float(np.sum(m.arrived_by_type))
            cancelled = float(np.sum(m.cancelled_by_type)) / arrived * 100
            missed = float(np.sum(m.missed_by_type)) / arrived * 100
            rows.append({"fig": "6", "heuristic": h, "rate": r.arrival_rate,
                         "cancelled_pct": round(cancelled, 2),
                         "missed_pct": round(missed, 2),
                         "unsuccessful_pct": round(cancelled + missed, 2)})
            stats[(h, r.arrival_rate)] = (cancelled, missed)
    delta = (stats[("MM", 3.0)][0] + stats[("MM", 3.0)][1]
             - stats[("ELARE", 3.0)][0] - stats[("ELARE", 3.0)][1])
    derived = {
        "claim": "paper: ELARE reduces unsuccessful tasks ~8.9% @rate 3; "
                 "ELARE cancels, MM misses",
        "measured_delta_pct_points": round(delta, 2),
        "elare_mostly_cancels": stats[("ELARE", 4.0)][0]
        > stats[("ELARE", 4.0)][1],
        "mm_mostly_misses": stats[("MM", 4.0)][1] > stats[("MM", 4.0)][0],
        "pass": delta > 0,
    }
    return rows, derived


def fig7_fairness(full=False):
    """Per-type + collective completion rates for all heuristics @rate 5."""
    spec = api.paper_system()
    rows, spread, coll = [], {}, {}
    for h in HEURISTICS:
        res = api.run_study(h, [5.0], spec,
                            n_traces=30 if full else 10,
                            n_tasks=2000 if full else 600)[0]
        cr = res.completion_rate_by_type
        rows.append({
            "fig": "7", "heuristic": h,
            **{f"T{i+1}": round(float(c), 3) for i, c in enumerate(cr)},
            "collective": round(res.completion_rate, 3),
            "std": round(float(np.std(cr)), 4),
        })
        spread[h] = float(np.std(cr))
        coll[h] = res.completion_rate
    # NOTE: a baseline can show a small spread by being uniformly *bad*
    # (the paper's category (ii): "similar but low"); fairness only counts
    # at a competitive collective rate, so FELARE is judged against
    # heuristics within 10 pts of the best collective completion.
    best_coll = max(coll.values())
    competitive = {h for h in coll if coll[h] >= best_coll - 0.10}
    derived = {
        "claim": "FELARE: fairest per-type spread among competitive "
                 "heuristics, negligible collective loss",
        "felare_std": round(spread["FELARE"], 4),
        "elare_std": round(spread["ELARE"], 4),
        "collective_delta": round(coll["FELARE"] - coll["ELARE"], 4),
        "competitive": sorted(competitive),
        "pass": spread["FELARE"] == min(spread[h] for h in competitive)
        and coll["FELARE"] >= coll["ELARE"] - 0.05,
    }
    return rows, derived


def fig8_aws_fairness(full=False):
    """AWS scenario fairness across face/speech applications @rate 2."""
    spec = api.aws_system()
    rows, spread = [], {}
    for h in HEURISTICS:
        res = api.run_study(h, [2.0], spec,
                            n_traces=10 if not full else 30,
                            n_tasks=600 if not full else 2000)[0]
        cr = res.completion_rate_by_type
        rows.append({"fig": "8", "heuristic": h,
                     "face": round(float(cr[0]), 3),
                     "speech": round(float(cr[1]), 3),
                     "collective": round(res.completion_rate, 3)})
        spread[h] = abs(float(cr[0] - cr[1]))
    derived = {
        "claim": "FELARE substantially fairer on the AWS pair",
        "felare_gap": round(spread["FELARE"], 4),
        "min_baseline_gap": round(
            min(spread[h] for h in ("MM", "MSD", "MMU")), 4),
        "pass": spread["FELARE"] <= min(
            spread[h] for h in ("MM", "MSD", "MMU")) + 0.02,
    }
    return rows, derived


def table_overhead(full=False):
    """Scheduler decision latency — the 'lightweight' claim (Sec. I).

    Measures one jitted mapping event (vectorized over a 2000-task arriving
    queue) and the per-task share.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import heuristics
    from repro.core.heuristics import MachineView
    from repro.core.types import SystemArrays
    from repro.core.eet import P_DYN, P_IDLE, TABLE_I

    sysarr = SystemArrays(jnp.asarray(TABLE_I), jnp.asarray(P_DYN),
                          jnp.asarray(P_IDLE))
    N = 2000
    key = jax.random.PRNGKey(0)
    ttype = jax.random.randint(key, (N,), 0, 4)
    dl = jax.random.uniform(key, (N,), minval=1.0, maxval=20.0)
    pending = jnp.ones((N,), bool)
    view = MachineView(jnp.zeros(4), jnp.full((4, 2), -1, jnp.int32),
                       jnp.zeros(4, jnp.int32))
    suffered = jnp.zeros(4, bool)
    rows = []
    for name in HEURISTICS:
        fn = jax.jit(lambda *a, f=heuristics.get(name): f(*a))
        out = fn(0.0, pending, ttype, dl, view, sysarr, suffered)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 50
        for _ in range(reps):
            out = fn(0.0, pending, ttype, dl, view, sysarr, suffered)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"fig": "overhead", "heuristic": name,
                     "us_per_event": round(us, 1),
                     "ns_per_task": round(us * 1000 / N, 1)})
    worst = max(r["us_per_event"] for r in rows)
    derived = {
        "claim": "mapping overhead must not worsen system performance",
        "worst_event_us": worst,
        "pass": worst < 100_000,  # < 0.1 ms per queued task at N=2000
    }
    return rows, derived


ALL = {
    "fig3_pareto": fig3_pareto,
    "fig4_wasted_energy": fig4_wasted_energy,
    "fig5_aws_wasted": fig5_aws_wasted,
    "fig6_unsuccessful": fig6_unsuccessful,
    "fig7_fairness": fig7_fairness,
    "fig8_aws_fairness": fig8_aws_fairness,
    "table_overhead": table_overhead,
}
