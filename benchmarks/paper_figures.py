"""One benchmark per paper figure/table.

Each function returns (rows, derived) where rows are CSV-ready dicts and
`derived` echoes the paper's headline claim next to our measurement.
Sizes are scaled (default 5 traces x 600 tasks vs the paper's 30 x 2000) to
finish on 1 CPU core; pass full=True for paper-scale runs.

All figures are thin consumers of `repro.experiments`: each one is a single
batched sweep (every heuristic x rate x replicate in one jitted vmap), and
the rows below just read the SweepResult reductions.
"""
from __future__ import annotations

import time

import numpy as np

from repro import experiments

HEURISTICS = ("MM", "MSD", "MMU", "ELARE", "FELARE")


_SWEEP_CACHE: dict = {}


def _sweep(heuristics, rates, system, full, *, reps=None, tasks=None,
           seed=0, scenario="poisson", observers=()):
    """One batched sweep: the whole figure's grid in one jit+vmap.

    Memoized on the full grid key — figures that read different reductions
    of the same grid (e.g. Figs. 3 and 4) share one simulation. The
    ``scenario`` axis (registered name from :mod:`repro.scenarios`) lets
    beyond-paper benchmarks reuse the same machinery under bursty /
    diurnal / heavy-tail workloads; the ``observers`` axis
    (:mod:`repro.core.observe`) attaches time-resolved telemetry.
    """
    spec = experiments.SweepSpec(
        system=system,
        scenario=scenario,
        rates=tuple(float(r) for r in rates),
        reps=reps if reps is not None else (30 if full else 5),
        n_tasks=tasks if tasks is not None else (2000 if full else 600),
        heuristics=tuple(heuristics),
        seed=seed,
        observers=tuple(observers),
    )
    if spec not in _SWEEP_CACHE:  # frozen dataclass: hashable, collision-proof
        _SWEEP_CACHE[spec] = experiments.run_sweep(spec)
    return _SWEEP_CACHE[spec]


def fig3_pareto(full=False):
    """Energy vs deadline-miss-rate trade-off curves (Pareto front)."""
    rates = [1.0, 2.0, 3.0, 4.0, 6.0, 8.0]
    res = _sweep(HEURISTICS, rates, "paper", full)
    miss = 1.0 - res.completion_rate_pooled            # (H, R)
    energy = res.energy                                # (H, R)
    rows = []
    pts = {}
    for h_i, h in enumerate(HEURISTICS):
        for r_i, rate in enumerate(rates):
            rows.append({
                "fig": "3", "heuristic": h, "rate": rate,
                "miss_rate": round(float(miss[h_i, r_i]), 4),
                "energy": round(float(energy[h_i, r_i]), 1),
            })
            pts.setdefault(h, []).append(
                (float(miss[h_i, r_i]), float(energy[h_i, r_i])))
    # non-domination check: at each low/moderate rate, no baseline may have
    # both <= miss-rate and <= energy (strictly better in one). Cross-rate
    # comparisons are meaningless here (lower arrival rate => longer trace
    # => more idle energy at identical service), so we compare per rate —
    # the within-curve reading of the paper's Fig. 3.
    dominated = 0
    for ri in range(4):  # low-to-moderate rates
        for h in ("ELARE", "FELARE"):
            m, e = pts[h][ri]
            for h2 in ("MM", "MSD", "MMU"):
                m2, e2 = pts[h2][ri]
                if m2 <= m + 1e-9 and e2 <= e + 1e-9 and (
                        m2 < m - 1e-3 or e2 < e - 1e-3):
                    dominated += 1
    derived = {
        "claim": "ELARE/FELARE non-dominated at low-moderate rates",
        "dominated_points": dominated,
        "pass": dominated == 0,
    }
    return rows, derived


def fig4_wasted_energy(full=False):
    """Wasted energy vs arrival rate, all heuristics (synthetic system)."""
    rates = [1.0, 2.0, 3.0, 4.0, 6.0, 8.0]
    res = _sweep(HEURISTICS, rates, "paper", full)
    wasted = res.wasted_pct                            # (H, R)
    rows = [
        {"fig": "4", "heuristic": h, "rate": rate,
         "wasted_pct": round(float(wasted[h_i, r_i]), 2)}
        for h_i, h in enumerate(HEURISTICS)
        for r_i, rate in enumerate(rates)
    ]
    rel = float(wasted[HEURISTICS.index("MM"), rates.index(4.0)]
                - wasted[HEURISTICS.index("ELARE"), rates.index(4.0)])
    derived = {
        "claim": "paper: ELARE ~12.6% less wasted energy than MM @rate 4",
        "measured_delta_pct_points": round(rel, 2),
        "pass": rel > 0,
    }
    return rows, derived


def fig5_aws_wasted(full=False):
    """AWS scenario (face/speech on t2.xlarge vs g3s.xlarge): wasted energy."""
    hs = ("MM", "ELARE", "FELARE")
    rates = [0.5, 1.0, 2.0, 3.0]
    res = _sweep(hs, rates, "aws", full)
    wasted = res.wasted_pct
    rows = [
        {"fig": "5", "heuristic": h, "rate": rate,
         "wasted_pct": round(float(wasted[h_i, r_i]), 2)}
        for h_i, h in enumerate(hs)
        for r_i, rate in enumerate(rates)
    ]
    mm_at_2 = float(wasted[hs.index("MM"), rates.index(2.0)])
    elare_at_2 = float(wasted[hs.index("ELARE"), rates.index(2.0)])
    derived = {
        "claim": "AWS scenario agrees with synthetic (ELARE wastes less)",
        "mm_minus_elare_at_2": round(mm_at_2 - elare_at_2, 2),
        "pass": elare_at_2 <= mm_at_2,
    }
    return rows, derived


def fig6_unsuccessful(full=False):
    """Cancelled vs missed decomposition, MM vs ELARE (proactive dropping)."""
    hs = ("MM", "ELARE")
    rates = [2.0, 3.0, 4.0, 6.0, 8.0]
    res = _sweep(hs, rates, "paper", full)
    cancelled, missed = res.cancelled_pct, res.missed_pct   # (H, R)
    rows, stats = [], {}
    for h_i, h in enumerate(hs):
        for r_i, rate in enumerate(rates):
            c = float(cancelled[h_i, r_i])
            m = float(missed[h_i, r_i])
            rows.append({"fig": "6", "heuristic": h, "rate": rate,
                         "cancelled_pct": round(c, 2),
                         "missed_pct": round(m, 2),
                         "unsuccessful_pct": round(c + m, 2)})
            stats[(h, rate)] = (c, m)
    delta = (stats[("MM", 3.0)][0] + stats[("MM", 3.0)][1]
             - stats[("ELARE", 3.0)][0] - stats[("ELARE", 3.0)][1])
    derived = {
        "claim": "paper: ELARE reduces unsuccessful tasks ~8.9% @rate 3; "
                 "ELARE cancels, MM misses",
        "measured_delta_pct_points": round(delta, 2),
        "elare_mostly_cancels": stats[("ELARE", 4.0)][0]
        > stats[("ELARE", 4.0)][1],
        "mm_mostly_misses": stats[("MM", 4.0)][1] > stats[("MM", 4.0)][0],
        "pass": delta > 0,
    }
    return rows, derived


def fig7_fairness(full=False):
    """Per-type + collective completion rates for all heuristics @rate 5."""
    res = _sweep(HEURISTICS, [5.0], "paper", full,
                 reps=30 if full else 10, tasks=2000 if full else 600)
    by_type = res.completion_rate_by_type[:, 0]        # (H, S)
    coll_arr = res.completion_rate_pooled[:, 0]        # (H,)
    rows, spread, coll = [], {}, {}
    for h_i, h in enumerate(HEURISTICS):
        cr = by_type[h_i]
        rows.append({
            "fig": "7", "heuristic": h,
            **{f"T{i+1}": round(float(c), 3) for i, c in enumerate(cr)},
            "collective": round(float(coll_arr[h_i]), 3),
            "std": round(float(np.std(cr)), 4),
        })
        spread[h] = float(np.std(cr))
        coll[h] = float(coll_arr[h_i])
    # NOTE: a baseline can show a small spread by being uniformly *bad*
    # (the paper's category (ii): "similar but low"); fairness only counts
    # at a competitive collective rate, so FELARE is judged against
    # heuristics within 10 pts of the best collective completion.
    best_coll = max(coll.values())
    competitive = {h for h in coll if coll[h] >= best_coll - 0.10}
    derived = {
        "claim": "FELARE: fairest per-type spread among competitive "
                 "heuristics, negligible collective loss",
        "felare_std": round(spread["FELARE"], 4),
        "elare_std": round(spread["ELARE"], 4),
        "collective_delta": round(coll["FELARE"] - coll["ELARE"], 4),
        "competitive": sorted(competitive),
        "pass": spread["FELARE"] == min(spread[h] for h in competitive)
        and coll["FELARE"] >= coll["ELARE"] - 0.05,
    }
    return rows, derived


def fig8_aws_fairness(full=False):
    """AWS scenario fairness across face/speech applications @rate 2."""
    res = _sweep(HEURISTICS, [2.0], "aws", full,
                 reps=30 if full else 10, tasks=2000 if full else 600)
    by_type = res.completion_rate_by_type[:, 0]        # (H, 2)
    coll = res.completion_rate_pooled[:, 0]
    rows, spread = [], {}
    for h_i, h in enumerate(HEURISTICS):
        cr = by_type[h_i]
        rows.append({"fig": "8", "heuristic": h,
                     "face": round(float(cr[0]), 3),
                     "speech": round(float(cr[1]), 3),
                     "collective": round(float(coll[h_i]), 3)})
        spread[h] = abs(float(cr[0] - cr[1]))
    derived = {
        "claim": "FELARE substantially fairer on the AWS pair",
        "felare_gap": round(spread["FELARE"], 4),
        "min_baseline_gap": round(
            min(spread[h] for h in ("MM", "MSD", "MMU")), 4),
        "pass": spread["FELARE"] <= min(
            spread[h] for h in ("MM", "MSD", "MMU")) + 0.02,
    }
    return rows, derived


def table_overhead(full=False):
    """Scheduler decision latency — the 'lightweight' claim (Sec. I).

    Measures one jitted mapping event (vectorized over a 2000-task arriving
    queue) and the per-task share.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import heuristics
    from repro.core.heuristics import MachineView
    from repro.core.types import SystemArrays
    from repro.core.eet import P_DYN, P_IDLE, TABLE_I

    sysarr = SystemArrays(jnp.asarray(TABLE_I), jnp.asarray(P_DYN),
                          jnp.asarray(P_IDLE))
    N = 2000
    key = jax.random.PRNGKey(0)
    ttype = jax.random.randint(key, (N,), 0, 4)
    dl = jax.random.uniform(key, (N,), minval=1.0, maxval=20.0)
    pending = jnp.ones((N,), bool)
    view = MachineView(jnp.zeros(4), jnp.full((4, 2), -1, jnp.int32),
                       jnp.zeros(4, jnp.int32))
    suffered = jnp.zeros(4, bool)
    rows = []
    for name in HEURISTICS:
        fn = jax.jit(lambda *a, f=heuristics.get(name): f(*a))
        out = fn(0.0, pending, ttype, dl, view, sysarr, suffered)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        reps = 50
        for _ in range(reps):
            out = fn(0.0, pending, ttype, dl, view, sysarr, suffered)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"fig": "overhead", "heuristic": name,
                     "us_per_event": round(us, 1),
                     "ns_per_task": round(us * 1000 / N, 1)})
    worst = max(r["us_per_event"] for r in rows)
    derived = {
        "claim": "mapping overhead must not worsen system performance",
        "worst_event_us": worst,
        "pass": worst < 100_000,  # < 0.1 ms per queued task at N=2000
    }
    return rows, derived


def scenario_stress(full=False):
    """Beyond-paper: the headline comparison under non-Poisson workloads.

    The paper only evaluates stationary Poisson arrivals; related work
    (Madej et al., Zhang et al.) stresses that priority/fair edge
    schedulers are sensitive to burstiness and heterogeneity. This
    benchmark replays the MM-vs-ELARE/FELARE comparison at one moderate
    rate under each registered stress scenario.
    """
    hs = ("MM", "ELARE", "FELARE")
    scenario_names = ("poisson", "bursty", "diurnal", "flash-crowd",
                      "heavy-tail", "tight-deadlines")
    rows, ontime = [], {}
    for scn in scenario_names:
        res = _sweep(hs, [3.0], "paper", full, scenario=scn)
        for h_i, h in enumerate(hs):
            cr = float(res.completion_rate_pooled[h_i, 0])
            rows.append({
                "fig": "scenario-stress", "scenario": scn, "heuristic": h,
                "rate": 3.0,
                "completion_rate": round(cr, 4),
                "wasted_pct": round(float(res.wasted_pct[h_i, 0]), 2),
                "jain": round(float(res.jain_index[h_i, 0]), 4),
            })
            ontime[(scn, h)] = cr
    # ELARE's proactive-drop advantage over MM should survive (or grow)
    # under every stressed workload at this moderate rate.
    margins = {scn: ontime[(scn, "ELARE")] - ontime[(scn, "MM")]
               for scn in scenario_names}
    derived = {
        "claim": "ELARE >= MM on-time completion under non-Poisson stress",
        "elare_minus_mm_by_scenario": {
            k: round(v, 4) for k, v in margins.items()},
        "pass": all(v >= -0.02 for v in margins.values()),
    }
    return rows, derived


def fairness_trajectory(full=False):
    """Beyond-paper: the Fig. 7 fairness picture resolved *over time*.

    Attaches the ``fairness_trajectory`` + ``timeline`` observers to the
    ELARE-vs-FELARE comparison at the Fig. 7 operating point and reads the
    suffered-type indicator per time bucket: how long each policy leaves
    some task type below the fairness limit ε = μ − f·σ (Alg. 4). Also a
    consistency check that the time series really is the engine's own
    state: the final timeline bucket must equal the end-of-trace Metrics.
    """
    hs = ("ELARE", "FELARE")
    res = _sweep(hs, [5.0], "paper", full,
                 reps=30 if full else 8, tasks=2000 if full else 600,
                 observers=("fairness_trajectory", "timeline"))
    suffered = res.aux["fairness_trajectory"]["suffered"]  # (H,1,K,B,S)
    tl_completed = res.aux["timeline"]["completed"]        # (H,1,K,B,S)
    rows, frac = [], {}
    B = suffered.shape[3]
    for h_i, h in enumerate(hs):
        # fraction of (replicate, bucket) samples with >= 1 suffered type,
        # and the mean number of suffered types per bucket
        any_suffered = suffered[h_i, 0].any(-1)            # (K, B)
        frac[h] = float(any_suffered.mean())
        per_quarter = any_suffered.reshape(
            any_suffered.shape[0], 4, B // 4).mean((0, 2))
        rows.append({
            "fig": "fairness-trajectory", "heuristic": h,
            "suffered_frac": round(frac[h], 4),
            **{f"q{i+1}": round(float(x), 4)
               for i, x in enumerate(per_quarter)},
            "mean_suffered_types": round(
                float(suffered[h_i, 0].sum(-1).mean()), 4),
        })
    consistent = bool(
        np.array_equal(tl_completed[:, :, :, -1],
                       np.asarray(res.metrics.completed_by_type)))
    derived = {
        "claim": "time-resolved telemetry is engine state (final bucket == "
                 "Metrics); FELARE's suffered-type exposure reported",
        "elare_suffered_frac": round(frac["ELARE"], 4),
        "felare_suffered_frac": round(frac["FELARE"], 4),
        "timeline_consistent": consistent,
        "pass": consistent,
    }
    return rows, derived


ALL = {
    "fig3_pareto": fig3_pareto,
    "fig4_wasted_energy": fig4_wasted_energy,
    "fig5_aws_wasted": fig5_aws_wasted,
    "fig6_unsuccessful": fig6_unsuccessful,
    "fig7_fairness": fig7_fairness,
    "fig8_aws_fairness": fig8_aws_fairness,
    "table_overhead": table_overhead,
    "scenario_stress": scenario_stress,
    "fairness_trajectory": fairness_trajectory,
}
