"""Tests for the federated multi-site layer (sites + dispatch + plumbing).

Contracts under test:

  * degeneracy — a single-site partition under the default ``sticky``
    dispatcher is bit-identical to the flat pre-federation engine (the
    frozen PR 4 metrics snapshot itself is pinned in
    ``tests/test_scenario_regression.py``, which runs the default
    single-site path);
  * oracle — the pure-Python interpreter reproduces the federated engine
    event-for-event (task_log cross-check) for ``round_robin`` and
    ``fair_spill`` on a 2-site paper fleet;
  * partition safety — no dispatcher/policy combination ever places a
    task on a machine outside its dispatched site (hypothesis property);
  * single-jit — one trace per (policy, dispatcher, scenario) triple,
    including through the CLI across every built-in dispatcher;
  * registries and JSON round-trips for dispatchers, federated fleets
    and site-partitioned SystemSpecs.
"""
import dataclasses
import json

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro import experiments, scenarios
from repro.core import api, dispatch, engine, pyengine, workload
from repro.core.types import SystemSpec
from repro.experiments import runner, sweep

SPEC = api.paper_system()
SPEC2 = scenarios.get_fleet("paper_x2").build()


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate, eet):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


# -------------------------------------------------------------- registries
def test_builtin_dispatchers_registered():
    names = dispatch.list_dispatchers()
    for name in ("sticky", "round_robin", "least_queued", "min_eet",
                 "fair_spill"):
        assert name in names
        assert dispatch.is_registered(name)
    assert isinstance(dispatch.get("STICKY"), dispatch.Sticky)  # case-insens
    with pytest.raises(KeyError, match="choose from"):
        dispatch.get("nope")
    with pytest.raises(TypeError, match="Dispatcher protocol"):
        dispatch.register("bad", object())


def test_dispatcher_json_round_trip():
    for d in (dispatch.Sticky(salt=3, by_type=True), dispatch.RoundRobin(),
              dispatch.LeastQueued(), dispatch.MinEet(),
              dispatch.FairSpill(salt=1)):
        back = dispatch.from_json_dict(
            json.loads(json.dumps(dispatch.to_json_dict(d))))
        assert back == d


def test_federated_fleets_registered_and_partitioned():
    for name, n_sites, per_site in (("paper_x2", 2, 4), ("paper_x4", 4, 4)):
        spec = scenarios.get_fleet(name).build()
        assert spec.n_sites == n_sites
        assert spec.n_machines == n_sites * per_site
        assert spec.eet.shape == (4, n_sites * per_site)
        # replicas: every site sees the same EET block
        for s in range(1, n_sites):
            np.testing.assert_array_equal(
                spec.eet[:, :per_site],
                spec.eet[:, s * per_site:(s + 1) * per_site])
    mixed = scenarios.get_fleet("mixed_sites").build()
    assert mixed.n_sites == 2
    assert mixed.site_of_machine == (0, 0, 0, 0, 1, 1, 1)


def test_system_spec_partition_validation():
    with pytest.raises(ValueError, match="entries for"):
        dataclasses.replace(SPEC, site_of_machine=(0, 1))
    with pytest.raises(ValueError, match="contiguous"):
        dataclasses.replace(SPEC, site_of_machine=(0, 0, 2, 2))
    flat = dataclasses.replace(SPEC, site_of_machine=None)
    assert flat.n_sites == 1 and flat.sites == (0, 0, 0, 0)


# -------------------------------------------------- single-site degeneracy
def test_single_site_sticky_bit_identical_to_flat_engine():
    """An explicit one-site partition + every dispatcher == the flat
    engine, metric-leaf for metric-leaf, bit for bit."""
    tr = _trace(0, 120, 3.0, SPEC.eet)
    one_site = dataclasses.replace(SPEC, site_of_machine=(0, 0, 0, 0))
    for h in ("FELARE", "MM"):
        flat = engine.simulate(tr, SPEC, h)
        for d in dispatch.list_dispatchers():
            fed = engine.simulate(tr, one_site, h, dispatcher=d)
            for f in flat._fields:
                a = np.asarray(getattr(flat, f))
                b = np.asarray(getattr(fed, f))
                assert a.tobytes() == b.tobytes(), f"{h}/{d}/{f}"


def test_single_site_sweep_metrics_unchanged_by_dispatcher_field():
    """run_sweep on a flat system ignores the dispatcher choice entirely."""
    base = dict(rates=(3.0,), reps=2, n_tasks=60, heuristics=("ELARE",),
                seed=1)
    ref = experiments.run_sweep(experiments.SweepSpec(**base))
    alt = experiments.run_sweep(experiments.SweepSpec(
        **base, dispatcher="least_queued"))
    for f in ref.metrics._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref.metrics, f)),
            np.asarray(getattr(alt.metrics, f)), f)


# --------------------------------------------------------- oracle parity
@pytest.mark.parametrize("dispatcher", ["round_robin", "fair_spill"])
@pytest.mark.parametrize("heuristic", ["ELARE", "FELARE"])
def test_two_site_task_log_matches_oracle_event_for_event(
        heuristic, dispatcher):
    """Engine vs pure-Python oracle on the 2-site paper fleet: per-task
    map/start/end/machine/site/status agree at every event timestamp."""
    for seed in (0, 5):
        tr = _trace(seed, 100, 4.0, SPEC2.eet)
        _, aux = engine.simulate(tr, SPEC2, heuristic,
                                 observers=("task_log",),
                                 dispatcher=dispatcher)
        log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
        ref = pyengine.simulate(tr, SPEC2, heuristic,
                                dispatcher=dispatcher)["task_log"]
        np.testing.assert_array_equal(log["status"], ref["status"])
        np.testing.assert_array_equal(log["machine"], ref["machine"])
        np.testing.assert_array_equal(log["site"], ref["site"])
        for field in ("map_time", "start_time", "end_time"):
            np.testing.assert_allclose(
                log[field], ref[field], rtol=1e-6, atol=1e-6,
                err_msg=f"{field} seed{seed}")


@pytest.mark.parametrize("heuristic,dispatcher",
                         [("ELARE", "round_robin"), ("FELARE", "fair_spill")])
def test_eight_site_task_log_matches_oracle_event_for_event(
        heuristic, dispatcher):
    """Same oracle parity on the 8-site paper fleet (32 machines) — the
    masked-vmap site loop at an F the static unroll never shipped with."""
    spec8 = scenarios.get_fleet("paper_x8").build()
    assert spec8.n_sites == 8
    for seed in (0, 7):
        tr = _trace(seed, 96, 8.0, spec8.eet)
        _, aux = engine.simulate(tr, spec8, heuristic,
                                 observers=("task_log",),
                                 dispatcher=dispatcher)
        log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
        ref = pyengine.simulate(tr, spec8, heuristic,
                                dispatcher=dispatcher)["task_log"]
        np.testing.assert_array_equal(log["status"], ref["status"])
        np.testing.assert_array_equal(log["machine"], ref["machine"])
        np.testing.assert_array_equal(log["site"], ref["site"])
        for field in ("map_time", "start_time", "end_time"):
            np.testing.assert_allclose(
                log[field], ref[field], rtol=1e-6, atol=1e-6,
                err_msg=f"{field} seed{seed}")


# ------------------------------------------------------ partition property
@given(seed=st.integers(0, 1000), rate=st.floats(1.0, 8.0),
       dispatcher=st.sampled_from(
           ["sticky", "round_robin", "least_queued", "min_eet",
            "fair_spill"]))
@settings(max_examples=10, deadline=None)
def test_dispatch_never_crosses_site_boundaries(seed, rate, dispatcher):
    """No task ever runs on a machine outside its dispatched site, and
    every admitted task carries a valid site id."""
    tr = _trace(seed, 80, rate, SPEC2.eet)
    _, aux = engine.simulate(tr, SPEC2, "FELARE", observers=("task_log",),
                             dispatcher=dispatcher)
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    sites = np.asarray(SPEC2.site_of_machine)
    ran = log["machine"] >= 0
    np.testing.assert_array_equal(
        sites[log["machine"][ran]], log["site"][ran],
        err_msg=f"{dispatcher}: task ran outside its site")
    from repro.core.types import UNARRIVED

    arrived = log["status"] != UNARRIVED
    assert np.all((log["site"][arrived] >= 0)
                  & (log["site"][arrived] < SPEC2.n_sites))
    assert np.all(log["site"][~arrived] == -1)


# ------------------------------------------------------------- single jit
def test_one_jit_trace_per_policy_dispatcher_scenario():
    heuristics = ("ELARE", "FELARE")
    runner._TRACE_LOG.clear()
    for d in ("sticky", "round_robin"):
        experiments.run_sweep(experiments.SweepSpec(
            system="paper_x2", rates=(3.0,), reps=2, n_tasks=50,
            heuristics=heuristics, seed=1, dispatcher=d,
        ))
    expected = {(h, "poisson", d, "none", "none")
                for h in heuristics for d in ("sticky", "round_robin")}
    assert set(runner._TRACE_LOG) == expected
    assert len(runner._TRACE_LOG) == len(expected)
    runner._TRACE_LOG.clear()


def test_cli_two_site_sweep_all_dispatchers(tmp_path):
    """A 2-site federation sweep runs end-to-end through the CLI for every
    built-in dispatcher, each in one jitted program (trace-log pinned),
    and writes the sweep artifacts."""
    runner._TRACE_LOG.clear()
    for d in dispatch.list_dispatchers():
        out = tmp_path / d
        sweep.main([
            "--system", "paper_x2", "--dispatcher", d,
            "--rates", "3.0", "--reps", "1", "--tasks", "40",
            "--heuristics", "ELARE", "--out", str(out),
        ])
        payload = json.loads((out / "sweep.json").read_text())
        assert payload["spec"]["dispatcher"] == d
        assert (out / "sweep.csv").exists()
    expected = {("ELARE", "poisson", d, "none", "none")
                for d in dispatch.list_dispatchers()}
    assert set(runner._TRACE_LOG) == expected
    assert len(runner._TRACE_LOG) == len(expected)
    runner._TRACE_LOG.clear()


def test_cli_rejects_unknown_dispatcher(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--dispatcher", "nope"])
    assert "unknown dispatcher" in capsys.readouterr().err


def test_cli_list_dispatchers(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--list-dispatchers"])
    out = capsys.readouterr().out
    for name in dispatch.list_dispatchers():
        assert name in out


# ---------------------------------------------------------- spec plumbing
def test_spec_rejects_unknown_dispatcher():
    with pytest.raises(ValueError, match="unknown dispatcher"):
        experiments.SweepSpec(dispatcher="nope")
    with pytest.raises(ValueError, match="Dispatcher"):
        experiments.SweepSpec(dispatcher=42)


def test_spec_json_roundtrip_with_dispatcher_and_sites():
    system = SystemSpec(
        eet=np.asarray([[1.0, 2.0, 3.0], [3.0, 4.0, 5.0]], np.float32),
        p_dyn=np.asarray([1.5, 2.5, 1.0], np.float32),
        p_idle=np.asarray([0.05, 0.05, 0.04], np.float32),
        queue_size=3, site_of_machine=(0, 0, 1),
    )
    spec = experiments.SweepSpec(
        system=system, rates=(2.0,), reps=2, n_tasks=40,
        heuristics=("MM",), dispatcher=dispatch.FairSpill(salt=2),
    )
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back.dispatcher == dispatch.FairSpill(salt=2)
    assert back.system.site_of_machine == (0, 0, 1)
    named = experiments.SweepSpec(system="paper_x2",
                                  dispatcher="least_queued")
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(named.to_json_dict())))
    assert back == named


def test_run_study_accepts_dispatcher():
    res = api.run_study("FELARE", (3.0,), SPEC2, n_traces=2, n_tasks=40,
                        dispatcher="round_robin")
    assert len(res) == 1
    assert float(res[0].completion_rate) > 0


# ------------------------------------------------------ per-site telemetry
def test_timeline_per_site_series():
    """The per-site timeline splits the global series exactly: site sums
    recover the totals, and the flat pytree is untouched by default."""
    from repro.core import observe

    tr = _trace(2, 120, 5.0, SPEC2.eet)
    _, aux = engine.simulate(
        tr, SPEC2, "ELARE", dispatcher="round_robin",
        observers=(observe.Timeline(per_site=True),))
    tl = {k: np.asarray(v) for k, v in aux["timeline"].items()}
    assert tl["site_qlen"].shape == (64, 2)
    assert tl["site_e_dyn"].shape == (64, 2)
    np.testing.assert_array_equal(tl["site_qlen"].sum(-1), tl["qlen"])
    # per-site dynamic energy sums to the finalized-run total: at the last
    # bucket every run has finalized, so it matches e_dyn exactly.
    np.testing.assert_allclose(tl["site_e_dyn"][-1].sum(), tl["e_dyn"][-1],
                               rtol=1e-5)
    # default stays flat
    _, aux = engine.simulate(tr, SPEC2, "ELARE", dispatcher="round_robin",
                             observers=("timeline",))
    assert "site_qlen" not in aux["timeline"]


# --------------------------------------------------- dispatch behaviours
def test_least_queued_balances_a_burst():
    """Simultaneous admissions spread across sites instead of dog-piling
    the momentarily-emptiest one (the sequential-balance contract)."""
    n = 16
    arrival = jnp.zeros((n,), jnp.float32)  # one burst, all at t=0
    task_type = jnp.zeros((n,), jnp.int32)
    deadline = jnp.full((n,), 100.0, jnp.float32)
    exec_actual = jnp.ones((n, SPEC2.n_machines), jnp.float32)
    tr = workload.Trace(arrival, task_type, deadline, exec_actual)
    _, aux = engine.simulate(tr, SPEC2, "MM", observers=("task_log",),
                             dispatcher="least_queued")
    site = np.asarray(aux["task_log"]["site"])
    counts = np.bincount(site, minlength=2)
    assert counts[0] == counts[1] == n // 2


def test_fair_spill_balances_suffered_burst_like_least_queued():
    """A t=0 burst of one type is suffered by Alg. 4 from the first event
    (arrivals but no completions yet), so fair_spill spills *every* task —
    degenerating to least_queued's equal split rather than sticky homes."""
    n = 16
    tr = workload.Trace(
        arrival=jnp.zeros((n,), jnp.float32),
        task_type=jnp.zeros((n,), jnp.int32),
        deadline=jnp.full((n,), 100.0, jnp.float32),
        exec_actual=jnp.ones((n, SPEC2.n_machines), jnp.float32),
    )
    _, a_spill = engine.simulate(tr, SPEC2, "MM", observers=("task_log",),
                                 dispatcher="fair_spill")
    spill = np.asarray(a_spill["task_log"]["site"])
    counts = np.bincount(spill, minlength=2)
    assert counts[0] == counts[1] == n // 2
