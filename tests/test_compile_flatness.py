"""Compile-flatness pins: site count F is data, not program structure.

The masked-vmap site loop promises that growing a federation from F=2 to
F=32 changes array extents only — the traced program (and therefore
compile time) stays flat. Pinned three ways:

  * jaxpr size — the recursive equation count and primitive multiset of a
    full simulator are *identical* for paper_x2 and paper_x32 (the arrays
    are wider; the program is the same);
  * single-jit contract — a sweep still traces each (policy, dispatcher,
    scenario) triple exactly once, and the trace-log entries for an F=32
    sweep equal those of an F=2 sweep (site count never leaks into how
    often anything traces);
  * wall clock — AOT ``lower().compile()`` of the F=32 simulator takes at
    most 1.2x the F=2 compile (min-of-2, plus a small absolute slack for
    scheduler noise), the ISSUE's acceptance bound. The same bound is
    tracked over F in ``benchmarks/BENCH_1.json``.
"""
import time

import jax
import numpy as np
import pytest

from repro import experiments, scenarios
from repro.core import dispatch, engine, policy, workload
from repro.experiments import runner

HEURISTIC, DISPATCHER = "FELARE", "fair_spill"  # the heaviest builtins


def _simulator_and_trace(fleet_name, n_tasks=24, seed=0, rate=4.0):
    system = scenarios.get_fleet(fleet_name).build()
    sim = engine.make_simulator(
        policy.get(HEURISTIC), system.as_jax(),
        queue_size=system.queue_size,
        fairness_factor=float(system.fairness_factor),
        dispatcher=dispatch.resolve(DISPATCHER),
        site_of_machine=system.sites,
    )
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n_tasks, rate,
                                system.eet)
    return sim, tr


def _count_eqns(jaxpr) -> int:
    """Total equation count, descending into nested (closed) jaxprs."""
    n = 0
    for eqn in jaxpr.eqns:
        n += 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            n += _count_eqns(sub)
    return n


def _primitive_counts(jaxpr, out=None) -> dict:
    out = {} if out is None else out
    for eqn in jaxpr.eqns:
        out[eqn.primitive.name] = out.get(eqn.primitive.name, 0) + 1
        for sub in jax.core.jaxprs_in_params(eqn.params):
            _primitive_counts(sub, out)
    return out


def test_jaxpr_size_independent_of_site_count():
    """paper_x2 and paper_x32 trace to the *same program*: equal equation
    counts and equal primitive multisets, recursively."""
    sim2, tr2 = _simulator_and_trace("paper_x2")
    sim32, tr32 = _simulator_and_trace("paper_x32")
    j2 = jax.make_jaxpr(sim2)(tr2).jaxpr
    j32 = jax.make_jaxpr(sim32)(tr32).jaxpr
    n2, n32 = _count_eqns(j2), _count_eqns(j32)
    assert n2 == n32, f"site count leaked into the program: {n2} vs {n32}"
    assert _primitive_counts(j2) == _primitive_counts(j32)


def test_flat_fleet_jaxpr_carries_no_federation_ops():
    """F=1 short-circuits: the single-site program is strictly smaller
    than the federated one (no masking, no dispatch, no gathers)."""
    sim1, tr1 = _simulator_and_trace("paper")
    sim2, tr2 = _simulator_and_trace("paper_x2")
    assert _count_eqns(jax.make_jaxpr(sim1)(tr1).jaxpr) \
        < _count_eqns(jax.make_jaxpr(sim2)(tr2).jaxpr)


def test_one_trace_per_triple_independent_of_site_count():
    """The single-jit contract holds at F=32, and the trace-log entries of
    an F=32 sweep are exactly those of the F=2 sweep."""
    heuristics = ("ELARE", "FELARE")
    logs = {}
    for fleet in ("paper_x2", "paper_x32"):
        runner._TRACE_LOG.clear()
        experiments.run_sweep(experiments.SweepSpec(
            system=fleet, rates=(3.0,), reps=2, n_tasks=30,
            heuristics=heuristics, seed=1, dispatcher="round_robin",
        ))
        logs[fleet] = list(runner._TRACE_LOG)
        runner._TRACE_LOG.clear()
    expected = [(h, "poisson", "round_robin", "none", "none")
                for h in heuristics]
    assert logs["paper_x2"] == expected
    assert logs["paper_x32"] == logs["paper_x2"]


def _aot_compile_seconds(fleet_name, repeats=2) -> float:
    best = np.inf
    for i in range(repeats):
        # vary the trace length per repeat: an identical HLO would hit the
        # in-process XLA executable cache and "compile" in ~0s.
        sim, tr = _simulator_and_trace(fleet_name, n_tasks=24 + i)
        t0 = time.perf_counter()
        jax.jit(sim).lower(tr).compile()
        best = min(best, time.perf_counter() - t0)
    return best


def test_compile_time_flat_in_site_count():
    """ISSUE acceptance bound: compiling the F=32 simulator costs at most
    1.2x the F=2 compile (min-of-2 AOT compiles + 0.5s absolute slack)."""
    _aot_compile_seconds("paper", repeats=1)  # absorb one-time jit/XLA init
    t2 = _aot_compile_seconds("paper_x2")
    t32 = _aot_compile_seconds("paper_x32")
    assert t32 <= 1.2 * t2 + 0.5, (
        f"F=32 compile {t32:.2f}s exceeds 1.2x F=2 compile {t2:.2f}s")
    if t32 > 1.2 * t2:
        pytest.skip(f"within absolute slack only (t2={t2:.2f}s "
                    f"t32={t32:.2f}s) — machine noise, not a regression")
