"""Deprecation-shim coverage: the legacy surfaces must stay live views of
the new registries, not frozen copies.

  * ``workload.trace_batch`` warns and delegates byte-identically onto the
    scenario layer's ``trace_stack``;
  * ``heuristics.get`` / ``HEURISTICS`` track the policy registry through
    custom registration and ``overwrite=True`` re-registration.
"""
import jax
import numpy as np
import pytest

from repro.core import heuristics, policy, workload
from repro.core.api import paper_system
from repro.datapipe import synthetic

SPEC = paper_system()


# ------------------------------------------------------------- trace_batch
def test_trace_batch_warns_and_delegates_byte_identically():
    key = jax.random.PRNGKey(11)
    with pytest.warns(DeprecationWarning, match="trace_batch"):
        got = workload.trace_batch(key, 5, 80, 2.5, SPEC.eet)
    want = jax.tree.map(
        lambda x: x[0], synthetic.trace_stack(key, (2.5,), 5, 80, SPEC.eet)
    )
    for g, w, name in zip(got, want, type(got)._fields):
        ga, wa = np.asarray(g), np.asarray(w)
        assert ga.dtype == wa.dtype and ga.shape == wa.shape, name
        assert ga.tobytes() == wa.tobytes(), f"{name} differs bitwise"


# ------------------------------------------------- heuristics registry view
def test_heuristics_view_tracks_custom_registration():
    custom = policy.TwoPhasePolicy(
        policy.MinExecution(), policy.SoonestDeadline(), policy.DropStale()
    )
    policy.register("shim-test", custom)
    try:
        assert heuristics.get("shim-test") is custom
        assert "SHIM-TEST" in heuristics.HEURISTICS
        assert heuristics.HEURISTICS["shim-test"] is custom
        assert len(heuristics.HEURISTICS) == len(policy.list_policies())
    finally:
        policy.unregister("shim-test")
    assert "SHIM-TEST" not in heuristics.HEURISTICS


def test_heuristics_view_tracks_overwrite():
    """register(..., overwrite=True) must be visible through the legacy
    view immediately — no stale name-keyed caches."""
    first = policy.TwoPhasePolicy(
        policy.MinCompletion(), policy.Fcfs(), policy.DropStale()
    )
    second = policy.TwoPhasePolicy(
        policy.MinExecution(), policy.Fcfs(), policy.DropStale()
    )
    policy.register("shim-ow", first)
    try:
        assert heuristics.get("shim-ow") is first
        with pytest.raises(ValueError, match="already registered"):
            policy.register("shim-ow", second)
        policy.register("shim-ow", second, overwrite=True)
        assert heuristics.get("shim-ow") is second
        assert heuristics.HEURISTICS["shim-ow"] is second
        # the view and the registry list the same names
        assert sorted(heuristics.HEURISTICS) == policy.list_policies()
    finally:
        policy.unregister("shim-ow")
