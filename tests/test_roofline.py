"""Tests for the roofline measurement layer: the jaxpr cost walk (trip-count
exactness) and the while-aware HLO collective parser."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hlo_graph, jaxpr_cost


class TestJaxprCost:
    def test_dot_flops_exact(self):
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        c = jaxpr_cost.jaxpr_cost(f, a, b)
        assert c["flops"] == 2 * 64 * 128 * 32
        assert c["matmul_flops"] == 2 * 64 * 128 * 32

    def test_scan_multiplies_by_trip_count(self):
        W = jax.ShapeDtypeStruct((16, 8, 8), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 8), jnp.float32)

        def f(ws, x):
            def body(h, w):
                return h @ w, None
            h, _ = jax.lax.scan(body, x, ws)
            return h

        c = jaxpr_cost.jaxpr_cost(f, W, x)
        assert c["matmul_flops"] == 16 * (2 * 4 * 8 * 8)

    def test_nested_scan(self):
        W = jax.ShapeDtypeStruct((3, 5, 8, 8), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 8), jnp.float32)

        def f(ws, x):
            def outer(h, wg):
                def inner(h2, w):
                    return h2 @ w, None
                h, _ = jax.lax.scan(inner, h, wg)
                return h, None
            h, _ = jax.lax.scan(outer, x, ws)
            return h

        c = jaxpr_cost.jaxpr_cost(f, W, x)
        assert c["matmul_flops"] == 15 * (2 * 4 * 8 * 8)

    def test_grad_counts_backward(self):
        a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((64, 16), jnp.float32)

        def loss(a, b):
            return jnp.sum((a @ b) ** 2)

        g = lambda a, b: jax.grad(loss)(a, b)
        c_f = jaxpr_cost.jaxpr_cost(loss, a, b)
        c_g = jaxpr_cost.jaxpr_cost(g, a, b)
        # backward has ~2x the matmul flops of forward (dL/da needs one more)
        assert c_g["matmul_flops"] >= 2 * c_f["matmul_flops"]

    def test_scatter_counts_touched_region_only(self):
        big = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        small = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

        def f(cache, upd):
            return jax.lax.dynamic_update_slice(cache, upd, (5, 0))

        c = jaxpr_cost.jaxpr_cost(f, big, small)
        # 2 x update bytes, NOT 2 x full cache
        assert c["bytes"] <= 4 * 1024 * 4 * 2 + 1024


class TestHloGraph:
    HLO = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ip, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ag = f32[16]{0} all-gather(%a), replica_groups={}
  %init = (s32[], f32[8]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""

    def test_while_multiplier(self):
        got = hlo_graph.collective_bytes_weighted(self.HLO)
        # all-reduce inside the 24-trip while: 24 * 8 * 4 bytes
        assert got.get("all-reduce") == pytest.approx(24 * 32)
        assert got.get("all-gather") == pytest.approx(64)

    def test_flat_parser_counts_once(self):
        got = analysis.collective_bytes(self.HLO)
        assert got.get("all-reduce") == 32  # body counted once (known limit)


class TestRooflineModel:
    def test_dominant_term(self):
        r = analysis.Roofline(
            arch="x", shape="train_4k", mesh="pod", chips=256,
            flops_per_device=1e12, bytes_per_device=1e12,
            coll_bytes_per_device=1e9, model_flops=1e14)
        assert r.dominant == "memory"
        assert r.t_mem > r.t_coll > r.t_comp
        assert 0 < r.mfu < 1

    def test_model_flops_train_vs_decode(self):
        from repro.configs import registry, shapes

        cfg = registry.get_config("qwen1.5-0.5b")
        tr = analysis.model_flops_for(cfg, shapes.SHAPES["train_4k"])
        de = analysis.model_flops_for(cfg, shapes.SHAPES["decode_32k"])
        assert tr > 1000 * de  # 1M tokens x 6ND vs 128 tokens x 2ND

    def test_moe_active_params(self):
        from repro.configs import registry

        cfg = registry.get_config("phi3.5-moe-42b-a6.6b")
        assert cfg.active_params() < 0.3 * cfg.n_params()


class TestShapesPolicy:
    def test_long500k_skips_full_attention(self):
        from repro.configs import registry, shapes

        for arch in registry.ARCH_IDS:
            cfg = registry.get_config(arch)
            ok, reason = shapes.applicable(cfg, "long_500k")
            if cfg.family in ("ssm", "hybrid"):
                assert ok, arch
            else:
                assert not ok and "SKIP" in reason, arch

    def test_all_cells_well_defined(self):
        from repro.configs import registry, shapes

        total = sum(
            len(shapes.cells(registry.get_config(a)))
            for a in registry.ARCH_IDS)
        assert total == 40  # the assigned 40-cell matrix
