"""Ring attention == full attention (subprocess, 4 placeholder devices)."""
import os
import subprocess
import sys
import textwrap

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
"""


def _run(body):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_ring_attention_matches_full():
    out = _run("""
    from repro.distributed.ring_attention import ring_attention
    from repro.launch.mesh import make_mesh
    from repro.models.layers import sdpa_xla

    mesh = make_mesh((4,), ("model",))
    B, S, H, hd = 2, 64, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5

    for causal in (True, False):
        got = ring_attention(q, k, v, mesh, "model", causal=causal)
        want = sdpa_xla(q, k, v, causal=causal)
        err = float(jnp.abs(got - want).max())
        print("causal", causal, "err", err)
        assert err < 1e-4
    print("OK")
    """)
    assert "OK" in out
