"""Hypothesis property tests on model-substrate invariants."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs import registry


@given(chunk=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_ssd_chunk_invariance(chunk, seed):
    """ssd_chunked result is independent of the chunk size (== ssd_ref)."""
    from repro.models.ssm import ssd_chunked, ssd_ref

    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, L, H, P, N = 1, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y, S = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, S2 = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=5e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S2), atol=5e-4)


@given(chunk=st.sampled_from([8, 16, 32]), seed=st.integers(0, 100))
@settings(max_examples=8, deadline=None)
def test_gla_chunk_invariance(chunk, seed):
    from repro.models.xlstm import gla_chunked, gla_ref

    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, L, H, Dk = 1, 32, 2, 8
    q = jax.random.normal(ks[0], (B, L, H, Dk)) * 0.5
    k = jax.random.normal(ks[1], (B, L, H, Dk)) * 0.5
    v = jax.random.normal(ks[2], (B, L, H, Dk)) * 0.5
    i = jax.nn.sigmoid(jax.random.normal(ks[3], (B, L, H)))
    f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, L, H)) + 2)
    y, (S, n) = gla_chunked(q, k, v, i, f, chunk)
    y2, (S2, n2) = gla_ref(q, k, v, i, f)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=5e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S2), atol=5e-4)


@given(seed=st.integers(0, 1000),
       group=st.sampled_from([4, 8, 16, 10_000]))
@settings(max_examples=8, deadline=None)
def test_moe_group_size_invariance_with_ample_capacity(seed, group):
    """With capacity ample enough that nothing drops, the grouped-scatter
    dispatch output is independent of the group size."""
    from repro.models import moe

    cfg = registry.get_smoke_config("granite-moe-3b-a800m").scaled(
        dtype="float32", param_dtype="float32", capacity_factor=16.0,
        moe_group=group)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 16, cfg.d_model))
    y, _ = moe.moe_apply(cfg, p, x)
    cfg_ref = cfg.scaled(moe_group=32)
    y2, _ = moe.moe_apply(cfg_ref, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=2e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=6, deadline=None)
def test_moe_overflow_tokens_pass_through_residual(seed):
    """Tokens dropped by capacity produce a ZERO moe output (the block's
    residual connection then passes them through unchanged)."""
    from repro.models import moe

    cfg = registry.get_smoke_config("granite-moe-3b-a800m").scaled(
        dtype="float32", param_dtype="float32", capacity_factor=0.01)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, cfg.d_model))
    y, _ = moe.moe_apply(cfg, p, x)
    # capacity ~= K slots per expert: some tokens must overflow fully and
    # come back as exact zeros (residual pass-through)
    zero_rows = np.sum(np.all(np.asarray(y[0]) == 0.0, axis=-1))
    assert 1 <= zero_rows <= 15


@given(seed=st.integers(0, 100), S=st.sampled_from([8, 16, 24]))
@settings(max_examples=6, deadline=None)
def test_decode_prefix_invariance(seed, S):
    """Decoding token-by-token from a shorter prefill matches a longer
    prefill (the cache is a faithful sufficient statistic)."""
    from repro.models import transformer as tf

    cfg = registry.get_smoke_config("internlm2-1.8b").scaled(
        remat=False, dtype="float32", param_dtype="float32")
    params = tf.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (1, S), 0,
                              cfg.vocab_size)
    _, cache_a = tf.prefill(cfg, params, {"tokens": toks}, max_seq=S + 8)
    _, cache_b = tf.prefill(cfg, params, {"tokens": toks[:, :-2]},
                            max_seq=S + 8)
    for t in (toks[:, -2:-1], toks[:, -1:]):
        logits_b, cache_b = tf.decode_step(cfg, params, cache_b, t)
    nxt = jax.random.randint(jax.random.PRNGKey(seed + 1), (1, 1), 0,
                             cfg.vocab_size)
    la, _ = tf.decode_step(cfg, params, cache_a, nxt)
    lb, _ = tf.decode_step(cfg, params, cache_b, nxt)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-4, atol=1e-4)
