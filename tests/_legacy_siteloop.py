"""Frozen PR 5 snapshot of the engine's *statically unrolled* site loop.

DO NOT EDIT: this is the bit-exactness reference for the masked-vmap map
stage. ``tests/test_siteloop_vmap.py`` property-tests that the flat-compile
engine (one vmapped policy evaluation over site-masked machine views)
reproduces this unrolled formulation exactly — event-level (the full
post-map SimState, byte for byte) and trace-level (task_log event logs) —
for F in {1, 2, 4} under every built-in dispatcher x ELARE/FELARE.

The code below is the verbatim PR 5 ``engine._stage_map`` body (static
Python loop over F sites, one ``select_fn`` call per site) delegating to
the *live* ``engine._apply_action`` epilogue, which is shared by both
formulations and pinned separately through the flat-engine snapshots.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import fairness
from repro.core.engine import _apply_action
from repro.core.policy import BIG, MachineView
from repro.core.types import PENDING, MapAction


def map_action_unrolled(st, trace, sysarr, select_fn, fairness_factor,
                        site_members=None) -> MapAction:
    """PR 5 map action: one ``select_fn`` call per site, masked-merged."""
    suffered = fairness.suffered_types(
        st.completed, st.arrived, fairness_factor
    )
    avail_base = jnp.maximum(
        jnp.where(st.run_task >= 0, st.run_end_exp, st.now), st.now
    )
    n_sites = 1 if site_members is None else site_members.shape[0]
    if n_sites == 1:
        view = MachineView(avail_base=avail_base, queue=st.queue,
                           qlen=st.qlen)
        return select_fn(
            st.now,
            st.status == PENDING,
            trace.task_type,
            trace.deadline,
            view,
            sysarr,
            suffered,
        )

    M, Q = st.queue.shape
    assign = jnp.full((M,), -1, jnp.int32)
    drop = jnp.zeros(st.status.shape, bool)
    queue_drop = jnp.zeros((M, Q), bool)
    for s in range(n_sites):
        in_site = jnp.asarray(site_members[s])  # (M,) bool constant
        view_s = MachineView(
            avail_base=jnp.where(in_site, avail_base, BIG),
            queue=jnp.where(in_site[:, None], st.queue, -1),
            qlen=jnp.where(in_site, st.qlen, Q),
        )
        sysarr_s = sysarr._replace(
            eet=jnp.where(in_site[None, :], sysarr.eet, BIG)
        )
        task_in_site = st.site == s
        action = select_fn(
            st.now,
            (st.status == PENDING) & task_in_site,
            trace.task_type,
            trace.deadline,
            view_s,
            sysarr_s,
            suffered,
        )
        assign = jnp.where(in_site, action.assign, assign)
        drop = drop | (action.drop & task_in_site)
        queue_drop = queue_drop | (action.queue_drop & in_site[:, None])
    return MapAction(assign, drop, queue_drop)


def stage_map_unrolled(st, trace, sysarr, select_fn, fairness_factor,
                       n_types, site_members=None):
    """PR 5 ``_stage_map``: the unrolled action + the live apply epilogue."""
    action = map_action_unrolled(st, trace, sysarr, select_fn,
                                 fairness_factor, site_members)
    return _apply_action(st, trace, action, n_types)
