"""The jit-discipline analyzer, tested from both sides.

Positive side: every AST rule (JD001-JD005) fires on a minimal seeded
violation with the right rule id AND line number; the jaxpr audit
(JX101-JX103) fires on seeded-bad programs (an F=1 vs F=2 flatness
mismatch, a weak-typed output, a ``jax.debug.print`` in the loop).

Negative side: the current tree is clean — the self-scan pins every
satellite fix (CRN markers, shared excludes, gated jax import) and the
flatness audit independently reproduces the F-invariance contract of
``tests/test_compile_flatness.py`` through the shared walker. The CLI
round-trips its ``--json`` report and exits 0/1 by findings.
"""
import json
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import astlint, check as check_cli, jaxpr_audit
from repro.analysis.config import AnalysisConfig, load_config
from repro.analysis.findings import Finding, from_json_dict, load_json

REPO_ROOT = analysis.find_repo_root()


# --------------------------------------------------------------------------
# Fixture scaffolding: a throwaway repo tree with one bad file
# --------------------------------------------------------------------------

def _mini_repo(tmp_path, rel, source):
    """A minimal scannable tree: pyproject + one file at ``rel``."""
    (tmp_path / "pyproject.toml").write_text(
        "[tool.repro.analysis]\nexclude = []\n")
    target = tmp_path / rel
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(textwrap.dedent(source))
    return AnalysisConfig(root=str(tmp_path), exclude=())


def _rules_at(findings, rule):
    return [(f.path, f.line) for f in findings if f.rule == rule]


# --------------------------------------------------------------------------
# JD001 registry-frozen
# --------------------------------------------------------------------------

def test_jd001_unfrozen_registered_class(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        import dataclasses

        def register(name, item):
            pass

        @dataclasses.dataclass
        class MutablePolicy:
            alpha: float = 1.0

        register("mutable", MutablePolicy())
        """)
    findings = astlint.RegistryFrozenCheck().run(cfg)
    assert _rules_at(findings, "JD001") == [("src/repro/core/bad.py", 7)]


def test_jd001_unhashable_field(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        import dataclasses
        from typing import List

        def register(name, item):
            pass

        @dataclasses.dataclass(frozen=True)
        class ListPolicy:
            weights: List[float] = None

        register("listy", ListPolicy())
        """)
    findings = astlint.RegistryFrozenCheck().run(cfg)
    assert _rules_at(findings, "JD001") == [("src/repro/core/bad.py", 9)]
    assert "unhashable" in findings[0].message


def test_jd001_loop_registration_idiom_resolved(tmp_path):
    """The repo's ``for _n, _x in [...]: register(_n, _x)`` idiom and
    nested component constructors are both traced to their classes."""
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        import dataclasses

        def register(name, item):
            pass

        @dataclasses.dataclass(frozen=True)
        class Outer:
            inner: object = None

        @dataclasses.dataclass
        class Inner:
            x: float = 0.0

        for _n, _x in [("outer", Outer(Inner()))]:
            register(_n, _x)
        """)
    findings = astlint.RegistryFrozenCheck().run(cfg)
    assert _rules_at(findings, "JD001") == [("src/repro/core/bad.py", 11)]


# --------------------------------------------------------------------------
# JD002 crn-discipline
# --------------------------------------------------------------------------

_JD002_SRC = """\
    import jax

    def make_noise():
        key = jax.random.PRNGKey(0)
        return jax.random.uniform(key, ())
    """


def test_jd002_stray_prngkey(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", _JD002_SRC)
    findings = astlint.CrnDisciplineCheck().run(cfg)
    assert _rules_at(findings, "JD002") == [("src/repro/core/bad.py", 4)]


def test_jd002_marker_suppresses(tmp_path):
    src = _JD002_SRC.replace(
        "key = jax.random.PRNGKey(0)",
        "key = jax.random.PRNGKey(0)  "
        "# repro: allow-prng[test fixture reason]")
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", src)
    assert astlint.CrnDisciplineCheck().run(cfg) == []


def test_jd002_marker_without_reason_is_a_finding(tmp_path):
    src = _JD002_SRC.replace(
        "key = jax.random.PRNGKey(0)",
        "key = jax.random.PRNGKey(0)  # repro: allow-prng")
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", src)
    findings = astlint.CrnDisciplineCheck().run(cfg)
    assert len(findings) == 1
    assert "without a [reason]" in findings[0].message


# --------------------------------------------------------------------------
# JD003 host-effects
# --------------------------------------------------------------------------

def test_jd003_host_call_in_stage(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        import time

        def _stage_admit(st, trace):
            t0 = time.perf_counter()
            return st, t0
        """)
    findings = astlint.HostEffectsCheck().run(cfg)
    assert _rules_at(findings, "JD003") == [("src/repro/core/bad.py", 4)]


def test_jd003_host_call_outside_jit_body_ok(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/ok.py", """\
        import time

        def benchmark_harness(st):
            return time.perf_counter()
        """)
    assert astlint.HostEffectsCheck().run(cfg) == []


def test_jd003_jit_body_marker_opts_in(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        import time

        # repro: jit-body
        def helper_called_from_stage(st):
            return time.perf_counter()
        """)
    findings = astlint.HostEffectsCheck().run(cfg)
    assert _rules_at(findings, "JD003") == [("src/repro/core/bad.py", 5)]


# --------------------------------------------------------------------------
# JD004 traced-branch
# --------------------------------------------------------------------------

def test_jd004_python_if_on_traced_value(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        import jax.numpy as jnp

        def _stage_map(st, trace):
            load = jnp.sum(st.queue)
            if load > 3:
                st = st._replace(now=st.now + 1)
            return st
        """)
    findings = astlint.TracedBranchCheck().run(cfg)
    assert _rules_at(findings, "JD004") == [("src/repro/core/bad.py", 5)]


def test_jd004_bool_coercion(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/bad.py", """\
        def _stage_start(st):
            flag = bool(st.halted)
            return flag
        """)
    findings = astlint.TracedBranchCheck().run(cfg)
    assert _rules_at(findings, "JD004") == [("src/repro/core/bad.py", 2)]


def test_jd004_static_branches_stay_legal(tmp_path):
    """Config ifs (static closure args, shape tests, `is None`) are the
    engine's idiom and must not be flagged."""
    cfg = _mini_repo(tmp_path, "src/repro/core/ok.py", """\
        def _stage_dispatch(st, n_sites=1, halted=None):
            if n_sites == 1:
                return st
            if halted is not None:
                return st
            if st.queue.shape[0] > 4:
                return st
            return st
        """)
    assert astlint.TracedBranchCheck().run(cfg) == []


# --------------------------------------------------------------------------
# JD005 oracle-f32
# --------------------------------------------------------------------------

def test_jd005_bare_float_literal(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/pyengine.py", """\
        import numpy as np

        F = np.float32

        def _nominate_min_energy(dl, val):
            return F(dl) + 1e-6 * val
        """)
    findings = astlint.OracleF32Check(
        oracle_rel="src/repro/core/pyengine.py").run(cfg)
    assert _rules_at(findings, "JD005") == [("src/repro/core/pyengine.py", 6)]


def test_jd005_float64_reference(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/pyengine.py", """\
        import numpy as np

        def _key_urgency(dl):
            return np.float64(dl)
        """)
    findings = astlint.OracleF32Check(
        oracle_rel="src/repro/core/pyengine.py").run(cfg)
    assert _rules_at(findings, "JD005") == [("src/repro/core/pyengine.py", 4)]


def test_jd005_wrapped_literals_clean(tmp_path):
    cfg = _mini_repo(tmp_path, "src/repro/core/pyengine.py", """\
        import numpy as np

        F = np.float32

        def _nominate_min_energy(dl, val):
            return F(F(dl) + F(F(1e-6) * F(val)))
        """)
    assert astlint.OracleF32Check(
        oracle_rel="src/repro/core/pyengine.py").run(cfg) == []


# --------------------------------------------------------------------------
# Self-scan: the tree is clean, and stays clean
# --------------------------------------------------------------------------

def test_layer1_self_scan_clean():
    """All five AST rules pass on the real tree — pins the CRN markers,
    the shared excludes, and every future core/scenarios edit."""
    findings, errors = analysis.run_checks(root=REPO_ROOT, layers=(1,))
    assert errors == []
    assert findings == [], analysis.format_findings(findings)


def test_excludes_shared_with_ruff():
    """pyproject is the single source of truth: the analyzer exclude list
    exists, covers the legacy snapshots, and equals ruff's."""
    cfg = load_config(REPO_ROOT)
    legacy = ("tests/_legacy_heuristics.py", "tests/_legacy_siteloop.py",
              "tests/_legacy_workload.py")
    for rel in legacy:
        assert cfg.is_excluded(rel), rel
    from repro.analysis.config import _parse_toml
    with open(f"{REPO_ROOT}/pyproject.toml") as fh:
        data = _parse_toml(fh.read())
    assert data["tool"]["ruff"]["extend-exclude"] == list(cfg.exclude)


# --------------------------------------------------------------------------
# Layer 2: jaxpr audit
# --------------------------------------------------------------------------

def test_jx101_flatness_clean_f2_vs_f8():
    """F is data, not program: paper_x2 and paper_x8 trace identically
    (the reusable form of the F=2 vs F=32 compile-flatness pin)."""
    cfg = load_config(REPO_ROOT)
    findings = jaxpr_audit.FlatnessCheck(
        fleets=("paper_x2", "paper_x8")).run(cfg)
    assert findings == [], analysis.format_findings(findings)


def test_jx101_flatness_flags_f1_vs_f2():
    """Seeded-bad pair: the single-site program IS structurally different
    from the federated one, and the audit must say so."""
    cfg = load_config(REPO_ROOT)
    findings = jaxpr_audit.FlatnessCheck(
        fleets=("paper", "paper_x2")).run(cfg)
    assert findings, "F=1 vs F=2 should differ structurally"
    assert all(f.rule == "JX101" for f in findings)


def test_jx102_weak_type_output_flagged(monkeypatch):
    """A python-scalar-derived (weak-typed) output is caught."""
    def weak_program():
        def fn(x):
            return x.sum(), jnp.exp(1.0)  # second output is weak f32
        return fn, (jnp.zeros((4,), jnp.float32),)

    monkeypatch.setattr(jaxpr_audit, "DEFAULT_PROGRAMS",
                        (("weak-fixture", weak_program),))
    findings = jaxpr_audit.DtypeAuditCheck().run(load_config(REPO_ROOT))
    assert any(f.rule == "JX102" and "weak-typed" in f.message
               for f in findings), findings


def test_jx103_debug_print_flagged(monkeypatch):
    def noisy_program():
        def fn(x):
            jax.debug.print("x = {}", x)
            return x * 2
        return fn, (jnp.zeros((4,), jnp.float32),)

    monkeypatch.setattr(jaxpr_audit, "DEFAULT_PROGRAMS",
                        (("noisy-fixture", noisy_program),))
    findings = jaxpr_audit.EffectsAuditCheck().run(load_config(REPO_ROOT))
    assert [f.rule for f in findings] == ["JX103"]
    assert "debug_callback" in findings[0].message


@pytest.mark.slow
def test_jx102_jx103_clean_on_default_programs():
    """The default audit matrix (ELARE/FELARE + full aux stack) carries
    no float64, no weak outputs, no effect primitives."""
    cfg = load_config(REPO_ROOT)
    for check in (jaxpr_audit.DtypeAuditCheck(),
                  jaxpr_audit.EffectsAuditCheck()):
        findings = check.run(cfg)
        assert findings == [], analysis.format_findings(findings)


@pytest.mark.slow
def test_jx104_retrace_replay_clean():
    findings = jaxpr_audit.RetraceAuditCheck(n_tasks=16).run(
        load_config(REPO_ROOT))
    assert findings == [], analysis.format_findings(findings)


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_list_checks(capsys):
    assert check_cli.main(["--list-checks"]) == 0
    out = capsys.readouterr().out
    for rule in ("JD001", "JD002", "JD003", "JD004", "JD005",
                 "JX101", "JX102", "JX103", "JX104"):
        assert rule in out


def test_cli_layer1_clean_exit0(capsys):
    assert check_cli.main(["--layer", "1", "--root", REPO_ROOT]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_json_round_trip(tmp_path, capsys):
    """Findings survive the --json report byte-exactly, and a dirty tree
    exits non-zero with rule ids in the report."""
    bad = tmp_path / "src" / "repro" / "core"
    bad.mkdir(parents=True)
    (tmp_path / "pyproject.toml").write_text(
        "[tool.repro.analysis]\nexclude = []\n")
    (bad / "bad.py").write_text(textwrap.dedent("""\
        import jax

        def _stage_admit(st):
            key = jax.random.PRNGKey(0)
            print("tracing")
            return st
        """))
    out_json = tmp_path / "analysis.json"
    rc = check_cli.main([
        "--layer", "1", "--root", str(tmp_path), "--json", str(out_json),
        "--checks", "crn-discipline,host-effects"])
    assert rc == 1
    report = json.loads(out_json.read_text())
    assert report["ok"] is False
    assert report["findings_by_rule"] == {"JD002": 1, "JD003": 1}
    loaded = load_json(out_json)
    assert loaded == sorted(
        from_json_dict(d) for d in report["findings"])
    assert {f.rule for f in loaded} == {"JD002", "JD003"}
    assert all(isinstance(f, Finding) and f.line for f in loaded)


def test_cli_crashed_check_fails_gate(tmp_path, monkeypatch):
    """A check that raises must fail the gate, not silently pass."""
    import dataclasses as _dc

    @_dc.dataclass(frozen=True)
    class Exploding:
        name: str = "exploding"
        rule: str = "JD999"
        layer: int = 1

        def run(self, cfg):
            raise RuntimeError("boom")

    analysis.register("exploding", Exploding())
    try:
        out_json = tmp_path / "r.json"
        rc = check_cli.main(["--checks", "exploding", "--root", REPO_ROOT,
                             "--json", str(out_json)])
        assert rc == 1
        report = json.loads(out_json.read_text())
        assert report["ok"] is False and report["errors"]
    finally:
        analysis.CHECKS.unregister("exploding")
