"""Frozen pre-scenario-API workload synthesis (PR 1/2 state). DO NOT EDIT.

Verbatim copies of ``repro.core.workload.poisson_trace`` and
``repro.datapipe.synthetic.trace_stack`` as they existed before the
composable Scenario API landed. ``tests/test_scenario_regression.py`` pins
the default ``scenario="poisson"`` path to be *byte-identical* to these —
the same PRNG key must yield the same split order, the same sampling ops in
the same dtype, and therefore the same bits in every ``Trace`` leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import eet as eet_mod
from repro.core import equations
from repro.core.types import Trace


def legacy_poisson_trace(key, n_tasks, arrival_rate, eet, *,
                         n_task_types=None, cv_run=0.1,
                         type_probs=None) -> Trace:
    """Pre-refactor ``workload.poisson_trace``, frozen."""
    eet = jnp.asarray(eet)
    if n_task_types is None:
        n_task_types = eet.shape[0]
    k_arr, k_type, k_exec = jax.random.split(key, 3)

    gaps = jax.random.exponential(k_arr, (n_tasks,)) / arrival_rate
    arrival = jnp.cumsum(gaps).astype(jnp.float32)

    if type_probs is None:
        task_type = jax.random.randint(k_type, (n_tasks,), 0, n_task_types)
    else:
        task_type = jax.random.choice(
            k_type, n_task_types, (n_tasks,), p=jnp.asarray(type_probs)
        )
    task_type = task_type.astype(jnp.int32)

    deadline = equations.deadlines(arrival, task_type, eet)
    exec_actual = eet_mod.sample_actual_exec(k_exec, eet, task_type, cv_run)
    return Trace(arrival, task_type, deadline, exec_actual)


def legacy_trace_stack(key, rates, reps, n_tasks, eet, *, cv_run=0.1,
                       type_probs=None):
    """Pre-refactor ``synthetic.trace_stack``, frozen."""
    rep_keys = jax.random.split(key, reps)                    # (K, 2)
    rates_arr = jnp.asarray(rates, jnp.float32)               # (R,)

    def one(rate, k):
        return legacy_poisson_trace(
            k, n_tasks, rate, eet, cv_run=cv_run, type_probs=type_probs
        )

    over_reps = jax.vmap(one, in_axes=(None, 0))              # (K, ...)
    return jax.vmap(over_reps, in_axes=(0, None))(rates_arr, rep_keys)
