"""Unit tests for the mapping heuristics at a single mapping event."""
import jax.numpy as jnp
import numpy as np

from repro.core import heuristics
from repro.core.heuristics import MachineView
from repro.core.types import SystemArrays

# 2 task types x 2 machines toy system:
#   machine 0: slow & frugal; machine 1: fast & hungry.
EET = jnp.array([[4.0, 1.0], [8.0, 2.0]], jnp.float32)
SYS = SystemArrays(
    eet=EET,
    p_dyn=jnp.array([1.0, 5.0], jnp.float32),
    p_idle=jnp.array([0.05, 0.05], jnp.float32),
)


def _view(avail=(0.0, 0.0), queue=None, Q=2):
    M = len(avail)
    q = jnp.full((M, Q), -1, jnp.int32) if queue is None else jnp.asarray(queue)
    qlen = (q >= 0).sum(axis=1).astype(jnp.int32)
    return MachineView(jnp.asarray(avail, jnp.float32), q, qlen)


def _call(fn, now, pending, ttype, dl, view, suffered=None):
    pending = jnp.asarray(pending)
    suffered = (
        jnp.zeros(EET.shape[0], bool) if suffered is None
        else jnp.asarray(suffered)
    )
    return fn(
        jnp.float32(now), pending, jnp.asarray(ttype, jnp.int32),
        jnp.asarray(dl, jnp.float32), view, SYS, suffered,
    )


class TestELARE:
    def test_picks_min_energy_feasible(self):
        # type-0 task, generous deadline: both machines feasible.
        # energies: m0 = 1*4 = 4, m1 = 5*1 = 5 -> picks m0 (min energy).
        act = _call(heuristics.elare_select, 0.0, [True], [0], [100.0], _view())
        assert int(act.assign[0]) == 0
        assert int(act.assign[1]) == -1

    def test_falls_back_to_fast_machine_under_tight_deadline(self):
        # deadline 2: only m1 (e=1) is feasible.
        act = _call(heuristics.elare_select, 0.0, [True], [0], [2.0], _view())
        assert int(act.assign[1]) == 0
        assert int(act.assign[0]) == -1

    def test_defers_infeasible_but_not_hopeless(self):
        # m1 busy until 5, m0 too slow: infeasible now, but an empty m1
        # could make it (0 + 1 <= 2 is false once avail=5 though) -> with
        # avail (0,5): s1=5, 5+1>2 infeasible; min eet = 1, now+1 <= 2 ->
        # not hopeless -> deferred, NOT dropped.
        act = _call(
            heuristics.elare_select, 0.0, [True], [0], [2.0], _view((0.0, 5.0))
        )
        assert int(act.assign[0]) == -1 and int(act.assign[1]) == -1
        assert not bool(act.drop[0])

    def test_drops_hopeless(self):
        # even the fastest machine misses: now + min_e = 0 + 1 > 0.5
        act = _call(heuristics.elare_select, 0.0, [True], [0], [0.5], _view())
        assert bool(act.drop[0])

    def test_drops_stale(self):
        act = _call(heuristics.elare_select, 10.0, [True], [0], [9.0], _view())
        assert bool(act.drop[0])

    def test_one_task_per_machine(self):
        # three identical tasks, all prefer m0 -> only the min-ec one maps.
        act = _call(
            heuristics.elare_select, 0.0, [True] * 3, [0, 0, 0],
            [100.0, 100.0, 100.0], _view(),
        )
        assert int(act.assign[0]) == 0  # lowest index on ties
        assigned = set(int(a) for a in act.assign if int(a) >= 0)
        assert len(assigned) == len([a for a in act.assign if int(a) >= 0])


class TestBaselines:
    def test_mm_picks_min_completion(self):
        # MM ignores energy: m1 completes at 1 < m0 at 4 -> m1.
        act = _call(heuristics.mm_select, 0.0, [True], [0], [100.0], _view())
        assert int(act.assign[1]) == 0

    def test_mm_maps_infeasible(self):
        # deadline hopeless -> MM still maps (no feasibility check). Eq. 1
        # clamps both completions to the deadline (tie) -> machine 0 wins.
        act = _call(heuristics.mm_select, 0.0, [True], [0], [0.5], _view())
        assert 0 in [int(a) for a in act.assign]
        assert not bool(act.drop[0])

    def test_msd_prefers_soonest_deadline(self):
        act = _call(
            heuristics.msd_select, 0.0, [True, True], [0, 0], [50.0, 20.0],
            _view(),
        )
        # both nominate m1 (faster); MSD picks task 1 (deadline 20).
        assert int(act.assign[1]) == 1

    def test_mmu_prefers_least_slack(self):
        act = _call(
            heuristics.mmu_select, 0.0, [True, True], [0, 0], [50.0, 3.0],
            _view(),
        )
        # task 1 slack = 3 - 1 = 2 << task 0 slack -> picked first.
        assert int(act.assign[1]) == 1


class TestFELARE:
    def test_suffered_priority(self):
        # two tasks, types 0 and 1, both feasible only on m1 (tight-ish dl).
        # type 1 is suffered -> it wins the machine even with higher energy.
        act = _call(
            heuristics.felare_select, 0.0, [True, True], [0, 1], [3.0, 3.0],
            _view(), suffered=[False, True],
        )
        assert int(act.assign[1]) == 1

    def test_queue_eviction_rescues_suffered(self):
        # m1 queue holds a non-suffered type-0 task (task idx 1); pending
        # suffered type-1 task (idx 0) infeasible with the queue ahead of it
        # (s = 2 + 1 = 3; 3 + 2 > 4) but feasible if the victim is evicted
        # (s = 2; 2 + 2 <= 4). m0 is far too slow (e=8).
        queue = jnp.array([[-1, -1], [1, -1]], jnp.int32)
        view = MachineView(
            jnp.array([0.0, 2.0], jnp.float32), queue,
            jnp.array([0, 1], jnp.int32),
        )
        # tasks: idx0 pending type1 dl 4; idx1 queued type0 dl big
        act = _call(
            heuristics.felare_select, 0.0, [True, False], [1, 0],
            [4.0, 100.0], view, suffered=[False, True],
        )
        assert bool(act.queue_drop[1, 0])          # victim evicted
        assert int(act.assign[1]) == 0             # suffered task mapped

    def test_no_eviction_of_suffered_victims(self):
        # same but the queued victim is itself of a suffered type -> no evict.
        queue = jnp.array([[-1, -1], [1, -1]], jnp.int32)
        view = MachineView(
            jnp.array([0.0, 2.0], jnp.float32), queue,
            jnp.array([0, 1], jnp.int32),
        )
        act = _call(
            heuristics.felare_select, 0.0, [True, False], [1, 1],
            [4.0, 100.0], view, suffered=[False, True],
        )
        assert not bool(act.queue_drop.any())

    def test_no_pointless_eviction(self):
        # suffered task hopeless even on an empty machine -> no eviction.
        queue = jnp.array([[-1, -1], [1, -1]], jnp.int32)
        view = MachineView(
            jnp.array([0.0, 2.0], jnp.float32), queue,
            jnp.array([0, 1], jnp.int32),
        )
        act = _call(
            heuristics.felare_select, 0.0, [True, False], [1, 0],
            [0.5, 100.0], view, suffered=[False, True],
        )
        assert not bool(act.queue_drop.any())

    def test_reduces_to_elare_when_no_suffering(self):
        act_f = _call(
            heuristics.felare_select, 0.0, [True, True], [0, 1],
            [100.0, 100.0], _view(), suffered=[False, False],
        )
        act_e = _call(
            heuristics.elare_select, 0.0, [True, True], [0, 1],
            [100.0, 100.0], _view(), suffered=[False, False],
        )
        assert np.array_equal(np.asarray(act_f.assign), np.asarray(act_e.assign))
        assert np.array_equal(np.asarray(act_f.drop), np.asarray(act_e.drop))


class TestInvariants:
    def test_full_queues_block_assignment(self):
        queue = jnp.array([[2, 3], [4, 5]], jnp.int32)
        view = MachineView(
            jnp.zeros(2, jnp.float32), queue, jnp.array([2, 2], jnp.int32)
        )
        for fn in heuristics.HEURISTICS.values():
            act = _call(fn, 0.0, [True], [0], [100.0], view)
            assert int(act.assign[0]) == -1 and int(act.assign[1]) == -1

    def test_nothing_assigned_when_nothing_pending(self):
        for fn in heuristics.HEURISTICS.values():
            act = _call(fn, 0.0, [False, False], [0, 1], [10.0, 10.0], _view())
            assert (np.asarray(act.assign) == -1).all()
            assert not np.asarray(act.drop).any()
