"""Tests for the network subsystem (tiers, link costs, tier-aware dispatch).

Contracts under test:

  * degeneracy — ``network="none"`` (and the default) is bit-identical
    to the pre-network engine: every metric leaf and the full task log
    match the frozen PR 8 snapshot
    (``tests/data/pr8_engine_snapshot.json``) for all dispatchers x
    ELARE/FELARE, and a *zero-cost* tiered network is bit-identical to
    the flat federation for every dispatcher (hypothesis battery);
  * oracle — the pure-Python interpreter replays ``uniform_latency``
    and ``tiered`` event-for-event on the tiered fleet (metrics,
    energies and full task logs including site ready times);
  * dispatch — ``tier_aware`` == ``min_eet`` bit-for-bit when no
    network is attached, and routes around expensive links when one is;
  * safety — no task ever starts before its ready time (hypothesis);
  * plumbing — the ``network`` observer, registries, tiered fleets,
    ``--network`` / ``--list-networks`` / ``--list-fleets``, SweepSpec
    JSON round-trips (old payloads default to ``"none"``), and the
    scale smoke (full size under ``REPRO_SCALE_FULL=1``).
"""
import json
import os

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro import experiments, scenarios
from repro.core import dispatch, engine, network, pyengine, workload
from repro.experiments import runner, sweep

SPEC2 = scenarios.get_fleet("paper_x2").build()
TIERED = scenarios.get_fleet("tiered_x4").build()

ZERO3 = ((0.0, 0.0, 0.0),) * 3
FREE_TIERED = network.Tiered(latency=ZERO3, energy=ZERO3)


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate, eet):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


# -------------------------------------------------------------- registries
def test_builtin_networks_registered():
    names = network.list_networks()
    for name in ("none", "uniform_latency", "tiered"):
        assert name in names
        assert network.is_registered(name)
        assert network.describe(name)  # non-empty one-liner
    assert isinstance(network.get("NONE"), network.NoNetwork)  # case-insens
    with pytest.raises(KeyError, match="choose from"):
        network.get("nope")
    with pytest.raises(TypeError, match="NetworkModel protocol"):
        network.register("bad", object())


def test_network_json_round_trip():
    for m in (network.NoNetwork(),
              network.UniformLatency(latency=0.5, energy=0.25, salt=3),
              network.Tiered(),
              network.Tiered(input_size=(0.5, 1.0, 2.0, 4.0), salt=1),
              FREE_TIERED):
        back = network.from_json_dict(
            json.loads(json.dumps(network.to_json_dict(m))))
        assert back == m
    with pytest.raises(ValueError, match="unknown network kind"):
        network.from_json_dict({"kind": "nope"})


def test_network_validation():
    with pytest.raises(ValueError, match=">= 0"):
        network.UniformLatency(latency=-0.1)
    with pytest.raises(ValueError, match="square"):
        network.Tiered(latency=((0.0, 1.0),))
    with pytest.raises(ValueError):
        # matrix covers 3 tiers; a fleet using tier 3 must be rejected
        network.Tiered().cost_tables((0, 1, 3), 4)
    with pytest.raises(ValueError, match="input_size"):
        network.Tiered(input_size=(1.0, 2.0)).cost_tables((0, 1, 2), 4)


def test_cost_tables_shape_and_zero_diagonal():
    tiers = TIERED.tiers
    F = len(tiers)
    for name in ("uniform_latency", "tiered"):
        lat, en = network.get(name).cost_tables(tiers, 4)
        assert lat.shape == en.shape == (4, F, F)
        assert lat.dtype == en.dtype == np.float32
        for t in range(4):
            assert np.all(np.diag(lat[t]) == 0.0)
            assert np.all(np.diag(en[t]) == 0.0)
        assert lat.min() >= 0.0 and en.min() >= 0.0


def test_hash_origins_host_mirrors_jax_bit_for_bit():
    """The oracle's plain-int origin hash reproduces the jitted draw
    exactly — the property that makes transfer traces cross-checkable."""
    for salt in (0, 7, 123):
        for elig in ((0,), (0, 1, 2), (2, 5, 6, 11)):
            dev = np.asarray(network.hash_origins(64, elig, salt))
            host = network.hash_origins_host(64, elig, salt)
            np.testing.assert_array_equal(dev, host)
            assert set(host) <= set(elig)


def test_origin_sites_lowest_tier_only():
    assert network.origin_sites((0, 0, 0, 2)) == (0, 1, 2)
    assert network.origin_sites((1, 2, 1)) == (0, 2)  # lowest tier present
    assert network.origin_sites((0, 0)) == (0, 1)  # flat: every site


# ------------------------------------------------------------ tiered fleets
def test_tiered_fleet_structure():
    assert TIERED.tiers == (0, 0, 0, 2)
    assert TIERED.n_tiers == 3
    assert TIERED.n_sites == 4
    S, M = TIERED.eet.shape
    cloud = [j for j in range(M) if TIERED.sites[j] == 3]
    device = [j for j in range(M) if TIERED.sites[j] != 3]
    assert cloud and device
    # cloud machines: mains-powered (no idle draw) and faster than base
    p_idle = np.asarray(TIERED.p_idle)
    assert np.all(p_idle[cloud] == 0.0)
    assert np.all(p_idle[device] > 0.0)
    eet = np.asarray(TIERED.eet)
    assert eet[:, cloud].min() < eet[:, device].min()
    big = scenarios.get_fleet("tiered_x16").build()
    assert big.n_sites == 16
    assert big.tiers == (0,) * 15 + (2,)


def test_systemspec_tier_validation():
    import dataclasses

    with pytest.raises(ValueError, match="tier_of_site"):
        dataclasses.replace(SPEC2, tier_of_site=(0,))  # len != n_sites
    with pytest.raises(ValueError, match="tiers must be >= 0"):
        dataclasses.replace(SPEC2, tier_of_site=(-1, 0))
    assert SPEC2.tiers == (0, 0)  # untirered default: all device tier
    assert SPEC2.n_tiers == 1


# ------------------------------------------------- degeneracy (bit-exact)
def test_network_none_bit_exact_with_pr8_snapshot():
    """network="none" (and the default) reproduce the frozen pre-network
    engine bit for bit: metrics and task logs for all dispatchers x 2
    mapping heuristics."""
    with open("tests/data/pr8_engine_snapshot.json") as f:
        snap = json.load(f)
    tr = _trace(1, 40, 4.0, SPEC2.eet)
    for key, want in snap.items():
        d, h = key.split("/")
        m, aux = engine.simulate(tr, SPEC2, h, observers=("task_log",),
                                 dispatcher=d, network="none")
        for f in m._fields:
            got = np.asarray(getattr(m, f), np.float32)
            ref = np.asarray(want[f], np.float32)
            assert got.tobytes() == ref.tobytes(), f"{key}/{f}"
        log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
        for f, ref in want["task_log"].items():
            got = log[f]
            ref = np.asarray(ref, got.dtype)
            assert got.tobytes() == ref.tobytes(), f"{key}/task_log.{f}"
        # without a network the ready column is the -1 sentinel fill
        assert np.all(log["ready_time"] == -1.0), key


def test_default_network_is_none():
    tr = _trace(1, 40, 4.0, SPEC2.eet)
    a = engine.simulate(tr, SPEC2, "FELARE", dispatcher="fair_spill")
    b = engine.simulate(tr, SPEC2, "FELARE", dispatcher="fair_spill",
                        network="none")
    for f in a._fields:
        assert np.asarray(getattr(a, f)).tobytes() == \
            np.asarray(getattr(b, f)).tobytes(), f


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 31), rate=st.sampled_from([2.0, 4.0, 6.0]))
def test_zero_cost_tiered_degenerates_to_flat_federation(seed, rate):
    """A tiered network whose matrices are all-zero is bit-identical to
    the flat federation (no network) for every dispatcher x ELARE/FELARE:
    ready times collapse to dispatch times, transfer energy to zero, and
    the event order is untouched."""
    tr = _trace(seed, 40, rate, TIERED.eet)
    for d in dispatch.list_dispatchers():
        for h in ("ELARE", "FELARE"):
            m0, a0 = engine.simulate(tr, TIERED, h, observers=("task_log",),
                                     dispatcher=d)
            m1, a1 = engine.simulate(tr, TIERED, h, observers=("task_log",),
                                     dispatcher=d, network=FREE_TIERED)
            for f in m0._fields:
                assert np.asarray(getattr(m0, f)).tobytes() == \
                    np.asarray(getattr(m1, f)).tobytes(), f"{d}/{h}/{f}"
            l0 = {k: np.asarray(v) for k, v in a0["task_log"].items()}
            l1 = {k: np.asarray(v) for k, v in a1["task_log"].items()}
            for f in l0:
                if f == "ready_time":  # -1 fill vs stamped, by design
                    continue
                assert l0[f].tobytes() == l1[f].tobytes(), f"{d}/{h}/{f}"


def test_tier_aware_equals_min_eet_without_network():
    tr = _trace(2, 60, 4.0, TIERED.eet)
    for h in ("ELARE", "FELARE"):
        a, la = engine.simulate(tr, TIERED, h, observers=("task_log",),
                                dispatcher="tier_aware")
        b, lb = engine.simulate(tr, TIERED, h, observers=("task_log",),
                                dispatcher="min_eet")
        for f in a._fields:
            assert np.asarray(getattr(a, f)).tobytes() == \
                np.asarray(getattr(b, f)).tobytes(), f"{h}/{f}"
        assert np.asarray(la["task_log"]["site"]).tobytes() == \
            np.asarray(lb["task_log"]["site"]).tobytes(), h


# ------------------------------------------------------------------ oracle
@pytest.mark.parametrize("net", ["uniform_latency", "tiered"])
@pytest.mark.parametrize("dispatcher", ["tier_aware", "fair_spill"])
@pytest.mark.parametrize("heuristic", ["ELARE", "FELARE"])
def test_tiered_task_log_matches_oracle_event_for_event(
        net, dispatcher, heuristic):
    """Engine and oracle agree event-for-event on the tiered fleet with
    transfer costs attached: per-type counters, energies, and the full
    task log including site ready times."""
    for seed in (0, 3):
        tr = _trace(seed, 60, 4.0, TIERED.eet)
        m, aux = engine.simulate(tr, TIERED, heuristic,
                                 observers=("task_log",),
                                 dispatcher=dispatcher, network=net)
        ref = pyengine.simulate(tr, TIERED, heuristic,
                                dispatcher=dispatcher, network=net)
        for f in ("completed_by_type", "missed_by_type",
                  "cancelled_by_type", "arrived_by_type"):
            np.testing.assert_array_equal(
                np.asarray(getattr(m, f)), ref[f], err_msg=f)
        np.testing.assert_allclose(
            float(m.energy_dynamic), ref["energy_dynamic"], rtol=1e-4)
        np.testing.assert_allclose(
            float(m.energy_wasted), ref["energy_wasted"], rtol=1e-4,
            atol=1e-6)
        np.testing.assert_allclose(
            float(m.makespan), ref["makespan"], rtol=1e-5)
        log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
        for f in ("map_time", "start_time", "end_time", "ready_time"):
            np.testing.assert_allclose(
                log[f], ref["task_log"][f], atol=1e-5, err_msg=f)
        for f in ("machine", "site", "status", "retries"):
            np.testing.assert_array_equal(
                log[f], ref["task_log"][f], err_msg=f)


# ------------------------------------------------------------------ safety
@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 63), rate=st.sampled_from([2.0, 4.0, 8.0]),
       net=st.sampled_from(["uniform_latency", "tiered"]))
def test_no_task_starts_before_it_lands(seed, rate, net):
    """With a network attached, no task ever starts before its stamped
    ready time — in-transit tasks are invisible to the mapper."""
    tr = _trace(seed, 50, rate, TIERED.eet)
    _, aux = engine.simulate(tr, TIERED, "FELARE", observers=("task_log",),
                             dispatcher="tier_aware", network=net)
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    started = log["start_time"] >= 0
    assert np.all(log["start_time"][started]
                  >= log["ready_time"][started] - 1e-5)
    # in-transit expiry is CANCELLED, never silently dropped: every
    # arrived task has a terminal status
    from repro.core.types import PENDING, QUEUED, RUNNING, UNARRIVED

    final = log["status"]
    assert not np.any((final == PENDING) | (final == QUEUED)
                      | (final == RUNNING))
    assert np.all((final == UNARRIVED) | (log["site"] >= -1))


def test_cross_tier_latency_slows_uniform_dispatches():
    """uniform_latency with a visible price must not beat the same run
    with free links on ready times: every stamped ready >= dispatch-time
    floor, and total dynamic energy strictly grows with link energy."""
    tr = _trace(5, 60, 4.0, TIERED.eet)
    base = engine.simulate(tr, TIERED, "FELARE", dispatcher="sticky")
    paid = engine.simulate(
        tr, TIERED, "FELARE", dispatcher="sticky",
        network=network.UniformLatency(latency=0.25, energy=0.5))
    assert float(paid.energy_dynamic) > float(base.energy_dynamic)


# ------------------------------------------------------- network observer
def test_network_observer_shapes_and_accounting():
    # sticky scatters tasks across sites, so cross-site links are paid
    # (tier_aware would keep every task on its free origin site here)
    tr = _trace(3, 60, 4.0, TIERED.eet)
    _, aux = engine.simulate(tr, TIERED, "FELARE",
                             observers=("network", "task_log"),
                             dispatcher="sticky", network="tiered")
    net = aux["network"]
    K = 64
    T = TIERED.n_tiers
    assert np.asarray(net["tier_load"]).shape == (K, T)
    assert np.asarray(net["xfer_energy"]).shape == (K, T)
    assert np.asarray(net["in_transit"]).shape == (K,)
    xe = np.asarray(net["xfer_energy"])
    # cumulative per-tier transfer energy: monotone non-decreasing
    assert np.all(np.diff(xe, axis=0) >= -1e-6)
    assert xe.sum() > 0  # tiered matrices have visible prices
    assert np.asarray(net["tier_load"]).min() >= 0
    assert np.asarray(net["in_transit"]).min() >= 0


def test_network_observer_flat_without_network():
    tr = _trace(3, 50, 4.0, SPEC2.eet)
    _, aux = engine.simulate(tr, SPEC2, "ELARE", observers=("network",))
    net = aux["network"]
    assert np.all(np.asarray(net["xfer_energy"]) == 0.0)
    assert np.all(np.asarray(net["in_transit"]) == 0)


# ------------------------------------------------------------ CLI + spec
def test_cli_tiered_sweep_writes_artifacts(tmp_path):
    runner._TRACE_LOG.clear()
    out = tmp_path / "tiered"
    sweep.main([
        "--system", "tiered_x4", "--dispatcher", "tier_aware",
        "--network", "tiered", "--observers", "network,task_log",
        "--rates", "4.0", "--reps", "1", "--tasks", "40",
        "--heuristics", "FELARE", "--out", str(out),
    ])
    payload = json.loads((out / "sweep.json").read_text())
    assert payload["spec"]["network"] == "tiered"
    assert (out / "sweep.csv").exists()
    assert (out / "observers.json").exists()
    assert set(runner._TRACE_LOG) == {
        ("FELARE", "poisson", "tier_aware", "none", "tiered")}
    runner._TRACE_LOG.clear()


def test_cli_rejects_unknown_network(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--network", "nope"])
    assert "unknown network" in capsys.readouterr().err


def test_cli_list_networks(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--list-networks"])
    out = capsys.readouterr().out
    for name in ("none", "uniform_latency", "tiered"):
        assert name in out


def test_cli_list_fleets(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--list-fleets"])
    out = capsys.readouterr().out
    for name in ("paper", "tiered_x4", "tiered_x16"):
        assert name in out
    assert "0,0,0,2" in out  # tier layout column for tiered_x4


def test_sweep_spec_network_round_trip():
    spec = experiments.SweepSpec(
        system="tiered_x4", rates=(4.0,), reps=1, n_tasks=20,
        heuristics=("FELARE",), network="tiered",
        dispatcher="tier_aware")
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back == spec
    # instance form round-trips through kind + fields
    spec2 = experiments.replace(
        spec, network=network.UniformLatency(latency=0.5))
    back2 = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec2.to_json_dict())))
    assert back2.resolve_network() == network.UniformLatency(latency=0.5)


def test_sweep_spec_old_payload_defaults_to_none():
    """Pre-network sweep.json payloads (no "network" key) load as free
    links — re-running an old artifact reproduces the old numbers."""
    d = experiments.SweepSpec(rates=(4.0,), reps=1, n_tasks=20,
                              heuristics=("ELARE",)).to_json_dict()
    del d["network"]
    spec = experiments.SweepSpec.from_json_dict(d)
    assert spec.network == "none"
    assert isinstance(spec.resolve_network(), network.NoNetwork)


def test_sweep_spec_rejects_unknown_network():
    with pytest.raises(ValueError, match="unknown network"):
        experiments.SweepSpec(rates=(4.0,), reps=1, n_tasks=20,
                              heuristics=("ELARE",), network="nope")


def test_systemspec_tiered_serialization_round_trip():
    spec = experiments.SweepSpec(
        system=TIERED, rates=(4.0,), reps=1, n_tasks=20,
        heuristics=("FELARE",), network="tiered")
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back.system.tier_of_site == TIERED.tier_of_site
    assert back.system.site_of_machine == TIERED.site_of_machine


def test_run_study_accepts_network():
    from repro.core import api

    res = api.run_study("FELARE", [4.0], TIERED, n_traces=2, n_tasks=30,
                        dispatcher="tier_aware", network="tiered")
    assert len(res) == 1
    assert int(np.asarray(res[0].metrics.arrived_by_type).sum()) > 0


# ------------------------------------------------------------- scale smoke
@pytest.mark.slow
def test_scale_smoke_single_trace_per_tuple():
    """A large vmapped tiered sweep completes with exactly one jit trace
    per (policy, dispatcher, dynamics, network) tuple. Default size is
    CI-friendly; REPRO_SCALE_FULL=1 runs the full 10^3 x 10^4 grid."""
    full = os.environ.get("REPRO_SCALE_FULL", "") == "1"
    reps = 1000 if full else 100
    n_tasks = 10_000 if full else 200
    runner._TRACE_LOG.clear()
    result = experiments.run_sweep(experiments.SweepSpec(
        system="tiered_x4", rates=(4.0,), reps=reps, n_tasks=n_tasks,
        heuristics=("ELARE", "FELARE"), seed=2,
        dispatcher="tier_aware", network="tiered",
    ))
    assert list(runner._TRACE_LOG) == [
        (h, "poisson", "tier_aware", "none", "tiered")
        for h in ("ELARE", "FELARE")]
    runner._TRACE_LOG.clear()
    arrived = np.asarray(result.metrics.arrived_by_type)
    assert arrived.shape[:3] == (2, 1, reps)
    assert np.all(arrived.sum(axis=-1) == n_tasks)  # every task accounted
