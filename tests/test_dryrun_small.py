"""The dry-run machinery end-to-end at CI scale: lower_cell on an 8-device
(2,2,2) mesh with reduced configs — exercises the same code path as the
512-chip sweep (subprocess for the device-count flag)."""
import os
import subprocess
import sys
import textwrap

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
"""


def _run(body):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_lower_cell_all_kinds_small_mesh():
    out = _run("""
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_mesh
    from repro.configs import registry

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    # dense train + decode, hybrid long-context: the three step kinds
    cases = [
        ("qwen1.5-0.5b", "train_4k"),
        ("qwen1.5-0.5b", "decode_32k"),
        ("internlm2-1.8b", "prefill_32k"),
        ("zamba2-2.7b", "long_500k"),
    ]
    for arch, shape in cases:
        cfg = registry.get_smoke_config(arch)
        rec = lower_cell(arch, shape, mesh, "ci", accum=2, cfg=cfg)
        assert rec["status"] == "ok", (arch, shape, rec)
        ro = rec["roofline"]
        assert ro["t_comp_s"] > 0 and ro["t_mem_s"] > 0
        print(arch, shape, "ok", ro["dominant"])
    # full-attention arch skips long_500k through the same path
    rec = lower_cell("qwen1.5-0.5b", "long_500k", mesh, "ci",
                     cfg=registry.get_smoke_config("qwen1.5-0.5b"))
    assert rec["status"] == "skip"
    print("OK")
    """)
    assert "OK" in out
