"""xla_chunked attention == dense attention (the XLA peak-memory option)."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as tf
from repro.models.layers import sdpa_xla, sdpa_xla_chunked


@pytest.mark.parametrize("Sq,Sk,block", [(64, 64, 16), (100, 100, 32),
                                         (32, 128, 8)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_dense(Sq, Sk, block, causal):
    if causal and Sq != Sk:
        pytest.skip("causal requires aligned q/k here")
    ks = jax.random.split(jax.random.PRNGKey(Sq + Sk), 3)
    B, H, Hkv, hd = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (B, Sq, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd)) * 0.5
    got = sdpa_xla_chunked(q, k, v, causal=causal, block=block)
    want = sdpa_xla(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_model_forward_with_chunked_attention():
    cfg = registry.get_smoke_config("internlm2-1.8b").scaled(
        remat=False, dtype="float32", param_dtype="float32")
    cfg_c = cfg.scaled(attn_impl="xla_chunked")
    params = tf.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                                          cfg.vocab_size)}
    h1, _ = tf.forward(cfg, params, batch)
    h2, _ = tf.forward(cfg_c, params, batch)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
