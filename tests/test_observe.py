"""Tests for the composable engine-observability API (repro.core.observe).

Contracts under test:

  * registry round-trip + spec validation (mirrors policy/scenario axes);
  * observers ride inside the single vmapped jit: batched sweep aux ==
    sequential per-trace aux, and attaching observers adds no retraces;
  * the ``task_log`` observer agrees with the pure-Python oracle
    event-for-event (ELARE and FELARE);
  * the ``energy_budget`` dynamic observer halts admission at capacity
    and is inert when unset;
  * internal consistency of the ``timeline``/``fairness_trajectory``
    series against end-of-trace Metrics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import experiments
from repro.core import api, engine, observe, pyengine, workload
from repro.experiments import runner

SPEC = api.paper_system()


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, SPEC.eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


# ----------------------------------------------------------------- registry
def test_builtins_registered():
    names = observe.list_observers()
    for name in ("timeline", "fairness_trajectory", "task_log",
                 "energy_budget"):
        assert name in names
        assert observe.is_registered(name)
    assert isinstance(observe.get("TIMELINE"), observe.Timeline)  # case-insens


def test_register_round_trip_and_unknown_name():
    ob = observe.Timeline(n_buckets=7)
    observe.register("My-Timeline", ob)
    try:
        got = observe.get("my-timeline")
        # the registered name is rebound onto the instance: the aux key is
        # the name you attached, not the class default
        assert got == observe.Timeline(n_buckets=7, name="my-timeline")
        assert observe.resolve(("my-timeline",)) == (got,)
    finally:
        observe.unregister("my-timeline")
    with pytest.raises(KeyError, match="choose from"):
        observe.get("nope")
    with pytest.raises(TypeError, match="Observer protocol"):
        observe.register("bad", object())


def test_registered_name_keys_the_aux():
    """Two same-class observers under distinct registry names coexist in
    one run, each keyed by its registered name."""
    observe.register("tl-coarse", observe.Timeline(n_buckets=4))
    observe.register("tl-fine", observe.Timeline(n_buckets=16))
    try:
        tr = _trace(1, 40, 3.0)
        _, aux = engine.simulate(tr, SPEC, "MM",
                                 observers=("tl-coarse", "tl-fine"))
        assert aux["tl-coarse"]["e_dyn"].shape == (4,)
        assert aux["tl-fine"]["e_dyn"].shape == (16,)
    finally:
        observe.unregister("tl-coarse")
        observe.unregister("tl-fine")


def test_spec_rejects_unknown_observer():
    with pytest.raises(ValueError, match="unknown observer"):
        experiments.SweepSpec(observers=("nope",))
    with pytest.raises(ValueError, match="Observer protocol"):
        experiments.SweepSpec(observers=(42,))


def test_spec_json_roundtrip_with_observers():
    import json

    spec = experiments.SweepSpec(
        rates=(2.0,), reps=2, n_tasks=40, heuristics=("MM",),
        observers=("timeline", observe.EnergyBudget(capacity=123.0),
                   observe.FairnessTrajectory(n_buckets=16)),
    )
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back == spec


# ------------------------------------------------- single-jit + vmap contract
def test_batched_aux_matches_sequential():
    """timeline + task_log run inside the one vmapped jit: the stacked aux
    equals per-trace simulation aux exactly (CRN trace grid preserved)."""
    spec = experiments.SweepSpec(
        rates=(2.0, 5.0), reps=2, n_tasks=60,
        heuristics=("MM", "FELARE"), seed=3,
        observers=("timeline", "task_log"),
    )
    res = experiments.run_sweep(spec)
    system = spec.resolve_system()
    scenario = spec.resolve_scenario()
    stacked = scenario.stack(
        jax.random.PRNGKey(spec.seed), spec.rates, spec.reps, spec.n_tasks,
        system.eet, cv_run=spec.cv_run,
    )
    for h_i, h in enumerate(spec.heuristics):
        for r_i in range(len(spec.rates)):
            for k in range(spec.reps):
                _, aux = engine.simulate(
                    jax.tree.map(lambda x: x[r_i, k], stacked), system, h,
                    observers=("timeline", "task_log"),
                )
                for obname, obaux in aux.items():
                    for leaf, arr in obaux.items():
                        np.testing.assert_array_equal(
                            np.asarray(arr),
                            res.aux[obname][leaf][h_i, r_i, k],
                            err_msg=f"{h} r{r_i} k{k} {obname}.{leaf}",
                        )


def test_observers_add_no_retraces():
    """One jit trace per (policy, scenario) with observers attached —
    telemetry must not grow the number of compiled programs."""
    heuristics = ("MM", "ELARE")
    runner._TRACE_LOG.clear()
    experiments.run_sweep(experiments.SweepSpec(
        rates=(3.0,), reps=2, n_tasks=50, heuristics=heuristics, seed=1,
        observers=("timeline", "task_log", "fairness_trajectory"),
    ))
    assert sorted(runner._TRACE_LOG) == sorted(
        (h, "poisson", "sticky", "none", "none") for h in heuristics)
    runner._TRACE_LOG.clear()


def test_no_observer_simulate_returns_bare_metrics():
    tr = _trace(0, 50, 3.0)
    m = engine.simulate(tr, SPEC, "ELARE")
    assert hasattr(m, "completed_by_type")  # Metrics, not (Metrics, aux)
    m2, aux = engine.simulate(tr, SPEC, "ELARE", observers=("task_log",))
    np.testing.assert_array_equal(np.asarray(m.completed_by_type),
                                  np.asarray(m2.completed_by_type))
    assert set(aux) == {"task_log"}


# --------------------------------------------------------- oracle cross-check
@pytest.mark.parametrize("heuristic", ["ELARE", "FELARE"])
@pytest.mark.parametrize("seed", [0, 5])
def test_task_log_matches_oracle_event_for_event(heuristic, seed):
    """The task_log observer's per-task map/start/end/machine/status agree
    with the pure-Python oracle at every event timestamp."""
    tr = _trace(seed, 100, 3.0)
    _, aux = engine.simulate(tr, SPEC, heuristic, observers=("task_log",))
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    ref = pyengine.simulate(tr, SPEC, heuristic)["task_log"]
    np.testing.assert_array_equal(log["status"], ref["status"])
    np.testing.assert_array_equal(log["machine"], ref["machine"])
    for field in ("map_time", "start_time", "end_time"):
        np.testing.assert_allclose(
            log[field], ref[field], rtol=1e-6, atol=1e-6, err_msg=field)


# ------------------------------------------------------------- energy budget
def test_energy_budget_halts_admission():
    tr = _trace(2, 200, 4.0)
    m = engine.simulate(tr, SPEC, "ELARE")
    total = float(m.energy_dynamic) + float(m.energy_idle)
    capacity = 0.5 * total
    ob = observe.EnergyBudget(capacity=capacity)
    mb, aux = engine.simulate(tr, SPEC, "ELARE", observers=(ob,))
    assert bool(aux["energy_budget"]["exhausted"])
    assert float(aux["energy_budget"]["t_exhausted"]) < float(m.makespan)
    # completed-task count saturates below the unbudgeted run
    assert int(np.sum(mb.completed_by_type)) < int(np.sum(m.completed_by_type))
    # total energy within one event's energy of capacity: at most the
    # in-flight work (M tasks' worth of dynamic energy) plus the idle power
    # over one longest execution.
    e_max = float(np.max(tr.exec_actual))
    slack = (float(np.max(SPEC.p_dyn)) * e_max * SPEC.n_machines
             + float(np.sum(SPEC.p_idle)) * e_max)
    budget_total = float(mb.energy_dynamic) + float(mb.energy_idle)
    assert budget_total <= capacity + slack
    # accounting stays conserved for everything that was admitted
    total_by_type = (np.asarray(mb.completed_by_type)
                     + np.asarray(mb.missed_by_type)
                     + np.asarray(mb.cancelled_by_type))
    np.testing.assert_array_equal(total_by_type,
                                  np.asarray(mb.arrived_by_type))


def test_energy_budget_unset_is_inert():
    """capacity=inf (the default registered observer) never gates: metrics
    are identical to a run without the observer."""
    tr = _trace(4, 120, 5.0)
    m = engine.simulate(tr, SPEC, "FELARE")
    mb, aux = engine.simulate(tr, SPEC, "FELARE", observers=("energy_budget",))
    for name in m._fields:
        np.testing.assert_array_equal(np.asarray(getattr(m, name)),
                                      np.asarray(getattr(mb, name)), name)
    assert not bool(aux["energy_budget"]["exhausted"])
    assert not observe.EnergyBudget().is_dynamic
    assert observe.EnergyBudget(capacity=10.0).is_dynamic


def test_energy_budget_through_run_sweep():
    """The budget flows through the batched sweep; tighter budgets complete
    no more tasks than looser ones."""
    base = dict(rates=(4.0,), reps=2, n_tasks=100, heuristics=("ELARE",),
                seed=0)
    free = experiments.run_sweep(experiments.SweepSpec(**base))
    total = float(free.energy_traces.max())
    tight = experiments.run_sweep(experiments.SweepSpec(
        **base, observers=(observe.EnergyBudget(capacity=0.4 * total),)))
    assert np.all(tight.aux["energy_budget"]["exhausted"])
    assert (tight.metrics.completed_by_type.sum()
            < free.metrics.completed_by_type.sum())


def test_fairness_trajectory_inherits_engine_factor():
    """With the default fairness_factor=None the observer samples the mask
    under the *engine's* configured factor: a lenient system (large f,
    eps = mu - f*sigma pushed down) must show strictly fewer suffered
    samples than a strict one (f=0), for an identical mapping policy."""
    tr = _trace(3, 150, 5.0)
    fracs = {}
    for f in (0.0, 4.0):
        spec = api.paper_system(fairness_factor=f)
        # MM ignores the mask entirely, so the simulated events are
        # identical across f — only the observer's sampling can differ.
        _, aux = engine.simulate(tr, spec, "MM",
                                 observers=("fairness_trajectory",))
        fracs[f] = float(np.asarray(
            aux["fairness_trajectory"]["suffered"]).mean())
    assert fracs[4.0] < fracs[0.0]
    # an explicit factor is a counterfactual override, not inherited
    _, aux = engine.simulate(
        tr, api.paper_system(fairness_factor=4.0), "MM",
        observers=(observe.FairnessTrajectory(fairness_factor=0.0),))
    assert float(np.asarray(
        aux["fairness_trajectory"]["suffered"]).mean()) == fracs[0.0]


def test_observers_json_is_strict_rfc8259(tmp_path):
    """inf leaves (an unexhausted budget's t_exhausted/capacity) must land
    as null, never the non-standard Infinity token."""
    import json

    res = experiments.run_sweep(experiments.SweepSpec(
        rates=(3.0,), reps=2, n_tasks=40, heuristics=("MM",),
        observers=("energy_budget",),
    ))
    paths = res.save(tmp_path)
    text = paths["observers_json"].read_text()
    assert "Infinity" not in text and "NaN" not in text
    payload = json.loads(text)
    assert payload["energy_budget"]["t_exhausted"][0][0] == [None, None]


# ------------------------------------------------------- series consistency
def test_timeline_final_bucket_matches_metrics():
    tr = _trace(6, 150, 4.0)
    m, aux = engine.simulate(tr, SPEC, "FELARE",
                             observers=("timeline", "fairness_trajectory"))
    tl = {k: np.asarray(v) for k, v in aux["timeline"].items()}
    np.testing.assert_array_equal(tl["completed"][-1],
                                  np.asarray(m.completed_by_type))
    np.testing.assert_array_equal(tl["arrived"][-1],
                                  np.asarray(m.arrived_by_type))
    assert tl["e_dyn"][-1] == pytest.approx(float(m.energy_dynamic), rel=1e-5)
    # cumulative series are monotone non-decreasing after forward-fill
    assert np.all(np.diff(tl["e_dyn"]) >= -1e-5)
    assert np.all(np.diff(tl["completed"].sum(-1)) >= 0)
    # end-state is drained: no queued/running tasks in the last bucket
    assert tl["qlen"][-1] == 0 and tl["running"][-1] == 0
    ft = {k: np.asarray(v) for k, v in aux["fairness_trajectory"].items()}
    assert ft["suffered"].shape == (64, SPEC.n_task_types)
    assert np.all((ft["cr"] >= 0) & (ft["cr"] <= 1))


def test_timeline_artifacts_written(tmp_path):
    from repro.experiments import sweep as sweep_cli

    out = tmp_path / "artifacts"
    sweep_cli.main([
        "--rates", "3", "--reps", "2", "--tasks", "50",
        "--heuristics", "MM", "--observers", "timeline,task_log",
        "--out", str(out),
    ])
    assert (out / "timeline.csv").exists()
    assert (out / "observers.json").exists()
    header = (out / "timeline.csv").read_text().splitlines()[0]
    assert header.startswith("heuristic,rate,rep,bucket,t,qlen")


def test_cli_list_observers_exits_clean(capsys):
    from repro.experiments import sweep as sweep_cli

    with pytest.raises(SystemExit) as e:
        sweep_cli.build_spec(["--list-observers"])
    assert e.value.code == 0
    out = capsys.readouterr().out
    assert "timeline" in out and "energy_budget" in out


# ----------------------------------------------------------- custom observer
def test_custom_observer_end_to_end():
    """A user-defined observer (event counter) registers, rides through
    run_sweep, and comes back stacked under (H, R, K)."""
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class EventCount(observe.Observer):
        name = "event_count"

        def init(self, trace, sysarr):
            return {"events": jnp.int32(0)}

        def on_event(self, stage, aux, st, trace, sysarr):
            if stage != "start":
                return aux
            return {"events": aux["events"] + 1}

    observe.register("event_count", EventCount())
    try:
        res = experiments.run_sweep(experiments.SweepSpec(
            rates=(2.0, 4.0), reps=2, n_tasks=40,
            heuristics=("MM", "ELARE"), observers=("event_count",),
        ))
        ev = res.aux["event_count"]["events"]
        assert ev.shape == (2, 2, 2)
        assert np.all(ev > 0)
    finally:
        observe.unregister("event_count")
