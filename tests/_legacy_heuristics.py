"""Frozen pre-refactor snapshot of the monolithic heuristics (PR 1 state).

DO NOT EDIT: this is the bit-exactness reference for the composed policy
API. tests/test_policy.py property-tests that every policy composed from
repro.core.policy reproduces these monoliths' MapActions and per-type
counters exactly on random traces.

Original module docstring:

Mapping heuristics: ELARE / FELARE (the paper's contribution) + baselines.

Everything is vectorized over the full arriving queue so one mapping event is
a handful of masked reductions — jittable, vmappable, and (for Phase-I) a
drop-in Pallas kernel (`repro.kernels.phase1_map`).

Conventions (shapes):
  N tasks in the trace, M machines, Q local-queue slots, S task types.
  ``pending``: (N,) bool — task is in the arriving queue right now.
  ``view``: MachineView — expected availability + queue contents.
Mapping semantics follow the paper: at each mapping event every machine is
assigned at most one task (Algorithm 3 returns one pair per machine).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax.numpy as jnp

from repro.core import equations
from repro.core.types import MapAction, SystemArrays

BIG = jnp.float32(1e30)


class MachineView(NamedTuple):
    """Scheduler-visible machine state at a mapping event."""

    avail_base: jnp.ndarray  # (M,) max(now, expected end of running task)
    queue: jnp.ndarray       # (M, Q) int32 task idx, -1 = empty, FCFS order
    qlen: jnp.ndarray        # (M,) int32


def queued_eet(view: MachineView, task_type, sysarr: SystemArrays):
    """(M, Q) expected execution time of each queued task on its machine."""
    M, Q = view.queue.shape
    occ = view.queue >= 0
    ttype = jnp.where(occ, task_type[jnp.clip(view.queue, 0)], 0)
    cols = jnp.arange(M)[:, None]
    e = sysarr.eet[ttype, jnp.broadcast_to(cols, (M, Q))]
    return jnp.where(occ, e, 0.0)


def avail_time(view: MachineView, task_type, sysarr: SystemArrays):
    """(M,) expected time each machine can start a newly-appended task."""
    return view.avail_base + queued_eet(view, task_type, sysarr).sum(axis=1)


def _pair_grid(now, task_type, deadline, view, sysarr):
    """Common (N, M) grids: start, exec, completion."""
    e = sysarr.eet[task_type]                      # (N, M)
    s = jnp.broadcast_to(
        jnp.maximum(avail_time(view, task_type, sysarr), now)[None, :], e.shape
    )
    return s, e


def _phase2(nominee: jnp.ndarray, key: jnp.ndarray, qfree: jnp.ndarray):
    """Algorithm 3: per machine pick the nominee with the minimum key.

    nominee: (N, M) bool, key: (N, M) float (lower = better).
    Returns assign: (M,) int32 task index or -1.
    """
    masked = jnp.where(nominee, key, BIG)
    best_task = jnp.argmin(masked, axis=0)                     # (M,)
    has = (jnp.min(masked, axis=0) < BIG) & qfree
    return jnp.where(has, best_task.astype(jnp.int32), -1)


def _stale(now, pending, deadline):
    return pending & (now >= deadline)


# --------------------------------------------------------------------------
# ELARE (Algorithms 1-3)
# --------------------------------------------------------------------------
def elare_phase1(now, pending, task_type, deadline, view, sysarr, qfree,
                 phase1_impl: Callable | None = None):
    """Phase-I: feasible efficient pairs.

    Returns (best_machine (N,), best_ec (N,), task_feasible (N,), s, e).
    ``phase1_impl`` optionally replaces the fused inner computation with the
    Pallas kernel (same contract as repro.kernels.phase1_map.ops.phase1_map).
    """
    s, e = _pair_grid(now, task_type, deadline, view, sysarr)
    d = deadline[:, None]
    if phase1_impl is not None:
        # Fused Pallas path: same contract, computed in one VMEM-tiled pass.
        best_m, best_ec = phase1_impl(
            s[0], e, deadline, sysarr.p_dyn, pending, qfree
        )
    else:
        feas = equations.feasible(s, e, d) & pending[:, None] & qfree[None, :]
        ec = equations.expected_energy(s, e, d, sysarr.p_dyn[None, :])
        ec_masked = jnp.where(feas, ec, BIG)
        best_m = jnp.argmin(ec_masked, axis=1).astype(jnp.int32)   # (N,)
        best_ec = jnp.min(ec_masked, axis=1)                       # (N,)
    task_feasible = best_ec < BIG
    return best_m, best_ec, task_feasible, s, e


def _hopeless(now, pending, task_type, deadline, sysarr):
    """Tasks that would miss their deadline even on an instantly-free machine.

    ELARE's proactive cancellation: deferring them cannot help, so they are
    dropped now instead of burning mapping events until staleness.
    """
    e_min = sysarr.eet[task_type].min(axis=1)
    return pending & (now + e_min > deadline)


def elare_select(now, pending, task_type, deadline, view, sysarr, suffered,
                 *, phase1_impl=None) -> MapAction:
    del suffered  # ELARE is fairness-blind
    Q = view.queue.shape[1]
    qfree = view.qlen < Q
    best_m, best_ec, task_feas, _, _ = elare_phase1(
        now, pending, task_type, deadline, view, sysarr, qfree, phase1_impl
    )
    nominee = (
        task_feas[:, None]
        & (best_m[:, None] == jnp.arange(sysarr.eet.shape[1])[None, :])
    )
    assign = _phase2(nominee, best_ec[:, None] * jnp.ones_like(nominee, jnp.float32),
                     qfree)
    drop = _stale(now, pending, deadline) | _hopeless(
        now, pending, task_type, deadline, sysarr
    )
    # Never drop a task we are assigning this very event.
    M = assign.shape[0]
    assigned_mask = jnp.zeros_like(pending).at[
        jnp.where(assign >= 0, assign, pending.shape[0])
    ].set(True, mode="drop")
    drop = drop & ~assigned_mask
    qdrop = jnp.zeros(view.queue.shape, bool)
    return MapAction(assign, drop, qdrop)


# --------------------------------------------------------------------------
# FELARE (Sec. V): suffered-type priority + queue eviction
# --------------------------------------------------------------------------
def felare_select(now, pending, task_type, deadline, view, sysarr, suffered,
                  *, phase1_impl=None) -> MapAction:
    """FELARE = ELARE + fairness.

    1. Suffered-type pending tasks form high-priority pairs; Phase-II maps
       them first.
    2. The earliest-deadline *infeasible* suffered task triggers queue
       eviction: non-suffered victims are dropped tail-first from its
       best-matching (fastest) machine until the task becomes feasible there.
    3. Machines left unassigned then serve the non-suffered pairs (keeps the
       collective completion rate from collapsing — Fig. 7's "negligible
       degradation").
    """
    M, Q = view.queue.shape
    qfree = view.qlen < Q
    suf_task = suffered[task_type] & pending                       # (N,)

    s, e = _pair_grid(now, task_type, deadline, view, sysarr)
    d = deadline[:, None]

    # --- queue eviction for the most urgent infeasible suffered task -------
    feas_now = equations.feasible(s, e, d) & pending[:, None]
    task_feas_now = jnp.any(feas_now & qfree[None, :], axis=1)
    # candidates: suffered, currently infeasible, not hopeless on an empty
    # machine (eviction cannot beat an empty machine).
    rescuable = (
        suf_task
        & ~task_feas_now
        & (now + sysarr.eet[task_type].min(axis=1) <= deadline)
    )
    cand_key = jnp.where(rescuable, deadline, BIG)
    tgt = jnp.argmin(cand_key).astype(jnp.int32)
    have_tgt = cand_key[tgt] < BIG

    # fastest (best-matching) machine for the target: min expected completion.
    comp_tgt = view.avail_base + queued_eet(view, task_type, sysarr).sum(1) \
        + sysarr.eet[task_type[tgt]]
    mstar = jnp.argmin(comp_tgt).astype(jnp.int32)

    # evict non-suffered victims tail-first until the target fits on mstar.
    q_eet = queued_eet(view, task_type, sysarr)                    # (M, Q)
    row = view.queue[mstar]                                        # (Q,)
    occ = row >= 0
    victim_ok = occ & ~suffered[task_type[jnp.clip(row, 0)]]
    e_tgt = sysarr.eet[task_type[tgt], mstar]
    base = jnp.maximum(view.avail_base[mstar], now)
    # tail-first greedy: walk q = Q-1 .. 0, evicting while still infeasible.
    evict = jnp.zeros((Q,), bool)
    remaining = q_eet[mstar].sum()
    for q in range(Q - 1, -1, -1):
        start_if = base + remaining
        need = start_if + e_tgt > deadline[tgt]
        take = need & victim_ok[q]
        evict = evict.at[q].set(take)
        remaining = remaining - jnp.where(take, q_eet[mstar, q], 0.0)
    feasible_after = base + remaining + e_tgt <= deadline[tgt]
    evict = evict & feasible_after & have_tgt  # only evict if it rescues
    qdrop = jnp.zeros((M, Q), bool).at[mstar].set(evict)

    # --- recompute availability with evictions applied ---------------------
    q_eet_after = jnp.where(qdrop, 0.0, q_eet)
    avail_after = view.avail_base + q_eet_after.sum(axis=1)
    qlen_after = view.qlen - qdrop.sum(axis=1).astype(view.qlen.dtype)
    qfree_after = qlen_after < Q
    s2 = jnp.broadcast_to(jnp.maximum(avail_after, now)[None, :], e.shape)

    if phase1_impl is not None:
        # Fused Pallas path over the post-eviction availability (same
        # contract as elare_phase1's hook).
        best_m, best_ec = phase1_impl(
            s2[0], e, deadline, sysarr.p_dyn, pending, qfree_after
        )
    else:
        feas = (equations.feasible(s2, e, d)
                & pending[:, None] & qfree_after[None, :])
        ec = equations.expected_energy(s2, e, d, sysarr.p_dyn[None, :])
        ec_masked = jnp.where(feas, ec, BIG)
        best_m = jnp.argmin(ec_masked, axis=1).astype(jnp.int32)
        best_ec = jnp.min(ec_masked, axis=1)
    task_feas = best_ec < BIG
    marange = jnp.arange(M)[None, :]
    nominee = task_feas[:, None] & (best_m[:, None] == marange)
    key = jnp.broadcast_to(best_ec[:, None], nominee.shape)

    # Phase-II, high-priority pairs first.
    hi = nominee & suf_task[:, None]
    assign_hi = _phase2(hi, key, qfree_after)
    taken = assign_hi >= 0
    lo = nominee & ~suf_task[:, None]
    assign_lo = _phase2(lo, key, qfree_after & ~taken)
    assign = jnp.where(taken, assign_hi, assign_lo)

    drop = _stale(now, pending, deadline) | _hopeless(
        now, pending, task_type, deadline, sysarr
    )
    assigned_mask = jnp.zeros_like(pending).at[
        jnp.where(assign >= 0, assign, pending.shape[0])
    ].set(True, mode="drop")
    drop = drop & ~assigned_mask
    return MapAction(assign, drop, qdrop)


# --------------------------------------------------------------------------
# Baselines: MM / MSD / MMU (Sec. VI-B)
# --------------------------------------------------------------------------
def _baseline_select(now, pending, task_type, deadline, view, sysarr, suffered,
                     *, phase2_key: str) -> MapAction:
    """Two-phase baselines. Phase-I: per-task min expected completion time
    (no feasibility / energy awareness). Phase-II key distinguishes MM
    (min completion), MSD (soonest deadline), MMU (max urgency).
    """
    del suffered
    M, Q = view.queue.shape
    qfree = view.qlen < Q
    # Stale tasks (deadline already passed) are purged, never mapped — the
    # baselines have no feasibility check, so without this mask a stale task
    # could win a machine on the phase-2 key and burn the slot.
    alive = pending & ~_stale(now, pending, deadline)
    s, e = _pair_grid(now, task_type, deadline, view, sysarr)
    c = equations.completion_time(s, e, deadline[:, None])
    c_masked = jnp.where(alive[:, None] & qfree[None, :], c, BIG)
    best_m = jnp.argmin(c_masked, axis=1).astype(jnp.int32)
    best_c = jnp.min(c_masked, axis=1)
    has = best_c < BIG
    nominee = has[:, None] & (best_m[:, None] == jnp.arange(M)[None, :])

    if phase2_key == "completion":        # MM
        key = best_c[:, None]
    elif phase2_key == "deadline":        # MSD (tie-break on completion)
        key = deadline[:, None] + 1e-6 * best_c[:, None]
    elif phase2_key == "urgency":         # MMU: maximize urgency = minimize -u
        e_best = jnp.take_along_axis(e, best_m[:, None], axis=1)[:, 0]
        u = equations.urgency(deadline, e_best, now)
        key = -u[:, None]
    else:  # pragma: no cover
        raise ValueError(phase2_key)
    key = jnp.broadcast_to(key, nominee.shape)
    assign = _phase2(nominee, key, qfree)

    drop = _stale(now, pending, deadline)  # baselines only purge stale tasks
    assigned_mask = jnp.zeros_like(pending).at[
        jnp.where(assign >= 0, assign, pending.shape[0])
    ].set(True, mode="drop")
    drop = drop & ~assigned_mask
    qdrop = jnp.zeros((M, Q), bool)
    return MapAction(assign, drop, qdrop)


mm_select = functools.partial(_baseline_select, phase2_key="completion")
msd_select = functools.partial(_baseline_select, phase2_key="deadline")
mmu_select = functools.partial(_baseline_select, phase2_key="urgency")


# --------------------------------------------------------------------------
# Extra single-phase baselines from the heterogeneous-computing literature
# (MET / MCT / RANDOM) — widen the comparison pool beyond the paper's three.
# --------------------------------------------------------------------------
def met_select(now, pending, task_type, deadline, view, sysarr, suffered
               ) -> MapAction:
    """Minimum Execution Time: ignore queue state, pick each task's fastest
    machine; per machine serve the min-execution nominee."""
    del suffered
    M, Q = view.queue.shape
    qfree = view.qlen < Q
    alive = pending & ~_stale(now, pending, deadline)
    e = sysarr.eet[task_type]                                   # (N, M)
    e_masked = jnp.where(alive[:, None] & qfree[None, :], e, BIG)
    best_m = jnp.argmin(e_masked, axis=1).astype(jnp.int32)
    best_e = jnp.min(e_masked, axis=1)
    nominee = (best_e < BIG)[:, None] & (
        best_m[:, None] == jnp.arange(M)[None, :])
    assign = _phase2(nominee, jnp.broadcast_to(best_e[:, None],
                                               nominee.shape), qfree)
    drop = _stale(now, pending, deadline)
    assigned_mask = jnp.zeros_like(pending).at[
        jnp.where(assign >= 0, assign, pending.shape[0])
    ].set(True, mode="drop")
    return MapAction(assign, drop & ~assigned_mask,
                     jnp.zeros((M, Q), bool))


def mct_select(now, pending, task_type, deadline, view, sysarr, suffered
               ) -> MapAction:
    """Minimum Completion Time with FCFS phase-2 (earliest arrival proxy =
    lowest task index)."""
    del suffered
    M, Q = view.queue.shape
    qfree = view.qlen < Q
    alive = pending & ~_stale(now, pending, deadline)
    s, e = _pair_grid(now, task_type, deadline, view, sysarr)
    c = equations.completion_time(s, e, deadline[:, None])
    c_masked = jnp.where(alive[:, None] & qfree[None, :], c, BIG)
    best_m = jnp.argmin(c_masked, axis=1).astype(jnp.int32)
    has = jnp.min(c_masked, axis=1) < BIG
    nominee = has[:, None] & (best_m[:, None] == jnp.arange(M)[None, :])
    fcfs_key = jnp.broadcast_to(
        jnp.arange(pending.shape[0], dtype=jnp.float32)[:, None],
        nominee.shape)
    assign = _phase2(nominee, fcfs_key, qfree)
    drop = _stale(now, pending, deadline)
    assigned_mask = jnp.zeros_like(pending).at[
        jnp.where(assign >= 0, assign, pending.shape[0])
    ].set(True, mode="drop")
    return MapAction(assign, drop & ~assigned_mask,
                     jnp.zeros((M, Q), bool))


def random_select(now, pending, task_type, deadline, view, sysarr, suffered
                  ) -> MapAction:
    """Pseudo-random mapping (hash of task index x event time) — the
    sanity-check lower bound."""
    del suffered
    M, Q = view.queue.shape
    qfree = view.qlen < Q
    n = pending.shape[0]
    alive = pending & ~_stale(now, pending, deadline)
    h = (jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)
         + (now * 1e3).astype(jnp.uint32)) % jnp.uint32(M)
    nominee = alive[:, None] & (
        h[:, None].astype(jnp.int32) == jnp.arange(M)[None, :])
    key = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.float32)[:, None], nominee.shape)
    assign = _phase2(nominee, key, qfree)
    drop = _stale(now, pending, deadline)
    assigned_mask = jnp.zeros_like(pending).at[
        jnp.where(assign >= 0, assign, pending.shape[0])
    ].set(True, mode="drop")
    return MapAction(assign, drop & ~assigned_mask,
                     jnp.zeros((M, Q), bool))


HEURISTICS: dict[str, Callable] = {
    "ELARE": elare_select,
    "FELARE": felare_select,
    "MM": mm_select,
    "MSD": msd_select,
    "MMU": mmu_select,
    "MET": met_select,
    "MCT": mct_select,
    "RANDOM": random_select,
}


def get(name: str) -> Callable:
    try:
        return HEURISTICS[name.upper()]
    except KeyError:
        raise KeyError(
            f"unknown heuristic {name!r}; choose from {sorted(HEURISTICS)}"
        ) from None
