"""Bit-exactness battery: masked-vmap site loop vs the PR 5 static unroll.

The flat-compile refactor replaced the engine's statically unrolled
per-site map stage (one ``select_fn`` copy per site in the traced program)
with a single ``jax.vmap`` over site-masked machine views. These tests pin
the refactor to the frozen snapshot in ``tests/_legacy_siteloop.py``:

  * event-level — for every event of a driven simulation, the combined
    :class:`MapAction` and the full post-map :class:`SimState` agree leaf
    for leaf, bit for bit (``jnp.array_equal`` inside one jitted
    comparator per combo);
  * trace-level — full simulations (``make_simulator`` + the task_log
    observer) agree on every metrics leaf and every task_log event field,
    byte for byte, with the legacy formulation monkeypatched in;

for F in {1, 2, 4} under every built-in dispatcher x ELARE/FELARE, on
exhaustive grids plus hypothesis-drawn Poisson traces. Comparators and
simulator pairs are cached per combo so hypothesis examples re-run the
compiled programs instead of re-tracing.
"""
import functools

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

import _legacy_siteloop as legacy
from repro import scenarios
from repro.core import engine, observe, policy, workload
from repro.core.types import MapAction, SimState, site_membership

FLEETS = {1: "paper", 2: "paper_x2", 4: "paper_x4"}
DISPATCHERS = ("sticky", "round_robin", "least_queued", "min_eet",
               "fair_spill")
POLICIES = ("ELARE", "FELARE")
# With one site the dispatch stage is bypassed (every task -> site 0), so
# the dispatcher axis collapses; F>1 runs the full grid.
GRID = tuple((1, "sticky", h) for h in POLICIES) + tuple(
    (F, d, h) for F in (2, 4) for d in DISPATCHERS for h in POLICIES
)
LEAF_NAMES = tuple(f"action.{f}" for f in MapAction._fields) + tuple(
    f"state.{f}" for f in SimState._fields
)


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate, eet):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


# ------------------------------------------------------------ event level
N_EVENT_TASKS = 16


@functools.lru_cache(maxsize=None)
def _comparator(n_sites: int, heuristic: str, k: int = 10):
    """Jitted k-event driver comparing both map formulations per event.

    Each event runs the real pre-map stages once, evaluates the masked-vmap
    ``engine._map_action`` AND the frozen ``legacy.map_action_unrolled`` on
    the identical pre-map state, applies both, and records per-leaf
    equality; the simulation continues from the new-formulation state.
    Returns a (k, n_leaves) bool array.

    The dispatch stage is replaced by its dispatch-once contract with an
    *arbitrary* per-task site array (``assigned``) — data, not a new trace
    — so one compiled comparator per (F, policy) covers every site pattern
    any dispatcher could produce (and adversarial ones none would). The
    real dispatchers run in the full-trace parity grid below.
    """
    system = scenarios.get_fleet(FLEETS[n_sites]).build()
    sysarr = system.as_jax()
    pol = policy.get(heuristic)
    sites_np = np.asarray(system.sites, np.int32)
    members = (site_membership(sites_np, system.n_sites)
               if system.n_sites > 1 else None)
    S, M = system.eet.shape
    Q, ff = system.queue_size, float(system.fairness_factor)

    def compare(trace, assigned):
        stt = engine._init_state(trace, M, Q, S)
        oks = []
        for _ in range(k):
            t = engine._next_event_time(stt, trace)
            # freeze time once the event queue drains (the while_loop's
            # cond would have exited) so trailing events are no-ops for
            # both formulations instead of poisoning the state with inf.
            t = jnp.where(jnp.isfinite(t), t, stt.now)
            stt = stt._replace(now=jnp.maximum(t, stt.now))
            stt = engine._stage_finalize(stt, trace, sysarr)
            stt = engine._stage_admit(stt, trace)
            new = (stt.status == engine.PENDING) & (stt.site < 0)
            stt = stt._replace(site=jnp.where(new, assigned, stt.site))
            a_new = engine._map_action(stt, trace, sysarr, pol, ff,
                                       members, sites_np)
            a_old = legacy.map_action_unrolled(stt, trace, sysarr, pol, ff,
                                               members)
            st_new = engine._apply_action(stt, trace, a_new, S)
            st_old = engine._apply_action(stt, trace, a_old, S)
            oks.append(jnp.stack(
                [jnp.array_equal(x, y) for x, y in
                 zip(jax.tree.leaves(a_new), jax.tree.leaves(a_old))]
                + [jnp.array_equal(x, y) for x, y in
                   zip(jax.tree.leaves(st_new), jax.tree.leaves(st_old))]
            ))
            stt = engine._stage_start(st_new, trace, sysarr)
            stt = stt._replace(steps=stt.steps + 1)
        return jnp.stack(oks)

    return jax.jit(compare)


def _assert_events_equal(ok, label):
    ok = np.asarray(ok)
    if not ok.all():
        ev, leaf = np.argwhere(~ok)[0]
        pytest.fail(f"{label}: event {ev} diverges at {LEAF_NAMES[leaf]}")


def _site_patterns(n_sites, n, seed=None):
    """Representative site assignments: round-robin, blocky, random."""
    if n_sites == 1:
        return [np.zeros((n,), np.int32)]  # the engine's F=1 bypass
    rng = np.random.default_rng(0xFE1A if seed is None else seed)
    return [np.arange(n, dtype=np.int32) % n_sites,
            np.minimum(np.arange(n) // (n // n_sites), n_sites - 1)
            .astype(np.int32),
            rng.integers(0, n_sites, n).astype(np.int32)]


@pytest.mark.parametrize("heuristic", POLICIES)
@pytest.mark.parametrize("n_sites", [1, 2, 4])
def test_event_level_map_parity(n_sites, heuristic):
    """MapAction + post-map SimState bit-equal between formulations at
    every event, across round-robin / blocky / random site partitions."""
    cmp_fn = _comparator(n_sites, heuristic)
    eet = scenarios.get_fleet(FLEETS[n_sites]).build().eet
    for seed in (0, 3):
        tr = _trace(seed, N_EVENT_TASKS, 4.0, eet)
        for i, assigned in enumerate(_site_patterns(n_sites, N_EVENT_TASKS)):
            ok = cmp_fn(tr, jnp.asarray(assigned))
            _assert_events_equal(
                ok, f"F={n_sites}/{heuristic}/seed{seed}/pattern{i}")


@given(combo=st.sampled_from(tuple((F, h) for F in (1, 2, 4)
                                   for h in POLICIES)),
       seed=st.integers(0, 10_000), rate=st.floats(0.5, 10.0))
@settings(max_examples=25, deadline=None)
def test_event_level_map_parity_property(combo, seed, rate):
    """Hypothesis sweep: drawn Poisson traces x drawn site assignments
    through the cached compiled comparators."""
    n_sites, heuristic = combo
    cmp_fn = _comparator(n_sites, heuristic)
    eet = scenarios.get_fleet(FLEETS[n_sites]).build().eet
    tr = _trace(seed, N_EVENT_TASKS, rate, eet)
    assigned = _site_patterns(n_sites, N_EVENT_TASKS, seed=seed)[-1]
    ok = cmp_fn(tr, jnp.asarray(assigned))
    _assert_events_equal(
        ok, f"F={n_sites}/{heuristic}/seed{seed}/rate{rate}")


# ------------------------------------------------------------ trace level
def _legacy_stage_map(st_, trace, sysarr, select_fn, fairness_factor,
                      n_types, site_members=None, site_of_machine=None,
                      health=False, backup_k=0):
    """Signature shim: the live engine body -> the frozen PR 5 unroll.

    ``health``/``backup_k`` are the PR 7 faults-subsystem knobs; this
    battery runs without a dynamics attached, where both are inert
    (False/0), so the frozen unroll simply ignores them.
    """
    assert not health and backup_k == 0
    return legacy.stage_map_unrolled(st_, trace, sysarr, select_fn,
                                     fairness_factor, n_types, site_members)


@functools.lru_cache(maxsize=None)
def _sim_pair(n_sites: int, dispatcher: str, heuristic: str):
    """(new, legacy) jitted full simulators with the task_log observer.

    Built via ``engine.make_simulator`` + a fresh ``jax.jit`` — NOT
    ``engine.simulate`` — because ``_simulate_jit``'s cache key doesn't
    include the (monkeypatched) ``_stage_map``. The legacy simulator runs
    with ``engine._stage_map`` swapped for the frozen unroll on every
    call, so its (lazy, first-call) trace picks up the old formulation.
    """
    system = scenarios.get_fleet(FLEETS[n_sites]).build()
    kw = dict(queue_size=system.queue_size,
              fairness_factor=float(system.fairness_factor),
              observers=observe.resolve(("task_log",)),
              dispatcher=dispatcher, site_of_machine=system.sites)
    pol = policy.get(heuristic)
    sysarr = system.as_jax()
    new_sim = jax.jit(engine.make_simulator(pol, sysarr, **kw))
    legacy_jit = jax.jit(engine.make_simulator(pol, sysarr, **kw))

    def legacy_sim(trace):
        orig = engine._stage_map
        engine._stage_map = _legacy_stage_map
        try:
            return legacy_jit(trace)
        finally:
            engine._stage_map = orig

    return new_sim, legacy_sim


@pytest.mark.parametrize("n_sites,dispatcher,heuristic", GRID)
def test_full_trace_task_log_parity(n_sites, dispatcher, heuristic):
    """Whole simulations agree byte for byte: every metrics leaf and every
    task_log field (map/start/end times, machine, site, status)."""
    new_sim, legacy_sim = _sim_pair(n_sites, dispatcher, heuristic)
    eet = scenarios.get_fleet(FLEETS[n_sites]).build().eet
    tr = _trace(1, 40, 4.0, eet)
    (m_new, aux_new), (m_old, aux_old) = new_sim(tr), legacy_sim(tr)
    for f in m_new._fields:
        a, b = np.asarray(getattr(m_new, f)), np.asarray(getattr(m_old, f))
        assert a.tobytes() == b.tobytes(), \
            f"F={n_sites}/{dispatcher}/{heuristic}: metrics.{f}"
    for f, a in aux_new["task_log"].items():
        a, b = np.asarray(a), np.asarray(aux_old["task_log"][f])
        assert a.tobytes() == b.tobytes(), \
            f"F={n_sites}/{dispatcher}/{heuristic}: task_log.{f}"


@given(seed=st.integers(0, 10_000), rate=st.floats(0.5, 10.0))
@settings(max_examples=15, deadline=None)
def test_full_trace_task_log_parity_property(seed, rate):
    """Hypothesis workloads through one cached simulator pair per F."""
    for n_sites, dispatcher, heuristic in (
            (1, "sticky", "FELARE"), (2, "fair_spill", "ELARE"),
            (4, "round_robin", "FELARE")):
        new_sim, legacy_sim = _sim_pair(n_sites, dispatcher, heuristic)
        eet = scenarios.get_fleet(FLEETS[n_sites]).build().eet
        tr = _trace(seed, 40, rate, eet)
        (m_new, aux_new), (m_old, aux_old) = new_sim(tr), legacy_sim(tr)
        for f in m_new._fields:
            assert (np.asarray(getattr(m_new, f)).tobytes()
                    == np.asarray(getattr(m_old, f)).tobytes()), \
                f"F={n_sites} seed{seed}: metrics.{f}"
        for f, a in aux_new["task_log"].items():
            assert (np.asarray(a).tobytes()
                    == np.asarray(aux_old["task_log"][f]).tobytes()), \
                f"F={n_sites} seed{seed}: task_log.{f}"
