"""End-to-end training THROUGH the compressed cross-pod all-reduce.

A small MLP LM trains data-parallel over a 4-way 'pod' axis inside
shard_map, gradients reduced with the int8 error-feedback collective; the
loss trajectory must track the exact-psum run (subprocess: 4 devices).
"""
import os
import subprocess
import sys
import textwrap

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from functools import partial
"""


def _run(body):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_compressed_dp_training_tracks_exact():
    out = _run("""
    from repro.distributed import compression as comp
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("pod",))
    D, V = 32, 64
    key = jax.random.PRNGKey(0)
    W1 = jax.random.normal(key, (D, 64)) * 0.1
    W2 = jax.random.normal(jax.random.fold_in(key, 1), (64, V)) * 0.1
    emb = jax.random.normal(jax.random.fold_in(key, 2), (V, D)) * 0.1
    params0 = {"emb": emb, "W1": W1, "W2": W2}

    def loss_fn(p, toks):
        x = p["emb"][toks[:, :-1]]
        h = jnp.tanh(x @ p["W1"])
        logits = h @ p["W2"]
        y = toks[:, 1:]
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[..., None], -1)[..., 0]
        return (lse - gold).mean()

    def make_step(compressed):
        @partial(jax.shard_map, mesh=mesh,
                 in_specs=(P(), P(), P("pod")), out_specs=(P(), P(), P()))
        def step(p, res, toks):
            l, g = jax.value_and_grad(loss_fn)(p, toks)
            if compressed:
                g, res = comp.crosspod_mean_compressed(g, res, "pod")
            else:
                g = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"), g)
            p = jax.tree.map(lambda a, b: a - 5.0 * b, p, g)
            return p, res, jax.lax.pmean(l, "pod")
        return jax.jit(step)

    rng = np.random.default_rng(0)
    fixed = jnp.asarray(rng.integers(0, V, (16, 12)), jnp.int32)
    data = [fixed] * 40  # memorize one batch: loss must drop fast

    for compressed in (False, True):
        p = jax.tree.map(jnp.copy, params0)
        res = jax.tree.map(lambda a: jnp.zeros_like(a), params0)
        step = make_step(compressed)
        losses = []
        for b in data:
            p, res, loss = step(p, res, b)
            losses.append(float(loss))
        if compressed:
            comp_losses = losses
        else:
            exact_losses = losses
    print("exact last", exact_losses[-1], "compressed last", comp_losses[-1])
    assert comp_losses[-1] < comp_losses[0] - 0.2       # it learns
    assert abs(comp_losses[-1] - exact_losses[-1]) < 0.1  # tracks exact
    print("OK")
    """)
    assert "OK" in out
