"""Distributed tests in a subprocess with 8 placeholder devices.

These run the REAL multi-device code paths (sharded train_step, elastic
checkpoint restore across mesh shapes, compressed cross-pod all-reduce in
shard_map) on a (2, 2, 2) (pod, data, model) mini production mesh. They are
in a subprocess because the 8-device XLA flag must be set before jax init,
and the main pytest process must keep seeing 1 device.
"""
import os
import subprocess
import sys
import textwrap

_SCRIPT_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
"""


def _run(body: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT_PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = _run("""
    from repro.configs import registry
    from repro.models import transformer as tf
    from repro.optim.adamw import AdamW
    from repro.train.steps import make_train_step
    from repro.datapipe.synthetic import SyntheticLM
    from repro.launch.mesh import make_mesh

    assert len(jax.devices()) == 8
    cfg = registry.get_smoke_config("internlm2-1.8b").scaled(
        dtype="float32", param_dtype="float32")
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    opt = AdamW(lr=1e-3)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    ost = opt.init(params)
    ds = SyntheticLM(cfg, batch=8, seq=32, accum=2)
    b = ds.batch_at(0)

    # single device reference
    step1 = make_train_step(cfg, opt, donate=False)
    p1, o1, m1 = step1(params, ost, b)

    # sharded on the mini production mesh
    step8 = make_train_step(cfg, opt, mesh, donate=False)
    with mesh:
        jitted = step8.jit_for(jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), b))
        p8, o8, m8 = jitted(params, ost, b)
    print("loss1", float(m1["loss"]), "loss8", float(m8["loss"]))
    assert abs(float(m1["loss"]) - float(m8["loss"])) < 1e-3
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p8)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32),
                                   atol=2e-3, rtol=2e-2)
    print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_restore_across_meshes():
    out = _run("""
    from repro.checkpoint import ckpt
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_mesh
    import tempfile

    mesh_a = make_mesh((4, 2), ("data", "model"))
    mesh_b = make_mesh((2, 4), ("data", "model"))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    sh_a = NamedSharding(mesh_a, P("data", "model"))
    wa = jax.device_put(w, sh_a)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"w": wa})
        target = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
        sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
        restored, _ = ckpt.restore(d, target, shardings=sh_b)
        assert restored["w"].sharding == sh_b["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
    print("OK")
    """)
    assert "OK" in out


def test_compressed_crosspod_allreduce():
    out = _run("""
    from repro.distributed import compression as comp
    from repro.launch.mesh import make_mesh
    from functools import partial

    mesh = make_mesh((4, 2), ("pod", "data"))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 256)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (4, 32))}
    res = jax.tree.map(lambda g: jnp.zeros_like(g), grads)

    @partial(jax.shard_map, mesh=mesh, in_specs=(P("pod"), P("pod")),
             out_specs=(P("pod"), P("pod")))
    def reduce_fn(g, r):
        return comp.crosspod_mean_compressed(g, r, "pod")

    out_g, out_r = reduce_fn(grads, res)
    # exact mean for reference
    want = jax.tree.map(lambda g: jnp.broadcast_to(
        g.reshape(4, -1).mean(0, keepdims=True), g.shape).reshape(g.shape),
        grads)
    for k in grads:
        got = np.asarray(out_g[k])
        ref = np.asarray(want[k])
        # int8 EF compression: small quantization error this round
        err = np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9)
        print(k, "rel err", err)
        assert err < 0.12
        # residual carries the quantization error (error feedback)
        assert np.abs(np.asarray(out_r[k])).max() > 0
    print("OK")
    """)
    assert "OK" in out


def test_sharded_decode_step_runs():
    out = _run("""
    from repro.configs import registry
    from repro.models import transformer as tf
    from repro.train.steps import make_serve_steps
    from repro.launch.mesh import make_mesh

    cfg = registry.get_smoke_config("internlm2-1.8b")
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = tf.init(jax.random.PRNGKey(0), cfg)
    cache = tf.init_cache(cfg, batch=8, max_seq=64)
    toks = jnp.ones((8, 1), jnp.int32)
    _, decode_jit_for = make_serve_steps(cfg, mesh)
    with mesh:
        jitted = decode_jit_for(
            jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         cache),
            jax.ShapeDtypeStruct(toks.shape, toks.dtype))
        logits, cache2 = jitted(params, cache, toks)
    assert logits.shape == (8, 1, cfg.vocab_size)
    assert int(cache2["len"][0]) == 1
    print("OK")
    """)
    assert "OK" in out


def test_sharded_sweep_bit_exact_vs_unsharded():
    """run_sweep(shard=True) over 8 placeholder devices == the unsharded
    sweep, byte for byte, on every metrics leaf and observer aux leaf.

    The grid (2 rates x 3 reps = 6 traces) deliberately doesn't divide the
    8-device mesh, so the pad-to-multiple + slice-off path is exercised.
    Auto-skips if the platform ignores the device-count flag.
    """
    out = _run("""
    if len(jax.devices()) < 2:
        print("SKIPPED: single device")
        raise SystemExit(0)
    from repro import experiments

    spec = experiments.SweepSpec(
        system="paper_x2", rates=(3.0, 5.0), reps=3, n_tasks=60,
        heuristics=("ELARE", "FELARE"), seed=2, dispatcher="round_robin",
        observers=("task_log",))
    ref = experiments.run_sweep(spec)
    sh = experiments.run_sweep(spec, shard=True)
    leaves_r = jax.tree.leaves((ref.metrics, ref.aux))
    leaves_s = jax.tree.leaves((sh.metrics, sh.aux))
    assert leaves_r and len(leaves_r) == len(leaves_s)
    for a, b in zip(leaves_r, leaves_s):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.tobytes() == b.tobytes()
    print("compared", len(leaves_r), "leaves over", len(jax.devices()),
          "devices")
    print("OK")
    """)
    if "SKIPPED" in out:
        import pytest

        pytest.skip("host platform exposes a single device")
    assert "OK" in out


def test_shard_flag_single_device_fallback_bit_exact():
    """In the main (1-device) process, shard=True silently falls back to
    the plain path and reproduces the unsharded sweep exactly."""
    import jax
    import numpy as np

    from repro import experiments
    from repro.distributed import sharding

    if len(jax.devices()) == 1:
        assert sharding.sweep_mesh() is None
    assert sharding.sweep_mesh(max_devices=1) is None
    spec = experiments.SweepSpec(
        system="paper_x2", rates=(4.0,), reps=2, n_tasks=50,
        heuristics=("ELARE",), seed=3, dispatcher="least_queued")
    ref = experiments.run_sweep(spec)
    fb = experiments.run_sweep(spec, shard=True)
    for a, b in zip(jax.tree.leaves(ref.metrics),
                    jax.tree.leaves(fb.metrics)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_pad_batch_pads_and_preserves():
    """pad_batch repeats row 0 up to the multiple and leaves aligned
    batches untouched."""
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed import sharding

    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(3, 2),
            "b": jnp.arange(3, dtype=jnp.int32)}
    padded = sharding.pad_batch(tree, 4)
    assert padded["a"].shape == (4, 2) and padded["b"].shape == (4,)
    np.testing.assert_array_equal(np.asarray(padded["a"][:3]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(padded["a"][3]),
                                  np.asarray(tree["a"][0]))
    same = sharding.pad_batch(tree, 3)
    assert same["a"].shape == (3, 2)


def test_gradient_compression_preserves_convergence():
    """Error feedback: compressed optimization tracks uncompressed on a
    quadratic (single process math check, no mesh needed)."""
    import jax.numpy as jnp

    from repro.distributed import compression as comp

    w = jnp.zeros((64,))
    w_c = jnp.zeros((64,))
    res = jnp.zeros((64,))
    target = jnp.linspace(-1, 1, 64)
    lr = 0.3
    for _ in range(60):
        g = w - target
        w = w - lr * g
        g_c = w_c - target
        q, s, res = comp.compress_tree(g_c, res)
        w_c = w_c - lr * comp.dequantize_int8(q, s)
    assert float(jnp.abs(w_c - target).max()) < 1e-2
    assert float(jnp.abs(w - w_c).max()) < 1e-2
