"""Tests for the composable workload-scenario API (repro.scenarios)."""
import dataclasses

import hypothesis.strategies as st
import jax
import numpy as np
import pytest
from hypothesis import given, settings

from repro import scenarios
from repro.core import api

SPEC = api.paper_system()

ALL_ARRIVALS = [
    scenarios.PoissonArrivals(),
    scenarios.MMPPArrivals(),
    scenarios.DiurnalArrivals(),
    scenarios.FlashCrowdArrivals(),
]


def _gaps_cv2(arrivals: np.ndarray) -> float:
    g = np.diff(arrivals)
    return float(g.var() / g.mean() ** 2)


# ------------------------------------------------------ arrival properties
@given(seed=st.integers(0, 1000), rate=st.floats(0.5, 12.0))
@settings(max_examples=12, deadline=None)
def test_arrivals_sorted_nonnegative_finite(seed, rate):
    """Every arrival process emits sorted, non-negative, finite times."""
    key = jax.random.PRNGKey(seed)
    for proc in ALL_ARRIVALS:
        a = np.asarray(proc.sample(key, 512, rate))
        assert a.shape == (512,), proc.kind
        assert np.all(np.isfinite(a)), proc.kind
        assert np.all(a >= 0), proc.kind
        assert np.all(np.diff(a) >= 0), proc.kind


@given(seed=st.integers(0, 1000), rate=st.floats(1.0, 10.0))
@settings(max_examples=10, deadline=None)
def test_empirical_rate_matches_nominal(seed, rate):
    """Rate-normalized processes hit the nominal rate within CI bounds.

    For n arrivals at rate λ the horizon t_n concentrates around n/λ with
    relative sd ~ sqrt(CV²/n); 8 sigma of margin (plus MMPP's phase
    correlation) keeps this deterministic-in-practice across seeds.
    """
    n = 4000
    key = jax.random.PRNGKey(seed)
    for proc, cv2_bound in [(scenarios.PoissonArrivals(), 1.0),
                            (scenarios.MMPPArrivals(), 12.0),
                            (scenarios.DiurnalArrivals(), 2.0)]:
        t_n = float(np.asarray(proc.sample(key, n, rate))[-1])
        emp = n / t_n
        tol = 8.0 * rate * np.sqrt(cv2_bound / n)
        assert abs(emp - rate) < tol, (proc.kind, emp, rate)


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_mmpp_burstier_than_poisson(seed):
    """MMPP inter-arrival CV² exceeds the Poisson process's (same key)."""
    key = jax.random.PRNGKey(seed)
    cv2_poisson = _gaps_cv2(
        np.asarray(scenarios.PoissonArrivals().sample(key, 4000, 3.0)))
    cv2_mmpp = _gaps_cv2(
        np.asarray(scenarios.MMPPArrivals().sample(key, 4000, 3.0)))
    assert cv2_mmpp > cv2_poisson + 0.1
    assert cv2_mmpp > 1.15  # analytically ~1.6 for the default parameters
    assert 0.6 < cv2_poisson < 1.5  # exponential gaps: CV² = 1


@given(seed=st.integers(0, 1000), rate=st.floats(1.0, 8.0))
@settings(max_examples=10, deadline=None)
def test_crn_invariance_across_rates(seed, rate):
    """Same replicate key ⇒ identical type and runtime draws across rates,
    for every registered scenario (the rate only enters arrivals)."""
    key = jax.random.PRNGKey(seed)
    for name in scenarios.list_scenarios():
        scn = scenarios.get(name)
        eet = (scn.fleet.build() if scn.fleet is not None else SPEC).eet
        st_ = scn.stack(key, (rate, 4.0 * rate), 2, 64, eet)
        np.testing.assert_array_equal(
            np.asarray(st_.task_type[0]), np.asarray(st_.task_type[1]),
            err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(st_.exec_actual[0]), np.asarray(st_.exec_actual[1]),
            err_msg=name)


def test_poisson_crn_arrivals_scale_inversely():
    """Poisson arrivals under CRN scale exactly as 1/rate."""
    scn = scenarios.get("poisson")
    st_ = scn.stack(jax.random.PRNGKey(3), (1.0, 4.0), 4, 60, SPEC.eet)
    np.testing.assert_allclose(np.asarray(st_.arrival[0]),
                               4.0 * np.asarray(st_.arrival[1]), rtol=1e-5)


def test_flash_crowd_concentrates_mass_in_window():
    """The spike window holds far more arrivals than a Poisson window."""
    proc = scenarios.FlashCrowdArrivals(spike_start=0.4, spike_frac=0.15,
                                        spike_mult=6.0)
    n, rate = 4000, 3.0
    a = np.asarray(proc.sample(jax.random.PRNGKey(0), n, rate))
    horizon = n / rate
    t0, t1 = 0.4 * horizon, (0.4 + 0.15) * horizon
    in_window = int(np.sum((a >= t0) & (a <= t1)))
    poisson_expect = rate * (t1 - t0)
    assert in_window > 3.0 * poisson_expect


def test_diurnal_rate_oscillates():
    """Arrival density alternates between above- and below-nominal across
    the configured cycles."""
    proc = scenarios.DiurnalArrivals(amplitude=0.8, cycles=4.0)
    n, rate = 8000, 3.0
    a = np.asarray(proc.sample(jax.random.PRNGKey(1), n, rate))
    horizon = n / rate
    edges = np.linspace(0.0, horizon, 33)
    counts, _ = np.histogram(a, bins=edges)
    per_bin = n / 32
    assert counts.max() > 1.3 * per_bin
    assert counts.min() < 0.7 * per_bin


# ----------------------------------------------------------------- mixes
def test_weighted_mix_respects_probs():
    mix = scenarios.WeightedMix((0.7, 0.1, 0.1, 0.1))
    t = np.asarray(mix.sample(jax.random.PRNGKey(0), 4000, 4))
    freq = np.bincount(t, minlength=4) / 4000
    assert abs(freq[0] - 0.7) < 0.05
    assert np.all(t >= 0) and np.all(t < 4)


def test_weighted_mix_validates_length():
    mix = scenarios.WeightedMix((0.5, 0.5))
    with pytest.raises(ValueError):
        mix.sample(jax.random.PRNGKey(0), 10, 4)
    with pytest.raises(ValueError):
        scenarios.WeightedMix(())
    with pytest.raises(ValueError):
        scenarios.WeightedMix((-1.0, 2.0))


def test_drift_mix_drifts():
    """Early tasks follow the start mix, late tasks the end mix."""
    mix = scenarios.DriftMix(start=(0.9, 0.1, 0.0, 0.0),
                             end=(0.0, 0.0, 0.1, 0.9))
    t = np.asarray(mix.sample(jax.random.PRNGKey(0), 4000, 4))
    head, tail = t[:1000], t[-1000:]
    assert np.mean(head == 0) > 0.5
    assert np.mean(tail == 3) > 0.5
    with pytest.raises(ValueError):
        scenarios.DriftMix(start=(0.5, 0.5), end=(1.0,))


# --------------------------------------------------------------- deadlines
def test_scaled_deadlines_interpolate_paper():
    scn = scenarios.get("poisson")
    tr = scn.sample_trace(jax.random.PRNGKey(0), 64, 3.0, SPEC.eet)
    paper = scenarios.PaperDeadlines().deadlines(
        tr.arrival, tr.task_type, SPEC.eet)
    tight = scenarios.ScaledDeadlines(0.75).deadlines(
        tr.arrival, tr.task_type, SPEC.eet)
    loose = scenarios.ScaledDeadlines(1.5).deadlines(
        tr.arrival, tr.task_type, SPEC.eet)
    unit = scenarios.ScaledDeadlines(1.0).deadlines(
        tr.arrival, tr.task_type, SPEC.eet)
    assert np.all(np.asarray(tight) < np.asarray(paper))
    assert np.all(np.asarray(loose) > np.asarray(paper))
    np.testing.assert_allclose(np.asarray(unit), np.asarray(paper),
                               rtol=1e-6)
    assert np.all(np.asarray(tight) > np.asarray(tr.arrival))


# ---------------------------------------------------------------- runtimes
def test_gamma_runtimes_default_matches_legacy_sampler():
    """cv=None delegates to eet.sample_actual_exec byte-for-byte."""
    from repro.core import eet as eet_mod

    key = jax.random.PRNGKey(5)
    ttype = np.zeros(32, np.int32)
    ours = scenarios.GammaRuntimes().sample(key, SPEC.eet, ttype, 0.1)
    ref = eet_mod.sample_actual_exec(key, SPEC.eet, ttype, 0.1)
    assert np.asarray(ours).tobytes() == np.asarray(ref).tobytes()


def test_gamma_runtimes_per_type_cv():
    """Per-type CVs produce per-type dispersion around unchanged means."""
    key = jax.random.PRNGKey(2)
    n = 6000
    ttype = np.asarray([0, 1] * (n // 2), np.int32)
    model = scenarios.GammaRuntimes(cv_by_type=(0.05, 0.5, 0.1, 0.1))
    draws = np.asarray(model.sample(key, SPEC.eet, ttype, 0.1))
    for s, cv in [(0, 0.05), (1, 0.5)]:
        rel = draws[ttype == s, 0] / float(SPEC.eet[s, 0])
        assert abs(rel.mean() - 1.0) < 0.05
        assert abs(rel.std() - cv) < 0.25 * cv + 0.01
    with pytest.raises(ValueError):
        scenarios.GammaRuntimes(cv_by_type=(0.1, 0.1)).sample(
            key, SPEC.eet, ttype, 0.1)


def test_lognormal_runtimes_mean_preserving_heavy_tail():
    key = jax.random.PRNGKey(4)
    n = 8000
    ttype = np.zeros(n, np.int32)
    ln = np.asarray(scenarios.LognormalRuntimes(sigma=0.6).sample(
        key, SPEC.eet, ttype, 0.1))
    gm = np.asarray(scenarios.GammaRuntimes().sample(
        key, SPEC.eet, ttype, 0.1))
    rel_ln = ln[:, 0] / float(SPEC.eet[0, 0])
    rel_gm = gm[:, 0] / float(SPEC.eet[0, 0])
    assert abs(rel_ln.mean() - 1.0) < 0.05
    # heavier right tail than the paper's Gamma model
    assert np.quantile(rel_ln, 0.999) > np.quantile(rel_gm, 0.999) * 1.5


# ------------------------------------------------------------------ fleets
def test_builtin_fleets_match_api_systems():
    paper = scenarios.get_fleet("paper").build()
    np.testing.assert_array_equal(paper.eet, api.paper_system().eet)
    aws = scenarios.get_fleet("aws").build()
    np.testing.assert_array_equal(aws.eet, api.aws_system().eet)


def test_parameterized_fleets_shape_determinism_ranges():
    f = scenarios.CvbFleet(n_task_types=5, n_machines=7, seed=3)
    s1, s2 = f.build(), f.build()
    assert s1.eet.shape == (5, 7)
    np.testing.assert_array_equal(s1.eet, s2.eet)  # deterministic in seed
    assert not np.array_equal(
        s1.eet, scenarios.CvbFleet(n_task_types=5, n_machines=7,
                                   seed=4).build().eet)

    r = scenarios.RangeFleet(n_task_types=3, n_machines=4, seed=0,
                             eet_range=(0.5, 5.0)).build()
    assert r.eet.shape == (3, 4)
    assert np.all(r.eet >= 0.5) and np.all(r.eet <= 5.0)
    assert np.all(r.p_dyn >= 1.0) and np.all(r.p_dyn <= 3.0)

    with pytest.raises(ValueError):
        scenarios.RangeFleet(eet_range=(5.0, 0.5))


def test_fleet_registry_roundtrip():
    fleet = scenarios.RangeFleet(n_task_types=2, n_machines=2, seed=9)
    scenarios.register_fleet("tiny-test-fleet", fleet)
    try:
        assert scenarios.is_registered_fleet("TINY-TEST-FLEET")
        assert scenarios.get_fleet("tiny-test-fleet") is fleet
        with pytest.raises(ValueError):
            scenarios.register_fleet("tiny-test-fleet", fleet)
    finally:
        scenarios.unregister_fleet("tiny-test-fleet")
    assert not scenarios.is_registered_fleet("tiny-test-fleet")
    with pytest.raises(KeyError):
        scenarios.get_fleet("tiny-test-fleet")


# ---------------------------------------------------------------- registry
def test_scenario_registry_roundtrip():
    scn = scenarios.Scenario(scenarios.PoissonArrivals(),
                             scenarios.UniformMix(),
                             scenarios.ScaledDeadlines(0.5),
                             scenarios.GammaRuntimes())
    scenarios.register("test-tight", scn)
    try:
        assert scenarios.is_registered("TEST-TIGHT")  # case-insensitive
        assert scenarios.get("test-tight") is scn
        with pytest.raises(ValueError):
            scenarios.register("test-tight", scn)  # no silent shadowing
        scenarios.register("test-tight", scn, overwrite=True)
    finally:
        scenarios.unregister("test-tight")
    assert not scenarios.is_registered("test-tight")
    with pytest.raises(KeyError):
        scenarios.get("test-tight")
    with pytest.raises(TypeError):
        scenarios.register("not-a-scenario", object())  # type: ignore


def test_builtin_registry_contents():
    """The registry ships the stress axes the issue names: >= 4 arrival
    processes and >= 2 fleet builders."""
    names = scenarios.list_scenarios()
    kinds = {scenarios.get(n).arrivals.kind for n in names}
    assert {"poisson", "mmpp", "diurnal", "flash-crowd"} <= kinds
    assert {"paper", "aws"} <= set(scenarios.list_fleets())
    assert "poisson" in names


# ------------------------------------------------------------ serialization
def test_scenario_json_roundtrip_all_builtins():
    for name in scenarios.list_scenarios():
        scn = scenarios.get(name)
        back = scenarios.Scenario.from_json_dict(scn.to_json_dict())
        assert back == scn, name


def test_scenario_json_roundtrip_custom():
    scn = scenarios.Scenario(
        scenarios.MMPPArrivals(rate_ratio=4.0, p_stay=0.9),
        scenarios.DriftMix(start=(0.7, 0.3), end=(0.2, 0.8)),
        scenarios.ScaledDeadlines(0.8),
        scenarios.GammaRuntimes(cv_by_type=(0.05, 0.4)),
        fleet=scenarios.RangeFleet(n_task_types=2, n_machines=3, seed=1),
    )
    back = scenarios.Scenario.from_json_dict(scn.to_json_dict())
    assert back == scn
    assert back.fleet.build().eet.shape == (2, 3)


def test_component_from_json_unknown_kind():
    with pytest.raises(ValueError):
        scenarios.component_from_json("arrivals", {"kind": "nope"})


def test_scenario_hashable_and_replace():
    scn = scenarios.get("bursty")
    assert hash(scn) == hash(scenarios.get("bursty"))
    tweaked = scenarios.replace(
        scn, arrivals=dataclasses.replace(scn.arrivals, rate_ratio=16.0))
    assert tweaked != scn and tweaked.arrivals.rate_ratio == 16.0


# ------------------------------------------------------- parameter checking
def test_component_parameter_validation():
    with pytest.raises(ValueError):
        scenarios.MMPPArrivals(rate_ratio=0.5)
    with pytest.raises(ValueError):
        scenarios.MMPPArrivals(burst_frac=1.5)
    with pytest.raises(ValueError):
        # jointly infeasible: quiet-phase exit probability 4.5 > 1 would
        # silently break the nominal-rate normalization
        scenarios.MMPPArrivals(p_stay=0.5, burst_frac=0.9)
    with pytest.raises(ValueError):
        scenarios.DiurnalArrivals(amplitude=1.2)
    with pytest.raises(ValueError):
        scenarios.FlashCrowdArrivals(spike_mult=0.5)
    with pytest.raises(ValueError):
        scenarios.ScaledDeadlines(0.0)
    with pytest.raises(ValueError):
        scenarios.LognormalRuntimes(sigma=-1.0)
