"""Unit + property tests for the paper's closed-form math (Eqs. 1-4)."""
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import equations
from repro.core.eet import TABLE_I


class TestCompletionTime:
    def test_feasible_row(self):
        # s + e <= d -> c = s + e
        assert float(equations.completion_time(1.0, 2.0, 10.0)) == 3.0

    def test_killed_mid_run(self):
        # s < d < s + e -> c = d (killed at the deadline)
        assert float(equations.completion_time(1.0, 20.0, 10.0)) == 10.0

    def test_never_started(self):
        # s >= d -> c = s (dropped before execution)
        assert float(equations.completion_time(11.0, 2.0, 10.0)) == 11.0

    @given(
        s=st.floats(0, 100, allow_nan=False),
        e=st.floats(0.01, 100, allow_nan=False),
        d=st.floats(0, 200, allow_nan=False),
    )
    @settings(max_examples=200, deadline=None)
    def test_cases_partition(self, s, e, d):
        c = float(equations.completion_time(s, e, d))
        s32, e32, d32 = (np.float32(x) for x in (s, e, d))
        if s32 + e32 <= d32:
            assert c == pytest.approx(float(s32 + e32), rel=1e-6)
        elif s32 < d32:
            assert c == pytest.approx(float(d32), rel=1e-6)
        else:
            assert c == pytest.approx(float(s32), rel=1e-6)


class TestEnergy:
    def test_feasible_energy(self):
        assert float(equations.expected_energy(0.0, 2.0, 10.0, 3.0)) == 6.0

    def test_wasted_energy_killed(self):
        # runs from s to d then killed: p * (d - s)
        assert float(equations.expected_energy(4.0, 20.0, 10.0, 2.0)) == 12.0

    def test_zero_energy_never_started(self):
        assert float(equations.expected_energy(12.0, 5.0, 10.0, 2.0)) == 0.0

    @given(
        s=st.floats(0, 50), e=st.floats(0.01, 50), d=st.floats(0, 100),
        p=st.floats(0.1, 10),
    )
    @settings(max_examples=200, deadline=None)
    def test_energy_nonnegative_and_bounded(self, s, e, d, p):
        ec = float(equations.expected_energy(s, e, d, p))
        assert ec >= 0.0
        # never exceeds the energy of a full successful run
        assert ec <= p * e + 1e-4


class TestFairnessLimit:
    def test_paper_example(self):
        # Sec. V worked example: rates 20/60/15/45 %, f=1 -> eps = 16.6
        cr = jnp.array([0.20, 0.60, 0.15, 0.45])
        eps = float(equations.fairness_limit(cr, 1.0))
        assert eps == pytest.approx(0.166, abs=5e-3)

    def test_large_f_disables(self):
        cr = jnp.array([0.2, 0.9, 0.4, 0.7])
        assert float(equations.fairness_limit(cr, 100.0)) == 0.0

    @given(st.lists(st.floats(0, 1), min_size=2, max_size=16),
           st.floats(0, 5))
    @settings(max_examples=200, deadline=None)
    def test_limit_below_mean(self, rates, f):
        eps = float(equations.fairness_limit(jnp.array(rates), f))
        assert 0.0 <= eps <= np.mean(rates) + 1e-6


class TestDeadlines:
    def test_eq4_structure(self):
        # delta = arr + e_bar_i + e_bar, from Table I
        e_bar_i = TABLE_I.mean(axis=1)
        e_bar = e_bar_i.mean()
        arr = jnp.array([0.0, 5.0])
        tt = jnp.array([2, 0])
        d = np.asarray(equations.deadlines(arr, tt, TABLE_I))
        assert d[0] == pytest.approx(e_bar_i[2] + e_bar, rel=1e-5)
        assert d[1] == pytest.approx(5.0 + e_bar_i[0] + e_bar, rel=1e-5)

    def test_deadline_after_arrival(self):
        arr = jnp.linspace(0, 10, 7)
        tt = jnp.zeros(7, jnp.int32)
        d = equations.deadlines(arr, tt, TABLE_I)
        assert bool(jnp.all(d > arr))
