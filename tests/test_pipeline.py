"""Pipeline-parallelism correctness: gpipe == sequential stage application.

Runs in a subprocess with 4 placeholder devices (pipe axis = 4).
"""
import os
import subprocess
import sys
import textwrap

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
"""


def _run(body):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _PRELUDE + textwrap.dedent(body)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_gpipe_matches_sequential():
    out = _run("""
    from repro.distributed.pipeline import gpipe, stack_stages
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("pipe",))
    L, D, M, B = 8, 16, 6, 2
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, D, D)) * (D ** -0.5)
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

    def stage_fn(stage_W, x):   # stage_W: (L/P, D, D)
        def body(h, W):
            return jnp.tanh(h @ W), None
        h, _ = jax.lax.scan(body, x, stage_W)
        return h

    # sequential reference: all L layers in order
    ref = []
    for m in range(M):
        h = xs[m]
        for l in range(L):
            h = jnp.tanh(h @ Ws[l])
        ref.append(h)
    ref = jnp.stack(ref)

    run = gpipe(stage_fn, mesh, "pipe")
    got = run(stack_stages({"w": Ws}, 4)["w"], xs)
    err = float(jnp.abs(got - ref).max())
    print("err", err)
    assert err < 1e-5
    print("OK")
    """)
    assert "OK" in out


def test_gpipe_grad_flows():
    out = _run("""
    from repro.distributed.pipeline import gpipe, stack_stages
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("pipe",))
    L, D, M, B = 4, 8, 4, 2
    Ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
    xs = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))

    def stage_fn(stage_W, x):
        def body(h, W):
            return jnp.tanh(h @ W), None
        h, _ = jax.lax.scan(body, x, stage_W)
        return h

    run = gpipe(stage_fn, mesh, "pipe")

    def loss_pipe(W):
        return (run(stack_stages({"w": W}, 4)["w"], xs) ** 2).mean()

    def loss_seq(W):
        def apply(h):
            for l in range(L):
                h = jnp.tanh(h @ W[l])
            return h
        return (jax.vmap(apply)(xs) ** 2).mean()

    g1 = jax.grad(loss_pipe)(Ws)
    g2 = jax.grad(loss_seq)(Ws)
    err = float(jnp.abs(g1 - g2).max())
    print("grad err", err)
    assert err < 1e-5
    print("OK")
    """)
    assert "OK" in out
