"""Tests for the faults subsystem (machine dynamics + orphans + backups).

Contracts under test:

  * degeneracy — ``dynamics="none"`` (the default) is bit-identical to
    the pre-faults engine: every metric leaf and the full task log match
    the frozen PR 6 snapshot (``tests/data/pr6_engine_snapshot.json``)
    for all 5 dispatchers x ELARE/FELARE;
  * oracle — the pure-Python interpreter replays ``bernoulli_updown``,
    ``site_outage`` and ``degrade`` event-for-event (metrics, energies
    and full task logs including orphan retry counts), with and without
    ``with_backup``;
  * safety — no task is ever started on a dead machine, and orphan
    retries are bounded by ``max_retries`` (hypothesis property);
  * single-jit — one trace per (policy, dispatcher, dynamics) triple,
    including through the CLI;
  * backups — ``with_backup`` is inert without a dynamics attached and
    validates its inputs;
  * plumbing — the ``health`` observer, registries, ``--dynamics`` /
    ``--list-dynamics``, and SweepSpec JSON round-trips.
"""
import json

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro import experiments, scenarios
from repro.core import dispatch, engine, faults, pyengine, workload
from repro.core.types import CANCELLED, COMPLETED, MISSED
from repro.experiments import runner, sweep
from repro.launch import elastic

SPEC2 = scenarios.get_fleet("paper_x2").build()

BERNOULLI = faults.BernoulliUpDown(p_fail=0.05, p_recover=0.3, seed=7)
OUTAGE = faults.SiteOutage(outages=((0, 0.25, 0.5), (1, 0.5, 0.625)))
DEGRADE = faults.Degrade(factor=2.0, p=0.5, seed=3)  # 2.0: f32-exact scale


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate, eet):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


# -------------------------------------------------------------- registries
def test_builtin_dynamics_registered():
    names = faults.list_dynamics()
    for name in ("none", "bernoulli_updown", "site_outage", "degrade"):
        assert name in names
        assert faults.is_registered(name)
        assert faults.describe(name)  # non-empty one-liner
    assert isinstance(faults.get("NONE"), faults.NoDynamics)  # case-insens
    with pytest.raises(KeyError, match="choose from"):
        faults.get("nope")
    with pytest.raises(TypeError, match="MachineDynamics protocol"):
        faults.register("bad", object())


def test_dynamics_json_round_trip():
    for d in (faults.NoDynamics(), BERNOULLI, OUTAGE, DEGRADE,
              faults.Degrade(factor=1.5, machines=(0, 3)),
              faults.SiteOutage(outages=((1, 0.1, 0.9),), max_retries=5)):
        back = faults.from_json_dict(
            json.loads(json.dumps(faults.to_json_dict(d))))
        assert back == d
    with pytest.raises(ValueError, match="unknown dynamics kind"):
        faults.from_json_dict({"kind": "nope"})


def test_dynamics_validation():
    with pytest.raises(ValueError, match="start < end"):
        faults.SiteOutage(outages=((0, 0.5, 0.25),))
    with pytest.raises(ValueError, match="factor"):
        faults.Degrade(factor=0.0)


def test_hash_uniform_host_mirrors_jax_bit_for_bit():
    """The oracle's plain-int hash reproduces the jitted draw exactly —
    the property that makes bernoulli failure traces cross-checkable."""
    for seed in (0, 7, 123):
        for step in (0, 1, 17, 4096):
            dev = np.asarray(faults.hash_uniform(
                jnp.arange(16, dtype=jnp.uint32), jnp.uint32(step), seed))
            host = np.asarray(
                [faults.hash_uniform_host(j, step, seed) for j in range(16)],
                np.float32)
            np.testing.assert_array_equal(dev, host)


# ------------------------------------------------- degeneracy (bit-exact)
def test_dynamics_none_bit_exact_with_pr6_snapshot():
    """dynamics="none" (and the default) reproduce the frozen pre-faults
    engine bit for bit: metrics and task logs for 5 dispatchers x 2
    mapping heuristics."""
    with open("tests/data/pr6_engine_snapshot.json") as f:
        snap = json.load(f)
    tr = _trace(1, 40, 4.0, SPEC2.eet)
    for key, want in snap.items():
        d, h = key.split("/")
        m, aux = engine.simulate(tr, SPEC2, h, observers=("task_log",),
                                 dispatcher=d, dynamics="none")
        for f in m._fields:
            got = np.asarray(getattr(m, f), np.float32)
            ref = np.asarray(want[f], np.float32)
            assert got.tobytes() == ref.tobytes(), f"{key}/{f}"
        log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
        for f, ref in want["task_log"].items():
            got = log[f]
            ref = np.asarray(ref, got.dtype)
            assert got.tobytes() == ref.tobytes(), f"{key}/task_log.{f}"
        # the new retries column exists and stays all-zero without faults
        assert log["retries"].max() == 0, key


def test_default_dynamics_is_none():
    tr = _trace(1, 40, 4.0, SPEC2.eet)
    a = engine.simulate(tr, SPEC2, "FELARE", dispatcher="fair_spill")
    b = engine.simulate(tr, SPEC2, "FELARE", dispatcher="fair_spill",
                        dynamics="none")
    for f in a._fields:
        assert (np.asarray(getattr(a, f)).tobytes()
                == np.asarray(getattr(b, f)).tobytes()), f


def test_with_backup_inert_without_dynamics():
    """Backups only matter when machines can die: a wrapped policy maps
    bit-identically to its base on a fault-free run."""
    tr = _trace(1, 40, 4.0, SPEC2.eet)
    base = engine.simulate(tr, SPEC2, "FELARE", dispatcher="sticky")
    wrapped = engine.simulate(tr, SPEC2, faults.with_backup("FELARE", k=2),
                              dispatcher="sticky")
    for f in base._fields:
        assert (np.asarray(getattr(base, f)).tobytes()
                == np.asarray(getattr(wrapped, f)).tobytes()), f


# --------------------------------------------------------- oracle parity
def _assert_engine_matches_oracle(tr, spec, heuristic, dispatcher, dynamics,
                                  tag):
    m, aux = engine.simulate(tr, spec, heuristic, dispatcher=dispatcher,
                             dynamics=dynamics, observers=("task_log",))
    ref = pyengine.simulate(tr, spec, heuristic, dispatcher=dispatcher,
                            dynamics=dynamics)
    for f in ("arrived_by_type", "completed_by_type", "missed_by_type",
              "cancelled_by_type"):
        np.testing.assert_array_equal(
            np.asarray(getattr(m, f)), np.asarray(ref[f]),
            err_msg=f"{tag}/{f}")
    for f in ("energy_dynamic", "energy_wasted", "makespan"):
        np.testing.assert_allclose(
            float(getattr(m, f)), float(ref[f]), rtol=1e-5, atol=1e-6,
            err_msg=f"{tag}/{f}")
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    rlog = ref["task_log"]
    for f in ("status", "machine", "site", "retries"):
        np.testing.assert_array_equal(log[f], np.asarray(rlog[f]),
                                      err_msg=f"{tag}/task_log.{f}")
    for f in ("map_time", "start_time", "end_time"):
        np.testing.assert_allclose(
            log[f], np.asarray(rlog[f], np.float32), rtol=1e-6, atol=1e-6,
            err_msg=f"{tag}/task_log.{f}")


@pytest.mark.parametrize("dynamics", [BERNOULLI, OUTAGE, DEGRADE],
                         ids=["bernoulli_updown", "site_outage", "degrade"])
@pytest.mark.parametrize("heuristic", ["ELARE", "FELARE"])
def test_faulty_task_log_matches_oracle_event_for_event(heuristic, dynamics):
    """Engine vs oracle under failures on the 2-site paper fleet: per-task
    status/machine/site/retries and every timestamp agree at every event
    — including bit-equal bernoulli failure draws and f32-exact outage
    window edges."""
    tr = _trace(3, 48, 4.0, SPEC2.eet)
    for dispatcher in ("sticky", "health_aware"):
        _assert_engine_matches_oracle(
            tr, SPEC2, heuristic, dispatcher, dynamics,
            f"{heuristic}/{dispatcher}/{dynamics.kind}")


@pytest.mark.parametrize("k", [1, 2])
def test_backup_failover_matches_oracle_event_for_event(k):
    """with_backup(k) under machine churn: the oracle mirrors the backup
    nomination (greedy min completion, primary excluded) and the
    fail-straight-over path, so the full task logs still agree."""
    tr = _trace(3, 48, 4.0, SPEC2.eet)
    for heuristic in ("ELARE", "FELARE"):
        _assert_engine_matches_oracle(
            tr, SPEC2, faults.with_backup(heuristic, k=k), "sticky",
            BERNOULLI, f"{heuristic}+backup{k}")


# ------------------------------------------------------- safety properties
@given(seed=st.integers(0, 1000), rate=st.floats(2.0, 8.0),
       dispatcher=st.sampled_from(["sticky", "least_queued", "fair_spill",
                                   "health_aware"]))
@settings(max_examples=8, deadline=None)
def test_no_task_starts_on_a_dead_machine(seed, rate, dispatcher):
    """Under a scheduled outage, no task ever *starts* on a machine inside
    its site's dead window, and orphan retries stay within max_retries."""
    dyn = faults.SiteOutage(outages=((0, 0.25, 0.5),), max_retries=2)
    tr = _trace(seed, 80, rate, SPEC2.eet)
    _, aux = engine.simulate(tr, SPEC2, "FELARE", observers=("task_log",),
                             dispatcher=dispatcher, dynamics=dyn)
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    horizon = np.float32(np.asarray(tr.deadline).max())
    t0 = np.float32(np.float32(0.25) * horizon)
    t1 = np.float32(np.float32(0.5) * horizon)
    sites = np.asarray(SPEC2.site_of_machine)
    ran = np.isin(log["status"], (COMPLETED, MISSED)) & (log["machine"] >= 0)
    started = log["start_time"][ran]
    on_dead_site = sites[log["machine"][ran]] == 0
    in_window = (started >= t0) & (started < t1)
    assert not np.any(on_dead_site & in_window), (
        "task started on a machine during its site's outage")
    # bounded retry: a surviving task never exceeded max_retries; only a
    # CANCELLED task carries the exhausting (max+1)-th increment
    surviving = log["status"] != CANCELLED
    assert log["retries"][surviving].max(initial=0) <= dyn.max_retries
    assert log["retries"].max() <= dyn.max_retries + 1


def test_full_blackout_cancels_everything_without_hanging():
    """Both sites dark for the whole trace: every arrived task dies by
    retry exhaustion (no machine ever accepts work) and the loop
    terminates."""
    dyn = faults.SiteOutage(outages=((0, 0.0, 10.0), (1, 0.0, 10.0)),
                            max_retries=1)
    tr = _trace(0, 30, 4.0, SPEC2.eet)
    m, aux = engine.simulate(tr, SPEC2, "FELARE", observers=("task_log",),
                             dynamics=dyn, dispatcher="health_aware")
    assert int(np.asarray(m.completed_by_type).sum()) == 0
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    assert np.all(log["machine"] == -1)  # nothing ever ran
    assert int(np.asarray(m.cancelled_by_type).sum()) == 30


# ------------------------------------------------------------- single jit
def test_one_jit_trace_per_policy_dispatcher_dynamics():
    heuristics = ("ELARE", "FELARE")
    runner._TRACE_LOG.clear()
    for dyn in ("none", "site_outage"):
        experiments.run_sweep(experiments.SweepSpec(
            system="paper_x2", rates=(3.0,), reps=2, n_tasks=50,
            heuristics=heuristics, seed=1, dispatcher="health_aware",
            dynamics=dyn,
        ))
    expected = {(h, "poisson", "health_aware", dyn, "none")
                for h in heuristics for dyn in ("none", "site_outage")}
    assert set(runner._TRACE_LOG) == expected
    assert len(runner._TRACE_LOG) == len(expected)
    runner._TRACE_LOG.clear()


# --------------------------------------------------------------- backups
def test_with_backup_validation_and_describe():
    with pytest.raises(ValueError, match="k must be >= 1"):
        faults.with_backup("FELARE", k=0)
    with pytest.raises(TypeError, match="mapping policy"):
        faults.with_backup(42)
    pol = faults.with_backup("FELARE", k=2)
    assert pol.backup_k == 2
    assert pol.describe().backup_k == 2


def test_backup_slots_are_disjoint_and_exclude_primary():
    """Every nominated backup set: k distinct machines, none the primary,
    all within reach of the task (checked through the engine's own
    nomination on a deterministic single-event run)."""
    tr = _trace(3, 48, 4.0, SPEC2.eet)
    ref = pyengine.simulate(tr, SPEC2, faults.with_backup("FELARE", k=2),
                            dispatcher="sticky", dynamics=BERNOULLI)
    backup = np.asarray(ref["backup"])
    machine = np.asarray(ref["task_log"]["machine"])
    assert backup.shape == (48, 2)
    for k_, row in enumerate(backup):
        slots = row[row >= 0]
        assert len(set(slots.tolist())) == len(slots), f"task {k_} dup slot"


# ------------------------------------------------------- health observer
def test_health_observer_series():
    tr = _trace(2, 100, 5.0, SPEC2.eet)
    _, aux = engine.simulate(
        tr, SPEC2, "FELARE", dispatcher="health_aware",
        dynamics=faults.SiteOutage(outages=((0, 0.25, 0.5),)),
        observers=("health",))
    h = {k: np.asarray(v) for k, v in aux["health"].items()}
    M, F = SPEC2.n_machines, SPEC2.n_sites
    assert h["healthy"].shape == (64,)
    assert h["site_healthy"].shape == (64, F)
    assert h["site_alive"].shape == (64, F)
    # the outage is visible: site 0 drops to zero healthy machines inside
    # the window and recovers after
    assert h["healthy"].min() == M // 2
    assert h["healthy"].max() == M
    assert not h["site_alive"][:, 0].all()
    assert h["site_alive"][:, 1].all()
    np.testing.assert_array_equal(h["site_healthy"].sum(-1), h["healthy"])
    # orphan pressure is cumulative
    assert np.all(np.diff(h["orphans"]) >= 0)
    assert h["orphans"][-1] > 0

    # with no dynamics the series are trivially flat
    _, aux = engine.simulate(tr, SPEC2, "FELARE", observers=("health",))
    h = {k: np.asarray(v) for k, v in aux["health"].items()}
    assert np.all(h["healthy"] == M)
    assert np.all(h["orphans"] == 0)


# ------------------------------------------------------------ CLI + spec
def test_cli_faulty_sweep_writes_artifacts(tmp_path):
    runner._TRACE_LOG.clear()
    out = tmp_path / "faults"
    sweep.main([
        "--system", "paper_x2", "--dispatcher", "health_aware",
        "--dynamics", "site_outage", "--observers", "health",
        "--rates", "4.0", "--reps", "1", "--tasks", "40",
        "--heuristics", "ELARE", "--out", str(out),
    ])
    payload = json.loads((out / "sweep.json").read_text())
    assert payload["spec"]["dynamics"] == "site_outage"
    assert (out / "sweep.csv").exists()
    assert (out / "observers.json").exists()
    assert set(runner._TRACE_LOG) == {
        ("ELARE", "poisson", "health_aware", "site_outage", "none")}
    runner._TRACE_LOG.clear()


def test_cli_rejects_unknown_dynamics(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--dynamics", "nope"])
    assert "unknown dynamics" in capsys.readouterr().err


def test_cli_list_dynamics(capsys):
    with pytest.raises(SystemExit):
        sweep.build_spec(["--list-dynamics"])
    out = capsys.readouterr().out
    for name in faults.list_dynamics():
        assert name in out


def test_spec_rejects_unknown_dynamics():
    with pytest.raises(ValueError, match="unknown dynamics"):
        experiments.SweepSpec(dynamics="nope")
    with pytest.raises(ValueError, match="MachineDynamics"):
        experiments.SweepSpec(dynamics=42)


def test_spec_json_roundtrip_with_dynamics():
    named = experiments.SweepSpec(system="paper_x2", dynamics="site_outage")
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(named.to_json_dict())))
    assert back == named
    inst = experiments.SweepSpec(
        system="paper_x2", dispatcher="health_aware",
        dynamics=faults.SiteOutage(outages=((1, 0.1, 0.4),), max_retries=5))
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(inst.to_json_dict())))
    assert back.dynamics == inst.dynamics
    # defaults stay "none" for old JSON payloads
    d = named.to_json_dict()
    d.pop("dynamics")
    assert experiments.SweepSpec.from_json_dict(d).dynamics == "none"


# ----------------------------------------------------------- launch demo
def test_elastic_launch_smoke():
    res = elastic.main(["--tasks", "60", "--rate", "4.0",
                        "--down", "1:0.25:0.5"])
    assert set(res) >= {"ontime", "orphans", "site_alive", "min_sites_live"}
    assert 0.0 <= res["ontime"] <= 1.0
    assert res["min_sites_live"] >= 1
