"""Checkpoint/restart + failure injection + elastic restore tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import registry
from repro.train.loop import SimulatedFailure, TrainJob, run, run_with_restarts

CFG = registry.get_smoke_config("qwen1.5-0.5b").scaled(
    n_layers=2, d_model=64, vocab_size=512)


def _job(d, steps=12, **kw):
    return TrainJob(cfg=CFG, steps=steps, batch=2, seq=16, ckpt_dir=str(d),
                    ckpt_every=4, lr=1e-3, ckpt_async=False, **kw)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        ckpt.save(tmp_path, 3, tree)
        restored, step = ckpt.restore(tmp_path, tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_latest_step(self, tmp_path):
        tree = {"x": jnp.zeros(3)}
        for s in (1, 5, 3):
            ckpt.save(tmp_path, s, tree)
        assert ckpt.latest_step(tmp_path) == 5

    def test_digest_detects_corruption(self, tmp_path):
        tree = {"x": jnp.arange(8.0)}
        ckpt.save(tmp_path, 1, tree)
        f = tmp_path / "step_00000001" / "arrays.npz"
        data = bytearray(f.read_bytes())
        data[-20] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError, match="digest"):
            ckpt.restore(tmp_path, tree)

    def test_async_save(self, tmp_path):
        tree = {"x": jnp.arange(128.0)}
        t = ckpt.save(tmp_path, 7, tree, blocking=False)
        t.join()
        _, step = ckpt.restore(tmp_path, tree)
        assert step == 7

    def test_elastic_restore_to_host(self, tmp_path):
        """Saved arrays restore against ShapeDtypeStruct targets (any mesh)."""
        tree = {"w": jnp.ones((8, 4), jnp.float32)}
        ckpt.save(tmp_path, 2, tree)
        target = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
        restored, _ = ckpt.restore(tmp_path, target)
        assert restored["w"].shape == (8, 4)


class TestFailureRecovery:
    def test_restart_resumes_from_checkpoint(self, tmp_path):
        params1, _, hist1 = run(_job(tmp_path, steps=8))
        # fresh run to 16 in two incarnations with a failure at 10
        job = _job(tmp_path / "b", steps=16)
        failures = {10: SimulatedFailure("boom")}
        params2, _, hist2, restarts = run_with_restarts(
            job, failures=failures)
        assert restarts == 1
        assert hist2[-1]["step"] == 15

    def test_restart_is_bit_exact(self, tmp_path):
        """Uninterrupted run == run interrupted at step 9 (same final params).

        Holds because batches are pure functions of the step, checkpoints are
        taken at step boundaries, and the failure lands exactly on one."""
        job_a = _job(tmp_path / "a", steps=12)
        pa, _, _ = run(job_a)
        job_b = _job(tmp_path / "b", steps=12)
        failures = {8: SimulatedFailure("preempted")}  # ckpt_every=4 -> step 8 boundary
        pb, _, _, restarts = run_with_restarts(job_b, failures=failures)
        assert restarts == 1
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_loss_decreases(self, tmp_path):
        cfg = CFG.scaled(vocab_size=256)
        job = TrainJob(cfg=cfg, steps=40, batch=8, seq=64, lr=1e-2,
                       ckpt_dir=None)
        _, _, hist = run(job)
        first5 = np.mean([h["loss"] for h in hist[:5]])
        last5 = np.mean([h["loss"] for h in hist[-5:]])
        assert last5 < first5 - 0.5  # clearly learning, not noise
