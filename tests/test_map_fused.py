"""Bit-exactness battery for the fused Pallas map/dispatch kernels.

Pins ``kernels/map_fused`` + ``policy.with_pallas_map`` +
``dispatch.with_pallas_balance`` to the lax path, in the style of
``tests/test_siteloop_vmap.py``:

  * select-level — fused ``FusedMapPolicy.select`` equals the lax
    ``select`` leaf for leaf (MapAction: assign/drop/queue_drop) over
    hypothesis-drawn random SchedContexts (arbitrary qfree/pending/
    deadline draws, padded vs exact machine counts), for all 8 built-in
    heuristics and their ``with_fairness`` variants;
  * trace-level — full simulations agree on every metrics leaf and every
    task_log event field, byte for byte, for F in {1, 2, 8} (block-
    reshaped site views) and a non-contiguous partition (masked-vmap
    view), plus metrics/task_log parity against the pure-Python oracle
    for ELARE/FELARE;
  * dispatch — the fused balance scan equals ``sequential_balance``'s
    ``lax.scan`` walk, standalone and through ``with_pallas_balance``;
  * backend selection — ``pallas_backend.default_interpret`` honors the
    ``REPRO_PALLAS_INTERPRET`` override and rejects junk values.

Interpret mode throughout (CPU-exact; the compiled path runs the same
kernel body on TPU/GPU).
"""
import functools

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import api, dispatch, engine, faults, policy, pyengine, workload
from repro.core.dispatch.base import DispatchContext, sequential_balance
from repro.core.policy.context import MachineView, SchedContext
from repro.core.policy.fused import FusedMapPolicy
from repro.core.types import SystemArrays, SystemSpec
from repro.kernels import pallas_backend
from repro.scenarios import fleets

SPEC = api.paper_system()
HEURISTICS = ("ELARE", "FELARE", "MM", "MSD", "MMU", "MET", "MCT", "RANDOM")
FLEETS = {1: "paper", 2: "paper_x2", 8: "paper_x8"}


@pytest.fixture(scope="module", autouse=True)
def _release_jit_caches():
    """Drop this module's executables when it finishes.

    The battery compiles hundreds of (policy x shape) programs; left in
    the in-process jit cache they push XLA's CPU compiler into
    segfault territory for later test modules in a one-process run.
    """
    yield
    _select_pair.cache_clear()
    _sim_pair.cache_clear()
    jax.clear_caches()


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate, eet):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return [bool(np.array_equal(np.asarray(x), np.asarray(y)))
            for x, y in zip(la, lb)]


# ----------------------------------------------------------- select level
def _rand_ctx(N, M, S, Q, seed):
    """A random SchedContext with adversarial qfree/pending/deadline draws
    (full queues, stale tasks, empty machines all reachable)."""
    r = np.random.default_rng(seed)
    eet = jnp.asarray(r.uniform(0.5, 20, (S, M)).astype(np.float32))
    sysarr = SystemArrays(
        eet=eet,
        p_dyn=jnp.asarray(r.uniform(1, 10, M).astype(np.float32)),
        p_idle=jnp.asarray(r.uniform(0.1, 1, M).astype(np.float32)),
    )
    queue = np.full((M, Q), -1, np.int32)
    qlen = r.integers(0, Q + 1, M).astype(np.int32)
    for m in range(M):
        queue[m, :qlen[m]] = r.integers(0, N, qlen[m])
    view = MachineView(
        avail_base=jnp.asarray(r.uniform(0, 60, M).astype(np.float32)),
        queue=jnp.asarray(queue),
        qlen=jnp.asarray(qlen),
    )
    return SchedContext(
        now=jnp.float32(r.uniform(0, 50)),
        pending=jnp.asarray(r.integers(0, 2, N).astype(bool)),
        task_type=jnp.asarray(r.integers(0, S, N).astype(np.int32)),
        deadline=jnp.asarray(r.uniform(0, 120, N).astype(np.float32)),
        view=view,
        sysarr=sysarr,
        suffered=jnp.asarray(r.integers(0, 2, S).astype(bool)),
    )


@functools.lru_cache(maxsize=None)
def _select_pair(name: str, fair: bool):
    lax_pol = policy.get(name)
    if fair and not policy.describe(lax_pol).fairness:
        lax_pol = policy.with_fairness(lax_pol)
    fused = policy.with_pallas_map(lax_pol, interpret=True)
    assert isinstance(fused, FusedMapPolicy)
    return lax_pol, fused


def _assert_select_parity(name, fair, N, M, S, Q, seed):
    lax_pol, fused = _select_pair(name, fair)
    ctx = _rand_ctx(N, M, S, Q, seed)
    a, b = lax_pol.select(ctx), fused.select(ctx)
    for field in ("assign", "drop", "queue_drop"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            err_msg=f"{name} fair={fair} {field} "
                    f"N={N} M={M} S={S} Q={Q} seed={seed}")


@settings(max_examples=40, deadline=None)
@given(name=st.sampled_from(HEURISTICS),
       seed=st.integers(0, 2**31 - 1),
       dims=st.sampled_from([(50, 4, 4, 2), (130, 9, 5, 3), (64, 128, 4, 2)]))
def test_select_parity_random_contexts(name, seed, dims):
    """Fused == lax bit-for-bit, padded (M=4/9) and exact-lane (M=128)
    machine counts, every built-in heuristic."""
    N, M, S, Q = dims
    _assert_select_parity(name, False, N, M, S, Q, seed)


@settings(max_examples=25, deadline=None)
@given(name=st.sampled_from(("ELARE", "MM", "MSD", "MMU", "MET",
                             "MCT", "RANDOM")),
       seed=st.integers(0, 2**31 - 1))
def test_select_parity_fairness_wrapped(name, seed):
    """The Sec. V wrapper (eviction plan + priority Phase-II) stays
    bit-exact through the fused path, over every base heuristic."""
    _assert_select_parity(name, True, 80, 6, 4, 3, seed)


def test_with_pallas_map_noop_on_unsupported():
    """Policies outside the kernel kind space pass through unchanged."""
    import dataclasses

    @dataclasses.dataclass(frozen=True)
    class WeirdNominator:
        kind = "not_a_kernel_kind"

        def nominate(self, ctx):  # pragma: no cover - never called
            raise NotImplementedError

    weird = policy.TwoPhasePolicy(
        WeirdNominator(), policy.NominationValue(), policy.DropStale())
    assert policy.with_pallas_map(weird, interpret=True) is weird
    opaque = lambda *a: None  # noqa: E731 - opaque callable policy
    assert policy.with_pallas_map(opaque, interpret=True) is opaque
    with pytest.raises(ValueError, match="fused map kernel"):
        FusedMapPolicy(weird, interpret=True)


def test_with_pallas_map_backup_composition():
    """BackupPolicy keeps its k on the outside; the base is fused."""
    bp = faults.with_backup("FELARE", k=2)
    fused = policy.with_pallas_map(bp, interpret=True)
    assert fused.backup_k == 2
    assert isinstance(fused.base, FusedMapPolicy)
    assert fused.describe() == bp.describe()
    ctx = _rand_ctx(40, 5, 4, 2, 11)
    a, b = bp.select(ctx), fused.select(ctx)
    for field in ("assign", "drop", "queue_drop"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)))


# ------------------------------------------------------------ trace level
@functools.lru_cache(maxsize=None)
def _sim_pair(fleet_name: str, heuristic: str):
    spec = (SPEC if fleet_name == "paper"
            else fleets.get_fleet(fleet_name).build())
    sysarr = spec.as_jax()
    lax_pol = policy.get(heuristic)
    fused = policy.with_pallas_map(lax_pol, interpret=True)
    kw = dict(queue_size=spec.queue_size,
              fairness_factor=float(spec.fairness_factor),
              site_of_machine=spec.sites)
    return (spec, jax.jit(engine.make_simulator(lax_pol, sysarr, **kw)),
            jax.jit(engine.make_simulator(fused, sysarr, **kw)))


@pytest.mark.parametrize("F", sorted(FLEETS))
@pytest.mark.parametrize("heuristic", ("ELARE", "FELARE", "MM", "RANDOM"))
def test_trace_parity_fleets(F, heuristic):
    """Full-trace metrics leaf equality, F in {1, 2, 8} (flat + the
    block-diagonal reshaped site views)."""
    spec, sim_lax, sim_fused = _sim_pair(FLEETS[F], heuristic)
    for seed in (0, 3):
        tr = _trace(seed, 150, 3.0, spec.eet)
        ok = _leaves_equal(sim_lax(tr), sim_fused(tr))
        assert all(ok), f"F={F} {heuristic} seed={seed}: {ok}"


@pytest.mark.parametrize("heuristic", ("ELARE", "FELARE"))
def test_trace_parity_masked_site_view(heuristic):
    """A non-contiguous partition forces the engine's masked-vmap site
    path (BIG-masked EET columns); the fused kernel must agree there too."""
    base = SPEC
    spec = SystemSpec(
        eet=base.eet, p_dyn=base.p_dyn, p_idle=base.p_idle,
        queue_size=base.queue_size,
        fairness_factor=float(base.fairness_factor),
        site_of_machine=(0, 1, 0, 1),  # interleaved: not block-reshapable
    )
    sysarr = spec.as_jax()
    lax_pol = policy.get(heuristic)
    fused = policy.with_pallas_map(lax_pol, interpret=True)
    kw = dict(queue_size=spec.queue_size,
              fairness_factor=float(spec.fairness_factor),
              site_of_machine=spec.sites)
    sim_lax = jax.jit(engine.make_simulator(lax_pol, sysarr, **kw))
    sim_fused = jax.jit(engine.make_simulator(fused, sysarr, **kw))
    tr = _trace(5, 120, 3.0, spec.eet)
    ok = _leaves_equal(sim_lax(tr), sim_fused(tr))
    assert all(ok), ok


@pytest.mark.parametrize("heuristic", ("ELARE", "FELARE"))
@pytest.mark.parametrize("seed", [0, 5])
def test_oracle_parity_metrics_and_task_log(heuristic, seed):
    """Fused-path full runs match the pure-Python oracle: count metrics
    byte-exact, task_log status/machine byte-exact, event times to f32
    round-off — and the task_log is *byte*-identical to the lax engine's.
    """
    tr = _trace(seed, 100, 3.0, SPEC.eet)
    fused = policy.with_pallas_map(policy.get(heuristic), interpret=True)
    m, aux = engine.simulate(tr, SPEC, fused, observers=("task_log",))
    m_lax, aux_lax = engine.simulate(tr, SPEC, heuristic,
                                     observers=("task_log",))
    # byte parity with the lax engine (metrics + full task log)
    assert all(_leaves_equal(m, m_lax))
    assert all(_leaves_equal(aux["task_log"], aux_lax["task_log"]))
    # oracle parity
    ref = pyengine.simulate(tr, SPEC, heuristic)
    for field in ("completed_by_type", "missed_by_type",
                  "cancelled_by_type", "arrived_by_type"):
        np.testing.assert_array_equal(
            np.asarray(getattr(m, field)), ref[field], err_msg=field)
    log = {k: np.asarray(v) for k, v in aux["task_log"].items()}
    np.testing.assert_array_equal(log["status"], ref["task_log"]["status"])
    np.testing.assert_array_equal(log["machine"],
                                  ref["task_log"]["machine"])
    for field in ("map_time", "start_time", "end_time"):
        np.testing.assert_allclose(
            log[field], ref["task_log"][field], rtol=1e-6, atol=1e-6,
            err_msg=field)


# --------------------------------------------------------------- dispatch
def _rand_dispatch_ctx(N, M, F, S, seed, with_alive=False):
    r = np.random.default_rng(seed)
    site_of_machine = np.sort(r.integers(0, F, M)).astype(np.int64)
    site_of_machine[:F] = np.arange(F)  # every site owns >= 1 machine
    site_of_machine = np.sort(site_of_machine)
    alive = None
    if with_alive:
        alive = jnp.asarray(r.integers(0, 2, M).astype(bool))
    return DispatchContext(
        now=jnp.float32(r.uniform(0, 50)),
        unassigned=jnp.asarray(r.integers(0, 2, N).astype(bool)),
        task_type=jnp.asarray(r.integers(0, S, N).astype(np.int32)),
        deadline=jnp.asarray(r.uniform(0, 120, N).astype(np.float32)),
        qlen=jnp.asarray(r.integers(0, 3, M).astype(np.int32)),
        running=jnp.asarray(r.integers(0, 2, M).astype(bool)),
        completed=jnp.asarray(r.integers(0, 20, S).astype(np.int32)),
        arrived=jnp.asarray(r.integers(20, 40, S).astype(np.int32)),
        eet=jnp.asarray(r.uniform(0.5, 20, (S, M)).astype(np.float32)),
        site_of_machine=site_of_machine,
        n_sites=F,
        fairness_factor=1.0,
        alive=alive,
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       dims=st.sampled_from([(40, 6, 2, 4), (130, 16, 8, 4),
                             (64, 12, 3, 5)]))
def test_balance_scan_parity(seed, dims):
    """Fused balance kernel == the lax.scan walk, via sequential_balance's
    impl hook, dead-site penalties included."""
    import functools as ft

    from repro.kernels.map_fused import balance_scan

    N, M, F, S = dims
    impl = ft.partial(balance_scan, interpret=True)
    r = np.random.default_rng(seed ^ 0x5EED)
    for with_alive in (False, True):
        ctx = _rand_dispatch_ctx(N, M, F, S, seed, with_alive=with_alive)
        target = jnp.asarray(r.integers(0, 2, N).astype(bool))
        home = jnp.asarray(r.integers(0, F, N).astype(np.int32))
        ref = sequential_balance(ctx, target, home)
        got = sequential_balance(ctx, target, home, impl)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@pytest.mark.parametrize("kind", ("least_queued", "fair_spill",
                                  "health_aware"))
def test_with_pallas_balance_dispatcher_parity(kind):
    lax_d = dispatch.get(kind)
    fused_d = dispatch.with_pallas_balance(lax_d, interpret=True)
    assert fused_d.balance_impl is not None
    for seed in (1, 2, 3):
        ctx = _rand_dispatch_ctx(90, 10, 4, 4, seed, with_alive=True)
        np.testing.assert_array_equal(
            np.asarray(lax_d.dispatch(ctx)),
            np.asarray(fused_d.dispatch(ctx)),
            err_msg=f"{kind} seed={seed}")


def test_with_pallas_balance_noop_and_serialization():
    """Scan-less dispatchers pass through; the ephemeral impl never
    serializes, and the JSON form round-trips to the lax default."""
    sticky = dispatch.get("sticky")
    assert dispatch.with_pallas_balance(sticky, interpret=True) is sticky
    fused_d = dispatch.with_pallas_balance("fair_spill", interpret=True)
    d = dispatch.to_json_dict(fused_d)
    assert "balance_impl" not in d
    back = dispatch.from_json_dict(d)
    assert back.balance_impl is None
    assert back.kind == "fair_spill"


# ------------------------------------------------------- backend selection
def test_default_interpret_env_override(monkeypatch):
    monkeypatch.setenv(pallas_backend.ENV_VAR, "1")
    assert pallas_backend.default_interpret() is True
    monkeypatch.setenv(pallas_backend.ENV_VAR, "0")
    assert pallas_backend.default_interpret() is False
    monkeypatch.setenv(pallas_backend.ENV_VAR, "yes")
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        pallas_backend.default_interpret()
    monkeypatch.delenv(pallas_backend.ENV_VAR)
    expected = jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
    assert pallas_backend.default_interpret() is expected


def test_spec_roundtrips_use_pallas_map():
    from repro.experiments.spec import SweepSpec

    spec = SweepSpec(use_pallas_map=True, n_tasks=10, reps=1,
                     rates=(2.0,), heuristics=("ELARE",))
    d = spec.to_json_dict()
    assert d["use_pallas_map"] is True
    back = SweepSpec.from_json_dict(d)
    assert back.use_pallas_map is True
