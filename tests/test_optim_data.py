"""Optimizer / schedule / data-pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.datapipe.synthetic import Prefetcher, SyntheticLM, input_specs
from repro.optim.adamw import AdamW
from repro.optim.schedule import constant, cosine_with_warmup


class TestAdamW:
    def test_converges_on_quadratic(self):
        opt = AdamW(lr=0.05, weight_decay=0.0)
        params = {"w": jnp.zeros((8,))}
        target = jnp.linspace(-1, 1, 8)
        state = opt.init(params)
        for _ in range(300):
            g = {"w": params["w"] - target}
            params, state, _ = opt.update(g, state, params)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_clipping_bounds_update(self):
        opt = AdamW(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
        params = {"w": jnp.zeros((4,))}
        state = opt.init(params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, gnorm = opt.update(g, state, params)
        assert float(gnorm) > 1e5  # reported norm is pre-clip

    def test_weight_decay_shrinks(self):
        opt = AdamW(lr=0.1, weight_decay=0.5)
        params = {"w": jnp.ones((4,))}
        state = opt.init(params)
        p2, _, _ = opt.update({"w": jnp.zeros((4,))}, state, params)
        assert float(p2["w"][0]) < 1.0

    def test_bf16_params_fp32_moments(self):
        opt = AdamW(lr=1e-2)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.init(params)
        assert state.mu["w"].dtype == jnp.float32
        p2, _, _ = opt.update({"w": jnp.ones((4,), jnp.bfloat16)},
                              state, params)
        assert p2["w"].dtype == jnp.bfloat16


class TestSchedules:
    def test_warmup_then_decay(self):
        lr = cosine_with_warmup(1e-3, warmup=10, total=100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1e-3, rel=1e-3)
        assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)
        assert float(lr(55)) < 1e-3

    def test_constant(self):
        assert float(constant(3e-4)(12345)) == pytest.approx(3e-4)


class TestSyntheticData:
    def test_deterministic_across_instances(self):
        cfg = registry.get_smoke_config("qwen1.5-0.5b")
        a = SyntheticLM(cfg, batch=4, seq=16, seed=7).batch_at(3)
        b = SyntheticLM(cfg, batch=4, seq=16, seed=7).batch_at(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_seed_changes_data(self):
        cfg = registry.get_smoke_config("qwen1.5-0.5b")
        a = SyntheticLM(cfg, batch=4, seq=16, seed=1).batch_at(0)
        b = SyntheticLM(cfg, batch=4, seq=16, seed=2).batch_at(0)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_accum_reshape(self):
        cfg = registry.get_smoke_config("qwen1.5-0.5b")
        b = SyntheticLM(cfg, batch=8, seq=16, accum=4).batch_at(0)
        assert b["tokens"].shape == (4, 2, 16)

    def test_tokens_in_vocab(self):
        cfg = registry.get_smoke_config("internvl2-1b")
        b = SyntheticLM(cfg, batch=4, seq=16).batch_at(0)
        assert b["tokens"].max() < cfg.vocab_size
        assert "patches" in b

    def test_prefetcher_order(self):
        it = Prefetcher(iter(range(10)), depth=3)
        assert list(it) == list(range(10))

    def test_input_specs_match_real_batches(self):
        from repro.configs import shapes

        for arch in ("qwen1.5-0.5b", "internvl2-1b", "whisper-medium"):
            cfg = registry.get_config(arch)
            spec = input_specs(cfg, shapes.SHAPES["train_4k"], accum=8)
            assert spec["tokens"].shape[0] == 8
            total = spec["tokens"].shape[0] * spec["tokens"].shape[1]
            assert total == 256  # global batch preserved


class TestVocabPadding:
    def test_padded_head_masks_extra_rows(self):
        from repro.models import layers as ll
        from repro.models import transformer as tf

        cfg = registry.get_smoke_config("qwen1.5-0.5b").scaled(
            vocab_size=300, pad_vocab_to=256,
            dtype="float32", param_dtype="float32")
        assert cfg.padded_vocab == 512
        params = tf.init(jax.random.PRNGKey(0), cfg)
        assert params["embed"]["tok"].shape[0] == 512
        h = jax.random.normal(jax.random.PRNGKey(1), (1, 2, cfg.d_model))
        logits = ll.unembed_apply(cfg, params["embed"], h)
        assert logits.shape[-1] == 512
        assert float(logits[..., 300:].max()) <= -1e29
