"""Per-architecture smoke tests (reduced configs, CPU): one forward + one
train step asserting shapes and finiteness, plus decode-vs-forward parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.datapipe.synthetic import SyntheticLM
from repro.models import layers as ll
from repro.models import transformer as tf
from repro.optim.adamw import AdamW
from repro.train.steps import make_train_step

ARCHS = registry.ARCH_IDS


def _batch(cfg, B=2, S=32, key=0):
    k = jax.random.PRNGKey(key)
    b = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(
            k, (B, cfg.n_patches, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(k, (B, S, cfg.d_model)) * 0.1
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = registry.get_smoke_config(arch)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    h, aux = tf.forward(cfg, params, batch)
    S = 32 + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert h.shape == (2, S, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_smoke_config(arch)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=1e-3)
    ost = opt.init(params)
    ds = SyntheticLM(cfg, batch=4, seq=32, accum=2)
    step = make_train_step(cfg, opt, donate=False)  # old params read below
    b = ds.batch_at(0)
    if cfg.family == "audio":
        b["tokens"] = b["tokens"][..., :16]
        b["frames"] = b["frames"][..., :16, :]
    params2, ost2, m = step(params, ost, b)
    assert bool(jnp.isfinite(m["loss"]))
    assert float(m["loss"]) > 0
    assert all(
        bool(jnp.isfinite(x.astype(jnp.float32)).all())
        for x in jax.tree.leaves(params2))
    assert int(ost2.step) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)).max()) > 0
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill(S-1) + decode(1) == forward(S) at the last position (f32)."""
    cfg = registry.get_smoke_config(arch).scaled(
        remat=False, dtype="float32", param_dtype="float32",
        capacity_factor=8.0)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S, key=1)
    max_seq = S + 4 + (cfg.n_patches if cfg.family == "vlm" else 0)
    h, _ = tf.forward(cfg, params, batch)
    want = ll.unembed_apply(cfg, params["embed"], h[:, -1:])
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :-1]
    _, cache = tf.prefill(cfg, params, pb, max_seq=max_seq)
    got, cache2 = tf.decode_step(cfg, params, cache, batch["tokens"][:, -1:])
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want),
        rtol=1e-4, atol=1e-3 * float(jnp.abs(want).max()))
    # VLM positions include the prepended patch embeddings
    expect_len = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert int(cache2["len"][0]) == expect_len


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Full config instantiates as shapes only; param count is plausible."""
    cfg = registry.get_config(arch)
    shapes = tf.param_shapes(cfg)  # eval_shape: no allocation
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    names = {
        "command-r-35b": (28e9, 45e9),
        "phi4-mini-3.8b": (3.0e9, 5.5e9),
        "internlm2-1.8b": (1.4e9, 2.4e9),
        "qwen1.5-0.5b": (0.35e9, 0.75e9),
        "xlstm-125m": (0.08e9, 0.25e9),
        "whisper-medium": (0.55e9, 1.1e9),
        "granite-moe-3b-a800m": (2.2e9, 4.5e9),
        "phi3.5-moe-42b-a6.6b": (35e9, 50e9),
        "zamba2-2.7b": (2.0e9, 3.6e9),
        "internvl2-1b": (0.35e9, 0.8e9),
    }
    lo, hi = names[arch]
    assert lo <= total <= hi, f"{arch}: {total/1e9:.2f}B params"


def test_moe_capacity_dispatch_exact_when_ample():
    """With ample capacity the einsum dispatch equals dense per-token top-k."""
    from repro.models import moe

    cfg = registry.get_smoke_config("granite-moe-3b-a800m").scaled(
        dtype="float32", param_dtype="float32", capacity_factor=8.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    y, _ = moe.moe_apply(cfg, p, x)
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]
    gv, gi = jax.lax.top_k(jax.nn.softmax(logits, -1),
                           cfg.experts_per_token)
    gv = gv / gv.sum(-1, keepdims=True)
    y_ref = []
    for t in range(xt.shape[0]):
        acc = 0
        for kk in range(cfg.experts_per_token):
            e = int(gi[t, kk])
            h = jax.nn.silu(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
            acc = acc + gv[t, kk] * (h @ p["w_down"][e])
        y_ref.append(acc)
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, cfg.d_model)), np.asarray(y_ref),
        atol=1e-4)


def test_chunked_loss_matches_dense():
    from repro.train.loss import chunked_lm_loss

    cfg = registry.get_smoke_config("qwen1.5-0.5b").scaled(
        dtype="float32", param_dtype="float32")
    params = tf.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    y = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    m = jnp.ones((B, S))
    loss, _ = chunked_lm_loss(cfg, params, h, y, m, chunk=8)
    logits = ll.unembed_apply(cfg, params["embed"], h)
    dense = (jax.nn.logsumexp(logits, -1)
             - jnp.take_along_axis(logits, y[..., None], -1)[..., 0]).mean()
    np.testing.assert_allclose(float(loss), float(dense), rtol=1e-5)
