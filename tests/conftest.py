"""Test-suite bootstrap.

The property-based tests use `hypothesis` (declared in requirements-dev.txt /
the ``dev`` extra of pyproject.toml). Hermetic environments that cannot pip
install get a deterministic miniature fallback instead: enough of the
`hypothesis` API (``given``, ``settings``, ``strategies.integers / floats /
sampled_from / lists``) to run every property test as a fixed, seeded sweep
of examples. The fallback never shrinks and never explores adaptively — it
is a safety net so the tier-1 suite always collects and runs, not a
replacement for the real dependency.
"""
from __future__ import annotations

import random
import sys


def _install_hypothesis_fallback() -> None:
    import functools
    import types

    class _Strategy:
        """A sampleable value source: ``sample(rng) -> value``."""

        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value, allow_nan=False, allow_infinity=False):
        del allow_nan, allow_infinity  # bounded ranges are always finite
        lo, hi = float(min_value), float(max_value)

        def sample(rng):
            # hit the boundaries occasionally — they are where Eq. 1/2's
            # regime changes live.
            r = rng.random()
            if r < 0.05:
                return lo
            if r < 0.10:
                return hi
            return rng.uniform(lo, hi)

        return _Strategy(sample)

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elements.sample(rng) for _ in range(n)]

        return _Strategy(sample)

    def settings(max_examples=10, deadline=None, **_ignored):
        def deco(f):
            f._max_examples = max_examples
            return f

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(f):
            max_examples = getattr(f, "_max_examples", 10)

            @functools.wraps(f)
            def wrapper(*call_args):  # () for functions, (self,) for methods
                rng = random.Random(0xFE1A)
                for _ in range(max_examples):
                    args = tuple(s.sample(rng) for s in arg_strategies)
                    kwargs = {k: s.sample(rng)
                              for k, s in kw_strategies.items()}
                    f(*call_args, *args, **kwargs)

            # pytest must not see the original (parametrized) signature,
            # or it would demand fixtures for every strategy argument.
            del wrapper.__wrapped__
            return wrapper

        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.__is_fallback__ = True
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.floats = floats
    strat.sampled_from = sampled_from
    strat.lists = lists
    hyp.strategies = strat
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat


try:  # pragma: no cover - depends on the environment
    import hypothesis
except ImportError:  # pragma: no cover
    _install_hypothesis_fallback()
