"""Regression pins for the scenario-API refactor.

Two contracts guard the redesign:

  1. **Bit-exactness** — the default ``scenario="poisson"`` path must
     reproduce the pre-refactor synthesis *byte for byte* under the same
     seed (``tests/_legacy_workload.py`` holds the frozen originals), and
     ``run_sweep`` on a default-scenario spec must match a frozen metrics
     snapshot.
  2. **Single-jit** — every (policy, scenario) pair must run inside ONE
     jitted sweep computation: exactly one trace of each per-policy
     simulator body per ``run_sweep`` call, observed through the
     runner's trace-time log.
"""
import json

import jax
import numpy as np
import pytest

from _legacy_workload import legacy_poisson_trace, legacy_trace_stack
from repro import experiments, scenarios
from repro.core import api, workload
from repro.core.types import SystemSpec
from repro.datapipe import synthetic
from repro.experiments import runner

SPEC = api.paper_system()


def _assert_traces_byte_identical(a, b):
    for leaf_a, leaf_b, name in zip(a, b, type(a)._fields):
        na, nb = np.asarray(leaf_a), np.asarray(leaf_b)
        assert na.dtype == nb.dtype and na.shape == nb.shape, name
        assert na.tobytes() == nb.tobytes(), f"{name} differs bitwise"


# ----------------------------------------------------------- bit-exactness
def test_poisson_trace_bit_exact_vs_prerefactor():
    key = jax.random.PRNGKey(42)
    _assert_traces_byte_identical(
        workload.poisson_trace(key, 200, 3.0, SPEC.eet),
        legacy_poisson_trace(key, 200, 3.0, SPEC.eet),
    )


def test_poisson_trace_type_probs_bit_exact_vs_prerefactor():
    key = jax.random.PRNGKey(17)
    probs = (0.4, 0.3, 0.2, 0.1)
    _assert_traces_byte_identical(
        workload.poisson_trace(key, 150, 2.0, SPEC.eet, type_probs=probs,
                               cv_run=0.2),
        legacy_poisson_trace(key, 150, 2.0, SPEC.eet, type_probs=probs,
                             cv_run=0.2),
    )


def test_trace_stack_bit_exact_vs_prerefactor():
    key = jax.random.PRNGKey(7)
    _assert_traces_byte_identical(
        synthetic.trace_stack(key, (2.0, 5.0), 3, 80, SPEC.eet),
        legacy_trace_stack(key, (2.0, 5.0), 3, 80, SPEC.eet),
    )


def test_default_scenario_object_is_the_poisson_registration():
    assert scenarios.get("poisson") == scenarios.default_scenario()
    _assert_traces_byte_identical(
        scenarios.default_scenario().sample_trace(
            jax.random.PRNGKey(5), 100, 4.0, SPEC.eet),
        legacy_poisson_trace(jax.random.PRNGKey(5), 100, 4.0, SPEC.eet),
    )


def test_trace_batch_deprecated_delegate_bit_exact():
    """The shim = trace_stack's single-rate slice, warning included."""
    key = jax.random.PRNGKey(3)
    with pytest.warns(DeprecationWarning):
        got = workload.trace_batch(key, 4, 100, 3.0, SPEC.eet)
    want = jax.tree.map(
        lambda x: x[0], legacy_trace_stack(key, (3.0,), 4, 100, SPEC.eet)
    )
    _assert_traces_byte_identical(got, want)


def test_trace_batch_still_accepts_legacy_kwargs():
    """The pre-refactor **kw surface (n_task_types, type_probs, cv_run)
    keeps working through the delegate."""
    key = jax.random.PRNGKey(8)
    with pytest.warns(DeprecationWarning):
        got = workload.trace_batch(key, 3, 50, 2.0, SPEC.eet,
                                   n_task_types=2, cv_run=0.2)
    assert int(np.asarray(got.task_type).max()) <= 1
    want = jax.vmap(
        lambda k: legacy_poisson_trace(k, 50, 2.0, SPEC.eet,
                                       n_task_types=2, cv_run=0.2)
    )(jax.random.split(key, 3))
    _assert_traces_byte_identical(got, want)


# ----------------------------------------------- frozen metrics snapshot
# run_sweep under the default scenario, all five default heuristics,
# seed 0 — (H=5, R=2, K=3) cells of 120-task traces. Counts are exact
# integers; energies/makespans are pinned to float32-roundoff tolerance.
_SNAP_SPEC = dict(rates=(2.0, 5.0), reps=3, n_tasks=120, seed=0)
_SNAP_COMPLETED = [
    [[101, 112, 114], [34, 32, 42]],
    [[102, 113, 114], [29, 26, 38]],
    [[102, 113, 114], [27, 26, 36]],
    [[101, 114, 112], [57, 58, 60]],
    [[102, 111, 111], [53, 53, 56]],
]
_SNAP_MISSED = [
    [[19, 8, 6], [86, 88, 78]],
    [[18, 7, 6], [91, 94, 82]],
    [[18, 7, 6], [83, 85, 80]],
    [[9, 2, 5], [7, 4, 10]],
    [[9, 3, 5], [8, 7, 11]],
]
_SNAP_CANCELLED = [
    [[0, 0, 0], [0, 0, 0]],
    [[0, 0, 0], [0, 0, 0]],
    [[0, 0, 0], [10, 9, 4]],
    [[10, 4, 3], [56, 58, 50]],
    [[9, 6, 4], [59, 60, 53]],
]
_SNAP_ENERGY_DYN = [
    [[311.9492, 317.2926, 307.6210], [187.4637, 186.4356, 207.6998]],
    [[308.8663, 317.1258, 307.6210], [184.0592, 187.5843, 212.3773]],
    [[308.8663, 317.1258, 307.6210], [186.6375, 185.6721, 209.1500]],
    [[290.0843, 309.5700, 295.9440], [183.1120, 180.6104, 197.9615]],
    [[301.3672, 306.4947, 292.9674], [182.5677, 174.3736, 198.6190]],
]
_SNAP_MAKESPAN = [
    [[58.2737, 53.3255, 65.7734], [26.5943, 24.8032, 30.3022]],
    [[58.2737, 53.3348, 65.7734], [26.5943, 24.8032, 30.3022]],
    [[58.2737, 53.3348, 65.7734], [26.9428, 24.8032, 29.9745]],
    [[57.9869, 53.1413, 66.5726], [26.2742, 24.0358, 29.6243]],
    [[57.9869, 53.5615, 66.5726], [26.2742, 24.4908, 29.3760]],
]


def test_run_sweep_default_scenario_matches_frozen_snapshot():
    res = experiments.run_sweep(experiments.SweepSpec(**_SNAP_SPEC))
    m = res.metrics
    np.testing.assert_array_equal(
        np.asarray(m.completed_by_type).sum(-1), np.asarray(_SNAP_COMPLETED))
    np.testing.assert_array_equal(
        np.asarray(m.missed_by_type).sum(-1), np.asarray(_SNAP_MISSED))
    np.testing.assert_array_equal(
        np.asarray(m.cancelled_by_type).sum(-1),
        np.asarray(_SNAP_CANCELLED))
    np.testing.assert_allclose(
        np.asarray(m.energy_dynamic), np.asarray(_SNAP_ENERGY_DYN),
        rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(m.makespan), np.asarray(_SNAP_MAKESPAN), rtol=1e-5)


# ------------------------------------------------------------- single jit
def test_one_jit_trace_per_policy_scenario_pair():
    """All policies of a sweep trace exactly once inside one XLA program,
    for the default scenario and for a non-Poisson one alike."""
    heuristics = ("MM", "ELARE", "FELARE")
    runner._TRACE_LOG.clear()
    for scn in ("poisson", "bursty"):
        experiments.run_sweep(experiments.SweepSpec(
            rates=(3.0,), reps=2, n_tasks=60, heuristics=heuristics,
            scenario=scn, seed=1,
        ))
    expected = {(h, s, "sticky", "none", "none")
                for h in heuristics for s in ("poisson", "bursty")}
    assert set(runner._TRACE_LOG) == expected
    # exactly once each: 3 policies x 2 scenarios = 6 trace events total
    assert len(runner._TRACE_LOG) == len(expected)
    runner._TRACE_LOG.clear()


# ------------------------------------------------------ spec round-tripping
def test_spec_json_roundtrip_default():
    spec = experiments.SweepSpec(**_SNAP_SPEC)
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back == spec


def test_spec_json_roundtrip_named_axes():
    spec = experiments.SweepSpec(
        system="aws", scenario="bursty", rates=(1.0, 2.0), reps=2,
        n_tasks=50, heuristics=("ELARE",), seed=3, cv_run=0.2,
        queue_size=4, fairness_factor=2.0, use_pallas_phase1=True,
        max_steps=500,
    )
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back == spec


def test_spec_json_roundtrip_custom_system_and_scenario():
    system = SystemSpec(
        eet=np.asarray([[1.0, 2.0], [3.0, 4.0]], np.float32),
        p_dyn=np.asarray([1.5, 2.5], np.float32),
        p_idle=np.asarray([0.05, 0.05], np.float32),
        queue_size=3, fairness_factor=1.5,
    )
    scenario = scenarios.Scenario(
        scenarios.MMPPArrivals(rate_ratio=4.0),
        scenarios.WeightedMix((0.6, 0.4)),
        scenarios.ScaledDeadlines(0.9),
        scenarios.LognormalRuntimes(sigma=0.4),
    )
    spec = experiments.SweepSpec(system=system, scenario=scenario,
                                 rates=(2.0,), reps=2, n_tasks=40,
                                 heuristics=("MM",))
    back = experiments.SweepSpec.from_json_dict(
        json.loads(json.dumps(spec.to_json_dict())))
    assert back.scenario == scenario
    np.testing.assert_array_equal(back.system.eet, system.eet)
    np.testing.assert_array_equal(back.system.p_dyn, system.p_dyn)
    assert back.system.queue_size == 3
    assert back.system.fairness_factor == 1.5
    assert back.rates == spec.rates and back.heuristics == spec.heuristics


def test_sweep_rerunnable_from_saved_artifact(tmp_path):
    """A sweep re-run from its own sweep.json reproduces the metrics."""
    spec = experiments.SweepSpec(rates=(3.0,), reps=2, n_tasks=60,
                                 heuristics=("MM", "ELARE"),
                                 scenario="flash-crowd", seed=9)
    res = experiments.run_sweep(spec)
    paths = res.save(tmp_path / "artifacts")
    payload = json.loads(paths["json"].read_text())
    respec = experiments.SweepSpec.from_json_dict(payload["spec"])
    assert respec == spec
    res2 = experiments.run_sweep(respec)
    for name in ("completed_by_type", "missed_by_type",
                 "cancelled_by_type", "arrived_by_type"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res.metrics, name)),
            np.asarray(getattr(res2.metrics, name)))


# ------------------------------------------------------- spec validation
def test_spec_rejects_unknown_scenario():
    with pytest.raises(ValueError):
        experiments.SweepSpec(scenario="nope")
    with pytest.raises(ValueError):
        experiments.SweepSpec(scenario=42)


def test_spec_scenario_fleet_precedence():
    """Explicit system wins; system=None defers to the scenario's fleet."""
    wide = experiments.SweepSpec(scenario="wide-fleet")
    assert wide.resolve_system().eet.shape == (8, 6)
    paper = experiments.SweepSpec(scenario="wide-fleet", system="paper")
    assert paper.resolve_system().eet.shape == (4, 4)
    default = experiments.SweepSpec()  # poisson scenario has no fleet
    np.testing.assert_array_equal(default.resolve_system().eet, SPEC.eet)
