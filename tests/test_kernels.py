"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


def _key(i):
    return jax.random.PRNGKey(i)


# --------------------------------------------------------------------------
# phase1_map
# --------------------------------------------------------------------------
@pytest.mark.parametrize("N,M", [(1, 4), (37, 4), (128, 4), (300, 8),
                                 (513, 3)])
def test_phase1_map_sweep(N, M):
    from repro.kernels.phase1_map import ops, ref

    ks = jax.random.split(_key(N * 17 + M), 6)
    eet = jax.random.uniform(ks[0], (N, M), minval=0.3, maxval=6.0)
    avail = jax.random.uniform(ks[1], (M,), maxval=4.0)
    dl = jax.random.uniform(ks[2], (N,), minval=0.5, maxval=10.0)
    pdyn = jax.random.uniform(ks[3], (M,), minval=1.0, maxval=3.0)
    pend = jax.random.bernoulli(ks[4], 0.6, (N,))
    qfree = jax.random.bernoulli(ks[5], 0.7, (M,))
    bm, bec = ops.phase1_map(avail, eet, dl, pdyn, pend, qfree,
                             interpret=True)
    bm2, bec2 = ref.phase1_map_ref(avail, pdyn, qfree, eet, dl, pend)
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(bm2))
    np.testing.assert_allclose(np.asarray(bec), np.asarray(bec2), rtol=1e-6)


def test_phase1_map_matches_heuristic_phase1():
    """Kernel slot-in: same (machine, energy) choice as the jnp Phase-I."""
    from repro.core import heuristics
    from repro.core.eet import P_DYN, P_IDLE, TABLE_I
    from repro.core.types import SystemArrays
    from repro.kernels.phase1_map import ops

    sysarr = SystemArrays(jnp.asarray(TABLE_I), jnp.asarray(P_DYN),
                          jnp.asarray(P_IDLE))
    ks = jax.random.split(_key(3), 3)
    N = 50
    ttype = jax.random.randint(ks[0], (N,), 0, 4)
    dl = jax.random.uniform(ks[1], (N,), minval=2.0, maxval=12.0)
    pending = jax.random.bernoulli(ks[2], 0.8, (N,))
    view = heuristics.MachineView(
        avail_base=jnp.array([0.0, 1.0, 0.5, 2.0]),
        queue=jnp.full((4, 2), -1, jnp.int32),
        qlen=jnp.zeros(4, jnp.int32),
    )
    qfree = view.qlen < 2

    def impl(avail, eet_rows, deadline, p_dyn, pend, qf):
        return ops.phase1_map(avail, eet_rows, deadline, p_dyn, pend, qf,
                              interpret=True)

    bm1, bec1, feas1, _, _ = heuristics.elare_phase1(
        0.0, pending, ttype, dl, view, sysarr, qfree, phase1_impl=impl)
    bm2, bec2, feas2, _, _ = heuristics.elare_phase1(
        0.0, pending, ttype, dl, view, sysarr, qfree, phase1_impl=None)
    np.testing.assert_array_equal(np.asarray(feas1), np.asarray(feas2))
    # argmin may differ only where infeasible (both report BIG)
    np.testing.assert_array_equal(
        np.asarray(bm1)[np.asarray(feas1)], np.asarray(bm2)[np.asarray(feas2)]
    )
    np.testing.assert_allclose(
        np.asarray(bec1)[np.asarray(feas1)],
        np.asarray(bec2)[np.asarray(feas2)], rtol=1e-6)


# --------------------------------------------------------------------------
# flash_attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("Sq,Sk,H,Hkv,hd", [
    (128, 128, 4, 4, 64),      # MHA
    (128, 128, 4, 2, 64),      # GQA
    (256, 256, 8, 1, 32),      # MQA
    (64, 192, 4, 2, 128),      # uneven, padded seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(Sq, Sk, H, Hkv, hd, dtype):
    from repro.kernels.flash_attention import ops, ref

    ks = jax.random.split(_key(Sq + Sk + H), 3)
    B = 2
    q = (jax.random.normal(ks[0], (B, Sq, H, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Sk, Hkv, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, Sk, Hkv, hd)) * 0.5).astype(dtype)
    causal = Sq == Sk
    out = ops.flash_attention(q, k, v, causal=causal, bq=64, bk=64,
                              interpret=True)
    want = jnp.moveaxis(
        ref.flash_attention_ref(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=causal), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol)


def test_flash_attention_kv_len_and_offset():
    from repro.kernels.flash_attention import ops, ref

    ks = jax.random.split(_key(9), 3)
    B, S, H, hd = 2, 128, 2, 32
    q = jax.random.normal(ks[0], (B, 32, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.5
    kv_len = jnp.array([100, 57], jnp.int32)
    out = ops.flash_attention(q, k, v, causal=True, kv_len=kv_len,
                              q_offset=64, bq=32, bk=64, interpret=True)
    want = jnp.moveaxis(
        ref.flash_attention_ref(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), kv_len, causal=True, q_offset=64), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


# --------------------------------------------------------------------------
# decode_attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("Sk,H,Hkv,hd", [
    (256, 4, 4, 64), (512, 8, 2, 64), (1024, 4, 1, 128), (192, 2, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(Sk, H, Hkv, hd, dtype):
    from repro.kernels.decode_attention import ops, ref

    ks = jax.random.split(_key(Sk + H), 4)
    B = 2
    q = (jax.random.normal(ks[0], (B, 1, H, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Sk, Hkv, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, Sk, Hkv, hd)) * 0.5).astype(dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, Sk)
    out = ops.decode_attention(q, k, v, kv_len, bk=128, interpret=True)
    want = jnp.moveaxis(
        ref.decode_attention_ref(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), kv_len), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol)


# --------------------------------------------------------------------------
# ssm_scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("L,H,P,N,chunk", [
    (64, 2, 32, 16, 16), (128, 4, 64, 64, 32), (96, 1, 16, 8, 32),
    (256, 2, 64, 32, 128),
])
def test_ssm_scan_sweep(L, H, P, N, chunk):
    from repro.kernels.ssm_scan import ops, ref

    ks = jax.random.split(_key(L + H + P), 5)
    B = 2
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y, S = ops.ssm_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y2, S2 = ref.ssm_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S2), atol=2e-4)


def test_ssm_scan_matches_model_path():
    """Kernel == the model's XLA ssd_chunked (the serving/training path)."""
    from repro.kernels.ssm_scan import ops
    from repro.models.ssm import ssd_chunked

    ks = jax.random.split(_key(77), 5)
    B, L, H, P, N = 2, 128, 2, 32, 16
    x = jax.random.normal(ks[0], (B, L, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, L, N)) * 0.5
    y1, S1 = ops.ssm_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    y2, S2 = ssd_chunked(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=2e-4)


# --------------------------------------------------------------------------
# model-integration: pallas_interpret attention == xla attention
# --------------------------------------------------------------------------
def test_model_attention_impl_parity():
    from repro.configs import registry
    from repro.models import transformer as tf

    cfg_x = registry.get_smoke_config("qwen1.5-0.5b").scaled(
        remat=False, dtype="float32", param_dtype="float32")
    cfg_p = cfg_x.scaled(attn_impl="pallas_interpret")
    params = tf.init(_key(0), cfg_x)
    batch = {"tokens": jax.random.randint(_key(1), (2, 64), 0,
                                          cfg_x.vocab_size)}
    h_x, _ = tf.forward(cfg_x, params, batch)
    h_p, _ = tf.forward(cfg_p, params, batch)
    np.testing.assert_allclose(
        np.asarray(h_x), np.asarray(h_p), atol=2e-4)
