"""Engine tests: conservation invariants, oracle equivalence (property-based),
and qualitative reproduction of the paper's headline behaviours."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import api, engine, pyengine, workload

SPEC = api.paper_system()
# All 8 registered policies: since the oracle interprets PolicyDesc
# compositions (not hard-coded names), MET/MCT/RANDOM are cross-checkable
# against pyengine too.
HEURISTICS = ["MM", "MSD", "MMU", "MET", "MCT", "RANDOM", "ELARE", "FELARE"]


def _dyadic(x):
    return (np.round(np.asarray(x) * 64) / 64).astype(np.float32)


def _trace(seed, n, rate):
    tr = workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, SPEC.eet)
    return tr._replace(
        arrival=jnp.asarray(_dyadic(tr.arrival)),
        deadline=jnp.asarray(_dyadic(tr.deadline)),
        exec_actual=jnp.asarray(_dyadic(tr.exec_actual)),
    )


@pytest.mark.parametrize("heuristic", HEURISTICS)
def test_task_conservation(heuristic):
    """Every arrived task ends exactly one of completed/missed/cancelled."""
    tr = _trace(1, 300, 4.0)
    m = engine.simulate(tr, SPEC, heuristic)
    total = (
        np.asarray(m.completed_by_type)
        + np.asarray(m.missed_by_type)
        + np.asarray(m.cancelled_by_type)
    )
    assert np.array_equal(total, np.asarray(m.arrived_by_type))
    assert int(np.sum(m.arrived_by_type)) == 300


@pytest.mark.parametrize("heuristic", HEURISTICS)
def test_energy_invariants(heuristic):
    tr = _trace(2, 200, 3.0)
    m = engine.simulate(tr, SPEC, heuristic)
    assert float(m.energy_wasted) <= float(m.energy_dynamic) + 1e-4
    assert float(m.energy_dynamic) >= 0 and float(m.energy_idle) >= 0
    assert float(m.makespan) > 0


@pytest.mark.parametrize("heuristic", HEURISTICS)
@pytest.mark.parametrize("seed", [0, 7])
def test_matches_python_oracle(heuristic, seed):
    tr = _trace(seed, 120, 2.5)
    mj = engine.simulate(tr, SPEC, heuristic)
    mp = pyengine.simulate(tr, SPEC, heuristic)
    for k in ["completed_by_type", "missed_by_type", "cancelled_by_type",
              "arrived_by_type"]:
        assert np.array_equal(np.asarray(getattr(mj, k)), mp[k]), k
    for k in ["energy_dynamic", "energy_wasted", "makespan"]:
        assert float(getattr(mj, k)) == pytest.approx(float(mp[k]), rel=1e-3)


@given(
    seed=st.integers(0, 10_000),
    rate=st.sampled_from([1.0, 2.0, 4.0, 8.0]),
    heuristic=st.sampled_from(HEURISTICS),
)
@settings(max_examples=12, deadline=None)
def test_property_oracle_equivalence(seed, rate, heuristic):
    """The vectorized lax engine and the loop oracle agree on arbitrary
    Poisson traces (dyadic-rounded so fp32/fp64 arithmetic is exact)."""
    tr = _trace(seed, 60, rate)
    mj = engine.simulate(tr, SPEC, heuristic)
    mp = pyengine.simulate(tr, SPEC, heuristic)
    assert np.array_equal(
        np.asarray(mj.completed_by_type), mp["completed_by_type"]
    )
    assert np.array_equal(
        np.asarray(mj.cancelled_by_type), mp["cancelled_by_type"]
    )
    assert float(mj.energy_wasted) == pytest.approx(
        mp["energy_wasted"], rel=1e-3, abs=1e-3
    )


@given(
    seed=st.integers(0, 10_000),
    heuristic=st.sampled_from(HEURISTICS),
    queue_size=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=10, deadline=None)
def test_property_conservation_any_queue(seed, heuristic, queue_size):
    spec = api.paper_system(queue_size=queue_size)
    tr = _trace(seed, 80, 5.0)
    m = engine.simulate(tr, spec, heuristic)
    total = (
        np.asarray(m.completed_by_type)
        + np.asarray(m.missed_by_type)
        + np.asarray(m.cancelled_by_type)
    )
    assert np.array_equal(total, np.asarray(m.arrived_by_type))


def test_vmap_batch_matches_single():
    traces = workload.trace_batch(
        jax.random.PRNGKey(3), 4, 100, 3.0, SPEC.eet
    )
    batched = engine.simulate_batch(traces, SPEC, "ELARE")
    for i in range(4):
        single = engine.simulate(jax.tree.map(lambda x: x[i], traces),
                                 SPEC, "ELARE")
        assert np.array_equal(
            np.asarray(batched.completed_by_type[i]),
            np.asarray(single.completed_by_type),
        )


# --- paper-claim-level behaviour -------------------------------------------
def test_elare_wastes_less_energy_than_mm():
    """Sec. VII-B: ELARE cuts wasted energy at low/moderate arrival rates."""
    traces = workload.trace_batch(
        jax.random.PRNGKey(11), 8, 400, 4.0, SPEC.eet
    )
    w = {}
    for h in ["MM", "ELARE"]:
        m = engine.simulate_batch(traces, SPEC, h)
        w[h] = float(np.mean(np.asarray(m.energy_wasted)))
    assert w["ELARE"] < w["MM"]


def test_elare_cancels_proactively_mm_misses():
    """Fig. 6: ELARE's unsuccessful tasks are mostly cancellations; MM's are
    mostly deadline misses (which imply wasted energy)."""
    traces = workload.trace_batch(
        jax.random.PRNGKey(13), 8, 400, 4.0, SPEC.eet
    )
    me = engine.simulate_batch(traces, SPEC, "ELARE")
    mm = engine.simulate_batch(traces, SPEC, "MM")
    assert np.sum(me.cancelled_by_type) > np.sum(me.missed_by_type)
    assert np.sum(mm.missed_by_type) > np.sum(mm.cancelled_by_type)


def test_felare_improves_fairness_over_elare():
    """Fig. 7: FELARE narrows the per-type completion-rate spread with only
    marginal collective completion loss."""
    traces = workload.trace_batch(
        jax.random.PRNGKey(17), 10, 500, 5.0, SPEC.eet
    )
    res = {}
    for h in ["ELARE", "FELARE"]:
        m = engine.simulate_batch(traces, SPEC, h)
        c = np.asarray(m.completed_by_type, np.float64).sum(0)
        a = np.asarray(m.arrived_by_type, np.float64).sum(0)
        cr = c / np.maximum(a, 1)
        res[h] = (cr.std(), c.sum() / a.sum())
    assert res["FELARE"][0] <= res["ELARE"][0] + 1e-9
    # negligible collective completion degradation (< 5 points)
    assert res["FELARE"][1] >= res["ELARE"][1] - 0.05
