"""Tests for the composable scheduling-policy API (repro.core.policy).

Covers: registry round-trip, composed-vs-legacy-monolith bit-equivalence
(event-level MapActions and full-trace counters, all 8 paper heuristics),
the Pallas kernel as a pluggable nominator, the assigned-never-dropped
invariant, and a custom registered policy flowing end-to-end through
``run_sweep`` and the CLI without touching ``repro/experiments``.
"""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

import _legacy_heuristics as legacy
from repro.core import api, engine, policy, workload
from repro.core.types import SystemArrays

ALL_POLICIES = ("ELARE", "FELARE", "MM", "MSD", "MMU", "MET", "MCT", "RANDOM")

LEGACY = {
    "ELARE": legacy.elare_select,
    "FELARE": legacy.felare_select,
    "MM": legacy.mm_select,
    "MSD": legacy.msd_select,
    "MMU": legacy.mmu_select,
    "MET": legacy.met_select,
    "MCT": legacy.mct_select,
    "RANDOM": legacy.random_select,
}

# 2 task types x 2 machines toy system for event-level tests.
EET = jnp.array([[4.0, 1.0], [8.0, 2.0]], jnp.float32)
SYS = SystemArrays(
    eet=EET,
    p_dyn=jnp.array([1.0, 5.0], jnp.float32),
    p_idle=jnp.array([0.05, 0.05], jnp.float32),
)
SPEC = api.paper_system()


def _random_event(seed: int, n: int = 16, M: int = 2, Q: int = 2):
    """A random mapping-event state (pending/queued tasks, machine views)."""
    rng = np.random.RandomState(seed)
    now = np.float32(rng.uniform(0, 10))
    pending = rng.rand(n) < 0.7
    ttype = rng.randint(0, 2, n)
    dl = (now + rng.uniform(-2, 15, n)).astype(np.float32)
    avail = (now + rng.uniform(0, 5, M)).astype(np.float32)
    queue = np.full((M, Q), -1, np.int32)
    for j in range(M):
        for s, t in enumerate(rng.choice(n, rng.randint(0, Q + 1),
                                         replace=False)):
            queue[j, s] = t
            pending[t] = False
    qlen = (queue >= 0).sum(1).astype(np.int32)
    view = policy.MachineView(jnp.asarray(avail), jnp.asarray(queue),
                              jnp.asarray(qlen))
    suffered = rng.rand(2) < 0.5
    return (jnp.float32(now), jnp.asarray(pending),
            jnp.asarray(ttype, jnp.int32), jnp.asarray(dl), view, SYS,
            jnp.asarray(suffered))


def _trace(seed, n, rate):
    return workload.poisson_trace(jax.random.PRNGKey(seed), n, rate, SPEC.eet)


# --------------------------------------------------------------- registry
class TestRegistry:
    def test_builtins_registered(self):
        assert set(ALL_POLICIES) <= set(policy.list_policies())

    def test_round_trip_and_case_insensitivity(self):
        pol = policy.TwoPhasePolicy(policy.MinCompletion(), policy.Fcfs(),
                                    policy.DropStale())
        policy.register("my-policy", pol)
        try:
            assert policy.get("my-policy") is pol
            assert policy.get("MY-POLICY") is pol
            assert policy.get("My-Policy") is pol
            assert policy.is_registered("mY-pOlIcY")
            assert "MY-POLICY" in policy.list_policies()
        finally:
            policy.unregister("my-policy")
        assert not policy.is_registered("my-policy")

    def test_duplicate_name_rejected(self):
        pol = policy.TwoPhasePolicy(policy.MinCompletion(), policy.Fcfs(),
                                    policy.DropStale())
        with pytest.raises(ValueError, match="already registered"):
            policy.register("elare", pol)
        # overwrite=True is the explicit escape hatch
        policy.register("dup-test", pol)
        try:
            policy.register("dup-test", pol, overwrite=True)
        finally:
            policy.unregister("dup-test")

    def test_unknown_name_lists_available(self):
        with pytest.raises(KeyError, match="ELARE"):
            policy.get("nope")
        with pytest.raises(KeyError):
            policy.unregister("nope")

    def test_bad_registrations_rejected(self):
        with pytest.raises(ValueError):
            policy.register("", policy.MM)
        with pytest.raises(TypeError):
            policy.register("notcallable", object())

    def test_describe(self):
        d = policy.describe("FELARE")
        assert d == policy.PolicyDesc("min_energy_feasible", "value",
                                      "stale_hopeless", fairness=True)
        assert not policy.describe("ELARE").fairness
        with pytest.raises(TypeError, match="opaque"):
            policy.describe(lambda *a: None)

    def test_legacy_heuristics_shim_is_registry_view(self):
        from repro.core import heuristics

        assert heuristics.get("felare") is policy.get("FELARE")
        assert set(ALL_POLICIES) <= set(heuristics.HEURISTICS)
        pol = policy.TwoPhasePolicy(policy.MinExecution(), policy.Fcfs(),
                                    policy.DropStale())
        policy.register("shim-view", pol)
        try:
            # user registrations appear through the legacy dict surface
            assert heuristics.HEURISTICS["shim-view"] is pol
        finally:
            policy.unregister("shim-view")


# ------------------------------------------------- composed == legacy monolith
@pytest.mark.parametrize("name", ALL_POLICIES)
def test_composed_matches_legacy_event_actions(name):
    """Every composed policy emits bit-identical MapActions to its
    pre-refactor monolith on random mapping events."""
    pol = policy.get(name)
    leg = LEGACY[name]
    for seed in range(60):
        args = _random_event(seed)
        a, b = pol(*args), leg(*args)
        for field in ("assign", "drop", "queue_drop"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
                err_msg=f"{name} seed={seed} {field}",
            )


@given(seed=st.integers(0, 10_000), rate=st.sampled_from([2.0, 5.0, 8.0]),
       name=st.sampled_from(ALL_POLICIES))
@settings(max_examples=16, deadline=None)
def test_composed_matches_legacy_trace_counters(seed, rate, name):
    """Property: full-trace per-type counters of each composed policy are
    bit-identical to the legacy monolith driven through the same engine."""
    tr = _trace(seed, 60, rate)
    sysarr = SPEC.as_jax()
    sim_new = engine.make_simulator(
        policy.get(name), sysarr, queue_size=SPEC.queue_size,
        fairness_factor=float(SPEC.fairness_factor))
    sim_old = engine.make_simulator(
        LEGACY[name], sysarr, queue_size=SPEC.queue_size,
        fairness_factor=float(SPEC.fairness_factor))
    m_new, m_old = sim_new(tr), sim_old(tr)
    for field in ("completed_by_type", "missed_by_type", "cancelled_by_type",
                  "arrived_by_type"):
        np.testing.assert_array_equal(
            np.asarray(getattr(m_new, field)),
            np.asarray(getattr(m_old, field)),
            err_msg=f"{name} seed={seed} rate={rate} {field}",
        )
    for field in ("energy_dynamic", "energy_wasted", "makespan"):
        assert float(getattr(m_new, field)) == float(getattr(m_old, field)), \
            f"{name} seed={seed} rate={rate} {field}"


# ------------------------------------------------------------ pallas nominator
def test_pallas_kernel_plugs_in_as_nominator():
    """`with_pallas_phase1` swaps the nominator implementation; the mapping
    decisions are identical to the jnp Phase-I on random events."""
    pal_elare = policy.with_pallas_phase1(policy.get("ELARE"))
    pal_felare = policy.with_pallas_phase1(policy.get("FELARE"))
    assert pal_elare.nominator.impl is not None
    assert pal_felare.base.nominator.impl is not None
    for seed in range(20):
        args = _random_event(seed, n=24)
        for ref_pol, pal_pol in ((policy.ELARE, pal_elare),
                                 (policy.FELARE, pal_felare)):
            a, b = ref_pol(*args), pal_pol(*args)
            for field in ("assign", "drop", "queue_drop"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, field)),
                    np.asarray(getattr(b, field)),
                    err_msg=f"seed={seed} {field}",
                )


def test_pallas_toggle_noop_for_hookless_policies():
    mm = policy.get("MM")
    assert policy.with_pallas_phase1(mm) is mm


# ----------------------------------------------------------- drop invariants
@given(seed=st.integers(0, 10_000), name=st.sampled_from(ALL_POLICIES))
@settings(max_examples=24, deadline=None)
def test_assigned_task_never_dropped(seed, name):
    """Regression for the shared epilogue: a task assigned to a machine at
    this event must never simultaneously appear in the drop mask."""
    args = _random_event(seed % 4096)
    act = policy.get(name)(*args)
    assign = np.asarray(act.assign)
    drop = np.asarray(act.drop)
    for j in range(assign.shape[0]):
        if assign[j] >= 0:
            assert not drop[assign[j]], (
                f"{name}: task {assign[j]} assigned to machine {j} "
                f"but also dropped"
            )


# -------------------------------------------------- custom policy end-to-end
def test_custom_policy_through_run_sweep_and_cli(tmp_path):
    """A user-registered composition runs through the whole one-jit sweep
    machinery and the CLI without modifying repro/experiments."""
    from repro import experiments
    from repro.experiments import sweep as sweep_cli

    custom = policy.with_fairness(
        policy.TwoPhasePolicy(policy.MinCompletion(), policy.SoonestDeadline(),
                              policy.DropStaleAndHopeless())
    )
    policy.register("FAIR-MSD", custom)
    try:
        spec = experiments.SweepSpec(
            rates=(3.0,), reps=2, n_tasks=60,
            heuristics=("fair-msd", "MSD"), seed=5,
        )
        res = experiments.run_sweep(spec)
        assert res.completion_rate.shape == (2, 1)
        assert spec.heuristics == ("FAIR-MSD", "MSD")

        out = tmp_path / "artifacts"
        result = sweep_cli.main([
            "--rates", "3", "--reps", "1", "--tasks", "40",
            "--heuristics", "FAIR-MSD,ELARE", "--out", str(out),
        ])
        assert (out / "sweep.csv").exists()
        assert result.completion_rate.shape == (2, 1)
    finally:
        policy.unregister("FAIR-MSD")


def test_custom_policy_oracle_interpretable():
    """Composed custom policies get pyengine oracle coverage for free."""
    from repro.core import pyengine

    custom = policy.TwoPhasePolicy(policy.MinCompletion(), policy.Fcfs(),
                                   policy.DropStaleAndHopeless())
    policy.register("MCT-PRO", custom)
    try:
        tr = _trace(11, 80, 4.0)
        tr = tr._replace(
            arrival=jnp.asarray(
                (np.round(np.asarray(tr.arrival) * 64) / 64), jnp.float32),
            deadline=jnp.asarray(
                (np.round(np.asarray(tr.deadline) * 64) / 64), jnp.float32),
            exec_actual=jnp.asarray(
                (np.round(np.asarray(tr.exec_actual) * 64) / 64), jnp.float32),
        )
        mj = engine.simulate(tr, SPEC, "MCT-PRO")
        mp = pyengine.simulate(tr, SPEC, "MCT-PRO")
        np.testing.assert_array_equal(
            np.asarray(mj.completed_by_type), mp["completed_by_type"])
        np.testing.assert_array_equal(
            np.asarray(mj.cancelled_by_type), mp["cancelled_by_type"])
    finally:
        policy.unregister("MCT-PRO")


def test_engine_simulate_sees_overwritten_registration():
    """Regression: engine.simulate resolves the policy outside the jit
    boundary, so overwrite=True re-registrations take effect instead of
    hitting a stale name-keyed jit cache."""
    tr = _trace(3, 60, 5.0)
    policy.register("SWAP-TEST", policy.get("MM"))
    try:
        first = engine.simulate(tr, SPEC, "SWAP-TEST")
        np.testing.assert_array_equal(
            np.asarray(first.completed_by_type),
            np.asarray(engine.simulate(tr, SPEC, "MM").completed_by_type))
        policy.register("SWAP-TEST", policy.get("ELARE"), overwrite=True)
        second = engine.simulate(tr, SPEC, "SWAP-TEST")
        np.testing.assert_array_equal(
            np.asarray(second.completed_by_type),
            np.asarray(engine.simulate(tr, SPEC, "ELARE").completed_by_type))
    finally:
        policy.unregister("SWAP-TEST")


def test_random_nominator_composes_with_value_key():
    """Regression: RandomMachine reports a real nomination value, so
    RandomMachine x NominationValue assigns tasks (and stays oracle-exact)
    instead of silently nominating nothing."""
    from repro.core import pyengine

    pol = policy.TwoPhasePolicy(policy.RandomMachine(),
                                policy.NominationValue(), policy.DropStale())
    policy.register("RAND-VAL", pol)
    try:
        tr = _trace(9, 80, 3.0)
        tr = tr._replace(
            arrival=jnp.asarray(
                np.round(np.asarray(tr.arrival) * 64) / 64, jnp.float32),
            deadline=jnp.asarray(
                np.round(np.asarray(tr.deadline) * 64) / 64, jnp.float32),
            exec_actual=jnp.asarray(
                np.round(np.asarray(tr.exec_actual) * 64) / 64, jnp.float32),
        )
        mj = engine.simulate(tr, SPEC, "RAND-VAL")
        assert int(np.sum(mj.completed_by_type)) > 0
        mp = pyengine.simulate(tr, SPEC, "RAND-VAL")
        np.testing.assert_array_equal(
            np.asarray(mj.completed_by_type), mp["completed_by_type"])
        np.testing.assert_array_equal(
            np.asarray(mj.cancelled_by_type), mp["cancelled_by_type"])
    finally:
        policy.unregister("RAND-VAL")


# --------------------------------------------------------------- CLI surface
def test_cli_list_flag(capsys):
    from repro.experiments import sweep as sweep_cli

    with pytest.raises(SystemExit) as e:
        sweep_cli.build_spec(["--list"])
    assert e.value.code == 0
    out = capsys.readouterr().out
    for name in ALL_POLICIES:
        assert name in out
    assert "min_energy_feasible" in out


def test_cli_unknown_policy_fails_fast(capsys):
    from repro.experiments import sweep as sweep_cli

    with pytest.raises(SystemExit) as e:
        sweep_cli.build_spec(["--heuristics", "ELARE,NOSUCH"])
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "NOSUCH" in err and "ELARE" in err  # available list shown


# ------------------------------------------------------------- StudyResult
def test_study_result_p_dyn_is_constructor_argument():
    """`wasted_energy_pct` works straight off the constructor (regression
    for the post-construction `_p_dyn` mutation hack)."""
    study = api.run_study("ELARE", [4.0], SPEC, n_traces=2, n_tasks=50)
    res = study[0]
    assert isinstance(res.p_dyn, np.ndarray)
    assert np.isfinite(res.wasted_energy_pct)
    rebuilt = api.StudyResult(res.heuristic, res.arrival_rate, res.metrics,
                              p_dyn=np.asarray(SPEC.p_dyn))
    assert rebuilt.wasted_energy_pct == res.wasted_energy_pct
