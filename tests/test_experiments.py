"""Tests for the batched Monte-Carlo sweep subsystem (repro.experiments)."""
import json

import jax
import numpy as np
import pytest

from repro import experiments
from repro.core import api, engine
from repro.datapipe import synthetic
from repro.experiments import sweep as sweep_cli

MINI = experiments.SweepSpec(
    rates=(2.0, 5.0), reps=3, n_tasks=80,
    heuristics=("MM", "ELARE", "FELARE"), seed=7,
)


# --------------------------------------------------------------- rate parsing
def test_parse_rates_comma_list():
    assert experiments.parse_rates("1,2,4.5") == (1.0, 2.0, 4.5)


def test_parse_rates_range_inclusive():
    assert experiments.parse_rates("30:90:10") == (
        30.0, 40.0, 50.0, 60.0, 70.0, 80.0, 90.0)
    assert experiments.parse_rates("1:3") == (1.0, 2.0, 3.0)


def test_parse_rates_rejects_bad_step():
    with pytest.raises(ValueError):
        experiments.parse_rates("1:5:0")


# ---------------------------------------------------------------- trace stack
def test_trace_stack_shapes():
    spec = api.paper_system()
    tr = synthetic.trace_stack(jax.random.PRNGKey(0), (1.0, 2.0, 4.0), 5,
                               50, spec.eet)
    assert tr.arrival.shape == (3, 5, 50)
    assert tr.task_type.shape == (3, 5, 50)
    assert tr.deadline.shape == (3, 5, 50)
    assert tr.exec_actual.shape == (3, 5, 50, 4)


def test_trace_stack_common_random_numbers():
    """Replicate k reuses the same subkey at every rate: task types are
    identical and arrival times scale as 1/rate."""
    spec = api.paper_system()
    tr = synthetic.trace_stack(jax.random.PRNGKey(3), (1.0, 4.0), 4, 60,
                               spec.eet)
    np.testing.assert_array_equal(np.asarray(tr.task_type[0]),
                                  np.asarray(tr.task_type[1]))
    np.testing.assert_array_equal(np.asarray(tr.exec_actual[0]),
                                  np.asarray(tr.exec_actual[1]))
    np.testing.assert_allclose(np.asarray(tr.arrival[0]),
                               4.0 * np.asarray(tr.arrival[1]), rtol=1e-5)


# ------------------------------------------------------- batched == sequential
def test_batched_sweep_matches_sequential_loop():
    """The one-jit vmapped sweep is bit-identical to simulating each trace
    one at a time through engine.simulate (the pre-subsystem code path)."""
    res = experiments.run_sweep(MINI)
    system = MINI.resolve_system()
    stacked = synthetic.trace_stack(
        jax.random.PRNGKey(MINI.seed), MINI.rates, MINI.reps, MINI.n_tasks,
        system.eet, cv_run=MINI.cv_run,
    )
    for h_i, h in enumerate(MINI.heuristics):
        for r_i in range(len(MINI.rates)):
            for k in range(MINI.reps):
                single = engine.simulate(
                    jax.tree.map(lambda x: x[r_i, k], stacked), system, h
                )
                batched = jax.tree.map(
                    lambda x: x[h_i, r_i, k], res.metrics
                )
                for name in ("completed_by_type", "missed_by_type",
                             "cancelled_by_type", "arrived_by_type"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(batched, name)),
                        np.asarray(getattr(single, name)),
                        err_msg=f"{h} rate[{r_i}] rep{k} {name}",
                    )
                for name in ("energy_dynamic", "energy_wasted",
                             "energy_idle", "makespan"):
                    assert float(getattr(batched, name)) == pytest.approx(
                        float(getattr(single, name)), rel=1e-6
                    ), f"{h} rate[{r_i}] rep{k} {name}"


def test_run_study_is_thin_consumer_of_sweep():
    """api.run_study must agree exactly with the sweep layer it wraps."""
    spec = api.paper_system()
    study = api.run_study("ELARE", [2.0, 5.0], spec, n_traces=3,
                          n_tasks=80, seed=7)
    res = experiments.run_sweep(
        experiments.SweepSpec(system=spec, rates=(2.0, 5.0), reps=3,
                              n_tasks=80, heuristics=("ELARE",), seed=7)
    )
    for r_i, sr in enumerate(study):
        np.testing.assert_array_equal(
            np.asarray(sr.metrics.completed_by_type),
            res.metrics.completed_by_type[0, r_i],
        )


# -------------------------------------------------------------------- pallas
def test_pallas_phase1_toggle_matches_jnp_path():
    spec = experiments.SweepSpec(rates=(3.0,), reps=2, n_tasks=64,
                                 heuristics=("ELARE", "FELARE"), seed=1)
    ref = experiments.run_sweep(spec)
    pal = experiments.run_sweep(
        experiments.replace(spec, use_pallas_phase1=True))
    for name in ("completed_by_type", "missed_by_type", "cancelled_by_type"):
        np.testing.assert_array_equal(getattr(ref.metrics, name),
                                      getattr(pal.metrics, name))


# ------------------------------------------------------------------ fairness
def test_felare_fairness_smoke():
    """Fixed-seed mini sweep: FELARE's suffered-type (worst per-type)
    completion rate must be >= ELARE's, with little collective loss."""
    res = experiments.run_sweep(
        experiments.SweepSpec(rates=(5.0,), reps=6, n_tasks=300,
                              heuristics=("ELARE", "FELARE"), seed=0)
    )
    by_type = res.completion_rate_by_type   # (2, 1, 4)
    worst_elare = float(by_type[0, 0].min())
    worst_felare = float(by_type[1, 0].min())
    assert worst_felare >= worst_elare
    coll = res.completion_rate_pooled
    assert float(coll[1, 0]) >= float(coll[0, 0]) - 0.05
    # spread shrinks too (the Fig. 7 reading)
    assert float(res.fairness_spread[1, 0]) <= float(
        res.fairness_spread[0, 0]) + 1e-9


# ------------------------------------------------------------------ results
def test_summary_reductions_shapes_and_sanity():
    res = experiments.run_sweep(MINI)
    H, R, K = len(MINI.heuristics), len(MINI.rates), MINI.reps
    assert res.completion_rate.shape == (H, R)
    assert res.completion_rate_ci.shape == (H, R)
    assert res.energy.shape == (H, R)
    assert res.completion_rate_by_type.shape == (H, R, 4)
    assert res.jain_index.shape == (H, R)
    assert np.all(res.completion_rate >= 0) and np.all(
        res.completion_rate <= 1)
    assert np.all(res.jain_index > 0) and np.all(res.jain_index <= 1 + 1e-9)
    assert np.all(res.energy > 0)
    # completion falls as load rises (rate 5 vs rate 2), for every heuristic
    assert np.all(res.completion_rate[:, 1] <= res.completion_rate[:, 0])


def test_metrics_for_cell_view():
    res = experiments.run_sweep(MINI)
    m = res.metrics_for("FELARE", 5.0)
    assert m.completed_by_type.shape == (MINI.reps, 4)
    with pytest.raises(ValueError):
        res.r_index(3.33)


# ---------------------------------------------------------------------- CLI
def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    result = sweep_cli.main([
        "--rates", "2,5", "--reps", "2", "--tasks", "60",
        "--heuristics", "MM,ELARE", "--out", str(out),
    ])
    csv_path = out / "sweep.csv"
    json_path = out / "sweep.json"
    assert csv_path.exists() and json_path.exists()
    lines = csv_path.read_text().splitlines()
    assert len(lines) == 1 + 2 * 2  # header + H*R rows
    assert lines[0].startswith("heuristic,rate,reps,completion_rate")
    payload = json.loads(json_path.read_text())
    assert payload["heuristics"] == ["MM", "ELARE"]
    assert payload["spec"]["reps"] == 2
    assert len(payload["summary"]) == 4
    # the returned result mirrors the artifacts
    assert result.completion_rate.shape == (2, 2)


def test_cli_list_scenarios_exits_clean(capsys):
    with pytest.raises(SystemExit) as e:
        sweep_cli.build_spec(["--list-scenarios"])
    assert e.value.code == 0
    out = capsys.readouterr().out
    assert "bursty" in out and "flash-crowd" in out and "fleets:" in out


def test_spec_validation():
    with pytest.raises(ValueError):
        experiments.SweepSpec(rates=())
    with pytest.raises(ValueError):
        experiments.SweepSpec(reps=0)
    with pytest.raises(ValueError):
        experiments.SweepSpec(system="nope").resolve_system()
    spec = experiments.SweepSpec(queue_size=4, fairness_factor=2.0)
    system = spec.resolve_system()
    assert system.queue_size == 4 and system.fairness_factor == 2.0
