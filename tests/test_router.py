"""Serving-router tests: FELARE as the live request scheduler."""
import numpy as np
import pytest

from repro.cluster.profiles import (
    FLEET,
    eet_from_roofline,
    power_vectors,
    request_cost,
)
from repro.cluster.router import Request, Router
from repro.configs import registry


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _router(heuristic="FELARE", eet=None, **kw):
    clock = FakeClock()
    if eet is None:
        eet = np.array([[1.0, 0.3], [2.0, 0.6]], np.float32)
    r = Router(eet, p_dyn=np.array([1.0, 5.0]), p_idle=np.array([0.1, 0.5]),
               heuristic=heuristic, now_fn=clock, **kw)
    return r, clock


class TestRouterLifecycle:
    def test_request_maps_and_starts(self):
        r, clock = _router()
        started = r.on_request(Request(0, 0, 0.0, deadline=10.0))
        assert len(started) == 1
        j, req = started[0]
        assert req.status == "running"
        assert j == 0  # ELARE-family picks the min-energy feasible machine

    def test_completion_updates_metrics_and_eet(self):
        r, clock = _router()
        (j, req), = r.on_request(Request(0, 0, 0.0, deadline=10.0))
        clock.t = 0.9
        r.on_completion(j, success=True, latency=0.9)
        m = r.metrics()
        assert m["completed"][0] == 1
        assert m["eet"][0, j] != pytest.approx(1.0)  # EMA moved

    def test_straggler_adaptation_shifts_routing(self):
        """A machine that keeps running slow loses traffic (EET EMA)."""
        r, clock = _router(heuristic="ELARE", eet=np.array(
            [[0.5, 0.6]], np.float32))
        # machine 0 looks best but is secretly 10x slow
        for k in range(8):
            started = r.on_request(
                Request(k, 0, clock.t, deadline=clock.t + 3.0))
            for j, req in started:
                clock.t += 5.0 if j == 0 else 0.6
                r.on_completion(j, success=(j != 0),
                                latency=5.0 if j == 0 else 0.6)
        assert r.eet[0, 0] > r.eet[0, 1]  # learned machine 0 is slow

    def test_deadline_miss_counts_missed(self):
        r, clock = _router()
        (j, req), = r.on_request(Request(0, 0, 0.0, deadline=0.5))
        clock.t = 2.0
        r.on_completion(j, success=False, latency=2.0)
        m = r.metrics()
        assert m["missed"][0] == 1
        assert m["energy_wasted"] > 0

    def test_fairness_tracking(self):
        r, clock = _router()
        for k in range(6):
            started = r.on_request(
                Request(k, k % 2, clock.t, deadline=clock.t + 8.0))
            for j, req in started:
                clock.t += 0.3
                r.on_completion(j, success=(req.task_type == 0),
                                latency=0.3)
        m = r.metrics()
        assert m["completion_rate_by_type"][0] > \
            m["completion_rate_by_type"][1]
        assert 0 < m["jain_fairness"] <= 1.0


class TestRooflineEET:
    def test_eet_from_roofline_ordering(self):
        """Bigger archs cost more everywhere; faster machines are faster."""
        cfgs = [registry.get_config("qwen1.5-0.5b"),
                registry.get_config("internlm2-1.8b")]
        eet = eet_from_roofline(cfgs)
        assert eet.shape == (2, len(FLEET))
        assert (eet[1] > eet[0]).all()          # 1.8b slower than 0.5b
        v5e4 = [m.name for m in FLEET].index("v5e-4")
        cpu = [m.name for m in FLEET].index("cpu-host")
        assert (eet[:, v5e4] < eet[:, cpu]).all()

    def test_request_cost_scales(self):
        cfg = registry.get_config("qwen1.5-0.5b")
        f1, _ = request_cost(cfg, 128)
        f2, _ = request_cost(cfg, 256)
        assert f2 == pytest.approx(2 * f1)

    def test_power_vectors(self):
        p_dyn, p_idle = power_vectors()
        assert (p_dyn > p_idle).all()


class TestRouterHeuristics:
    @pytest.mark.parametrize("h", ["FELARE", "ELARE", "MM", "MSD", "MMU"])
    def test_all_heuristics_drive_router(self, h):
        r, clock = _router(heuristic=h)
        done = 0
        for k in range(10):
            clock.t += 0.2
            started = r.on_request(
                Request(k, k % 2, clock.t, deadline=clock.t + 4.0))
            for j, req in started:
                clock.t += float(r.eet[req.task_type, j])
                r.on_completion(j, success=True,
                                latency=float(r.eet[req.task_type, j]))
                done += 1
        m = r.metrics()
        total = (m["completed"] + m["missed"] + m["cancelled"]).sum()
        pending_or_queued = m["arrived"].sum() - total
        assert pending_or_queued >= 0  # conservation
        assert m["completed"].sum() > 0
