"""Task-type mixes: which application types the arrivals carry.

Contract: ``sample(key, n_tasks, n_types)`` returns ``(N,)`` int32 type
indices in ``[0, n_types)``. The mix never sees arrival *times* — drifting
mixes key off the arrival *index* (position in the trace), which is both
fixed-shape and rate-invariant, so the CRN grid draws identical types at
every arrival rate.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.scenarios.base import component


@component("mix")
@dataclasses.dataclass(frozen=True)
class UniformMix:
    """Uniform over the task types (the paper's Sec. VI-A workload)."""

    kind: ClassVar[str] = "uniform"

    def sample(self, key, n_tasks: int, n_types: int) -> jnp.ndarray:
        return jax.random.randint(
            key, (n_tasks,), 0, n_types
        ).astype(jnp.int32)


@component("mix")
@dataclasses.dataclass(frozen=True)
class WeightedMix:
    """Fixed categorical type mix (``probs`` need not be normalized)."""

    kind: ClassVar[str] = "weighted"
    probs: Tuple[float, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "probs", tuple(float(p) for p in self.probs)
        )
        if not self.probs:
            raise ValueError("WeightedMix needs a non-empty probs tuple")
        if any(p < 0 for p in self.probs) or sum(self.probs) <= 0:
            raise ValueError(f"probs must be non-negative and sum > 0, "
                             f"got {self.probs}")

    def sample(self, key, n_tasks: int, n_types: int) -> jnp.ndarray:
        if len(self.probs) != n_types:
            raise ValueError(
                f"WeightedMix has {len(self.probs)} probs but the system "
                f"has {n_types} task types"
            )
        return jax.random.choice(
            key, n_types, (n_tasks,), p=jnp.asarray(self.probs)
        ).astype(jnp.int32)


@component("mix")
@dataclasses.dataclass(frozen=True)
class DriftMix:
    """Time-varying mix: linearly drifts from ``start`` to ``end`` probs.

    Task ``k`` of ``N`` draws from ``(1 - k/(N-1))·start + k/(N-1)·end`` —
    e.g. a workload that begins face-recognition-heavy and ends
    speech-heavy. Sampled with one ``categorical`` over an (N, S) logit
    grid: fixed shape, one key.
    """

    kind: ClassVar[str] = "drift"
    start: Tuple[float, ...] = ()
    end: Tuple[float, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "start",
                           tuple(float(p) for p in self.start))
        object.__setattr__(self, "end", tuple(float(p) for p in self.end))
        for name, probs in (("start", self.start), ("end", self.end)):
            if not probs or any(p < 0 for p in probs) or sum(probs) <= 0:
                raise ValueError(
                    f"DriftMix.{name} must be non-empty, non-negative, "
                    f"sum > 0; got {probs}"
                )
        if len(self.start) != len(self.end):
            raise ValueError("DriftMix start/end must have equal lengths")

    def sample(self, key, n_tasks: int, n_types: int) -> jnp.ndarray:
        if len(self.start) != n_types:
            raise ValueError(
                f"DriftMix has {len(self.start)} probs but the system has "
                f"{n_types} task types"
            )
        p0 = jnp.asarray(self.start, jnp.float32)
        p0 = p0 / p0.sum()
        p1 = jnp.asarray(self.end, jnp.float32)
        p1 = p1 / p1.sum()
        w = jnp.linspace(0.0, 1.0, n_tasks)[:, None]       # (N, 1)
        probs = (1.0 - w) * p0 + w * p1                    # (N, S)
        return jax.random.categorical(
            key, jnp.log(probs), axis=-1
        ).astype(jnp.int32)


def mix_from_probs(type_probs: Optional[Tuple[float, ...]]):
    """``None`` → :class:`UniformMix`, else :class:`WeightedMix` — the
    legacy ``type_probs=`` convention as a mix component."""
    if type_probs is None:
        return UniformMix()
    return WeightedMix(tuple(float(p) for p in type_probs))
