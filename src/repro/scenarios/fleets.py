"""Fleet builders: which heterogeneous edge system a scenario runs on.

A :class:`FleetBuilder` turns a handful of parameters into a full
:class:`~repro.core.types.SystemSpec` — (S, M) EET matrix, power profiles,
queue depth, fairness factor. The two paper systems are builders, and the
parameterized generators (:class:`CvbFleet`, :class:`RangeFleet`) produce
fleets of arbitrary size and heterogeneity from a seed, so heterogeneity
itself becomes a sweepable axis.

Builders are addressed by name through a registry mirroring the policy
registry; ``SweepSpec.system`` resolves any registered name (``"paper"``
and ``"aws"`` stop being special-cased string literals).
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, List, Protocol, Tuple

import jax
import numpy as np

from repro.core import eet as eet_mod
from repro.core.registry import NameRegistry
from repro.core.types import SystemSpec
from repro.scenarios.base import component


class FleetBuilder(Protocol):
    """Builds the SystemSpec a scenario simulates."""

    kind: str

    def build(self) -> SystemSpec: ...


def _sample_powers(k_dyn, k_idle, n_machines: int, p_dyn_range, p_idle_range):
    """Uniform per-machine dynamic/idle power profiles from the ranges."""
    p_dyn = np.asarray(jax.random.uniform(
        k_dyn, (n_machines,),
        minval=p_dyn_range[0], maxval=p_dyn_range[1],
    ), np.float32)
    p_idle = np.asarray(jax.random.uniform(
        k_idle, (n_machines,),
        minval=p_idle_range[0], maxval=p_idle_range[1],
    ), np.float32)
    return p_dyn, p_idle


@component("fleet")
@dataclasses.dataclass(frozen=True)
class PaperFleet:
    """The Sec. VI-A synthetic 4×4 system (Table I + power profile)."""

    kind: ClassVar[str] = "paper"
    queue_size: int = 2
    fairness_factor: float = 1.0

    def build(self) -> SystemSpec:
        from repro.core import api

        return api.paper_system(self.queue_size, self.fairness_factor)


@component("fleet")
@dataclasses.dataclass(frozen=True)
class AwsFleet:
    """The AWS 2×2 scenario: t2.xlarge/g3s.xlarge × FaceNet/DeepSpeech."""

    kind: ClassVar[str] = "aws"
    queue_size: int = 2
    fairness_factor: float = 1.0

    def build(self) -> SystemSpec:
        from repro.core import api

        return api.aws_system(self.queue_size, self.fairness_factor)


@component("fleet")
@dataclasses.dataclass(frozen=True)
class CvbFleet:
    """Coefficient-of-Variation-Based synthetic fleet of arbitrary size.

    The (S, M) EET comes from the CVB method the paper used to generate
    Table I (``eet.cvb_eet``): ``cv_task`` controls task heterogeneity,
    ``cv_mach`` machine heterogeneity. Dynamic/idle powers are uniform
    draws from the given ranges. Deterministic in ``seed``.
    """

    kind: ClassVar[str] = "cvb"
    n_task_types: int = 8
    n_machines: int = 6
    seed: int = 0
    mean_task: float = 3.0
    cv_task: float = 0.6
    cv_mach: float = 0.6
    p_dyn_range: Tuple[float, float] = (1.0, 3.0)
    p_idle_range: Tuple[float, float] = (0.03, 0.08)
    queue_size: int = 2
    fairness_factor: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "p_dyn_range",
                           tuple(float(x) for x in self.p_dyn_range))
        object.__setattr__(self, "p_idle_range",
                           tuple(float(x) for x in self.p_idle_range))
        if self.n_task_types < 1 or self.n_machines < 1:
            raise ValueError("fleet must have >= 1 task type and machine")

    def build(self) -> SystemSpec:
        # repro: allow-prng[host-side fleet synthesis from a static seed]
        key = jax.random.PRNGKey(self.seed)
        # repro: allow-prng[host-side fleet synthesis from a static seed]
        k_eet, k_dyn, k_idle = jax.random.split(key, 3)
        eet = np.asarray(eet_mod.cvb_eet(
            k_eet, self.n_task_types, self.n_machines,
            mean_task=self.mean_task, cv_task=self.cv_task,
            cv_mach=self.cv_mach,
        ))
        p_dyn, p_idle = _sample_powers(
            k_dyn, k_idle, self.n_machines,
            self.p_dyn_range, self.p_idle_range)
        return SystemSpec(eet=eet, p_dyn=p_dyn, p_idle=p_idle,
                          queue_size=self.queue_size,
                          fairness_factor=self.fairness_factor)


@component("fleet")
@dataclasses.dataclass(frozen=True)
class RangeFleet:
    """Uniform-range synthetic fleet: EET entries i.i.d. in ``eet_range``.

    The flattest possible heterogeneity model (no task/machine structure at
    all) — a useful null against :class:`CvbFleet`'s structured rows.
    Deterministic in ``seed``.
    """

    kind: ClassVar[str] = "range"
    n_task_types: int = 6
    n_machines: int = 6
    seed: int = 0
    eet_range: Tuple[float, float] = (0.5, 5.0)
    p_dyn_range: Tuple[float, float] = (1.0, 3.0)
    p_idle_range: Tuple[float, float] = (0.03, 0.08)
    queue_size: int = 2
    fairness_factor: float = 1.0

    def __post_init__(self):
        for name in ("eet_range", "p_dyn_range", "p_idle_range"):
            rng = tuple(float(x) for x in getattr(self, name))
            object.__setattr__(self, name, rng)
            if not 0 < rng[0] <= rng[1]:
                raise ValueError(f"{name} must satisfy 0 < lo <= hi, "
                                 f"got {rng}")
        if self.n_task_types < 1 or self.n_machines < 1:
            raise ValueError("fleet must have >= 1 task type and machine")

    def build(self) -> SystemSpec:
        # repro: allow-prng[host-side fleet synthesis from a static seed]
        key = jax.random.PRNGKey(self.seed)
        # repro: allow-prng[host-side fleet synthesis from a static seed]
        k_eet, k_dyn, k_idle = jax.random.split(key, 3)
        eet = np.asarray(jax.random.uniform(
            k_eet, (self.n_task_types, self.n_machines),
            minval=self.eet_range[0], maxval=self.eet_range[1],
        ), np.float32)
        p_dyn, p_idle = _sample_powers(
            k_dyn, k_idle, self.n_machines,
            self.p_dyn_range, self.p_idle_range)
        return SystemSpec(eet=eet, p_dyn=p_dyn, p_idle=p_idle,
                          queue_size=self.queue_size,
                          fairness_factor=self.fairness_factor)


# --------------------------------------------------------------------------
# Federation builders: multi-site systems for the two-level dispatch layer
# --------------------------------------------------------------------------


@component("fleet")
@dataclasses.dataclass(frozen=True)
class FederatedFleet:
    """F replicas of a registered base fleet, one per site.

    The base system's machines are tiled F times and ``site_of_machine``
    partitions the copies — ``paper_x2``/``paper_x4`` are registered
    instances replicating the Sec. VI-A 4×4 system. Every replica shares
    the base EET/power profile, so dispatch quality (not machine
    heterogeneity) is the isolated variable.
    """

    kind: ClassVar[str] = "federated"
    base: str = "paper"
    n_sites: int = 2

    def __post_init__(self):
        if self.n_sites < 1:
            raise ValueError("federation must have >= 1 site")

    def build(self) -> SystemSpec:
        spec = get_fleet(self.base).build()
        F, M = self.n_sites, spec.n_machines
        return SystemSpec(
            eet=np.tile(np.asarray(spec.eet), (1, F)),
            p_dyn=np.tile(np.asarray(spec.p_dyn), F),
            p_idle=np.tile(np.asarray(spec.p_idle), F),
            queue_size=spec.queue_size,
            fairness_factor=spec.fairness_factor,
            site_of_machine=tuple(s for s in range(F) for _ in range(M)),
        )


@component("fleet")
@dataclasses.dataclass(frozen=True)
class MixedSitesFleet:
    """Heterogeneous federation: per-site CVB-generated machine groups.

    Each site gets its own machine count and machine-heterogeneity
    coefficient (``site_machines[i]`` machines with ``cv_mach[i]``), all
    serving the same S task types — e.g. a big uniform site next to a
    small highly-heterogeneous one, the regime where EET-aware dispatch
    (``min_eet``) separates from load-blind rules. Deterministic in
    ``seed``.
    """

    kind: ClassVar[str] = "mixed_sites"
    n_task_types: int = 4
    site_machines: Tuple[int, ...] = (4, 3)
    cv_mach: Tuple[float, ...] = (0.3, 0.9)
    seed: int = 0
    mean_task: float = 3.0
    cv_task: float = 0.6
    p_dyn_range: Tuple[float, float] = (1.0, 3.0)
    p_idle_range: Tuple[float, float] = (0.03, 0.08)
    queue_size: int = 2
    fairness_factor: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "site_machines",
                           tuple(int(m) for m in self.site_machines))
        object.__setattr__(self, "cv_mach",
                           tuple(float(c) for c in self.cv_mach))
        object.__setattr__(self, "p_dyn_range",
                           tuple(float(x) for x in self.p_dyn_range))
        object.__setattr__(self, "p_idle_range",
                           tuple(float(x) for x in self.p_idle_range))
        if len(self.site_machines) != len(self.cv_mach):
            raise ValueError("site_machines and cv_mach must align per site")
        if not self.site_machines or min(self.site_machines) < 1:
            raise ValueError("every site needs >= 1 machine")

    def build(self) -> SystemSpec:
        # repro: allow-prng[host-side fleet synthesis from a static seed]
        key = jax.random.PRNGKey(self.seed)
        eet_cols, p_dyn_cols, p_idle_cols, sites = [], [], [], []
        for s, (m, cv) in enumerate(zip(self.site_machines, self.cv_mach)):
            # repro: allow-prng[per-site chain split of the static seed]
            key, k_eet, k_dyn, k_idle = jax.random.split(key, 4)
            eet_cols.append(np.asarray(eet_mod.cvb_eet(
                k_eet, self.n_task_types, m,
                mean_task=self.mean_task, cv_task=self.cv_task, cv_mach=cv,
            )))
            p_dyn, p_idle = _sample_powers(
                k_dyn, k_idle, m, self.p_dyn_range, self.p_idle_range)
            p_dyn_cols.append(p_dyn)
            p_idle_cols.append(p_idle)
            sites.extend([s] * m)
        return SystemSpec(
            eet=np.concatenate(eet_cols, axis=1),
            p_dyn=np.concatenate(p_dyn_cols),
            p_idle=np.concatenate(p_idle_cols),
            queue_size=self.queue_size,
            fairness_factor=self.fairness_factor,
            site_of_machine=tuple(sites),
        )


@component("fleet")
@dataclasses.dataclass(frozen=True)
class TieredFleet:
    """Edge-cloud hierarchy: device sites plus one cloud site.

    ``n_device_sites`` replicas of the base fleet sit on the device tier
    (tier 0) next to a single cloud site (tier 2) holding
    ``cloud_replicas`` copies of the base machines, each
    ``cloud_speedup``× faster (EET divided) and mains-powered
    (``p_idle = 0`` — the cloud's idle draw is not the edge battery's
    problem; its dynamic draw still counts toward Eq. 2, it is paid by
    *somebody*). The cloud is high-capacity and fast but — under a
    non-trivial :mod:`repro.core.network` model — slow and expensive to
    *reach*, which is exactly the trade-off ``tier_aware`` dispatch
    prices and load-blind rules ignore. ``cloud_speedup`` defaults to a
    power of two so device/cloud EETs stay exactly representable in f32
    (bit-exactness batteries depend on dyadic arithmetic).
    """

    kind: ClassVar[str] = "tiered"
    base: str = "paper"
    n_device_sites: int = 3
    cloud_replicas: int = 2
    cloud_speedup: float = 2.0

    def __post_init__(self):
        if self.n_device_sites < 1:
            raise ValueError("tiered fleet needs >= 1 device site")
        if self.cloud_replicas < 1:
            raise ValueError("tiered fleet needs >= 1 cloud replica")
        if float(self.cloud_speedup) <= 0.0:
            raise ValueError("cloud_speedup must be > 0")

    def build(self) -> SystemSpec:
        spec = get_fleet(self.base).build()
        D, C, M = self.n_device_sites, self.cloud_replicas, spec.n_machines
        eet = np.asarray(spec.eet, np.float32)
        cloud_eet = (np.tile(eet, (1, C))
                     / np.float32(self.cloud_speedup)).astype(np.float32)
        sites = [s for s in range(D) for _ in range(M)] + [D] * (C * M)
        return SystemSpec(
            eet=np.concatenate([np.tile(eet, (1, D)), cloud_eet], axis=1),
            p_dyn=np.concatenate([np.tile(np.asarray(spec.p_dyn), D),
                                  np.tile(np.asarray(spec.p_dyn), C)]),
            p_idle=np.concatenate([np.tile(np.asarray(spec.p_idle), D),
                                   np.zeros((C * M,), np.float32)]),
            queue_size=spec.queue_size,
            fairness_factor=spec.fairness_factor,
            site_of_machine=tuple(sites),
            tier_of_site=(0,) * D + (2,),
        )


# --------------------------------------------------------------------------
# Fleet registry (shared NameRegistry mechanics, like policies/scenarios)
# --------------------------------------------------------------------------


def _check(name, fleet) -> None:
    if not hasattr(fleet, "build"):
        raise TypeError(f"fleet {name!r} must have a .build() method")


_REGISTRY = NameRegistry("fleet", case=str.lower, check=_check)


def register_fleet(name: str, fleet: FleetBuilder, *,
                   overwrite: bool = False) -> FleetBuilder:
    """Register a fleet builder under ``name`` (case-insensitive)."""
    return _REGISTRY.register(name, fleet, overwrite=overwrite)


def unregister_fleet(name: str) -> None:
    """Remove a registered fleet builder (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered_fleet(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get_fleet(name: str) -> FleetBuilder:
    """Resolve a fleet builder by (case-insensitive) name."""
    return _REGISTRY.get(name)


def list_fleets() -> List[str]:
    """Sorted names of every registered fleet builder."""
    return _REGISTRY.names()


for _name, _fleet in [
    ("paper", PaperFleet()),
    ("aws", AwsFleet()),
    ("cvb", CvbFleet()),
    ("range", RangeFleet()),
    ("paper_x2", FederatedFleet(base="paper", n_sites=2)),
    ("paper_x4", FederatedFleet(base="paper", n_sites=4)),
    ("paper_x8", FederatedFleet(base="paper", n_sites=8)),
    ("paper_x32", FederatedFleet(base="paper", n_sites=32)),
    ("mixed_sites", MixedSitesFleet()),
    ("tiered_x4", TieredFleet(n_device_sites=3)),
    ("tiered_x16", TieredFleet(n_device_sites=15)),
]:
    register_fleet(_name, _fleet)
del _name, _fleet
