"""Runtime models: how actual execution times scatter around the EET.

Contract: ``sample(key, eet, task_type, cv_run)`` returns ``(N, M)``
float32 actual runtimes whose row means track ``eet[task_type]``.
``cv_run`` is the sweep-level dispersion (``SweepSpec.cv_run``); models
with their own dispersion parameters ignore it.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import eet as eet_mod
from repro.scenarios.base import component


@component("runtime")
@dataclasses.dataclass(frozen=True)
class GammaRuntimes:
    """Gamma-distributed runtimes around the EET (the paper's model).

    ``cv=None`` defers to the sweep-level ``cv_run`` and delegates to
    ``eet.sample_actual_exec`` — byte-identical to the pre-scenario path.
    ``cv_by_type`` instead gives each task type its own CV (e.g. a stable
    vision model next to a high-variance speech model); it overrides both.
    """

    kind: ClassVar[str] = "gamma"
    cv: Optional[float] = None
    cv_by_type: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.cv_by_type is not None:
            object.__setattr__(
                self, "cv_by_type",
                tuple(float(c) for c in self.cv_by_type),
            )
            if any(c <= 0 for c in self.cv_by_type):
                raise ValueError("cv_by_type entries must be positive")
        if self.cv is not None and not self.cv > 0:
            raise ValueError("cv must be positive")

    def sample(self, key, eet, task_type, cv_run) -> jnp.ndarray:
        eet = jnp.asarray(eet)
        if self.cv_by_type is None:
            cv = self.cv if self.cv is not None else cv_run
            return eet_mod.sample_actual_exec(key, eet, task_type, cv)
        cvs = jnp.asarray(self.cv_by_type, jnp.float32)
        if cvs.shape[0] != eet.shape[0]:
            raise ValueError(
                f"cv_by_type has {cvs.shape[0]} entries but the system "
                f"has {eet.shape[0]} task types"
            )
        means = eet[task_type]                       # (N, M)
        cv_k = cvs[task_type][:, None]               # (N, 1)
        shape = 1.0 / cv_k**2
        draw = jax.random.gamma(key, jnp.broadcast_to(shape, means.shape))
        return (draw * (means * cv_k**2)).astype(jnp.float32)


@component("runtime")
@dataclasses.dataclass(frozen=True)
class LognormalRuntimes:
    """Heavy-tailed lognormal runtimes, mean-preserving around the EET.

    ``X = EET · exp(σZ − σ²/2)`` with ``Z ~ N(0, 1)``: E[X] = EET exactly,
    but the right tail is far heavier than the Gamma model's — stragglers
    that blow through deadlines even on the right machine.
    """

    kind: ClassVar[str] = "lognormal"
    sigma: float = 0.6

    def __post_init__(self):
        if not self.sigma > 0:
            raise ValueError("sigma must be positive")

    def sample(self, key, eet, task_type, cv_run) -> jnp.ndarray:
        del cv_run  # dispersion is governed by sigma
        eet = jnp.asarray(eet)
        means = eet[task_type]                       # (N, M)
        z = jax.random.normal(key, means.shape)
        return (
            means * jnp.exp(self.sigma * z - 0.5 * self.sigma**2)
        ).astype(jnp.float32)
