"""Mutable, case-insensitive scenario registry.

Scenarios are addressed by name everywhere — ``SweepSpec.scenario``, the
sweep CLI's ``--scenario``, ``trace_stack`` — so registering a composition
here makes it flow through the entire one-jit sweep machinery untouched:

    from repro import scenarios

    rush_hour = scenarios.Scenario(
        scenarios.MMPPArrivals(rate_ratio=12.0),
        scenarios.WeightedMix((0.5, 0.2, 0.2, 0.1)),
        scenarios.ScaledDeadlines(0.8),
        scenarios.GammaRuntimes(),
    )
    scenarios.register("rush-hour", rush_hour)
    # ... SweepSpec(scenario="rush-hour") now just works.

The mechanics live in the shared
:class:`repro.core.registry.NameRegistry` (also behind the policy and
fleet registries).
"""
from __future__ import annotations

from typing import List

from repro.core.registry import NameRegistry
from repro.scenarios.base import Scenario


def _check(name, scenario) -> None:
    if not isinstance(scenario, Scenario):
        raise TypeError(
            f"scenario {name!r} must be a Scenario, got {scenario!r}"
        )


_REGISTRY = NameRegistry("scenario", case=str.lower, check=_check)


def register(name: str, scenario: Scenario, *,
             overwrite: bool = False) -> Scenario:
    """Register ``scenario`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True``.
    Returns the scenario, so registration can be used expression-style.
    """
    return _REGISTRY.register(name, scenario, overwrite=overwrite)


def unregister(name: str) -> None:
    """Remove a registered scenario (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get(name: str) -> Scenario:
    """Resolve a scenario by (case-insensitive) name."""
    return _REGISTRY.get(name)


def list_scenarios() -> List[str]:
    """Sorted names of every registered scenario."""
    return _REGISTRY.names()
