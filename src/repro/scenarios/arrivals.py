"""Arrival processes: when tasks hit the edge system.

All processes share one contract: ``sample(key, n_tasks, rate)`` returns
``(N,)`` sorted, non-negative float32 arrival times whose *nominal* rate is
``rate`` tasks/sec, computed with fixed-shape JAX only. Non-stationary
processes are built by inverse-transform: draw a unit-rate Poisson stream
``Γ_k = cumsum(Exp(1))`` once, then map it through the inverse of the
integrated rate ``Λ(t) = ∫₀ᵗ λ(s) ds`` — closed-form where possible,
a fixed number of Newton steps otherwise. No rejection, no data-dependent
shapes, so every process runs inside the single-jit vmapped sweep.

Time-scale convention: non-stationary structure (burst dwell, diurnal
period, spike window) is parameterized as *fractions of the nominal
horizon* ``n_tasks / rate``, so a scenario means the same thing at every
arrival rate and the CRN trace grid stays comparable across the rate axis.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.scenarios.base import component

_NEWTON_ITERS = 20  # fixed-count inversion of the integrated rate


@component("arrivals")
@dataclasses.dataclass(frozen=True)
class PoissonArrivals:
    """Stationary Poisson arrivals (the paper's Sec. VI-A workload)."""

    kind: ClassVar[str] = "poisson"

    def sample(self, key, n_tasks: int, rate) -> jnp.ndarray:
        gaps = jax.random.exponential(key, (n_tasks,)) / rate
        return jnp.cumsum(gaps).astype(jnp.float32)


@component("arrivals")
@dataclasses.dataclass(frozen=True)
class MMPPArrivals:
    """Bursty 2-phase Markov-modulated Poisson process (on–off).

    A sticky two-state Markov chain over arrivals switches between a quiet
    phase and a burst phase whose rate is ``rate_ratio``× higher; phase
    rates are normalized so the long-run mean arrival rate equals the
    nominal ``rate``. ``p_stay`` controls dwell (expected burst run length
    ``1 / (1 - p_stay)`` arrivals), ``burst_frac`` the stationary fraction
    of arrivals emitted in the burst phase. Inter-arrival CV² exceeds the
    Poisson process's 1 — the burstiness the property tests pin.
    """

    kind: ClassVar[str] = "mmpp"
    rate_ratio: float = 8.0
    p_stay: float = 0.95
    burst_frac: float = 0.3

    def __post_init__(self):
        if not self.rate_ratio > 1.0:
            raise ValueError("rate_ratio must be > 1 (burst faster than quiet)")
        if not 0.0 < self.burst_frac < 1.0:
            raise ValueError("burst_frac must be in (0, 1)")
        if not 0.0 <= self.p_stay < 1.0:
            raise ValueError("p_stay must be in [0, 1)")
        # Joint feasibility: detailed balance fixes the quiet-phase exit
        # probability at (1 - p_stay) * burst_frac / (1 - burst_frac); if
        # that exceeds 1 the chain cannot realize the assumed stationary
        # distribution and the nominal-rate normalization silently breaks.
        q_qb = (1.0 - self.p_stay) * self.burst_frac / (1.0 - self.burst_frac)
        if q_qb > 1.0:
            raise ValueError(
                f"infeasible MMPP: quiet-phase exit probability "
                f"(1 - p_stay) * burst_frac / (1 - burst_frac) = "
                f"{q_qb:.3f} > 1; increase p_stay or lower burst_frac"
            )

    def sample(self, key, n_tasks: int, rate) -> jnp.ndarray:
        # repro: allow-prng[component-local fan-out of the arrival subkey]
        k_exp, k_switch, k_init = jax.random.split(key, 3)
        e = jax.random.exponential(k_exp, (n_tasks,))
        u = jax.random.uniform(k_switch, (n_tasks,))
        pi_b = self.burst_frac
        pi_q = 1.0 - pi_b
        # Exit probabilities with the stationary distribution (pi_q, pi_b):
        # detailed balance pi_b * q_bq == pi_q * q_qb.
        q_bq = 1.0 - self.p_stay
        q_qb = q_bq * pi_b / pi_q
        init_burst = jax.random.uniform(k_init, ()) < pi_b

        def step(burst, u_k):
            switch = jnp.where(burst, u_k < q_bq, u_k < q_qb)
            burst = jnp.logical_xor(burst, switch)
            return burst, burst

        _, burst = jax.lax.scan(step, init_burst, u)
        # Quiet-phase rate such that E[gap] = pi_q/r_q + pi_b/r_b = 1/rate.
        r_quiet = rate * (pi_q + pi_b / self.rate_ratio)
        rate_k = jnp.where(burst, self.rate_ratio * r_quiet, r_quiet)
        return jnp.cumsum(e / rate_k).astype(jnp.float32)


@component("arrivals")
@dataclasses.dataclass(frozen=True)
class DiurnalArrivals:
    """Sinusoidal-rate arrivals: λ(t) = rate · (1 + a·sin(2πt/P)).

    The period ``P`` spans ``1/cycles`` of the nominal horizon
    ``n_tasks / rate``, so a trace sees ``cycles`` full day/night swings at
    any arrival rate. Sampled by time-rescaling: a unit-rate Poisson stream
    is pushed through Λ⁻¹ with a fixed number of Newton iterations (Λ is
    strictly increasing for ``|a| < 1``), then ``cummax`` re-asserts
    monotonicity against the last float32 ulp of Newton residue.
    """

    kind: ClassVar[str] = "diurnal"
    amplitude: float = 0.8
    cycles: float = 4.0

    def __post_init__(self):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1) so λ(t) > 0")
        if not self.cycles > 0:
            raise ValueError("cycles must be positive")

    def sample(self, key, n_tasks: int, rate) -> jnp.ndarray:
        gam = jnp.cumsum(jax.random.exponential(key, (n_tasks,)))
        a = self.amplitude
        period = n_tasks / (rate * self.cycles)
        w = 2.0 * jnp.pi / period

        def big_lambda(t):
            return rate * t + rate * a / w * (1.0 - jnp.cos(w * t))

        def small_lambda(t):
            return rate * (1.0 + a * jnp.sin(w * t))

        t = gam / rate  # stationary-Poisson initial guess
        for _ in range(_NEWTON_ITERS):
            t = t - (big_lambda(t) - gam) / small_lambda(t)
        t = jax.lax.cummax(jnp.maximum(t, 0.0))
        return t.astype(jnp.float32)


@component("arrivals")
@dataclasses.dataclass(frozen=True)
class FlashCrowdArrivals:
    """Baseline Poisson with a flash-crowd spike window.

    The rate is ``rate`` everywhere except ``spike_mult × rate`` inside the
    window ``[spike_start, spike_start + spike_frac]`` (fractions of the
    nominal horizon ``n_tasks / rate``). The piecewise-linear integrated
    rate inverts in closed form — three ``where`` branches, fixed shape.
    """

    kind: ClassVar[str] = "flash-crowd"
    spike_start: float = 0.4
    spike_frac: float = 0.15
    spike_mult: float = 6.0

    def __post_init__(self):
        if not 0.0 <= self.spike_start < 1.0:
            raise ValueError("spike_start must be in [0, 1)")
        if not self.spike_frac > 0:
            raise ValueError("spike_frac must be positive")
        if not self.spike_mult >= 1.0:
            raise ValueError("spike_mult must be >= 1")

    def sample(self, key, n_tasks: int, rate) -> jnp.ndarray:
        gam = jnp.cumsum(jax.random.exponential(key, (n_tasks,)))
        horizon = n_tasks / rate
        t0 = self.spike_start * horizon
        dur = self.spike_frac * horizon
        mult = self.spike_mult
        g0 = rate * t0                       # Λ mass before the spike
        g1 = g0 + rate * mult * dur          # Λ mass through the spike
        t_pre = gam / rate
        t_in = t0 + (gam - g0) / (rate * mult)
        t_post = t0 + dur + (gam - g1) / rate
        t = jnp.where(gam <= g0, t_pre, jnp.where(gam <= g1, t_in, t_post))
        return t.astype(jnp.float32)
