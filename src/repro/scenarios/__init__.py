"""Composable workload-scenario API.

The paper evaluates under exactly one workload shape — stationary Poisson
arrivals, uniform types, Eq. 4 deadlines, Gamma runtimes — on two fixed
systems. This package turns each of those axes into a swappable component
behind one typed surface, mirroring the policy algebra:

    Scenario = ArrivalProcess × TypeMix × DeadlineModel × RuntimeModel
               [× FleetBuilder]

Every component is fixed-shape JAX, so any scenario drops into the
single-jit vmapped sweep unchanged. Built-in scenarios are registered by
name in a mutable, case-insensitive registry consumed by ``SweepSpec``,
``run_sweep``, ``trace_stack``, and the sweep CLI (``--scenario`` /
``--list-scenarios``); fleet builders get a parallel registry behind
``SweepSpec.system``. See ``docs/scenarios.md`` for the component table.
"""
from __future__ import annotations

from repro.scenarios.arrivals import (
    DiurnalArrivals,
    FlashCrowdArrivals,
    MMPPArrivals,
    PoissonArrivals,
)
from repro.scenarios.base import (
    ArrivalProcess,
    DeadlineModel,
    RuntimeModel,
    Scenario,
    TypeMix,
    component,
    component_from_json,
    component_to_json,
    replace,
)
from repro.scenarios.deadlines import PaperDeadlines, ScaledDeadlines
from repro.scenarios.fleets import (
    AwsFleet,
    CvbFleet,
    FederatedFleet,
    FleetBuilder,
    MixedSitesFleet,
    PaperFleet,
    RangeFleet,
    get_fleet,
    is_registered_fleet,
    list_fleets,
    register_fleet,
    unregister_fleet,
)
from repro.scenarios.mixes import DriftMix, UniformMix, WeightedMix, mix_from_probs
from repro.scenarios.registry import (
    get,
    is_registered,
    list_scenarios,
    register,
    unregister,
)
from repro.scenarios.runtimes import GammaRuntimes, LognormalRuntimes

__all__ = [
    "ArrivalProcess",
    "AwsFleet",
    "CvbFleet",
    "DEFAULT",
    "DeadlineModel",
    "DiurnalArrivals",
    "DriftMix",
    "FederatedFleet",
    "FlashCrowdArrivals",
    "FleetBuilder",
    "MixedSitesFleet",
    "GammaRuntimes",
    "LognormalRuntimes",
    "MMPPArrivals",
    "PaperDeadlines",
    "PaperFleet",
    "PoissonArrivals",
    "RangeFleet",
    "RuntimeModel",
    "ScaledDeadlines",
    "Scenario",
    "TypeMix",
    "UniformMix",
    "WeightedMix",
    "component",
    "component_from_json",
    "component_to_json",
    "default_scenario",
    "get",
    "get_fleet",
    "is_registered",
    "is_registered_fleet",
    "list_fleets",
    "list_scenarios",
    "mix_from_probs",
    "register",
    "register_fleet",
    "replace",
    "unregister",
    "unregister_fleet",
]


# --------------------------------------------------------------------------
# Built-in scenarios (Sec. VI-A default + the stress axes related work
# highlights: burstiness, non-stationarity, mix drift, runtime tails,
# deadline tightness, fleet heterogeneity).
# --------------------------------------------------------------------------

#: The paper's workload, byte-identical to the pre-scenario synthesis path.
DEFAULT = Scenario(PoissonArrivals(), UniformMix(), PaperDeadlines(),
                   GammaRuntimes())

# A 4-type drift (vision-heavy -> speech-heavy) for the paper-sized fleets.
_DRIFT_4 = DriftMix(start=(0.4, 0.3, 0.2, 0.1), end=(0.1, 0.2, 0.3, 0.4))

for _name, _scn in [
    ("poisson", DEFAULT),
    ("bursty", Scenario(MMPPArrivals(), UniformMix(), PaperDeadlines(),
                        GammaRuntimes())),
    ("diurnal", Scenario(DiurnalArrivals(), UniformMix(), PaperDeadlines(),
                         GammaRuntimes())),
    ("flash-crowd", Scenario(FlashCrowdArrivals(), UniformMix(),
                             PaperDeadlines(), GammaRuntimes())),
    ("heavy-tail", Scenario(PoissonArrivals(), UniformMix(),
                            PaperDeadlines(), LognormalRuntimes())),
    ("drift", Scenario(PoissonArrivals(), _DRIFT_4, PaperDeadlines(),
                       GammaRuntimes())),
    ("tight-deadlines", Scenario(PoissonArrivals(), UniformMix(),
                                 ScaledDeadlines(0.75), GammaRuntimes())),
    ("bursty-heavy-tail", Scenario(MMPPArrivals(), UniformMix(),
                                   PaperDeadlines(), LognormalRuntimes())),
    ("wide-fleet", Scenario(PoissonArrivals(), UniformMix(),
                            PaperDeadlines(), GammaRuntimes(),
                            fleet=CvbFleet(n_task_types=8, n_machines=6))),
    # Federation stress: 2-site paper replica under a skewed type mix.
    # With the type-affine sticky dispatcher (dispatch.Sticky(by_type=True))
    # the skewed mix becomes per-site arrival skew — one site drowning
    # while the other idles, the regime fair_spill/least_queued target.
    ("federated-skew", Scenario(PoissonArrivals(),
                                WeightedMix((0.55, 0.25, 0.12, 0.08)),
                                PaperDeadlines(), GammaRuntimes(),
                                fleet=FederatedFleet(base="paper",
                                                     n_sites=2))),
]:
    register(_name, _scn)
del _name, _scn


def default_scenario() -> Scenario:
    """The paper's Poisson workload — what ``scenario='poisson'`` resolves
    to, and what the legacy ``poisson_trace``/``trace_stack`` wrap."""
    return DEFAULT
