"""The composable workload-scenario algebra.

A :class:`Scenario` is to workload synthesis what
:class:`~repro.core.policy.base.TwoPhasePolicy` is to mapping policies —
a frozen composition of small, independently swappable pieces:

  * :class:`ArrivalProcess` — *when* tasks arrive (stationary Poisson,
    bursty MMPP, diurnal sinusoidal-rate, flash-crowd spike, ...).
  * :class:`TypeMix` — *which* task types arrive (uniform, weighted,
    time-varying drift).
  * :class:`DeadlineModel` — how deadlines follow from arrivals (Eq. 4 and
    tightness-scaled variants).
  * :class:`RuntimeModel` — how actual runtimes scatter around the EET
    (Gamma with scalar or per-type CV, heavy-tail lognormal).
  * :class:`~repro.scenarios.fleets.FleetBuilder` (optional) — which
    system the scenario is *meant* to run on; ``None`` defers to the
    caller's system choice.

Every component is fixed-shape JAX: sampling is inverse-transform over a
pre-drawn ``(N,)`` block of randomness (Newton inversion of the integrated
rate for non-stationary processes), never rejection with data-dependent
shapes. That keeps a :class:`Scenario` usable inside ``vmap`` + one
``jax.jit`` — the single-dispatch sweep design of ``repro.experiments``
works for every scenario, not just the paper's Poisson default.

Components are frozen dataclasses with a ``kind`` class attribute, so a
scenario is hashable (jit can close over it statically) and serializes to
JSON by recording each component's kind + parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core.types import Trace

# --------------------------------------------------------------------------
# Component protocols
# --------------------------------------------------------------------------


class ArrivalProcess(Protocol):
    """Samples N sorted, non-negative arrival times at a nominal rate."""

    kind: str

    def sample(self, key, n_tasks: int, rate) -> jnp.ndarray: ...


class TypeMix(Protocol):
    """Samples N task-type indices in ``[0, n_types)``."""

    kind: str

    def sample(self, key, n_tasks: int, n_types: int) -> jnp.ndarray: ...


class DeadlineModel(Protocol):
    """Maps (arrival, task_type, eet) to per-task deadlines."""

    kind: str

    def deadlines(self, arrival, task_type, eet) -> jnp.ndarray: ...


class RuntimeModel(Protocol):
    """Samples (N, M) actual runtimes around the EET rows.

    ``cv_run`` is the sweep-level coefficient of variation
    (``SweepSpec.cv_run``); models with their own dispersion parameters are
    free to ignore it.
    """

    kind: str

    def sample(self, key, eet, task_type, cv_run) -> jnp.ndarray: ...


# --------------------------------------------------------------------------
# Component (de)serialization: kind-keyed class registry
# --------------------------------------------------------------------------

_COMPONENTS: Dict[Tuple[str, str], Type] = {}


def component(category: str):
    """Class decorator registering a component for JSON round-tripping.

    ``category`` is the Scenario field family (``"arrivals"``, ``"mix"``,
    ``"deadline"``, ``"runtime"``, ``"fleet"``); together with the class's
    ``kind`` it keys the class for :func:`component_from_json`.
    """

    def deco(cls):
        key = (category, cls.kind)
        if key in _COMPONENTS and _COMPONENTS[key] is not cls:
            raise ValueError(f"duplicate component kind {key!r}")
        _COMPONENTS[key] = cls
        return cls

    return deco


def component_to_json(comp) -> dict:
    """``{"kind": ..., <param>: ...}`` for a registered component."""
    out = {"kind": comp.kind}
    for f in dataclasses.fields(comp):
        v = getattr(comp, f.name)
        out[f.name] = list(v) if isinstance(v, tuple) else v
    return out


def component_from_json(category: str, d: dict):
    """Inverse of :func:`component_to_json` (tuples restored from lists)."""
    try:
        cls = _COMPONENTS[(category, d["kind"])]
    except KeyError:
        known = sorted(k for c, k in _COMPONENTS if c == category)
        raise ValueError(
            f"unknown {category} component kind {d.get('kind')!r}; "
            f"choose from {known}"
        ) from None
    kwargs = {
        k: tuple(v) if isinstance(v, list) else v
        for k, v in d.items() if k != "kind"
    }
    return cls(**kwargs)


# --------------------------------------------------------------------------
# Scenario
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """arrivals × mix × deadline × runtime [× fleet] — one workload recipe.

    Frozen and hashable, so jit can specialize on a scenario the same way
    it specializes on a policy, and ``SweepSpec`` (itself frozen) can embed
    one directly.

    Attributes:
      arrivals: the :class:`ArrivalProcess`.
      mix: the :class:`TypeMix`.
      deadline: the :class:`DeadlineModel`.
      runtime: the :class:`RuntimeModel`.
      fleet: optional :class:`~repro.scenarios.fleets.FleetBuilder` naming
        the system this scenario is designed for. ``None`` (the default)
        means "whatever system the spec chose" — scenarios that only vary
        the workload leave it unset.
    """

    arrivals: ArrivalProcess
    mix: TypeMix
    deadline: DeadlineModel
    runtime: RuntimeModel
    fleet: Optional[object] = None  # FleetBuilder; typed loosely to avoid a cycle

    def sample_trace(self, key, n_tasks: int, rate, eet, *,
                     cv_run: float = 0.1, n_task_types=None) -> Trace:
        """Synthesize one workload trace under this scenario.

        The key-split discipline (one 3-way split: arrivals, types,
        runtimes) is pinned: the default Poisson scenario reproduces the
        pre-scenario-API ``poisson_trace`` byte-for-byte under the same
        key (see ``tests/test_scenario_regression.py``).
        """
        eet = jnp.asarray(eet)
        if n_task_types is None:
            n_task_types = eet.shape[0]
        # repro: allow-prng[pinned CRN fan-out of the caller's trace key]
        k_arr, k_type, k_exec = jax.random.split(key, 3)
        arrival = self.arrivals.sample(k_arr, n_tasks, rate)
        task_type = self.mix.sample(k_type, n_tasks, n_task_types)
        deadline = self.deadline.deadlines(arrival, task_type, eet)
        exec_actual = self.runtime.sample(k_exec, eet, task_type, cv_run)
        return Trace(arrival, task_type, deadline, exec_actual)

    def stack(self, key, rates, reps: int, n_tasks: int, eet, *,
              cv_run: float = 0.1, n_task_types=None) -> Trace:
        """The full (R rates × K replicates) CRN trace grid under one key.

        Replicate ``k`` reuses the same subkey at every rate (common random
        numbers): type and runtime draws are rate-independent by
        construction (the rate only enters the arrival process), so the
        sweep's rate axis stays paired for every scenario.

        Returns a Trace whose leaves carry leading dims (R, K).
        """
        # repro: allow-prng[per-replicate CRN split; rate axis reuses keys]
        rep_keys = jax.random.split(key, reps)                    # (K, 2)
        rates_arr = jnp.asarray(rates, jnp.float32)               # (R,)

        def one(rate, k):
            return self.sample_trace(k, n_tasks, rate, eet, cv_run=cv_run,
                                     n_task_types=n_task_types)

        over_reps = jax.vmap(one, in_axes=(None, 0))              # (K, ...)
        return jax.vmap(over_reps, in_axes=(0, None))(rates_arr, rep_keys)

    # -- introspection / serialization -------------------------------------
    def describe(self) -> dict:
        """Component kinds by field, for ``--list-scenarios`` output."""
        return {
            "arrivals": self.arrivals.kind,
            "mix": self.mix.kind,
            "deadline": self.deadline.kind,
            "runtime": self.runtime.kind,
            "fleet": self.fleet.kind if self.fleet is not None else "-",
        }

    def to_json_dict(self) -> dict:
        return {
            "arrivals": component_to_json(self.arrivals),
            "mix": component_to_json(self.mix),
            "deadline": component_to_json(self.deadline),
            "runtime": component_to_json(self.runtime),
            "fleet": (component_to_json(self.fleet)
                      if self.fleet is not None else None),
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> "Scenario":
        return cls(
            arrivals=component_from_json("arrivals", d["arrivals"]),
            mix=component_from_json("mix", d["mix"]),
            deadline=component_from_json("deadline", d["deadline"]),
            runtime=component_from_json("runtime", d["runtime"]),
            fleet=(component_from_json("fleet", d["fleet"])
                   if d.get("fleet") is not None else None),
        )


def replace(scenario: Scenario, **kwargs) -> Scenario:
    """``dataclasses.replace`` re-exported for fluent scenario tweaking."""
    return dataclasses.replace(scenario, **kwargs)
