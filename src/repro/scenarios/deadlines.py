"""Deadline models: how deadlines follow from arrivals.

Contract: ``deadlines(arrival, task_type, eet)`` returns ``(N,)`` float32
absolute deadlines, strictly after the arrivals for sensible parameters.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax.numpy as jnp

from repro.core import equations
from repro.scenarios.base import component


@component("deadline")
@dataclasses.dataclass(frozen=True)
class PaperDeadlines:
    """Eq. 4 verbatim: δ_k = arr_k + ē_i + ē."""

    kind: ClassVar[str] = "paper"

    def deadlines(self, arrival, task_type, eet) -> jnp.ndarray:
        return equations.deadlines(arrival, task_type, eet)


@component("deadline")
@dataclasses.dataclass(frozen=True)
class ScaledDeadlines:
    """Eq. 4 with a tightness knob: δ_k = arr_k + tightness · (ē_i + ē).

    ``tightness=1`` reproduces :class:`PaperDeadlines`; ``< 1`` squeezes
    the slack (harder traces — the regime where proactive dropping pays),
    ``> 1`` relaxes it.
    """

    kind: ClassVar[str] = "scaled"
    tightness: float = 0.75

    def __post_init__(self):
        if not self.tightness > 0:
            raise ValueError("tightness must be positive")

    def deadlines(self, arrival, task_type, eet) -> jnp.ndarray:
        arrival = jnp.asarray(arrival, jnp.float32)
        # Eq. 4 at arrival 0 is exactly the slack term e_bar_i + e_bar.
        slack = equations.deadlines(jnp.zeros_like(arrival), task_type, eet)
        return arrival + self.tightness * slack
