"""AdamW with decoupled weight decay, global-norm clipping, bf16-param /
fp32-moment layout (built from scratch; no optax in this environment)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | None = 3e-4        # None -> schedule fn required at update
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params, lr=None):
        lr = self.lr if lr is None else lr
        step = state.step + 1

        # global-norm clip (fp32)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g * g) for g in jax.tree.leaves(g32)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        g32 = jax.tree.map(lambda g: g * scale, g32)

        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, g32)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, g32)

        def upd(p, m, v):
            mh = m / b1c
            vh = v / b2c
            u = mh / (jnp.sqrt(vh) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), gnorm
