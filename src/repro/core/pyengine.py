"""Independent pure-Python oracle simulator.

Deliberately written with plain loops and numpy (no shared code with the JAX
engine beyond the dataclasses) so hypothesis property tests can cross-check
the vectorized `repro.core.engine` implementation event-by-event.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import (
    CANCELLED,
    COMPLETED,
    MISSED,
    PENDING,
    QUEUED,
    RUNNING,
    UNARRIVED,
)

BIG = 1e30


class _Machine:
    def __init__(self, j):
        self.j = j
        self.run = -1
        self.run_start = 0.0
        self.run_end_act = np.inf
        self.run_end_exp = 0.0
        self.run_success = False
        self.queue: list[int] = []
        self.busy = 0.0


def _completion(s, e, d):
    if s + e <= d:
        return s + e
    if s < d:
        return d
    return s


def _energy(s, e, d, p):
    if s + e <= d:
        return p * e
    if s < d:
        return p * (d - s)
    return 0.0


def simulate(trace, spec, heuristic: str):
    """Run one trace; returns a dict mirroring Metrics."""
    heuristic = heuristic.upper()
    eet = np.asarray(spec.eet, np.float64)
    p_dyn = np.asarray(spec.p_dyn, np.float64)
    p_idle = np.asarray(spec.p_idle, np.float64)
    S, M = eet.shape
    Q = spec.queue_size
    f = spec.fairness_factor

    arr = np.asarray(trace.arrival, np.float64)
    ttype = np.asarray(trace.task_type)
    dl = np.asarray(trace.deadline, np.float64)
    exec_act = np.asarray(trace.exec_actual, np.float64)
    n = len(arr)

    status = np.full(n, UNARRIVED)
    machines = [_Machine(j) for j in range(M)]
    completed = np.zeros(S, int)
    missed = np.zeros(S, int)
    cancelled = np.zeros(S, int)
    arrived = np.zeros(S, int)
    e_dyn = 0.0
    e_wasted = 0.0
    now = 0.0

    def next_event():
        ts = [arr[k] for k in range(n) if status[k] == UNARRIVED]
        ts += [m.run_end_act for m in machines if m.run >= 0]
        ts += [dl[k] for k in range(n) if status[k] == PENDING]
        return min(ts) if ts else np.inf

    def avail_base(m):
        return max(now, m.run_end_exp if m.run >= 0 else now)

    def avail(m):
        return avail_base(m) + sum(eet[ttype[k], m.j] for k in m.queue)

    def suffered_mask():
        cr = np.where(arrived > 0, completed / np.maximum(arrived, 1), 1.0)
        eps = max(cr.mean() - f * cr.std(), 0.0)
        return (cr <= eps) & (arrived >= 1)

    def phase2(pairs, machines_free):
        """pairs: list of (task, machine, key). One task per machine, min key."""
        assign = {}
        for j in machines_free:
            cand = [(key, k) for (k, jj, key) in pairs if jj == j]
            if cand:
                key, k = min(cand)
                assign[j] = k
        # a task may not be assigned twice (cannot happen: each task appears
        # with exactly one machine in `pairs`)
        return assign

    def mapping_event():
        nonlocal status
        pend = [k for k in range(n) if status[k] == PENDING]
        free = [j for j in range(M) if len(machines[j].queue) < Q]
        suffered = suffered_mask()

        # stale purge (all heuristics)
        for k in list(pend):
            if now >= dl[k]:
                status[k] = CANCELLED
                cancelled[ttype[k]] += 1
                pend.remove(k)

        if heuristic in ("ELARE", "FELARE"):
            # hopeless proactive drop
            for k in list(pend):
                if now + eet[ttype[k]].min() > dl[k]:
                    status[k] = CANCELLED
                    cancelled[ttype[k]] += 1
                    pend.remove(k)

        if heuristic == "FELARE":
            # queue eviction for the earliest-deadline rescuable suffered task
            resc = [
                k for k in pend
                if suffered[ttype[k]]
                and not any(
                    avail(machines[j]) + eet[ttype[k], j] <= dl[k]
                    for j in range(M) if len(machines[j].queue) < Q
                )
                and now + eet[ttype[k]].min() <= dl[k]
            ]
            if resc:
                k = min(resc, key=lambda k: dl[k])
                mstar = min(
                    range(M),
                    key=lambda j: avail(machines[j]) + eet[ttype[k], j],
                )
                m = machines[mstar]
                evict = []
                base = avail_base(m)
                rem = sum(eet[ttype[t], mstar] for t in m.queue)
                for qi in range(len(m.queue) - 1, -1, -1):
                    t = m.queue[qi]
                    if base + rem + eet[ttype[k], mstar] <= dl[k]:
                        break
                    if not suffered[ttype[t]]:
                        evict.append(qi)
                        rem -= eet[ttype[t], mstar]
                if base + rem + eet[ttype[k], mstar] <= dl[k]:
                    for qi in evict:
                        t = m.queue.pop(qi)
                        status[t] = CANCELLED
                        cancelled[ttype[t]] += 1
            free = [j for j in range(M) if len(machines[j].queue) < Q]

        # Phase-I
        pairs = []
        if heuristic in ("ELARE", "FELARE"):
            for k in pend:
                best = None
                for j in free:
                    s = avail(machines[j])
                    e = eet[ttype[k], j]
                    if s + e <= dl[k]:
                        ec = _energy(s, e, dl[k], p_dyn[j])
                        if best is None or ec < best[2]:
                            best = (k, j, ec)
                if best:
                    pairs.append(best)
        else:  # MM / MSD / MMU: min completion machine, no feasibility
            for k in pend:
                best = None
                for j in free:
                    s = avail(machines[j])
                    c = _completion(s, eet[ttype[k], j], dl[k])
                    if best is None or c < best[2]:
                        best = (k, j, c)
                if best:
                    k, j, c = best
                    # keys computed in float32 with the same op order as the
                    # JAX engine, so tie-breaking is bit-identical (the
                    # 1e-6 epsilon / reciprocal are not dyadic-exact).
                    f32 = np.float32
                    if heuristic == "MM":
                        key = float(f32(c))
                    elif heuristic == "MSD":
                        key = float(f32(dl[k]) + f32(1e-6) * f32(c))
                    else:  # MMU
                        slack = (f32(dl[k]) - f32(now)
                                 - f32(eet[ttype[k], j]))
                        if abs(slack) < 1e-9:
                            slack = f32(1e-9)
                        key = float(f32(-1.0) / slack)
                    pairs.append((k, j, key))

        # Phase-II (FELARE: suffered pairs first)
        if heuristic == "FELARE":
            hi = [p for p in pairs if suffered[ttype[p[0]]]]
            lo = [p for p in pairs if not suffered[ttype[p[0]]]]
            assign = phase2(hi, free)
            rest = [j for j in free if j not in assign]
            taken = set(assign.values())
            assign.update(
                phase2([p for p in lo if p[0] not in taken], rest)
            )
        else:
            assign = phase2(pairs, free)

        for j, k in assign.items():
            if status[k] == PENDING and len(machines[j].queue) < Q:
                machines[j].queue.append(k)
                status[k] = QUEUED

    def start_tasks():
        # One pop per machine per event; a dead-on-arrival task becomes a
        # zero-duration run (finalized as MISSED with zero energy at the same
        # timestamp) — mirrors the JAX engine's event structure exactly.
        for m in machines:
            if m.run < 0 and m.queue:
                k = m.queue.pop(0)
                m.run = k
                m.run_start = now
                status[k] = RUNNING
                if now >= dl[k]:
                    m.run_success = False
                    m.run_end_act = now
                    m.run_end_exp = now
                else:
                    e_act = exec_act[k, m.j]
                    m.run_success = now + e_act <= dl[k]
                    m.run_end_act = min(now + e_act, dl[k])
                    m.run_end_exp = _completion(now, eet[ttype[k], m.j], dl[k])

    max_steps = 16 * n + 64
    for _ in range(max_steps):
        t = next_event()
        if not np.isfinite(t):
            break
        now = max(now, t)
        # finalize completions
        for m in machines:
            if m.run >= 0 and m.run_end_act <= now:
                k = m.run
                dur = m.run_end_act - m.run_start
                en = p_dyn[m.j] * dur
                e_dyn += en
                m.busy += dur
                if m.run_success:
                    status[k] = COMPLETED
                    completed[ttype[k]] += 1
                else:
                    status[k] = MISSED
                    missed[ttype[k]] += 1
                    e_wasted += en
                m.run = -1
                m.run_end_act = np.inf
                m.run_end_exp = now
        # arrivals
        for k in range(n):
            if status[k] == UNARRIVED and arr[k] <= now:
                status[k] = PENDING
                arrived[ttype[k]] += 1
        mapping_event()
        start_tasks()
    makespan = now
    e_idle = float(sum(p_idle[m.j] * (makespan - m.busy) for m in machines))
    return dict(
        completed_by_type=completed,
        missed_by_type=missed,
        cancelled_by_type=cancelled,
        arrived_by_type=arrived,
        energy_dynamic=e_dyn,
        energy_wasted=e_wasted,
        energy_idle=e_idle,
        makespan=makespan,
    )
