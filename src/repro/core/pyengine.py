"""Independent pure-Python oracle simulator.

Deliberately written with plain loops and numpy (no shared code with the JAX
engine beyond the dataclasses) so hypothesis property tests can cross-check
the vectorized `repro.core.engine` implementation event-by-event.

Policies are interpreted from their declarative description
(:class:`repro.core.policy.PolicyDesc` — nominator × phase-2 key × drop rule
× fairness flag) rather than hard-coded name branches, so any policy
composed from the registered pieces is oracle-checkable, including
user-registered compositions. Opaque policies (custom callables without a
``describe()``) have no oracle interpretation and raise ``TypeError``.

Federations are interpreted the same way: when ``spec.site_of_machine``
partitions the machines into F sites, a ``dispatch`` step assigns each
newly-pending task a site (interpreting the dispatcher's ``kind`` +
dataclass fields — every built-in of :mod:`repro.core.dispatch` has a
plain-loop mirror here) and the mapping event then runs once per site
over the site's own pending tasks and machines, with site-local
feasibility (``hopeless``/``rescuable`` consult the site's fastest
machine, exactly like the engine's BIG-masked EET rows).

Precision note: trace times are dyadic (the tests round them), so event
timestamps are exact in both engines. Everything derived from the EET table
(availability sums, feasibility boundaries, energy keys, the fairness limit)
is NOT dyadic, and the JAX engine computes it in float32 — a float64 oracle
flips near-tie mapping decisions and diverges. All decision arithmetic below
therefore mirrors the engine's float32 operation order exactly; only the
reported energy accumulators stay float64 (tests compare them with rel
tolerance).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import (
    CANCELLED,
    COMPLETED,
    MISSED,
    PENDING,
    QUEUED,
    RUNNING,
    UNARRIVED,
)

BIG = 1e30
F = np.float32


class _Machine:
    def __init__(self, j):
        self.j = j
        self.run = -1
        self.run_start = 0.0
        self.run_end_act = np.inf
        self.run_end_exp = F(0.0)
        self.run_success = False
        self.queue: list[int] = []
        self.busy = 0.0


def _completion(s, e, d):
    if s + e <= d:
        return s + e
    if s < d:
        return d
    return s


def _lookup(table, kind, what):
    """kind -> handler, with the guard and the dispatch one data structure."""
    try:
        return table[kind]
    except KeyError:
        raise NotImplementedError(
            f"oracle has no interpretation for {what} {kind!r}"
        ) from None


def _dispatch_interpreter(dispatcher, n_sites: int):
    """``kind`` + fields -> a plain-loop ``assign_sites`` closure.

    ``assign_sites(new, ttype, suffered, load, eet_min_site)`` returns
    ``{task index: site}`` for the indices in ``new`` (walked in
    ascending order), mutating ``load`` for the load-balancing kinds
    exactly like the engine's ``sequential_balance`` scan;
    ``eet_min_site`` is the (S, F) per-site fastest-machine table
    ``min_eet`` consults.
    """
    from repro.core import dispatch as dispatch_mod

    d = dispatch_mod.resolve(dispatcher)
    F = n_sites

    def _hash(k, salt):
        return ((k * 2654435761 + salt) & 0xFFFFFFFF) % F

    if d.kind == "sticky":
        def assign(new, ttype, suffered, load, eet_min_site):
            return {k: (ttype[k] % F if d.by_type else _hash(k, d.salt))
                    for k in new}
    elif d.kind == "round_robin":
        def assign(new, ttype, suffered, load, eet_min_site):
            return {k: k % F for k in new}
    elif d.kind == "least_queued":
        def assign(new, ttype, suffered, load, eet_min_site):
            out = {}
            for k in new:  # ascending index order, like the engine's scan
                s = int(np.argmin(load))
                load[s] += 1
                out[k] = s
            return out
    elif d.kind == "min_eet":
        def assign(new, ttype, suffered, load, eet_min_site):
            return {k: int(np.argmin(eet_min_site[ttype[k]])) for k in new}
    elif d.kind == "fair_spill":
        def assign(new, ttype, suffered, load, eet_min_site):
            out = {}
            for k in new:
                s = (int(np.argmin(load)) if suffered[ttype[k]]
                     else _hash(k, d.salt))
                load[s] += 1
                out[k] = s
            return out
    else:
        raise NotImplementedError(
            f"oracle has no interpretation for dispatcher {d.kind!r}"
        )
    return assign


def simulate(trace, spec, heuristic: str, dispatcher=None):
    """Run one trace; returns a dict mirroring Metrics.

    The dict also carries a ``"task_log"`` entry mirroring the JAX
    engine's ``task_log`` observer (:mod:`repro.core.observe`): per-task
    map/start/end times, machine, federation site and final status,
    stamped at the same event timestamps — the cross-check is
    event-for-event, not just end-of-trace.
    """
    from repro.core import policy as policy_mod

    desc = policy_mod.describe(heuristic)
    eet = np.asarray(spec.eet, np.float32)
    p_dyn = np.asarray(spec.p_dyn, np.float32)
    p_idle = np.asarray(spec.p_idle, np.float64)
    S, M = eet.shape
    Q = spec.queue_size
    fair_f = F(spec.fairness_factor)

    arr = np.asarray(trace.arrival, np.float64)
    ttype = np.asarray(trace.task_type)
    dl = np.asarray(trace.deadline, np.float64)
    exec_act = np.asarray(trace.exec_actual, np.float64)
    n = len(arr)

    # --- federation structure (F=1 for flat pre-federation specs) ----------
    sites = np.asarray(getattr(spec, "sites", (0,) * M), int)
    F_sites = int(sites.max()) + 1
    site_machines = [[j for j in range(M) if sites[j] == s]
                     for s in range(F_sites)]
    # (S, F) f32 — each type's fastest machine per site (site-local
    # feasibility mirror of the engine's BIG-masked EET rows).
    eet_min_site = np.stack(
        [eet[:, ms].min(axis=1) for ms in site_machines], axis=1
    )
    task_site = np.full(n, -1, int)
    assign_sites = (_dispatch_interpreter(dispatcher, F_sites)
                    if F_sites > 1 else None)

    status = np.full(n, UNARRIVED)
    machines = [_Machine(j) for j in range(M)]
    completed = np.zeros(S, int)
    missed = np.zeros(S, int)
    cancelled = np.zeros(S, int)
    arrived = np.zeros(S, int)
    e_dyn = 0.0
    e_wasted = 0.0
    now = 0.0

    # task_log mirror: stamped once, at the event that made the transition.
    log_map = np.full(n, -1.0)
    log_start = np.full(n, -1.0)
    log_end = np.full(n, -1.0)
    log_machine = np.full(n, -1, int)

    def _end(k):
        if log_end[k] < 0:
            log_end[k] = now

    def next_event():
        ts = [arr[k] for k in range(n) if status[k] == UNARRIVED]
        ts += [m.run_end_act for m in machines if m.run >= 0]
        ts += [dl[k] for k in range(n) if status[k] == PENDING]
        return min(ts) if ts else np.inf

    def avail_base(m):
        return F(max(now, m.run_end_exp if m.run >= 0 else now))

    def qsum(m):
        # f32 slot-order reduction, like the engine's queued_eet(...).sum(1)
        s = F(0.0)
        for k in m.queue:
            s = F(s + eet[ttype[k], m.j])
        return s

    def avail(m):
        return F(avail_base(m) + qsum(m))

    def suffered_mask():
        cr = np.where(
            arrived > 0,
            completed.astype(F) / np.maximum(arrived, 1).astype(F),
            F(1.0),
        ).astype(F)
        mu = cr.mean(dtype=F)
        sigma = cr.std(dtype=F)
        eps = max(F(mu - F(fair_f * sigma)), F(0.0))
        return (cr <= eps) & (arrived >= 1)

    # --- Phase-I: one (task, machine, value) nomination per task -----------
    def _nominate_min_energy_feasible(pend, free):
        pairs = []
        for k in pend:
            best = None
            for j in free:
                s = avail(machines[j])
                e = eet[ttype[k], j]
                if F(s + e) <= dl[k]:
                    ec = F(p_dyn[j] * e)
                    if best is None or ec < best[2]:
                        best = (k, j, ec)
            if best:
                pairs.append(best)
        return pairs

    def _nominate_min_completion(pend, free):
        pairs = []
        for k in pend:
            best = None
            for j in free:
                s = avail(machines[j])
                c = _completion(s, eet[ttype[k], j], dl[k])
                if best is None or c < best[2]:
                    best = (k, j, c)
            if best:
                pairs.append(best)
        return pairs

    def _nominate_min_execution(pend, free):
        pairs = []
        for k in pend:
            best = None
            for j in free:
                e = eet[ttype[k], j]
                if best is None or e < best[2]:
                    best = (k, j, e)
            if best:
                pairs.append(best)
        return pairs

    def _nominate_random_hash(pend, free):
        t32 = int(np.uint32(F(F(now) * F(1e3))))
        return [(k, ((k * 2654435761 + t32) & 0xFFFFFFFF) % M, float(k))
                for k in pend]

    # --- Phase-II keys (lower = better), float32 with the engine's op order
    # so tie-breaking is bit-identical --------------------------------------
    def _key_urgency(k, j, val):
        slack = F(F(F(dl[k]) - F(now)) - eet[ttype[k], j])
        if abs(slack) < 1e-9:
            slack = F(1e-9)
        return F(-(F(1.0) / slack))

    nominate = _lookup({
        "min_energy_feasible": _nominate_min_energy_feasible,
        "min_completion": _nominate_min_completion,
        "min_execution": _nominate_min_execution,
        "random_hash": _nominate_random_hash,
    }, desc.nominator, "nominator")
    phase2_key = _lookup({
        "value": lambda k, j, val: F(val),
        "deadline": lambda k, j, val: F(F(dl[k]) + F(F(1e-6) * F(val))),
        "urgency": _key_urgency,
        "fcfs": lambda k, j, val: float(k),
    }, desc.phase2_key, "phase-2 key")
    drop_hopeless = _lookup({
        "stale": False,
        "stale_hopeless": True,
    }, desc.drop_rule, "drop rule")

    def phase2(pairs, machines_free):
        """pairs: list of (task, machine, key). One task per machine, min key."""
        assign = {}
        for j in machines_free:
            cand = [(key, k) for (k, jj, key) in pairs if jj == j]
            if cand:
                key, k = min(cand)
                assign[j] = k
        # a task may not be assigned twice (cannot happen: each task appears
        # with exactly one machine in `pairs`)
        return assign

    def dispatch_event():
        """Assign newly-pending tasks to sites (dispatch-once)."""
        new = [k for k in range(n)
               if status[k] == PENDING and task_site[k] < 0]
        if not new:
            return
        if F_sites == 1:
            for k in new:
                task_site[k] = 0
            return
        suffered = suffered_mask()
        load = np.asarray(
            [sum(len(machines[j].queue) for j in site_machines[s])
             + sum(1 for j in site_machines[s] if machines[j].run >= 0)
             for s in range(F_sites)], int)
        for k, s in assign_sites(new, ttype, suffered, load,
                                 eet_min_site).items():
            task_site[k] = min(max(int(s), 0), F_sites - 1)

    def mapping_event():
        suffered = suffered_mask()
        for s in range(F_sites):
            _map_site(s, suffered)

    def _map_site(s, suffered):
        nonlocal status
        msite = site_machines[s]
        pend = [k for k in range(n)
                if status[k] == PENDING and task_site[k] == s]

        def site_hopeless(k):
            return F(F(now) + eet_min_site[ttype[k], s]) > dl[k]

        # stale purge (all policies: stale tasks are never nominated)
        for k in list(pend):
            if now >= dl[k]:
                status[k] = CANCELLED
                cancelled[ttype[k]] += 1
                _end(k)
                pend.remove(k)

        if desc.fairness:
            # queue eviction for the earliest-deadline rescuable suffered task
            resc = [
                k for k in pend
                if suffered[ttype[k]]
                and not any(
                    F(avail(machines[j]) + eet[ttype[k], j]) <= dl[k]
                    for j in msite if len(machines[j].queue) < Q
                )
                and F(F(now) + eet_min_site[ttype[k], s]) <= dl[k]
            ]
            if resc:
                k = min(resc, key=lambda k: dl[k])
                mstar = min(
                    msite,
                    key=lambda j: F(avail(machines[j]) + eet[ttype[k], j]),
                )
                m = machines[mstar]
                e_tgt = eet[ttype[k], mstar]
                evict = []
                base = avail_base(m)
                rem = qsum(m)
                for qi in range(len(m.queue) - 1, -1, -1):
                    t = m.queue[qi]
                    if F(F(base + rem) + e_tgt) <= dl[k]:
                        break
                    if not suffered[ttype[t]]:
                        evict.append(qi)
                        rem = F(rem - eet[ttype[t], mstar])
                if F(F(base + rem) + e_tgt) <= dl[k]:
                    for qi in evict:
                        t = m.queue.pop(qi)
                        status[t] = CANCELLED
                        cancelled[ttype[t]] += 1
                        _end(t)

        free = [j for j in msite if len(machines[j].queue) < Q]

        # Phase-I + Phase-II (fairness: suffered-type pairs claim machines
        # first, remaining machines serve the non-suffered pairs).
        pairs = [(k, j, phase2_key(k, j, val))
                 for (k, j, val) in nominate(pend, free)]
        if desc.fairness:
            hi = [p for p in pairs if suffered[ttype[p[0]]]]
            lo = [p for p in pairs if not suffered[ttype[p[0]]]]
            assign = phase2(hi, free)
            rest = [j for j in free if j not in assign]
            taken = set(assign.values())
            assign.update(
                phase2([p for p in lo if p[0] not in taken], rest)
            )
        else:
            assign = phase2(pairs, free)

        # proactive drops: never drop a task assigned this very event
        if drop_hopeless:
            assigned = set(assign.values())
            for k in list(pend):
                if k not in assigned and site_hopeless(k):
                    status[k] = CANCELLED
                    cancelled[ttype[k]] += 1
                    _end(k)
                    pend.remove(k)

        for j, k in assign.items():
            if status[k] == PENDING and len(machines[j].queue) < Q:
                machines[j].queue.append(k)
                status[k] = QUEUED
                if log_map[k] < 0:
                    log_map[k] = now

    def start_tasks():
        # One pop per machine per event; a dead-on-arrival task becomes a
        # zero-duration run (finalized as MISSED with zero energy at the same
        # timestamp) — mirrors the JAX engine's event structure exactly.
        for m in machines:
            if m.run < 0 and m.queue:
                k = m.queue.pop(0)
                m.run = k
                m.run_start = now
                status[k] = RUNNING
                if log_start[k] < 0:
                    log_start[k] = now
                    log_machine[k] = m.j
                if now >= dl[k]:
                    m.run_success = False
                    m.run_end_act = now
                    m.run_end_exp = F(now)
                else:
                    e_act = exec_act[k, m.j]
                    m.run_success = now + e_act <= dl[k]
                    m.run_end_act = min(now + e_act, dl[k])
                    m.run_end_exp = F(
                        _completion(F(now), eet[ttype[k], m.j], F(dl[k]))
                    )

    max_steps = 16 * n + 64
    for _ in range(max_steps):
        t = next_event()
        if not np.isfinite(t):
            break
        now = max(now, t)
        # finalize completions
        for m in machines:
            if m.run >= 0 and m.run_end_act <= now:
                k = m.run
                dur = m.run_end_act - m.run_start
                en = float(p_dyn[m.j]) * dur
                e_dyn += en
                m.busy += dur
                if m.run_success:
                    status[k] = COMPLETED
                    completed[ttype[k]] += 1
                else:
                    status[k] = MISSED
                    missed[ttype[k]] += 1
                    e_wasted += en
                _end(k)
                m.run = -1
                m.run_end_act = np.inf
                m.run_end_exp = F(now)
        # arrivals
        for k in range(n):
            if status[k] == UNARRIVED and arr[k] <= now:
                status[k] = PENDING
                arrived[ttype[k]] += 1
        dispatch_event()
        mapping_event()
        start_tasks()
    makespan = now
    e_idle = float(sum(p_idle[m.j] * (makespan - m.busy) for m in machines))
    return dict(
        completed_by_type=completed,
        missed_by_type=missed,
        cancelled_by_type=cancelled,
        arrived_by_type=arrived,
        energy_dynamic=e_dyn,
        energy_wasted=e_wasted,
        energy_idle=e_idle,
        makespan=makespan,
        task_log=dict(
            map_time=log_map,
            start_time=log_start,
            end_time=log_end,
            machine=log_machine,
            site=task_site.copy(),
            status=status.copy(),
        ),
    )
