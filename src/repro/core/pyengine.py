"""Independent pure-Python oracle simulator.

Deliberately written with plain loops and numpy (no shared code with the JAX
engine beyond the dataclasses) so hypothesis property tests can cross-check
the vectorized `repro.core.engine` implementation event-by-event.

Policies are interpreted from their declarative description
(:class:`repro.core.policy.PolicyDesc` — nominator × phase-2 key × drop rule
× fairness flag) rather than hard-coded name branches, so any policy
composed from the registered pieces is oracle-checkable, including
user-registered compositions. Opaque policies (custom callables without a
``describe()``) have no oracle interpretation and raise ``TypeError``.

Federations are interpreted the same way: when ``spec.site_of_machine``
partitions the machines into F sites, a ``dispatch`` step assigns each
newly-pending task a site (interpreting the dispatcher's ``kind`` +
dataclass fields — every built-in of :mod:`repro.core.dispatch` has a
plain-loop mirror here) and the mapping event then runs once per site
over the site's own pending tasks and machines, with site-local
feasibility (``hopeless``/``rescuable`` consult the site's fastest
machine, exactly like the engine's BIG-masked EET rows).

Machine dynamics (:mod:`repro.core.faults`) are interpreted too: a
``faults`` step between arrivals and dispatch evolves per-machine
``(alive, slowdown)`` (each built-in ``kind`` has a plain-loop mirror,
down to the integer hash driving ``bernoulli_updown``), orphans the
dead machines' tasks with the engine's exact retry/cancel/failover
rules, and every decision table (EET columns, availability, per-site
fastest machine) is re-derived with dead machines masked to BIG —
byte-identical to how the engine masks out-of-site machines.

Network models (:mod:`repro.core.network`) are interpreted the same
way: task origins come from the same salted counter hash
(``hash_origins_host``), each dispatch stamps the task's site ready
time ``f32(now) + f32(lat)`` and charges the link's transfer energy,
in-transit tasks are invisible to the mapper until they land (landings
drive events), and an in-transit task whose deadline passes is
cancelled at the dispatch step — all mirroring the engine's f32
transfer arithmetic operation-for-operation.

Precision note: trace times are dyadic (the tests round them), so event
timestamps are exact in both engines. Everything derived from the EET table
(availability sums, feasibility boundaries, energy keys, the fairness limit)
is NOT dyadic, and the JAX engine computes it in float32 — a float64 oracle
flips near-tie mapping decisions and diverges. All decision arithmetic below
therefore mirrors the engine's float32 operation order exactly; only the
reported energy accumulators stay float64 (tests compare them with rel
tolerance).
"""
from __future__ import annotations

import numpy as np

from repro.core.types import (
    CANCELLED,
    COMPLETED,
    MISSED,
    PENDING,
    QUEUED,
    RUNNING,
    UNARRIVED,
)

BIG = 1e30
F = np.float32


class _Machine:
    def __init__(self, j):
        self.j = j
        self.run = -1
        self.run_start = 0.0
        self.run_end_act = np.inf
        self.run_end_exp = F(0.0)
        self.run_success = False
        self.queue: list[int] = []
        self.busy = 0.0


def _completion(s, e, d):
    if s + e <= d:
        return s + e
    if s < d:
        return d
    return s


def _lookup(table, kind, what):
    """kind -> handler, with the guard and the dispatch one data structure."""
    try:
        return table[kind]
    except KeyError:
        raise NotImplementedError(
            f"oracle has no interpretation for {what} {kind!r}"
        ) from None


def _dispatch_interpreter(dispatcher, n_sites: int):
    """``kind`` + fields -> a plain-loop ``assign_sites`` closure.

    ``assign_sites(new, ttype, suffered, load, eet_min_site, site_alive,
    xfer_lat)`` returns ``{task index: site}`` for the indices in ``new``
    (walked in ascending order), mutating ``load`` for the
    load-balancing kinds exactly like the engine's
    ``sequential_balance`` scan; ``eet_min_site`` is the (S, F) per-site
    fastest-machine table ``min_eet`` consults. ``site_alive`` is the
    faults subsystem's heartbeat mask (``None`` with no dynamics
    attached); the caller has already folded the engine's dead-site load
    penalty into ``load``, so only ``health_aware`` reads the mask
    directly (for its home check). ``xfer_lat`` is the network
    subsystem's (n, F) per-task link-latency row table (``None`` with no
    network attached); only ``tier_aware`` reads it.
    """
    from repro.core import dispatch as dispatch_mod

    d = dispatch_mod.resolve(dispatcher)
    F = n_sites

    def _hash(k, salt):
        return ((k * 2654435761 + salt) & 0xFFFFFFFF) % F

    if d.kind == "sticky":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            return {k: (ttype[k] % F if d.by_type else _hash(k, d.salt))
                    for k in new}
    elif d.kind == "round_robin":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            return {k: k % F for k in new}
    elif d.kind == "least_queued":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            out = {}
            for k in new:  # ascending index order, like the engine's scan
                s = int(np.argmin(load))
                load[s] += 1
                out[k] = s
            return out
    elif d.kind == "min_eet":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            return {k: int(np.argmin(eet_min_site[ttype[k]])) for k in new}
    elif d.kind == "fair_spill":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            out = {}
            for k in new:
                s = (int(np.argmin(load)) if suffered[ttype[k]]
                     else _hash(k, d.salt))
                load[s] += 1
                out[k] = s
            return out
    elif d.kind == "health_aware":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            out = {}
            for k in new:
                home = _hash(k, d.salt)
                s = (home if site_alive is None or site_alive[home]
                     else int(np.argmin(load)))
                load[s] += 1
                out[k] = s
            return out
    elif d.kind == "tier_aware":
        def assign(new, ttype, suffered, load, eet_min_site, site_alive,
                   xfer_lat):
            # engine: score = eet_min_by_site[type] (+ xfer_lat); argmin.
            # f32 + f32 row addition mirrors the traced add exactly.
            out = {}
            for k in new:
                row = eet_min_site[ttype[k]]
                if xfer_lat is not None:
                    row = (row + xfer_lat[k]).astype(np.float32)
                out[k] = int(np.argmin(row))
            return out
    else:
        raise NotImplementedError(
            f"oracle has no interpretation for dispatcher {d.kind!r}"
        )
    return assign


def simulate(trace, spec, heuristic: str, dispatcher=None, dynamics=None,
             network=None):
    """Run one trace; returns a dict mirroring Metrics.

    The dict also carries a ``"task_log"`` entry mirroring the JAX
    engine's ``task_log`` observer (:mod:`repro.core.observe`): per-task
    map/start/end times, machine, federation site, final status, orphan
    retry count and (with a network attached) site ready time, stamped
    at the same event timestamps — the cross-check is event-for-event,
    not just end-of-trace.
    """
    from repro.core import faults as faults_mod
    from repro.core import network as network_mod
    from repro.core import policy as policy_mod
    from repro.core.faults.base import hash_uniform_host

    desc = policy_mod.describe(heuristic)
    eet = np.asarray(spec.eet, np.float32)
    p_dyn = np.asarray(spec.p_dyn, np.float32)
    p_idle = np.asarray(spec.p_idle, np.float64)
    S, M = eet.shape
    Q = spec.queue_size
    fair_f = F(spec.fairness_factor)

    arr = np.asarray(trace.arrival, np.float64)
    ttype = np.asarray(trace.task_type)
    dl = np.asarray(trace.deadline, np.float64)
    exec_act = np.asarray(trace.exec_actual, np.float64)
    n = len(arr)

    # --- federation structure (F=1 for flat pre-federation specs) ----------
    sites = np.asarray(getattr(spec, "sites", (0,) * M), int)
    F_sites = int(sites.max()) + 1
    site_machines = [[j for j in range(M) if sites[j] == s]
                     for s in range(F_sites)]
    task_site = np.full(n, -1, int)
    assign_sites = (_dispatch_interpreter(dispatcher, F_sites)
                    if F_sites > 1 else None)

    # --- network costs (None = no transfer arithmetic, like the engine) ----
    net = network_mod.resolve(network)
    if getattr(net, "kind", None) == "none":
        net = None
    lat_task = en_task = None
    ready = arr.copy()  # site ready time; == arrival until first dispatch
    if net is not None:
        tiers = tuple(getattr(spec, "tiers", (0,) * F_sites))
        lat_tab, en_tab = net.cost_tables(tiers, S)
        origin = network_mod.hash_origins_host(
            n, network_mod.origin_sites(tiers), int(getattr(net, "salt", 0))
        )
        lat_task = np.asarray(lat_tab, F)[ttype, origin]  # (n, F) rows
        en_task = np.asarray(en_tab, F)[ttype, origin]

    # --- machine dynamics (None = no faults step, like the engine) ---------
    dyn = faults_mod.resolve(dynamics)
    if getattr(dyn, "kind", None) == "none":
        dyn = None
    backup_k = int(getattr(desc, "backup_k", 0)) if dyn is not None else 0
    max_retries = int(getattr(dyn, "max_retries", 3))
    horizon = F(dl.max())
    wake_ts = ([float(F(F(w) * horizon)) for w in dyn.wake_fracs()]
               if dyn is not None and hasattr(dyn, "wake_fracs") else [])
    alive = np.ones(M, bool)
    slowdown = np.ones(M, np.float32)
    retries = np.zeros(n, int)
    backup = np.full((n, backup_k), -1, int)

    # Decision tables, re-derived whenever health changes: dead machines'
    # EET columns read BIG (the engine's out-of-site masking, reused) and
    # straggler columns are slowdown-scaled. With no dynamics these are
    # exactly the raw tables (x * 1.0 is f32-exact).
    eet_c = eet
    eet_min_site = np.stack(
        [eet[:, ms].min(axis=1) for ms in site_machines], axis=1
    )

    def _refresh_tables():
        nonlocal eet_c, eet_min_site
        eet_c = np.where(
            alive[None, :], (eet * slowdown[None, :]).astype(F), F(BIG)
        ).astype(F)
        eet_min_site = np.stack(
            [eet_c[:, ms].min(axis=1) for ms in site_machines], axis=1
        )

    status = np.full(n, UNARRIVED)
    machines = [_Machine(j) for j in range(M)]
    completed = np.zeros(S, int)
    missed = np.zeros(S, int)
    cancelled = np.zeros(S, int)
    arrived = np.zeros(S, int)
    e_dyn = 0.0
    e_wasted = 0.0
    now = 0.0

    # task_log mirror: stamped once, at the event that made the transition
    # (``machine`` restamps at every start — it reports the task's last
    # placement, which moves on failover/re-dispatch).
    log_map = np.full(n, -1.0)
    log_start = np.full(n, -1.0)
    log_end = np.full(n, -1.0)
    log_machine = np.full(n, -1, int)

    def _end(k):
        if log_end[k] < 0:
            log_end[k] = now

    def next_event():
        ts = [arr[k] for k in range(n) if status[k] == UNARRIVED]
        ts += [m.run_end_act for m in machines if m.run >= 0]
        ts += [dl[k] for k in range(n) if status[k] == PENDING]
        ts += [w for w in wake_ts if w > now]  # outage window edges
        if net is not None:  # in-transit landings drive events too
            ts += [ready[k] for k in range(n)
                   if status[k] == PENDING and ready[k] > now]
        return min(ts) if ts else np.inf

    def avail_base(m):
        if not alive[m.j]:
            return F(BIG)
        return F(max(now, m.run_end_exp if m.run >= 0 else now))

    def qsum(m):
        # f32 slot-order reduction, like the engine's queued_eet(...).sum(1)
        s = F(0.0)
        for k in m.queue:
            s = F(s + eet_c[ttype[k], m.j])
        return s

    def avail(m):
        return F(avail_base(m) + qsum(m))

    def suffered_mask():
        cr = np.where(
            arrived > 0,
            completed.astype(F) / np.maximum(arrived, 1).astype(F),
            F(1.0),
        ).astype(F)
        mu = cr.mean(dtype=F)
        sigma = cr.std(dtype=F)
        eps = max(F(mu - F(fair_f * sigma)), F(0.0))
        return (cr <= eps) & (arrived >= 1)

    # --- Phase-I: one (task, machine, value) nomination per task -----------
    def _nominate_min_energy_feasible(pend, free):
        pairs = []
        for k in pend:
            best = None
            for j in free:
                s = avail(machines[j])
                e = eet_c[ttype[k], j]
                if F(s + e) <= dl[k]:
                    ec = F(p_dyn[j] * e)
                    if best is None or ec < best[2]:
                        best = (k, j, ec)
            if best:
                pairs.append(best)
        return pairs

    def _nominate_min_completion(pend, free):
        pairs = []
        for k in pend:
            best = None
            for j in free:
                s = avail(machines[j])
                c = _completion(s, eet_c[ttype[k], j], dl[k])
                if best is None or c < best[2]:
                    best = (k, j, c)
            if best:
                pairs.append(best)
        return pairs

    def _nominate_min_execution(pend, free):
        pairs = []
        for k in pend:
            best = None
            for j in free:
                e = eet_c[ttype[k], j]
                if best is None or e < best[2]:
                    best = (k, j, e)
            if best:
                pairs.append(best)
        return pairs

    def _nominate_random_hash(pend, free):
        t32 = int(np.uint32(F(F(now) * F(1e3))))
        return [(k, ((k * 2654435761 + t32) & 0xFFFFFFFF) % M, float(k))
                for k in pend]

    # --- Phase-II keys (lower = better), float32 with the engine's op order
    # so tie-breaking is bit-identical --------------------------------------
    def _key_urgency(k, j, val):
        slack = F(F(F(dl[k]) - F(now)) - eet_c[ttype[k], j])
        if abs(slack) < 1e-9:
            slack = F(1e-9)
        return F(-(F(1.0) / slack))

    nominate = _lookup({
        "min_energy_feasible": _nominate_min_energy_feasible,
        "min_completion": _nominate_min_completion,
        "min_execution": _nominate_min_execution,
        "random_hash": _nominate_random_hash,
    }, desc.nominator, "nominator")
    phase2_key = _lookup({
        "value": lambda k, j, val: F(val),
        "deadline": lambda k, j, val: F(F(dl[k]) + F(F(1e-6) * F(val))),
        "urgency": _key_urgency,
        "fcfs": lambda k, j, val: float(k),
    }, desc.phase2_key, "phase-2 key")
    drop_hopeless = _lookup({
        "stale": False,
        "stale_hopeless": True,
    }, desc.drop_rule, "drop rule")

    def phase2(pairs, machines_free):
        """pairs: list of (task, machine, key). One task per machine, min key."""
        assign = {}
        for j in machines_free:
            cand = [(key, k) for (k, jj, key) in pairs if jj == j]
            if cand:
                key, k = min(cand)
                assign[j] = k
        # a task may not be assigned twice (cannot happen: each task appears
        # with exactly one machine in `pairs`)
        return assign

    def dispatch_event():
        """Assign newly-pending tasks to sites (dispatch-once).

        With a network attached, each assignment also stamps the link's
        ready time and charges its transfer energy, and any in-transit
        task whose deadline passed is cancelled here — the engine does
        all three inside ``_stage_dispatch``.
        """
        nonlocal e_dyn
        new = [k for k in range(n)
               if status[k] == PENDING and task_site[k] < 0]
        if F_sites == 1:
            for k in new:
                task_site[k] = 0
        elif new:
            suffered = suffered_mask()
            load = np.asarray(
                [sum(len(machines[j].queue) for j in site_machines[s])
                 + sum(1 for j in site_machines[s] if machines[j].run >= 0)
                 for s in range(F_sites)], int)
            site_alive = None
            if dyn is not None:
                site_alive = np.asarray(
                    [any(alive[j] for j in site_machines[s])
                     for s in range(F_sites)])
                # engine's sequential_balance dead-site penalty
                load = load + np.where(site_alive, 0, 1_000_000)
            for k, s in assign_sites(new, ttype, suffered, load,
                                     eet_min_site, site_alive,
                                     lat_task).items():
                task_site[k] = min(max(int(s), 0), F_sites - 1)
        if net is None:
            return
        for k in new:
            s = task_site[k]
            # engine: ready = f32(now) + f32(lat); orphans re-pay on
            # re-dispatch (their task_site was reset to -1).
            ready[k] = float(F(F(now) + lat_task[k, s]))
            e_dyn += float(en_task[k, s])
        for k in range(n):  # stale in-transit purge (energy stays spent)
            if status[k] == PENDING and ready[k] > now and now >= dl[k]:
                status[k] = CANCELLED
                cancelled[ttype[k]] += 1
                _end(k)

    def mapping_event():
        suffered = suffered_mask()
        for s in range(F_sites):
            _map_site(s, suffered)

    def _map_site(s, suffered):
        nonlocal status
        msite = site_machines[s]
        pend = [k for k in range(n)
                if status[k] == PENDING and task_site[k] == s
                and (net is None or ready[k] <= now)]  # in transit: invisible

        def site_hopeless(k):
            return F(F(now) + eet_min_site[ttype[k], s]) > dl[k]

        # stale purge (all policies: stale tasks are never nominated)
        for k in list(pend):
            if now >= dl[k]:
                status[k] = CANCELLED
                cancelled[ttype[k]] += 1
                _end(k)
                pend.remove(k)

        if desc.fairness:
            # queue eviction for the earliest-deadline rescuable suffered task
            resc = [
                k for k in pend
                if suffered[ttype[k]]
                and not any(
                    F(avail(machines[j]) + eet_c[ttype[k], j]) <= dl[k]
                    for j in msite if alive[j] and len(machines[j].queue) < Q
                )
                and F(F(now) + eet_min_site[ttype[k], s]) <= dl[k]
            ]
            if resc:
                k = min(resc, key=lambda k: dl[k])
                mstar = min(
                    msite,
                    key=lambda j: F(avail(machines[j]) + eet_c[ttype[k], j]),
                )
                m = machines[mstar]
                e_tgt = eet_c[ttype[k], mstar]
                evict = []
                base = avail_base(m)
                rem = qsum(m)
                for qi in range(len(m.queue) - 1, -1, -1):
                    t = m.queue[qi]
                    if F(F(base + rem) + e_tgt) <= dl[k]:
                        break
                    if not suffered[ttype[t]]:
                        evict.append(qi)
                        rem = F(rem - eet_c[ttype[t], mstar])
                if F(F(base + rem) + e_tgt) <= dl[k]:
                    for qi in evict:
                        t = m.queue.pop(qi)
                        status[t] = CANCELLED
                        cancelled[ttype[t]] += 1
                        _end(t)

        free = [j for j in msite if alive[j] and len(machines[j].queue) < Q]

        # Phase-I + Phase-II (fairness: suffered-type pairs claim machines
        # first, remaining machines serve the non-suffered pairs).
        pairs = [(k, j, phase2_key(k, j, val))
                 for (k, j, val) in nominate(pend, free)]
        if desc.fairness:
            hi = [p for p in pairs if suffered[ttype[p[0]]]]
            lo = [p for p in pairs if not suffered[ttype[p[0]]]]
            assign = phase2(hi, free)
            rest = [j for j in free if j not in assign]
            taken = set(assign.values())
            assign.update(
                phase2([p for p in lo if p[0] not in taken], rest)
            )
        else:
            assign = phase2(pairs, free)

        # proactive drops: never drop a task assigned this very event
        if drop_hopeless:
            assigned = set(assign.values())
            for k in list(pend):
                if k not in assigned and site_hopeless(k):
                    status[k] = CANCELLED
                    cancelled[ttype[k]] += 1
                    _end(k)
                    pend.remove(k)

        for j, k in assign.items():
            if status[k] == PENDING and len(machines[j].queue) < Q:
                machines[j].queue.append(k)
                status[k] = QUEUED
                if log_map[k] < 0:
                    log_map[k] = now
                if backup_k:
                    _nominate_backup(k, j)

    def _nominate_backup(k, jprim):
        """k cheapest completion-score backups, primary/dead masked to BIG.

        Mirrors the engine's ``_nominate_backups``: score is the *current*
        base availability (queue backlog ignored, FEST-style) plus the
        health-masked EET, and the iterative argmin naturally yields
        distinct machines in score order (picked slots are re-masked).
        """
        sc = np.empty(M, F)
        for j2 in range(M):
            if j2 == jprim:
                sc[j2] = F(BIG)
                continue
            m2 = machines[j2]
            ab = (F(BIG) if not alive[j2]
                  else F(max(now, m2.run_end_exp if m2.run >= 0 else now)))
            sc[j2] = F(ab + eet_c[ttype[k], j2])
        for slot in range(backup_k):
            b = int(np.argmin(sc))
            backup[k, slot] = b if sc[b] < F(BIG) else -1
            sc[b] = F(BIG)

    def start_tasks():
        # One pop per machine per event; a dead-on-arrival task becomes a
        # zero-duration run (finalized as MISSED with zero energy at the same
        # timestamp) — mirrors the JAX engine's event structure exactly.
        for m in machines:
            if m.run < 0 and m.queue and alive[m.j]:
                k = m.queue.pop(0)
                m.run = k
                m.run_start = now
                status[k] = RUNNING
                if log_start[k] < 0:
                    log_start[k] = now
                log_machine[k] = m.j  # last placement (moves on failover)
                if now >= dl[k]:
                    m.run_success = False
                    m.run_end_act = now
                    m.run_end_exp = F(now)
                else:
                    e_act = exec_act[k, m.j] * float(slowdown[m.j])
                    m.run_success = now + e_act <= dl[k]
                    m.run_end_act = min(now + e_act, dl[k])
                    m.run_end_exp = F(
                        _completion(F(now), eet_c[ttype[k], m.j], F(dl[k]))
                    )

    # --- faults step: evolve health, orphan the dead machines' tasks -------
    def dyn_step(it):
        """Plain-loop mirror of the registered dynamics' ``step``."""
        alive_new = alive.copy()
        slow_new = slowdown.copy()
        if dyn.kind == "bernoulli_updown":
            for j in range(M):
                u = hash_uniform_host(j, it, dyn.seed)
                alive_new[j] = (u >= F(dyn.p_fail)) if alive[j] \
                    else (u < F(dyn.p_recover))
        elif dyn.kind == "site_outage":
            dead = np.zeros(M, bool)
            for (s, a, b) in dyn.outages:
                t0 = F(F(a) * horizon)
                t1 = F(F(b) * horizon)
                dead |= (sites == s) & (now >= t0) & (now < t1)
            alive_new = ~dead
        elif dyn.kind == "degrade":
            if dyn.machines is not None:
                mask = np.asarray(
                    [j in dyn.machines for j in range(M)])
            else:
                mask = np.asarray(
                    [hash_uniform_host(j, 0, dyn.seed) < F(dyn.p)
                     for j in range(M)])
            slow_new = np.where(mask, F(dyn.factor), F(1.0)).astype(F)
        else:
            raise NotImplementedError(
                f"oracle has no interpretation for dynamics {dyn.kind!r}"
            )
        return alive_new, slow_new

    def faults_event(it):
        nonlocal e_dyn, e_wasted
        alive_new, slow_new = dyn_step(it)
        died = alive & ~alive_new
        # flush dead machines' queues (machine index order, like the scan)
        for m in machines:
            if not died[m.j]:
                continue
            for k in m.queue:
                retries[k] += 1
                if retries[k] > max_retries:
                    status[k] = CANCELLED
                    cancelled[ttype[k]] += 1
                    _end(k)  # site kept: records where it gave up
                else:
                    status[k] = PENDING
                    task_site[k] = -1  # re-enters dispatch this event
            m.queue.clear()
        # kill running tasks: partial energy is spent AND wasted
        for m in machines:
            if not (died[m.j] and m.run >= 0):
                continue
            k = m.run
            dur = now - m.run_start
            en = float(p_dyn[m.j]) * dur
            e_dyn += en
            e_wasted += en
            m.busy += dur
            retries[k] += 1
            if retries[k] > max_retries:
                status[k] = CANCELLED
                cancelled[ttype[k]] += 1
                _end(k)
            else:
                fb = -1  # first live backup with queue room
                for b in backup[k] if backup_k else ():
                    if b >= 0 and alive_new[b] and \
                            len(machines[b].queue) < Q:
                        fb = int(b)
                        break
                if fb >= 0:
                    machines[fb].queue.append(k)
                    status[k] = QUEUED
                    task_site[k] = int(sites[fb])
                else:
                    status[k] = PENDING
                    task_site[k] = -1
            m.run = -1
            m.run_end_act = np.inf
            m.run_end_exp = F(now)
            m.run_success = False
        alive[:] = alive_new
        slowdown[:] = slow_new
        _refresh_tables()

    max_steps = 16 * n + 64
    for it in range(max_steps):
        t = next_event()
        if not np.isfinite(t):
            break
        now = max(now, t)
        # finalize completions
        for m in machines:
            if m.run >= 0 and m.run_end_act <= now:
                k = m.run
                dur = m.run_end_act - m.run_start
                en = float(p_dyn[m.j]) * dur
                e_dyn += en
                m.busy += dur
                if m.run_success:
                    status[k] = COMPLETED
                    completed[ttype[k]] += 1
                else:
                    status[k] = MISSED
                    missed[ttype[k]] += 1
                    e_wasted += en
                _end(k)
                m.run = -1
                m.run_end_act = np.inf
                m.run_end_exp = F(now)
        # arrivals
        for k in range(n):
            if status[k] == UNARRIVED and arr[k] <= now:
                status[k] = PENDING
                arrived[ttype[k]] += 1
        if dyn is not None:
            faults_event(it)
        dispatch_event()
        mapping_event()
        start_tasks()
    makespan = now
    e_idle = float(sum(p_idle[m.j] * (makespan - m.busy) for m in machines))
    return dict(
        completed_by_type=completed,
        missed_by_type=missed,
        cancelled_by_type=cancelled,
        arrived_by_type=arrived,
        energy_dynamic=e_dyn,
        energy_wasted=e_wasted,
        energy_idle=e_idle,
        makespan=makespan,
        backup=backup.copy(),
        task_log=dict(
            map_time=log_map,
            start_time=log_start,
            end_time=log_end,
            machine=log_machine,
            site=task_site.copy(),
            status=status.copy(),
            retries=retries.copy(),
            ready_time=(ready.copy() if net is not None
                        else np.full(n, -1.0)),
        ),
    )
