"""Concrete policy pieces: Phase-I nominators, Phase-II keys, drop rules.

Every piece is a frozen (hashable) dataclass carrying a ``kind`` tag — the
tag is what the pure-Python oracle (:mod:`repro.core.pyengine`) and the CLI
``--list`` output key on, so a composition of these pieces is fully
described by strings (see :class:`repro.core.policy.base.PolicyDesc`).

All arithmetic deliberately mirrors the legacy monolithic heuristics op for
op: the composed policies are bit-identical to their pre-refactor monoliths
(property-tested in ``tests/test_policy.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core import equations
from repro.core.policy.base import Nomination
from repro.core.policy.context import BIG, SchedContext


# --------------------------------------------------------------------------
# Phase-I nominators
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MinEnergyFeasible:
    """ELARE Phase-I (Alg. 2): min-energy machine among *feasible* pairs.

    ``impl`` optionally replaces the fused inner computation — the Pallas
    kernel ``repro.kernels.phase1_map.ops.phase1_map`` plugs in here as a
    first-class nominator implementation (contract:
    ``impl(start, exec_grid, deadline, p_dyn, pending, qfree)
    -> (best_machine, best_energy)``).
    """

    kind = "min_energy_feasible"
    impl: Optional[Callable] = None

    def with_impl(self, impl) -> "MinEnergyFeasible":
        return dataclasses.replace(self, impl=impl)

    def nominate(self, ctx: SchedContext) -> Nomination:
        if self.impl is not None:
            best_m, best_ec = self.impl(
                ctx.start, ctx.exec_grid, ctx.deadline, ctx.sysarr.p_dyn,
                ctx.pending, ctx.qfree,
            )
        else:
            s, e, d = ctx.start_grid, ctx.exec_grid, ctx.deadline[:, None]
            feas = (equations.feasible(s, e, d)
                    & ctx.pending[:, None] & ctx.qfree[None, :])
            ec = equations.expected_energy(s, e, d, ctx.sysarr.p_dyn[None, :])
            ec_masked = jnp.where(feas, ec, BIG)
            best_m = jnp.argmin(ec_masked, axis=1).astype(jnp.int32)
            best_ec = jnp.min(ec_masked, axis=1)
        return Nomination(best_m, best_ec, best_ec < BIG)


@dataclasses.dataclass(frozen=True)
class MinCompletion:
    """Baseline Phase-I (MM/MSD/MMU/MCT): min expected completion time
    (Eq. 1), no feasibility or energy awareness; stale tasks never nominate.
    """

    kind = "min_completion"

    def nominate(self, ctx: SchedContext) -> Nomination:
        c = equations.completion_time(
            ctx.start_grid, ctx.exec_grid, ctx.deadline[:, None]
        )
        c_masked = jnp.where(
            ctx.alive[:, None] & ctx.qfree[None, :], c, BIG
        )
        best_m = jnp.argmin(c_masked, axis=1).astype(jnp.int32)
        best_c = jnp.min(c_masked, axis=1)
        return Nomination(best_m, best_c, best_c < BIG)


@dataclasses.dataclass(frozen=True)
class MinExecution:
    """MET Phase-I: ignore queue state entirely, nominate the machine with
    the smallest raw EET entry."""

    kind = "min_execution"

    def nominate(self, ctx: SchedContext) -> Nomination:
        e_masked = jnp.where(
            ctx.alive[:, None] & ctx.qfree[None, :], ctx.exec_grid, BIG
        )
        best_m = jnp.argmin(e_masked, axis=1).astype(jnp.int32)
        best_e = jnp.min(e_masked, axis=1)
        return Nomination(best_m, best_e, best_e < BIG)


@dataclasses.dataclass(frozen=True)
class RandomMachine:
    """Pseudo-random nomination (hash of task index × event time) — the
    sanity-check lower bound. Full machines are filtered in Phase-II.

    The nomination value is the task index (arrival-order proxy), so
    composing with :class:`NominationValue` behaves like :class:`Fcfs`
    rather than silently nominating nothing.
    """

    kind = "random_hash"

    def nominate(self, ctx: SchedContext) -> Nomination:
        n, M = ctx.n_tasks, ctx.n_machines
        h = (jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)
             + (ctx.now * 1e3).astype(jnp.uint32)) % jnp.uint32(M)
        return Nomination(
            h.astype(jnp.int32), jnp.arange(n, dtype=jnp.float32), ctx.alive
        )


# --------------------------------------------------------------------------
# Phase-II keys (lower = better)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NominationValue:
    """Serve the nominee whose Phase-I value is smallest (ELARE: energy,
    MM: completion time, MET: execution time)."""

    kind = "value"

    def key(self, ctx: SchedContext, nom: Nomination) -> jnp.ndarray:
        return nom.value


@dataclasses.dataclass(frozen=True)
class SoonestDeadline:
    """MSD: earliest-deadline nominee first, Phase-I value as tie-break."""

    kind = "deadline"

    def key(self, ctx: SchedContext, nom: Nomination) -> jnp.ndarray:
        return ctx.deadline + 1e-6 * nom.value


@dataclasses.dataclass(frozen=True)
class MaxUrgency:
    """MMU: most-urgent nominee first, urgency = 1/(δ − now − e)."""

    kind = "urgency"

    def key(self, ctx: SchedContext, nom: Nomination) -> jnp.ndarray:
        e_best = jnp.take_along_axis(
            ctx.exec_grid, nom.best_machine[:, None], axis=1
        )[:, 0]
        return -equations.urgency(ctx.deadline, e_best, ctx.now)


@dataclasses.dataclass(frozen=True)
class Fcfs:
    """First-come-first-served: lowest task index (arrival-sorted traces
    make the index an arrival-order proxy)."""

    kind = "fcfs"

    def key(self, ctx: SchedContext, nom: Nomination) -> jnp.ndarray:
        return jnp.arange(ctx.n_tasks, dtype=jnp.float32)


# --------------------------------------------------------------------------
# Drop rules
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DropStale:
    """Purge only tasks whose deadline already passed (the baselines)."""

    kind = "stale"

    def drop(self, ctx: SchedContext) -> jnp.ndarray:
        return ctx.stale


@dataclasses.dataclass(frozen=True)
class DropStaleAndHopeless:
    """ELARE's proactive cancellation (Alg. 1): also drop tasks that would
    miss their deadline even on an instantly-free machine."""

    kind = "stale_hopeless"

    def drop(self, ctx: SchedContext) -> jnp.ndarray:
        return ctx.stale | ctx.hopeless
