"""The composable scheduling-policy algebra.

A mapping policy is assembled from three small pieces plus optional
wrappers, all speaking :class:`~repro.core.policy.context.SchedContext`:

  * :class:`Nominator` — Phase-I (Alg. 2): each pending task nominates one
    machine and reports the value it optimized (energy, completion, ...).
  * :class:`Phase2Key` — Phase-II (Alg. 3): the per-machine tie-break key a
    machine uses to pick among its nominees (lower = better).
  * :class:`DropRule` — which pending tasks to cancel proactively this event.
  * :func:`~repro.core.policy.fair.with_fairness` — Sec. V wrapper adding
    suffered-type priority and queue eviction (FELARE = fairness over ELARE).

:class:`TwoPhasePolicy` glues the three pieces together and is itself a
drop-in ``select_fn`` for the engine: calling it with the legacy positional
signature ``(now, pending, task_type, deadline, view, sysarr, suffered)``
returns a :class:`~repro.core.types.MapAction`. The shared Phase-II /
assigned-mask / drop epilogue lives exactly once, in :func:`phase2` and
:func:`finalize`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Protocol

import jax.numpy as jnp

from repro.core.policy.context import BIG, MachineView, SchedContext
from repro.core.types import MapAction, SystemArrays


class Nomination(NamedTuple):
    """Phase-I output: one nominated machine per task.

    best_machine: (N,) int32 — the machine each task nominates (garbage
      where ``valid`` is False).
    value: (N,) f32 — the quantity Phase-I minimized (expected energy for
      ELARE, completion time for MM/MSD/MMU, ...); ``BIG`` where invalid.
    valid: (N,) bool — task produced a nomination this event.
    """

    best_machine: jnp.ndarray
    value: jnp.ndarray
    valid: jnp.ndarray

    def grid(self, ctx: SchedContext) -> jnp.ndarray:
        """(N, M) bool nominee grid: task i nominates machine j."""
        return self.valid[:, None] & (
            self.best_machine[:, None] == ctx.machine_arange
        )


class Nominator(Protocol):
    """Phase-I: pick each task's machine. ``kind`` names the rule for the
    pure-Python oracle and ``--list`` output."""

    kind: str

    def nominate(self, ctx: SchedContext) -> Nomination: ...


class Phase2Key(Protocol):
    """Phase-II tie-break key (lower = better), one value per task."""

    kind: str

    def key(self, ctx: SchedContext, nom: Nomination) -> jnp.ndarray: ...


class DropRule(Protocol):
    """Which pending tasks to cancel proactively at this mapping event."""

    kind: str

    def drop(self, ctx: SchedContext) -> jnp.ndarray: ...


class PolicyDesc(NamedTuple):
    """Declarative description of a composed policy.

    This is how heuristics become *data*: the pure-Python oracle
    (:mod:`repro.core.pyengine`) interprets the same four fields with plain
    loops, so any composition of registered pieces is cross-checkable
    without writing a second implementation.
    """

    nominator: str
    phase2_key: str
    drop_rule: str
    fairness: bool = False
    backup_k: int = 0  # k-failure backup nominations (faults.with_backup)


class Policy(Protocol):
    """A mapping policy: legacy-positional callable returning a MapAction."""

    def __call__(self, now, pending, task_type, deadline, view, sysarr,
                 suffered) -> MapAction: ...

    def select(self, ctx: SchedContext) -> MapAction: ...


def phase2(nominee: jnp.ndarray, key: jnp.ndarray, qfree: jnp.ndarray):
    """Algorithm 3: per machine pick the nominee with the minimum key.

    nominee: (N, M) bool, key: (N, M) float (lower = better).
    Returns assign: (M,) int32 task index or -1.
    """
    masked = jnp.where(nominee, key, BIG)
    best_task = jnp.argmin(masked, axis=0)                     # (M,)
    has = (jnp.min(masked, axis=0) < BIG) & qfree
    return jnp.where(has, best_task.astype(jnp.int32), -1)


def finalize(ctx: SchedContext, assign: jnp.ndarray, drop: jnp.ndarray,
             queue_drop: Optional[jnp.ndarray] = None) -> MapAction:
    """Shared epilogue: never drop a task assigned this very event.

    The assigned-task mask is scattered once here (the block every legacy
    monolith used to copy) and the invariant ``assign ∩ drop = ∅`` holds by
    construction — see ``tests/test_policy.py``.
    """
    assigned_mask = jnp.zeros_like(ctx.pending).at[
        jnp.where(assign >= 0, assign, ctx.n_tasks)
    ].set(True, mode="drop")
    if queue_drop is None:
        queue_drop = jnp.zeros(ctx.view.queue.shape, bool)
    return MapAction(assign, drop & ~assigned_mask, queue_drop)


@dataclasses.dataclass(frozen=True)
class TwoPhasePolicy:
    """nominator × phase2_key × drop_rule — the paper's two-phase template.

    Frozen (hashable) so jit can close over policies statically; swap a
    piece with :func:`dataclasses.replace` or :meth:`with_phase1_impl`.
    """

    nominator: Nominator
    phase2_key: Phase2Key
    drop_rule: DropRule

    def select(self, ctx: SchedContext) -> MapAction:
        nom = self.nominator.nominate(ctx)
        nominee = nom.grid(ctx)
        key = jnp.broadcast_to(
            self.phase2_key.key(ctx, nom)[:, None], nominee.shape
        )
        assign = phase2(nominee, key, ctx.qfree)
        return finalize(ctx, assign, self.drop_rule.drop(ctx))

    def __call__(self, now, pending, task_type, deadline, view: MachineView,
                 sysarr: SystemArrays, suffered) -> MapAction:
        return self.select(SchedContext(
            now, pending, task_type, deadline, view, sysarr, suffered
        ))

    # -- introspection / variants ------------------------------------------
    def describe(self) -> PolicyDesc:
        return PolicyDesc(self.nominator.kind, self.phase2_key.kind,
                          self.drop_rule.kind, fairness=False)

    @property
    def supports_phase1_impl(self) -> bool:
        return hasattr(self.nominator, "with_impl")

    def with_phase1_impl(self, impl) -> "TwoPhasePolicy":
        """Swap the nominator's fused Phase-I implementation (e.g. the
        Pallas ``phase1_map`` kernel). No-op if the nominator has no hook."""
        if not self.supports_phase1_impl:
            return self
        return dataclasses.replace(
            self, nominator=self.nominator.with_impl(impl)
        )
