"""The Sec. V fairness wrapper: suffered-type priority + queue eviction.

``with_fairness(base)`` lifts any two-phase policy into its fairness-aware
variant; FELARE is exactly ``with_fairness(ELARE)``. The wrapper adds:

  1. Queue eviction for the earliest-deadline *rescuable* suffered task:
     non-suffered victims are dropped tail-first from its best-matching
     (fastest) machine until the task becomes feasible there — and only if
     the eviction actually rescues it.
  2. Priority Phase-II: suffered-type nominees are served first; machines
     left unassigned then serve the non-suffered nominees (keeps the
     collective completion rate from collapsing — Fig. 7's "negligible
     degradation").

Phase-I and the drop rule are the base policy's own, re-run against the
post-eviction machine state via ``SchedContext.with_view``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import equations
from repro.core.policy.base import (
    PolicyDesc,
    TwoPhasePolicy,
    finalize,
    phase2,
)
from repro.core.policy.context import (
    BIG,
    MachineView,
    SchedContext,
    queued_eet,
)
from repro.core.types import MapAction, SystemArrays


def _plan_eviction(ctx: SchedContext) -> jnp.ndarray:
    """(M, Q) bool eviction mask rescuing the most urgent suffered task.

    Candidates are suffered, currently infeasible on every free machine, and
    not hopeless on an empty machine (eviction cannot beat an empty
    machine). Victims are non-suffered queued tasks, taken tail-first from
    the target's fastest machine while the target still does not fit.
    """
    s, e, d = ctx.start_grid, ctx.exec_grid, ctx.deadline[:, None]
    feas_now = equations.feasible(s, e, d) & ctx.pending[:, None]
    task_feas_now = jnp.any(feas_now & ctx.qfree[None, :], axis=1)
    return _plan_eviction_from_stats(ctx, task_feas_now, ctx.min_exec)


def _plan_eviction_from_stats(ctx: SchedContext, task_feas_now, min_exec):
    """Eviction plan from precomputed per-task grid reductions.

    ``task_feas_now`` (N,) bool and ``min_exec`` (N,) f32 are the only two
    quantities :func:`_plan_eviction` derives from the (N, M) grid — the
    fused kernel path (``kernels/map_fused.evict_stats``) computes them in
    one pass and re-enters here, so the target/victim selection below is
    shared verbatim between the lax and kernel paths.
    """
    M, Q = ctx.view.queue.shape
    rescuable = (
        ctx.suffered_tasks
        & ~task_feas_now
        & (ctx.now + min_exec <= ctx.deadline)
    )
    cand_key = jnp.where(rescuable, ctx.deadline, BIG)
    tgt = jnp.argmin(cand_key).astype(jnp.int32)
    have_tgt = cand_key[tgt] < BIG

    # fastest (best-matching) machine for the target: min expected completion.
    comp_tgt = ctx.avail + ctx.sysarr.eet[ctx.task_type[tgt]]
    mstar = jnp.argmin(comp_tgt).astype(jnp.int32)

    # evict non-suffered victims tail-first until the target fits on mstar.
    q_eet = queued_eet(ctx.view, ctx.task_type, ctx.sysarr)        # (M, Q)
    row = ctx.view.queue[mstar]                                    # (Q,)
    occ = row >= 0
    victim_ok = occ & ~ctx.suffered[ctx.task_type[jnp.clip(row, 0)]]
    e_tgt = ctx.sysarr.eet[ctx.task_type[tgt], mstar]
    base = jnp.maximum(ctx.view.avail_base[mstar], ctx.now)
    # tail-first greedy: walk q = Q-1 .. 0, evicting while still infeasible.
    evict = jnp.zeros((Q,), bool)
    remaining = q_eet[mstar].sum()
    for q in range(Q - 1, -1, -1):
        start_if = base + remaining
        need = start_if + e_tgt > ctx.deadline[tgt]
        take = need & victim_ok[q]
        evict = evict.at[q].set(take)
        remaining = remaining - jnp.where(take, q_eet[mstar, q], 0.0)
    feasible_after = base + remaining + e_tgt <= ctx.deadline[tgt]
    evict = evict & feasible_after & have_tgt  # only evict if it rescues
    return jnp.zeros((M, Q), bool).at[mstar].set(evict)


def _evicted_view(ctx: SchedContext, qdrop) -> MachineView:
    """The post-eviction machine view the base policy re-runs against."""
    return MachineView(
        avail_base=ctx.view.avail_base,
        queue=jnp.where(qdrop, jnp.int32(-1), ctx.view.queue),
        qlen=ctx.view.qlen - qdrop.sum(axis=1).astype(ctx.view.qlen.dtype),
    )


@dataclasses.dataclass(frozen=True)
class FairnessPolicy:
    """A two-phase policy wrapped with the Sec. V fairness mechanisms."""

    base: TwoPhasePolicy

    def select(self, ctx: SchedContext) -> MapAction:
        qdrop = _plan_eviction(ctx)

        # Re-run the base policy's Phase-I against post-eviction state.
        ctx2 = ctx.with_view(_evicted_view(ctx, qdrop))
        nom = self.base.nominator.nominate(ctx2)
        nominee = nom.grid(ctx2)
        key = jnp.broadcast_to(
            self.base.phase2_key.key(ctx2, nom)[:, None], nominee.shape
        )

        # Priority Phase-II: suffered-type nominees claim machines first.
        hi = nominee & ctx.suffered_tasks[:, None]
        assign_hi = phase2(hi, key, ctx2.qfree)
        taken = assign_hi >= 0
        lo = nominee & ~ctx.suffered_tasks[:, None]
        assign_lo = phase2(lo, key, ctx2.qfree & ~taken)
        assign = jnp.where(taken, assign_hi, assign_lo)

        return finalize(ctx, assign, self.base.drop_rule.drop(ctx), qdrop)

    def __call__(self, now, pending, task_type, deadline, view: MachineView,
                 sysarr: SystemArrays, suffered) -> MapAction:
        return self.select(SchedContext(
            now, pending, task_type, deadline, view, sysarr, suffered
        ))

    # -- introspection / variants ------------------------------------------
    def describe(self) -> PolicyDesc:
        return self.base.describe()._replace(fairness=True)

    @property
    def supports_phase1_impl(self) -> bool:
        return self.base.supports_phase1_impl

    def with_phase1_impl(self, impl) -> "FairnessPolicy":
        return dataclasses.replace(
            self, base=self.base.with_phase1_impl(impl)
        )


def with_fairness(base: TwoPhasePolicy) -> FairnessPolicy:
    """Wrap ``base`` with suffered-type priority + queue eviction (Sec. V)."""
    return FairnessPolicy(base)
