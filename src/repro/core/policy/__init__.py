"""Composable scheduling-policy API.

The paper's whole contribution is a family of two-phase mapping heuristics;
this package expresses them as *data* — compositions of three small pieces
behind one typed surface:

    Policy = Nominator (Phase-I) × Phase2Key (Phase-II) × DropRule
             [× with_fairness  (Sec. V suffered-type priority + eviction)]

All eight paper heuristics are one-to-three-line compositions (see the
table in ``docs/heuristics.md``), registered by name in a mutable,
case-insensitive registry consumed by the engine, the pyengine oracle, the
experiments subsystem and the CLI. The Pallas ``phase1_map`` kernel plugs
in as a first-class nominator implementation via ``with_pallas_phase1``;
``with_pallas_map`` goes further and fuses the *whole* per-event map
decision (Phase-I + Phase-II + drop + fairness eviction stats) into one
``kernels/map_fused`` pass, bit-exact with the lax path.
"""
from __future__ import annotations

from repro.core.policy.base import (
    DropRule,
    Nomination,
    Nominator,
    Phase2Key,
    Policy,
    PolicyDesc,
    TwoPhasePolicy,
    finalize,
    phase2,
)
from repro.core.policy.components import (
    DropStale,
    DropStaleAndHopeless,
    Fcfs,
    MaxUrgency,
    MinCompletion,
    MinEnergyFeasible,
    MinExecution,
    NominationValue,
    RandomMachine,
    SoonestDeadline,
)
from repro.core.policy.context import (
    BIG,
    MachineView,
    SchedContext,
    avail_time,
    queued_eet,
)
from repro.core.policy.fair import FairnessPolicy, with_fairness
from repro.core.policy.fused import FusedMapPolicy, supports_fused_map
from repro.core.policy.registry import (
    get,
    is_registered,
    list_policies,
    register,
    unregister,
)

__all__ = [
    "BIG",
    "DropRule",
    "DropStale",
    "DropStaleAndHopeless",
    "FairnessPolicy",
    "Fcfs",
    "FusedMapPolicy",
    "MachineView",
    "MaxUrgency",
    "MinCompletion",
    "MinEnergyFeasible",
    "MinExecution",
    "Nomination",
    "Nominator",
    "NominationValue",
    "Phase2Key",
    "Policy",
    "PolicyDesc",
    "RandomMachine",
    "SchedContext",
    "SoonestDeadline",
    "TwoPhasePolicy",
    "avail_time",
    "describe",
    "finalize",
    "get",
    "is_registered",
    "list_policies",
    "phase2",
    "queued_eet",
    "register",
    "supports_fused_map",
    "unregister",
    "with_fairness",
    "with_pallas_map",
    "with_pallas_phase1",
]


def describe(name_or_policy) -> PolicyDesc:
    """The declarative (nominator, key, drop, fairness) description of a
    policy — what the pure-Python oracle interprets.

    Raises TypeError for opaque policies (custom callables without a
    ``describe`` method): those run through the JAX engine but have no
    oracle interpretation.
    """
    pol = get(name_or_policy) if isinstance(name_or_policy, str) else name_or_policy
    fn = getattr(pol, "describe", None)
    if fn is None:
        raise TypeError(
            f"policy {pol!r} is opaque (no .describe()); the pure-Python "
            f"oracle can only interpret composed policies"
        )
    return fn()


def with_pallas_phase1(pol: Policy, interpret=None) -> Policy:
    """Swap a policy's Phase-I onto the fused Pallas ``phase1_map`` kernel.

    No-op for policies whose nominator has no fused implementation hook
    (matching the legacy behaviour where only ELARE/FELARE had one).
    The backend (compiled vs interpreter) is resolved here, once, at
    construction — never inside the jitted select (JD003).
    """
    if not getattr(pol, "supports_phase1_impl", False):
        return pol
    import functools

    from repro.kernels.pallas_backend import default_interpret
    from repro.kernels.phase1_map.ops import phase1_map

    if interpret is None:
        interpret = default_interpret()
    return pol.with_phase1_impl(
        functools.partial(phase1_map, interpret=bool(interpret))
    )


def with_pallas_map(pol: Policy, interpret=None) -> Policy:
    """Run a policy's whole map decision as one fused Pallas kernel pass.

    Wraps composed policies (``TwoPhasePolicy``, fairness- and
    backup-wrapped variants) in :class:`FusedMapPolicy`; the lax path
    stays the default everywhere else. No-op for policies outside the
    kernel's kind space (custom nominators/keys/drops) or opaque
    callables, mirroring :func:`with_pallas_phase1`.

    ``interpret=None`` resolves the backend once, here at construction
    (:func:`repro.kernels.pallas_backend.default_interpret`): compiled on
    TPU/GPU, interpreter on CPU, env override ``REPRO_PALLAS_INTERPRET``.
    """
    import dataclasses as _dc

    from repro.core.faults.backup import BackupPolicy

    if isinstance(pol, str):
        pol = get(pol)
    if isinstance(pol, BackupPolicy):
        # Mapping is pure delegation there; the engine reads backup_k off
        # the outer wrapper, so rewrap the inner policy and keep k.
        return _dc.replace(pol, base=with_pallas_map(pol.base, interpret))
    fn = getattr(pol, "describe", None)
    if fn is None or not supports_fused_map(fn()):
        return pol
    if interpret is None:
        from repro.kernels.pallas_backend import default_interpret

        interpret = default_interpret()
    return FusedMapPolicy(pol, bool(interpret))


# --------------------------------------------------------------------------
# The eight paper heuristics as compositions (Secs. IV-VI).
# --------------------------------------------------------------------------
ELARE = TwoPhasePolicy(MinEnergyFeasible(), NominationValue(),
                       DropStaleAndHopeless())
FELARE = with_fairness(ELARE)
MM = TwoPhasePolicy(MinCompletion(), NominationValue(), DropStale())
MSD = TwoPhasePolicy(MinCompletion(), SoonestDeadline(), DropStale())
MMU = TwoPhasePolicy(MinCompletion(), MaxUrgency(), DropStale())
MET = TwoPhasePolicy(MinExecution(), NominationValue(), DropStale())
MCT = TwoPhasePolicy(MinCompletion(), Fcfs(), DropStale())
RANDOM = TwoPhasePolicy(RandomMachine(), Fcfs(), DropStale())

for _name, _pol in [
    ("ELARE", ELARE), ("FELARE", FELARE), ("MM", MM), ("MSD", MSD),
    ("MMU", MMU), ("MET", MET), ("MCT", MCT), ("RANDOM", RANDOM),
]:
    register(_name, _pol)
del _name, _pol
