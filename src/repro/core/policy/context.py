"""Scheduling context: everything a mapping policy may look at, computed once.

The legacy heuristics threaded a 7-positional-argument convention
``(now, pending, task_type, deadline, view, sysarr, suffered)`` through every
helper and recomputed the (N, M) start/exec grids, the free-slot mask and the
stale/hopeless masks in every sub-step. :class:`SchedContext` freezes that
tuple into one object and caches each derived grid the first time a policy
component asks for it, so one mapping event computes each grid exactly once
(and under ``jit`` the trace contains one instance of each op).

Shapes follow the paper: N tasks, M machines, Q local-queue slots, S types.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax.numpy as jnp

from repro.core.types import SystemArrays

BIG = jnp.float32(1e30)


class MachineView(NamedTuple):
    """Scheduler-visible machine state at a mapping event."""

    avail_base: jnp.ndarray  # (M,) max(now, expected end of running task)
    queue: jnp.ndarray       # (M, Q) int32 task idx, -1 = empty, FCFS order
    qlen: jnp.ndarray        # (M,) int32


def queued_eet(view: MachineView, task_type, sysarr: SystemArrays):
    """(M, Q) expected execution time of each queued task on its machine."""
    M, Q = view.queue.shape
    occ = view.queue >= 0
    ttype = jnp.where(occ, task_type[jnp.clip(view.queue, 0)], 0)
    cols = jnp.arange(M)[:, None]
    e = sysarr.eet[ttype, jnp.broadcast_to(cols, (M, Q))]
    return jnp.where(occ, e, 0.0)


def avail_time(view: MachineView, task_type, sysarr: SystemArrays):
    """(M,) expected time each machine can start a newly-appended task."""
    return view.avail_base + queued_eet(view, task_type, sysarr).sum(axis=1)


@dataclasses.dataclass(frozen=True)
class SchedContext:
    """Frozen snapshot of one mapping event.

    Constructor fields are the raw scheduler inputs; every derived quantity
    is a ``cached_property`` so policies can compose freely without paying
    for grids they do not read (or paying twice for grids they share).
    """

    now: jnp.ndarray         # () f32 current event time
    pending: jnp.ndarray     # (N,) bool — task is in the arriving queue
    task_type: jnp.ndarray   # (N,) int32
    deadline: jnp.ndarray    # (N,) f32
    view: MachineView
    sysarr: SystemArrays
    suffered: jnp.ndarray    # (S,) bool — fairness monitor (Alg. 4)

    # -- static shapes ------------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return self.pending.shape[0]

    @property
    def n_machines(self) -> int:
        return self.sysarr.eet.shape[1]

    @property
    def queue_slots(self) -> int:
        return self.view.queue.shape[1]

    # -- derived machine state ---------------------------------------------
    @functools.cached_property
    def qfree(self):
        """(M,) bool — machine has at least one free local-queue slot."""
        return self.view.qlen < self.queue_slots

    @functools.cached_property
    def avail(self):
        """(M,) f32 — expected start time of a newly-appended task."""
        return avail_time(self.view, self.task_type, self.sysarr)

    @functools.cached_property
    def start(self):
        """(M,) f32 — mapping-event start times: max(avail, now)."""
        return jnp.maximum(self.avail, self.now)

    @functools.cached_property
    def machine_arange(self):
        """(1, M) int32 — broadcast helper for nominee grids."""
        return jnp.arange(self.n_machines)[None, :]

    # -- derived (N, M) pair grids -----------------------------------------
    @functools.cached_property
    def exec_grid(self):
        """(N, M) f32 — expected execution time of each task on each machine."""
        return self.sysarr.eet[self.task_type]

    @functools.cached_property
    def start_grid(self):
        """(N, M) f32 — :attr:`start` broadcast across tasks."""
        return jnp.broadcast_to(self.start[None, :], self.exec_grid.shape)

    # -- derived task masks ------------------------------------------------
    @functools.cached_property
    def stale(self):
        """(N,) bool — pending and past its deadline (must be purged)."""
        return self.pending & (self.now >= self.deadline)

    @functools.cached_property
    def alive(self):
        """(N,) bool — pending and not yet stale."""
        return self.pending & ~self.stale

    @functools.cached_property
    def min_exec(self):
        """(N,) f32 — each task's execution time on its fastest machine."""
        return self.exec_grid.min(axis=1)

    @functools.cached_property
    def hopeless(self):
        """(N,) bool — would miss its deadline even on an idle machine.

        ELARE's proactive-cancellation predicate (Alg. 1): deferring such a
        task cannot help, so drop rules may cancel it now instead of burning
        mapping events until staleness.
        """
        return self.pending & (self.now + self.min_exec > self.deadline)

    @functools.cached_property
    def suffered_tasks(self):
        """(N,) bool — pending tasks whose type is currently suffered."""
        return self.suffered[self.task_type] & self.pending

    # -- derived contexts --------------------------------------------------
    def with_view(self, view: MachineView) -> "SchedContext":
        """A fresh context over modified machine state (e.g. post-eviction).

        All cached grids are recomputed lazily against the new view.
        """
        return dataclasses.replace(self, view=view)

    def with_qfree(self, qfree) -> "SchedContext":
        """A fresh context whose free-slot mask is overridden.

        For legacy callers that computed ``qfree`` themselves (the old
        ``elare_phase1`` signature). Kept here, next to the
        ``cached_property`` it pre-seeds, so a refactor of :attr:`qfree`
        cannot miss it.
        """
        ctx = dataclasses.replace(self)
        ctx.__dict__["qfree"] = qfree
        return ctx
