"""Mutable, case-insensitive policy registry.

Policies are addressed by name everywhere — ``SweepSpec.heuristics``, the
sweep CLI, ``engine.simulate``, the pyengine oracle — so registering a new
policy here makes it flow through the entire one-jit sweep machinery
untouched:

    from repro.core import policy

    my_policy = policy.TwoPhasePolicy(
        policy.MinCompletion(), policy.SoonestDeadline(),
        policy.DropStaleAndHopeless(),
    )
    policy.register("MSD+", my_policy)
    # ... SweepSpec(heuristics=("MSD+", "FELARE")) now just works.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.policy.base import Policy

_REGISTRY: Dict[str, Policy] = {}


def _canon(name: str) -> str:
    if not isinstance(name, str) or not name.strip():
        raise ValueError(f"policy name must be a non-empty string, got {name!r}")
    return name.strip().upper()


def register(name: str, policy: Policy, *, overwrite: bool = False) -> Policy:
    """Register ``policy`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True`` —
    silently shadowing a built-in (or a colleague's policy) is the kind of
    spooky action a registry should refuse by default.

    Returns the policy, so registration can be used expression-style.
    """
    key = _canon(name)
    if not callable(policy):
        raise TypeError(f"policy {name!r} must be callable, got {policy!r}")
    if key in _REGISTRY and not overwrite:
        raise ValueError(
            f"policy {name!r} is already registered; pass overwrite=True "
            f"to replace it"
        )
    _REGISTRY[key] = policy
    return policy


def unregister(name: str) -> None:
    """Remove a registered policy (KeyError if absent)."""
    key = _canon(name)
    if key not in _REGISTRY:
        raise KeyError(f"policy {name!r} is not registered")
    del _REGISTRY[key]


def is_registered(name: str) -> bool:
    try:
        return _canon(name) in _REGISTRY
    except ValueError:
        return False


def get(name: str) -> Policy:
    """Resolve a policy by (case-insensitive) name.

    Raises KeyError listing the available policies — the same error
    surface the legacy ``heuristics.get`` had.
    """
    try:
        return _REGISTRY[_canon(name)]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; choose from {list_policies()}"
        ) from None


def list_policies() -> List[str]:
    """Sorted names of every registered policy."""
    return sorted(_REGISTRY)
