"""Mutable, case-insensitive policy registry.

Policies are addressed by name everywhere — ``SweepSpec.heuristics``, the
sweep CLI, ``engine.simulate``, the pyengine oracle — so registering a new
policy here makes it flow through the entire one-jit sweep machinery
untouched:

    from repro.core import policy

    my_policy = policy.TwoPhasePolicy(
        policy.MinCompletion(), policy.SoonestDeadline(),
        policy.DropStaleAndHopeless(),
    )
    policy.register("MSD+", my_policy)
    # ... SweepSpec(heuristics=("MSD+", "FELARE")) now just works.

The mechanics (canonicalization, shadowing protection, unknown-name
errors) live in the shared :class:`repro.core.registry.NameRegistry`,
the same machinery behind the scenario and fleet registries.
"""
from __future__ import annotations

from typing import List

from repro.core.policy.base import Policy
from repro.core.registry import NameRegistry


def _check(name, policy) -> None:
    if not callable(policy):
        raise TypeError(f"policy {name!r} must be callable, got {policy!r}")


_REGISTRY = NameRegistry("policy", case=str.upper, check=_check)


def register(name: str, policy: Policy, *, overwrite: bool = False) -> Policy:
    """Register ``policy`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True``.
    Returns the policy, so registration can be used expression-style.
    """
    return _REGISTRY.register(name, policy, overwrite=overwrite)


def unregister(name: str) -> None:
    """Remove a registered policy (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get(name: str) -> Policy:
    """Resolve a policy by (case-insensitive) name.

    Raises KeyError listing the available policies — the same error
    surface the legacy ``heuristics.get`` had.
    """
    return _REGISTRY.get(name)


def list_policies() -> List[str]:
    """Sorted names of every registered policy."""
    return _REGISTRY.names()
