"""Fused-kernel mapping: the whole per-event decision as one Pallas pass.

:class:`FusedMapPolicy` wraps a composed two-phase policy (optionally
fairness-wrapped) and replaces its multi-pass lax ``select`` with calls
into ``kernels/map_fused``:

  * non-fair: one ``map_decide`` kernel pass computes Phase-I nomination,
    the drop mask, and per-machine Phase-II running argmins; the Phase-II
    assignment is a three-line lax epilogue over the (M,) kernel outputs.
  * fair (FELARE): an ``evict_stats`` pass yields the two per-task grid
    reductions the Sec. V eviction planner needs; the shared
    ``fair._plan_eviction_from_stats`` plans the eviction, and the same
    ``map_decide`` pass then runs against the post-eviction view with the
    suffered split live — the priority Phase-II becomes a ``where`` chain
    over the hi/lo kernel argmins.

The wrapper is bit-exact with the lax path (pinned by
``tests/test_map_fused.py``): every kernel expression mirrors
``components.py``/``base.py:phase2`` op for op, and the drop rule is
view-independent so computing it inside the post-eviction kernel pass
still equals ``drop_rule.drop(ctx)`` on the pre-eviction context.

``interpret`` is resolved once at construction
(:func:`repro.kernels.pallas_backend.default_interpret`), never inside
the jitted ``select`` (analyzer rule JD003).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.policy import fair as fair_mod
from repro.core.policy.base import BIG, PolicyDesc, finalize
from repro.core.policy.context import MachineView, SchedContext
from repro.core.types import MapAction, SystemArrays

#: Kinds the fused kernel implements (mirrors kernels/map_fused/kernel.py;
#: imported lazily there to keep policy import free of jax.experimental).
SUPPORTED_NOMINATORS = ("min_energy_feasible", "min_completion",
                        "min_execution", "random_hash")
SUPPORTED_KEYS = ("value", "deadline", "urgency", "fcfs")
SUPPORTED_DROPS = ("stale", "stale_hopeless")


def supports_fused_map(desc: PolicyDesc) -> bool:
    """Is this composed policy within the fused kernel's kind space?"""
    return (desc.nominator in SUPPORTED_NOMINATORS
            and desc.phase2_key in SUPPORTED_KEYS
            and desc.drop_rule in SUPPORTED_DROPS)


@dataclasses.dataclass(frozen=True)
class FusedMapPolicy:
    """A composed policy whose map decision runs as one fused kernel pass.

    ``base`` is the wrapped :class:`TwoPhasePolicy` or
    :class:`~repro.core.policy.fair.FairnessPolicy`; its ``describe()``
    kinds select the kernel's static specialization. Frozen and hashable
    like every policy so jit closes over it statically.
    """

    base: object
    interpret: bool

    def __post_init__(self):
        desc = self.base.describe()
        if not supports_fused_map(desc):
            raise ValueError(
                f"fused map kernel does not implement {desc!r}; "
                f"use with_pallas_map() which no-ops on unsupported policies"
            )

    def select(self, ctx: SchedContext) -> MapAction:
        from repro.kernels import map_fused

        desc = self.base.describe()
        if desc.fairness:
            task_feas_now, min_exec = map_fused.evict_stats(
                ctx.start, ctx.qfree, ctx.sysarr.eet, ctx.deadline,
                ctx.pending, ctx.task_type, interpret=self.interpret)
            qdrop = fair_mod._plan_eviction_from_stats(
                ctx, task_feas_now, min_exec)
            ctx2 = ctx.with_view(fair_mod._evicted_view(ctx, qdrop))
            suffered_task = ctx.suffered_tasks
        else:
            qdrop = None
            ctx2 = ctx
            # Empty hi pool: the priority epilogue degenerates to the
            # plain Phase-II argmin over all nominees.
            suffered_task = jnp.zeros_like(ctx.pending)

        drop, hi_key, hi_task, lo_key, lo_task = map_fused.map_decide(
            ctx.now, ctx2.start, ctx.sysarr.p_dyn, ctx2.qfree,
            ctx.sysarr.eet, ctx.deadline, ctx.pending, ctx.task_type,
            suffered_task, nominator=desc.nominator,
            phase2_key=desc.phase2_key, drop_rule=desc.drop_rule,
            interpret=self.interpret)

        # Priority Phase-II epilogue over the per-machine running argmins
        # (== base.py:phase2 / fair.py's hi-then-lo chain).
        qfree2 = ctx2.qfree
        assign_hi = jnp.where((hi_key < BIG) & qfree2, hi_task,
                              jnp.int32(-1))
        taken = assign_hi >= 0
        assign_lo = jnp.where((lo_key < BIG) & qfree2 & ~taken, lo_task,
                              jnp.int32(-1))
        assign = jnp.where(taken, assign_hi, assign_lo)
        return finalize(ctx, assign, drop, qdrop)

    def __call__(self, now, pending, task_type, deadline, view: MachineView,
                 sysarr: SystemArrays, suffered) -> MapAction:
        return self.select(SchedContext(
            now, pending, task_type, deadline, view, sysarr, suffered
        ))

    # -- introspection / variants ------------------------------------------
    def describe(self) -> PolicyDesc:
        return self.base.describe()

    @property
    def supports_phase1_impl(self) -> bool:
        # Phase-I is already inside the fused kernel; the phase1_map hook
        # does not compose on top.
        return False

    def with_phase1_impl(self, impl) -> "FusedMapPolicy":
        return self
