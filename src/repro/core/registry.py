"""Generic name → item registry.

The repo addresses three open-ended axes by name — mapping policies,
workload scenarios, and fleet builders — and all three want the same
behaviour: case-insensitive lookup, refuse-to-shadow registration,
helpful unknown-name errors listing what *is* registered.
:class:`NameRegistry` implements that once; each axis instantiates it
with its label, case convention, and item check, and keeps its existing
module-level function surface as thin wrappers.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional


class NameRegistry:
    """A mutable, case-insensitive mapping from names to items.

    Args:
      label: what an item is called in error messages ("policy",
        "scenario", "fleet", ...).
      case: canonical-form function (``str.upper`` or ``str.lower``).
      check: optional ``check(name, item)`` raising TypeError for items
        that don't belong in this registry.
    """

    def __init__(self, label: str, *, case: Callable[[str], str] = str.upper,
                 check: Optional[Callable[[str, Any], None]] = None):
        self._label = label
        self._case = case
        self._check = check
        self._items: Dict[str, Any] = {}

    def canon(self, name: str) -> str:
        if not isinstance(name, str) or not name.strip():
            raise ValueError(
                f"{self._label} name must be a non-empty string, "
                f"got {name!r}"
            )
        return self._case(name.strip())

    def register(self, name: str, item, *, overwrite: bool = False):
        """Register ``item`` under ``name`` (case-insensitive).

        Re-registering an existing name raises unless ``overwrite=True``
        — silently shadowing a built-in (or a colleague's entry) is the
        kind of spooky action a registry should refuse by default.

        Returns the item, so registration can be used expression-style.
        """
        key = self.canon(name)
        if self._check is not None:
            self._check(name, item)
        if key in self._items and not overwrite:
            raise ValueError(
                f"{self._label} {name!r} is already registered; pass "
                f"overwrite=True to replace it"
            )
        self._items[key] = item
        return item

    def unregister(self, name: str) -> None:
        """Remove a registered item (KeyError if absent)."""
        key = self.canon(name)
        if key not in self._items:
            raise KeyError(f"{self._label} {name!r} is not registered")
        del self._items[key]

    def is_registered(self, name: str) -> bool:
        try:
            return self.canon(name) in self._items
        except ValueError:
            return False

    def get(self, name: str):
        """Resolve an item by (case-insensitive) name, or raise KeyError
        listing every registered name."""
        try:
            return self._items[self.canon(name)]
        except KeyError:
            raise KeyError(
                f"unknown {self._label} {name!r}; "
                f"choose from {self.names()}"
            ) from None

    def names(self) -> List[str]:
        """Sorted names of every registered item."""
        return sorted(self._items)
