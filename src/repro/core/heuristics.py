"""Deprecation shim over :mod:`repro.core.policy`.

The eight monolithic heuristic functions that used to live here are now
1-3-line compositions over the policy algebra (Phase-I nominators ×
Phase-II keys × drop rules, with FELARE = ``with_fairness(ELARE)``) — see
``repro/core/policy/`` and ``docs/heuristics.md``. This module keeps the
legacy surface alive for existing callers:

  * ``get(name)`` / ``HEURISTICS`` — now views over the mutable policy
    registry, so user-registered policies appear here too;
  * ``elare_select`` / ``felare_select`` / ... — the old per-heuristic
    callables (policies are drop-in ``select_fn``s already; the ELARE pair
    keeps its ``phase1_impl`` keyword);
  * ``MachineView`` / ``queued_eet`` / ``avail_time`` / ``elare_phase1`` —
    re-exports for engine/kernel-test consumers.

New code should import from :mod:`repro.core.policy` directly.
"""
from __future__ import annotations

from typing import Callable, Iterator, Mapping

from repro.core import policy
from repro.core.policy import (  # re-exported legacy surface
    BIG,
    MachineView,
    SchedContext,
    avail_time,
    queued_eet,
)
from repro.core.types import MapAction

__all__ = [
    "BIG",
    "HEURISTICS",
    "MachineView",
    "SchedContext",
    "avail_time",
    "elare_phase1",
    "elare_select",
    "felare_select",
    "get",
    "mct_select",
    "met_select",
    "mm_select",
    "mmu_select",
    "msd_select",
    "queued_eet",
    "random_select",
]


def get(name: str) -> Callable:
    """Resolve a mapping policy by name (deprecated: use ``policy.get``)."""
    return policy.get(name)


class _RegistryView(Mapping):
    """Live read-only mapping view of the policy registry (legacy
    ``HEURISTICS`` dict surface: iteration, ``in``, ``.values()``...)."""

    def __getitem__(self, name: str):
        return policy.get(name)

    def __iter__(self) -> Iterator[str]:
        return iter(policy.list_policies())

    def __len__(self) -> int:
        return len(policy.list_policies())

    def __repr__(self) -> str:
        return f"HEURISTICS({policy.list_policies()})"


HEURISTICS: Mapping[str, Callable] = _RegistryView()


# --------------------------------------------------------------------------
# Legacy per-heuristic callables.
# --------------------------------------------------------------------------
def elare_select(now, pending, task_type, deadline, view, sysarr, suffered,
                 *, phase1_impl=None) -> MapAction:
    """ELARE (Algorithms 1-3): min-energy-feasible × min-value × proactive
    drops. ``phase1_impl`` keeps the legacy Pallas hook alive."""
    pol = policy.ELARE
    if phase1_impl is not None:
        pol = pol.with_phase1_impl(phase1_impl)
    return pol(now, pending, task_type, deadline, view, sysarr, suffered)


def felare_select(now, pending, task_type, deadline, view, sysarr, suffered,
                  *, phase1_impl=None) -> MapAction:
    """FELARE (Sec. V) = ``with_fairness(ELARE)``."""
    pol = policy.FELARE
    if phase1_impl is not None:
        pol = pol.with_phase1_impl(phase1_impl)
    return pol(now, pending, task_type, deadline, view, sysarr, suffered)


mm_select = policy.MM
msd_select = policy.MSD
mmu_select = policy.MMU
met_select = policy.MET
mct_select = policy.MCT
random_select = policy.RANDOM


def elare_phase1(now, pending, task_type, deadline, view, sysarr, qfree,
                 phase1_impl=None):
    """Legacy Phase-I entry point (kernel tests / external callers).

    Returns ``(best_machine (N,), best_ec (N,), task_feasible (N,), s, e)``
    exactly as before; now a thin wrapper over the
    :class:`~repro.core.policy.MinEnergyFeasible` nominator.
    """
    import jax.numpy as jnp

    ctx = SchedContext(now, pending, task_type, deadline, view, sysarr,
                       jnp.zeros(sysarr.eet.shape[0], bool)).with_qfree(qfree)
    nom = policy.MinEnergyFeasible(impl=phase1_impl).nominate(ctx)
    return nom.best_machine, nom.value, nom.valid, ctx.start_grid, ctx.exec_grid
