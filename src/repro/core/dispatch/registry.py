"""Mutable, case-insensitive dispatcher registry.

Dispatchers are addressed by name everywhere — ``SweepSpec.dispatcher``,
the sweep CLI's ``--dispatcher``, ``engine.simulate(dispatcher=...)`` —
so registering one here makes it flow through the single-jit sweep
machinery untouched:

    from repro.core import dispatch

    dispatch.register("sticky-7", dispatch.Sticky(salt=7))
    # ... SweepSpec(system="paper_x2", dispatcher="sticky-7") just works.

The mechanics live in the shared
:class:`repro.core.registry.NameRegistry` (also behind the policy,
scenario, fleet and observer registries).
"""
from __future__ import annotations

from typing import List

from repro.core.registry import NameRegistry


def _check(name, dispatcher) -> None:
    if not callable(getattr(dispatcher, "dispatch", None)):
        raise TypeError(
            f"dispatcher {name!r} must implement the Dispatcher protocol "
            f"(a .dispatch(ctx) method); got {dispatcher!r}"
        )


_REGISTRY = NameRegistry("dispatcher", case=str.lower, check=_check)


def register(name: str, dispatcher, *, overwrite: bool = False):
    """Register ``dispatcher`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True``.
    Returns the dispatcher, so registration can be used expression-style.
    """
    return _REGISTRY.register(name, dispatcher, overwrite=overwrite)


def unregister(name: str) -> None:
    """Remove a registered dispatcher (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get(name: str):
    """Resolve a dispatcher by (case-insensitive) name, or raise KeyError
    listing every registered name."""
    return _REGISTRY.get(name)


def list_dispatchers() -> List[str]:
    """Sorted names of every registered dispatcher."""
    return _REGISTRY.names()
