"""Registry-backed two-level dispatch: which *site* serves each task.

The paper evaluates FELARE on one flat 4-machine system, but its setting
— battery-powered edge sites serving concurrent latency-sensitive ML
traffic — is inherently multi-site. This package is the federation's
first level, mirroring the policy algebra one layer up:

    Federation = Dispatcher (task -> site)  ×  Policy (task -> machine)

A :class:`Dispatcher` picks the site of each newly-admitted task at the
engine's ``dispatch`` stage; the per-site mapping policy then runs under
a site-masked machine view. Built-ins:

  * ``sticky`` — load-blind hash home, fixed at admission (the default;
    the identity on single-site systems);
  * ``round_robin`` — arrival-order rotation across sites;
  * ``least_queued`` — join-the-shortest-site (queued + running);
  * ``min_eet`` — EET-aware cheapest site for the task's type;
  * ``fair_spill`` — sticky homes, but Alg. 4 *suffered* types spill to
    the least-loaded site (FELARE's fairness signal at dispatch level);
  * ``health_aware`` — sticky homes, but tasks whose home site is down
    (per the faults subsystem's heartbeat mask) re-route to the
    least-loaded healthy site;
  * ``tier_aware`` — ``min_eet`` plus the network subsystem's transfer
    latency: the cheapest site *including the cost of getting there*.

All are frozen hashable dataclasses behind the shared
:class:`~repro.core.registry.NameRegistry`, interpreted by the pure-
Python oracle, and serialize to JSON by kind + fields. See
``docs/federation.md`` for the stage contract and a worked
writing-a-dispatcher example.
"""
from __future__ import annotations

from repro.core.dispatch.base import (
    DispatchContext,
    Dispatcher,
    sequential_balance,
)
from repro.core.dispatch.builtins import (
    FairSpill,
    HealthAware,
    LeastQueued,
    MinEet,
    RoundRobin,
    Sticky,
    TierAware,
)
from repro.core.dispatch.registry import (
    get,
    is_registered,
    list_dispatchers,
    register,
    unregister,
)

__all__ = [
    "DispatchContext",
    "Dispatcher",
    "FairSpill",
    "HealthAware",
    "LeastQueued",
    "MinEet",
    "RoundRobin",
    "Sticky",
    "TierAware",
    "describe",
    "from_json_dict",
    "get",
    "is_registered",
    "list_dispatchers",
    "register",
    "resolve",
    "sequential_balance",
    "to_json_dict",
    "unregister",
    "with_pallas_balance",
]

#: JSON ``kind`` -> built-in dispatcher class, for spec round-tripping.
_KINDS = {
    "sticky": Sticky,
    "round_robin": RoundRobin,
    "least_queued": LeastQueued,
    "min_eet": MinEet,
    "fair_spill": FairSpill,
    "health_aware": HealthAware,
    "tier_aware": TierAware,
}


def resolve(dispatcher) -> Dispatcher:
    """Normalize a name-or-instance to a Dispatcher instance.

    ``None`` resolves to the default :class:`Sticky`; strings resolve
    through the registry (KeyError on unknown names lists what is
    registered).
    """
    if dispatcher is None:
        return Sticky()
    if isinstance(dispatcher, str):
        return get(dispatcher)
    if not callable(getattr(dispatcher, "dispatch", None)):
        raise TypeError(
            f"dispatcher must be a registered name or implement the "
            f"Dispatcher protocol, got {dispatcher!r}"
        )
    return dispatcher


def describe(name_or_dispatcher) -> str:
    """One-line human description (for ``--list-dispatchers``)."""
    d = resolve(name_or_dispatcher)
    doc = (d.__class__.__doc__ or "").strip().splitlines()
    head = doc[0].rstrip(".") if doc else d.__class__.__name__
    return head


def to_json_dict(dispatcher) -> dict:
    """``{"kind": ..., <param>: ...}`` for a built-in-style dispatcher.

    Ephemeral callable fields (``balance_impl`` — the fused-kernel hook)
    are skipped: a serialized spec round-trips to the default lax scan,
    and the runner re-applies ``with_pallas_balance`` from its own flag.
    """
    import dataclasses

    d = resolve(dispatcher)
    out = {"kind": d.kind}
    for f in dataclasses.fields(d):
        v = getattr(d, f.name)
        if v is None or callable(v):
            continue
        out[f.name] = v
    return out


def with_pallas_balance(dispatcher, interpret=None) -> Dispatcher:
    """Swap a dispatcher's sequential balance scan onto the fused Pallas
    kernel (``kernels/map_fused.balance_scan``), bit-exact with the lax
    ``lax.scan`` walk.

    No-op for dispatchers without a ``balance_impl`` hook (``sticky``,
    ``round_robin``, ``min_eet``, ``tier_aware`` never run the scan).
    ``interpret=None`` resolves the backend once, at construction
    (compiled on TPU/GPU, interpreter on CPU, env override
    ``REPRO_PALLAS_INTERPRET``) — mirroring ``policy.with_pallas_map``.
    """
    import dataclasses
    import functools

    d = resolve(dispatcher)
    if (not dataclasses.is_dataclass(d)
            or "balance_impl" not in {f.name for f in dataclasses.fields(d)}):
        return d
    if interpret is None:
        from repro.kernels.pallas_backend import default_interpret

        interpret = default_interpret()
    from repro.kernels.map_fused import balance_scan

    impl = functools.partial(balance_scan, interpret=bool(interpret))
    return dataclasses.replace(d, balance_impl=impl)


def from_json_dict(d: dict) -> Dispatcher:
    """Rebuild a built-in dispatcher from its :func:`to_json_dict` form."""
    kind = d.get("kind")
    cls = _KINDS.get(kind)
    if cls is None:
        raise ValueError(
            f"unknown dispatcher kind {kind!r}; choose from {sorted(_KINDS)}"
        )
    return cls(**{k: v for k, v in d.items() if k != "kind"})


for _name, _disp in [
    ("sticky", Sticky()),
    ("round_robin", RoundRobin()),
    ("least_queued", LeastQueued()),
    ("min_eet", MinEet()),
    ("fair_spill", FairSpill()),
    ("health_aware", HealthAware()),
    ("tier_aware", TierAware()),
]:
    register(_name, _disp)
del _name, _disp
