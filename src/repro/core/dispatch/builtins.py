"""Built-in dispatchers: the five site-selection rules.

Each is a frozen (hashable) dataclass the engine closes over statically —
attaching a dispatcher never retraces per call — and each is *data*: the
pure-Python oracle (:mod:`repro.core.pyengine`) interprets ``kind`` + the
dataclass fields with plain loops, so every built-in is cross-checkable
event-for-event.

All dispatchers are dispatch-once: a task's site is chosen the first time
it is pending and never migrates (sticky in the Madej et al. sense); they
differ in how the one-shot choice is made.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.dispatch.base import DispatchContext, sequential_balance


def _hash_sites(n_tasks: int, n_sites: int, salt: int) -> jnp.ndarray:
    """(N,) int32 static multiplicative-hash home sites (uint32 wrap)."""
    h = (jnp.arange(n_tasks, dtype=jnp.uint32) * jnp.uint32(2654435761)
         + jnp.uint32(salt)) % jnp.uint32(n_sites)
    return h.astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class Sticky:
    """Load-blind home site, fixed at admission.

    Default: a multiplicative hash of the task index (uniform across
    sites, deterministic, CRN-friendly). With ``by_type=True`` the home
    is ``task_type % F`` instead — types get site affinity, so a skewed
    :class:`~repro.scenarios.mixes.WeightedMix` becomes *per-site arrival
    skew* (some sites see heavy traffic, others idle).

    The default dispatcher: on a single-site system it is the identity
    (every task -> site 0), which is what keeps flat pre-federation runs
    bit-exact.
    """

    kind = "sticky"
    salt: int = 0
    by_type: bool = False

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        if self.by_type:
            return (ctx.task_type % ctx.n_sites).astype(jnp.int32)
        return _hash_sites(ctx.n_tasks, ctx.n_sites, self.salt)


@dataclasses.dataclass(frozen=True)
class RoundRobin:
    """Arrival-order round-robin: task index mod F.

    Traces are arrival-sorted, so the index is an arrival-order proxy and
    consecutive arrivals alternate sites regardless of load."""

    kind = "round_robin"

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        return (jnp.arange(ctx.n_tasks, dtype=jnp.int32)
                % jnp.int32(ctx.n_sites))


@dataclasses.dataclass(frozen=True)
class LeastQueued:
    """Join-the-shortest-site: least queued+running tasks at dispatch time.

    Simultaneous admissions are balanced sequentially in arrival order
    (each dispatched task counts toward its site's load before the next
    task chooses), so a burst spreads across sites instead of
    dog-piling the momentarily-emptiest one.

    ``balance_impl`` optionally swaps the balance scan onto a fused
    implementation (the Pallas kernel, via ``with_pallas_balance``);
    ephemeral (not serialized), the lax scan is the default."""

    kind = "least_queued"
    balance_impl: Optional[Callable] = None

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        all_spill = jnp.ones((ctx.n_tasks,), bool)
        home = jnp.zeros((ctx.n_tasks,), jnp.int32)
        return sequential_balance(ctx, all_spill, home, self.balance_impl)


@dataclasses.dataclass(frozen=True)
class MinEet:
    """EET-aware cheapest site: the site whose fastest machine for the
    task's type has the smallest expected execution time (heterogeneous
    federations route each type to the site that serves it best; ties ->
    lowest site id). Load-blind, like the profiling-table-driven tier
    selection in HE2C."""

    kind = "min_eet"

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        return jnp.argmin(
            ctx.eet_min_by_site[ctx.task_type], axis=1
        ).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class FairSpill:
    """Sticky homes, but *suffered* types may spill to the least-loaded
    site — FELARE's Alg. 4 fairness signal reused at the dispatch level.

    Non-suffered tasks keep their hash home (locality, cache-warm
    models); a task whose type currently sits below the fairness limit
    ε = μ − f·σ escapes its (possibly overloaded) home and is balanced
    onto the least-loaded site, sequentially like :class:`LeastQueued`.
    """

    kind = "fair_spill"
    salt: int = 0
    balance_impl: Optional[Callable] = None

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        home = _hash_sites(ctx.n_tasks, ctx.n_sites, self.salt)
        spill = ctx.suffered[ctx.task_type]
        return sequential_balance(ctx, spill, home, self.balance_impl)


@dataclasses.dataclass(frozen=True)
class TierAware:
    """EET-aware cheapest site *including the cost of getting there*.

    Scores each site by ``EET of its fastest machine for the task's type
    + transfer latency from the task's origin`` and takes the argmin
    (ties -> lowest site id). This is :class:`MinEet` made network-
    conscious: a slow-to-reach cloud site must win by more than the WAN
    latency it costs — the joint delay term of MEL's task-allocation
    formulation at the dispatch level. With no network attached
    (``ctx.xfer_lat is None``) the latency term vanishes and this *is*
    ``min_eet``, bit-for-bit.
    """

    kind = "tier_aware"

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        score = ctx.eet_min_by_site[ctx.task_type]  # (N, F)
        if ctx.xfer_lat is not None:
            score = score + ctx.xfer_lat
        return jnp.argmin(score, axis=1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class HealthAware:
    """Sticky homes, but tasks whose home site is *down* re-route to the
    least-loaded healthy site.

    Uses the heartbeat mask (``ctx.site_alive``: site alive iff at least
    one healthy machine) maintained by the faults subsystem
    (:mod:`repro.core.faults`). Healthy-home tasks keep their hash home
    exactly like :class:`Sticky` — with no dynamics attached the mask is
    absent and this *is* ``sticky``, bit-for-bit. Dead-home tasks enter
    the :func:`~repro.core.dispatch.base.sequential_balance` scan, where
    dead sites carry a large load penalty, so re-routed work spreads
    over the surviving sites instead of dog-piling one.
    """

    kind = "health_aware"
    salt: int = 0
    balance_impl: Optional[Callable] = None

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray:
        home = _hash_sites(ctx.n_tasks, ctx.n_sites, self.salt)
        sa = ctx.site_alive
        if sa is None:
            return home
        reroute = ~sa[home]
        return sequential_balance(ctx, reroute, home, self.balance_impl)
