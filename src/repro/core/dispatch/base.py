"""The dispatch layer's typed surface: context + protocol.

A federation separates *where a task goes* (which site) from *where it
runs* (which machine of that site). The first question is answered once
per task, at the ``dispatch`` stage of the event loop, by a
:class:`Dispatcher`; the second stays the per-site mapping policy's job
(:mod:`repro.core.policy`), run under a site-masked machine view.

:class:`DispatchContext` freezes everything a dispatcher may look at —
the newly-admitted task mask, machine/queue occupancy, the static site
partition, and the Alg. 4 fairness monitor — and caches each derived
per-site aggregate, mirroring :class:`~repro.core.policy.context.
SchedContext`. The site partition and site count are *static* (Python
ints / numpy constants), so dispatchers trace fixed-shape computations
and the whole federation rides inside the single jitted ``while_loop``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy.context import BIG
from repro.core.types import site_membership


@dataclasses.dataclass(frozen=True)
class DispatchContext:
    """Frozen snapshot of one dispatch event.

    Constructor fields are the raw inputs; per-site aggregates are
    ``cached_property`` grids so dispatchers compose without recomputing
    (or paying for aggregates they never read).

    Shapes: N tasks, M machines, S types, F sites (static).
    """

    now: jnp.ndarray          # () f32 current event time
    unassigned: jnp.ndarray   # (N,) bool — pending and not yet dispatched
    task_type: jnp.ndarray    # (N,) int32
    deadline: jnp.ndarray     # (N,) f32
    qlen: jnp.ndarray         # (M,) int32 local-queue occupancy
    running: jnp.ndarray      # (M,) bool machine is executing a task
    completed: jnp.ndarray    # (S,) int32 on-time completions so far
    arrived: jnp.ndarray      # (S,) int32 arrivals so far
    eet: jnp.ndarray          # (S, M) expected execution times
    site_of_machine: np.ndarray  # (M,) int — STATIC partition (numpy)
    n_sites: int              # F — STATIC
    fairness_factor: float    # Eq. 3's f — STATIC engine config
    alive: Optional[jnp.ndarray] = None  # (M,) bool health (None = no faults)
    #: (N, F) f32 per-task transfer latency to each site (None = free
    #: network): row ``k`` prices task k's ``origin -> site`` links, as
    #: computed by the attached :mod:`repro.core.network` model.
    xfer_lat: Optional[jnp.ndarray] = None
    #: (N, F) f32 per-task transfer energy to each site (None = free).
    xfer_energy: Optional[jnp.ndarray] = None

    # -- static shapes ------------------------------------------------------
    @property
    def n_tasks(self) -> int:
        return self.unassigned.shape[0]

    @property
    def n_machines(self) -> int:
        return self.qlen.shape[0]

    # -- static site structure ---------------------------------------------
    @functools.cached_property
    def site_members(self) -> np.ndarray:
        """(F, M) bool — constant membership grid of the partition."""
        return site_membership(self.site_of_machine, self.n_sites)

    @functools.cached_property
    def site_ids(self) -> jnp.ndarray:
        """(M,) int32 — the partition as a device constant."""
        return jnp.asarray(self.site_of_machine, jnp.int32)

    # -- derived per-site load ---------------------------------------------
    @functools.cached_property
    def site_queued(self) -> jnp.ndarray:
        """(F,) int32 — queued tasks per site."""
        return jax.ops.segment_sum(self.qlen, self.site_ids, self.n_sites)

    @functools.cached_property
    def site_running(self) -> jnp.ndarray:
        """(F,) int32 — busy machines per site."""
        return jax.ops.segment_sum(
            self.running.astype(jnp.int32), self.site_ids, self.n_sites
        )

    @functools.cached_property
    def site_load(self) -> jnp.ndarray:
        """(F,) int32 — queued + running tasks per site (the load signal
        ``least_queued`` and ``fair_spill`` balance on)."""
        return self.site_queued + self.site_running

    # -- derived per-site EET structure ------------------------------------
    @functools.cached_property
    def eet_min_by_site(self) -> jnp.ndarray:
        """(S, F) f32 — each type's fastest machine within each site.

        One masked reduction over the (S, F, M) grid — like the engine's
        map stage, the site count F is an array extent here, not a trace
        dimension, so dispatchers cost the same program at any F.
        """
        members = jnp.asarray(self.site_members)  # (F, M) constant
        return jnp.min(
            jnp.where(members[None, :, :], self.eet[:, None, :], BIG),
            axis=2,
        )

    # -- site health (faults subsystem) -------------------------------------
    @functools.cached_property
    def site_alive(self) -> Optional[jnp.ndarray]:
        """(F,) bool — heartbeat mask: site alive iff >= 1 healthy machine.

        ``None`` when no machine dynamics is attached (``alive is
        None``), so health-agnostic dispatchers stay byte-identical
        programs on the default path. When present, the engine has
        already BIG-masked dead machines' EET/availability, so this mask
        is only needed by dispatchers that *route around* dead sites
        (``health_aware``) or penalize them in a load scan.
        """
        if self.alive is None:
            return None
        return jax.ops.segment_sum(
            self.alive.astype(jnp.int32), self.site_ids, self.n_sites
        ) > 0

    # -- fairness monitor ---------------------------------------------------
    @functools.cached_property
    def suffered(self) -> jnp.ndarray:
        """(S,) bool — Alg. 4 suffered-type mask at this event (the same
        signal the FELARE mapping wrapper consults, reused at the
        dispatch level by ``fair_spill``)."""
        from repro.core import fairness

        return fairness.suffered_types(
            self.completed, self.arrived, self.fairness_factor
        )


class Dispatcher(Protocol):
    """Site selection for newly-admitted tasks.

    Implementations are frozen (hashable) dataclasses with a ``kind`` tag
    — the tag is what the pure-Python oracle (:mod:`repro.core.pyengine`)
    and the CLI ``--list-dispatchers`` output key on, so a dispatcher is
    fully described by ``kind`` + its dataclass fields.

    ``dispatch`` returns an (N,) int32 site proposal for *every* task;
    the engine applies it only where ``ctx.unassigned`` is True, and a
    task's site never changes afterwards (dispatch-once semantics — all
    built-ins differ only in *how* the one-shot choice is made).
    """

    kind: str

    def dispatch(self, ctx: DispatchContext) -> jnp.ndarray: ...


def sequential_balance(ctx: DispatchContext, target_mask, home,
                       impl=None) -> jnp.ndarray:
    """Shared least-loaded assignment scan (``least_queued``/``fair_spill``).

    Walks tasks in index (arrival) order carrying per-site loads: each
    unassigned task whose ``target_mask`` is set goes to the currently
    least-loaded site (ties -> lowest site id), others keep their
    ``home`` proposal; every dispatched task increments its site's load
    so simultaneous admissions spread instead of dog-piling one site.
    Integer arithmetic throughout — the oracle mirrors it exactly.

    When machine dynamics are attached (``ctx.site_alive`` is not None),
    dead sites enter the scan with a +1_000_000 load penalty, so the
    least-loaded choice never lands on a site with zero healthy machines
    while any site is still up (integer penalty — still oracle-exact).

    ``impl`` optionally replaces the ``lax.scan`` walk with a fused
    implementation sharing the same contract
    (``impl(load0, unassigned, target_mask, home) -> (N,) int32 sites``)
    — the Pallas ``kernels/map_fused.balance_scan`` kernel plugs in here
    via :func:`repro.core.dispatch.with_pallas_balance`, bit-exact.
    """
    F = ctx.n_sites
    lanes = jnp.arange(F, dtype=jnp.int32)
    load0 = ctx.site_load.astype(jnp.int32)
    sa = ctx.site_alive
    if sa is not None:
        load0 = load0 + jnp.where(sa, 0, 1_000_000)

    if impl is not None:
        return impl(load0, ctx.unassigned, target_mask, home)

    def step(load, xs):
        new_k, tgt_k, home_k = xs
        best = jnp.argmin(load).astype(jnp.int32)
        s = jnp.where(tgt_k, best, home_k)
        load = load + jnp.where((lanes == s) & new_k, 1, 0)
        return load, s

    _, sites = jax.lax.scan(
        step, load0,
        (ctx.unassigned, target_mask, home),
    )
    return sites
