"""Core datatypes for the FELARE scheduling system.

Shapes use the paper's notation:
  S = number of task types (ML applications), M = number of machine types,
  N = number of tasks in a workload trace, Q = per-machine local-queue slots.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

# Task status codes used by both engines.
UNARRIVED = 0   # not yet arrived
PENDING = 1     # in the arriving queue (arrived, unmapped)
QUEUED = 2      # in a machine's local queue
RUNNING = 3     # executing
COMPLETED = 4   # finished on time
MISSED = 5      # started execution but killed at its deadline
CANCELLED = 6   # dropped before being assigned (proactive drop / stale / victim)

STATUS_NAMES = {
    UNARRIVED: "unarrived",
    PENDING: "pending",
    QUEUED: "queued",
    RUNNING: "running",
    COMPLETED: "completed",
    MISSED: "missed",
    CANCELLED: "cancelled",
}

INF = jnp.inf


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """A heterogeneous edge system: machines + profiling data.

    eet:    (S, M) expected execution time of task type i on machine type j.
    p_dyn:  (M,) dynamic power of each machine.
    p_idle: (M,) idle power of each machine.
    queue_size: local queue slots per machine (bounded, equal across machines).
    fairness_factor: ``f`` in Eq. 3; aggressiveness of the fairness method.
    site_of_machine: optional (M,) partition of the machines into F edge
      *sites* (a federation). ``None`` — the default, and what every spec
      built before the federation layer carries — means one site holding
      every machine, so a flat system is just the degenerate F=1
      federation. Sites must be numbered contiguously ``0..F-1`` and every
      site must own at least one machine. Stored as a tuple of ints so the
      spec stays hashable and ``==``-comparable.
    tier_of_site: optional (F,) edge-cloud tier of each site — device=0,
      edge=1, cloud=2 (higher tiers allowed for deeper hierarchies).
      ``None`` means every site sits on the device tier, so flat and
      pre-network specs are the degenerate single-tier hierarchy. Tasks
      originate on the lowest tier present (see
      :mod:`repro.core.network`). Stored as a tuple of ints for
      hashability.
    """

    eet: np.ndarray
    p_dyn: np.ndarray
    p_idle: np.ndarray
    queue_size: int = 2
    fairness_factor: float = 1.0
    site_of_machine: Optional[Tuple[int, ...]] = None
    tier_of_site: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.site_of_machine is not None:
            sites = tuple(int(s) for s in np.asarray(self.site_of_machine))
            object.__setattr__(self, "site_of_machine", sites)
            if len(sites) != self.n_machines:
                raise ValueError(
                    f"site_of_machine has {len(sites)} entries for "
                    f"{self.n_machines} machines"
                )
            present = set(sites)
            n_sites = max(sites) + 1
            if min(sites) < 0 or present != set(range(n_sites)):
                raise ValueError(
                    f"sites must be contiguous 0..F-1 with every site "
                    f"non-empty, got {sites}"
                )
        if self.tier_of_site is not None:
            tiers = tuple(int(t) for t in np.asarray(self.tier_of_site))
            object.__setattr__(self, "tier_of_site", tiers)
            if len(tiers) != self.n_sites:
                raise ValueError(
                    f"tier_of_site has {len(tiers)} entries for "
                    f"{self.n_sites} sites"
                )
            if min(tiers) < 0:
                raise ValueError(f"tiers must be >= 0, got {tiers}")

    @property
    def n_task_types(self) -> int:
        return self.eet.shape[0]

    @property
    def n_machines(self) -> int:
        return self.eet.shape[1]

    @property
    def n_sites(self) -> int:
        """Number of federation sites F (1 for the flat single-site system)."""
        if self.site_of_machine is None:
            return 1
        return max(self.site_of_machine) + 1

    @property
    def sites(self) -> Tuple[int, ...]:
        """The (M,) site partition, materialized (all-zeros when unset)."""
        if self.site_of_machine is None:
            return (0,) * self.n_machines
        return self.site_of_machine

    @property
    def tiers(self) -> Tuple[int, ...]:
        """The (F,) site tiers, materialized (all-device when unset)."""
        if self.tier_of_site is None:
            return (0,) * self.n_sites
        return self.tier_of_site

    @property
    def n_tiers(self) -> int:
        """Number of hierarchy levels spanned (``max tier + 1``)."""
        return max(self.tiers) + 1

    def as_jax(self) -> "SystemArrays":
        return SystemArrays(
            eet=jnp.asarray(self.eet, jnp.float32),
            p_dyn=jnp.asarray(self.p_dyn, jnp.float32),
            p_idle=jnp.asarray(self.p_idle, jnp.float32),
            site_of_machine=jnp.asarray(self.sites, jnp.int32),
        )


def site_membership(site_of_machine, n_sites: Optional[int] = None
                    ) -> np.ndarray:
    """(F, M) bool membership grid of a site partition, as a host constant.

    Row ``s`` is the machine mask of site ``s``. Both the engine's masked
    ``vmap`` map stage and the dispatch layer consume this grid as *data*
    (an array fed to vectorized masking), so the site count F shapes only
    array extents — never the traced program — which is what keeps compile
    time flat in F (see ``tests/test_compile_flatness.py``).
    """
    sites = np.asarray(site_of_machine, np.int32)
    F = int(sites.max()) + 1 if n_sites is None else int(n_sites)
    return np.arange(F, dtype=np.int32)[:, None] == sites[None, :]


class SystemArrays(NamedTuple):
    """Device-side mirror of :class:`SystemSpec` for jitted consumers.

    ``site_of_machine`` is the federation partition as an (M,) int32
    array (``None`` on flat systems) — what site-aware policies and
    observers (e.g. the per-site :class:`~repro.core.observe.timeline.
    Timeline`) read inside the trace; the engine's own per-site loop uses
    the *static* tuple instead, since the site count shapes the program.
    """

    eet: jnp.ndarray     # (S, M)
    p_dyn: jnp.ndarray   # (M,)
    p_idle: jnp.ndarray  # (M,)
    site_of_machine: Optional[jnp.ndarray] = None  # (M,) int32 site ids


class Trace(NamedTuple):
    """A workload trace of N dynamically-arriving tasks (arrival-sorted)."""

    arrival: jnp.ndarray    # (N,) float32
    task_type: jnp.ndarray  # (N,) int32
    deadline: jnp.ndarray   # (N,) float32  (Eq. 4)
    exec_actual: jnp.ndarray  # (N, M) float32 Gamma-sampled actual runtimes


class MapAction(NamedTuple):
    """Output of a mapping heuristic at one mapping event."""

    assign: jnp.ndarray      # (M,) int32 task index per machine, -1 = none
    drop: jnp.ndarray        # (N,) bool  proactive drops from the arriving queue
    queue_drop: jnp.ndarray  # (M, Q) bool victims evicted from local queues (FELARE)


class SimState(NamedTuple):
    """The engine's fixed-shape event-loop state (one trace).

    Every field is a JAX array of static shape, so the whole state threads
    through ``lax.while_loop`` and vmaps over trace batches. Observers
    (:mod:`repro.core.observe`) receive this read-only at every event
    stage; their own state rides next to it in :class:`EngineState.aux`.

    The trailing health fields belong to the faults subsystem
    (:mod:`repro.core.faults`): ``alive``/``slowdown`` are the
    per-machine health state a :class:`~repro.core.faults.
    MachineDynamics` evolves at the ``faults`` stage, ``retries`` counts
    each task's orphan re-dispatches, and ``backup`` holds the k-failure
    backup nominations of :func:`~repro.core.faults.with_backup`
    (shape (N, 0) when no backups are in play). With ``dynamics="none"``
    they are constant carries — present in the state, never read by any
    stage — which keeps the default program bit-exact with the
    pre-faults engine.

    The trailing network fields belong to the network subsystem
    (:mod:`repro.core.network`): ``ready`` is each task's arrival time
    at its *dispatched site* (arrival time + link latency; the mapper
    will not place an in-transit task) and ``e_xfer`` accumulates
    transfer energy per destination tier for the ``network`` observer.
    With ``network="none"`` both stay ``None`` — absent pytree leaves,
    so the traced program is structurally identical to the pre-network
    engine.
    """

    now: jnp.ndarray            # ()
    status: jnp.ndarray         # (N,) int32
    site: jnp.ndarray           # (N,) int32 federation site, -1 undispatched
    run_task: jnp.ndarray       # (M,) int32, -1 idle
    run_start: jnp.ndarray      # (M,)
    run_end_act: jnp.ndarray    # (M,) actual completion (inf if idle)
    run_end_exp: jnp.ndarray    # (M,) expected completion (for the mapper)
    run_success: jnp.ndarray    # (M,) bool
    queue: jnp.ndarray          # (M, Q) int32, -1 empty
    qlen: jnp.ndarray           # (M,) int32
    busy_time: jnp.ndarray      # (M,)
    e_dyn: jnp.ndarray          # ()
    e_wasted: jnp.ndarray       # ()
    completed: jnp.ndarray      # (S,) int32
    missed: jnp.ndarray         # (S,) int32
    cancelled: jnp.ndarray      # (S,) int32
    arrived: jnp.ndarray        # (S,) int32
    steps: jnp.ndarray          # () int32
    alive: Optional[jnp.ndarray] = None     # (M,) bool machine health
    slowdown: Optional[jnp.ndarray] = None  # (M,) f32 straggler factors
    retries: Optional[jnp.ndarray] = None   # (N,) int32 orphan re-dispatches
    backup: Optional[jnp.ndarray] = None    # (N, k) int32 backup machines
    ready: Optional[jnp.ndarray] = None     # (N,) f32 ready time at site
    e_xfer: Optional[jnp.ndarray] = None    # (T,) f32 transfer energy by tier


class EngineState(NamedTuple):
    """The extensible event-loop carrier: core state + observer aux.

    ``aux`` maps each attached observer's name to its own fixed-shape
    pytree, so extensions carry state through the ``lax.while_loop``
    without touching :class:`SimState` fields. With no observers it is an
    empty dict and the loop is structurally identical to the bare engine.
    """

    sim: SimState
    aux: dict  # observer name -> pytree, fixed structure per simulation


class Metrics(NamedTuple):
    """Aggregate results of one simulated trace."""

    completed_by_type: jnp.ndarray  # (S,)
    missed_by_type: jnp.ndarray     # (S,)
    cancelled_by_type: jnp.ndarray  # (S,)
    arrived_by_type: jnp.ndarray    # (S,)
    energy_dynamic: jnp.ndarray     # () total dynamic energy
    energy_wasted: jnp.ndarray      # () dynamic energy spent on missed tasks
    energy_idle: jnp.ndarray        # () idle energy over the makespan
    makespan: jnp.ndarray           # () time of last event

    @property
    def completion_rate_by_type(self):
        return self.completed_by_type / jnp.maximum(self.arrived_by_type, 1)

    @property
    def collective_completion_rate(self):
        return self.completed_by_type.sum() / jnp.maximum(
            self.arrived_by_type.sum(), 1
        )
