"""Expected Execution Time (EET) matrices.

Provides the paper's Table I verbatim, the Coefficient-of-Variation-Based
(CVB) synthesis method [Ali et al. 2000] used to generate it, and the AWS
scenario EET (t2.xlarge CPU vs g3s.xlarge GPU running FaceNet / DeepSpeech).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# --- Table I of the paper (4 task types x 4 machine types, seconds) ---------
TABLE_I = np.array(
    [
        [2.238, 1.696, 4.359, 0.736],
        [2.256, 1.828, 4.377, 0.868],
        [2.076, 1.531, 5.096, 0.865],
        [2.092, 1.622, 4.388, 0.913],
    ],
    dtype=np.float32,
)

# Machine power profiles from Sec. VI-A, in units of the unit power ``p``.
P_DYN = np.array([1.6, 3.0, 1.8, 1.5], dtype=np.float32)
P_IDLE = np.full(4, 0.05, dtype=np.float32)

# --- AWS scenario (Sec. VI-A, scenario i) ------------------------------------
# Rows: face recognition (MTCNN+FaceNet+SVM), speech recognition (DeepSpeech).
# Cols: t2.xlarge (Xeon CPU), g3s.xlarge (Tesla M60 GPU). Values are mean
# end-to-end inference latencies (s) consistent with the published SmartSight /
# E2C-Sim measurements; powers are the TDPs quoted in the paper (120 W, 300 W).
AWS_EET = np.array(
    [
        [0.570, 0.270],   # face recognition: CPU vs GPU
        [3.380, 0.980],   # speech recognition: CPU vs GPU
    ],
    dtype=np.float32,
)
AWS_P_DYN = np.array([120.0, 300.0], dtype=np.float32)
AWS_P_IDLE = np.array([6.0, 15.0], dtype=np.float32)


def cvb_eet(key, n_task_types, n_machines, mean_task=3.0, cv_task=0.6, cv_mach=0.6):
    """Coefficient-of-Variation-Based EET synthesis [38].

    Two nested Gamma draws: a per-task-type baseline q_i ~ Gamma with mean
    ``mean_task`` and CV ``cv_task``; then row i is filled with draws from a
    Gamma with mean q_i and CV ``cv_mach``. CVs control task/machine
    heterogeneity (inconsistent heterogeneity emerges naturally).
    """
    # repro: allow-prng[CVB synthesis splits the caller's key; CRN-safe]
    k_task, k_mach = jax.random.split(key)
    shape_t = 1.0 / cv_task**2
    scale_t = mean_task * cv_task**2
    q = jax.random.gamma(k_task, shape_t, (n_task_types,)) * scale_t  # (S,)

    shape_m = 1.0 / cv_mach**2
    scale_m = q[:, None] * cv_mach**2  # (S, 1)
    eet = (
        jax.random.gamma(k_mach, shape_m, (n_task_types, n_machines)) * scale_m
    )
    return eet.astype(jnp.float32)


def sample_actual_exec(key, eet, task_type, cv_run=0.1):
    """Sample per-task actual runtimes on every machine.

    Actual execution time of task k (type i) on machine j ~ Gamma with mean
    EET[i, j] and CV ``cv_run`` — the execution-time uncertainty the paper
    models (Sec. VI-A).
    """
    eet = jnp.asarray(eet)
    means = eet[task_type]  # (N, M)
    shape = 1.0 / cv_run**2
    draw = jax.random.gamma(key, shape, means.shape)
    return (draw * (means * cv_run**2)).astype(jnp.float32)
