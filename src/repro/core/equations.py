"""The paper's closed-form scheduling math (Eqs. 1-4), vectorized.

All functions broadcast over arbitrary leading dims; the canonical use is
(N_tasks, M_machines) grids at a mapping event.

Feasibility note: Eq. 1 of the paper uses strict ``<`` in the first row and
Algorithm 2 tests ``c_ij <= delta_i`` — under the middle row (``c = delta``)
that test is vacuously true, which is a pseudo-code slip. We define a pair
feasible iff ``s + e <= delta`` (the task can fully execute before its
deadline), the only reading consistent with the prose.
"""
from __future__ import annotations

import jax.numpy as jnp


def completion_time(start, exec_time, deadline):
    """Eq. 1 — expected completion time of a task mapped at ``start``.

    Three regimes: finishes on time (s+e <= d); killed at its deadline
    mid-execution (s < d < s+e); dropped before starting (s >= d).
    """
    s, e, d = jnp.broadcast_arrays(
        jnp.asarray(start, jnp.float32),
        jnp.asarray(exec_time, jnp.float32),
        jnp.asarray(deadline, jnp.float32),
    )
    on_time = s + e <= d
    started = s < d
    return jnp.where(on_time, s + e, jnp.where(started, d, s))


def feasible(start, exec_time, deadline):
    """A [task, machine] pair is feasible iff it completes by the deadline."""
    return jnp.asarray(start, jnp.float32) + exec_time <= deadline


def expected_energy(start, exec_time, deadline, p_dyn):
    """Eq. 2 — expected dynamic energy of executing the pair.

    Feasible: p_dyn * e.  Killed mid-run: p_dyn * (d - s) — pure waste.
    Never started (s >= d): 0.
    """
    s, e, d, p = jnp.broadcast_arrays(
        jnp.asarray(start, jnp.float32),
        jnp.asarray(exec_time, jnp.float32),
        jnp.asarray(deadline, jnp.float32),
        jnp.asarray(p_dyn, jnp.float32),
    )
    on_time = s + e <= d
    started = s < d
    return jnp.where(on_time, p * e, jnp.where(started, p * (d - s), 0.0))


def fairness_limit(completion_rates, fairness_factor):
    """Eq. 3 — epsilon = mu - f * sigma over per-type completion rates.

    ``f`` large => epsilon -> 0 => fairness disabled. Clamped at 0 so a huge
    ``f`` never produces a negative (meaningless) limit.
    """
    cr = jnp.asarray(completion_rates, jnp.float32)
    mu = cr.mean()
    sigma = cr.std()
    return jnp.maximum(mu - fairness_factor * sigma, 0.0)


def deadlines(arrival, task_type, eet):
    """Eq. 4 — delta_i(k) = arr_k + e_bar_i + e_bar.

    e_bar_i = mean over machines of EET row i; e_bar = mean of e_bar_i.
    """
    eet = jnp.asarray(eet, jnp.float32)
    e_bar_i = eet.mean(axis=1)          # (S,)
    e_bar = e_bar_i.mean()              # ()
    return jnp.asarray(arrival, jnp.float32) + e_bar_i[task_type] + e_bar


def urgency(deadline, exec_time, now):
    """MMU's urgency metric: 1 / (delta - e). Higher = more urgent.

    Negative slack (cannot finish) yields a negative urgency => lowest
    priority, matching the baseline's intent. ``now`` shifts slack to be
    relative to the current mapping event.
    """
    slack = deadline - now - exec_time
    return 1.0 / jnp.where(jnp.abs(slack) < 1e-9, 1e-9, slack)
