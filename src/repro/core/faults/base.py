"""The MachineDynamics protocol: per-machine health inside the jitted loop.

A federation is only fault-tolerant if failure is a *modeled input*, not
an exception path. This module defines the typed surface of the faults
subsystem, mirroring the ArrivalProcess/Observer/Dispatcher pattern:

  * :class:`FaultContext` — the frozen snapshot a dynamics reads at the
    engine's ``faults`` stage (current time, event counter, trace
    horizon, the health state it is evolving, and the static site
    partition);
  * :class:`MachineDynamics` — the protocol: frozen hashable dataclasses
    with a ``kind`` tag and a pure ``step(ctx) -> (alive, slowdown)``
    map, closed over statically by the engine (attaching a dynamics
    never retraces per call, and the whole failure process rides inside
    the single jitted — and vmapped — ``while_loop``);
  * :func:`hash_uniform` — the counter-based uniform draw every
    stochastic built-in keys on. It is a pure function of
    ``(machine, event counter, seed)``, so failure traces are common
    random numbers across the vmapped sweep grid (every heuristic in a
    paired comparison sees the *same* failures) and the pure-Python
    oracle reproduces each draw exactly (:func:`hash_uniform_host`).

Health is two fixed-shape arrays threaded through ``SimState``:

  ``alive``    (M,) bool — dead machines read avail=BIG/EET=BIG at the
               dispatch and map stages, exactly like out-of-site
               machines, so policies route around them with zero new
               policy code;
  ``slowdown`` (M,) f32  — a straggler factor scaling the machine's EET
               column (and actual runtimes); 1.0 = nominal.

See ``docs/faults.md`` for the stage contract, orphan semantics, and a
worked writing-a-dynamics example.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultContext:
    """Frozen snapshot handed to :meth:`MachineDynamics.step` each event.

    ``now``/``steps``/``alive``/``slowdown`` are traced arrays;
    ``site_of_machine`` and ``n_sites`` are static host constants (the
    partition shapes programs elsewhere in the engine, never here).
    ``horizon`` is the trace horizon (max deadline) — the time scale
    window-based dynamics (:class:`~repro.core.faults.builtins.
    SiteOutage`) express their fractions against.
    """

    now: jnp.ndarray              # () f32 current event time
    steps: jnp.ndarray            # () int32 completed loop iterations
    horizon: jnp.ndarray          # () f32 trace horizon (max deadline)
    alive: jnp.ndarray            # (M,) bool current health
    slowdown: jnp.ndarray         # (M,) f32 current EET scale factors
    site_of_machine: np.ndarray   # (M,) int — STATIC partition
    n_sites: int                  # F — STATIC

    @property
    def n_machines(self) -> int:
        return self.alive.shape[0]


class MachineDynamics(Protocol):
    """A per-machine health process evolved at the engine's ``faults`` stage.

    Implementations are frozen (hashable) dataclasses with a ``kind`` tag
    — the tag is what the pure-Python oracle and ``--list-dynamics`` key
    on, so a dynamics is fully described by ``kind`` + its fields.

    ``step`` returns the *next* ``(alive, slowdown)`` pair — both full
    (M,) arrays, pure functions of the context (no hidden state: the
    engine carries health in ``SimState``). ``wake_fracs`` lets
    scheduled dynamics (outage windows) name horizon fractions at which
    the engine must fire an event even if nothing else is due — without
    it a quiet system would sleep through a scheduled recovery.
    ``max_retries`` bounds orphan re-dispatch: a task orphaned more than
    this many times is CANCELLED instead of re-entering the queue.
    """

    kind: str
    max_retries: int

    def step(self, ctx: FaultContext) -> Tuple[jnp.ndarray, jnp.ndarray]: ...

    def wake_fracs(self) -> Tuple[float, ...]: ...


def hash_uniform(machine, steps, seed: int) -> jnp.ndarray:
    """Counter-based uniform draw in [0, 1), exact in float32.

    A stateless multiplicative-xorshift hash of ``(machine, steps,
    seed)`` on wrapping uint32 arithmetic; the top 24 bits become the
    mantissa, so every value is an exact float32 (no rounding to diverge
    on) and :func:`hash_uniform_host` reproduces each draw with plain
    Python integers. No ``jax.random`` — the draw must not consume the
    trace PRNG stream (CRN across the sweep grid) and must be cheap
    enough to run every event.
    """
    u32 = jnp.uint32
    x = (jnp.asarray(machine).astype(u32) * u32(0x9E3779B1)
         + jnp.asarray(steps).astype(u32) * u32(0x85EBCA6B)
         + u32((seed & 0xFFFFFFFF) * 0xC2B2AE35 & 0xFFFFFFFF))
    x = x * u32(2654435761)
    x = x ^ (x >> 13)
    x = x * u32(2654435761)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def hash_uniform_host(machine: int, steps: int, seed: int) -> np.float32:
    """Plain-integer mirror of :func:`hash_uniform` (oracle side)."""
    m32 = 0xFFFFFFFF
    x = (machine * 0x9E3779B1 + steps * 0x85EBCA6B
         + ((seed & m32) * 0xC2B2AE35 & m32)) & m32
    x = (x * 2654435761) & m32
    x ^= x >> 13
    x = (x * 2654435761) & m32
    return np.float32(np.float32(x >> 8) * np.float32(1.0 / (1 << 24)))
