"""Registry-backed machine dynamics: failures, outages and stragglers.

FELARE targets battery-powered edge fleets, but real HEC deployments
lose machines and whole sites mid-trace — the on-time-completion
objective is only meaningful if the scheduler survives that. This
package makes failure a first-class, composable axis next to policies,
scenarios, observers and dispatchers:

    Run = Policy x Scenario x Dispatcher x Observers x **Dynamics**

A :class:`MachineDynamics` evolves a per-machine ``(alive, slowdown)``
health state at the engine's ``faults`` stage (after ``admit``, before
``dispatch``), inside the single jitted event loop. Built-ins:

  * ``none`` — no failures; the default, bit-exact with the pre-faults
    engine (the stage is skipped entirely);
  * ``bernoulli_updown`` — independent per-machine fail/recover Markov
    chain, counter-hash keyed so failure traces are common random
    numbers across the vmapped sweep grid;
  * ``site_outage`` — scheduled correlated whole-site outage windows
    (with engine wake-ups at the window edges);
  * ``degrade`` — stragglers: a slowdown factor scaling EET rows rather
    than killing the machine.

Dead machines read avail=BIG/EET=BIG exactly like out-of-site machines;
running tasks on a dying machine become *orphans* that re-enter the
dispatch queue with a bounded retry count, and dispatchers see a
heartbeat-style site-health mask ("site alive iff >= 1 healthy
machine"). On top of the mask, :func:`with_backup` adds FEST/EnSuRe-
style k-failure backup allocation as a policy wrapper, and the
``health_aware`` dispatcher routes admissions around dead sites.

All dynamics are frozen hashable dataclasses behind the shared
:class:`~repro.core.registry.NameRegistry`, interpreted by the pure-
Python oracle event-for-event, and serialize to JSON by kind + fields.
See ``docs/faults.md`` for the stage contract, orphan semantics and a
worked writing-a-dynamics example.
"""
from __future__ import annotations

from repro.core.faults.backup import BackupPolicy, with_backup
from repro.core.faults.base import (
    FaultContext,
    MachineDynamics,
    hash_uniform,
    hash_uniform_host,
)
from repro.core.faults.builtins import (
    BernoulliUpDown,
    Degrade,
    NoDynamics,
    SiteOutage,
)
from repro.core.faults.registry import (
    get,
    is_registered,
    list_dynamics,
    register,
    unregister,
)

__all__ = [
    "BackupPolicy",
    "BernoulliUpDown",
    "Degrade",
    "FaultContext",
    "MachineDynamics",
    "NoDynamics",
    "SiteOutage",
    "describe",
    "from_json_dict",
    "get",
    "hash_uniform",
    "hash_uniform_host",
    "is_registered",
    "list_dynamics",
    "register",
    "resolve",
    "to_json_dict",
    "unregister",
    "with_backup",
]

#: JSON ``kind`` -> built-in dynamics class, for spec round-tripping.
_KINDS = {
    "none": NoDynamics,
    "bernoulli_updown": BernoulliUpDown,
    "site_outage": SiteOutage,
    "degrade": Degrade,
}


def resolve(dynamics) -> MachineDynamics:
    """Normalize a name-or-instance to a MachineDynamics instance.

    ``None`` resolves to :class:`NoDynamics` (the engine further
    normalizes ``kind == "none"`` to "no faults stage at all", keeping
    the default path bit-exact); strings resolve through the registry
    (KeyError on unknown names lists what is registered).
    """
    if dynamics is None:
        return NoDynamics()
    if isinstance(dynamics, str):
        return get(dynamics)
    if not callable(getattr(dynamics, "step", None)):
        raise TypeError(
            f"dynamics must be a registered name or implement the "
            f"MachineDynamics protocol, got {dynamics!r}"
        )
    return dynamics


def describe(name_or_dynamics) -> str:
    """One-line human description (for ``--list-dynamics``)."""
    d = resolve(name_or_dynamics)
    doc = (d.__class__.__doc__ or "").strip().splitlines()
    return doc[0].rstrip(".") if doc else d.__class__.__name__


def to_json_dict(dynamics) -> dict:
    """``{"kind": ..., <param>: ...}`` for a built-in-style dynamics."""
    import dataclasses

    d = resolve(dynamics)
    out = {"kind": d.kind}
    for f in dataclasses.fields(d):
        v = getattr(d, f.name)
        if isinstance(v, tuple):
            v = [list(x) if isinstance(x, tuple) else x for x in v]
        out[f.name] = v
    return out


def from_json_dict(d: dict) -> MachineDynamics:
    """Rebuild a built-in dynamics from its :func:`to_json_dict` form."""
    kind = d.get("kind")
    cls = _KINDS.get(kind)
    if cls is None:
        raise ValueError(
            f"unknown dynamics kind {kind!r}; choose from {sorted(_KINDS)}"
        )
    params = {k: v for k, v in d.items() if k != "kind"}
    for k, v in params.items():
        if isinstance(v, list):
            params[k] = tuple(
                tuple(x) if isinstance(x, list) else x for x in v
            )
    return cls(**params)


for _name, _dyn in [
    ("none", NoDynamics()),
    ("bernoulli_updown", BernoulliUpDown()),
    ("site_outage", SiteOutage()),
    ("degrade", Degrade()),
]:
    register(_name, _dyn)
del _name, _dyn
