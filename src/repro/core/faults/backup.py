"""FEST/EnSuRe-style k-failure backup allocation as a policy wrapper.

:func:`with_backup` wraps any mapping policy so that every task assigned
a *primary* machine also gets ``k`` backup machines nominated on
disjoint machines (FEST's primary/backup split, generalized to k
failures like EnSuRe). The backups are passive standbys: nothing is
reserved or executed on them while the primary is healthy — backup slots
are simply *cancelled by construction* on primary success, realizing the
"backup cancelled on primary success" half of FEST for free. Only when
the primary machine dies mid-run does the orphaned task fail over: the
engine's ``faults`` stage enqueues it directly on its first healthy,
non-full backup, skipping the dispatch/map round-trip an unprotected
orphan pays (and the extra retry risk that comes with it).

Backups are chosen at assignment time by minimum expected completion
(``avail_base + EET``) over healthy machines excluding the primary —
the same greedy rule FEST uses for its backup slot — and recorded in the
fixed-shape ``SimState.backup`` (N, k) table. The wrapper delegates
everything else to the base policy unchanged, so ``with_backup(FELARE,
k=1)`` maps exactly like FELARE until a failure happens; with
``dynamics="none"`` the engine skips the backup machinery entirely and
the wrapper is inert (bit-exact with the bare policy).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BackupPolicy:
    """A mapping policy plus k-failure backup nomination (see module doc).

    Frozen and hashable like every policy, so the engine closes over it
    statically; ``backup_k`` is the attribute the engine keys the backup
    machinery on (0 = none).
    """

    base: object
    k: int = 1

    def __post_init__(self):
        if int(self.k) < 1:
            raise ValueError(f"backup count k must be >= 1, got {self.k}")
        if not (callable(self.base) or hasattr(self.base, "select")):
            raise TypeError(
                f"with_backup needs a mapping policy, got {self.base!r}"
            )
        object.__setattr__(self, "k", int(self.k))

    @property
    def backup_k(self) -> int:
        return self.k

    # -- pure delegation: mapping decisions are the base policy's ----------
    def select(self, ctx):
        return self.base.select(ctx)

    def __call__(self, now, pending, task_type, deadline, view, sysarr,
                 suffered):
        return self.base(now, pending, task_type, deadline, view, sysarr,
                         suffered)

    def describe(self):
        from repro.core import policy as policy_mod

        return policy_mod.describe(self.base)._replace(backup_k=self.k)

    @property
    def supports_phase1_impl(self) -> bool:
        return getattr(self.base, "supports_phase1_impl", False)

    def with_phase1_impl(self, impl) -> "BackupPolicy":
        if not self.supports_phase1_impl:
            return self
        return dataclasses.replace(
            self, base=self.base.with_phase1_impl(impl)
        )


def with_backup(policy_or_name, k: int = 1) -> BackupPolicy:
    """Wrap a policy (or registered policy name) with k-failure backups.

        from repro.core import faults
        pol = faults.with_backup("FELARE", k=1)
        engine.simulate(trace, spec, pol, dynamics="site_outage")
    """
    from repro.core import policy as policy_mod

    base = (policy_mod.get(policy_or_name)
            if isinstance(policy_or_name, str) else policy_or_name)
    return BackupPolicy(base, k)
