"""Mutable, case-insensitive machine-dynamics registry.

Dynamics are addressed by name everywhere — ``SweepSpec.dynamics``, the
sweep CLI's ``--dynamics``, ``engine.simulate(dynamics=...)`` — so
registering one here makes it flow through the single-jit sweep
machinery untouched:

    from repro.core import faults

    faults.register("flaky", faults.BernoulliUpDown(p_fail=0.1))
    # ... SweepSpec(system="paper_x2", dynamics="flaky") just works.

The mechanics live in the shared
:class:`repro.core.registry.NameRegistry` (also behind the policy,
scenario, fleet, observer and dispatcher registries).
"""
from __future__ import annotations

from typing import List

from repro.core.registry import NameRegistry


def _check(name, dynamics) -> None:
    if not callable(getattr(dynamics, "step", None)):
        raise TypeError(
            f"dynamics {name!r} must implement the MachineDynamics "
            f"protocol (a .step(ctx) method); got {dynamics!r}"
        )


_REGISTRY = NameRegistry("dynamics", case=str.lower, check=_check)


def register(name: str, dynamics, *, overwrite: bool = False):
    """Register ``dynamics`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True``.
    Returns the dynamics, so registration can be used expression-style.
    """
    return _REGISTRY.register(name, dynamics, overwrite=overwrite)


def unregister(name: str) -> None:
    """Remove a registered dynamics (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get(name: str):
    """Resolve a dynamics by (case-insensitive) name, or raise KeyError
    listing every registered name."""
    return _REGISTRY.get(name)


def list_dynamics() -> List[str]:
    """Sorted names of every registered machine dynamics."""
    return _REGISTRY.names()
