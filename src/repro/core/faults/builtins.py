"""Built-in machine dynamics: the four failure processes.

Each is a frozen (hashable) dataclass the engine closes over statically,
and each is *data*: the pure-Python oracle (:mod:`repro.core.pyengine`)
interprets ``kind`` + the dataclass fields with plain loops, so every
built-in is cross-checkable event-for-event — including the failure
draws themselves (:func:`~repro.core.faults.base.hash_uniform` is
integer-exact on both sides).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.faults.base import FaultContext, hash_uniform


@dataclasses.dataclass(frozen=True)
class NoDynamics:
    """No failures: every machine healthy forever (the default).

    The engine treats this as the absence of a dynamics — the ``faults``
    stage is skipped entirely and no health masking enters the traced
    program, so ``dynamics="none"`` is *bit-exact* with the
    pre-faults engine (pinned in ``tests/test_faults.py`` against a
    frozen PR 6 snapshot).
    """

    kind = "none"
    max_retries: int = 3

    def step(self, ctx: FaultContext):
        return ctx.alive, ctx.slowdown

    def wake_fracs(self) -> Tuple[float, ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class BernoulliUpDown:
    """Independent per-machine fail/recover Markov chain, one draw per event.

    At each event every machine draws one :func:`hash_uniform` value
    keyed on ``(machine, event counter, seed)``: an alive machine dies
    with probability ``p_fail``, a dead one recovers with probability
    ``p_recover``. Event-driven (not wall-clock-driven) by design — the
    chain advances when the system does, which keeps the process inside
    the fixed-shape event loop and identical across the vmapped sweep
    grid (common random failures for paired comparisons).
    """

    kind = "bernoulli_updown"
    p_fail: float = 0.02
    p_recover: float = 0.2
    seed: int = 0
    max_retries: int = 3

    def step(self, ctx: FaultContext):
        u = hash_uniform(
            jnp.arange(ctx.n_machines, dtype=jnp.uint32), ctx.steps,
            self.seed,
        )
        alive = jnp.where(
            ctx.alive,
            u >= jnp.float32(self.p_fail),
            u < jnp.float32(self.p_recover),
        )
        return alive, ctx.slowdown

    def wake_fracs(self) -> Tuple[float, ...]:
        return ()


@dataclasses.dataclass(frozen=True)
class SiteOutage:
    """Scheduled correlated whole-site outages (power loss, backhaul cut).

    ``outages`` is a tuple of ``(site, start_frac, end_frac)`` windows,
    fractions of the trace horizon (max deadline): every machine of
    ``site`` is dead for ``now in [start_frac * horizon, end_frac *
    horizon)`` and healthy outside all of its windows. Health is a pure
    function of time, so the process is trivially reproducible; the
    window boundaries are reported as :meth:`wake_fracs` so the engine
    fires an event at each outage start/end even when nothing else is
    due (a recovery nobody observes never reschedules anything).
    """

    kind = "site_outage"
    outages: Tuple[Tuple[int, float, float], ...] = ((0, 0.25, 0.5),)
    max_retries: int = 3

    def __post_init__(self):
        norm = tuple(
            (int(s), float(a), float(b)) for (s, a, b) in self.outages
        )
        for s, a, b in norm:
            if not (0.0 <= a < b):
                raise ValueError(
                    f"outage window ({s}, {a}, {b}) needs 0 <= start < end"
                )
        object.__setattr__(self, "outages", norm)

    def step(self, ctx: FaultContext):
        site_ids = jnp.asarray(
            np.asarray(ctx.site_of_machine, np.int32)
        )
        dead = jnp.zeros((ctx.n_machines,), bool)
        for s, a, b in self.outages:
            t0 = jnp.float32(a) * ctx.horizon
            t1 = jnp.float32(b) * ctx.horizon
            dead = dead | (
                (site_ids == jnp.int32(s)) & (ctx.now >= t0) & (ctx.now < t1)
            )
        return ~dead, ctx.slowdown

    def wake_fracs(self) -> Tuple[float, ...]:
        return tuple(sorted({
            float(f) for (_, a, b) in self.outages for f in (a, b)
        }))


@dataclasses.dataclass(frozen=True)
class Degrade:
    """Stragglers: a static set of machines runs slower, nothing dies.

    The straggler set is either ``machines`` (explicit indices) or, when
    ``None``, each machine independently with probability ``p`` (one
    :func:`hash_uniform` draw keyed on ``(machine, 0, seed)`` — static
    over the trace). Stragglers execute every task ``factor``× slower:
    the engine scales their EET column *and* their actual runtimes, so
    policies that consult the EET table see the degradation and route
    around it (this is the paper's heterogeneity axis made dynamic).
    """

    kind = "degrade"
    factor: float = 2.0
    machines: Optional[Tuple[int, ...]] = None
    p: float = 0.25
    seed: int = 0
    max_retries: int = 3

    def __post_init__(self):
        if self.machines is not None:
            object.__setattr__(
                self, "machines", tuple(int(j) for j in self.machines)
            )
        if self.factor <= 0:
            raise ValueError(f"factor must be positive, got {self.factor}")

    def step(self, ctx: FaultContext):
        M = ctx.n_machines
        if self.machines is not None:
            mask = np.zeros((M,), bool)
            mask[list(self.machines)] = True
            straggler = jnp.asarray(mask)
        else:
            u = hash_uniform(
                jnp.arange(M, dtype=jnp.uint32), jnp.uint32(0), self.seed
            )
            straggler = u < jnp.float32(self.p)
        slow = jnp.where(straggler, jnp.float32(self.factor),
                         jnp.float32(1.0))
        return ctx.alive, slow

    def wake_fracs(self) -> Tuple[float, ...]:
        return ()
