"""Workload trace synthesis: Poisson arrivals, Eq. 4 deadlines."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import eet as eet_mod
from repro.core import equations
from repro.core.types import Trace


def poisson_trace(key, n_tasks, arrival_rate, eet, *, n_task_types=None,
                  cv_run=0.1, type_probs=None) -> Trace:
    """Synthesize one workload trace.

    Inter-arrival ~ Exp(rate) (Poisson process, Sec. VI-A); task types are
    drawn uniformly (or per ``type_probs``); deadlines follow Eq. 4; actual
    runtimes are Gamma-sampled around the EET entries.
    """
    eet = jnp.asarray(eet)
    if n_task_types is None:
        n_task_types = eet.shape[0]
    k_arr, k_type, k_exec = jax.random.split(key, 3)

    gaps = jax.random.exponential(k_arr, (n_tasks,)) / arrival_rate
    arrival = jnp.cumsum(gaps).astype(jnp.float32)

    if type_probs is None:
        task_type = jax.random.randint(k_type, (n_tasks,), 0, n_task_types)
    else:
        task_type = jax.random.choice(
            k_type, n_task_types, (n_tasks,), p=jnp.asarray(type_probs)
        )
    task_type = task_type.astype(jnp.int32)

    deadline = equations.deadlines(arrival, task_type, eet)
    exec_actual = eet_mod.sample_actual_exec(k_exec, eet, task_type, cv_run)
    return Trace(arrival, task_type, deadline, exec_actual)


def trace_batch(key, n_traces, n_tasks, arrival_rate, eet, **kw):
    """A batch of i.i.d. traces (stacked leading dim) for vmapped simulation."""
    keys = jax.random.split(key, n_traces)
    make = lambda k: poisson_trace(k, n_tasks, arrival_rate, eet, **kw)
    return jax.vmap(make)(keys)
