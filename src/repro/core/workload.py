"""Workload trace synthesis — thin wrappers over the scenario API.

The actual synthesis logic lives in :mod:`repro.scenarios`: a composable
``Scenario`` (arrival process × type mix × deadline model × runtime model)
replaces the hard-coded Poisson recipe that used to live here.
:func:`poisson_trace` remains the stable convenience entry point and is
byte-identical to its pre-scenario implementation (pinned by
``tests/test_scenario_regression.py``); :func:`trace_batch` is a
deprecation shim over the CRN-capable ``trace_stack``.
"""
from __future__ import annotations

import warnings

import jax

from repro.core.types import Trace


def poisson_trace(key, n_tasks, arrival_rate, eet, *, n_task_types=None,
                  cv_run=0.1, type_probs=None) -> Trace:
    """Synthesize one workload trace under the paper's default scenario.

    Inter-arrival ~ Exp(rate) (Poisson process, Sec. VI-A); task types are
    drawn uniformly (or per ``type_probs``); deadlines follow Eq. 4; actual
    runtimes are Gamma-sampled around the EET entries.

    Equivalent to ``scenarios.default_scenario().sample_trace(...)`` (with
    ``type_probs`` swapping in a ``WeightedMix``); use a
    :class:`repro.scenarios.Scenario` directly for anything richer.
    """
    from repro import scenarios

    scenario = scenarios.DEFAULT
    if type_probs is not None:
        scenario = scenarios.replace(
            scenario, mix=scenarios.mix_from_probs(tuple(type_probs))
        )
    return scenario.sample_trace(key, n_tasks, arrival_rate, eet,
                                 cv_run=cv_run, n_task_types=n_task_types)


def trace_batch(key, n_traces, n_tasks, arrival_rate, eet, **kw):
    """Deprecated: a batch of i.i.d. traces (stacked leading dim).

    .. deprecated::
        ``trace_batch`` predates the CRN trace grids of
        :func:`repro.datapipe.synthetic.trace_stack` and survives only as a
        delegate: ``trace_batch(key, K, ...)`` is exactly
        ``trace_stack(key, rates=(rate,), reps=K, ...)`` with the
        single-rate axis squeezed (same key-split order, same bits). Call
        ``trace_stack`` (or ``Scenario.stack``) directly.
    """
    warnings.warn(
        "workload.trace_batch is deprecated; use "
        "repro.datapipe.synthetic.trace_stack (rates=(rate,), reps=n_traces)"
        " or Scenario.stack instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.datapipe import synthetic

    stacked = synthetic.trace_stack(
        key, (arrival_rate,), n_traces, n_tasks, eet, **kw
    )
    return jax.tree.map(lambda x: x[0], stacked)
