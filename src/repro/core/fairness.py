"""Fairness measure over task types (Sec. V, Algorithm 4)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import equations


def completion_rates(completed_by_type, arrived_by_type):
    """cr_i = on-time completions of type i / arrivals of type i (so far).

    Types with no arrivals yet report rate 1.0 (they cannot have suffered).
    """
    arrived = jnp.asarray(arrived_by_type)
    completed = jnp.asarray(completed_by_type)
    return jnp.where(arrived > 0, completed / jnp.maximum(arrived, 1), 1.0)


def suffered_types(completed_by_type, arrived_by_type, fairness_factor,
                   min_arrivals: int = 1):
    """Algorithm 4 — the suffered-task-type mask.

    A type is suffered iff its completion rate is <= the fairness limit
    (Eq. 3). ``min_arrivals`` guards cold-start noise: a type is only
    judged once it has arrived at least that many times.
    """
    cr = completion_rates(completed_by_type, arrived_by_type)
    eps = equations.fairness_limit(cr, fairness_factor)
    judged = jnp.asarray(arrived_by_type) >= min_arrivals
    return (cr <= eps) & judged


def jain_index(values):
    """Jain's fairness index over per-type completion rates (reporting aid;
    1.0 = perfectly fair). Not part of the paper's method, used in benchmarks
    to summarize Fig. 7-style bar charts as a scalar."""
    v = jnp.asarray(values, jnp.float32)
    s1 = v.sum()
    s2 = (v * v).sum()
    n = v.shape[0]
    return jnp.where(s2 > 0, s1 * s1 / (n * s2), 1.0)
