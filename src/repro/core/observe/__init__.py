"""Composable engine-observability API.

The paper's premise is a battery-powered edge system whose fairness and
energy behavior evolve *over time* (Figs. 5–8 are time/rate-resolved),
yet an end-of-trace :class:`~repro.core.types.Metrics` tuple is frozen.
This package gives the engine the same composable, registry-backed shape
the policy and scenario layers have:

    Observer = init × on_event(stage, ...) × finalize  [× halted]

Built-ins (all fixed-shape JAX, riding inside the single jitted — and
vmapped — event loop with CRN preserved):

  * ``timeline`` — :class:`Timeline`, K-bucket queue-occupancy / energy /
    per-type completion time series;
  * ``fairness_trajectory`` — :class:`FairnessTrajectory`, the Alg. 4
    suffered-type indicator over time;
  * ``task_log`` — :class:`TaskLog`, per-task map/start/end times, final
    status and machine (oracle-checked event-for-event);
  * ``energy_budget`` — :class:`EnergyBudget`, the first *dynamic*
    observer: a finite battery capacity the engine consults to stop
    admitting work (Eq. 2's energy-limited regime; inert at the default
    ``capacity=inf``);
  * ``health`` — :class:`Health`, K-bucket machine/site health and
    orphan-pressure series from the faults subsystem
    (:mod:`repro.core.faults`);
  * ``network`` — :class:`Network`, K-bucket per-tier load and
    transfer-energy series from the network subsystem
    (:mod:`repro.core.network`).

See ``docs/engine.md`` for the event-stage contract and a worked
"writing an observer" example.
"""
from __future__ import annotations

from repro.core.observe.base import (
    Observer,
    bucket_index,
    forward_fill,
)
from repro.core.observe.energy import EnergyBudget
from repro.core.observe.health import Health
from repro.core.observe.network import Network
from repro.core.observe.registry import (
    get,
    is_registered,
    list_observers,
    register,
    resolve,
    unregister,
)
from repro.core.observe.tasklog import TaskLog
from repro.core.observe.timeline import FairnessTrajectory, Timeline

__all__ = [
    "EnergyBudget",
    "FairnessTrajectory",
    "Health",
    "Network",
    "Observer",
    "TaskLog",
    "Timeline",
    "bucket_index",
    "describe",
    "forward_fill",
    "from_json_dict",
    "get",
    "is_registered",
    "list_observers",
    "register",
    "resolve",
    "unregister",
]

#: JSON ``kind`` -> built-in observer class, for spec round-tripping.
_KINDS = {
    "timeline": Timeline,
    "fairness_trajectory": FairnessTrajectory,
    "task_log": TaskLog,
    "energy_budget": EnergyBudget,
    "health": Health,
    "network": Network,
}


def from_json_dict(d: dict):
    """Rebuild a built-in observer from its ``to_json_dict`` form."""
    kind = d.get("kind")
    cls = _KINDS.get(kind)
    if cls is None:
        raise ValueError(
            f"unknown observer kind {kind!r}; choose from {sorted(_KINDS)}"
        )
    params = {k: v for k, v in d.items() if k != "kind"}
    if hasattr(cls, "from_json_dict"):
        return cls.from_json_dict(params)
    return cls(**params)


def describe(name_or_observer) -> str:
    """One-line human description of an observer (for ``--list-observers``)."""
    ob = (get(name_or_observer) if isinstance(name_or_observer, str)
          else name_or_observer)
    doc = (ob.__class__.__doc__ or "").strip().splitlines()
    head = getattr(ob, "summary", None) or (
        doc[0].rstrip(".") if doc else ob.__class__.__name__)
    tag = " [dynamic]" if getattr(ob, "is_dynamic", False) else ""
    return f"{head}{tag}"


for _name, _ob in [
    ("timeline", Timeline()),
    ("fairness_trajectory", FairnessTrajectory()),
    ("task_log", TaskLog()),
    ("energy_budget", EnergyBudget()),
    ("health", Health()),
    ("network", Network()),
]:
    register(_name, _ob)
del _name, _ob
