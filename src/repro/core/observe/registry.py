"""Mutable, case-insensitive observer registry.

Observers are addressed by name everywhere — ``SweepSpec.observers``, the
sweep CLI's ``--observers``, ``engine.simulate(observers=...)`` — so
registering an instance here makes it flow through the single-jit sweep
machinery untouched:

    from repro.core import observe

    observe.register("budget-500", observe.EnergyBudget(capacity=500.0))
    # ... SweepSpec(observers=("timeline", "budget-500")) now just works.

The mechanics live in the shared
:class:`repro.core.registry.NameRegistry` (also behind the policy,
scenario and fleet registries).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.registry import NameRegistry

_PROTOCOL = ("init", "on_event", "finalize")


def _check(name, observer) -> None:
    missing = [m for m in _PROTOCOL if not callable(getattr(observer, m, None))]
    if missing:
        raise TypeError(
            f"observer {name!r} must implement the Observer protocol "
            f"(init/on_event/finalize); {observer!r} lacks {missing}"
        )


_REGISTRY = NameRegistry("observer", case=str.lower, check=_check)


def register(name: str, observer, *, overwrite: bool = False):
    """Register ``observer`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True``.
    Returns the (possibly rebound) observer, so registration can be used
    expression-style.

    The registered name becomes the observer's ``name`` — the key of its
    slice of the engine aux and of ``SweepResult.aux`` — so
    ``register("budget-500", EnergyBudget(500.0))`` yields results under
    ``aux["budget-500"]``, and two instances of the same class can ride
    one simulation under distinct names. (Rebinding requires ``name`` to
    be a dataclass field, as on every built-in; other observers are
    registered as-is and keep their own ``name``.)
    """
    key = _REGISTRY.canon(name)
    if (dataclasses.is_dataclass(observer)
            and any(f.name == "name" for f in dataclasses.fields(observer))
            and getattr(observer, "name", key) != key):
        observer = dataclasses.replace(observer, name=key)
    return _REGISTRY.register(name, observer, overwrite=overwrite)


def unregister(name: str) -> None:
    """Remove a registered observer (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get(name: str):
    """Resolve an observer by (case-insensitive) name."""
    return _REGISTRY.get(name)


def list_observers() -> List[str]:
    """Sorted names of every registered observer."""
    return _REGISTRY.names()


def resolve(observers) -> tuple:
    """Normalize a mixed names/instances sequence to an instance tuple.

    Accepts a single name/instance or a sequence; strings resolve through
    the registry (KeyError on unknown names lists what is registered).
    """
    if observers is None:
        return ()
    if isinstance(observers, str) or not hasattr(observers, "__iter__"):
        observers = (observers,)
    out = []
    for ob in observers:
        if isinstance(ob, str):
            ob = get(ob)
        else:
            _check(getattr(ob, "name", ob), ob)
        out.append(ob)
    return tuple(out)
