"""Fleet-health telemetry observer (``health``).

Samples the faults subsystem's per-machine health state
(:mod:`repro.core.faults`) into K uniform time buckets over the trace
horizon, like :class:`~repro.core.observe.timeline.Timeline` — healthy
machine counts (fleet-wide and per-site), the site heartbeat mask the
``health_aware`` dispatcher consults, and the cumulative orphan/retry
pressure failures put on the workload. With no dynamics attached the
series are trivially flat (everything alive, zero orphans), so the
observer composes with any run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.observe.base import Observer, bucket_index, forward_fill
from repro.core.types import SimState, SystemArrays, Trace


@dataclasses.dataclass(frozen=True)
class Health(Observer):
    """K-bucket machine/site health and orphan-pressure series.

    Result pytree (leaves lead with the K=``n_buckets`` axis):
      ``t``            (K,)   right edge of each bucket (seconds)
      ``healthy``      (K,)   alive machines at the last event <= t
      ``site_healthy`` (K,F)  alive machines per federation site
      ``site_alive``   (K,F)  heartbeat mask: site has >= 1 healthy machine
      ``orphans``      (K,)   cumulative orphan re-dispatches (sum of
                              per-task retry counters)
      ``retried``      (K,)   tasks orphaned at least once so far
      ``horizon``      ()     the sampled time horizon (max deadline)

    The F axis sizes from the engine-bound site partition
    (:meth:`with_engine_config`, like :class:`Timeline`'s per-site
    series); flat systems get F=1.
    """

    n_buckets: int = 64
    name: str = "health"
    site_of_machine: tuple | None = None  # engine-bound, not serialized

    def with_engine_config(self, *, site_of_machine=None, **config):
        if site_of_machine is None:
            return self
        return dataclasses.replace(
            self, site_of_machine=tuple(int(s) for s in site_of_machine)
        )

    @property
    def _n_sites(self) -> int:
        if self.site_of_machine is None:
            return 1
        return max(self.site_of_machine) + 1

    def _site_ids(self, n_machines: int) -> jnp.ndarray:
        return jnp.asarray(
            self.site_of_machine or (0,) * n_machines, jnp.int32
        )

    def init(self, trace: Trace, sysarr: SystemArrays):
        K, F = self.n_buckets, self._n_sites
        M = sysarr.eet.shape[1]
        return {
            "horizon": jnp.max(trace.deadline).astype(jnp.float32),
            "touched": jnp.zeros((K,), bool),
            "healthy": jnp.zeros((K,), jnp.int32),
            "site_healthy": jnp.zeros((K, F), jnp.int32),
            "site_alive": jnp.zeros((K, F), bool),
            "orphans": jnp.zeros((K,), jnp.int32),
            "retried": jnp.zeros((K,), jnp.int32),
            # pre-first-event fill: the whole fleet starts healthy
            "init_site_healthy": jax.ops.segment_sum(
                jnp.ones((M,), jnp.int32), self._site_ids(M), F
            ),
        }

    def on_event(self, stage, aux, st: SimState, trace, sysarr):
        if stage != "start":  # sample once per event, at end-of-event state
            return aux
        b = bucket_index(st.now, aux["horizon"], self.n_buckets)
        alive = st.alive.astype(jnp.int32)
        site_healthy = jax.ops.segment_sum(
            alive, self._site_ids(alive.shape[0]), self._n_sites
        )
        return {
            **aux,
            "touched": aux["touched"].at[b].set(True),
            "healthy": aux["healthy"].at[b].set(alive.sum()),
            "site_healthy": aux["site_healthy"].at[b].set(site_healthy),
            "site_alive": aux["site_alive"].at[b].set(site_healthy > 0),
            "orphans": aux["orphans"].at[b].set(
                st.retries.sum().astype(jnp.int32)),
            "retried": aux["retried"].at[b].set(
                (st.retries > 0).sum().astype(jnp.int32)),
        }

    def finalize(self, aux, st: SimState):
        K, F = self.n_buckets, self._n_sites
        series = {k: aux[k] for k in ("healthy", "site_healthy",
                                      "site_alive", "orphans", "retried")}
        init = {
            "healthy": aux["init_site_healthy"].sum(),
            "site_healthy": aux["init_site_healthy"],
            "site_alive": aux["init_site_healthy"] > 0,
            "orphans": jnp.zeros((), jnp.int32),
            "retried": jnp.zeros((), jnp.int32),
        }
        filled = forward_fill(aux["touched"], series, init)
        width = aux["horizon"] / K
        filled["t"] = jnp.arange(1, K + 1, dtype=jnp.float32) * width
        filled["horizon"] = aux["horizon"]
        return filled

    def to_json_dict(self) -> dict:
        return {"kind": "health", "n_buckets": self.n_buckets,
                "name": self.name}
