"""The Observer protocol: composable, jit-resident engine telemetry.

An :class:`Observer` threads its own fixed-shape pytree (``aux``) through
the engine's event loop, next to — never inside — the core
:class:`~repro.core.types.SimState`. The contract mirrors the policy and
scenario algebras: observers are small frozen (hashable) objects the
engine closes over statically, so attaching one specializes the jit once
and never retraces per call, and the whole computation still vmaps over
trace batches with CRN preserved.

Lifecycle, all inside the jitted simulator:

  * ``init(trace, sysarr) -> aux`` — allocate the fixed-shape state.
  * ``on_event(stage, aux, st, trace, sysarr) -> aux`` — called after
    every stage of every event, in :data:`repro.core.engine.STAGES` order
    (``finalize``/``admit``/``faults``/``dispatch``/``map``/``start``;
    the ``faults`` stage only fires when a machine dynamics is
    attached); ``stage`` is a static Python string, so per-stage
    branching costs nothing at runtime.
  * ``finalize(aux, st) -> pytree`` — shape the carried state into the
    result returned next to :class:`~repro.core.types.Metrics`.

**The fixed-shape-aux contract:** every leaf of ``aux`` must keep a
static shape and dtype across ``init``/``on_event`` — it lives in a
``lax.while_loop`` carry. Grow-as-you-go telemetry (e.g. time series)
must therefore pre-allocate (K buckets, N tasks, ...) and scatter into
place, exactly like the engine's own state.

*Dynamic* observers additionally set ``is_dynamic = True`` and implement
``halted(aux, st) -> () bool``: the engine ORs these flags each event and,
once true, stops admitting work (see
:class:`repro.core.observe.energy.EnergyBudget`). Observe-only observers
leave ``is_dynamic`` False and are guaranteed not to perturb the
simulation.
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.core.types import SimState, SystemArrays, Trace


class Observer:
    """Base class for engine observers (see module docstring).

    Subclasses should be frozen dataclasses (hashable — the engine uses
    the instance as part of its static jit cache key) and set ``name`` to
    a unique, stable identifier: it keys the observer's slice of
    ``EngineState.aux`` and of the ``(Metrics, aux)`` result.
    """

    name: str = "observer"
    #: Dynamic observers may halt admission via :meth:`halted`.
    is_dynamic: bool = False

    def with_engine_config(self, **config) -> "Observer":
        """Bind engine configuration just before simulation.

        ``make_simulator`` calls this with the engine's static config
        (currently ``fairness_factor``, ``queue_size`` and the
        ``site_of_machine`` federation partition) so observers that
        mirror engine-config-dependent quantities can inherit them
        instead of requiring the caller to keep two copies in sync
        (:class:`~repro.core.observe.timeline.FairnessTrajectory` and the
        per-site :class:`~repro.core.observe.timeline.Timeline` are the
        built-in examples). Default: return self unchanged.
        """
        return self

    def init(self, trace: Trace, sysarr: SystemArrays) -> Any:
        """Allocate this observer's fixed-shape aux pytree."""
        return {}

    def on_event(self, stage: str, aux: Any, st: SimState, trace: Trace,
                 sysarr: SystemArrays) -> Any:
        """Fold one engine stage into ``aux`` (same structure in and out)."""
        return aux

    def finalize(self, aux: Any, st: SimState) -> Any:
        """Shape the carried aux into the returned result pytree."""
        return aux

    def halted(self, aux: Any, st: SimState) -> jnp.ndarray:
        """() bool — dynamic observers only; ORed into the engine's gate."""
        return jnp.bool_(False)


def bucket_index(now, horizon, n_buckets: int) -> jnp.ndarray:
    """Map an event time onto one of ``n_buckets`` uniform buckets.

    The horizon is a *dynamic* (trace-dependent) scalar, so one compiled
    simulator serves every trace length; the bucket count is static, so
    the series has a fixed shape and vmaps.
    """
    width = horizon / n_buckets
    b = jnp.floor(now / jnp.maximum(width, 1e-9)).astype(jnp.int32)
    return jnp.clip(b, 0, n_buckets - 1)


def forward_fill(touched, series: dict, init: dict) -> dict:
    """Carry the last written bucket forward over untouched ones.

    ``series`` maps name -> (K, ...) array scattered at event buckets;
    ``touched`` is the (K,) bool write mask; ``init`` gives the value
    before the first event (bucket "-1"). Runs as a ``lax.scan`` over the
    static bucket axis, inside jit.
    """
    import jax

    def step(carry, xs):
        t, vals = xs
        new = {k: jnp.where(t, vals[k], carry[k]) for k in vals}
        return new, new

    _, filled = jax.lax.scan(step, init, (touched, series))
    return filled
