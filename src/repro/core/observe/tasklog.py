"""Per-task event log observer (``task_log``).

Records, for every task in the trace, the times of its lifecycle
transitions and where it ran — the event-level ground truth the
pure-Python oracle (:mod:`repro.core.pyengine`) cross-checks
event-for-event. All (N,)-shaped, stamp-once semantics: a field is
written at the first event whose stage shows the transition and never
overwritten, so within-iteration stage ordering (map before start before
the next finalize) is captured exactly.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.observe.base import Observer
from repro.core.types import COMPLETED, QUEUED, RUNNING, SimState


@dataclasses.dataclass(frozen=True)
class TaskLog(Observer):
    """Result pytree (all (N,) except noted):

      ``map_time``   f32, when the task was assigned to a local queue
                     (−1 = never mapped)
      ``start_time`` f32, when it started executing (−1 = never started)
      ``end_time``   f32, when it reached a terminal status (−1 = never)
      ``machine``    int32, the machine it ran on (−1 = none)
      ``site``       int32, the federation site it was dispatched to
                     (−1 = never dispatched; 0 on single-site systems)
      ``status``     int32, final status code (see ``types.STATUS_NAMES``)
      ``retries``    int32, orphan re-dispatches the task suffered from
                     machine failures (0 with no dynamics attached)
      ``ready_time`` f32, when the task landed at its dispatched site
                     (arrival + transfer latency, re-stamped on orphan
                     re-dispatch; −1 with no network attached)

    ``machine`` reflects the *last* machine the task ran on, so a task
    failed over to a backup or re-dispatched after a machine death logs
    its final placement.
    """

    name: str = "task_log"
    summary = ("Per-task map/start/end times, final status and machine "
               "(oracle-checkable)")

    def init(self, trace, sysarr):
        n = trace.arrival.shape[0]
        f = jnp.float32
        return {
            "map_time": jnp.full((n,), -1.0, f),
            "start_time": jnp.full((n,), -1.0, f),
            "end_time": jnp.full((n,), -1.0, f),
            "machine": jnp.full((n,), -1, jnp.int32),
        }

    def on_event(self, stage, aux, st: SimState, trace, sysarr):
        now = st.now

        def stamp(t, mask):
            return jnp.where(mask & (t < 0), now, t)

        n = st.status.shape[0]
        machine = aux["machine"].at[
            jnp.where(st.run_task >= 0, st.run_task, n)
        ].set(jnp.arange(st.run_task.shape[0], dtype=jnp.int32), mode="drop")
        return {
            "map_time": stamp(aux["map_time"], st.status == QUEUED),
            "start_time": stamp(aux["start_time"], st.status == RUNNING),
            "end_time": stamp(aux["end_time"], st.status >= COMPLETED),
            "machine": machine,
        }

    def finalize(self, aux, st: SimState):
        n = st.status.shape[0]
        ready = (st.ready if st.ready is not None
                 else jnp.full((n,), -1.0, jnp.float32))
        return {**aux, "site": st.site, "status": st.status,
                "retries": st.retries, "ready_time": ready}

    def to_json_dict(self) -> dict:
        return {"kind": "task_log", "name": self.name}
