"""The ``energy_budget`` dynamic observer: a finite battery as a runtime
constraint.

FELARE's premise is a *battery-powered* edge system, but the paper's
experiments normalize energy after the fact; related work (Mohammad et
al., arXiv 2012.00143) treats the per-device energy budget as a hard
constraint of the allocation problem. :class:`EnergyBudget` realizes
Eq. 2's energy-limited regime: it tracks cumulative dynamic + idle energy
against a per-fleet battery ``capacity`` and latches an ``exhausted``
flag. As the engine's first *dynamic* observer it feeds that flag back:
once exhausted the engine stops admitting work — no new arrivals enter
the system, pending tasks are cancelled, local queues are flushed with
zero energy — while tasks already executing run to completion (so total
energy may overshoot capacity by at most the in-flight work plus the idle
power of the final event, the "one event's energy" slack).

With the default ``capacity=inf`` the observer never fires and the gating
is inert; with no ``energy_budget`` observer attached at all, the engine
contains no gating ops whatsoever and stays bit-identical to the
unbudgeted simulator.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core.observe.base import Observer
from repro.core.types import SimState


@dataclasses.dataclass(frozen=True)
class EnergyBudget(Observer):
    """Track cumulative energy against a battery ``capacity`` (energy
    units of the simulated system, i.e. power-profile units × seconds).

    ``capacity`` is static configuration (part of the jit cache key, like
    a policy): one compiled simulator per budget level, matching the
    per-fleet-battery framing. Result pytree: ``exhausted`` () bool,
    ``e_total`` () f32 (dynamic + idle at the last event),
    ``t_exhausted`` () f32 (time the budget ran out, inf if it never did),
    ``capacity`` () f32.
    """

    capacity: float = math.inf
    name: str = "energy_budget"

    summary = ("Finite battery capacity; halts admission once cumulative "
               "energy exhausts it")

    @property
    def is_dynamic(self) -> bool:
        # capacity=inf is "unset": keep the admission gate out of the
        # compiled loop entirely so unbudgeted runs are untouched.
        return math.isfinite(self.capacity)

    def init(self, trace, sysarr):
        return {
            "exhausted": jnp.bool_(False),
            "e_total": jnp.float32(0.0),
            "t_exhausted": jnp.float32(jnp.inf),
        }

    def on_event(self, stage, aux, st: SimState, trace, sysarr):
        if stage != "finalize":  # energy only accrues at completions
            return aux
        e_total = st.e_dyn + (sysarr.p_idle * (st.now - st.busy_time)).sum()
        exhausted = aux["exhausted"] | (e_total >= self.capacity)
        newly = exhausted & ~aux["exhausted"]
        return {
            "exhausted": exhausted,
            "e_total": e_total,
            "t_exhausted": jnp.where(newly, st.now, aux["t_exhausted"]),
        }

    def halted(self, aux, st: SimState):
        return aux["exhausted"]

    def finalize(self, aux, st: SimState):
        return {**aux, "capacity": jnp.float32(self.capacity)}

    # ------------------------------------------------------------- JSON
    def to_json_dict(self) -> dict:
        cap = None if math.isinf(self.capacity) else float(self.capacity)
        return {"kind": "energy_budget", "capacity": cap, "name": self.name}

    @classmethod
    def from_json_dict(cls, d: dict) -> "EnergyBudget":
        cap = d.get("capacity", math.inf)
        return cls(capacity=math.inf if cap in (None, "inf") else float(cap),
                   name=d.get("name", "energy_budget"))
