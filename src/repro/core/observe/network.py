"""Edge-cloud network telemetry observer (``network``).

Samples the network subsystem's transfer state
(:mod:`repro.core.network`) into K uniform time buckets over the trace
horizon, like :class:`~repro.core.observe.health.Health` — per-tier
queued+running load (did the cloud actually absorb work, or did
everything stay on-device?), the cumulative transfer energy charged per
destination tier, and the in-transit task count. With ``network="none"``
the series are trivially flat (zero transfer energy, nothing ever in
transit), so the observer composes with any run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.observe.base import Observer, bucket_index, forward_fill
from repro.core.types import PENDING, SimState, SystemArrays, Trace


@dataclasses.dataclass(frozen=True)
class Network(Observer):
    """K-bucket per-tier load and transfer-energy series.

    Result pytree (leaves lead with the K=``n_buckets`` axis):
      ``t``           (K,)   right edge of each bucket (seconds)
      ``tier_load``   (K,T)  queued + running tasks on each tier's
                             machines at the last event <= t
      ``xfer_energy`` (K,T)  cumulative transfer energy charged to links
                             landing on each tier (joules)
      ``in_transit``  (K,)   dispatched tasks still paying link latency
      ``horizon``     ()     the sampled time horizon (max deadline)

    The T axis sizes from the engine-bound tier partition
    (:meth:`with_engine_config`); untiered fleets get T=1 and flat
    all-device series.
    """

    n_buckets: int = 64
    name: str = "network"
    site_of_machine: tuple | None = None  # engine-bound, not serialized
    tier_of_site: tuple | None = None     # engine-bound, not serialized

    def with_engine_config(self, *, site_of_machine=None, tier_of_site=None,
                           **config):
        ob = self
        if site_of_machine is not None:
            ob = dataclasses.replace(
                ob, site_of_machine=tuple(int(s) for s in site_of_machine)
            )
        if tier_of_site is not None:
            ob = dataclasses.replace(
                ob, tier_of_site=tuple(int(t) for t in tier_of_site)
            )
        return ob

    @property
    def _n_tiers(self) -> int:
        if self.tier_of_site is None:
            return 1
        return max(self.tier_of_site) + 1

    def _tier_ids(self, n_machines: int) -> jnp.ndarray:
        """(M,) int32 tier of each machine (site tier through the owner)."""
        sites = self.site_of_machine or (0,) * n_machines
        tiers = self.tier_of_site or (0,) * (max(sites) + 1)
        return jnp.asarray([tiers[s] for s in sites], jnp.int32)

    def init(self, trace: Trace, sysarr: SystemArrays):
        K, T = self.n_buckets, self._n_tiers
        f = jnp.float32
        return {
            "horizon": jnp.max(trace.deadline).astype(f),
            "touched": jnp.zeros((K,), bool),
            "tier_load": jnp.zeros((K, T), jnp.int32),
            "xfer_energy": jnp.zeros((K, T), f),
            "in_transit": jnp.zeros((K,), jnp.int32),
        }

    def on_event(self, stage, aux, st: SimState, trace, sysarr):
        if stage != "start":  # sample once per event, at end-of-event state
            return aux
        b = bucket_index(st.now, aux["horizon"], self.n_buckets)
        M = st.qlen.shape[0]
        T = self._n_tiers
        load = st.qlen + (st.run_task >= 0).astype(jnp.int32)
        tier_load = jax.ops.segment_sum(load, self._tier_ids(M), T)
        e_xfer = (st.e_xfer if st.e_xfer is not None
                  else jnp.zeros((T,), jnp.float32))
        in_transit = (jnp.zeros((), jnp.int32) if st.ready is None
                      else ((st.status == PENDING) & (st.ready > st.now))
                      .sum().astype(jnp.int32))
        return {
            **aux,
            "touched": aux["touched"].at[b].set(True),
            "tier_load": aux["tier_load"].at[b].set(tier_load),
            "xfer_energy": aux["xfer_energy"].at[b].set(e_xfer),
            "in_transit": aux["in_transit"].at[b].set(in_transit),
        }

    def finalize(self, aux, st: SimState):
        K, T = self.n_buckets, self._n_tiers
        series = {k: aux[k] for k in ("tier_load", "xfer_energy",
                                      "in_transit")}
        init = {
            "tier_load": jnp.zeros((T,), jnp.int32),
            "xfer_energy": jnp.zeros((T,), jnp.float32),
            "in_transit": jnp.zeros((), jnp.int32),
        }
        filled = forward_fill(aux["touched"], series, init)
        width = aux["horizon"] / K
        filled["t"] = jnp.arange(1, K + 1, dtype=jnp.float32) * width
        filled["horizon"] = aux["horizon"]
        return filled

    def to_json_dict(self) -> dict:
        return {"kind": "network", "n_buckets": self.n_buckets,
                "name": self.name}
