"""Time-resolved telemetry observers: ``timeline`` and
``fairness_trajectory``.

Both sample the engine state into K uniform time buckets over the trace
horizon (max deadline — no event can fire later), fixed-shape so the
series jits and vmaps. Buckets with no event are forward-filled from the
last observed value in ``finalize``, still inside the jit, so the output
reads as a proper sampled time series (paper Figs. 5–8 are exactly such
time/rate-resolved views).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.observe.base import Observer, bucket_index, forward_fill
from repro.core.types import SimState, SystemArrays, Trace


@dataclasses.dataclass(frozen=True)
class Timeline(Observer):
    """K-bucket queue-occupancy / energy / per-type completion series.

    Result pytree (leaves lead with the K=``n_buckets`` axis):
      ``t``         (K,)   right edge of each bucket (seconds)
      ``qlen``      (K,)   total queued tasks at the last event <= t
      ``running``   (K,)   busy machines at the last event <= t
      ``e_dyn``     (K,)   cumulative dynamic energy
      ``e_idle``    (K,)   cumulative idle energy (estimate at event time)
      ``completed`` (K,S)  cumulative on-time completions per type
      ``arrived``   (K,S)  cumulative arrivals per type
      ``horizon``   ()     the sampled time horizon (max deadline)

    With ``per_site=True`` on a federated system the pytree additionally
    carries per-site series over the F sites (the engine binds the site
    partition via :meth:`with_engine_config`, like the fairness factor):
      ``site_qlen``  (K,F) queued tasks per site
      ``site_e_dyn`` (K,F) cumulative dynamic energy per site (machines'
                     dynamic power × accumulated busy time)
    With the default ``per_site=False`` the pytree is exactly the flat
    one above — attaching the observer to a pre-federation sweep stays
    bit-identical.
    """

    n_buckets: int = 64
    name: str = "timeline"
    per_site: bool = False
    site_of_machine: tuple | None = None  # engine-bound, not serialized

    def with_engine_config(self, *, site_of_machine=None, **config):
        if not self.per_site or site_of_machine is None:
            return self
        return dataclasses.replace(
            self, site_of_machine=tuple(int(s) for s in site_of_machine)
        )

    @property
    def _n_sites(self) -> int:
        if self.site_of_machine is None:
            return 1
        return max(self.site_of_machine) + 1

    def init(self, trace: Trace, sysarr: SystemArrays):
        K, S = self.n_buckets, sysarr.eet.shape[0]
        f = jnp.float32
        aux = {
            "horizon": jnp.max(trace.deadline).astype(f),
            "touched": jnp.zeros((K,), bool),
            "qlen": jnp.zeros((K,), jnp.int32),
            "running": jnp.zeros((K,), jnp.int32),
            "e_dyn": jnp.zeros((K,), f),
            "e_idle": jnp.zeros((K,), f),
            "completed": jnp.zeros((K, S), jnp.int32),
            "arrived": jnp.zeros((K, S), jnp.int32),
        }
        if self.per_site:
            aux["site_qlen"] = jnp.zeros((K, self._n_sites), jnp.int32)
            aux["site_e_dyn"] = jnp.zeros((K, self._n_sites), f)
        return aux

    def on_event(self, stage, aux, st: SimState, trace, sysarr):
        if stage != "start":  # sample once per event, at end-of-event state
            return aux
        b = bucket_index(st.now, aux["horizon"], self.n_buckets)
        e_idle = (sysarr.p_idle * (st.now - st.busy_time)).sum()
        out = {
            "horizon": aux["horizon"],
            "touched": aux["touched"].at[b].set(True),
            "qlen": aux["qlen"].at[b].set(st.qlen.sum()),
            "running": aux["running"].at[b].set(
                (st.run_task >= 0).sum().astype(jnp.int32)),
            "e_dyn": aux["e_dyn"].at[b].set(st.e_dyn),
            "e_idle": aux["e_idle"].at[b].set(e_idle),
            "completed": aux["completed"].at[b].set(st.completed),
            "arrived": aux["arrived"].at[b].set(st.arrived),
        }
        if self.per_site:
            # the partition rides on SystemArrays; the engine-bound tuple
            # (with_engine_config) is the static fallback sizing F.
            site_ids = sysarr.site_of_machine
            if site_ids is None:
                site_ids = jnp.asarray(
                    self.site_of_machine or (0,) * st.qlen.shape[0],
                    jnp.int32)
            out["site_qlen"] = aux["site_qlen"].at[b].set(
                jax.ops.segment_sum(st.qlen, site_ids, self._n_sites))
            out["site_e_dyn"] = aux["site_e_dyn"].at[b].set(
                jax.ops.segment_sum(sysarr.p_dyn * st.busy_time, site_ids,
                                    self._n_sites))
        return out

    def finalize(self, aux, st: SimState):
        K = self.n_buckets
        series = {k: v for k, v in aux.items()
                  if k not in ("horizon", "touched")}
        init = {k: jnp.zeros(v.shape[1:], v.dtype) for k, v in series.items()}
        filled = forward_fill(aux["touched"], series, init)
        width = aux["horizon"] / K
        filled["t"] = (jnp.arange(1, K + 1, dtype=jnp.float32) * width)
        filled["horizon"] = aux["horizon"]
        return filled

    def to_json_dict(self) -> dict:
        return {"kind": "timeline", "n_buckets": self.n_buckets,
                "name": self.name, "per_site": self.per_site}


@dataclasses.dataclass(frozen=True)
class FairnessTrajectory(Observer):
    """Suffered-type indicator (Alg. 4) over K time buckets.

    Samples the same mask the FELARE wrapper consults at each mapping
    event, so the series answers the paper's Fig. 7/8 question *over
    time*: which task types sat below the fairness limit ε = μ − f·σ, and
    for how long. ``fairness_factor`` is an engine-config scalar (not
    part of ``SystemArrays``); with the default ``None`` the engine binds
    its own configured value via :meth:`with_engine_config`, so the
    series always reflects the mask the mapper actually consulted. Set it
    explicitly only to observe a *counterfactual* fairness limit.

    Result: ``suffered`` (K,S) bool, ``cr`` (K,S) per-type completion
    rate, ``t`` (K,) bucket edges, ``horizon`` ().
    """

    n_buckets: int = 64
    fairness_factor: float | None = None
    name: str = "fairness_trajectory"

    def with_engine_config(self, *, fairness_factor=1.0, **config):
        if self.fairness_factor is not None:
            return self
        return dataclasses.replace(self, fairness_factor=fairness_factor)

    def init(self, trace: Trace, sysarr: SystemArrays):
        K, S = self.n_buckets, sysarr.eet.shape[0]
        return {
            "horizon": jnp.max(trace.deadline).astype(jnp.float32),
            "touched": jnp.zeros((K,), bool),
            "suffered": jnp.zeros((K, S), bool),
            "cr": jnp.ones((K, S), jnp.float32),
        }

    def on_event(self, stage, aux, st: SimState, trace, sysarr):
        if stage != "map":  # sample the mask the mapper just consulted
            return aux
        from repro.core import fairness

        b = bucket_index(st.now, aux["horizon"], self.n_buckets)
        suffered = fairness.suffered_types(
            st.completed, st.arrived, self.fairness_factor
        )
        cr = fairness.completion_rates(st.completed, st.arrived)
        return {
            "horizon": aux["horizon"],
            "touched": aux["touched"].at[b].set(True),
            "suffered": aux["suffered"].at[b].set(suffered),
            "cr": aux["cr"].at[b].set(cr.astype(jnp.float32)),
        }

    def finalize(self, aux, st: SimState):
        K = self.n_buckets
        S = aux["suffered"].shape[1]
        series = {"suffered": aux["suffered"], "cr": aux["cr"]}
        init = {
            "suffered": jnp.zeros((S,), bool),
            "cr": jnp.ones((S,), jnp.float32),
        }
        filled = forward_fill(aux["touched"], series, init)
        width = aux["horizon"] / K
        filled["t"] = jnp.arange(1, K + 1, dtype=jnp.float32) * width
        filled["horizon"] = aux["horizon"]
        return filled

    def to_json_dict(self) -> dict:
        return {"kind": "fairness_trajectory", "n_buckets": self.n_buckets,
                "fairness_factor": self.fairness_factor, "name": self.name}
