"""Registry-backed network costs: the edge-cloud hierarchy axis.

FELARE's fleet is flat — every mapper reaches every machine for free.
Real edge ML deployments are tiered (device / edge site / cloud), and
each dispatch across a tier boundary pays data-transfer latency and
energy.  This package makes that cost a first-class, composable axis
next to everything else:

    Run = Policy x Scenario x Dispatcher x Observers x Dynamics
          x **Network**

A :class:`NetworkModel` prices each ``origin site -> chosen site`` link
per task type.  The engine charges the price at the ``dispatch`` stage,
inside the single jitted event loop: the task's *ready time* at the
chosen site is pushed out by the link latency (it cannot be mapped
before it lands) and the link energy is charged to the Eq. 2 dynamic
account (and tallied per destination tier for the ``network``
observer).  Built-ins:

  * ``none`` — free instantaneous links; the default, bit-exact with
    the pre-network engine (the transfer arithmetic is skipped
    entirely);
  * ``uniform_latency`` — one flat price for any cross-site hop;
  * ``tiered`` — a per-tier-pair latency/energy matrix scaled by
    task-type input sizes (device->cloud pays the WAN, same-site is
    free).

Task origins are a salted counter hash over the *device-tier* sites,
so origins are common random numbers across the vmapped sweep grid and
reproducible in the pure-Python oracle.  Dispatchers see the per-task
link costs via ``DispatchContext.xfer_lat`` / ``.xfer_energy``; the
``tier_aware`` built-in dispatcher folds latency into the site EET
comparison and degenerates to ``min_eet`` exactly when the network is
free.

All models are frozen hashable dataclasses behind the shared
:class:`~repro.core.registry.NameRegistry`, interpreted by the pure-
Python oracle event-for-event, and serialize to JSON by kind + fields.
See ``docs/network.md`` for tier semantics, the transfer-accounting
contract and a worked writing-a-network-model example.
"""
from __future__ import annotations

from repro.core.network.base import (
    NetworkModel,
    hash_origins,
    hash_origins_host,
    origin_sites,
)
from repro.core.network.builtins import (
    NoNetwork,
    Tiered,
    UniformLatency,
)
from repro.core.network.registry import (
    get,
    is_registered,
    list_networks,
    register,
    unregister,
)

__all__ = [
    "NetworkModel",
    "NoNetwork",
    "Tiered",
    "UniformLatency",
    "describe",
    "from_json_dict",
    "get",
    "hash_origins",
    "hash_origins_host",
    "is_registered",
    "list_networks",
    "origin_sites",
    "register",
    "resolve",
    "to_json_dict",
    "unregister",
]

#: JSON ``kind`` -> built-in model class, for spec round-tripping.
_KINDS = {
    "none": NoNetwork,
    "uniform_latency": UniformLatency,
    "tiered": Tiered,
}


def resolve(model) -> NetworkModel:
    """Normalize a name-or-instance to a NetworkModel instance.

    ``None`` resolves to :class:`NoNetwork` (the engine further
    normalizes ``kind == "none"`` to "no transfer arithmetic at all",
    keeping the default path bit-exact); strings resolve through the
    registry (KeyError on unknown names lists what is registered).
    """
    if model is None:
        return NoNetwork()
    if isinstance(model, str):
        return get(model)
    if not callable(getattr(model, "cost_tables", None)):
        raise TypeError(
            f"network must be a registered name or implement the "
            f"NetworkModel protocol, got {model!r}"
        )
    return model


def describe(name_or_model) -> str:
    """One-line human description (for ``--list-networks``)."""
    m = resolve(name_or_model)
    doc = (m.__class__.__doc__ or "").strip().splitlines()
    return doc[0].rstrip(".") if doc else m.__class__.__name__


def to_json_dict(model) -> dict:
    """``{"kind": ..., <param>: ...}`` for a built-in-style model."""
    import dataclasses

    m = resolve(model)
    out = {"kind": m.kind}
    for f in dataclasses.fields(m):
        v = getattr(m, f.name)
        if isinstance(v, tuple):
            v = [list(x) if isinstance(x, tuple) else x for x in v]
        out[f.name] = v
    return out


def from_json_dict(d: dict) -> NetworkModel:
    """Rebuild a built-in model from its :func:`to_json_dict` form."""
    kind = d.get("kind")
    cls = _KINDS.get(kind)
    if cls is None:
        raise ValueError(
            f"unknown network kind {kind!r}; choose from {sorted(_KINDS)}"
        )
    params = {k: v for k, v in d.items() if k != "kind"}
    for k, v in params.items():
        if isinstance(v, list):
            params[k] = tuple(
                tuple(x) if isinstance(x, list) else x for x in v
            )
    return cls(**params)


for _name, _model in [
    ("none", NoNetwork()),
    ("uniform_latency", UniformLatency()),
    ("tiered", Tiered()),
]:
    register(_name, _model)
del _name, _model
