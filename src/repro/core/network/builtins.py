"""Built-in network models: ``none``, ``uniform_latency``, ``tiered``.

All three are frozen dataclasses (hashable => valid static jit args)
whose fields fully determine the cost tables, so the pyengine oracle
can interpret them with plain loops and match the engine bit-for-bit
on the f32 decision arithmetic.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from .base import NetworkModel  # noqa: F401  (re-exported for type refs)


def _zero_diag(lat: np.ndarray, en: np.ndarray) -> None:
    idx = np.arange(lat.shape[1])
    lat[:, idx, idx] = 0.0
    en[:, idx, idx] = 0.0


@dataclasses.dataclass(frozen=True)
class NoNetwork:
    """Free, instantaneous links everywhere (the flat PR 8 federation).

    ``resolve("none")`` returns this; the engine normalizes it to *no*
    network before the jit cache key so the traced program is the exact
    PR 8 program (see the frozen-snapshot pin in tests/test_network.py).
    """

    kind = "none"

    def cost_tables(self, tier_of_site: Sequence[int],
                    n_types: int) -> Tuple[np.ndarray, np.ndarray]:
        f = len(tuple(tier_of_site))
        z = np.zeros((n_types, f, f), dtype=np.float32)
        return z, z.copy()


@dataclasses.dataclass(frozen=True)
class UniformLatency:
    """Flat mesh: every cross-site hop costs the same, same-site is free.

    The simplest non-trivial model — one latency and one energy figure
    for any off-site dispatch, independent of task type and tier.  Good
    for "does my dispatcher care about locality at all?" ablations.
    """

    kind = "uniform_latency"

    latency: float = 0.25
    energy: float = 0.0
    salt: int = 0

    def __post_init__(self):
        if float(self.latency) < 0.0 or float(self.energy) < 0.0:
            raise ValueError("uniform_latency costs must be >= 0")

    def cost_tables(self, tier_of_site: Sequence[int],
                    n_types: int) -> Tuple[np.ndarray, np.ndarray]:
        f = len(tuple(tier_of_site))
        lat = np.full((n_types, f, f), np.float32(self.latency),
                      dtype=np.float32)
        en = np.full((n_types, f, f), np.float32(self.energy),
                     dtype=np.float32)
        _zero_diag(lat, en)
        return lat, en


#: Default per-tier-pair link latency (seconds per unit input size):
#: device<->device hops are cheap LAN transfers, device<->cloud pays a
#: WAN round-trip, cloud<->cloud is an in-datacenter no-op.
_DEFAULT_LATENCY = ((0.05, 0.2, 1.0),
                    (0.2, 0.05, 0.5),
                    (1.0, 0.5, 0.0))
#: Default per-tier-pair transfer energy (joules per unit input size):
#: the radio cost of pushing inputs uphill dominates (Sec. I's battery
#: argument applies to the network interface too).
_DEFAULT_ENERGY = ((0.1, 0.5, 2.0),
                   (0.5, 0.1, 1.0),
                   (2.0, 1.0, 0.0))


@dataclasses.dataclass(frozen=True)
class Tiered:
    """Per-tier-pair latency/energy matrix scaled by task input size.

    ``latency[i][j]`` / ``energy[i][j]`` price a transfer from a tier-i
    origin to a tier-j destination, per unit of input size;
    ``input_size[t]`` scales both for task type ``t`` (empty tuple
    means every type moves one unit).  Same-*site* transfers are free
    regardless of the matrix — distinct sites on the same tier pay the
    intra-tier entry (two edge closets still cross a switch).
    """

    kind = "tiered"

    latency: Tuple[Tuple[float, ...], ...] = _DEFAULT_LATENCY
    energy: Tuple[Tuple[float, ...], ...] = _DEFAULT_ENERGY
    input_size: Tuple[float, ...] = ()
    salt: int = 0

    def __post_init__(self):
        lat = tuple(tuple(float(x) for x in row) for row in self.latency)
        en = tuple(tuple(float(x) for x in row) for row in self.energy)
        object.__setattr__(self, "latency", lat)
        object.__setattr__(self, "energy", en)
        object.__setattr__(
            self, "input_size",
            tuple(float(x) for x in self.input_size))
        for name, m in (("latency", lat), ("energy", en)):
            if not m or any(len(row) != len(m) for row in m):
                raise ValueError(f"tiered {name} matrix must be square")
            if any(x < 0.0 for row in m for x in row):
                raise ValueError(f"tiered {name} entries must be >= 0")
        if len(lat) != len(en):
            raise ValueError("latency and energy matrices must agree in size")
        if any(s < 0.0 for s in self.input_size):
            raise ValueError("input_size entries must be >= 0")

    def cost_tables(self, tier_of_site: Sequence[int],
                    n_types: int) -> Tuple[np.ndarray, np.ndarray]:
        tiers = tuple(int(t) for t in tier_of_site)
        n_tiers = len(self.latency)
        if tiers and max(tiers) >= n_tiers:
            raise ValueError(
                f"fleet uses tier {max(tiers)} but the tiered matrix only "
                f"covers tiers 0..{n_tiers - 1}")
        if self.input_size and len(self.input_size) != n_types:
            raise ValueError(
                f"input_size has {len(self.input_size)} entries for "
                f"{n_types} task types")
        size = (np.asarray(self.input_size, dtype=np.float32)
                if self.input_size
                else np.ones((n_types,), dtype=np.float32))
        t = np.asarray(tiers, dtype=np.int32)
        # (F, F) per-tier-pair prices, gathered through the site->tier map,
        # then scaled per type: cost[t, o, s] = size[t] * M[tier[o], tier[s]].
        lat_ff = np.asarray(self.latency, dtype=np.float32)[
            t[:, None], t[None, :]]
        en_ff = np.asarray(self.energy, dtype=np.float32)[
            t[:, None], t[None, :]]
        lat = (size[:, None, None] * lat_ff[None, :, :]).astype(np.float32)
        en = (size[:, None, None] * en_ff[None, :, :]).astype(np.float32)
        _zero_diag(lat, en)
        return lat, en
