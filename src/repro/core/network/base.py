"""Network-model protocol: the sixth composable axis of a run.

A :class:`NetworkModel` describes the *edge-cloud hierarchy* the fleet
lives in: which sites are cheap to reach from a task's origin and what
each dispatch across a tier boundary costs in transfer latency and
transfer energy.  The contract mirrors ``faults.MachineDynamics``:

* models are **frozen, hashable dataclasses** — they ride into
  ``jax.jit`` as static arguments, so two sweeps with the same model
  share one compiled program;
* all randomness is **counter-based** (origin sites are a salted
  multiplicative hash of the task index), so the engine and the
  pyengine oracle derive identical origins with no RNG state;
* the model is pure *data*: ``cost_tables`` returns host-side numpy
  constants and the engine folds them into the traced program.  The
  pyengine oracle interprets the same dataclass fields with plain
  Python loops, which is what makes event-for-event parity testable.

Semantics
---------
Each task originates at a *device-tier* site (the lowest tier present
in the fleet).  When the dispatch stage routes the task to site ``s``,
the link ``origin -> s`` charges:

* **transfer latency** — the task's ready-time at ``s`` becomes
  ``now + lat[type, origin, s]``; the mapper cannot place it on a
  machine before that (an in-transit task is invisible to Eq. 1/3
  scoring until it lands);
* **transfer energy** — ``en[type, origin, s]`` joules are charged to
  the Eq. 2 dynamic-energy account (radios draw from the same battery
  the accelerators do) and recorded per destination tier for the
  ``network`` observer.

Same-site dispatch is always free: ``lat[t, s, s] == en[t, s, s] == 0``
is part of the contract and is validated by the built-ins.
"""
from __future__ import annotations

from typing import Protocol, Sequence, Tuple, runtime_checkable

import numpy as np


@runtime_checkable
class NetworkModel(Protocol):
    """Static description of inter-site transfer costs.

    Implementations must be hashable (frozen dataclasses) because the
    model is a static argument of the jitted simulator.  ``kind`` names
    the model in registries, JSON payloads, and the pyengine oracle.
    """

    kind: str

    def cost_tables(self, tier_of_site: Sequence[int],
                    n_types: int) -> Tuple[np.ndarray, np.ndarray]:
        """Return ``(lat, en)`` cost tables, each ``(n_types, F, F)`` f32.

        ``lat[t, o, s]`` / ``en[t, o, s]`` are the transfer latency /
        energy for a type-``t`` task dispatched from origin site ``o``
        to site ``s``.  Diagonals (``o == s``) must be exactly zero.
        Tables are host-side constants — the engine folds them into the
        trace, so they may not depend on runtime state.
        """
        ...


def origin_sites(tier_of_site: Sequence[int]) -> Tuple[int, ...]:
    """Sites eligible to originate tasks: every site on the lowest tier.

    On a flat (untiered) fleet every site is tier 0, so every site is an
    origin — the tiered model then degenerates to a flat federation.
    """
    tiers = tuple(int(t) for t in tier_of_site)
    lo = min(tiers)
    return tuple(i for i, t in enumerate(tiers) if t == lo)


def hash_origins(n_tasks: int, eligible: Sequence[int], salt: int = 0):
    """Deterministic per-task origin sites (device-side, traced).

    The same salted multiplicative hash the ``sticky`` dispatcher uses,
    mapped onto the *eligible* origin list so cloud/edge sites never
    originate work.  Counter-based: task ``k`` always hashes to the
    same origin, with no RNG state threaded through the loop.
    """
    import jax.numpy as jnp

    elig = jnp.asarray(tuple(int(s) for s in eligible), dtype=jnp.int32)
    k = jnp.arange(n_tasks, dtype=jnp.uint32)
    h = (k * jnp.uint32(2654435761) + jnp.uint32(salt)) % jnp.uint32(
        elig.shape[0])
    return elig[h.astype(jnp.int32)]


def hash_origins_host(n_tasks: int, eligible: Sequence[int],
                      salt: int = 0) -> np.ndarray:
    """Host mirror of :func:`hash_origins` (pyengine oracle)."""
    elig = np.asarray(tuple(int(s) for s in eligible), dtype=np.int32)
    k = np.arange(n_tasks, dtype=np.uint64)
    h = ((k * 2654435761 + salt) & 0xFFFFFFFF) % elig.shape[0]
    return elig[h.astype(np.int32)]
