"""Mutable, case-insensitive network-model registry.

Network models are addressed by name everywhere — ``SweepSpec.network``,
the sweep CLI's ``--network``, ``engine.simulate(network=...)`` — so
registering one here makes it flow through the single-jit sweep
machinery untouched:

    from repro.core import network

    network.register("wan", network.UniformLatency(latency=1.0))
    # ... SweepSpec(system="tiered_x4", network="wan") just works.

The mechanics live in the shared
:class:`repro.core.registry.NameRegistry` (also behind the policy,
scenario, fleet, observer, dispatcher and dynamics registries).
"""
from __future__ import annotations

from typing import List

from repro.core.registry import NameRegistry


def _check(name, model) -> None:
    if not callable(getattr(model, "cost_tables", None)):
        raise TypeError(
            f"network {name!r} must implement the NetworkModel protocol "
            f"(a .cost_tables(tier_of_site, n_types) method); got {model!r}"
        )


_REGISTRY = NameRegistry("network", case=str.lower, check=_check)


def register(name: str, model, *, overwrite: bool = False):
    """Register ``model`` under ``name`` (case-insensitive).

    Re-registering an existing name raises unless ``overwrite=True``.
    Returns the model, so registration can be used expression-style.
    """
    return _REGISTRY.register(name, model, overwrite=overwrite)


def unregister(name: str) -> None:
    """Remove a registered network model (KeyError if absent)."""
    _REGISTRY.unregister(name)


def is_registered(name: str) -> bool:
    return _REGISTRY.is_registered(name)


def get(name: str):
    """Resolve a network model by (case-insensitive) name, or raise
    KeyError listing every registered name."""
    return _REGISTRY.get(name)


def list_networks() -> List[str]:
    """Sorted names of every registered network model."""
    return _REGISTRY.names()
