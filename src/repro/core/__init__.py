"""FELARE core: the paper's scheduling contribution, in JAX.

Public surface:
  equations  — Eqs. 1-4 (completion time, energy, fairness limit, deadlines)
  eet        — Table I, CVB synthesis, AWS scenario
  workload   — Poisson trace generation
  heuristics — ELARE / FELARE / MM / MSD / MMU
  fairness   — completion rates, suffered task types (Alg. 4)
  dispatch   — federation site-selection rules (sticky, round_robin,
               least_queued, min_eet, fair_spill) behind a registry
  engine     — jittable/vmappable discrete-event simulator
  observe    — composable engine observers (timeline, task_log,
               fairness_trajectory, energy_budget) behind a registry
  pyengine   — independent pure-Python oracle
  api        — experiment-level helpers (paper_system, run_study)
"""
from repro.core import api, dispatch, eet, engine, equations, fairness
from repro.core import heuristics, observe, pyengine, workload
from repro.core.types import Metrics, SystemSpec, Trace

__all__ = [
    "api", "dispatch", "eet", "engine", "equations", "fairness",
    "heuristics", "observe", "pyengine", "workload", "Metrics",
    "SystemSpec", "Trace",
]
