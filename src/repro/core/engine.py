"""Discrete-event simulation engine for the HEC system, in pure JAX.

The whole simulator is a ``lax.while_loop`` over events with fixed-shape
state, so a full workload trace is one jittable computation and a batch of
traces is one ``vmap``. Semantics follow Sec. III of the paper:

  * mapping events fire on task arrival and task completion (plus a progress
    event at the earliest pending deadline so stale tasks are always purged);
  * machines serve their bounded local queues FCFS;
  * a running task that passes its deadline is killed at the deadline (its
    dynamic energy is wasted, Eq. 2 row 1);
  * a queued task whose deadline passed before it starts is dropped with zero
    energy (Eq. 2 row 3);
  * per-type completion counters feed the fairness monitor continuously.

Each event is processed as six named stages, threading an
:class:`~repro.core.types.EngineState` = ``(SimState, aux)``:

  ``finalize`` -> ``admit`` -> ``faults`` -> ``dispatch`` -> ``map`` -> ``start``

``faults`` evolves the per-machine health state under a pluggable
:class:`~repro.core.faults.MachineDynamics` (failures, site outages,
stragglers): dead machines read avail=BIG/EET=BIG downstream exactly
like out-of-site machines, their queued tasks and running task become
*orphans* re-entering the dispatch queue (bounded retry count), and
``with_backup``-wrapped policies fail orphans over to pre-nominated
backup machines. With the default ``dynamics="none"`` the stage is
skipped entirely — no masking enters the traced program and the loop is
bit-exact with the pre-faults engine (observers never see a ``faults``
stage then). Because ``finalize`` runs first, a task completing at
exactly the instant its machine dies *completes* — the deterministic
tie rule both engines share.

``dispatch`` is the federation's first level: a pluggable
:class:`~repro.core.dispatch.Dispatcher` assigns each newly-admitted task
to one of F *sites* (bounded partitions of the machine set), and ``map``
then evaluates the mapping policy as one ``jax.vmap`` over the F
site-masked :class:`~repro.core.policy.MachineView` batches — the site
count enters the program as *data* (array extents), never as program
structure, so trace size and compile time are flat in F: an F=100
federation compiles the same program as an F=2 one. With one site (every
spec built before the federation layer) the dispatch stage degenerates
to "site 0" and the map stage is the exact pre-federation computation,
so flat runs stay bit-identical.

With a non-trivial :mod:`repro.core.network` model attached, the
dispatch stage additionally *pays each task's link*: the chosen site's
transfer latency shifts the task's ready time (the mapper cannot place
an in-transit task until it lands — landings drive events of their own)
and the link's transfer energy is charged to Eq. 2's dynamic account
(and tallied per destination tier for the ``network`` observer). With
the default ``network="none"`` every network field stays out of the
state pytree and the loop is bit-exact with the pre-network engine.

After every stage, each attached :class:`~repro.core.observe.Observer`
folds the stage name and the fresh :class:`~repro.core.types.SimState`
into its own fixed-shape ``aux`` pytree, so time-resolved telemetry
(queue/energy/fairness trajectories, per-task logs) rides inside the same
single jitted ``while_loop`` — and *dynamic* observers (the energy
budget) can expose a ``halted`` flag the engine consults to stop
admitting work (Eq. 2's energy-limited regime). With no observers the
loop is structurally and bit-for-bit identical to the bare engine.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fairness
from repro.core.dispatch.base import DispatchContext
from repro.core.policy import BIG, MachineView
from repro.core.types import (
    CANCELLED,
    COMPLETED,
    MISSED,
    PENDING,
    QUEUED,
    RUNNING,
    UNARRIVED,
    EngineState,
    MapAction,
    Metrics,
    SimState,
    SystemArrays,
    Trace,
    site_membership,
)

INF = jnp.float32(jnp.inf)

#: Stage names, in event order. Observers receive each after it ran
#: (``faults`` only fires when a non-trivial dynamics is attached).
STAGES = ("finalize", "admit", "faults", "dispatch", "map", "start")


def _init_state(trace: Trace, n_machines: int, queue_size: int,
                n_types: int, *, backup_k: int = 0,
                network: bool = False, n_tiers: int = 1) -> SimState:
    n = trace.arrival.shape[0]
    M, Q, S = n_machines, queue_size, n_types
    f = jnp.float32
    return SimState(
        now=f(0.0),
        status=jnp.full((n,), UNARRIVED, jnp.int32),
        site=jnp.full((n,), -1, jnp.int32),
        run_task=jnp.full((M,), -1, jnp.int32),
        run_start=jnp.zeros((M,), f),
        run_end_act=jnp.full((M,), jnp.inf, f),
        run_end_exp=jnp.zeros((M,), f),
        run_success=jnp.zeros((M,), bool),
        queue=jnp.full((M, Q), -1, jnp.int32),
        qlen=jnp.zeros((M,), jnp.int32),
        busy_time=jnp.zeros((M,), f),
        e_dyn=f(0.0),
        e_wasted=f(0.0),
        completed=jnp.zeros((S,), jnp.int32),
        missed=jnp.zeros((S,), jnp.int32),
        cancelled=jnp.zeros((S,), jnp.int32),
        arrived=jnp.zeros((S,), jnp.int32),
        steps=jnp.int32(0),
        alive=jnp.ones((M,), bool),
        slowdown=jnp.ones((M,), f),
        retries=jnp.zeros((n,), jnp.int32),
        backup=jnp.full((n, backup_k), -1, jnp.int32),
        # network fields stay absent (None) with network="none" so the
        # default pytree — and therefore the traced program — is exactly
        # the pre-network one.
        ready=(trace.arrival.astype(f) if network else None),
        e_xfer=(jnp.zeros((n_tiers,), f) if network else None),
    )


def _next_event_time(st: SimState, trace: Trace,
                     halted: Optional[jnp.ndarray] = None,
                     wake_ts: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    pending = st.status == PENDING
    unarrived = st.status == UNARRIVED
    t_arr = jnp.min(jnp.where(unarrived, trace.arrival, jnp.inf))
    if halted is not None:
        # energy-limited shutdown: un-admitted arrivals no longer drive
        # events (they would otherwise pin the next-event time forever).
        t_arr = jnp.where(halted, jnp.inf, t_arr)
    t_comp = jnp.min(st.run_end_act)
    # progress guard: earliest pending deadline (so stale tasks get purged
    # even when no machine is busy and no arrivals remain).
    t_dead = jnp.min(jnp.where(pending, trace.deadline, jnp.inf))
    t = jnp.minimum(jnp.minimum(t_arr, t_comp), t_dead)
    if st.ready is not None:
        # in-transit landings: a dispatched task becomes mappable at its
        # site-arrival time, which must drive an event even when no
        # machine is busy and no arrivals remain.
        t_ready = jnp.min(jnp.where(pending & (st.ready > st.now),
                                    st.ready, jnp.inf))
        t = jnp.minimum(t, t_ready)
    if wake_ts is not None:
        # scheduled-dynamics wake-ups (outage window edges): each fires at
        # most once — strictly future times only, and the event it drives
        # sets ``now`` onto (at or past) it.
        t_wake = jnp.min(jnp.where(wake_ts > st.now, wake_ts, jnp.inf))
        t = jnp.minimum(t, t_wake)
    return t


# ---------------------------------------------------------------------------
# Event stages. Each is a pure SimState -> SimState map; the loop body runs
# them in STAGES order and hands the result to every observer in between.
# ---------------------------------------------------------------------------
def _stage_finalize(st: SimState, trace: Trace, sysarr: SystemArrays):
    """Close out machines whose running task's actual end <= now."""
    done = (st.run_task >= 0) & (st.run_end_act <= st.now)
    idx = jnp.where(done, st.run_task, 0)
    ttype = trace.task_type[idx]
    dur = jnp.where(done, st.run_end_act - st.run_start, 0.0)
    energy = sysarr.p_dyn * dur
    ok = done & st.run_success
    ko = done & ~st.run_success

    completed = st.completed.at[ttype].add(ok.astype(jnp.int32))
    missed = st.missed.at[ttype].add(ko.astype(jnp.int32))
    e_dyn = st.e_dyn + energy.sum()
    e_wasted = st.e_wasted + jnp.where(ko, energy, 0.0).sum()
    busy = st.busy_time + dur
    sidx = jnp.where(done, idx, st.status.shape[0])  # OOB sentinel -> dropped
    status = st.status.at[sidx].set(
        jnp.where(ok, COMPLETED, MISSED), mode="drop"
    )
    return st._replace(
        status=status,
        run_task=jnp.where(done, -1, st.run_task),
        run_end_act=jnp.where(done, jnp.inf, st.run_end_act),
        run_end_exp=jnp.where(done, st.now, st.run_end_exp),
        run_success=jnp.where(done, False, st.run_success),
        completed=completed,
        missed=missed,
        cancelled=st.cancelled,
        e_dyn=e_dyn,
        e_wasted=e_wasted,
        busy_time=busy,
    )


def _stage_admit(st: SimState, trace: Trace,
                 halted: Optional[jnp.ndarray] = None):
    """Admit newly-arrived tasks to the arriving queue.

    When a dynamic observer reports ``halted`` (battery exhausted), the
    system stops taking work: nothing is admitted, every pending task is
    cancelled, and local queues are flushed (their tasks cancelled with
    zero energy). Tasks already running finish normally — the one-event
    slack the energy-budget contract allows.
    """
    newly = (st.status == UNARRIVED) & (trace.arrival <= st.now)
    if halted is not None:
        newly = newly & ~halted
    status = jnp.where(newly, PENDING, st.status)
    arrived = st.arrived + jax.ops.segment_sum(
        newly.astype(jnp.int32), trace.task_type, st.arrived.shape[0]
    )
    st = st._replace(status=status, arrived=arrived)
    if halted is None:
        return st
    return _halt_shutdown(st, trace, halted)


def _halt_shutdown(st: SimState, trace: Trace, halted: jnp.ndarray):
    """Cancel pending tasks and flush local queues once ``halted``."""
    n, n_types = st.status.shape[0], st.cancelled.shape[0]
    drop = halted & (st.status == PENDING)
    status = jnp.where(drop, CANCELLED, st.status)
    cancelled = st.cancelled + jax.ops.segment_sum(
        drop.astype(jnp.int32), trace.task_type, n_types
    )
    victim = halted & (st.queue >= 0)
    vidx = jnp.where(victim, st.queue, n)  # OOB sentinel -> dropped
    status = status.at[vidx.reshape(-1)].set(CANCELLED, mode="drop")
    cancelled = cancelled + jax.ops.segment_sum(
        victim.reshape(-1).astype(jnp.int32),
        trace.task_type[jnp.clip(vidx, 0, n - 1)].reshape(-1),
        n_types,
    )
    return st._replace(
        status=status,
        cancelled=cancelled,
        queue=jnp.where(victim, -1, st.queue),
        qlen=jnp.where(halted, 0, st.qlen),
    )


def _stage_faults(st: SimState, trace: Trace, sysarr: SystemArrays,
                  dynamics, horizon, n_types: int, backup_k: int,
                  site_of_machine: np.ndarray, n_sites: int):
    """Evolve machine health and orphan the casualties.

    Order within the stage (mirrored exactly by the oracle):

      1. ``dynamics.step`` proposes the next ``(alive, slowdown)``.
      2. Newly-dead machines flush their local queues — each queued task
         is *orphaned*: its retry count increments and it re-enters the
         dispatch queue (PENDING, site cleared) unless the count exceeds
         ``dynamics.max_retries``, in which case it is CANCELLED.
      3. Newly-dead machines kill their running task: the partial run's
         dynamic energy is spent *and* wasted (the work is lost), then
         the task is orphaned like a queue victim — except that under a
         ``with_backup`` policy a running-task orphan with a healthy,
         non-full backup machine fails over: it is enqueued there
         directly (QUEUED on the backup's site), skipping the
         dispatch/map round-trip. Queue victims never fail over — they
         had no primary yet in the FEST sense.

    Orphans made PENDING here are re-dispatched at *this same event*
    (the dispatch stage follows), so a one-event outage costs at most
    one retry. Machines revive with clean state; the finalize stage ran
    first, so a task completing at exactly the death instant completes.
    """
    from repro.core.faults.base import FaultContext

    M, Q = st.queue.shape
    n = st.status.shape[0]
    max_retries = int(getattr(dynamics, "max_retries", 3))
    ctx = FaultContext(
        now=st.now,
        steps=st.steps,
        horizon=horizon,
        alive=st.alive,
        slowdown=st.slowdown,
        site_of_machine=np.asarray(site_of_machine, np.int32),
        n_sites=n_sites,
    )
    alive_new, slow_new = dynamics.step(ctx)
    alive_new = alive_new.astype(bool)
    slow_new = slow_new.astype(jnp.float32)
    died = st.alive & ~alive_new

    # -- 2. flush dead machines' local queues (queued tasks orphan) --------
    qvict = died[:, None] & (st.queue >= 0)
    qidx = jnp.where(qvict, st.queue, n)          # OOB sentinel -> dropped
    retries = st.retries.at[qidx.reshape(-1)].add(1, mode="drop")
    qsafe = jnp.clip(qidx, 0, n - 1)
    q_exh = qvict & (retries[qsafe] > max_retries)
    status = st.status.at[qidx.reshape(-1)].set(
        jnp.where(q_exh, CANCELLED, PENDING).reshape(-1), mode="drop"
    )
    cancelled = st.cancelled + jax.ops.segment_sum(
        q_exh.reshape(-1).astype(jnp.int32),
        trace.task_type[qsafe].reshape(-1),
        n_types,
    )
    # surviving orphans lose their site (re-dispatched this same event);
    # exhausted ones keep it, like any other cancelled task.
    site = st.site.at[
        jnp.where(qvict & ~q_exh, st.queue, n).reshape(-1)
    ].set(-1, mode="drop")
    queue = jnp.where(died[:, None], -1, st.queue)
    qlen = jnp.where(died, 0, st.qlen)

    # -- 3. kill running tasks on newly-dead machines ----------------------
    kill = died & (st.run_task >= 0)
    vict = jnp.where(kill, st.run_task, 0)
    dur = jnp.where(kill, st.now - st.run_start, 0.0)
    energy = sysarr.p_dyn * dur
    e_dyn = st.e_dyn + energy.sum()
    e_wasted = st.e_wasted + jnp.where(kill, energy, 0.0).sum()
    busy = st.busy_time + dur
    retries = retries.at[jnp.where(kill, vict, n)].add(1, mode="drop")
    r_exh = kill & (retries[vict] > max_retries)
    ttype_v = trace.task_type[vict]

    if backup_k == 0:
        status = status.at[jnp.where(kill, vict, n)].set(
            jnp.where(r_exh, CANCELLED, PENDING), mode="drop"
        )
        cancelled = cancelled + jax.ops.segment_sum(
            r_exh.astype(jnp.int32), ttype_v, n_types
        )
        site = site.at[jnp.where(kill & ~r_exh, vict, n)].set(
            -1, mode="drop"
        )
    else:
        # Failover scan, machine index order (queue capacity is consumed
        # sequentially — two orphans favoring the same backup must not
        # both land in its last slot).
        sids = jnp.asarray(np.asarray(site_of_machine, np.int32))
        bks_all = st.backup[vict]                 # (M, k)

        def step(carry, xs):
            status, site, queue, qlen, cancelled = carry
            kill_m, v, exh, bks, tt = xs
            chosen = jnp.int32(-1)
            for i in range(backup_k):
                b = bks[i]
                bc = jnp.clip(b, 0)
                okb = ((chosen < 0) & (b >= 0) & alive_new[bc]
                       & (qlen[bc] < Q))
                chosen = jnp.where(okb, b, chosen)
            fail_over = kill_m & ~exh & (chosen >= 0)
            bc = jnp.clip(chosen, 0)
            slot = jnp.clip(qlen[bc], 0, Q - 1)
            queue = queue.at[bc, slot].set(
                jnp.where(fail_over, v, queue[bc, slot])
            )
            qlen = qlen.at[bc].add(jnp.where(fail_over, 1, 0))
            new_stat = jnp.where(
                exh, CANCELLED, jnp.where(fail_over, QUEUED, PENDING)
            )
            status = status.at[v].set(
                jnp.where(kill_m, new_stat, status[v])
            )
            new_site = jnp.where(
                fail_over, sids[bc], jnp.where(exh, site[v], -1)
            )
            site = site.at[v].set(jnp.where(kill_m, new_site, site[v]))
            cancelled = cancelled.at[tt].add(
                jnp.where(kill_m & exh, 1, 0)
            )
            return (status, site, queue, qlen, cancelled), None

        (status, site, queue, qlen, cancelled), _ = jax.lax.scan(
            step, (status, site, queue, qlen, cancelled),
            (kill, vict, r_exh, bks_all, ttype_v),
        )

    return st._replace(
        alive=alive_new,
        slowdown=slow_new,
        status=status,
        site=site,
        queue=queue,
        qlen=qlen,
        retries=retries,
        cancelled=cancelled,
        run_task=jnp.where(kill, -1, st.run_task),
        run_end_act=jnp.where(kill, jnp.inf, st.run_end_act),
        run_end_exp=jnp.where(kill, st.now, st.run_end_exp),
        run_success=jnp.where(kill, False, st.run_success),
        e_dyn=e_dyn,
        e_wasted=e_wasted,
        busy_time=busy,
    )


def _stage_dispatch(st: SimState, trace: Trace, sysarr: SystemArrays,
                    dispatcher, site_of_machine: np.ndarray, n_sites: int,
                    fairness_factor: float, health: bool = False,
                    net=None):
    """Assign newly-admitted tasks to federation sites (dispatch-once).

    A task is dispatched at the first event where it is PENDING and still
    siteless; its site never changes afterwards. With one site the
    dispatcher is bypassed entirely (every task -> site 0), so flat
    systems carry zero dispatch ops in the traced loop body.

    With ``health`` (a non-trivial dynamics attached) the context's EET
    table is health-masked — dead machines' columns read BIG, straggler
    columns are slowdown-scaled — and ``ctx.alive`` carries the raw
    mask, from which ``ctx.site_alive`` derives the heartbeat aggregate
    ("site alive iff >= 1 healthy machine") that ``sequential_balance``
    and ``health_aware`` route on. ``min_eet`` needs no code of its own:
    a fully-dead site's ``eet_min_by_site`` column is BIG automatically.

    With ``net`` (a non-trivial network model attached — a 4-tuple
    ``(lat_task, en_task, site_tier, n_tiers)`` of per-task (N, F) link
    costs and the static tier map) each fresh dispatch *pays its link*:

      * the task's ready time at the chosen site becomes ``now +
        lat_task[k, site]`` — the map stage will not place it before it
        lands (dispatch decisions are made at admission, on the
        information available then; the transfer is committed);
      * ``en_task[k, site]`` joules are charged to the Eq. 2 dynamic
        account and tallied per destination tier (``e_xfer``);
      * in-transit tasks whose deadline passes before they land are
        CANCELLED here (the map stage cannot see them, so the stale-drop
        policies never get the chance) — the transfer energy already
        spent stays spent, but is not counted as *wasted* compute
        energy, matching Eq. 2's row-3 zero-compute-energy drop.

    An orphan re-dispatched by the faults stage (site cleared) pays the
    transfer again from its origin; a backup failover does not — FEST-
    style backups pre-stage their inputs at nomination time.
    """
    new = (st.status == PENDING) & (st.site < 0)
    if n_sites == 1:
        sites = 0  # scalar — broadcasts in the wheres below, like PR 8
    else:
        eet = sysarr.eet
        alive = None
        if health:
            alive = st.alive
            eet = jnp.where(alive[None, :], eet * st.slowdown[None, :], BIG)
        ctx = DispatchContext(
            now=st.now,
            unassigned=new,
            task_type=trace.task_type,
            deadline=trace.deadline,
            qlen=st.qlen,
            running=st.run_task >= 0,
            completed=st.completed,
            arrived=st.arrived,
            eet=eet,
            site_of_machine=site_of_machine,
            n_sites=n_sites,
            fairness_factor=fairness_factor,
            alive=alive,
            xfer_lat=None if net is None else net[0],
            xfer_energy=None if net is None else net[1],
        )
        sites = jnp.clip(dispatcher.dispatch(ctx).astype(jnp.int32),
                         0, n_sites - 1)
    st = st._replace(site=jnp.where(new, sites, st.site))
    if net is None:
        return st
    lat_task, en_task, site_tier, n_tiers = net
    s = jnp.clip(jnp.where(new, sites, 0), 0, n_sites - 1)
    lat = jnp.take_along_axis(lat_task, s[:, None], axis=1)[:, 0]
    en = jnp.take_along_axis(en_task, s[:, None], axis=1)[:, 0]
    ready = jnp.where(new, st.now + lat, st.ready)
    pay = jnp.where(new, en, 0.0)
    e_xfer = st.e_xfer + jax.ops.segment_sum(pay, site_tier[s], n_tiers)
    stale = ((st.status == PENDING) & (ready > st.now)
             & (st.now >= trace.deadline))
    status = jnp.where(stale, CANCELLED, st.status)
    cancelled = st.cancelled + jax.ops.segment_sum(
        stale.astype(jnp.int32), trace.task_type, st.cancelled.shape[0]
    )
    return st._replace(ready=ready, e_dyn=st.e_dyn + pay.sum(),
                       e_xfer=e_xfer, status=status, cancelled=cancelled)


def _stage_map(st: SimState, trace: Trace, sysarr: SystemArrays,
               select_fn: Callable, fairness_factor: float, n_types: int,
               site_members: Optional[np.ndarray] = None,
               site_of_machine: Optional[np.ndarray] = None,
               health: bool = False, backup_k: int = 0):
    """Run the per-site mapping policy and apply the combined MapAction.

    ``site_members`` is the (F, M) partition grid — a host constant whose
    *values* are data, not program structure: the policy is evaluated once
    as a single ``jax.vmap`` over the F site-masked machine views, so the
    traced program contains exactly one copy of the mapping computation
    regardless of F (trace size and compile time are flat in the site
    count; only array extents grow). Machines outside a site appear full
    (``qlen = Q``), empty-queued, and infinitely far away (``avail_base =
    BIG``, EET rows ``BIG``), so nominators, feasibility guards and the
    fairness eviction all see a site-local system — in particular
    ``hopeless``/``rescuable`` use the site's own fastest machine.

    The F per-site :class:`MapAction` batches are combined by gathers:
    machine ``m`` takes its owning site's ``assign``/``queue_drop`` row
    (``site_of_machine`` is the (M,) owner map), and task ``n`` takes its
    dispatched site's ``drop`` entry — the same one-owner-per-entry
    semantics the PR 5 static unroll realized with F masked merges
    (pinned bit-exact in ``tests/test_siteloop_vmap.py``). With F=1 the
    branch below is literally the pre-federation computation (no masking
    ops), keeping flat runs bit-exact.
    """
    action = _map_action(st, trace, sysarr, select_fn, fairness_factor,
                         site_members, site_of_machine, health)
    st2 = _apply_action(st, trace, action, n_types)
    if backup_k > 0:
        st2 = _nominate_backups(st2, trace, sysarr, action, backup_k)
    return st2


def _nominate_backups(st: SimState, trace: Trace, sysarr: SystemArrays,
                      action: MapAction, backup_k: int) -> SimState:
    """Record k backup machines for each task enqueued this event.

    FEST-style greedy: per assigned task, the k healthy machines
    (primary excluded, disjoint among themselves) minimizing expected
    completion ``avail_base + EET`` — iterative masked argmins, ties to
    the lowest machine index. Backups are passive standbys written into
    ``st.backup``; the faults stage reads them only when the primary
    dies mid-run. ``-1`` marks "no eligible backup" (fewer than k
    healthy candidates).
    """
    M, Q = st.queue.shape
    n = st.status.shape[0]
    a = jnp.clip(action.assign, 0)
    ok = (action.assign >= 0) & (st.status[a] == QUEUED)
    eet_eff = jnp.where(
        st.alive[None, :], sysarr.eet * st.slowdown[None, :], BIG
    )
    avail_base = jnp.maximum(
        jnp.where(st.run_task >= 0, st.run_end_exp, st.now), st.now
    )
    avail_base = jnp.where(st.alive, avail_base, BIG)
    score = avail_base[None, :] + eet_eff[trace.task_type[a]]   # (M, M)
    cols = jnp.arange(M)
    score = jnp.where(cols[None, :] == cols[:, None], BIG, score)
    picks = []
    for _ in range(backup_k):
        b = jnp.argmin(score, axis=1).astype(jnp.int32)
        has = jnp.take_along_axis(score, b[:, None], axis=1)[:, 0] < BIG
        picks.append(jnp.where(ok & has, b, -1))
        score = jnp.where(cols[None, :] == b[:, None], BIG, score)
    backup = st.backup.at[jnp.where(ok, a, n)].set(
        jnp.stack(picks, axis=1), mode="drop"
    )
    return st._replace(backup=backup)


def _map_action(st: SimState, trace: Trace, sysarr: SystemArrays,
                select_fn: Callable, fairness_factor: float,
                site_members: Optional[np.ndarray] = None,
                site_of_machine: Optional[np.ndarray] = None,
                health: bool = False) -> MapAction:
    """The combined :class:`MapAction` of one mapping event (pre-apply).

    With ``health`` the machine view is masked *before* the single-site /
    block-diagonal / masked-vmap split: dead machines read avail=BIG,
    empty queues, qlen=Q and EET=BIG — byte-identical to how out-of-site
    machines already look — and straggler EET columns are slowdown-
    scaled. Policies therefore route around failures with zero
    policy-side code (in particular ``stale_hopeless`` cancels a dead
    site's pending tasks: its fastest machine reads BIG).
    """
    suffered = fairness.suffered_types(
        st.completed, st.arrived, fairness_factor
    )
    pending = st.status == PENDING
    if st.ready is not None:
        # network subsystem: in-transit tasks (dispatched, not yet landed
        # at their site) are invisible to the mapper until they arrive.
        pending = pending & (st.ready <= st.now)
    avail_base = jnp.maximum(
        jnp.where(st.run_task >= 0, st.run_end_exp, st.now), st.now
    )
    queue_v, qlen_v = st.queue, st.qlen
    if health:
        Q = st.queue.shape[1]
        sysarr = sysarr._replace(eet=jnp.where(
            st.alive[None, :], sysarr.eet * st.slowdown[None, :], BIG
        ))
        avail_base = jnp.where(st.alive, avail_base, BIG)
        queue_v = jnp.where(st.alive[:, None], st.queue, -1)
        qlen_v = jnp.where(st.alive, st.qlen, Q)
    n_sites = 1 if site_members is None else site_members.shape[0]
    if n_sites == 1:
        view = MachineView(avail_base=avail_base, queue=queue_v,
                           qlen=qlen_v)
        return select_fn(
            st.now,
            pending,
            trace.task_type,
            trace.deadline,
            view,
            sysarr,
            suffered,
        )

    M, Q = st.queue.shape
    owner_np = np.asarray(site_of_machine, np.int32)
    m = M // n_sites
    if M % n_sites == 0 and (
            owner_np == np.repeat(np.arange(n_sites), m)).all():
        # Block-diagonal fast path: every fleet whose sites are equal
        # contiguous machine blocks (all `paper_xF` scalings) reshapes the
        # (M,)-wide state into (F, m) per-site views instead of masking —
        # the widest op in the vmapped policy is O(m), not O(M), keeping
        # both XLA codegen time and warm runtime flat in F. Bit-exact vs
        # the masked path: every machine-axis reduction in policy code is
        # a min/argmin whose assignment is gated on feasibility
        # (`phase2`'s `key < BIG`), so dropping the BIG-padded outside
        # machines changes no reduced value and no tie-break order.
        S = sysarr.eet.shape[0]

        def one_block(avail_s, queue_s, qlen_s, eet_s, p_dyn_s, p_idle_s, s):
            view_s = MachineView(avail_base=avail_s, queue=queue_s,
                                 qlen=qlen_s)
            sysarr_s = SystemArrays(eet=eet_s, p_dyn=p_dyn_s,
                                    p_idle=p_idle_s)
            return select_fn(
                st.now,
                pending & (st.site == s),
                trace.task_type,
                trace.deadline,
                view_s,
                sysarr_s,
                suffered,
            )

        acts = jax.vmap(one_block)(
            avail_base.reshape(n_sites, m),
            queue_v.reshape(n_sites, m, Q),
            qlen_v.reshape(n_sites, m),
            jnp.moveaxis(sysarr.eet.reshape(S, n_sites, m), 0, 1),
            sysarr.p_dyn.reshape(n_sites, m),
            sysarr.p_idle.reshape(n_sites, m),
            jnp.arange(n_sites, dtype=jnp.int32),
        )
        assign = acts.assign.reshape(M)
        tsite = jnp.clip(st.site, 0, n_sites - 1)
        drop = (jnp.take_along_axis(acts.drop, tsite[None, :], axis=0)[0]
                & (st.site >= 0))
        queue_drop = acts.queue_drop.reshape(M, Q)
        return MapAction(assign, drop, queue_drop)

    def one_site(in_site, s):
        view_s = MachineView(
            avail_base=jnp.where(in_site, avail_base, BIG),
            queue=jnp.where(in_site[:, None], queue_v, -1),
            qlen=jnp.where(in_site, qlen_v, Q),
        )
        sysarr_s = sysarr._replace(
            eet=jnp.where(in_site[None, :], sysarr.eet, BIG)
        )
        return select_fn(
            st.now,
            pending & (st.site == s),
            trace.task_type,
            trace.deadline,
            view_s,
            sysarr_s,
            suffered,
        )

    acts = jax.vmap(one_site)(
        jnp.asarray(site_members), jnp.arange(n_sites, dtype=jnp.int32)
    )  # MapAction with (F,)-leading leaves
    owner = jnp.asarray(site_of_machine, jnp.int32)  # (M,) constant
    assign = jnp.take_along_axis(acts.assign, owner[None, :], axis=0)[0]
    tsite = jnp.clip(st.site, 0, n_sites - 1)
    drop = (jnp.take_along_axis(acts.drop, tsite[None, :], axis=0)[0]
            & (st.site >= 0))
    queue_drop = jnp.take_along_axis(
        acts.queue_drop, owner[None, :, None], axis=0
    )[0]
    return MapAction(assign, drop, queue_drop)


def _apply_action(st: SimState, trace: Trace, action, n_types: int):
    """Apply a MapAction: queue evictions, proactive drops, assignments."""
    M, Q = st.queue.shape
    # --- queue evictions (FELARE victims) -> CANCELLED ----------------------
    victim = action.queue_drop & (st.queue >= 0)
    vidx = jnp.where(victim, st.queue, st.status.shape[0])
    status = st.status.at[vidx.reshape(-1)].set(CANCELLED, mode="drop")
    cancelled = st.cancelled + jax.ops.segment_sum(
        victim.reshape(-1).astype(jnp.int32),
        trace.task_type[jnp.clip(vidx, 0, st.status.shape[0] - 1)].reshape(-1),
        n_types,
    )
    # compact queues (stable: keep FCFS order of survivors)
    keep = ~victim & (st.queue >= 0)
    order = jnp.argsort(~keep, axis=1, stable=True)  # survivors first
    queue = jnp.take_along_axis(jnp.where(keep, st.queue, -1), order, axis=1)
    qlen = keep.sum(axis=1).astype(jnp.int32)

    # --- proactive drops from the arriving queue ----------------------------
    drop = action.drop & (status == PENDING)
    status = jnp.where(drop, CANCELLED, status)
    cancelled = cancelled + jax.ops.segment_sum(
        drop.astype(jnp.int32), trace.task_type, n_types
    )

    # --- assignments: append to queue tails ---------------------------------
    assign = action.assign  # (M,)
    # guard: task must still be PENDING (not dropped above) and slot free
    tstat = status[jnp.clip(assign, 0)]
    ok = (assign >= 0) & (tstat == PENDING) & (qlen < Q)
    slot = jnp.clip(qlen, 0, Q - 1)
    queue = queue.at[jnp.arange(M), slot].set(
        jnp.where(ok, assign, queue[jnp.arange(M), slot])
    )
    qlen = jnp.where(ok, qlen + 1, qlen)
    status = status.at[jnp.where(ok, assign, st.status.shape[0])].set(
        QUEUED, mode="drop"
    )
    return st._replace(status=status, queue=queue, qlen=qlen,
                       cancelled=cancelled)


def _stage_start(st: SimState, trace: Trace, sysarr: SystemArrays,
                 health: bool = False):
    """Idle machines pop their queue head (one pop per machine per event).

    A popped task whose deadline already passed "runs" for zero time with
    success=False and zero energy — the next loop iteration (same timestamp)
    finalizes it and pops again, which realizes Eq. 1/2's third row without
    an inner loop.

    With ``health``, dead machines never pop (their queues are empty
    anyway — the faults stage flushed them) and straggler machines run
    every task ``slowdown``× longer, both in actual and expected time.
    """
    M = st.run_task.shape[0]
    can = (st.run_task < 0) & (st.qlen > 0)
    if health:
        can = can & st.alive
    head = jnp.where(can, st.queue[:, 0], 0)
    ttype = trace.task_type[head]
    dl = trace.deadline[head]
    e_act = trace.exec_actual[head, jnp.arange(M)]
    e_exp = sysarr.eet[ttype, jnp.arange(M)]
    if health:
        e_act = e_act * st.slowdown
        e_exp = e_exp * st.slowdown
    dead_on_arrival = st.now >= dl
    end_act = jnp.where(
        dead_on_arrival, st.now, jnp.minimum(st.now + e_act, dl)
    )
    success = ~dead_on_arrival & (st.now + e_act <= dl)
    end_exp = jnp.where(
        dead_on_arrival, st.now, jnp.minimum(st.now + e_exp, dl)
    )

    queue = jnp.where(
        can[:, None],
        jnp.concatenate(
            [st.queue[:, 1:], jnp.full((M, 1), -1, jnp.int32)], axis=1
        ),
        st.queue,
    )
    status = st.status.at[jnp.where(can, head, st.status.shape[0])].set(
        RUNNING, mode="drop"
    )
    return st._replace(
        status=status,
        run_task=jnp.where(can, head, st.run_task),
        run_start=jnp.where(can, st.now, st.run_start),
        run_end_act=jnp.where(can, end_act, st.run_end_act),
        run_end_exp=jnp.where(can, end_exp, st.run_end_exp),
        run_success=jnp.where(can, success, st.run_success),
        queue=queue,
        qlen=jnp.where(can, st.qlen - 1, st.qlen),
    )


# Backwards-compatible aliases for the pre-stage-split helper names.
_finalize_completions = _stage_finalize
_admit_arrivals = _stage_admit
_start_tasks = _stage_start


def make_simulator(select_fn: Callable, sysarr: SystemArrays, *,
                   queue_size: int, fairness_factor: float = 1.0,
                   max_steps: int | None = None,
                   observers: tuple = (),
                   dispatcher=None,
                   site_of_machine: tuple | None = None,
                   dynamics=None,
                   network=None,
                   tier_of_site: tuple | None = None) -> Callable:
    """Build ``simulate(trace)`` for one mapping policy.

    ``dynamics`` is the machine-failure process — a registered
    :mod:`repro.core.faults` name or :class:`~repro.core.faults.
    MachineDynamics` instance, closed over statically like the policy.
    ``None``/``"none"`` (the default) skips the faults stage entirely,
    keeping the loop bit-exact with the pre-faults engine; any other
    dynamics turns on health masking at the dispatch/map/start stages
    and orphan re-dispatch at the ``faults`` stage. A ``with_backup``-
    wrapped policy additionally activates k-failure backup nomination
    (inert without a dynamics — backups only matter if machines can
    die).

    ``network`` is the inter-site cost model — a registered
    :mod:`repro.core.network` name or :class:`~repro.core.network.
    NetworkModel` instance, closed over statically. ``None``/``"none"``
    (the default) skips all transfer arithmetic, keeping the loop
    bit-exact with the pre-network engine; any other model prices each
    task's ``origin -> chosen site`` link at the dispatch stage (ready-
    time shift + Eq. 2 transfer energy; see :func:`_stage_dispatch`).
    ``tier_of_site`` is the static (F,) site-tier partition (device=0 /
    edge=1 / cloud=2; ``None`` = all device-tier) the model prices and
    the ``network`` observer aggregates on.

    ``select_fn(now, pending, task_type, deadline, view, sysarr, suffered)``
    is any :class:`repro.core.policy.Policy` (e.g. from
    ``policy.get(name)``) or a bare function with the same signature; it is
    closed over statically so jit specializes per policy.

    ``site_of_machine`` is the *static* federation partition — a tuple of
    per-machine site ids (``None`` = one site) — and ``dispatcher`` the
    :class:`repro.core.dispatch.Dispatcher` assigning newly-admitted
    tasks to sites (``None`` = the default ``sticky``; irrelevant with
    one site, where the dispatch stage is the constant "site 0"). Both
    are closed over statically, like the policy.

    ``observers`` is a tuple of :class:`repro.core.observe.Observer`
    instances (hashable, closed over statically — attaching observers
    never retraces per call). With ``observers=()`` the simulator returns
    bare :class:`Metrics`, bit-identical to the pre-observer engine; with
    observers it returns ``(Metrics, aux)`` where ``aux`` maps each
    observer's name to its finalized pytree.
    """
    from repro.core import dispatch as dispatch_mod
    from repro.core import faults as faults_mod
    from repro.core import network as network_mod

    S, M = sysarr.eet.shape
    dynamics = faults_mod.resolve(dynamics)
    if getattr(dynamics, "kind", None) == "none":
        dynamics = None
    backup_k = (int(getattr(select_fn, "backup_k", 0))
                if dynamics is not None else 0)
    wake = (tuple(float(w) for w in dynamics.wake_fracs())
            if dynamics is not None and hasattr(dynamics, "wake_fracs")
            else ())
    sites = ((0,) * M if site_of_machine is None
             else tuple(int(s) for s in site_of_machine))
    if len(sites) != M:
        raise ValueError(
            f"site_of_machine has {len(sites)} entries for {M} machines"
        )
    n_sites = max(sites) + 1
    sites_np = np.asarray(sites, np.int32)
    site_members = (site_membership(sites_np, n_sites)
                    if n_sites > 1 else None)
    dispatcher = dispatch_mod.resolve(dispatcher)
    tiers = ((0,) * n_sites if tier_of_site is None
             else tuple(int(t) for t in tier_of_site))
    if len(tiers) != n_sites:
        raise ValueError(
            f"tier_of_site has {len(tiers)} entries for {n_sites} sites"
        )
    network = network_mod.resolve(network)
    if getattr(network, "kind", None) == "none":
        network = None
    if network is not None:
        n_tiers = max(tiers) + 1
        lat_np, en_np = network.cost_tables(tiers, S)
        origins = network_mod.origin_sites(tiers)
        net_salt = int(getattr(network, "salt", 0))
        tiers_np = np.asarray(tiers, np.int32)
    observers = tuple(
        ob.with_engine_config(fairness_factor=fairness_factor,
                              queue_size=queue_size,
                              site_of_machine=sites,
                              tier_of_site=tiers)
        if hasattr(ob, "with_engine_config") else ob
        for ob in observers
    )
    names = [ob.name for ob in observers]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate observer names {names}")
    gaters = tuple(ob for ob in observers if getattr(ob, "is_dynamic", False))

    def _halt(st, aux):
        h = jnp.bool_(False)
        for ob in gaters:
            h = h | ob.halted(aux[ob.name], st)
        return h

    def simulate(trace: Trace):
        n = trace.arrival.shape[0]
        steps_cap = max_steps if max_steps is not None else 8 * n + 64
        netted = network is not None
        st = _init_state(trace, M, queue_size, S, backup_k=backup_k,
                         network=netted,
                         n_tiers=n_tiers if netted else 1)
        if netted:
            # Per-task (N, F) link costs, gathered once outside the loop:
            # row k prices task k's origin (a salted counter hash over the
            # device-tier sites) against every destination site.
            origin = network_mod.hash_origins(n, origins, net_salt)
            lat_task = jnp.asarray(lat_np)[trace.task_type, origin]
            en_task = jnp.asarray(en_np)[trace.task_type, origin]
            net = (lat_task, en_task, jnp.asarray(tiers_np), n_tiers)
        else:
            net = None
        aux = {ob.name: ob.init(trace, sysarr) for ob in observers}
        health = dynamics is not None
        horizon = (jnp.max(trace.deadline).astype(jnp.float32)
                   if health else None)
        wake_ts = (jnp.asarray(wake, jnp.float32) * horizon
                   if wake else None)

        def cond(est: EngineState):
            st, aux = est
            halted = _halt(st, aux) if gaters else None
            return (jnp.isfinite(_next_event_time(st, trace, halted,
                                                  wake_ts))
                    & (st.steps < steps_cap))

        def notify(stage, aux, st):
            return {
                ob.name: ob.on_event(stage, aux[ob.name], st, trace, sysarr)
                for ob in observers
            }

        def body(est: EngineState):
            st, aux = est
            halted = _halt(st, aux) if gaters else None
            t = _next_event_time(st, trace, halted, wake_ts)
            st = st._replace(now=jnp.maximum(t, st.now))
            st = _stage_finalize(st, trace, sysarr)
            aux = notify("finalize", aux, st)
            st = _stage_admit(st, trace, halted)
            aux = notify("admit", aux, st)
            if health:
                st = _stage_faults(st, trace, sysarr, dynamics, horizon, S,
                                   backup_k, sites_np, n_sites)
                aux = notify("faults", aux, st)
            st = _stage_dispatch(st, trace, sysarr, dispatcher, sites_np,
                                 n_sites, fairness_factor, health, net)
            aux = notify("dispatch", aux, st)
            st = _stage_map(st, trace, sysarr, select_fn, fairness_factor, S,
                            site_members, sites_np, health, backup_k)
            aux = notify("map", aux, st)
            st = _stage_start(st, trace, sysarr, health)
            aux = notify("start", aux, st)
            return EngineState(st._replace(steps=st.steps + 1), aux)

        st, aux = jax.lax.while_loop(cond, body, EngineState(st, aux))
        makespan = st.now
        e_idle = (sysarr.p_idle * (makespan - st.busy_time)).sum()
        metrics = Metrics(
            completed_by_type=st.completed,
            missed_by_type=st.missed,
            cancelled_by_type=st.cancelled,
            arrived_by_type=st.arrived,
            energy_dynamic=st.e_dyn,
            energy_wasted=st.e_wasted,
            energy_idle=e_idle,
            makespan=makespan,
        )
        if not observers:
            return metrics
        aux_out = {ob.name: ob.finalize(aux[ob.name], st) for ob in observers}
        return metrics, aux_out

    return simulate


@functools.partial(jax.jit, static_argnames=("select_fn", "observers",
                                             "queue_size", "fairness_factor",
                                             "max_steps", "batched",
                                             "dispatcher", "sites",
                                             "dynamics", "network", "tiers"))
def _simulate_jit(trace, eet, p_dyn, p_idle, select_fn, observers,
                  queue_size, fairness_factor, max_steps, batched,
                  dispatcher=None, sites=None, dynamics=None,
                  network=None, tiers=None):
    """The one cached jit entry point behind ``simulate``/``simulate_batch``.

    Keyed on ``(select_fn, observers, dispatcher, sites, dynamics,
    network, tiers, static config)`` — re-calling with the same (frozen,
    hashable) policy, observer, dispatcher, dynamics and network objects
    hits the jit cache instead of re-tracing, including the vmapped
    batch path. ``sites`` is the static site-partition tuple (``None`` =
    single site); ``dynamics`` is the static machine-dynamics instance
    (``None`` = no faults stage); ``network``/``tiers`` are the static
    network model and (F,) site-tier tuple (``None`` = no transfer
    arithmetic).
    """
    sysarr = SystemArrays(
        eet=eet, p_dyn=p_dyn, p_idle=p_idle,
        site_of_machine=(None if sites is None
                         else jnp.asarray(sites, jnp.int32)),
    )
    sim = make_simulator(
        select_fn, sysarr, queue_size=queue_size,
        fairness_factor=fairness_factor, max_steps=max_steps,
        observers=observers, dispatcher=dispatcher, site_of_machine=sites,
        dynamics=dynamics, network=network, tier_of_site=tiers,
    )
    return jax.vmap(sim)(trace) if batched else sim(trace)


def _simulate(trace, spec, heuristic, observers, max_steps, batched,
              dispatcher=None, dynamics=None, network=None):
    from repro.core import dispatch as dispatch_mod
    from repro.core import faults as faults_mod
    from repro.core import network as network_mod
    from repro.core import observe, policy

    obs = observe.resolve(observers)
    sites = getattr(spec, "site_of_machine", None)
    sites = None if sites is None else tuple(int(s) for s in sites)
    # Single-site systems bypass the dispatch stage entirely, so the
    # dispatcher must not enter the static jit cache key there — else two
    # bit-identical flat runs under different dispatcher names would each
    # pay a full recompile.
    disp = (None if sites is None or max(sites) == 0
            else dispatch_mod.resolve(dispatcher))
    # Same idea for dynamics: the trivial "none" dynamics is normalized
    # to None before the jit key, so ``dynamics="none"`` and the default
    # share one cache entry (and one bit-exact program).
    dyn = faults_mod.resolve(dynamics)
    if getattr(dyn, "kind", None) == "none":
        dyn = None
    # And for networks: "none" and the default share the PR 8 program.
    net = network_mod.resolve(network)
    if getattr(net, "kind", None) == "none":
        net = None
    net_tiers = (None if net is None
                 else tuple(int(t) for t in spec.tiers)
                 if hasattr(spec, "tiers") else None)
    return _simulate_jit(
        trace,
        jnp.asarray(spec.eet, jnp.float32),
        jnp.asarray(spec.p_dyn, jnp.float32),
        jnp.asarray(spec.p_idle, jnp.float32),
        policy.get(heuristic) if isinstance(heuristic, str) else heuristic,
        obs,
        spec.queue_size,
        float(spec.fairness_factor),
        max_steps,
        batched,
        disp,
        sites,
        dyn,
        net,
        net_tiers,
    )


def simulate(trace: Trace, spec, heuristic: str, *, observers=(),
             max_steps=None, dispatcher=None, dynamics=None, network=None):
    """Convenience entry point: one trace, one SystemSpec, one heuristic.

    The heuristic name is resolved through the policy registry, observer
    names through the observer registry, the dispatcher name through the
    dispatcher registry, and the dynamics/network names through their
    registries — all *outside* the jit boundary; the (frozen, hashable)
    policy/observer/dispatcher/dynamics/network objects are the static
    cache key — so re-registering a name with ``overwrite=True`` takes
    effect instead of silently hitting a stale name-keyed jit cache.
    ``spec.site_of_machine`` (if set) partitions the machines into
    federation sites served through ``dispatcher``; ``dynamics``
    (default ``None`` = ``"none"``) injects machine failures at the
    ``faults`` stage (see :mod:`repro.core.faults`); ``network``
    (default ``None`` = ``"none"``) prices inter-site dispatch over
    ``spec.tier_of_site`` (see :mod:`repro.core.network`).

    Returns :class:`Metrics` when ``observers`` is empty, else
    ``(Metrics, aux)`` with ``aux`` keyed by observer name.
    """
    return _simulate(trace, spec, heuristic, observers, max_steps, False,
                     dispatcher, dynamics, network)


def simulate_batch(traces: Trace, spec, heuristic: str, *, observers=(),
                   max_steps=None, dispatcher=None, dynamics=None,
                   network=None):
    """vmap over a stacked batch of traces (the paper's 30-trace studies).

    Shares the cached ``_simulate_jit`` with :func:`simulate`: calling it
    in a loop over heuristics compiles each policy exactly once instead of
    rebuilding and re-jitting the vmapped simulator per call.
    """
    return _simulate(traces, spec, heuristic, observers, max_steps, True,
                     dispatcher, dynamics, network)
