"""Discrete-event simulation engine for the HEC system, in pure JAX.

The whole simulator is a ``lax.while_loop`` over events with fixed-shape
state, so a full workload trace is one jittable computation and a batch of
traces is one ``vmap``. Semantics follow Sec. III of the paper:

  * mapping events fire on task arrival and task completion (plus a progress
    event at the earliest pending deadline so stale tasks are always purged);
  * machines serve their bounded local queues FCFS;
  * a running task that passes its deadline is killed at the deadline (its
    dynamic energy is wasted, Eq. 2 row 1);
  * a queued task whose deadline passed before it starts is dropped with zero
    energy (Eq. 2 row 3);
  * per-type completion counters feed the fairness monitor continuously.

Each event is processed as five named stages, threading an
:class:`~repro.core.types.EngineState` = ``(SimState, aux)``:

  ``finalize`` -> ``admit`` -> ``dispatch`` -> ``map`` -> ``start``

``dispatch`` is the federation's first level: a pluggable
:class:`~repro.core.dispatch.Dispatcher` assigns each newly-admitted task
to one of F *sites* (bounded partitions of the machine set), and ``map``
then evaluates the mapping policy as one ``jax.vmap`` over the F
site-masked :class:`~repro.core.policy.MachineView` batches — the site
count enters the program as *data* (array extents), never as program
structure, so trace size and compile time are flat in F: an F=100
federation compiles the same program as an F=2 one. With one site (every
spec built before the federation layer) the dispatch stage degenerates
to "site 0" and the map stage is the exact pre-federation computation,
so flat runs stay bit-identical.

After every stage, each attached :class:`~repro.core.observe.Observer`
folds the stage name and the fresh :class:`~repro.core.types.SimState`
into its own fixed-shape ``aux`` pytree, so time-resolved telemetry
(queue/energy/fairness trajectories, per-task logs) rides inside the same
single jitted ``while_loop`` — and *dynamic* observers (the energy
budget) can expose a ``halted`` flag the engine consults to stop
admitting work (Eq. 2's energy-limited regime). With no observers the
loop is structurally and bit-for-bit identical to the bare engine.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fairness
from repro.core.dispatch.base import DispatchContext
from repro.core.policy import BIG, MachineView
from repro.core.types import (
    CANCELLED,
    COMPLETED,
    MISSED,
    PENDING,
    QUEUED,
    RUNNING,
    UNARRIVED,
    EngineState,
    MapAction,
    Metrics,
    SimState,
    SystemArrays,
    Trace,
    site_membership,
)

INF = jnp.float32(jnp.inf)

#: Stage names, in event order. Observers receive each after it ran.
STAGES = ("finalize", "admit", "dispatch", "map", "start")


def _init_state(trace: Trace, n_machines: int, queue_size: int,
                n_types: int) -> SimState:
    n = trace.arrival.shape[0]
    M, Q, S = n_machines, queue_size, n_types
    f = jnp.float32
    return SimState(
        now=f(0.0),
        status=jnp.full((n,), UNARRIVED, jnp.int32),
        site=jnp.full((n,), -1, jnp.int32),
        run_task=jnp.full((M,), -1, jnp.int32),
        run_start=jnp.zeros((M,), f),
        run_end_act=jnp.full((M,), jnp.inf, f),
        run_end_exp=jnp.zeros((M,), f),
        run_success=jnp.zeros((M,), bool),
        queue=jnp.full((M, Q), -1, jnp.int32),
        qlen=jnp.zeros((M,), jnp.int32),
        busy_time=jnp.zeros((M,), f),
        e_dyn=f(0.0),
        e_wasted=f(0.0),
        completed=jnp.zeros((S,), jnp.int32),
        missed=jnp.zeros((S,), jnp.int32),
        cancelled=jnp.zeros((S,), jnp.int32),
        arrived=jnp.zeros((S,), jnp.int32),
        steps=jnp.int32(0),
    )


def _next_event_time(st: SimState, trace: Trace,
                     halted: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    pending = st.status == PENDING
    unarrived = st.status == UNARRIVED
    t_arr = jnp.min(jnp.where(unarrived, trace.arrival, jnp.inf))
    if halted is not None:
        # energy-limited shutdown: un-admitted arrivals no longer drive
        # events (they would otherwise pin the next-event time forever).
        t_arr = jnp.where(halted, jnp.inf, t_arr)
    t_comp = jnp.min(st.run_end_act)
    # progress guard: earliest pending deadline (so stale tasks get purged
    # even when no machine is busy and no arrivals remain).
    t_dead = jnp.min(jnp.where(pending, trace.deadline, jnp.inf))
    return jnp.minimum(jnp.minimum(t_arr, t_comp), t_dead)


# ---------------------------------------------------------------------------
# Event stages. Each is a pure SimState -> SimState map; the loop body runs
# them in STAGES order and hands the result to every observer in between.
# ---------------------------------------------------------------------------
def _stage_finalize(st: SimState, trace: Trace, sysarr: SystemArrays):
    """Close out machines whose running task's actual end <= now."""
    done = (st.run_task >= 0) & (st.run_end_act <= st.now)
    idx = jnp.where(done, st.run_task, 0)
    ttype = trace.task_type[idx]
    dur = jnp.where(done, st.run_end_act - st.run_start, 0.0)
    energy = sysarr.p_dyn * dur
    ok = done & st.run_success
    ko = done & ~st.run_success

    completed = st.completed.at[ttype].add(ok.astype(jnp.int32))
    missed = st.missed.at[ttype].add(ko.astype(jnp.int32))
    e_dyn = st.e_dyn + energy.sum()
    e_wasted = st.e_wasted + jnp.where(ko, energy, 0.0).sum()
    busy = st.busy_time + dur
    sidx = jnp.where(done, idx, st.status.shape[0])  # OOB sentinel -> dropped
    status = st.status.at[sidx].set(
        jnp.where(ok, COMPLETED, MISSED), mode="drop"
    )
    return st._replace(
        status=status,
        run_task=jnp.where(done, -1, st.run_task),
        run_end_act=jnp.where(done, jnp.inf, st.run_end_act),
        run_end_exp=jnp.where(done, st.now, st.run_end_exp),
        run_success=jnp.where(done, False, st.run_success),
        completed=completed,
        missed=missed,
        cancelled=st.cancelled,
        e_dyn=e_dyn,
        e_wasted=e_wasted,
        busy_time=busy,
    )


def _stage_admit(st: SimState, trace: Trace,
                 halted: Optional[jnp.ndarray] = None):
    """Admit newly-arrived tasks to the arriving queue.

    When a dynamic observer reports ``halted`` (battery exhausted), the
    system stops taking work: nothing is admitted, every pending task is
    cancelled, and local queues are flushed (their tasks cancelled with
    zero energy). Tasks already running finish normally — the one-event
    slack the energy-budget contract allows.
    """
    newly = (st.status == UNARRIVED) & (trace.arrival <= st.now)
    if halted is not None:
        newly = newly & ~halted
    status = jnp.where(newly, PENDING, st.status)
    arrived = st.arrived + jax.ops.segment_sum(
        newly.astype(jnp.int32), trace.task_type, st.arrived.shape[0]
    )
    st = st._replace(status=status, arrived=arrived)
    if halted is None:
        return st
    return _halt_shutdown(st, trace, halted)


def _halt_shutdown(st: SimState, trace: Trace, halted: jnp.ndarray):
    """Cancel pending tasks and flush local queues once ``halted``."""
    n, n_types = st.status.shape[0], st.cancelled.shape[0]
    drop = halted & (st.status == PENDING)
    status = jnp.where(drop, CANCELLED, st.status)
    cancelled = st.cancelled + jax.ops.segment_sum(
        drop.astype(jnp.int32), trace.task_type, n_types
    )
    victim = halted & (st.queue >= 0)
    vidx = jnp.where(victim, st.queue, n)  # OOB sentinel -> dropped
    status = status.at[vidx.reshape(-1)].set(CANCELLED, mode="drop")
    cancelled = cancelled + jax.ops.segment_sum(
        victim.reshape(-1).astype(jnp.int32),
        trace.task_type[jnp.clip(vidx, 0, n - 1)].reshape(-1),
        n_types,
    )
    return st._replace(
        status=status,
        cancelled=cancelled,
        queue=jnp.where(victim, -1, st.queue),
        qlen=jnp.where(halted, 0, st.qlen),
    )


def _stage_dispatch(st: SimState, trace: Trace, sysarr: SystemArrays,
                    dispatcher, site_of_machine: np.ndarray, n_sites: int,
                    fairness_factor: float):
    """Assign newly-admitted tasks to federation sites (dispatch-once).

    A task is dispatched at the first event where it is PENDING and still
    siteless; its site never changes afterwards. With one site the
    dispatcher is bypassed entirely (every task -> site 0), so flat
    systems carry zero dispatch ops in the traced loop body.
    """
    new = (st.status == PENDING) & (st.site < 0)
    if n_sites == 1:
        return st._replace(site=jnp.where(new, 0, st.site))
    ctx = DispatchContext(
        now=st.now,
        unassigned=new,
        task_type=trace.task_type,
        deadline=trace.deadline,
        qlen=st.qlen,
        running=st.run_task >= 0,
        completed=st.completed,
        arrived=st.arrived,
        eet=sysarr.eet,
        site_of_machine=site_of_machine,
        n_sites=n_sites,
        fairness_factor=fairness_factor,
    )
    sites = jnp.clip(dispatcher.dispatch(ctx).astype(jnp.int32),
                     0, n_sites - 1)
    return st._replace(site=jnp.where(new, sites, st.site))


def _stage_map(st: SimState, trace: Trace, sysarr: SystemArrays,
               select_fn: Callable, fairness_factor: float, n_types: int,
               site_members: Optional[np.ndarray] = None,
               site_of_machine: Optional[np.ndarray] = None):
    """Run the per-site mapping policy and apply the combined MapAction.

    ``site_members`` is the (F, M) partition grid — a host constant whose
    *values* are data, not program structure: the policy is evaluated once
    as a single ``jax.vmap`` over the F site-masked machine views, so the
    traced program contains exactly one copy of the mapping computation
    regardless of F (trace size and compile time are flat in the site
    count; only array extents grow). Machines outside a site appear full
    (``qlen = Q``), empty-queued, and infinitely far away (``avail_base =
    BIG``, EET rows ``BIG``), so nominators, feasibility guards and the
    fairness eviction all see a site-local system — in particular
    ``hopeless``/``rescuable`` use the site's own fastest machine.

    The F per-site :class:`MapAction` batches are combined by gathers:
    machine ``m`` takes its owning site's ``assign``/``queue_drop`` row
    (``site_of_machine`` is the (M,) owner map), and task ``n`` takes its
    dispatched site's ``drop`` entry — the same one-owner-per-entry
    semantics the PR 5 static unroll realized with F masked merges
    (pinned bit-exact in ``tests/test_siteloop_vmap.py``). With F=1 the
    branch below is literally the pre-federation computation (no masking
    ops), keeping flat runs bit-exact.
    """
    action = _map_action(st, trace, sysarr, select_fn, fairness_factor,
                         site_members, site_of_machine)
    return _apply_action(st, trace, action, n_types)


def _map_action(st: SimState, trace: Trace, sysarr: SystemArrays,
                select_fn: Callable, fairness_factor: float,
                site_members: Optional[np.ndarray] = None,
                site_of_machine: Optional[np.ndarray] = None) -> MapAction:
    """The combined :class:`MapAction` of one mapping event (pre-apply)."""
    suffered = fairness.suffered_types(
        st.completed, st.arrived, fairness_factor
    )
    avail_base = jnp.maximum(
        jnp.where(st.run_task >= 0, st.run_end_exp, st.now), st.now
    )
    n_sites = 1 if site_members is None else site_members.shape[0]
    if n_sites == 1:
        view = MachineView(avail_base=avail_base, queue=st.queue,
                           qlen=st.qlen)
        return select_fn(
            st.now,
            st.status == PENDING,
            trace.task_type,
            trace.deadline,
            view,
            sysarr,
            suffered,
        )

    M, Q = st.queue.shape
    pending = st.status == PENDING
    owner_np = np.asarray(site_of_machine, np.int32)
    m = M // n_sites
    if M % n_sites == 0 and (
            owner_np == np.repeat(np.arange(n_sites), m)).all():
        # Block-diagonal fast path: every fleet whose sites are equal
        # contiguous machine blocks (all `paper_xF` scalings) reshapes the
        # (M,)-wide state into (F, m) per-site views instead of masking —
        # the widest op in the vmapped policy is O(m), not O(M), keeping
        # both XLA codegen time and warm runtime flat in F. Bit-exact vs
        # the masked path: every machine-axis reduction in policy code is
        # a min/argmin whose assignment is gated on feasibility
        # (`phase2`'s `key < BIG`), so dropping the BIG-padded outside
        # machines changes no reduced value and no tie-break order.
        S = sysarr.eet.shape[0]

        def one_block(avail_s, queue_s, qlen_s, eet_s, p_dyn_s, p_idle_s, s):
            view_s = MachineView(avail_base=avail_s, queue=queue_s,
                                 qlen=qlen_s)
            sysarr_s = SystemArrays(eet=eet_s, p_dyn=p_dyn_s,
                                    p_idle=p_idle_s)
            return select_fn(
                st.now,
                pending & (st.site == s),
                trace.task_type,
                trace.deadline,
                view_s,
                sysarr_s,
                suffered,
            )

        acts = jax.vmap(one_block)(
            avail_base.reshape(n_sites, m),
            st.queue.reshape(n_sites, m, Q),
            st.qlen.reshape(n_sites, m),
            jnp.moveaxis(sysarr.eet.reshape(S, n_sites, m), 0, 1),
            sysarr.p_dyn.reshape(n_sites, m),
            sysarr.p_idle.reshape(n_sites, m),
            jnp.arange(n_sites, dtype=jnp.int32),
        )
        assign = acts.assign.reshape(M)
        tsite = jnp.clip(st.site, 0, n_sites - 1)
        drop = (jnp.take_along_axis(acts.drop, tsite[None, :], axis=0)[0]
                & (st.site >= 0))
        queue_drop = acts.queue_drop.reshape(M, Q)
        return MapAction(assign, drop, queue_drop)

    def one_site(in_site, s):
        view_s = MachineView(
            avail_base=jnp.where(in_site, avail_base, BIG),
            queue=jnp.where(in_site[:, None], st.queue, -1),
            qlen=jnp.where(in_site, st.qlen, Q),
        )
        sysarr_s = sysarr._replace(
            eet=jnp.where(in_site[None, :], sysarr.eet, BIG)
        )
        return select_fn(
            st.now,
            pending & (st.site == s),
            trace.task_type,
            trace.deadline,
            view_s,
            sysarr_s,
            suffered,
        )

    acts = jax.vmap(one_site)(
        jnp.asarray(site_members), jnp.arange(n_sites, dtype=jnp.int32)
    )  # MapAction with (F,)-leading leaves
    owner = jnp.asarray(site_of_machine, jnp.int32)  # (M,) constant
    assign = jnp.take_along_axis(acts.assign, owner[None, :], axis=0)[0]
    tsite = jnp.clip(st.site, 0, n_sites - 1)
    drop = (jnp.take_along_axis(acts.drop, tsite[None, :], axis=0)[0]
            & (st.site >= 0))
    queue_drop = jnp.take_along_axis(
        acts.queue_drop, owner[None, :, None], axis=0
    )[0]
    return MapAction(assign, drop, queue_drop)


def _apply_action(st: SimState, trace: Trace, action, n_types: int):
    """Apply a MapAction: queue evictions, proactive drops, assignments."""
    M, Q = st.queue.shape
    # --- queue evictions (FELARE victims) -> CANCELLED ----------------------
    victim = action.queue_drop & (st.queue >= 0)
    vidx = jnp.where(victim, st.queue, st.status.shape[0])
    status = st.status.at[vidx.reshape(-1)].set(CANCELLED, mode="drop")
    cancelled = st.cancelled + jax.ops.segment_sum(
        victim.reshape(-1).astype(jnp.int32),
        trace.task_type[jnp.clip(vidx, 0, st.status.shape[0] - 1)].reshape(-1),
        n_types,
    )
    # compact queues (stable: keep FCFS order of survivors)
    keep = ~victim & (st.queue >= 0)
    order = jnp.argsort(~keep, axis=1, stable=True)  # survivors first
    queue = jnp.take_along_axis(jnp.where(keep, st.queue, -1), order, axis=1)
    qlen = keep.sum(axis=1).astype(jnp.int32)

    # --- proactive drops from the arriving queue ----------------------------
    drop = action.drop & (status == PENDING)
    status = jnp.where(drop, CANCELLED, status)
    cancelled = cancelled + jax.ops.segment_sum(
        drop.astype(jnp.int32), trace.task_type, n_types
    )

    # --- assignments: append to queue tails ---------------------------------
    assign = action.assign  # (M,)
    # guard: task must still be PENDING (not dropped above) and slot free
    tstat = status[jnp.clip(assign, 0)]
    ok = (assign >= 0) & (tstat == PENDING) & (qlen < Q)
    slot = jnp.clip(qlen, 0, Q - 1)
    queue = queue.at[jnp.arange(M), slot].set(
        jnp.where(ok, assign, queue[jnp.arange(M), slot])
    )
    qlen = jnp.where(ok, qlen + 1, qlen)
    status = status.at[jnp.where(ok, assign, st.status.shape[0])].set(
        QUEUED, mode="drop"
    )
    return st._replace(status=status, queue=queue, qlen=qlen,
                       cancelled=cancelled)


def _stage_start(st: SimState, trace: Trace, sysarr: SystemArrays):
    """Idle machines pop their queue head (one pop per machine per event).

    A popped task whose deadline already passed "runs" for zero time with
    success=False and zero energy — the next loop iteration (same timestamp)
    finalizes it and pops again, which realizes Eq. 1/2's third row without
    an inner loop.
    """
    M = st.run_task.shape[0]
    can = (st.run_task < 0) & (st.qlen > 0)
    head = jnp.where(can, st.queue[:, 0], 0)
    ttype = trace.task_type[head]
    dl = trace.deadline[head]
    e_act = trace.exec_actual[head, jnp.arange(M)]
    e_exp = sysarr.eet[ttype, jnp.arange(M)]
    dead_on_arrival = st.now >= dl
    end_act = jnp.where(
        dead_on_arrival, st.now, jnp.minimum(st.now + e_act, dl)
    )
    success = ~dead_on_arrival & (st.now + e_act <= dl)
    end_exp = jnp.where(
        dead_on_arrival, st.now, jnp.minimum(st.now + e_exp, dl)
    )

    queue = jnp.where(
        can[:, None],
        jnp.concatenate(
            [st.queue[:, 1:], jnp.full((M, 1), -1, jnp.int32)], axis=1
        ),
        st.queue,
    )
    status = st.status.at[jnp.where(can, head, st.status.shape[0])].set(
        RUNNING, mode="drop"
    )
    return st._replace(
        status=status,
        run_task=jnp.where(can, head, st.run_task),
        run_start=jnp.where(can, st.now, st.run_start),
        run_end_act=jnp.where(can, end_act, st.run_end_act),
        run_end_exp=jnp.where(can, end_exp, st.run_end_exp),
        run_success=jnp.where(can, success, st.run_success),
        queue=queue,
        qlen=jnp.where(can, st.qlen - 1, st.qlen),
    )


# Backwards-compatible aliases for the pre-stage-split helper names.
_finalize_completions = _stage_finalize
_admit_arrivals = _stage_admit
_start_tasks = _stage_start


def make_simulator(select_fn: Callable, sysarr: SystemArrays, *,
                   queue_size: int, fairness_factor: float = 1.0,
                   max_steps: int | None = None,
                   observers: tuple = (),
                   dispatcher=None,
                   site_of_machine: tuple | None = None) -> Callable:
    """Build ``simulate(trace)`` for one mapping policy.

    ``select_fn(now, pending, task_type, deadline, view, sysarr, suffered)``
    is any :class:`repro.core.policy.Policy` (e.g. from
    ``policy.get(name)``) or a bare function with the same signature; it is
    closed over statically so jit specializes per policy.

    ``site_of_machine`` is the *static* federation partition — a tuple of
    per-machine site ids (``None`` = one site) — and ``dispatcher`` the
    :class:`repro.core.dispatch.Dispatcher` assigning newly-admitted
    tasks to sites (``None`` = the default ``sticky``; irrelevant with
    one site, where the dispatch stage is the constant "site 0"). Both
    are closed over statically, like the policy.

    ``observers`` is a tuple of :class:`repro.core.observe.Observer`
    instances (hashable, closed over statically — attaching observers
    never retraces per call). With ``observers=()`` the simulator returns
    bare :class:`Metrics`, bit-identical to the pre-observer engine; with
    observers it returns ``(Metrics, aux)`` where ``aux`` maps each
    observer's name to its finalized pytree.
    """
    from repro.core import dispatch as dispatch_mod

    S, M = sysarr.eet.shape
    sites = ((0,) * M if site_of_machine is None
             else tuple(int(s) for s in site_of_machine))
    if len(sites) != M:
        raise ValueError(
            f"site_of_machine has {len(sites)} entries for {M} machines"
        )
    n_sites = max(sites) + 1
    sites_np = np.asarray(sites, np.int32)
    site_members = (site_membership(sites_np, n_sites)
                    if n_sites > 1 else None)
    dispatcher = dispatch_mod.resolve(dispatcher)
    observers = tuple(
        ob.with_engine_config(fairness_factor=fairness_factor,
                              queue_size=queue_size,
                              site_of_machine=sites)
        if hasattr(ob, "with_engine_config") else ob
        for ob in observers
    )
    names = [ob.name for ob in observers]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate observer names {names}")
    gaters = tuple(ob for ob in observers if getattr(ob, "is_dynamic", False))

    def _halt(st, aux):
        h = jnp.bool_(False)
        for ob in gaters:
            h = h | ob.halted(aux[ob.name], st)
        return h

    def simulate(trace: Trace):
        n = trace.arrival.shape[0]
        steps_cap = max_steps if max_steps is not None else 8 * n + 64
        st = _init_state(trace, M, queue_size, S)
        aux = {ob.name: ob.init(trace, sysarr) for ob in observers}

        def cond(est: EngineState):
            st, aux = est
            halted = _halt(st, aux) if gaters else None
            return (jnp.isfinite(_next_event_time(st, trace, halted))
                    & (st.steps < steps_cap))

        def notify(stage, aux, st):
            return {
                ob.name: ob.on_event(stage, aux[ob.name], st, trace, sysarr)
                for ob in observers
            }

        def body(est: EngineState):
            st, aux = est
            halted = _halt(st, aux) if gaters else None
            t = _next_event_time(st, trace, halted)
            st = st._replace(now=jnp.maximum(t, st.now))
            st = _stage_finalize(st, trace, sysarr)
            aux = notify("finalize", aux, st)
            st = _stage_admit(st, trace, halted)
            aux = notify("admit", aux, st)
            st = _stage_dispatch(st, trace, sysarr, dispatcher, sites_np,
                                 n_sites, fairness_factor)
            aux = notify("dispatch", aux, st)
            st = _stage_map(st, trace, sysarr, select_fn, fairness_factor, S,
                            site_members, sites_np)
            aux = notify("map", aux, st)
            st = _stage_start(st, trace, sysarr)
            aux = notify("start", aux, st)
            return EngineState(st._replace(steps=st.steps + 1), aux)

        st, aux = jax.lax.while_loop(cond, body, EngineState(st, aux))
        makespan = st.now
        e_idle = (sysarr.p_idle * (makespan - st.busy_time)).sum()
        metrics = Metrics(
            completed_by_type=st.completed,
            missed_by_type=st.missed,
            cancelled_by_type=st.cancelled,
            arrived_by_type=st.arrived,
            energy_dynamic=st.e_dyn,
            energy_wasted=st.e_wasted,
            energy_idle=e_idle,
            makespan=makespan,
        )
        if not observers:
            return metrics
        aux_out = {ob.name: ob.finalize(aux[ob.name], st) for ob in observers}
        return metrics, aux_out

    return simulate


@functools.partial(jax.jit, static_argnames=("select_fn", "observers",
                                             "queue_size", "fairness_factor",
                                             "max_steps", "batched",
                                             "dispatcher", "sites"))
def _simulate_jit(trace, eet, p_dyn, p_idle, select_fn, observers,
                  queue_size, fairness_factor, max_steps, batched,
                  dispatcher=None, sites=None):
    """The one cached jit entry point behind ``simulate``/``simulate_batch``.

    Keyed on ``(select_fn, observers, dispatcher, sites, static config)``
    — re-calling with the same (frozen, hashable) policy, observer and
    dispatcher objects hits the jit cache instead of re-tracing,
    including the vmapped batch path. ``sites`` is the static
    site-partition tuple (``None`` = single site).
    """
    sysarr = SystemArrays(
        eet=eet, p_dyn=p_dyn, p_idle=p_idle,
        site_of_machine=(None if sites is None
                         else jnp.asarray(sites, jnp.int32)),
    )
    sim = make_simulator(
        select_fn, sysarr, queue_size=queue_size,
        fairness_factor=fairness_factor, max_steps=max_steps,
        observers=observers, dispatcher=dispatcher, site_of_machine=sites,
    )
    return jax.vmap(sim)(trace) if batched else sim(trace)


def _simulate(trace, spec, heuristic, observers, max_steps, batched,
              dispatcher=None):
    from repro.core import dispatch as dispatch_mod
    from repro.core import observe, policy

    obs = observe.resolve(observers)
    sites = getattr(spec, "site_of_machine", None)
    sites = None if sites is None else tuple(int(s) for s in sites)
    # Single-site systems bypass the dispatch stage entirely, so the
    # dispatcher must not enter the static jit cache key there — else two
    # bit-identical flat runs under different dispatcher names would each
    # pay a full recompile.
    disp = (None if sites is None or max(sites) == 0
            else dispatch_mod.resolve(dispatcher))
    return _simulate_jit(
        trace,
        jnp.asarray(spec.eet, jnp.float32),
        jnp.asarray(spec.p_dyn, jnp.float32),
        jnp.asarray(spec.p_idle, jnp.float32),
        policy.get(heuristic) if isinstance(heuristic, str) else heuristic,
        obs,
        spec.queue_size,
        float(spec.fairness_factor),
        max_steps,
        batched,
        disp,
        sites,
    )


def simulate(trace: Trace, spec, heuristic: str, *, observers=(),
             max_steps=None, dispatcher=None):
    """Convenience entry point: one trace, one SystemSpec, one heuristic.

    The heuristic name is resolved through the policy registry, observer
    names through the observer registry, and the dispatcher name through
    the dispatcher registry — all *outside* the jit boundary; the
    (frozen, hashable) policy/observer/dispatcher objects are the static
    cache key — so re-registering a name with ``overwrite=True`` takes
    effect instead of silently hitting a stale name-keyed jit cache.
    ``spec.site_of_machine`` (if set) partitions the machines into
    federation sites served through ``dispatcher``.

    Returns :class:`Metrics` when ``observers`` is empty, else
    ``(Metrics, aux)`` with ``aux`` keyed by observer name.
    """
    return _simulate(trace, spec, heuristic, observers, max_steps, False,
                     dispatcher)


def simulate_batch(traces: Trace, spec, heuristic: str, *, observers=(),
                   max_steps=None, dispatcher=None):
    """vmap over a stacked batch of traces (the paper's 30-trace studies).

    Shares the cached ``_simulate_jit`` with :func:`simulate`: calling it
    in a loop over heuristics compiles each policy exactly once instead of
    rebuilding and re-jitting the vmapped simulator per call.
    """
    return _simulate(traces, spec, heuristic, observers, max_steps, True,
                     dispatcher)
