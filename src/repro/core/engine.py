"""Discrete-event simulation engine for the HEC system, in pure JAX.

The whole simulator is a ``lax.while_loop`` over events with fixed-shape
state, so a full workload trace is one jittable computation and a batch of
traces is one ``vmap``. Semantics follow Sec. III of the paper:

  * mapping events fire on task arrival and task completion (plus a progress
    event at the earliest pending deadline so stale tasks are always purged);
  * machines serve their bounded local queues FCFS;
  * a running task that passes its deadline is killed at the deadline (its
    dynamic energy is wasted, Eq. 2 row 1);
  * a queued task whose deadline passed before it starts is dropped with zero
    energy (Eq. 2 row 3);
  * per-type completion counters feed the fairness monitor continuously.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fairness
from repro.core.policy import MachineView
from repro.core.types import (
    CANCELLED,
    COMPLETED,
    MISSED,
    PENDING,
    QUEUED,
    RUNNING,
    UNARRIVED,
    Metrics,
    SystemArrays,
    Trace,
)

INF = jnp.float32(jnp.inf)


class SimState(NamedTuple):
    now: jnp.ndarray            # ()
    status: jnp.ndarray         # (N,) int32
    run_task: jnp.ndarray       # (M,) int32, -1 idle
    run_start: jnp.ndarray      # (M,)
    run_end_act: jnp.ndarray    # (M,) actual completion (inf if idle)
    run_end_exp: jnp.ndarray    # (M,) expected completion (for the mapper)
    run_success: jnp.ndarray    # (M,) bool
    queue: jnp.ndarray          # (M, Q) int32, -1 empty
    qlen: jnp.ndarray           # (M,) int32
    busy_time: jnp.ndarray      # (M,)
    e_dyn: jnp.ndarray          # ()
    e_wasted: jnp.ndarray       # ()
    completed: jnp.ndarray      # (S,) int32
    missed: jnp.ndarray         # (S,) int32
    cancelled: jnp.ndarray      # (S,) int32
    arrived: jnp.ndarray        # (S,) int32
    steps: jnp.ndarray          # () int32


def _init_state(trace: Trace, n_machines: int, queue_size: int,
                n_types: int) -> SimState:
    n = trace.arrival.shape[0]
    M, Q, S = n_machines, queue_size, n_types
    f = jnp.float32
    return SimState(
        now=f(0.0),
        status=jnp.full((n,), UNARRIVED, jnp.int32),
        run_task=jnp.full((M,), -1, jnp.int32),
        run_start=jnp.zeros((M,), f),
        run_end_act=jnp.full((M,), jnp.inf, f),
        run_end_exp=jnp.zeros((M,), f),
        run_success=jnp.zeros((M,), bool),
        queue=jnp.full((M, Q), -1, jnp.int32),
        qlen=jnp.zeros((M,), jnp.int32),
        busy_time=jnp.zeros((M,), f),
        e_dyn=f(0.0),
        e_wasted=f(0.0),
        completed=jnp.zeros((S,), jnp.int32),
        missed=jnp.zeros((S,), jnp.int32),
        cancelled=jnp.zeros((S,), jnp.int32),
        arrived=jnp.zeros((S,), jnp.int32),
        steps=jnp.int32(0),
    )


def _next_event_time(st: SimState, trace: Trace) -> jnp.ndarray:
    pending = st.status == PENDING
    unarrived = st.status == UNARRIVED
    t_arr = jnp.min(jnp.where(unarrived, trace.arrival, jnp.inf))
    t_comp = jnp.min(st.run_end_act)
    # progress guard: earliest pending deadline (so stale tasks get purged
    # even when no machine is busy and no arrivals remain).
    t_dead = jnp.min(jnp.where(pending, trace.deadline, jnp.inf))
    return jnp.minimum(jnp.minimum(t_arr, t_comp), t_dead)


def _finalize_completions(st: SimState, trace: Trace, sysarr: SystemArrays):
    """Close out machines whose running task's actual end <= now."""
    done = (st.run_task >= 0) & (st.run_end_act <= st.now)
    idx = jnp.where(done, st.run_task, 0)
    ttype = trace.task_type[idx]
    dur = jnp.where(done, st.run_end_act - st.run_start, 0.0)
    energy = sysarr.p_dyn * dur
    ok = done & st.run_success
    ko = done & ~st.run_success

    completed = st.completed.at[ttype].add(ok.astype(jnp.int32))
    missed = st.missed.at[ttype].add(ko.astype(jnp.int32))
    e_dyn = st.e_dyn + energy.sum()
    e_wasted = st.e_wasted + jnp.where(ko, energy, 0.0).sum()
    busy = st.busy_time + dur
    sidx = jnp.where(done, idx, st.status.shape[0])  # OOB sentinel -> dropped
    status = st.status.at[sidx].set(
        jnp.where(ok, COMPLETED, MISSED), mode="drop"
    )
    return st._replace(
        status=status,
        run_task=jnp.where(done, -1, st.run_task),
        run_end_act=jnp.where(done, jnp.inf, st.run_end_act),
        run_end_exp=jnp.where(done, st.now, st.run_end_exp),
        run_success=jnp.where(done, False, st.run_success),
        completed=completed,
        missed=missed,
        cancelled=st.cancelled,
        e_dyn=e_dyn,
        e_wasted=e_wasted,
        busy_time=busy,
    )


def _admit_arrivals(st: SimState, trace: Trace):
    newly = (st.status == UNARRIVED) & (trace.arrival <= st.now)
    status = jnp.where(newly, PENDING, st.status)
    arrived = st.arrived + jax.ops.segment_sum(
        newly.astype(jnp.int32), trace.task_type, st.arrived.shape[0]
    )
    return st._replace(status=status, arrived=arrived)


def _start_tasks(st: SimState, trace: Trace, sysarr: SystemArrays):
    """Idle machines pop their queue head (one pop per machine per event).

    A popped task whose deadline already passed "runs" for zero time with
    success=False and zero energy — the next loop iteration (same timestamp)
    finalizes it and pops again, which realizes Eq. 1/2's third row without
    an inner loop.
    """
    M = st.run_task.shape[0]
    can = (st.run_task < 0) & (st.qlen > 0)
    head = jnp.where(can, st.queue[:, 0], 0)
    ttype = trace.task_type[head]
    dl = trace.deadline[head]
    e_act = trace.exec_actual[head, jnp.arange(M)]
    e_exp = sysarr.eet[ttype, jnp.arange(M)]
    dead_on_arrival = st.now >= dl
    end_act = jnp.where(
        dead_on_arrival, st.now, jnp.minimum(st.now + e_act, dl)
    )
    success = ~dead_on_arrival & (st.now + e_act <= dl)
    end_exp = jnp.where(
        dead_on_arrival, st.now, jnp.minimum(st.now + e_exp, dl)
    )

    queue = jnp.where(
        can[:, None],
        jnp.concatenate(
            [st.queue[:, 1:], jnp.full((M, 1), -1, jnp.int32)], axis=1
        ),
        st.queue,
    )
    status = st.status.at[jnp.where(can, head, st.status.shape[0])].set(
        RUNNING, mode="drop"
    )
    return st._replace(
        status=status,
        run_task=jnp.where(can, head, st.run_task),
        run_start=jnp.where(can, st.now, st.run_start),
        run_end_act=jnp.where(can, end_act, st.run_end_act),
        run_end_exp=jnp.where(can, end_exp, st.run_end_exp),
        run_success=jnp.where(can, success, st.run_success),
        queue=queue,
        qlen=jnp.where(can, st.qlen - 1, st.qlen),
    )


def _apply_action(st: SimState, trace: Trace, action, n_types: int):
    """Apply a MapAction: queue evictions, proactive drops, assignments."""
    M, Q = st.queue.shape
    # --- queue evictions (FELARE victims) -> CANCELLED ----------------------
    victim = action.queue_drop & (st.queue >= 0)
    vidx = jnp.where(victim, st.queue, st.status.shape[0])
    status = st.status.at[vidx.reshape(-1)].set(CANCELLED, mode="drop")
    cancelled = st.cancelled + jax.ops.segment_sum(
        victim.reshape(-1).astype(jnp.int32),
        trace.task_type[jnp.clip(vidx, 0, st.status.shape[0] - 1)].reshape(-1),
        n_types,
    )
    # compact queues (stable: keep FCFS order of survivors)
    keep = ~victim & (st.queue >= 0)
    order = jnp.argsort(~keep, axis=1, stable=True)  # survivors first
    queue = jnp.take_along_axis(jnp.where(keep, st.queue, -1), order, axis=1)
    qlen = keep.sum(axis=1).astype(jnp.int32)

    # --- proactive drops from the arriving queue ----------------------------
    drop = action.drop & (status == PENDING)
    status = jnp.where(drop, CANCELLED, status)
    cancelled = cancelled + jax.ops.segment_sum(
        drop.astype(jnp.int32), trace.task_type, n_types
    )

    # --- assignments: append to queue tails ---------------------------------
    assign = action.assign  # (M,)
    # guard: task must still be PENDING (not dropped above) and slot free
    tstat = status[jnp.clip(assign, 0)]
    ok = (assign >= 0) & (tstat == PENDING) & (qlen < Q)
    slot = jnp.clip(qlen, 0, Q - 1)
    queue = queue.at[jnp.arange(M), slot].set(
        jnp.where(ok, assign, queue[jnp.arange(M), slot])
    )
    qlen = jnp.where(ok, qlen + 1, qlen)
    status = status.at[jnp.where(ok, assign, st.status.shape[0])].set(
        QUEUED, mode="drop"
    )
    return st._replace(status=status, queue=queue, qlen=qlen,
                       cancelled=cancelled)


def make_simulator(select_fn: Callable, sysarr: SystemArrays, *,
                   queue_size: int, fairness_factor: float = 1.0,
                   max_steps: int | None = None) -> Callable:
    """Build ``simulate(trace) -> Metrics`` for one mapping policy.

    ``select_fn(now, pending, task_type, deadline, view, sysarr, suffered)``
    is any :class:`repro.core.policy.Policy` (e.g. from
    ``policy.get(name)``) or a bare function with the same signature; it is
    closed over statically so jit specializes per policy.
    """
    S, M = sysarr.eet.shape

    def simulate(trace: Trace) -> Metrics:
        n = trace.arrival.shape[0]
        steps_cap = max_steps if max_steps is not None else 8 * n + 64
        st = _init_state(trace, M, queue_size, S)

        def cond(st: SimState):
            return (jnp.isfinite(_next_event_time(st, trace))
                    & (st.steps < steps_cap))

        def body(st: SimState):
            t = _next_event_time(st, trace)
            st = st._replace(now=jnp.maximum(t, st.now))
            st = _finalize_completions(st, trace, sysarr)
            st = _admit_arrivals(st, trace)

            suffered = fairness.suffered_types(
                st.completed, st.arrived, fairness_factor
            )
            view = MachineView(
                avail_base=jnp.maximum(
                    jnp.where(st.run_task >= 0, st.run_end_exp, st.now),
                    st.now,
                ),
                queue=st.queue,
                qlen=st.qlen,
            )
            action = select_fn(
                st.now,
                st.status == PENDING,
                trace.task_type,
                trace.deadline,
                view,
                sysarr,
                suffered,
            )
            st = _apply_action(st, trace, action, S)
            st = _start_tasks(st, trace, sysarr)
            return st._replace(steps=st.steps + 1)

        st = jax.lax.while_loop(cond, body, st)
        makespan = st.now
        e_idle = (sysarr.p_idle * (makespan - st.busy_time)).sum()
        return Metrics(
            completed_by_type=st.completed,
            missed_by_type=st.missed,
            cancelled_by_type=st.cancelled,
            arrived_by_type=st.arrived,
            energy_dynamic=st.e_dyn,
            energy_wasted=st.e_wasted,
            energy_idle=e_idle,
            makespan=makespan,
        )

    return simulate


@functools.partial(jax.jit, static_argnames=("select_fn", "queue_size",
                                             "fairness_factor", "max_steps"))
def _simulate_jit(trace, eet, p_dyn, p_idle, select_fn, queue_size,
                  fairness_factor, max_steps):
    sysarr = SystemArrays(eet=eet, p_dyn=p_dyn, p_idle=p_idle)
    sim = make_simulator(
        select_fn, sysarr, queue_size=queue_size,
        fairness_factor=fairness_factor, max_steps=max_steps,
    )
    return sim(trace)


def simulate(trace: Trace, spec, heuristic: str, *, max_steps=None) -> Metrics:
    """Convenience entry point: one trace, one SystemSpec, one heuristic.

    The name is resolved through the policy registry *outside* the jit
    boundary, and the (frozen, hashable) policy object is the static cache
    key — so re-registering a name with ``overwrite=True`` takes effect
    instead of silently hitting a stale name-keyed jit cache.
    """
    from repro.core import policy

    return _simulate_jit(
        trace,
        jnp.asarray(spec.eet, jnp.float32),
        jnp.asarray(spec.p_dyn, jnp.float32),
        jnp.asarray(spec.p_idle, jnp.float32),
        policy.get(heuristic),
        spec.queue_size,
        float(spec.fairness_factor),
        max_steps,
    )


def simulate_batch(traces: Trace, spec, heuristic: str, *, max_steps=None):
    """vmap over a stacked batch of traces (the paper's 30-trace studies)."""
    sysarr = spec.as_jax()
    from repro.core import policy

    sim = make_simulator(
        policy.get(heuristic), sysarr, queue_size=spec.queue_size,
        fairness_factor=float(spec.fairness_factor), max_steps=max_steps,
    )
    return jax.jit(jax.vmap(sim))(traces)
