"""High-level experiment API over the simulation engine."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import eet as eet_mod
from repro.core import engine, workload
from repro.core.types import Metrics, SystemSpec


def paper_system(queue_size: int = 2, fairness_factor: float = 1.0) -> SystemSpec:
    """The synthetic 4x4 system of Sec. VI-A (Table I + power profile)."""
    return SystemSpec(
        eet=eet_mod.TABLE_I,
        p_dyn=eet_mod.P_DYN,
        p_idle=eet_mod.P_IDLE,
        queue_size=queue_size,
        fairness_factor=fairness_factor,
    )


def aws_system(queue_size: int = 2, fairness_factor: float = 1.0) -> SystemSpec:
    """The AWS scenario (t2.xlarge / g3s.xlarge; FaceNet / DeepSpeech)."""
    return SystemSpec(
        eet=eet_mod.AWS_EET,
        p_dyn=eet_mod.AWS_P_DYN,
        p_idle=eet_mod.AWS_P_IDLE,
        queue_size=queue_size,
        fairness_factor=fairness_factor,
    )


@dataclasses.dataclass
class StudyResult:
    heuristic: str
    arrival_rate: float
    metrics: Metrics  # batched over traces

    @property
    def completion_rate(self) -> float:
        m = self.metrics
        return float(
            np.sum(m.completed_by_type) / np.maximum(np.sum(m.arrived_by_type), 1)
        )

    @property
    def miss_rate(self) -> float:
        return 1.0 - self.completion_rate

    @property
    def completion_rate_by_type(self) -> np.ndarray:
        m = self.metrics
        c = np.asarray(m.completed_by_type, np.float64).sum(0)
        a = np.asarray(m.arrived_by_type, np.float64).sum(0)
        return c / np.maximum(a, 1)

    @property
    def energy_total(self) -> float:
        m = self.metrics
        return float(
            np.mean(
                np.asarray(m.energy_dynamic) + np.asarray(m.energy_idle)
            )
        )

    @property
    def wasted_energy_pct(self) -> float:
        """Wasted dynamic energy as % of the initial battery capacity.

        Battery capacity is normalized as the mean total energy a fully-busy
        system would draw over the trace makespan (Sec. VII-B measures waste
        relative to the initial available energy)."""
        m = self.metrics
        cap = np.mean(
            np.asarray(m.makespan)
        ) * float(np.sum(self._p_dyn))
        return float(np.mean(np.asarray(m.energy_wasted))) / max(cap, 1e-9) * 100

    _p_dyn: np.ndarray = dataclasses.field(default=None, repr=False)


def run_study(heuristic: str, arrival_rates, spec: SystemSpec, *,
              n_traces: int = 30, n_tasks: int = 2000, seed: int = 0,
              cv_run: float = 0.1):
    """The paper's experiment template: ``n_traces`` i.i.d. traces per
    arrival rate, simulated in a single vmap per rate."""
    results = []
    for r_i, rate in enumerate(arrival_rates):
        key = jax.random.PRNGKey(seed * 1000 + r_i)
        traces = workload.trace_batch(
            key, n_traces, n_tasks, float(rate), spec.eet, cv_run=cv_run
        )
        metrics = engine.simulate_batch(traces, spec, heuristic)
        metrics = jax.tree.map(np.asarray, metrics)
        res = StudyResult(heuristic, float(rate), metrics)
        res._p_dyn = np.asarray(spec.p_dyn)
        results.append(res)
    return results
