"""High-level experiment API over the simulation engine.

This module is the stable, paper-oriented surface:

  * :func:`paper_system` / :func:`aws_system` build the two evaluation
    systems of Sec. VI-A;
  * :func:`run_study` runs the paper's experiment template (K i.i.d.
    traces per arrival rate, one heuristic) and returns per-rate
    :class:`StudyResult` views.

Since the batched Monte-Carlo subsystem landed, ``run_study`` is a thin
consumer of :mod:`repro.experiments` — the heavy lifting (trace-stack
synthesis, the single-jit vmapped simulation, reductions) lives there.
Prefer :func:`repro.experiments.run_sweep` directly for multi-heuristic
grids; ``run_study`` remains for single-heuristic studies and backward
compatibility.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import eet as eet_mod
from repro.core.types import Metrics, SystemSpec


def paper_system(queue_size: int = 2, fairness_factor: float = 1.0
                 ) -> SystemSpec:
    """The synthetic 4x4 system of Sec. VI-A (Table I + power profile).

    Args:
      queue_size: bounded local-queue slots per machine (paper: 2).
      fairness_factor: Eq. 3's ``f``; 1.0 is the paper's operating point,
        larger values make the fairness trigger less aggressive.

    Returns:
      A :class:`SystemSpec` with the (4, 4) Table I EET in seconds and the
      Sec. VI-A dynamic/idle power profile in unit-power multiples.
    """
    return SystemSpec(
        eet=eet_mod.TABLE_I,
        p_dyn=eet_mod.P_DYN,
        p_idle=eet_mod.P_IDLE,
        queue_size=queue_size,
        fairness_factor=fairness_factor,
    )


def aws_system(queue_size: int = 2, fairness_factor: float = 1.0
               ) -> SystemSpec:
    """The AWS scenario: t2.xlarge / g3s.xlarge running FaceNet / DeepSpeech.

    Args:
      queue_size: bounded local-queue slots per machine.
      fairness_factor: Eq. 3's ``f``.

    Returns:
      A :class:`SystemSpec` with a (2, 2) EET (face/speech x CPU/GPU,
      seconds of end-to-end inference latency) and TDP-based powers (W).
    """
    return SystemSpec(
        eet=eet_mod.AWS_EET,
        p_dyn=eet_mod.AWS_P_DYN,
        p_idle=eet_mod.AWS_P_IDLE,
        queue_size=queue_size,
        fairness_factor=fairness_factor,
    )


@dataclasses.dataclass
class StudyResult:
    """One (heuristic, arrival-rate) cell of a study.

    Attributes:
      heuristic: the mapping policy name (e.g. ``"FELARE"``).
      arrival_rate: the Poisson arrival rate (tasks/sec) of this cell.
      metrics: raw per-trace :class:`Metrics`; every leaf carries a leading
        replicate dim (K traces): count leaves are (K, S) int arrays,
        energy/makespan leaves are (K,) floats.
      p_dyn: (M,) per-machine dynamic power of the simulated system —
        needed to normalize :attr:`wasted_energy_pct`.
      aux: observer outputs for this cell, keyed by observer name (every
        leaf carries the leading K-replicate dim); ``None`` when the study
        attached no observers.
    """

    heuristic: str
    arrival_rate: float
    metrics: Metrics  # batched over traces
    p_dyn: np.ndarray = dataclasses.field(repr=False)
    aux: dict | None = dataclasses.field(default=None, repr=False)

    @property
    def completion_rate(self) -> float:
        """On-time completion rate pooled over all replicates and types."""
        m = self.metrics
        return float(
            np.sum(m.completed_by_type) / np.maximum(np.sum(m.arrived_by_type), 1)
        )

    @property
    def miss_rate(self) -> float:
        """1 - :attr:`completion_rate` (the paper's deadline-miss rate)."""
        return 1.0 - self.completion_rate

    @property
    def completion_rate_by_type(self) -> np.ndarray:
        """(S,) per-task-type completion rates, pooled over replicates."""
        m = self.metrics
        c = np.asarray(m.completed_by_type, np.float64).sum(0)
        a = np.asarray(m.arrived_by_type, np.float64).sum(0)
        return c / np.maximum(a, 1)

    @property
    def energy_total(self) -> float:
        """Mean (dynamic + idle) energy per trace, in the system's units."""
        m = self.metrics
        return float(
            np.mean(
                np.asarray(m.energy_dynamic) + np.asarray(m.energy_idle)
            )
        )

    @property
    def wasted_energy_pct(self) -> float:
        """Wasted dynamic energy as % of the initial battery capacity.

        Battery capacity is normalized as the mean total energy a fully-busy
        system would draw over the trace makespan (Sec. VII-B measures waste
        relative to the initial available energy)."""
        m = self.metrics
        cap = np.mean(
            np.asarray(m.makespan)
        ) * float(np.sum(self.p_dyn))
        return float(np.mean(np.asarray(m.energy_wasted))) / max(cap, 1e-9) * 100


def run_study(heuristic: str, arrival_rates, spec: SystemSpec, *,
              n_traces: int = 30, n_tasks: int = 2000, seed: int = 0,
              cv_run: float = 0.1, scenario="poisson", observers=(),
              dispatcher="sticky", dynamics="none", network="none"):
    """The paper's experiment template for one heuristic.

    Thin wrapper over :func:`repro.experiments.run_sweep`: synthesizes
    ``n_traces`` replicate traces per arrival rate under one PRNG key
    (common random numbers across rates) and simulates the whole
    (rate x replicate) grid in a single jitted batch.

    Args:
      heuristic: any registered policy name
        (:func:`repro.core.policy.list_policies`).
      arrival_rates: sequence of R nominal arrival rates (tasks/sec).
      spec: the :class:`SystemSpec` to simulate (its queue size and
        fairness factor are used as-is).
      n_traces: K replicate traces per rate (paper: 30).
      n_tasks: N tasks per trace (paper: 2000).
      seed: PRNG seed for trace synthesis.
      cv_run: coefficient of variation of actual runtimes around the EET.
      scenario: workload scenario — a registered name
        (:func:`repro.scenarios.list_scenarios`) or a
        :class:`repro.scenarios.Scenario`; default is the paper's
        stationary Poisson workload.
      observers: engine observers to attach — registered names
        (:func:`repro.core.observe.list_observers`) or
        :class:`repro.core.observe.Observer` instances. Their per-cell
        outputs land on :attr:`StudyResult.aux`.
      dispatcher: federation site-selection rule — a registered name
        (:func:`repro.core.dispatch.list_dispatchers`) or a
        :class:`repro.core.dispatch.Dispatcher` instance. Only relevant
        when ``spec.site_of_machine`` partitions the machines into sites;
        the default ``"sticky"`` keeps single-site studies bit-identical
        to pre-federation ones.
      dynamics: machine-failure process — a registered name
        (:func:`repro.core.faults.list_dynamics`) or a
        :class:`repro.core.faults.MachineDynamics` instance; the default
        ``"none"`` keeps studies bit-identical to fault-free ones.
      network: edge-cloud transfer-cost model — a registered name
        (:func:`repro.core.network.list_networks`) or a
        :class:`repro.core.network.NetworkModel` instance; the default
        ``"none"`` keeps studies bit-identical to network-free ones.

    Returns:
      list[StudyResult] of length R, in ``arrival_rates`` order.
    """
    from repro import experiments

    sweep_spec = experiments.SweepSpec(
        system=spec,
        scenario=scenario,
        rates=tuple(float(r) for r in arrival_rates),
        reps=n_traces,
        n_tasks=n_tasks,
        heuristics=(heuristic,),
        seed=seed,
        cv_run=cv_run,
        observers=tuple(observers),
        dispatcher=dispatcher,
        dynamics=dynamics,
        network=network,
    )
    result = experiments.run_sweep(sweep_spec)

    def cell_aux(r_i):
        if not result.aux:
            return None

        def take(x):
            if isinstance(x, dict):
                return {k: take(v) for k, v in x.items()}
            return x[0, r_i]

        return take(result.aux)

    return [
        StudyResult(
            heuristic, float(rate), result.metrics_for(heuristic, rate),
            p_dyn=np.asarray(spec.p_dyn),
            aux=cell_aux(r_i),
        )
        for r_i, rate in enumerate(sweep_spec.rates)
    ]
