"""Chunked cross-entropy LM loss.

The LM head matmul + softmax runs per sequence-chunk inside a ``lax.scan``,
so (tokens x vocab) logits are never materialized for the whole batch — the
difference between fitting and OOMing for command-r's 256k vocab at 1M-token
global batches. With vocab TP-sharded, XLA keeps the chunk logits sharded and
reduces the logsumexp across the ``model`` axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as ll


def chunked_lm_loss(cfg, params, hidden, labels, mask, chunk: int = 512):
    """hidden: (B, S, d); labels, mask: (B, S). Returns (mean_loss, n_tokens).

    ``mask`` zeroes padding / modality positions (e.g. VLM patch slots).
    """
    B, S, d = hidden.shape
    C = min(chunk, S)
    while S % C:
        C -= 1
    nc = S // C
    h = hidden.reshape(B, nc, C, d).swapaxes(0, 1)     # (nc, B, C, d)
    y = labels.reshape(B, nc, C).swapaxes(0, 1)
    m = mask.reshape(B, nc, C).swapaxes(0, 1)

    def body(carry, xs):
        loss_sum, count = carry
        hc, yc, mc = xs
        logits = ll.unembed_apply(cfg, params["embed"], hc)  # fp32 (B,C,V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, yc[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (loss_sum + nll.sum(), count + mc.sum()), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (h, y, m))
    return loss_sum / jnp.maximum(count, 1.0), count


def make_loss_fn(cfg, aux_weight: float = 0.01):
    """(params, batch) -> (scalar loss, metrics dict).

    batch: tokens (B, S) plus family extras; labels are tokens shifted left.
    VLM: loss only on text positions (hidden covers patches + text).
    """
    from repro.models import transformer as tf

    def loss_fn(params, batch):
        hidden, aux = tf.forward(cfg, params, batch)
        tokens = batch["tokens"]
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
        if "mask" in batch:
            mask = mask * batch["mask"]
        if cfg.family == "vlm":
            # hidden = [patches | text]; predict text tokens only
            hidden = hidden[:, cfg.n_patches:]
        loss, count = chunked_lm_loss(cfg, params, hidden, labels, mask)
        total = loss + aux_weight * aux
        return total, {"loss": loss, "aux": aux, "tokens": count}

    return loss_fn
