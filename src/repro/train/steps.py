"""jit-compiled train / serve steps with multi-pod sharding.

``make_train_step``: microbatched gradient accumulation via ``lax.scan``
(batch: (A, mb, S)), per-layer remat inside the model, AdamW update. The
returned callable is ``jax.jit`` with explicit in/out shardings so the same
code lowers on 1 CPU device, a 256-chip pod, or the 512-chip 2-pod mesh.

``make_serve_steps``: prefill + single-token decode against a sharded cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import transformer as tf
from repro.optim.adamw import AdamW
from repro.train.loss import make_loss_fn


def make_train_step(cfg, optimizer: AdamW, mesh=None, *, lr_schedule=None,
                    donate: bool = True):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch["tokens"]: (A, mb, S) — A grad-accum microbatches.
    """
    loss_fn = make_loss_fn(cfg)

    def train_step(params, opt_state, batch):
        def micro(carry, mb):
            gsum, lsum, tsum = carry
            (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + metrics["loss"], tsum + metrics["tokens"]), None

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum, tsum), _ = jax.lax.scan(
            micro, (gzero, jnp.float32(0.0), jnp.float32(0.0)), batch)
        A = batch["tokens"].shape[0]
        grads = jax.tree.map(lambda g: g / A, gsum)
        lr = lr_schedule(opt_state.step) if lr_schedule else None
        params, opt_state, gnorm = optimizer.update(
            grads, opt_state, params, lr=lr)
        metrics = {"loss": lsum / A, "grad_norm": gnorm, "tokens": tsum}
        return params, opt_state, metrics

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0, 1) if donate else ())

    pshapes = tf.param_shapes(cfg)
    pshard = sh.param_shardings(pshapes, mesh, cfg)
    oshard = sh.opt_state_shardings(pshapes, mesh, cfg)

    def in_batch_shardings(batch_shapes):
        return sh.batch_sharding(mesh, batch_shapes, accum_dim=True)

    def jit_for(batch_shapes):
        return jax.jit(
            train_step,
            in_shardings=(pshard, oshard, in_batch_shardings(batch_shapes)),
            out_shardings=(pshard, oshard,
                           jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                        {"loss": 0, "grad_norm": 0,
                                         "tokens": 0})),
            donate_argnums=(0, 1) if donate else (),
        )

    train_step.jit_for = jit_for  # type: ignore[attr-defined]
    train_step.param_shardings = pshard  # type: ignore[attr-defined]
    train_step.opt_shardings = oshard  # type: ignore[attr-defined]
    return train_step


def make_serve_steps(cfg, mesh=None):
    """-> (prefill_step, decode_step) jit'd (sharded when mesh given)."""

    def prefill_step(params, batch, *, max_seq):
        from repro.models import layers as ll
        hidden, cache = tf.prefill(cfg, params, batch, max_seq)
        logits = ll.unembed_apply(cfg, params["embed"], hidden)
        return logits, cache

    def decode_step(params, cache, tokens):
        return tf.decode_step(cfg, params, cache, tokens)

    if mesh is None:
        return (
            jax.jit(prefill_step, static_argnames=("max_seq",)),
            jax.jit(decode_step),
        )

    pshapes = tf.param_shapes(cfg)
    pshard = sh.param_shardings(pshapes, mesh, cfg)

    def decode_jit_for(cache_shapes, token_shapes):
        cshard = sh.cache_sharding(cfg, mesh, cache_shapes)
        tshard = sh.batch_sharding(mesh, token_shapes)
        return jax.jit(
            decode_step,
            in_shardings=(pshard, cshard, tshard),
            out_shardings=(
                sh.batch_sharding(
                    mesh,
                    jax.eval_shape(decode_step, pshapes, cache_shapes,
                                   token_shapes)[0]),
                cshard,
            ),
            donate_argnums=(1,),
        )

    def prefill_jit_for(batch_shapes, max_seq):
        bshard = sh.batch_sharding(mesh, batch_shapes)
        fn = functools.partial(prefill_step, max_seq=max_seq)
        out_sh = jax.eval_shape(fn, pshapes, batch_shapes)
        return jax.jit(
            fn,
            in_shardings=(pshard, bshard),
            out_shardings=(
                sh.batch_sharding(mesh, out_sh[0]),
                sh.cache_sharding(cfg, mesh, out_sh[1]),
            ),
        )

    return prefill_jit_for, decode_jit_for
