"""Fault-tolerant training loop: checkpoint/restart + failure injection.

The loop is restart-idempotent: data batches are a pure function of the step
(repro.datapipe.SyntheticLM), checkpoints are atomic, and ``run_with_restarts``
demonstrates the full preemption story — a SimulatedFailure at step k loses
at most ``ckpt_every`` steps of work and training continues bit-exactly from
the last checkpoint (asserted in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.checkpoint import ckpt
from repro.datapipe.synthetic import SyntheticLM
from repro.models import transformer as tf
from repro.optim.adamw import AdamW
from repro.train.steps import make_train_step


class SimulatedFailure(RuntimeError):
    """Injected preemption (a 'node failure' in the dry-run environment)."""


@dataclasses.dataclass
class TrainJob:
    cfg: object
    steps: int
    batch: int = 4
    seq: int = 32
    accum: int = 1
    lr: float = 1e-3
    ckpt_dir: str | None = None
    ckpt_every: int = 10
    ckpt_async: bool = True
    seed: int = 0
    mesh: object = None
    log_every: int = 10


def run(job: TrainJob, *, fail_at: dict[int, Exception] | None = None,
        on_step: Callable | None = None):
    """One incarnation: restores from the latest checkpoint if present,
    trains to job.steps, checkpoints periodically. Raises the injected
    failure if the plan says so (simulating preemption mid-run)."""
    cfg = job.cfg
    opt = AdamW(lr=job.lr)
    data = SyntheticLM(cfg, batch=job.batch, seq=job.seq, seed=job.seed,
                       accum=job.accum)
    step_fn = make_train_step(cfg, opt, job.mesh, donate=False)
    if job.mesh is not None:
        raise NotImplementedError(
            "mesh-sharded loop is exercised via launch/train.py")

    start = 0
    params = opt_state = None
    if job.ckpt_dir is not None and ckpt.latest_step(job.ckpt_dir) is not None:
        target = tf.param_shapes(cfg)
        opt_t = jax.eval_shape(opt.init, target)
        state, start = ckpt.restore(job.ckpt_dir, {"p": target, "o": opt_t})
        params, opt_state = state["p"], state["o"]
    if params is None:
        params = tf.init(jax.random.PRNGKey(job.seed), cfg)
        opt_state = opt.init(params)

    history = []
    pending_save = None
    for step in range(start, job.steps):
        if fail_at and step in fail_at:
            raise fail_at.pop(step)
        batch = data.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        history.append({"step": step, "loss": loss,
                        "grad_norm": float(metrics["grad_norm"])})
        if on_step:
            on_step(step, history[-1])
        if (job.ckpt_dir is not None
                and (step + 1) % job.ckpt_every == 0):
            if pending_save is not None:
                pending_save.join()
            pending_save = ckpt.save(
                job.ckpt_dir, step + 1, {"p": params, "o": opt_state},
                blocking=not job.ckpt_async)
    if pending_save is not None:
        pending_save.join()
    if job.ckpt_dir is not None:
        ckpt.save(job.ckpt_dir, job.steps, {"p": params, "o": opt_state})
    return params, opt_state, history


def run_with_restarts(job: TrainJob, *, failures: dict[int, Exception],
                      max_restarts: int = 8):
    """The supervisor: restart-from-checkpoint on (simulated) node failure."""
    attempts = 0
    history = []
    while True:
        try:
            params, opt_state, h = run(job, fail_at=failures)
            history.extend(h)
            return params, opt_state, history, attempts
        except SimulatedFailure:
            attempts += 1
            if attempts > max_restarts:
                raise
            time.sleep(0.01)
