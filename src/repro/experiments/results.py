"""Sweep result container: raw per-trace Metrics + statistical reductions.

The raw material is a :class:`~repro.core.types.Metrics` pytree whose leaves
carry (H, R, K, ...) leading dims — H heuristics, R arrival rates, K
replicate traces. :class:`SweepResult` reduces that to the quantities the
paper plots (Figs. 3-8): on-time completion rate, total/wasted energy, and
per-type fairness, each with a mean and a 95% normal CI over the K
replicates, and serializes everything to CSV/JSON artifacts.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import pathlib
from typing import TYPE_CHECKING

import numpy as np

from repro.core.types import Metrics, SystemSpec

if TYPE_CHECKING:  # pragma: no cover
    from repro.experiments.spec import SweepSpec

_Z95 = 1.96


def _tree_np(x):
    """Recursively materialize an aux pytree as host numpy arrays."""
    if isinstance(x, dict):
        return {k: _tree_np(v) for k, v in x.items()}
    return np.asarray(x)


def _mean_ci(x: np.ndarray, axis: int = -1):
    """Mean and 95% normal CI half-width over ``axis`` (K replicates)."""
    x = np.asarray(x, np.float64)
    k = x.shape[axis]
    mean = x.mean(axis=axis)
    if k < 2:
        return mean, np.zeros_like(mean)
    sem = x.std(axis=axis, ddof=1) / np.sqrt(k)
    return mean, _Z95 * sem


def _jain(values: np.ndarray, axis: int = -1):
    """Jain's fairness index along ``axis`` (1.0 = perfectly fair)."""
    v = np.asarray(values, np.float64)
    s1 = v.sum(axis=axis)
    s2 = (v * v).sum(axis=axis)
    n = v.shape[axis]
    with np.errstate(invalid="ignore", divide="ignore"):
        out = np.where(s2 > 0, s1 * s1 / (n * s2), 1.0)
    return out


@dataclasses.dataclass
class SweepResult:
    """Everything a sweep produced, reduced and raw.

    Attributes:
      spec: the :class:`SweepSpec` that generated this result.
      system: the resolved SystemSpec actually simulated.
      heuristics: H heuristic names (axis 0 of every array below).
      rates: R arrival rates (axis 1).
      metrics: raw Metrics pytree; count leaves are (H, R, K, S) int arrays,
        energy/makespan leaves are (H, R, K) floats.
      aux: observer outputs keyed by observer name (empty dict when the
        spec attached none); every leaf leads with the same (H, R, K)
        batch dims — e.g. the ``timeline`` observer's ``e_dyn`` series is
        (H, R, K, n_buckets).
    """

    spec: "SweepSpec"
    system: SystemSpec
    heuristics: tuple[str, ...]
    rates: tuple[float, ...]
    metrics: Metrics
    aux: dict = dataclasses.field(default_factory=dict, repr=False)

    @classmethod
    def from_metrics(cls, spec, system: SystemSpec, metrics: Metrics,
                     aux: dict | None = None) -> "SweepResult":
        metrics = Metrics(*(np.asarray(leaf) for leaf in metrics))
        aux = {} if aux is None else _tree_np(aux)
        return cls(spec=spec, system=system,
                   heuristics=tuple(spec.heuristics),
                   rates=tuple(spec.rates), metrics=metrics, aux=aux)

    # ---------------------------------------------------------------- axes
    def h_index(self, heuristic: str) -> int:
        return self.heuristics.index(heuristic.upper())

    def r_index(self, rate: float) -> int:
        r = float(rate)
        for i, x in enumerate(self.rates):
            if abs(x - r) < 1e-9:
                return i
        raise ValueError(f"rate {rate!r} not in sweep grid {self.rates}")

    # ------------------------------------------------------- per-trace stats
    @property
    def completion_rate_traces(self) -> np.ndarray:
        """(H, R, K) on-time completion rate of each simulated trace."""
        c = self.metrics.completed_by_type.sum(-1).astype(np.float64)
        a = self.metrics.arrived_by_type.sum(-1).astype(np.float64)
        return c / np.maximum(a, 1.0)

    @property
    def energy_traces(self) -> np.ndarray:
        """(H, R, K) total (dynamic + idle) energy of each trace."""
        return (np.asarray(self.metrics.energy_dynamic, np.float64)
                + np.asarray(self.metrics.energy_idle, np.float64))

    @property
    def wasted_pct_traces(self) -> np.ndarray:
        """(H, R, K) wasted dynamic energy as % of normalized battery.

        Battery capacity is normalized per (heuristic, rate) cell as the
        mean energy a fully-busy system would draw over the cell's mean
        makespan (the Sec. VII-B convention).
        """
        cap = (self.metrics.makespan.mean(-1, keepdims=True)
               * float(np.sum(self.system.p_dyn)))
        return (np.asarray(self.metrics.energy_wasted, np.float64)
                / np.maximum(cap, 1e-9) * 100.0)

    # ------------------------------------------------------- cell summaries
    @property
    def completion_rate(self) -> np.ndarray:
        """(H, R) mean on-time completion rate over replicates."""
        return _mean_ci(self.completion_rate_traces)[0]

    @property
    def completion_rate_ci(self) -> np.ndarray:
        """(H, R) 95% CI half-width of the completion rate."""
        return _mean_ci(self.completion_rate_traces)[1]

    @property
    def completion_rate_pooled(self) -> np.ndarray:
        """(H, R) completion rate pooled over replicates and types.

        Pooled = total completions / total arrivals (replicates weighted by
        their arrival counts), matching ``StudyResult.completion_rate``;
        :attr:`completion_rate` instead averages per-trace rates (each
        replicate weighted equally).
        """
        c = self.metrics.completed_by_type.sum(-1).sum(-1).astype(np.float64)
        a = self.metrics.arrived_by_type.sum(-1).sum(-1).astype(np.float64)
        return c / np.maximum(a, 1.0)

    @property
    def energy(self) -> np.ndarray:
        """(H, R) mean total energy."""
        return _mean_ci(self.energy_traces)[0]

    @property
    def energy_ci(self) -> np.ndarray:
        return _mean_ci(self.energy_traces)[1]

    @property
    def wasted_pct(self) -> np.ndarray:
        """(H, R) mean wasted-energy percentage."""
        return _mean_ci(self.wasted_pct_traces)[0]

    @property
    def cancelled_pct(self) -> np.ndarray:
        """(H, R) cancelled tasks as % of arrivals (pooled over reps)."""
        c = self.metrics.cancelled_by_type.sum(-1).sum(-1).astype(np.float64)
        a = self.metrics.arrived_by_type.sum(-1).sum(-1).astype(np.float64)
        return c / np.maximum(a, 1.0) * 100.0

    @property
    def missed_pct(self) -> np.ndarray:
        """(H, R) deadline-missed tasks as % of arrivals (pooled)."""
        m = self.metrics.missed_by_type.sum(-1).sum(-1).astype(np.float64)
        a = self.metrics.arrived_by_type.sum(-1).sum(-1).astype(np.float64)
        return m / np.maximum(a, 1.0) * 100.0

    @property
    def completion_rate_by_type(self) -> np.ndarray:
        """(H, R, S) per-type completion rates, pooled over replicates.

        Pooling (sum completions / sum arrivals) matches the paper's Fig. 7
        bars; it weighs replicates by their arrival counts.
        """
        c = self.metrics.completed_by_type.sum(2).astype(np.float64)
        a = self.metrics.arrived_by_type.sum(2).astype(np.float64)
        return c / np.maximum(a, 1.0)

    @property
    def fairness_spread(self) -> np.ndarray:
        """(H, R) std of per-type completion rates (lower = fairer)."""
        return self.completion_rate_by_type.std(-1)

    @property
    def jain_index(self) -> np.ndarray:
        """(H, R) Jain's fairness index over per-type rates (1 = fair)."""
        return _jain(self.completion_rate_by_type)

    def metrics_for(self, heuristic: str, rate: float) -> Metrics:
        """The raw per-trace Metrics of one (heuristic, rate) cell: (K, ...)."""
        h, r = self.h_index(heuristic), self.r_index(rate)
        return Metrics(*(leaf[h, r] for leaf in self.metrics))

    # ------------------------------------------------------------ artifacts
    def summary_rows(self) -> list[dict]:
        """One CSV-ready dict per (heuristic, rate) cell."""
        cr, cr_ci = _mean_ci(self.completion_rate_traces)
        en, en_ci = _mean_ci(self.energy_traces)
        wp, wp_ci = _mean_ci(self.wasted_pct_traces)
        by_type = self.completion_rate_by_type
        spread = self.fairness_spread
        jain = self.jain_index
        cpct, mpct = self.cancelled_pct, self.missed_pct
        rows = []
        for h_i, h in enumerate(self.heuristics):
            for r_i, rate in enumerate(self.rates):
                row = {
                    "heuristic": h,
                    "rate": rate,
                    "reps": self.metrics.makespan.shape[2],
                    "completion_rate": round(float(cr[h_i, r_i]), 6),
                    "completion_rate_ci95": round(float(cr_ci[h_i, r_i]), 6),
                    "energy": round(float(en[h_i, r_i]), 3),
                    "energy_ci95": round(float(en_ci[h_i, r_i]), 3),
                    "wasted_pct": round(float(wp[h_i, r_i]), 4),
                    "wasted_pct_ci95": round(float(wp_ci[h_i, r_i]), 4),
                    "cancelled_pct": round(float(cpct[h_i, r_i]), 4),
                    "missed_pct": round(float(mpct[h_i, r_i]), 4),
                    "fairness_spread": round(float(spread[h_i, r_i]), 6),
                    "jain_index": round(float(jain[h_i, r_i]), 6),
                }
                for s in range(by_type.shape[-1]):
                    row[f"completion_rate_T{s + 1}"] = round(
                        float(by_type[h_i, r_i, s]), 6)
                rows.append(row)
        return rows

    def to_json_dict(self) -> dict:
        return {
            "spec": self.spec.to_json_dict(),
            "heuristics": list(self.heuristics),
            "rates": list(self.rates),
            "summary": self.summary_rows(),
        }

    # -------------------------------------------------- time-series views
    def timeline_rows(self) -> list[dict]:
        """Long-form CSV rows of the ``timeline`` observer's series.

        One row per (heuristic, rate, replicate, bucket) with the sampled
        queue occupancy, cumulative energies and per-type completions.
        Raises KeyError if the sweep did not attach the observer.
        """
        tl = self.aux["timeline"]
        H, R, K, B = tl["e_dyn"].shape
        S = tl["completed"].shape[-1]
        rows = []
        for h_i, h in enumerate(self.heuristics):
            for r_i, rate in enumerate(self.rates):
                for k in range(K):
                    for b in range(B):
                        row = {
                            "heuristic": h,
                            "rate": rate,
                            "rep": k,
                            "bucket": b,
                            "t": round(float(tl["t"][h_i, r_i, k, b]), 6),
                            "qlen": int(tl["qlen"][h_i, r_i, k, b]),
                            "running": int(tl["running"][h_i, r_i, k, b]),
                            "energy_dynamic": round(
                                float(tl["e_dyn"][h_i, r_i, k, b]), 4),
                            "energy_idle": round(
                                float(tl["e_idle"][h_i, r_i, k, b]), 4),
                        }
                        for s in range(S):
                            row[f"completed_T{s + 1}"] = int(
                                tl["completed"][h_i, r_i, k, b, s])
                        rows.append(row)
        return rows

    def aux_json_dict(self) -> dict:
        """Every observer's stacked aux as JSON-ready nested lists.

        Non-finite floats (e.g. the energy budget's ``t_exhausted=inf``
        when the battery never ran out) become ``null`` — strict RFC 8259
        JSON, so the artifact survives jq / JS parsers.
        """
        def scrub(v):
            if isinstance(v, list):
                return [scrub(i) for i in v]
            if isinstance(v, float) and not np.isfinite(v):
                return None
            return v

        def conv(x):
            if isinstance(x, dict):
                return {k: conv(v) for k, v in x.items()}
            return scrub(np.asarray(x).tolist())

        return conv(self.aux)

    def save(self, outdir) -> dict[str, pathlib.Path]:
        """Write ``sweep.csv`` + ``sweep.json`` under ``outdir``.

        Returns the written paths keyed by format. The CSV holds the
        per-cell summary table; the JSON additionally embeds the generating
        spec so the sweep is reproducible from the artifact alone. When
        observers were attached, their stacked aux is emitted too:
        ``observers.json`` (all observers, nested lists) and — if the
        ``timeline`` observer ran — a long-form ``timeline.csv``.
        """
        outdir = pathlib.Path(outdir)
        outdir.mkdir(parents=True, exist_ok=True)
        rows = self.summary_rows()
        csv_path = outdir / "sweep.csv"
        with open(csv_path, "w", newline="") as f:
            writer = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            writer.writeheader()
            writer.writerows(rows)
        json_path = outdir / "sweep.json"
        with open(json_path, "w") as f:
            json.dump(self.to_json_dict(), f, indent=2)
        paths = {"csv": csv_path, "json": json_path}
        if self.aux:
            obs_path = outdir / "observers.json"
            with open(obs_path, "w") as f:
                json.dump(self.aux_json_dict(), f, allow_nan=False)
            paths["observers_json"] = obs_path
        if "timeline" in self.aux:
            trows = self.timeline_rows()
            tpath = outdir / "timeline.csv"
            with open(tpath, "w", newline="") as f:
                writer = csv.DictWriter(f, fieldnames=list(trows[0].keys()))
                writer.writeheader()
                writer.writerows(trows)
            paths["timeline_csv"] = tpath
        return paths
