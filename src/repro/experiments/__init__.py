"""Batched Monte-Carlo experiment subsystem.

The paper's evaluation (Figs. 3-8) is statistical: many random workload
traces per arrival rate, simulated under every mapping heuristic. This
package turns that into one-dispatch batched computations:

  spec     — :class:`SweepSpec`, the full experiment configuration
             (system fleet + workload scenario + grid), JSON round-trip
             via ``to_json_dict``/``from_json_dict``
  runner   — :func:`run_sweep` / :func:`simulate_sweep`, one jit per sweep
  results  — :class:`SweepResult`, mean/CI reductions + CSV/JSON artifacts
  sweep    — the CLI: ``python -m repro.experiments.sweep``

Workload synthesis is delegated to the composable scenario API
(:mod:`repro.scenarios`): ``SweepSpec.scenario`` names any registered
``Scenario`` (arrival process x type mix x deadline model x runtime model
[x fleet]), all fixed-shape JAX, so every scenario runs inside the same
single-jit vmapped sweep. Multi-site federations ride the same way:
``SweepSpec.dispatcher`` names any registered
:mod:`repro.core.dispatch` rule, applied when the resolved system's
``site_of_machine`` partitions its machines into sites.

`repro.core.api.run_study`, `benchmarks/`, and `examples/` are thin
consumers of this layer.
"""
from repro.experiments.results import SweepResult
from repro.experiments.runner import run_sweep, simulate_sweep
from repro.experiments.spec import SweepSpec, parse_rates, replace

__all__ = [
    "SweepResult",
    "SweepSpec",
    "parse_rates",
    "replace",
    "run_sweep",
    "simulate_sweep",
]
