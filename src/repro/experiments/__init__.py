"""Batched Monte-Carlo experiment subsystem.

The paper's evaluation (Figs. 3-8) is statistical: many random workload
traces per arrival rate, simulated under every mapping heuristic. This
package turns that into one-dispatch batched computations:

  spec     — :class:`SweepSpec`, the full experiment configuration
  runner   — :func:`run_sweep` / :func:`simulate_sweep`, one jit per sweep
  results  — :class:`SweepResult`, mean/CI reductions + CSV/JSON artifacts
  sweep    — the CLI: ``python -m repro.experiments.sweep``

`repro.core.api.run_study`, `benchmarks/`, and `examples/` are thin
consumers of this layer.
"""
from repro.experiments.results import SweepResult
from repro.experiments.runner import run_sweep, simulate_sweep
from repro.experiments.spec import SweepSpec, parse_rates, replace

__all__ = [
    "SweepResult",
    "SweepSpec",
    "parse_rates",
    "replace",
    "run_sweep",
    "simulate_sweep",
]
