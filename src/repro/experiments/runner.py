"""Batched Monte-Carlo sweep execution.

One sweep = one trace stack + one jitted computation. The trace stack is
the full (rates x reps) grid from ``Scenario.stack`` (every heuristic sees
identical traces — the paper's paired-comparison design; the scenario
resolves through the :mod:`repro.scenarios` registry). The jitted
computation contains one vmapped ``lax.while_loop`` simulator per
heuristic over the flattened grid, so the whole experiment is a single XLA
program and a single dispatch:

    Metrics leaves come back with shape (H, R, K, ...) for H heuristics,
    R rates, K replicates — and so does every leaf of the observer aux
    when the spec attaches engine observers (:mod:`repro.core.observe`):
    telemetry rides inside the same jitted program, never a second pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.types import SystemSpec, Trace
from repro.experiments.results import SweepResult
from repro.experiments.spec import SweepSpec

# Trace-time observability: one (heuristic, scenario label, dispatcher
# label, dynamics label, network label) entry is appended each time a
# per-heuristic simulator body is *traced* (not dispatched). Tests read
# this to pin the single-jit contract — every (policy, dispatcher,
# dynamics, network, scenario) tuple of a sweep must trace exactly once
# inside one XLA program. Bounded to the most recent entries so
# long-lived processes don't accumulate.
_TRACE_LOG: list = []
_TRACE_LOG_MAX = 256


def _select_fns(names, use_pallas: bool, use_pallas_map: bool = False):
    """Resolve policy names through the registry, with the Pallas toggles.

    When ``use_pallas`` is set, every policy whose nominator has a fused
    Phase-I hook (built-ins: ELARE/FELARE) is swapped onto the Pallas
    ``phase1_map`` kernel nominator; other policies are unaffected.
    ``use_pallas_map`` instead fuses the whole map decision
    (``policy.with_pallas_map``); applied after the phase1 toggle, it
    wins wherever both could apply (the fused kernel subsumes phase1).
    """
    pols = [policy.get(name) for name in names]
    if use_pallas:
        pols = [policy.with_pallas_phase1(p) for p in pols]
    if use_pallas_map:
        pols = [policy.with_pallas_map(p) for p in pols]
    return pols


def simulate_sweep(traces: Trace, system: SystemSpec, heuristic_names,
                   *, use_pallas_phase1: bool = False,
                   use_pallas_map: bool = False,
                   max_steps=None, trace_label: str = "",
                   observers=(), dispatcher=None, dynamics=None,
                   network=None, shard: bool = False):
    """Simulate a flat batch of traces under every heuristic, in one jit.

    Args:
      traces: a Trace whose leaves have one flat leading batch dim B
        (e.g. the flattened (R*K) stack from ``Scenario.stack``).
      system: the SystemSpec to simulate; its ``site_of_machine``
        partition (if any) federates the machines into sites.
      heuristic_names: sequence of H heuristic names.
      use_pallas_phase1: route ELARE Phase-I through the Pallas kernel.
      use_pallas_map: fuse the whole map decision into the Pallas
        ``map_fused`` kernel for every policy in its kind space, and the
        dispatcher's balance scan into the fused scan kernel — bit-exact
        with the lax path (``tests/test_map_fused.py``).
      max_steps: optional per-trace event cap (``None`` = engine default).
      trace_label: annotation recorded next to each heuristic in the
        module's trace log (``run_sweep`` passes the scenario name).
      observers: engine observers — registered names or
        :class:`repro.core.observe.Observer` instances. They ride inside
        the same single jit (closed over statically: attaching observers
        adds zero retraces).
      dispatcher: the federation site-selection rule — a registered name
        or :class:`repro.core.dispatch.Dispatcher` instance (``None`` =
        the default ``sticky``; inert on single-site systems). Closed
        over statically like the policies: one trace per
        (policy, dispatcher, dynamics, scenario) tuple.
      dynamics: the machine-failure process — a registered
        :mod:`repro.core.faults` name or
        :class:`repro.core.faults.MachineDynamics` instance
        (``None``/``"none"`` = no failures, bit-exact with pre-faults
        sweeps). Closed over statically like the policies.
      network: the edge-cloud transfer-cost model — a registered
        :mod:`repro.core.network` name or
        :class:`repro.core.network.NetworkModel` instance
        (``None``/``"none"`` = free instantaneous links, bit-exact with
        pre-network sweeps). Closed over statically like the policies.
      shard: split the trace batch across every visible device with
        ``jax.shard_map`` (``repro.distributed.sharding.sweep_mesh``) —
        each device simulates its slice of the batch; the batch is
        padded to the device count and the padding sliced back off, so
        results are *bit-identical* to the unsharded path. With a single
        visible device this falls back to the plain path silently.

    Returns:
      With ``observers=()``: Metrics with leaves of shape (H, B, ...) —
      axis 0 follows ``heuristic_names`` order, axis 1 the trace batch.
      With observers: ``(Metrics, aux)`` where ``aux`` maps observer name
      to its pytree with the same (H, B, ...) leading dims.
    """
    from repro.core import dispatch as dispatch_mod
    from repro.core import faults as faults_mod
    from repro.core import observe

    obs = observe.resolve(observers)
    disp = dispatch_mod.resolve(dispatcher)
    if use_pallas_map:
        disp = dispatch_mod.with_pallas_balance(disp)
    disp_label = (dispatcher if isinstance(dispatcher, str)
                  else getattr(disp, "kind", type(disp).__name__))
    dyn = faults_mod.resolve(dynamics)
    dyn_label = (dynamics if isinstance(dynamics, str)
                 else getattr(dyn, "kind", type(dyn).__name__))
    from repro.core import network as network_mod

    net = network_mod.resolve(network)
    net_label = (network if isinstance(network, str)
                 else getattr(net, "kind", type(net).__name__))
    sysarr = system.as_jax()
    sims = [
        engine.make_simulator(
            fn, sysarr, queue_size=system.queue_size,
            fairness_factor=float(system.fairness_factor),
            max_steps=max_steps, observers=obs,
            dispatcher=disp, site_of_machine=system.sites,
            dynamics=dyn, network=net,
            tier_of_site=getattr(system, "tiers", None),
        )
        for fn in _select_fns(heuristic_names, use_pallas_phase1,
                              use_pallas_map)
    ]

    def run_all(tr):
        per_h = []
        for name, sim in zip(heuristic_names, sims):
            _TRACE_LOG.append(
                (name, trace_label, disp_label, dyn_label,
                 net_label))  # trace-time
            per_h.append(jax.vmap(sim)(tr))
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_h)

    mesh = None
    if shard:
        from repro.distributed import sharding

        mesh = sharding.sweep_mesh()
    if mesh is None:
        out = jax.jit(run_all)(traces)
    else:
        from jax.sharding import PartitionSpec as P

        from repro.distributed import sharding

        B = traces.arrival.shape[0]
        padded = sharding.pad_batch(traces, mesh.devices.size)
        sharded = jax.jit(jax.shard_map(
            run_all, mesh=mesh,
            in_specs=P(sharding.SWEEP_AXIS),
            out_specs=P(None, sharding.SWEEP_AXIS),
        ))
        out = jax.tree.map(lambda x: x[:, :B], sharded(padded))
    del _TRACE_LOG[:-_TRACE_LOG_MAX]
    return out


def run_sweep(spec: SweepSpec, *, shard: bool = False) -> SweepResult:
    """Execute a full batched Monte-Carlo sweep.

    Resolves the spec's scenario and system through their registries,
    builds the (rates x reps) trace stack under ``PRNGKey(spec.seed)``,
    simulates it under every heuristic in one jitted batch, and wraps the
    raw per-trace Metrics in a :class:`SweepResult` with mean/CI
    reductions.

    Cost scales as H * R * K single-trace simulations of N tasks each;
    the paper-scale grid (5 x 7 x 30 x 2000) runs in one dispatch.
    ``shard=True`` splits the (R*K) trace batch across every visible
    device (``shard_map`` over ``sweep_mesh``) — an execution detail, not
    part of the spec: results are bit-identical to the unsharded sweep
    and the flag is a silent no-op on one device, so a spec remains
    reproducible regardless of the device topology it ran on.
    """
    system = spec.resolve_system()
    scenario = spec.resolve_scenario()
    key = jax.random.PRNGKey(spec.seed)
    stacked = scenario.stack(
        key, spec.rates, spec.reps, spec.n_tasks, system.eet,
        cv_run=spec.cv_run,
    )
    R, K = len(spec.rates), spec.reps
    flat = jax.tree.map(
        lambda x: x.reshape((R * K,) + x.shape[2:]), stacked
    )
    label = (spec.scenario if isinstance(spec.scenario, str)
             else "<custom scenario>")
    observers = spec.resolve_observers()
    out = simulate_sweep(
        flat, system, spec.heuristics,
        use_pallas_phase1=spec.use_pallas_phase1,
        use_pallas_map=spec.use_pallas_map, max_steps=spec.max_steps,
        trace_label=label, observers=observers, dispatcher=spec.dispatcher,
        dynamics=spec.dynamics, network=spec.network, shard=shard,
    )
    metrics, aux = out if observers else (out, {})
    H = len(spec.heuristics)
    unflatten = lambda x: x.reshape((H, R, K) + x.shape[2:])
    metrics = jax.tree.map(unflatten, metrics)
    aux = jax.tree.map(unflatten, aux)
    return SweepResult.from_metrics(spec, system, metrics, aux=aux)
