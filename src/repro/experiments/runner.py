"""Batched Monte-Carlo sweep execution.

One sweep = one trace stack + one jitted computation. The trace stack is
the full (rates x reps) grid from :func:`repro.datapipe.synthetic.trace_stack`
(every heuristic sees identical traces — the paper's paired-comparison
design). The jitted computation contains one vmapped
``lax.while_loop`` simulator per heuristic over the flattened grid, so the
whole experiment is a single XLA program and a single dispatch:

    Metrics leaves come back with shape (H, R, K, ...) for H heuristics,
    R rates, K replicates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import engine, policy
from repro.core.types import Metrics, SystemSpec, Trace
from repro.datapipe import synthetic
from repro.experiments.results import SweepResult
from repro.experiments.spec import SweepSpec


def _select_fns(names, use_pallas: bool):
    """Resolve policy names through the registry, with the Pallas toggle.

    When ``use_pallas`` is set, every policy whose nominator has a fused
    Phase-I hook (built-ins: ELARE/FELARE) is swapped onto the Pallas
    ``phase1_map`` kernel nominator; other policies are unaffected.
    """
    pols = [policy.get(name) for name in names]
    if use_pallas:
        pols = [policy.with_pallas_phase1(p) for p in pols]
    return pols


def simulate_sweep(traces: Trace, system: SystemSpec, heuristic_names,
                   *, use_pallas_phase1: bool = False,
                   max_steps=None) -> Metrics:
    """Simulate a flat batch of traces under every heuristic, in one jit.

    Args:
      traces: a Trace whose leaves have one flat leading batch dim B
        (e.g. the flattened (R*K) stack from ``trace_stack``).
      system: the SystemSpec to simulate.
      heuristic_names: sequence of H heuristic names.
      use_pallas_phase1: route ELARE Phase-I through the Pallas kernel.
      max_steps: optional per-trace event cap (``None`` = engine default).

    Returns:
      Metrics with leaves of shape (H, B, ...): axis 0 follows
      ``heuristic_names`` order, axis 1 the trace batch.
    """
    sysarr = system.as_jax()
    sims = [
        engine.make_simulator(
            fn, sysarr, queue_size=system.queue_size,
            fairness_factor=float(system.fairness_factor),
            max_steps=max_steps,
        )
        for fn in _select_fns(heuristic_names, use_pallas_phase1)
    ]

    @jax.jit
    def run_all(tr):
        per_h = [jax.vmap(sim)(tr) for sim in sims]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per_h)

    return run_all(traces)


def run_sweep(spec: SweepSpec) -> SweepResult:
    """Execute a full batched Monte-Carlo sweep.

    Builds the (rates x reps) trace stack under ``PRNGKey(spec.seed)``,
    simulates it under every heuristic in one jitted batch, and wraps the
    raw per-trace Metrics in a :class:`SweepResult` with mean/CI reductions.

    Cost scales as H * R * K single-trace simulations of N tasks each;
    the paper-scale grid (5 x 7 x 30 x 2000) runs in one dispatch.
    """
    system = spec.resolve_system()
    key = jax.random.PRNGKey(spec.seed)
    stacked = synthetic.trace_stack(
        key, spec.rates, spec.reps, spec.n_tasks, system.eet,
        cv_run=spec.cv_run,
    )
    R, K = len(spec.rates), spec.reps
    flat = jax.tree.map(
        lambda x: x.reshape((R * K,) + x.shape[2:]), stacked
    )
    metrics = simulate_sweep(
        flat, system, spec.heuristics,
        use_pallas_phase1=spec.use_pallas_phase1, max_steps=spec.max_steps,
    )
    H = len(spec.heuristics)
    metrics = jax.tree.map(
        lambda x: x.reshape((H, R, K) + x.shape[2:]), metrics
    )
    return SweepResult.from_metrics(spec, system, metrics)
