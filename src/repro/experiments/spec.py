"""Sweep configuration: what to simulate, over which grid, with which engine.

A :class:`SweepSpec` fully determines a batched Monte-Carlo experiment —
(system, scenario, arrival rates, replicates, heuristics, seed) — so a
sweep is reproducible from its spec alone and the spec can be serialized
next to the result artifacts (and, via :meth:`SweepSpec.from_json_dict`,
re-run *from* them).

Both open-ended axes resolve through registries: heuristic names through
:mod:`repro.core.policy`, scenario names through :mod:`repro.scenarios`,
and system names through the fleet-builder registry
(:func:`repro.scenarios.list_fleets`) — ``"paper"``/``"aws"`` are just the
two built-in fleets, not special-cased literals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core.types import SystemSpec

DEFAULT_HEURISTICS = ("MM", "MSD", "MMU", "ELARE", "FELARE")
DEFAULT_RATES = (2.0, 3.0, 4.0, 6.0, 8.0)


def parse_rates(text: str) -> tuple[float, ...]:
    """Parse a CLI rate grid.

    Two forms are accepted:
      * ``"a,b,c"`` — an explicit comma-separated list: ``"1,2,4.5"``.
      * ``"start:stop:step"`` — an inclusive range: ``"30:90:10"`` is
        (30, 40, 50, 60, 70, 80, 90). ``"start:stop"`` uses step 1.
    """
    text = text.strip()
    if ":" in text:
        parts = [float(p) for p in text.split(":")]
        if len(parts) == 2:
            start, stop, step = parts[0], parts[1], 1.0
        elif len(parts) == 3:
            start, stop, step = parts
        else:
            raise ValueError(f"bad rate range {text!r}; want start:stop[:step]")
        if step <= 0:
            raise ValueError(f"rate step must be positive, got {step}")
        out = []
        r = start
        # inclusive end, tolerant of float accumulation
        while r <= stop + 1e-9:
            out.append(round(r, 9))
            r += step
        return tuple(out)
    return tuple(float(p) for p in text.split(",") if p.strip())


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A batched Monte-Carlo sweep over (rates x replicates x heuristics).

    Attributes:
      system: which HEC system to simulate — a registered fleet-builder
        name (built-ins: ``"paper"``, ``"aws"``, ``"cvb"``, ``"range"``;
        see :func:`repro.scenarios.list_fleets`), a custom
        :class:`~repro.core.types.SystemSpec`, or ``None`` to defer to the
        scenario's own fleet (falling back to ``"paper"`` for scenarios
        without one).
      scenario: the workload recipe — a registered scenario name
        (built-ins: ``"poisson"``, ``"bursty"``, ``"diurnal"``,
        ``"flash-crowd"``, ...; see
        :func:`repro.scenarios.list_scenarios`) or a custom
        :class:`repro.scenarios.Scenario`.
      rates: R nominal arrival rates (tasks/sec).
      reps: K i.i.d. workload traces per rate (the paper uses 30).
      n_tasks: N tasks per trace (the paper uses 2000).
      heuristics: mapping-policy names resolved through the
        :mod:`repro.core.policy` registry — built-ins and any policy the
        caller has ``policy.register``-ed.
      seed: PRNG seed; the sweep consumes exactly one
        ``jax.random.PRNGKey(seed)``.
      cv_run: coefficient of variation of actual runtimes around the EET
        (scenario runtime models carrying their own dispersion ignore it).
      queue_size: per-machine local-queue slots; ``None`` keeps the
        system's own value.
      fairness_factor: Eq. 3's ``f``; ``None`` keeps the system's value.
      use_pallas_phase1: route Phase-I through the fused Pallas kernel
        (`repro.kernels.phase1_map`) for every policy whose nominator has a
        fused-implementation hook (built-ins: ELARE and FELARE); other
        policies are unaffected.
      use_pallas_map: route the *whole* map decision (Phase-I + Phase-II
        + drop + fairness eviction stats) through the fused Pallas kernel
        (`repro.kernels.map_fused`) for every policy inside the kernel's
        kind space (all 8 built-ins and their fairness/backup wrappers),
        and the dispatcher's balance scan through the fused scan kernel;
        bit-exact with the lax path. Mutually composable with
        ``use_pallas_phase1`` (the map kernel wins where both apply).
      max_steps: optional hard cap on simulator events per trace (mostly
        for tests); ``None`` uses the engine default of ``8 * N + 64``.
      observers: engine observers to attach — registered names
        (built-ins: ``"timeline"``, ``"fairness_trajectory"``,
        ``"task_log"``, ``"energy_budget"``; see
        :func:`repro.core.observe.list_observers`) or
        :class:`repro.core.observe.Observer` instances. Their time-resolved
        aux pytrees come back on :attr:`SweepResult.aux` stacked under the
        same (H, R, K) batch dims as the metrics; with ``()`` the sweep is
        bit-identical to an unobserved one.
      dispatcher: the federation's site-selection rule — a registered
        dispatcher name (built-ins: ``"sticky"``, ``"round_robin"``,
        ``"least_queued"``, ``"min_eet"``, ``"fair_spill"``; see
        :func:`repro.core.dispatch.list_dispatchers`) or a
        :class:`repro.core.dispatch.Dispatcher` instance. Only relevant
        when the resolved system partitions its machines into sites
        (``SystemSpec.site_of_machine``); single-site systems bypass the
        dispatch stage entirely, so the default ``"sticky"`` keeps flat
        sweeps bit-identical to pre-federation ones.
      dynamics: the machine-failure process — a registered dynamics name
        (built-ins: ``"none"``, ``"bernoulli_updown"``, ``"site_outage"``,
        ``"degrade"``; see :func:`repro.core.faults.list_dynamics`) or a
        :class:`repro.core.faults.MachineDynamics` instance. The default
        ``"none"`` skips the engine's faults stage entirely and is
        bit-exact with pre-faults sweeps.
      network: the edge-cloud transfer-cost model — a registered network
        name (built-ins: ``"none"``, ``"uniform_latency"``, ``"tiered"``;
        see :func:`repro.core.network.list_networks`) or a
        :class:`repro.core.network.NetworkModel` instance. The default
        ``"none"`` skips the engine's transfer arithmetic entirely and
        is bit-exact with pre-network sweeps.
    """

    system: Union[str, SystemSpec, None] = None
    rates: tuple[float, ...] = DEFAULT_RATES
    reps: int = 8
    n_tasks: int = 400
    heuristics: tuple[str, ...] = DEFAULT_HEURISTICS
    seed: int = 0
    cv_run: float = 0.1
    queue_size: Optional[int] = None
    fairness_factor: Optional[float] = None
    use_pallas_phase1: bool = False
    use_pallas_map: bool = False
    max_steps: Optional[int] = None
    scenario: Union[str, "object"] = "poisson"  # name or scenarios.Scenario
    observers: tuple = ()  # names or observe.Observer instances
    dispatcher: Union[str, "object"] = "sticky"  # name or dispatch.Dispatcher
    dynamics: Union[str, "object"] = "none"  # name or faults.MachineDynamics
    network: Union[str, "object"] = "none"  # name or network.NetworkModel

    def __post_init__(self):
        object.__setattr__(self, "rates",
                           tuple(float(r) for r in self.rates))
        object.__setattr__(self, "heuristics",
                           tuple(h.upper() for h in self.heuristics))
        if self.reps < 1:
            raise ValueError("reps must be >= 1")
        if self.n_tasks < 1:
            raise ValueError("n_tasks must be >= 1")
        if not self.rates:
            raise ValueError("rates must be non-empty")
        if not self.heuristics:
            raise ValueError("heuristics must be non-empty")
        from repro import scenarios
        from repro.core import policy

        unknown = [h for h in self.heuristics if not policy.is_registered(h)]
        if unknown:
            raise ValueError(
                f"unknown heuristics {unknown}; "
                f"choose from {policy.list_policies()} "
                f"(or policy.register(...) your own)"
            )
        if isinstance(self.scenario, str):
            if not scenarios.is_registered(self.scenario):
                raise ValueError(
                    f"unknown scenario {self.scenario!r}; "
                    f"choose from {scenarios.list_scenarios()} "
                    f"(or scenarios.register(...) your own)"
                )
        elif not isinstance(self.scenario, scenarios.Scenario):
            raise ValueError(
                f"scenario must be a registered name or a "
                f"scenarios.Scenario, got {self.scenario!r}"
            )
        from repro.core import dispatch

        if isinstance(self.dispatcher, str):
            name = self.dispatcher.strip().lower()
            if not dispatch.is_registered(name):
                raise ValueError(
                    f"unknown dispatcher {self.dispatcher!r}; "
                    f"choose from {dispatch.list_dispatchers()} "
                    f"(or dispatch.register(...) your own)"
                )
            object.__setattr__(self, "dispatcher", name)
        elif not callable(getattr(self.dispatcher, "dispatch", None)):
            raise ValueError(
                f"dispatcher must be a registered name or a "
                f"dispatch.Dispatcher, got {self.dispatcher!r}"
            )
        from repro.core import faults

        if isinstance(self.dynamics, str):
            name = self.dynamics.strip().lower()
            if not faults.is_registered(name):
                raise ValueError(
                    f"unknown dynamics {self.dynamics!r}; "
                    f"choose from {faults.list_dynamics()} "
                    f"(or faults.register(...) your own)"
                )
            object.__setattr__(self, "dynamics", name)
        elif not callable(getattr(self.dynamics, "step", None)):
            raise ValueError(
                f"dynamics must be a registered name or a "
                f"faults.MachineDynamics, got {self.dynamics!r}"
            )
        from repro.core import network

        if isinstance(self.network, str):
            name = self.network.strip().lower()
            if not network.is_registered(name):
                raise ValueError(
                    f"unknown network {self.network!r}; "
                    f"choose from {network.list_networks()} "
                    f"(or network.register(...) your own)"
                )
            object.__setattr__(self, "network", name)
        elif not callable(getattr(self.network, "cost_tables", None)):
            raise ValueError(
                f"network must be a registered name or a "
                f"network.NetworkModel, got {self.network!r}"
            )
        from repro.core import observe

        obs = []
        for ob in (self.observers if not isinstance(self.observers, str)
                   else (self.observers,)):
            if isinstance(ob, str):
                name = ob.strip().lower()
                if not observe.is_registered(name):
                    raise ValueError(
                        f"unknown observer {ob!r}; "
                        f"choose from {observe.list_observers()} "
                        f"(or observe.register(...) your own)"
                    )
                obs.append(name)
            else:
                try:  # one protocol check: the registry's
                    observe.resolve((ob,))
                except TypeError as e:
                    raise ValueError(str(e)) from None
                obs.append(ob)
        object.__setattr__(self, "observers", tuple(obs))

    @property
    def n_simulations(self) -> int:
        """Total single-trace simulations the sweep performs."""
        return len(self.heuristics) * len(self.rates) * self.reps

    def resolve_scenario(self):
        """Materialize the :class:`repro.scenarios.Scenario`."""
        from repro import scenarios

        if isinstance(self.scenario, scenarios.Scenario):
            return self.scenario
        return scenarios.get(str(self.scenario))

    def resolve_observers(self) -> tuple:
        """Materialize the :class:`repro.core.observe.Observer` tuple."""
        from repro.core import observe

        return observe.resolve(self.observers)

    def resolve_dispatcher(self):
        """Materialize the :class:`repro.core.dispatch.Dispatcher`."""
        from repro.core import dispatch

        return dispatch.resolve(self.dispatcher)

    def resolve_dynamics(self):
        """Materialize the :class:`repro.core.faults.MachineDynamics`."""
        from repro.core import faults

        return faults.resolve(self.dynamics)

    def resolve_network(self):
        """Materialize the :class:`repro.core.network.NetworkModel`."""
        from repro.core import network

        return network.resolve(self.network)

    def resolve_system(self) -> SystemSpec:
        """Materialize the SystemSpec, applying queue/fairness overrides.

        Precedence: an explicit ``SystemSpec`` or fleet name always wins;
        ``system=None`` uses the scenario's own fleet builder, or the
        paper system when the scenario carries none.
        """
        from repro import scenarios

        if isinstance(self.system, SystemSpec):
            sys_spec = self.system
        elif self.system is None:
            fleet = self.resolve_scenario().fleet
            if fleet is None:
                fleet = scenarios.get_fleet("paper")
            sys_spec = fleet.build()
        else:
            try:
                sys_spec = scenarios.get_fleet(str(self.system)).build()
            except KeyError:
                raise ValueError(
                    f"unknown system {self.system!r}; choose from "
                    f"{scenarios.list_fleets()} or pass a SystemSpec"
                ) from None
        overrides = {}
        if self.queue_size is not None:
            overrides["queue_size"] = int(self.queue_size)
        if self.fairness_factor is not None:
            overrides["fairness_factor"] = float(self.fairness_factor)
        if overrides:
            sys_spec = dataclasses.replace(sys_spec, **overrides)
        return sys_spec

    def to_json_dict(self) -> dict:
        """JSON-serializable form; inverse of :meth:`from_json_dict`.

        Custom SystemSpecs record their full shape; a scenario serializes
        as its registry name when given by name, else as the structured
        component form from ``Scenario.to_json_dict``.
        """
        if isinstance(self.system, SystemSpec):
            system = {
                "eet": [[float(x) for x in row] for row in self.system.eet],
                "p_dyn": [float(x) for x in self.system.p_dyn],
                "p_idle": [float(x) for x in self.system.p_idle],
                "queue_size": self.system.queue_size,
                "fairness_factor": self.system.fairness_factor,
            }
            if self.system.site_of_machine is not None:
                system["site_of_machine"] = list(self.system.site_of_machine)
            if self.system.tier_of_site is not None:
                system["tier_of_site"] = list(self.system.tier_of_site)
        else:
            system = self.system
        scenario = (self.scenario if isinstance(self.scenario, str)
                    else self.scenario.to_json_dict())
        from repro.core import dispatch

        dispatcher = (self.dispatcher if isinstance(self.dispatcher, str)
                      else dispatch.to_json_dict(self.dispatcher))
        from repro.core import faults

        dynamics = (self.dynamics if isinstance(self.dynamics, str)
                    else faults.to_json_dict(self.dynamics))
        from repro.core import network as network_mod

        network = (self.network if isinstance(self.network, str)
                   else network_mod.to_json_dict(self.network))
        observers = []
        for ob in self.observers:
            if isinstance(ob, str):
                observers.append(ob)
            elif hasattr(ob, "to_json_dict"):
                observers.append(ob.to_json_dict())
            else:
                raise ValueError(
                    f"observer {ob!r} has no to_json_dict; register it and "
                    f"pass the name to make the spec serializable"
                )
        return {
            "system": system,
            "scenario": scenario,
            "observers": observers,
            "dispatcher": dispatcher,
            "dynamics": dynamics,
            "network": network,
            "rates": list(self.rates),
            "reps": self.reps,
            "n_tasks": self.n_tasks,
            "heuristics": list(self.heuristics),
            "seed": self.seed,
            "cv_run": self.cv_run,
            "queue_size": self.queue_size,
            "fairness_factor": self.fairness_factor,
            "use_pallas_phase1": self.use_pallas_phase1,
            "use_pallas_map": self.use_pallas_map,
            "max_steps": self.max_steps,
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> "SweepSpec":
        """Rebuild a spec from :meth:`to_json_dict` output — i.e. from the
        ``"spec"`` block of a saved ``sweep.json`` artifact, so any sweep
        can be re-run from its own artifact."""
        from repro import scenarios

        d = dict(d)
        system = d.get("system")
        if isinstance(system, dict):
            sites = system.get("site_of_machine")
            tiers = system.get("tier_of_site")
            system = SystemSpec(
                eet=np.asarray(system["eet"], np.float32),
                p_dyn=np.asarray(system["p_dyn"], np.float32),
                p_idle=np.asarray(system["p_idle"], np.float32),
                queue_size=int(system.get("queue_size", 2)),
                fairness_factor=float(system.get("fairness_factor", 1.0)),
                site_of_machine=None if sites is None else tuple(sites),
                tier_of_site=None if tiers is None else tuple(tiers),
            )
        scenario = d.get("scenario", "poisson")
        if isinstance(scenario, dict):
            scenario = scenarios.Scenario.from_json_dict(scenario)
        from repro.core import dispatch, observe

        observers = tuple(
            observe.from_json_dict(ob) if isinstance(ob, dict) else ob
            for ob in d.get("observers", ())
        )
        dispatcher = d.get("dispatcher", "sticky")
        if isinstance(dispatcher, dict):
            dispatcher = dispatch.from_json_dict(dispatcher)
        from repro.core import faults

        dynamics = d.get("dynamics", "none")
        if isinstance(dynamics, dict):
            dynamics = faults.from_json_dict(dynamics)
        from repro.core import network as network_mod

        network = d.get("network", "none")  # old payloads: free links
        if isinstance(network, dict):
            network = network_mod.from_json_dict(network)
        return cls(
            system=system,
            scenario=scenario,
            observers=observers,
            dispatcher=dispatcher,
            dynamics=dynamics,
            network=network,
            rates=tuple(d["rates"]),
            reps=int(d["reps"]),
            n_tasks=int(d["n_tasks"]),
            heuristics=tuple(d["heuristics"]),
            seed=int(d["seed"]),
            cv_run=float(d["cv_run"]),
            queue_size=d.get("queue_size"),
            fairness_factor=d.get("fairness_factor"),
            use_pallas_phase1=bool(d.get("use_pallas_phase1", False)),
            use_pallas_map=bool(d.get("use_pallas_map", False)),
            max_steps=d.get("max_steps"),
        )


def replace(spec: SweepSpec, **kwargs) -> SweepSpec:
    """``dataclasses.replace`` re-exported for fluent spec tweaking."""
    return dataclasses.replace(spec, **kwargs)
