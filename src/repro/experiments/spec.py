"""Sweep configuration: what to simulate, over which grid, with which engine.

A :class:`SweepSpec` fully determines a batched Monte-Carlo experiment —
(system, arrival rates, replicates, heuristics, seed) — so a sweep is
reproducible from its spec alone and the spec can be serialized next to the
result artifacts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from repro.core.types import SystemSpec

DEFAULT_HEURISTICS = ("MM", "MSD", "MMU", "ELARE", "FELARE")
DEFAULT_RATES = (2.0, 3.0, 4.0, 6.0, 8.0)


def parse_rates(text: str) -> tuple[float, ...]:
    """Parse a CLI rate grid.

    Two forms are accepted:
      * ``"a,b,c"`` — an explicit comma-separated list: ``"1,2,4.5"``.
      * ``"start:stop:step"`` — an inclusive range: ``"30:90:10"`` is
        (30, 40, 50, 60, 70, 80, 90). ``"start:stop"`` uses step 1.
    """
    text = text.strip()
    if ":" in text:
        parts = [float(p) for p in text.split(":")]
        if len(parts) == 2:
            start, stop, step = parts[0], parts[1], 1.0
        elif len(parts) == 3:
            start, stop, step = parts
        else:
            raise ValueError(f"bad rate range {text!r}; want start:stop[:step]")
        if step <= 0:
            raise ValueError(f"rate step must be positive, got {step}")
        out = []
        r = start
        # inclusive end, tolerant of float accumulation
        while r <= stop + 1e-9:
            out.append(round(r, 9))
            r += step
        return tuple(out)
    return tuple(float(p) for p in text.split(",") if p.strip())


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A batched Monte-Carlo sweep over (rates x replicates x heuristics).

    Attributes:
      system: ``"paper"`` (the Sec. VI-A synthetic 4x4 system), ``"aws"``
        (the t2.xlarge/g3s.xlarge FaceNet/DeepSpeech scenario), or a custom
        :class:`~repro.core.types.SystemSpec`.
      rates: R Poisson arrival rates (tasks/sec).
      reps: K i.i.d. workload traces per rate (the paper uses 30).
      n_tasks: N tasks per trace (the paper uses 2000).
      heuristics: mapping-policy names resolved through the
        :mod:`repro.core.policy` registry — built-ins and any policy the
        caller has ``policy.register``-ed.
      seed: PRNG seed; the sweep consumes exactly one
        ``jax.random.PRNGKey(seed)``.
      cv_run: coefficient of variation of actual runtimes around the EET.
      queue_size: per-machine local-queue slots; ``None`` keeps the
        system's own value.
      fairness_factor: Eq. 3's ``f``; ``None`` keeps the system's value.
      use_pallas_phase1: route Phase-I through the fused Pallas kernel
        (`repro.kernels.phase1_map`) for every policy whose nominator has a
        fused-implementation hook (built-ins: ELARE and FELARE); other
        policies are unaffected.
      max_steps: optional hard cap on simulator events per trace (mostly
        for tests); ``None`` uses the engine default of ``8 * N + 64``.
    """

    system: Union[str, SystemSpec] = "paper"
    rates: tuple[float, ...] = DEFAULT_RATES
    reps: int = 8
    n_tasks: int = 400
    heuristics: tuple[str, ...] = DEFAULT_HEURISTICS
    seed: int = 0
    cv_run: float = 0.1
    queue_size: Optional[int] = None
    fairness_factor: Optional[float] = None
    use_pallas_phase1: bool = False
    max_steps: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "rates",
                           tuple(float(r) for r in self.rates))
        object.__setattr__(self, "heuristics",
                           tuple(h.upper() for h in self.heuristics))
        if self.reps < 1:
            raise ValueError("reps must be >= 1")
        if self.n_tasks < 1:
            raise ValueError("n_tasks must be >= 1")
        if not self.rates:
            raise ValueError("rates must be non-empty")
        if not self.heuristics:
            raise ValueError("heuristics must be non-empty")
        from repro.core import policy

        unknown = [h for h in self.heuristics if not policy.is_registered(h)]
        if unknown:
            raise ValueError(
                f"unknown heuristics {unknown}; "
                f"choose from {policy.list_policies()} "
                f"(or policy.register(...) your own)"
            )

    @property
    def n_simulations(self) -> int:
        """Total single-trace simulations the sweep performs."""
        return len(self.heuristics) * len(self.rates) * self.reps

    def resolve_system(self) -> SystemSpec:
        """Materialize the SystemSpec, applying queue/fairness overrides."""
        if isinstance(self.system, SystemSpec):
            sys_spec = self.system
        else:
            from repro.core import api  # local import: api consumes us too

            builders = {"paper": api.paper_system, "aws": api.aws_system}
            try:
                sys_spec = builders[str(self.system).lower()]()
            except KeyError:
                raise ValueError(
                    f"unknown system {self.system!r}; "
                    f"choose from {sorted(builders)} or pass a SystemSpec"
                ) from None
        overrides = {}
        if self.queue_size is not None:
            overrides["queue_size"] = int(self.queue_size)
        if self.fairness_factor is not None:
            overrides["fairness_factor"] = float(self.fairness_factor)
        if overrides:
            sys_spec = dataclasses.replace(sys_spec, **overrides)
        return sys_spec

    def to_json_dict(self) -> dict:
        """JSON-serializable form (custom SystemSpecs record their shape)."""
        if isinstance(self.system, SystemSpec):
            system = {
                "eet": [[float(x) for x in row] for row in self.system.eet],
                "p_dyn": [float(x) for x in self.system.p_dyn],
                "p_idle": [float(x) for x in self.system.p_idle],
                "queue_size": self.system.queue_size,
                "fairness_factor": self.system.fairness_factor,
            }
        else:
            system = self.system
        return {
            "system": system,
            "rates": list(self.rates),
            "reps": self.reps,
            "n_tasks": self.n_tasks,
            "heuristics": list(self.heuristics),
            "seed": self.seed,
            "cv_run": self.cv_run,
            "queue_size": self.queue_size,
            "fairness_factor": self.fairness_factor,
            "use_pallas_phase1": self.use_pallas_phase1,
            "max_steps": self.max_steps,
        }


def replace(spec: SweepSpec, **kwargs) -> SweepSpec:
    """``dataclasses.replace`` re-exported for fluent spec tweaking."""
    return dataclasses.replace(spec, **kwargs)
