"""One-command batched Monte-Carlo sweep CLI.

    PYTHONPATH=src python -m repro.experiments.sweep \
        --system paper --rates 2,3,4,6,8 --reps 8 --tasks 400 \
        --heuristics MM,MSD,MMU,ELARE,FELARE --out artifacts/sweep

Rates accept either a comma list (``2,3,4.5``) or an inclusive
``start:stop:step`` range (``30:90:10``). The sweep runs all
(rate x replicate x heuristic) simulations as one jitted batch, prints the
per-cell summary table, and writes ``sweep.csv`` + ``sweep.json`` under
``--out``.

``--heuristics`` accepts any name registered in the
:mod:`repro.core.policy` registry (``--list`` prints them with their
nominator x key x drop composition); unknown names fail fast with the
available-policy list instead of deep inside jit tracing.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core import policy
from repro.experiments.results import SweepResult
from repro.experiments.runner import run_sweep
from repro.experiments.spec import (
    DEFAULT_HEURISTICS,
    DEFAULT_RATES,
    SweepSpec,
    parse_rates,
)


def build_spec(argv=None) -> tuple[SweepSpec, argparse.Namespace]:
    """Parse CLI args into a SweepSpec (exposed for tests)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.sweep",
        description="Batched Monte-Carlo sweep over "
                    "(arrival rates x replicates x heuristics).",
    )
    ap.add_argument("--system", default="paper", choices=["paper", "aws"],
                    help="which HEC system to simulate (default: paper)")
    ap.add_argument("--rates", default=None,
                    help="comma list '2,3,4' or inclusive range "
                         "'start:stop:step' (default: "
                         + ",".join(str(r) for r in DEFAULT_RATES) + ")")
    ap.add_argument("--reps", type=int, default=8,
                    help="replicate traces per rate (default: 8)")
    ap.add_argument("--tasks", type=int, default=400,
                    help="tasks per trace (default: 400; paper uses 2000)")
    ap.add_argument("--heuristics",
                    default=",".join(DEFAULT_HEURISTICS),
                    help="comma list of registered policy names (default: "
                         + ",".join(DEFAULT_HEURISTICS)
                         + "; see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list the registered scheduling policies and exit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cv-run", type=float, default=0.1,
                    help="CV of actual runtimes around the EET (default 0.1)")
    ap.add_argument("--queue-size", type=int, default=None,
                    help="per-machine queue slots (default: system's own)")
    ap.add_argument("--fairness-factor", type=float, default=None,
                    help="Eq. 3 fairness factor f (default: system's own)")
    ap.add_argument("--pallas-phase1", action="store_true",
                    help="route ELARE Phase-I through the Pallas kernel")
    ap.add_argument("--out", default="artifacts/sweep",
                    help="artifact directory (default: artifacts/sweep)")
    args = ap.parse_args(argv)

    if args.list:
        print_policy_list()
        raise SystemExit(0)

    heuristics = tuple(
        h.strip() for h in args.heuristics.split(",") if h.strip()
    )
    # Fail fast on unknown names with the available-policy list, instead of
    # erroring deep inside jit tracing.
    unknown = [h for h in heuristics if not policy.is_registered(h)]
    if unknown:
        ap.error(
            f"unknown heuristics {unknown}; registered policies: "
            + ", ".join(policy.list_policies())
            + " (run with --list for details)"
        )
    try:
        rates = parse_rates(args.rates) if args.rates else DEFAULT_RATES
        spec = SweepSpec(
            system=args.system,
            rates=rates,
            reps=args.reps,
            n_tasks=args.tasks,
            heuristics=heuristics,
            seed=args.seed,
            cv_run=args.cv_run,
            queue_size=args.queue_size,
            fairness_factor=args.fairness_factor,
            use_pallas_phase1=args.pallas_phase1,
        )
    except ValueError as e:
        ap.error(str(e))  # clean exit 2 instead of a traceback
    return spec, args


def print_policy_list(file=None) -> None:
    """One line per registered policy: name + composition (or 'opaque')."""
    file = file if file is not None else sys.stdout
    print(f"{'name':10s} {'phase-1 nominator':20s} {'phase-2 key':12s} "
          f"{'drop rule':15s} {'fairness':8s}", file=file)
    for name in policy.list_policies():
        try:
            d = policy.describe(name)
            print(f"{name:10s} {d.nominator:20s} {d.phase2_key:12s} "
                  f"{d.drop_rule:15s} {'yes' if d.fairness else 'no':8s}",
                  file=file)
        except TypeError:
            print(f"{name:10s} (opaque custom policy)", file=file)


def print_summary(result: SweepResult, file=None) -> None:
    """Human-readable per-cell table (one line per heuristic x rate)."""
    file = file if file is not None else sys.stdout
    print(f"{'heuristic':9s} {'rate':>6s} {'ontime%':>8s} {'±ci':>6s} "
          f"{'energy':>10s} {'waste%':>7s} {'cancel%':>8s} {'miss%':>6s} "
          f"{'spread':>7s} {'jain':>6s}", file=file)
    for row in result.summary_rows():
        print(f"{row['heuristic']:9s} {row['rate']:6.2f} "
              f"{100 * row['completion_rate']:8.2f} "
              f"{100 * row['completion_rate_ci95']:6.2f} "
              f"{row['energy']:10.1f} {row['wasted_pct']:7.2f} "
              f"{row['cancelled_pct']:8.2f} {row['missed_pct']:6.2f} "
              f"{row['fairness_spread']:7.4f} {row['jain_index']:6.4f}",
              file=file)


def main(argv=None) -> SweepResult:
    spec, args = build_spec(argv)
    n = spec.n_simulations
    print(f"sweep: {len(spec.heuristics)} heuristics x "
          f"{len(spec.rates)} rates x {spec.reps} reps "
          f"({n} traces of {spec.n_tasks} tasks) on system={args.system}",
          flush=True)
    t0 = time.perf_counter()
    result = run_sweep(spec)
    dt = time.perf_counter() - t0
    print(f"simulated {n} traces in {dt:.1f}s "
          f"({1e3 * dt / n:.0f} ms/trace incl. compile)\n")
    print_summary(result)
    paths = result.save(args.out)
    print(f"\nwrote {paths['csv']} and {paths['json']}")
    return result


if __name__ == "__main__":
    main()
