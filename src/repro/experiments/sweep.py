"""One-command batched Monte-Carlo sweep CLI.

    PYTHONPATH=src python -m repro.experiments.sweep \
        --system paper --scenario bursty --rates 2,3,4,6,8 --reps 8 \
        --tasks 400 --heuristics MM,MSD,MMU,ELARE,FELARE \
        --out artifacts/sweep

Rates accept either a comma list (``2,3,4.5``) or an inclusive
``start:stop:step`` range (``30:90:10``). The sweep runs all
(rate x replicate x heuristic) simulations as one jitted batch, prints the
per-cell summary table, and writes ``sweep.csv`` + ``sweep.json`` under
``--out``.

Every open-ended axis resolves through a registry and fails fast on
unknown names instead of deep inside jit tracing: ``--heuristics`` through
:mod:`repro.core.policy` (``--list`` prints the nominator x key x drop
compositions), ``--scenario`` through :mod:`repro.scenarios`
(``--list-scenarios`` prints the arrival x mix x deadline x runtime x
fleet compositions), and ``--system`` through the fleet-builder registry.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro import scenarios
from repro.core import dispatch, faults, network, observe, policy
from repro.experiments.results import SweepResult
from repro.experiments.runner import run_sweep
from repro.experiments.spec import (
    DEFAULT_HEURISTICS,
    DEFAULT_RATES,
    SweepSpec,
    parse_rates,
)


def build_spec(argv=None) -> tuple[SweepSpec, argparse.Namespace]:
    """Parse CLI args into a SweepSpec (exposed for tests)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.sweep",
        description="Batched Monte-Carlo sweep over "
                    "(arrival rates x replicates x heuristics).",
    )
    ap.add_argument("--system", default=None,
                    help="which HEC system to simulate: a registered fleet"
                         " builder (see --list-scenarios for the fleet "
                         "list). Default: the scenario's own fleet, or "
                         "'paper'.")
    ap.add_argument("--scenario", default="poisson",
                    help="workload scenario name (default: poisson; see "
                         "--list-scenarios)")
    ap.add_argument("--rates", default=None,
                    help="comma list '2,3,4' or inclusive range "
                         "'start:stop:step' (default: "
                         + ",".join(str(r) for r in DEFAULT_RATES) + ")")
    ap.add_argument("--reps", type=int, default=8,
                    help="replicate traces per rate (default: 8)")
    ap.add_argument("--tasks", type=int, default=400,
                    help="tasks per trace (default: 400; paper uses 2000)")
    ap.add_argument("--heuristics",
                    default=",".join(DEFAULT_HEURISTICS),
                    help="comma list of registered policy names (default: "
                         + ",".join(DEFAULT_HEURISTICS)
                         + "; see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list the registered scheduling policies and exit")
    ap.add_argument("--list-scenarios", action="store_true",
                    help="list the registered workload scenarios and fleet "
                         "builders, then exit")
    ap.add_argument("--dispatcher", default="sticky",
                    help="federation site-selection rule for multi-site "
                         "systems (default: sticky; see --list-dispatchers)."
                         " Inert on single-site systems.")
    ap.add_argument("--list-dispatchers", action="store_true",
                    help="list the registered federation dispatchers and "
                         "exit")
    ap.add_argument("--dynamics", default="none",
                    help="machine-failure process to inject (default: none;"
                         " see --list-dynamics). 'none' is bit-exact with a"
                         " fault-free sweep.")
    ap.add_argument("--list-dynamics", action="store_true",
                    help="list the registered machine dynamics and exit")
    ap.add_argument("--network", default="none",
                    help="edge-cloud transfer-cost model (default: none; "
                         "see --list-networks). 'none' is bit-exact with a "
                         "network-free sweep.")
    ap.add_argument("--list-networks", action="store_true",
                    help="list the registered network models and exit")
    ap.add_argument("--list-fleets", action="store_true",
                    help="list the registered fleet builders and exit")
    ap.add_argument("--observers", default="",
                    help="comma list of registered engine observers to "
                         "attach (e.g. timeline,task_log; see "
                         "--list-observers). Their time-resolved outputs "
                         "are written next to the sweep artifacts.")
    ap.add_argument("--list-observers", action="store_true",
                    help="list the registered engine observers and exit")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cv-run", type=float, default=0.1,
                    help="CV of actual runtimes around the EET (default 0.1)")
    ap.add_argument("--queue-size", type=int, default=None,
                    help="per-machine queue slots (default: system's own)")
    ap.add_argument("--fairness-factor", type=float, default=None,
                    help="Eq. 3 fairness factor f (default: system's own)")
    ap.add_argument("--pallas-phase1", action="store_true",
                    help="route ELARE Phase-I through the Pallas kernel")
    ap.add_argument("--pallas-map", action="store_true",
                    help="fuse the whole map decision (Phase-I + Phase-II "
                         "+ drop + fairness eviction stats) and the "
                         "dispatch balance scan into the Pallas map_fused "
                         "kernels; bit-exact with the lax path")
    ap.add_argument("--shard", action="store_true",
                    help="shard the (rate x replicate) trace batch across "
                         "every visible device (shard_map); bit-identical "
                         "results, silent no-op on a single device")
    ap.add_argument("--out", default="artifacts/sweep",
                    help="artifact directory (default: artifacts/sweep)")
    args = ap.parse_args(argv)

    if args.list:
        print_policy_list()
        raise SystemExit(0)
    if args.list_scenarios:
        print_scenario_list()
        raise SystemExit(0)
    if args.list_observers:
        print_observer_list()
        raise SystemExit(0)
    if args.list_dispatchers:
        print_dispatcher_list()
        raise SystemExit(0)
    if args.list_dynamics:
        print_dynamics_list()
        raise SystemExit(0)
    if args.list_networks:
        print_network_list()
        raise SystemExit(0)
    if args.list_fleets:
        print_fleet_list()
        raise SystemExit(0)

    heuristics = tuple(
        h.strip() for h in args.heuristics.split(",") if h.strip()
    )
    # Fail fast on unknown names with the available lists, instead of
    # erroring deep inside jit tracing.
    unknown = [h for h in heuristics if not policy.is_registered(h)]
    if unknown:
        ap.error(
            f"unknown heuristics {unknown}; registered policies: "
            + ", ".join(policy.list_policies())
            + " (run with --list for details)"
        )
    if not scenarios.is_registered(args.scenario):
        ap.error(
            f"unknown scenario {args.scenario!r}; registered scenarios: "
            + ", ".join(scenarios.list_scenarios())
            + " (run with --list-scenarios for details)"
        )
    if args.system is not None and not scenarios.is_registered_fleet(
            args.system):
        ap.error(
            f"unknown system {args.system!r}; registered fleets: "
            + ", ".join(scenarios.list_fleets())
        )
    if not dispatch.is_registered(args.dispatcher):
        ap.error(
            f"unknown dispatcher {args.dispatcher!r}; registered "
            "dispatchers: " + ", ".join(dispatch.list_dispatchers())
            + " (run with --list-dispatchers for details)"
        )
    if not faults.is_registered(args.dynamics):
        ap.error(
            f"unknown dynamics {args.dynamics!r}; registered dynamics: "
            + ", ".join(faults.list_dynamics())
            + " (run with --list-dynamics for details)"
        )
    if not network.is_registered(args.network):
        ap.error(
            f"unknown network {args.network!r}; registered networks: "
            + ", ".join(network.list_networks())
            + " (run with --list-networks for details)"
        )
    observers = tuple(
        o.strip() for o in args.observers.split(",") if o.strip()
    )
    unknown = [o for o in observers if not observe.is_registered(o)]
    if unknown:
        ap.error(
            f"unknown observers {unknown}; registered observers: "
            + ", ".join(observe.list_observers())
            + " (run with --list-observers for details)"
        )
    try:
        rates = parse_rates(args.rates) if args.rates else DEFAULT_RATES
        spec = SweepSpec(
            system=args.system,
            scenario=args.scenario,
            rates=rates,
            reps=args.reps,
            n_tasks=args.tasks,
            heuristics=heuristics,
            seed=args.seed,
            cv_run=args.cv_run,
            queue_size=args.queue_size,
            fairness_factor=args.fairness_factor,
            use_pallas_phase1=args.pallas_phase1,
            use_pallas_map=args.pallas_map,
            observers=observers,
            dispatcher=args.dispatcher,
            dynamics=args.dynamics,
            network=args.network,
        )
    except ValueError as e:
        ap.error(str(e))  # clean exit 2 instead of a traceback
    return spec, args


def print_policy_list(file=None) -> None:
    """One line per registered policy: name + composition (or 'opaque')."""
    file = file if file is not None else sys.stdout
    print(f"{'name':10s} {'phase-1 nominator':20s} {'phase-2 key':12s} "
          f"{'drop rule':15s} {'fairness':8s}", file=file)
    for name in policy.list_policies():
        try:
            d = policy.describe(name)
            print(f"{name:10s} {d.nominator:20s} {d.phase2_key:12s} "
                  f"{d.drop_rule:15s} {'yes' if d.fairness else 'no':8s}",
                  file=file)
        except TypeError:
            print(f"{name:10s} (opaque custom policy)", file=file)


def print_scenario_list(file=None) -> None:
    """One line per registered scenario: name + component composition,
    then the registered fleet builders."""
    file = file if file is not None else sys.stdout
    print(f"{'scenario':18s} {'arrivals':12s} {'mix':10s} "
          f"{'deadline':10s} {'runtime':11s} {'fleet':8s}", file=file)
    for name in scenarios.list_scenarios():
        d = scenarios.get(name).describe()
        print(f"{name:18s} {d['arrivals']:12s} {d['mix']:10s} "
              f"{d['deadline']:10s} {d['runtime']:11s} {d['fleet']:8s}",
              file=file)
    print(f"\nfleets: {', '.join(scenarios.list_fleets())}", file=file)


def print_observer_list(file=None) -> None:
    """One line per registered engine observer: name + description."""
    file = file if file is not None else sys.stdout
    for name in observe.list_observers():
        print(f"{name:22s} {observe.describe(name)}", file=file)


def print_dispatcher_list(file=None) -> None:
    """One line per registered federation dispatcher: name + description."""
    file = file if file is not None else sys.stdout
    for name in dispatch.list_dispatchers():
        print(f"{name:14s} {dispatch.describe(name)}", file=file)


def print_dynamics_list(file=None) -> None:
    """One line per registered machine dynamics: name + description."""
    file = file if file is not None else sys.stdout
    for name in faults.list_dynamics():
        print(f"{name:18s} {faults.describe(name)}", file=file)


def print_network_list(file=None) -> None:
    """One line per registered network model: name + description."""
    file = file if file is not None else sys.stdout
    for name in network.list_networks():
        print(f"{name:18s} {network.describe(name)}", file=file)


def print_fleet_list(file=None) -> None:
    """One line per registered fleet builder: name, shape, tier layout."""
    file = file if file is not None else sys.stdout
    print(f"{'fleet':14s} {'types':>5s} {'machines':>8s} {'sites':>5s} "
          f"{'tiers':14s}", file=file)
    for name in scenarios.list_fleets():
        spec = scenarios.get_fleet(name).build()
        S, M = spec.eet.shape
        tiers = spec.tiers
        label = ("flat" if max(tiers) == 0
                 else ",".join(str(t) for t in tiers))
        print(f"{name:14s} {S:5d} {M:8d} {spec.n_sites:5d} {label:14s}",
              file=file)


def print_summary(result: SweepResult, file=None) -> None:
    """Human-readable per-cell table (one line per heuristic x rate)."""
    file = file if file is not None else sys.stdout
    print(f"{'heuristic':9s} {'rate':>6s} {'ontime%':>8s} {'±ci':>6s} "
          f"{'energy':>10s} {'waste%':>7s} {'cancel%':>8s} {'miss%':>6s} "
          f"{'spread':>7s} {'jain':>6s}", file=file)
    for row in result.summary_rows():
        print(f"{row['heuristic']:9s} {row['rate']:6.2f} "
              f"{100 * row['completion_rate']:8.2f} "
              f"{100 * row['completion_rate_ci95']:6.2f} "
              f"{row['energy']:10.1f} {row['wasted_pct']:7.2f} "
              f"{row['cancelled_pct']:8.2f} {row['missed_pct']:6.2f} "
              f"{row['fairness_spread']:7.4f} {row['jain_index']:6.4f}",
              file=file)


def main(argv=None) -> SweepResult:
    spec, args = build_spec(argv)
    n = spec.n_simulations
    system_label = args.system or (
        "scenario fleet" if spec.resolve_scenario().fleet is not None
        else "paper"
    )
    n_sites = spec.resolve_system().n_sites
    fed = (f" sites={n_sites} dispatcher={args.dispatcher}"
           if n_sites > 1 else "")
    if args.dynamics != "none":
        fed += f" dynamics={args.dynamics}"
    if args.network != "none":
        fed += f" network={args.network}"
    shard_note = ""
    if args.shard:
        import jax

        n_dev = len(jax.devices())
        shard_note = (f" sharded over {n_dev} devices" if n_dev > 1
                      else " (--shard: single device, running unsharded)")
    print(f"sweep: {len(spec.heuristics)} heuristics x "
          f"{len(spec.rates)} rates x {spec.reps} reps "
          f"({n} traces of {spec.n_tasks} tasks) "
          f"on system={system_label} scenario={args.scenario}{fed}"
          f"{shard_note}",
          flush=True)
    t0 = time.perf_counter()
    result = run_sweep(spec, shard=args.shard)
    dt = time.perf_counter() - t0
    print(f"simulated {n} traces in {dt:.1f}s "
          f"({1e3 * dt / n:.0f} ms/trace incl. compile)\n")
    print_summary(result)
    paths = result.save(args.out)
    print("\nwrote " + ", ".join(str(p) for p in paths.values()))
    return result


if __name__ == "__main__":
    main()
