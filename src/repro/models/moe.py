"""Mixture-of-Experts layer: top-k token-choice routing with capacity-based
dispatch (einsum formulation => XLA lowers the dispatch to all-to-alls under
expert parallelism; FLOPs scale with *active* experts, not total)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def moe_init(key, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": _dense_init(ks[1], (E, d, ff), cfg.p_dtype),
        "w_up": _dense_init(ks[2], (E, d, ff), cfg.p_dtype),
        "w_down": _dense_init(ks[3], (E, ff, d), cfg.p_dtype),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.n_experts)
    return max(c, cfg.experts_per_token)


def moe_apply(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (B, S, d); aux: load-balancing loss.

    GROUPED capacity-based dispatch (§Perf iteration A): tokens are split
    into groups of <= ``cfg.moe_group`` and each group dispatches within its
    own capacity buffer. The dispatch one-hot is then (G, Tg, E, Cg) with
    Cg ∝ Tg — LINEAR total size in T instead of the naive (T, E, C) whose
    C ∝ T made dispatch traffic quadratic in tokens (the granite-moe
    prefill_32k baseline spent 99.9% of its bytes there). Per-group capacity
    also bounds expert hot-spotting locally, the standard Switch/GShard
    formulation. Overflow tokens fall back to the residual path.
    """
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.experts_per_token
    Tg = min(cfg.moe_group, T)
    while T % Tg:
        Tg -= 1
    G = T // Tg
    C = _capacity(Tg, cfg)
    xt = x.reshape(G, Tg, d)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)               # (G, Tg, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)         # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # slot of each (token, k) within its expert's per-group capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (G, Tg, K, E)
    flat = onehot.reshape(G, Tg * K, E)
    pos = ((jnp.cumsum(flat, axis=1) * flat - 1)
           .reshape(G, Tg, K, E)
           .max(axis=-1))                                  # (G, Tg, K)
    within = (pos >= 0) & (pos < C)
    pos_c = jnp.where(within, pos, C)                      # C = overflow bin

    # SCATTER dispatch (§Perf iteration A2): route tokens into the per-
    # expert capacity buffers with a scatter-add instead of a (Tg,K,E,C)
    # one-hot einsum — traffic drops from O(T·K·E·C) to O(T·K·d).
    gidx = jnp.arange(G)[:, None, None]
    gidx = jnp.broadcast_to(gidx, (G, Tg, K))
    vals = (xt[:, :, None, :] * within[..., None].astype(xt.dtype))
    xe = jnp.zeros((G, E, C + 1, d), xt.dtype).at[
        gidx, gate_idx, pos_c].add(vals)[:, :, :C]         # (G, E, C, d)

    # expert matmuls in the (E, G*C, d) layout (single batch dim keeps the
    # dot on the fast path of every backend)
    xe3 = xe.swapaxes(0, 1).reshape(E, G * C, d)
    g = jnp.einsum("ecd,edf->ecf", xe3, p["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xe3, p["w_up"],
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    h = (jax.nn.silu(g).astype(xt.dtype) * u)
    ye3 = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                     preferred_element_type=jnp.float32).astype(xt.dtype)
    ye = ye3.reshape(E, G, C, d).swapaxes(0, 1)            # (G, E, C, d)

    # GATHER combine: y[t] = sum_k gate[t,k] * ye[e_k, slot_k]
    back = ye[gidx, gate_idx, jnp.clip(pos_c, 0, C - 1)]   # (G, Tg, K, d)
    y = (back * (gate_vals.astype(xt.dtype)
                 * within.astype(xt.dtype))[..., None]).sum(2)

    # Switch-style load balancing aux loss
    me = probs.reshape(T, E).mean(0)
    ce = (onehot.reshape(T, K, E).sum(1) > 0).astype(jnp.float32).mean(0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux
