"""Unified model stack for all assigned architectures.

One ``init`` / ``forward`` / ``prefill`` / ``decode_step`` / ``init_cache``
surface dispatching on ``cfg.family``:

  dense | vlm   : pre-norm GQA transformer (VLM prepends stub patch embeds)
  moe           : GQA attention + top-k expert MLP
  ssm           : xLSTM — scan over (mLSTM, sLSTM) superblocks
  hybrid        : Zamba2 — Mamba2 backbone + one shared attention block
                  invoked every ``attn_every`` layers (per-invocation norms)
  audio         : Whisper backbone — bidirectional encoder (stub frame
                  embeddings) + causal decoder with cross-attention

Layer stacks are ``lax.scan`` over stacked parameters (HLO stays O(1) in
depth — essential for the 512-device dry-run compiles) with optional per-layer
remat. ``forward`` returns final *hidden states*; the LM head / loss is
applied chunked in repro.train.loss so full-vocab logits are never
materialized for a whole batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


# ==========================================================================
# init
# ==========================================================================
def _attn_block_init(key, cfg, cross=False):
    ks = jax.random.split(key, 6)
    p = {
        "ln1": ll.norm_init(cfg),
        "attn": ll.attn_init(ks[0], cfg),
        "ln2": ll.norm_init(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["mlp"] = ll.mlp_init(ks[1], cfg)
    if cross:
        p["lnx"] = ll.norm_init(cfg)
        p["xattn"] = ll.attn_init(ks[2], cfg)
    return p


def _stacked(key, n, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def init(key, cfg: ModelConfig):
    k_emb, k_blocks, k_extra = jax.random.split(key, 3)
    params = {"embed": ll.embed_init(k_emb, cfg),
              "final_norm": ll.norm_init(cfg)}
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        params["blocks"] = _stacked(
            k_blocks, cfg.n_layers, lambda k: _attn_block_init(k, cfg))
    elif fam == "ssm":
        assert cfg.n_layers % 2 == 0
        nsb = cfg.n_layers // 2
        params["blocks"] = _stacked(
            k_blocks, nsb,
            lambda k: {
                "mlstm": xlstm_mod.mlstm_init(jax.random.fold_in(k, 0), cfg),
                "slstm": xlstm_mod.slstm_init(jax.random.fold_in(k, 1), cfg),
            })
    elif fam == "hybrid":
        assert cfg.n_layers % cfg.attn_every == 0
        n_inv = cfg.n_layers // cfg.attn_every
        params["blocks"] = _stacked(
            k_blocks, cfg.n_layers,
            lambda k: {"ln": ll.norm_init(cfg),
                       "mamba": ssm_mod.mamba_init(k, cfg)})
        params["shared_attn"] = _attn_block_init(k_extra, cfg)
        params["inv_norms"] = jnp.ones((n_inv, cfg.d_model), cfg.p_dtype)
    elif fam == "audio":
        ke, kd = jax.random.split(k_blocks)
        params["enc_blocks"] = _stacked(
            ke, cfg.encoder_layers, lambda k: _attn_block_init(k, cfg))
        params["blocks"] = _stacked(
            kd, cfg.n_layers,
            lambda k: _attn_block_init(k, cfg, cross=True))
        params["enc_norm"] = ll.norm_init(cfg)
    else:
        raise ValueError(fam)
    return params


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run / sharding rules)."""
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ==========================================================================
# full-sequence forward (train / prefill body)
# ==========================================================================
def _maybe_remat(cfg, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _attn_block_apply(cfg, p, x, positions, *, causal=True, enc=None,
                      enc_positions=None, collect_kv=False):
    h, kv = ll.attn_apply(cfg, p["attn"], ll.norm_apply(cfg, p["ln1"], x),
                          positions, causal=causal)
    x = x + h
    xkv = None
    if enc is not None:
        h, xkv = ll.attn_apply(
            cfg, p["xattn"], ll.norm_apply(cfg, p["lnx"], x), positions,
            causal=False, kv_src=enc, kv_positions=enc_positions)
        x = x + h
    aux = jnp.float32(0.0)
    if cfg.family == "moe":
        h, aux = moe_mod.moe_apply(cfg, p["moe"],
                                   ll.norm_apply(cfg, p["ln2"], x))
    else:
        h = ll.mlp_apply(cfg, p["mlp"], ll.norm_apply(cfg, p["ln2"], x))
    x = x + h
    if collect_kv:
        return x, aux, (kv, xkv)
    return x, aux


def _embed_input(cfg, params, batch):
    """tokens (+ stub modality embeddings) -> (B, S, d), positions (S,)."""
    x = ll.embed_apply(params["embed"], batch["tokens"], cfg.act_dtype)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(cfg.act_dtype), x], 1)
    S = x.shape[1]
    return x, jnp.arange(S)


def forward(cfg: ModelConfig, params, batch):
    """-> (hidden (B, S, d), aux_loss). Causal LM over the full sequence."""
    fam = cfg.family
    if fam == "audio":
        return _forward_audio(cfg, params, batch)
    x, positions = _embed_input(cfg, params, batch)

    if fam in ("dense", "vlm", "moe"):
        def body(x, lp):
            x, aux = _attn_block_apply(cfg, lp, x, positions)
            return x, aux
        x, auxs = jax.lax.scan(_maybe_remat(cfg, body), x, params["blocks"])
        aux = auxs.sum()
    elif fam == "ssm":
        def body(x, lp):
            x = xlstm_mod.mlstm_apply(cfg, lp["mlstm"], x)
            x = xlstm_mod.slstm_apply(cfg, lp["slstm"], x)
            return x, jnp.float32(0.0)
        x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, params["blocks"])
        aux = jnp.float32(0.0)
    elif fam == "hybrid":
        n_inv = cfg.n_layers // cfg.attn_every
        blocks = jax.tree.map(
            lambda a: a.reshape(n_inv, cfg.attn_every, *a.shape[1:]),
            params["blocks"])

        def mamba_body(x, lp):
            x = x + ssm_mod.mamba_apply(
                cfg, lp["mamba"],
                ll.norm_apply(cfg, lp["ln"], x))
            return x, None

        mb = _maybe_remat(cfg, mamba_body)
        for g in range(n_inv):
            grp = jax.tree.map(lambda a, g=g: a[g], blocks)
            x, _ = jax.lax.scan(mb, x, grp)
            xn = x * params["inv_norms"][g][None, None].astype(x.dtype)
            x, _ = _attn_block_apply(cfg, params["shared_attn"], xn,
                                     positions)
        aux = jnp.float32(0.0)
    else:
        raise ValueError(fam)
    return ll.norm_apply(cfg, params["final_norm"], x), aux


def _forward_audio(cfg, params, batch):
    """frames (B, Se, d) [stub embeddings] + tokens (B, Sd)."""
    frames = batch["frames"].astype(cfg.act_dtype)
    enc_pos = jnp.arange(frames.shape[1])

    def enc_body(x, lp):
        x, aux = _attn_block_apply(cfg, lp, x, enc_pos, causal=False)
        return x, aux
    enc, _ = jax.lax.scan(_maybe_remat(cfg, enc_body), frames,
                          params["enc_blocks"])
    enc = ll.norm_apply(cfg, params["enc_norm"], enc)

    x = ll.embed_apply(params["embed"], batch["tokens"], cfg.act_dtype)
    dec_pos = jnp.arange(x.shape[1])

    def dec_body(x, lp):
        x, aux = _attn_block_apply(cfg, lp, x, dec_pos, enc=enc,
                                   enc_positions=enc_pos)
        return x, aux
    x, _ = jax.lax.scan(_maybe_remat(cfg, dec_body), x, params["blocks"])
    return ll.norm_apply(cfg, params["final_norm"], x), jnp.float32(0.0)


# ==========================================================================
# KV / state caches
# ==========================================================================
def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Zero-initialized decode cache (shapes depend on family)."""
    fam = cfg.family
    dt = cfg.act_dtype
    if fam in ("dense", "vlm", "moe"):
        L = cfg.n_layers
        kv = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt)
        return {"k": kv, "v": kv, "len": jnp.zeros((batch,), jnp.int32)}
    if fam == "ssm":
        nsb = cfg.n_layers // 2
        H, d = cfg.n_heads, cfg.d_model
        di, dh = 2 * d, d // H
        dk = di // H
        f32 = jnp.float32
        return {
            "mlstm": {
                "S": jnp.zeros((nsb, batch, H, dk, dk), f32),
                "n": jnp.zeros((nsb, batch, H, dk), f32),
                "conv": jnp.zeros((nsb, batch, 3, di), dt),
            },
            "slstm": {
                "c": jnp.zeros((nsb, batch, H, dh), f32),
                "n": jnp.zeros((nsb, batch, H, dh), f32),
                "h": jnp.zeros((nsb, batch, H, dh), f32),
                "m": jnp.full((nsb, batch, H, dh), -1e9, f32),
            },
            "len": jnp.zeros((batch,), jnp.int32),
        }
    if fam == "hybrid":
        L = cfg.n_layers
        n_inv = L // cfg.attn_every
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_ch = cfg.d_inner + 2 * N
        return {
            "ssm": jnp.zeros((L, batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch), dt),
            "k": jnp.zeros((n_inv, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dt),
            "v": jnp.zeros((n_inv, batch, max_seq, cfg.n_kv_heads, cfg.hd),
                           dt),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    if fam == "audio":
        L = cfg.n_layers
        enc_seq = max_seq  # cross-KV over encoder frames
        kv = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.hd), dt)
        xkv = jnp.zeros((L, batch, enc_seq, cfg.n_kv_heads, cfg.hd), dt)
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv,
                "len": jnp.zeros((batch,), jnp.int32),
                "xlen": jnp.zeros((batch,), jnp.int32)}
    raise ValueError(fam)


# ==========================================================================
# prefill
# ==========================================================================
def prefill(cfg: ModelConfig, params, batch, max_seq: int):
    """Process the prompt; return (last hidden (B,1,d), cache)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        x, positions = _embed_input(cfg, params, batch)
        B, S = x.shape[:2]

        def body(x, lp):
            x, _, (kv, _) = _attn_block_apply(
                cfg, lp, x, positions, collect_kv=True)
            return x, kv
        x, kvs = jax.lax.scan(body, x, params["blocks"])
        k, v = kvs  # (L, B, S, Hkv, hd)
        pad = max_seq - S
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": k, "v": v,
                 "len": jnp.full((B,), S, jnp.int32)}
        x = ll.norm_apply(cfg, params["final_norm"], x)
        return x[:, -1:], cache
    if fam == "ssm":
        x, _ = _embed_input(cfg, params, batch)
        B, S = x.shape[:2]

        def body(x, lp):
            x, mst = xlstm_mod.mlstm_apply(cfg, lp["mlstm"], x,
                                           return_state=True)
            x, sst = xlstm_mod.slstm_apply(cfg, lp["slstm"], x,
                                           return_state=True)
            return x, (mst, sst)
        x, (mst, sst) = jax.lax.scan(body, x, params["blocks"])
        cache = {"mlstm": mst, "slstm": sst,
                 "len": jnp.full((B,), S, jnp.int32)}
        x = ll.norm_apply(cfg, params["final_norm"], x)
        return x[:, -1:], cache
    if fam == "hybrid":
        x, positions = _embed_input(cfg, params, batch)
        B, S = x.shape[:2]
        n_inv = cfg.n_layers // cfg.attn_every
        blocks = jax.tree.map(
            lambda a: a.reshape(n_inv, cfg.attn_every, *a.shape[1:]),
            params["blocks"])

        def mamba_body(x, lp):
            out, stt = ssm_mod.mamba_apply(
                cfg, lp["mamba"], ll.norm_apply(cfg, lp["ln"], x),
                return_state=True)
            return x + out, stt

        ssm_states, conv_states, ks, vs = [], [], [], []
        for g in range(n_inv):
            grp = jax.tree.map(lambda a, g=g: a[g], blocks)
            x, stt = jax.lax.scan(mamba_body, x, grp)
            ssm_states.append(stt["ssm"])
            conv_states.append(stt["conv"])
            xn = x * params["inv_norms"][g][None, None].astype(x.dtype)
            x, _, (kv, _) = _attn_block_apply(
                cfg, params["shared_attn"], xn, positions, collect_kv=True)
            ks.append(kv[0])
            vs.append(kv[1])
        pad = max_seq - S
        k = jnp.pad(jnp.stack(ks), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(jnp.stack(vs), ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {
            "ssm": jnp.concatenate(ssm_states, 0).reshape(
                cfg.n_layers, B, cfg.ssm_heads, cfg.ssm_state,
                cfg.ssm_head_dim),
            "conv": jnp.concatenate(conv_states, 0).reshape(
                cfg.n_layers, B, cfg.ssm_conv - 1, -1),
            "k": k, "v": v, "len": jnp.full((B,), S, jnp.int32),
        }
        x = ll.norm_apply(cfg, params["final_norm"], x)
        return x[:, -1:], cache
    if fam == "audio":
        frames = batch["frames"].astype(cfg.act_dtype)
        enc_pos = jnp.arange(frames.shape[1])

        def enc_body(x, lp):
            x, _ = _attn_block_apply(cfg, lp, x, enc_pos, causal=False)
            return x, None
        enc, _ = jax.lax.scan(enc_body, frames, params["enc_blocks"])
        enc = ll.norm_apply(cfg, params["enc_norm"], enc)

        x = ll.embed_apply(params["embed"], batch["tokens"], cfg.act_dtype)
        B, Sd = x.shape[:2]
        dec_pos = jnp.arange(Sd)

        def dec_body(x, lp):
            x, _, (kv, xkv) = _attn_block_apply(
                cfg, lp, x, dec_pos, enc=enc, enc_positions=enc_pos,
                collect_kv=True)
            return x, (kv, xkv)
        x, (kvs, xkvs) = jax.lax.scan(dec_body, x, params["blocks"])
        pad = max_seq - Sd
        padk = lambda a: jnp.pad(
            a, ((0, 0), (0, 0), (0, max_seq - a.shape[2]), (0, 0), (0, 0)))
        cache = {"k": padk(kvs[0]), "v": padk(kvs[1]),
                 "xk": padk(xkvs[0]), "xv": padk(xkvs[1]),
                 "len": jnp.full((B,), Sd, jnp.int32),
                 "xlen": jnp.full((B,), frames.shape[1], jnp.int32)}
        x = ll.norm_apply(cfg, params["final_norm"], x)
        return x[:, -1:], cache
    raise ValueError(fam)


# ==========================================================================
# decode
# ==========================================================================
def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One decode step. tokens: (B, 1) -> (logits (B, 1, V), new cache)."""
    fam = cfg.family
    x = ll.embed_apply(params["embed"], tokens, cfg.act_dtype)
    B = x.shape[0]
    pos = cache["len"][:, None]  # (B,1) absolute position of the new token

    if fam in ("dense", "vlm", "moe"):
        def body(x, scanned):
            lp, ck, cv = scanned
            h, nk, nv, _ = ll.attn_decode(
                cfg, lp["attn"], ll.norm_apply(cfg, lp["ln1"], x), pos,
                ck, cv, cache["len"])
            x = x + h
            if cfg.family == "moe":
                h, _ = moe_mod.moe_apply(cfg, lp["moe"],
                                         ll.norm_apply(cfg, lp["ln2"], x))
            else:
                h = ll.mlp_apply(cfg, lp["mlp"],
                                 ll.norm_apply(cfg, lp["ln2"], x))
            return x + h, (nk, nv)
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": nk, "v": nv, "len": cache["len"] + 1}
    elif fam == "ssm":
        def body(x, scanned):
            lp, mst, sst = scanned
            x, mst = xlstm_mod.mlstm_decode(cfg, lp["mlstm"], x, mst)
            x, sst = xlstm_mod.slstm_decode(cfg, lp["slstm"], x, sst)
            return x, (mst, sst)
        x, (mst, sst) = jax.lax.scan(
            body, x, (params["blocks"], cache["mlstm"], cache["slstm"]))
        cache = {"mlstm": mst, "slstm": sst, "len": cache["len"] + 1}
    elif fam == "hybrid":
        n_inv = cfg.n_layers // cfg.attn_every
        blocks = jax.tree.map(
            lambda a: a.reshape(n_inv, cfg.attn_every, *a.shape[1:]),
            params["blocks"])
        rs = lambda a: a.reshape(n_inv, cfg.attn_every, *a.shape[1:])
        ssm_g, conv_g = rs(cache["ssm"]), rs(cache["conv"])

        def mamba_body(x, scanned):
            lp, s_ssm, s_conv = scanned
            out, stt = ssm_mod.mamba_decode(
                cfg, lp["mamba"], ll.norm_apply(cfg, lp["ln"], x),
                {"ssm": s_ssm, "conv": s_conv})
            return x + out, (stt["ssm"], stt["conv"])

        new_ssm, new_conv, new_k, new_v = [], [], [], []
        for g in range(n_inv):
            grp = jax.tree.map(lambda a, g=g: a[g], blocks)
            x, (s1, c1) = jax.lax.scan(
                mamba_body, x, (grp, ssm_g[g], conv_g[g]))
            new_ssm.append(s1)
            new_conv.append(c1)
            xn = x * params["inv_norms"][g][None, None].astype(x.dtype)
            sp = params["shared_attn"]
            h, nk, nv, _ = ll.attn_decode(
                cfg, sp["attn"], ll.norm_apply(cfg, sp["ln1"], xn), pos,
                cache["k"][g], cache["v"][g], cache["len"])
            x = x + h
            h = ll.mlp_apply(cfg, sp["mlp"],
                             ll.norm_apply(cfg, sp["ln2"], x))
            x = x + h
            new_k.append(nk)
            new_v.append(nv)
        cache = {
            "ssm": jnp.concatenate(new_ssm, 0),
            "conv": jnp.concatenate(new_conv, 0),
            "k": jnp.stack(new_k), "v": jnp.stack(new_v),
            "len": cache["len"] + 1,
        }
    elif fam == "audio":
        def body(x, scanned):
            lp, ck, cv, cxk, cxv = scanned
            h, nk, nv, _ = ll.attn_decode(
                cfg, lp["attn"], ll.norm_apply(cfg, lp["ln1"], x), pos,
                ck, cv, cache["len"])
            x = x + h
            h, _, _, _ = ll.attn_decode(
                cfg, lp["xattn"], ll.norm_apply(cfg, lp["lnx"], x), pos,
                cxk, cxv, cache["xlen"], cross=True)
            x = x + h
            h = ll.mlp_apply(cfg, lp["mlp"],
                             ll.norm_apply(cfg, lp["ln2"], x))
            return x + h, (nk, nv)
        x, (nk, nv) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["k"], cache["v"], cache["xk"],
             cache["xv"]))
        cache = {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"],
                 "len": cache["len"] + 1, "xlen": cache["xlen"]}
    else:
        raise ValueError(fam)

    x = ll.norm_apply(cfg, params["final_norm"], x)
    logits = ll.unembed_apply(cfg, params["embed"], x)
    return logits, cache
