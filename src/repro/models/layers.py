"""Shared model layers: norms, RoPE, GQA attention, MLPs.

All layers are pure functions over parameter pytrees (nested dicts). Matmul
accumulation is fp32 (``preferred_element_type``); activations flow in the
config's dtype (bf16 by default).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def norm_init(cfg: ModelConfig, with_bias=None):
    with_bias = cfg.norm == "layernorm" if with_bias is None else with_bias
    p = {"scale": jnp.ones((cfg.d_model,), cfg.p_dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((cfg.d_model,), cfg.p_dtype)
    return p


def norm_apply(cfg: ModelConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" and "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA), pluggable impl
# --------------------------------------------------------------------------
def attn_init(key, cfg: ModelConfig, d_kv_src: int | None = None):
    """QKVO projections. ``d_kv_src`` != None -> cross-attention K/V source."""
    d, hd = cfg.d_model, cfg.hd
    dk = d_kv_src if d_kv_src is not None else d
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, cfg.n_heads * hd), cfg.p_dtype),
        "wk": _dense_init(ks[1], (dk, cfg.n_kv_heads * hd), cfg.p_dtype),
        "wv": _dense_init(ks[2], (dk, cfg.n_kv_heads * hd), cfg.p_dtype),
        "wo": _dense_init(ks[3], (cfg.n_heads * hd, d), cfg.p_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), cfg.p_dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.p_dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), cfg.p_dtype)
    return p


def _proj(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def qkv(cfg: ModelConfig, p, x, kv_src=None):
    """Project to (B, S, H, hd) / (B, Skv, Hkv, hd)."""
    B = x.shape[0]
    kv_src = x if kv_src is None else kv_src
    q = _proj(x, p["wq"], p.get("bq")).reshape(B, -1, cfg.n_heads, cfg.hd)
    k = _proj(kv_src, p["wk"], p.get("bk")).reshape(
        B, -1, cfg.n_kv_heads, cfg.hd)
    v = _proj(kv_src, p["wv"], p.get("bv")).reshape(
        B, -1, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def sdpa_xla(q, k, v, *, causal: bool, kv_len=None, q_offset=0):
    """Reference scaled-dot-product attention with GQA, fp32 softmax.

    q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd). ``kv_len``: (B,) valid KV
    prefix length (decode); ``q_offset``: absolute position of q[0] for the
    causal mask.
    """
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]  # (B, Sk)
        logits = jnp.where(valid[:, None, None, None], logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def sdpa_xla_chunked(q, k, v, *, causal, kv_len=None, q_offset=0,
                     block: int = 1024):
    """Query-blockwise attention: numerically identical to ``sdpa_xla`` but
    peak score memory is (B, Hkv, g, block, Sk) instead of (.., Sq, Sk) —
    the XLA-level peak-memory control for long prefill when the Pallas
    flash kernel isn't available (attn_impl="xla_chunked")."""
    B, Sq, H, hd = q.shape
    bs = min(block, Sq)
    pad = (-Sq) % bs
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    nb = qp.shape[1] // bs
    qb = qp.reshape(B, nb, bs, H, hd).swapaxes(0, 1)  # (nb, B, bs, H, hd)

    def body(_, qi_i):
        qi, i = qi_i
        out = sdpa_xla(qi, k, v, causal=causal, kv_len=kv_len,
                       q_offset=q_offset + i * bs)
        return None, out

    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    out = outs.swapaxes(0, 1).reshape(B, nb * bs, H, hd)
    return out[:, :Sq]


def sdpa(cfg: ModelConfig, q, k, v, *, causal, kv_len=None, q_offset=0):
    """Implementation dispatch: xla | xla_chunked | pallas |
    pallas_interpret."""
    if cfg.attn_impl == "xla":
        return sdpa_xla(q, k, v, causal=causal, kv_len=kv_len,
                        q_offset=q_offset)
    if cfg.attn_impl == "xla_chunked":
        return sdpa_xla_chunked(q, k, v, causal=causal, kv_len=kv_len,
                                q_offset=q_offset)
    from repro.kernels.flash_attention import ops as flash_ops
    from repro.kernels.decode_attention import ops as dec_ops

    interpret = cfg.attn_impl == "pallas_interpret"
    if q.shape[1] == 1 and kv_len is not None:  # decode
        return dec_ops.decode_attention(q, k, v, kv_len, interpret=interpret)
    return flash_ops.flash_attention(
        q, k, v, causal=causal, kv_len=kv_len, q_offset=q_offset,
        interpret=interpret,
    )


def attn_apply(cfg: ModelConfig, p, x, positions, *, causal=True,
               kv_src=None, kv_positions=None, use_rope=True):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q, k, v = qkv(cfg, p, x, kv_src)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        kp = positions if kv_positions is None else kv_positions
        k = rope(k, kp, cfg.rope_theta)
    out = sdpa(cfg, q, k, v, causal=causal)
    B, S = x.shape[:2]
    return _proj(out.reshape(B, S, -1), p["wo"]), (k, v)


def attn_decode(cfg: ModelConfig, p, x, pos, ck, cv, cache_len, *,
                use_rope=True, cross=False):
    """Single-token decode against a KV cache.

    x: (B, 1, d); ck/cv: (B, S_max, Hkv, hd); cache_len: (B,) ints.
    Returns (out (B,1,d), new_ck, new_cv, new_len).
    """
    B = x.shape[0]
    if cross:
        q = _proj(x, p["wq"], p.get("bq")).reshape(B, 1, cfg.n_heads, cfg.hd)
        if use_rope:
            q = rope(q, pos, cfg.rope_theta)
        k, v, new_len = ck, cv, cache_len
    else:
        q, k1, v1 = qkv(cfg, p, x)
        if use_rope:
            q = rope(q, pos, cfg.rope_theta)
            k1 = rope(k1, pos, cfg.rope_theta)
        # in-place scatter at each sequence's write position: touches one
        # (Hkv, hd) row per batch element instead of rewriting the cache
        # (§Perf iteration C: the full-cache `where` doubled decode traffic).
        bidx = jnp.arange(B)
        # mode="drop": writing past capacity is a no-op, never a corruption
        k = ck.at[bidx, cache_len].set(k1[:, 0].astype(ck.dtype),
                                       mode="drop")
        v = cv.at[bidx, cache_len].set(v1[:, 0].astype(cv.dtype),
                                       mode="drop")
        new_len = cache_len + 1
    out = sdpa(cfg, q, k, v, causal=False, kv_len=new_len)
    return _proj(out.reshape(B, 1, -1), p["wo"]), k, v, new_len


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {
            "w_gate": _dense_init(ks[0], (d, ff), cfg.p_dtype),
            "w_up": _dense_init(ks[1], (d, ff), cfg.p_dtype),
            "w_down": _dense_init(ks[2], (ff, d), cfg.p_dtype),
        }
    return {
        "w_up": _dense_init(ks[0], (d, ff), cfg.p_dtype),
        "b_up": jnp.zeros((ff,), cfg.p_dtype),
        "w_down": _dense_init(ks[1], (ff, d), cfg.p_dtype),
        "b_down": jnp.zeros((d,), cfg.p_dtype),
    }


def mlp_apply(cfg: ModelConfig, p, x):
    if cfg.mlp == "swiglu":
        g = _proj(x, p["w_gate"])
        u = _proj(x, p["w_up"])
        return _proj(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u,
                     p["w_down"])
    h = _proj(x, p["w_up"], p["b_up"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return _proj(h, p["w_down"], p["b_down"])


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------
def embed_init(key, cfg: ModelConfig):
    # vocab padded to cfg.pad_vocab_to so the LM head stays TP-shardable
    # (§Perf iteration B: a non-divisible vocab silently replicates the
    # embedding and all chunk logits). Padding rows are masked at the head.
    V = cfg.padded_vocab
    p = {"tok": _dense_init(key, (V, cfg.d_model), cfg.p_dtype,
                            scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(
            jax.random.fold_in(key, 1), (cfg.d_model, V), cfg.p_dtype)
    return p


def embed_apply(p, tokens, dtype):
    return p["tok"][tokens].astype(dtype)


def unembed_apply(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        w = p["tok"].T
    else:
        w = p["unembed"]
    logits = jnp.einsum("...d,dv->...v", x, w,
                        preferred_element_type=jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits
