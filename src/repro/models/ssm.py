"""Mamba2 (SSD) blocks, TPU-adapted.

The SSD scan is written in its *chunked* matmul form — intra-chunk work is
(Q x Q) / (Q x N) matmuls that map onto the MXU, inter-chunk state flows
through a `lax.scan` — the TPU-native restructuring of the CUDA selective
scan. A Pallas kernel for the intra-chunk part lives in
repro/kernels/ssm_scan; this module is the XLA path and the oracle's basis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def mamba_init(key, cfg: ModelConfig):
    d, din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * N
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * din + 2 * N + H), cfg.p_dtype),
        "conv_w": _dense_init(ks[1], (cfg.ssm_conv, conv_ch), cfg.p_dtype,
                              scale=cfg.ssm_conv ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), cfg.p_dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((din,), cfg.p_dtype),
        "out_proj": _dense_init(ks[2], (din, d), cfg.p_dtype),
    }


def _causal_conv(x, w, b):
    """x: (B, L, C); w: (K, C) depthwise causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    segs = [xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K)]
    y = sum(segs) + b[None, None, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x:  (B, L, H, P) inputs per head
    dt: (B, L, H)    positive step sizes
    A:  (H,)         negative per-head decay rates
    Bm: (B, L, N)    input projections (single group)
    Cm: (B, L, N)    output projections
    Returns y: (B, L, H, P), final_state: (B, H, N, P).
    """
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    nc = L // Q

    f32 = jnp.float32
    xr = x.reshape(B, nc, Q, H, P).astype(f32)
    dtr = dt.reshape(B, nc, Q, H).astype(f32)
    Br = Bm.reshape(B, nc, Q, N).astype(f32)
    Cr = Cm.reshape(B, nc, Q, N).astype(f32)

    loga = dtr * A[None, None, None, :]                # (B,nc,Q,H) negative
    cl = jnp.cumsum(loga, axis=2)                      # inclusive cumsum

    # intra-chunk: y[i] += sum_{j<=i} C_i.B_j exp(cl_i - cl_j) dt_j x_j
    CB = jnp.einsum("bciN,bcjN->bcij", Cr, Br)         # (B,nc,Q,Q)
    seg = cl[:, :, :, None, :] - cl[:, :, None, :, :]  # (B,nc,Q,Q,H) i,j
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    xdt = xr * dtr[..., None]                          # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", CB, decay, xdt)

    # chunk summaries: S_c = sum_j exp(cl_last - cl_j) dt_j B_j x_j^T
    segl = jnp.exp(cl[:, :, -1:, :] - cl)              # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcjh,bcjN,bcjhp->bchNp", segl * dtr, Br, xr)
    chunk_decay = jnp.exp(cl[:, :, -1, :])             # (B,nc,H)

    def scan_fn(S_prev, inp):
        S_c, dec = inp  # (B,H,N,P), (B,H)
        S_new = dec[:, :, None, None] * S_prev + S_c
        return S_new, S_prev

    S0 = jnp.zeros((B, H, N, P), f32)
    S_final, S_prevs = jax.lax.scan(
        scan_fn,
        S0,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)              # (B,nc,H,N,P)

    # inter-chunk: y[i] += C_i exp(cl_i) . S_prev
    y_inter = jnp.einsum(
        "bciN,bcih,bchNp->bcihp", Cr, jnp.exp(cl), S_prevs
    )
    y = (y_intra + y_inter).reshape(B, L, H, P)
    return y.astype(x.dtype), S_final


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential oracle for ssd_chunked (and the Pallas kernel)."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(S, inp):
        xt, dtt, Bt, Ct = inp  # (B,H,P),(B,H),(B,N),(B,N)
        a = jnp.exp(dtt * A[None])                      # (B,H)
        S = a[:, :, None, None] * S + jnp.einsum(
            "bh,bN,bhp->bhNp", dtt, Bt, xt.astype(f32))
        y = jnp.einsum("bN,bhNp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((B, H, N, P), f32)
    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(Bm.astype(f32), 1, 0),
        jnp.moveaxis(Cm.astype(f32), 1, 0),
    )
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S


def mamba_apply(cfg: ModelConfig, p, x, *, return_state=False):
    """Full-sequence Mamba2 mixer. x: (B, L, d)."""
    B, L, d = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xh = xs.reshape(B, L, H, P)
    if cfg.ssm_impl == "xla":
        y, S = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    else:
        from repro.kernels.ssm_scan import ops as ssm_ops
        y, S = ssm_ops.ssm_scan(
            xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
            interpret=cfg.ssm_impl == "pallas_interpret")
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, L, din)
    # gated RMSNorm (mamba2's norm-before-out_proj)
    yz = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    ms = (yz.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-5)
          * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", yz, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        K = cfg.ssm_conv
        conv_state = conv_in[:, -(K - 1):, :] if L >= K - 1 else jnp.pad(
            conv_in, ((0, 0), (K - 1 - L, 0), (0, 0)))
        return out, {"ssm": S, "conv": conv_state}
    return out


def mamba_decode(cfg: ModelConfig, p, x, state):
    """Single-token decode. x: (B, 1, d); state: {ssm (B,H,N,P), conv (B,K-1,C)}."""
    B = x.shape[0]
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)       # (B,1,C)
    window = jnp.concatenate([state["conv"], conv_in], axis=1)  # (B,K,C)
    w = p["conv_w"]
    y = (window * w[None]).sum(axis=1, keepdims=True) + p["conv_b"]
    conv_out = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(conv_out, [din, din + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])

    xh = xs.reshape(B, H, P).astype(jnp.float32)
    a = jnp.exp(dt * A[None])                               # (B,H)
    S = state["ssm"]
    S = a[:, :, None, None] * S + jnp.einsum(
        "bh,bN,bhp->bhNp", dt, Bm[:, 0].astype(jnp.float32), xh)
    yh = jnp.einsum("bN,bhNp->bhp", Cm[:, 0].astype(jnp.float32), S)
    yh = yh + xh * p["D"][None, :, None]
    yv = yh.reshape(B, 1, din).astype(x.dtype)
    yz = yv * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    ms = (yz.astype(jnp.float32) ** 2).mean(-1, keepdims=True)
    yz = (yz.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-5)
          * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", yz, p["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_state = {"ssm": S, "conv": window[:, 1:, :]}
    return out, new_state
