"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory with hidden-state recurrence, sequential lax.scan).

TPU adaptation notes (DESIGN.md): the mLSTM uses the sigmoid-input-gate
gated-linear-attention variant so the chunkwise form is MXU matmuls without
the exponential-gate stabilizer bookkeeping; the sLSTM keeps its inherently
sequential recurrence (h_{t-1} feeds the gates) as a `lax.scan` — it cannot
be parallelized over time and that is a property of the architecture, not
the implementation. The assignment's d_ff=0 means blocks carry their own
up/down projections and there is no separate MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init, norm_apply, norm_init


# --------------------------------------------------------------------------
# chunked gated linear attention (mLSTM core)
# --------------------------------------------------------------------------
def gla_chunked(q, k, v, i_gate, logf, chunk: int):
    """S_t = f_t S_{t-1} + i_t k_t^T v_t;  n_t likewise with v=1;
    y_t = (q_t S_t) / max(|q_t n_t|, 1).

    q,k: (B,L,H,Dk); v: (B,L,H,Dv); i_gate: (B,L,H); logf: (B,L,H) (<=0).
    Returns y: (B,L,H,Dv), (S_final, n_final).
    """
    B, L, H, Dk = q.shape
    Dv = v.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q
    f32 = jnp.float32

    qr = q.reshape(B, nc, Q, H, Dk).astype(f32) * (Dk ** -0.5)
    kr = k.reshape(B, nc, Q, H, Dk).astype(f32)
    vr = v.reshape(B, nc, Q, H, Dv).astype(f32)
    ir = i_gate.reshape(B, nc, Q, H).astype(f32)
    cl = jnp.cumsum(logf.reshape(B, nc, Q, H).astype(f32), axis=2)

    seg = cl[:, :, :, None, :] - cl[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    qk = jnp.einsum("bcihd,bcjhd->bchij", qr, kr)
    irj = jnp.moveaxis(ir, 2, 3)[:, :, :, None, :]          # (B,nc,H,1,Q_j)
    w = qk * jnp.moveaxis(decay, -1, 2) * irj               # (B,nc,H,i,j)
    y_intra = jnp.einsum("bchij,bcjhv->bcihv", w, vr)
    # normalizer intra: sum_j decay_ij i_j (q_i . k_j) is exactly w row-sum
    n_intra_scalar = w.sum(-1)                              # (B,nc,H,Q)

    segl = jnp.exp(cl[:, :, -1:, :] - cl)                  # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcjh,bcjhd,bcjhv->bchdv", segl * ir, kr, vr)
    n_chunk = jnp.einsum("bcjh,bcjhd->bchd", segl * ir, kr)
    cdecay = jnp.exp(cl[:, :, -1, :])                      # (B,nc,H)

    def scan_fn(carry, inp):
        S_prev, n_prev = carry
        S_c, n_c, dec = inp
        S_new = dec[:, :, None, None] * S_prev + S_c
        n_new = dec[:, :, None] * n_prev + n_c
        return (S_new, n_new), (S_prev, n_prev)

    S0 = jnp.zeros((B, H, Dk, Dv), f32)
    n0 = jnp.zeros((B, H, Dk), f32)
    (S_f, n_f), (S_prevs, n_prevs) = jax.lax.scan(
        scan_fn, (S0, n0),
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(n_chunk, 1, 0),
         jnp.moveaxis(cdecay, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                  # (B,nc,H,Dk,Dv)
    n_prevs = jnp.moveaxis(n_prevs, 0, 1)                  # (B,nc,H,Dk)

    y_inter = jnp.einsum("bcihd,bcih,bchdv->bcihv", qr, jnp.exp(cl),
                         S_prevs)
    n_inter = jnp.einsum("bcihd,bcih,bchd->bcih", qr, jnp.exp(cl), n_prevs)

    y = y_intra + y_inter                                   # (B,nc,Q,H,Dv)
    n = jnp.moveaxis(n_intra_scalar, -1, 2)[..., None] + n_inter[..., None]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    return (
        y.reshape(B, L, H, Dv).astype(q.dtype),
        (S_f, n_f),
    )


def gla_ref(q, k, v, i_gate, logf):
    """Sequential oracle for gla_chunked."""
    B, L, H, Dk = q.shape
    Dv = v.shape[-1]
    f32 = jnp.float32

    def step(carry, inp):
        S, n = carry
        qt, kt, vt, it, ft = inp
        f = jnp.exp(ft)
        S = f[..., None, None] * S + it[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f[..., None] * n + it[..., None] * kt
        qs = qt * (Dk ** -0.5)
        num = jnp.einsum("bhd,bhdv->bhv", qs, S)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), 1.0)
        return (S, n), num / den[..., None]

    xs = tuple(
        jnp.moveaxis(a.astype(f32), 1, 0)
        for a in (q, k, v, i_gate, logf)
    )
    (S, n), ys = jax.lax.scan(step, (
        jnp.zeros((B, H, Dk, Dv), f32), jnp.zeros((B, H, Dk), f32)), xs)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype), (S, n)


# --------------------------------------------------------------------------
# mLSTM block
# --------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    di = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "norm": norm_init(cfg),
        "w_up": _dense_init(ks[0], (d, 2 * di), cfg.p_dtype),
        "conv_w": _dense_init(ks[1], (4, di), cfg.p_dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), cfg.p_dtype),
        "wq": _dense_init(ks[2], (di, di), cfg.p_dtype),
        "wk": _dense_init(ks[3], (di, di), cfg.p_dtype),
        "wv": _dense_init(ks[4], (di, di), cfg.p_dtype),
        "w_if": _dense_init(ks[5], (di, 2 * H), jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "w_down": _dense_init(ks[6], (di, d), cfg.p_dtype),
    }


def _conv4(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(K))
    return jax.nn.silu((y + b[None, None]).astype(jnp.float32)).astype(x.dtype)


def mlstm_apply(cfg: ModelConfig, p, x, *, chunk=128, return_state=False):
    B, L, d = x.shape
    H = cfg.n_heads
    di = 2 * d
    h = norm_apply(cfg, p["norm"], x)
    up = jnp.einsum("bld,de->ble", h, p["w_up"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    xc = _conv4(xm, p["conv_w"], p["conv_b"])
    q = jnp.einsum("ble,ef->blf", xc, p["wq"]).reshape(B, L, H, -1)
    k = jnp.einsum("ble,ef->blf", xc, p["wk"]).reshape(B, L, H, -1)
    v = jnp.einsum("ble,ef->blf", xm, p["wv"]).reshape(B, L, H, -1)
    gates = jnp.einsum("ble,ef->blf", xc.astype(jnp.float32), p["w_if"]) \
        + p["b_if"]
    i_gate = jax.nn.sigmoid(gates[..., :H])
    logf = jax.nn.log_sigmoid(gates[..., H:])
    y, (S, n) = gla_chunked(q, k, v, i_gate, logf, min(chunk, L))
    y = y.reshape(B, L, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("ble,ed->bld", y, p["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        conv_state = jnp.pad(xm, ((0, 0), (max(0, 3 - L), 0), (0, 0)))[:, -3:]
        return x + out, {"S": S, "n": n, "conv": conv_state}
    return x + out


def mlstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    H = cfg.n_heads
    d = cfg.d_model
    di = 2 * d
    h = norm_apply(cfg, p["norm"], x)
    up = jnp.einsum("bld,de->ble", h, p["w_up"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xm, z = jnp.split(up, 2, axis=-1)
    window = jnp.concatenate([state["conv"], xm], axis=1)   # (B,4,di)
    y = (window * p["conv_w"][None]).sum(1, keepdims=True) + p["conv_b"]
    xc = jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype)
    q = jnp.einsum("ble,ef->blf", xc, p["wq"]).reshape(B, H, -1)
    k = jnp.einsum("ble,ef->blf", xc, p["wk"]).reshape(B, H, -1)
    v = jnp.einsum("ble,ef->blf", xm, p["wv"]).reshape(B, H, -1)
    gates = jnp.einsum("ble,ef->blf", xc.astype(jnp.float32), p["w_if"]) \
        + p["b_if"]
    i_gate = jax.nn.sigmoid(gates[:, 0, :H])
    logf = jax.nn.log_sigmoid(gates[:, 0, H:])
    f = jnp.exp(logf)
    S = f[..., None, None] * state["S"] + i_gate[..., None, None] * (
        k[..., :, None].astype(jnp.float32)
        * v[..., None, :].astype(jnp.float32))
    n = f[..., None] * state["n"] + i_gate[..., None] * k.astype(jnp.float32)
    qs = q.astype(jnp.float32) * (q.shape[-1] ** -0.5)
    num = jnp.einsum("bhd,bhdv->bhv", qs, S)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n)), 1.0)
    yv = (num / den[..., None]).reshape(B, 1, di).astype(x.dtype)
    yv = yv * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", yv, p["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return x + out, {"S": S, "n": n, "conv": window[:, 1:]}


# --------------------------------------------------------------------------
# sLSTM block (sequential; hidden-state recurrence)
# --------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 3)
    return {
        "norm": norm_init(cfg),
        "w_in": _dense_init(ks[0], (d, 4 * d), cfg.p_dtype),
        "b_in": jnp.zeros((4 * d,), jnp.float32),
        "r": _dense_init(ks[1], (H, dh, 4 * dh), cfg.p_dtype,
                         scale=dh ** -0.5),
        "w_out": _dense_init(ks[2], (d, d), cfg.p_dtype),
    }


def _slstm_cell(cfg, p, carry, gx):
    """One sLSTM step. carry: (c, n, h, m) each (B,H,dh); gx: (B,4d)."""
    H = cfg.n_heads
    dh = cfg.d_model // H
    c, n, h, m = carry
    rec = jnp.einsum("bhd,hdf->bhf", h, p["r"].astype(jnp.float32))
    g = gx.reshape(*gx.shape[:-1], H, 4 * dh).astype(jnp.float32) + rec
    zi, fi, ii, oi = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    logf = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(logf + m, ii)
    i = jnp.exp(ii - m_new)
    f = jnp.exp(logf + m - m_new)
    c = f * c + i * z
    n = f * n + i
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new)


def slstm_apply(cfg: ModelConfig, p, x, *, return_state=False):
    B, L, d = x.shape
    H = cfg.n_heads
    dh = d // H
    xin = norm_apply(cfg, p["norm"], x)
    gx = jnp.einsum("bld,df->blf", xin, p["w_in"],
                    preferred_element_type=jnp.float32) + p["b_in"]

    def step(carry, g):
        carry = _slstm_cell(cfg, p, carry, g)
        return carry, carry[2]

    f32 = jnp.float32
    init = tuple(jnp.zeros((B, H, dh), f32) for _ in range(3)) + (
        jnp.full((B, H, dh), -1e9, f32),)
    carry, hs = jax.lax.scan(step, init, jnp.moveaxis(gx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, L, d).astype(x.dtype)
    out = jnp.einsum("bld,df->blf", hs, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        return x + out, {"c": carry[0], "n": carry[1], "h": carry[2],
                         "m": carry[3]}
    return x + out


def slstm_decode(cfg: ModelConfig, p, x, state):
    B = x.shape[0]
    d = cfg.d_model
    xin = norm_apply(cfg, p["norm"], x)
    gx = jnp.einsum("bld,df->blf", xin, p["w_in"],
                    preferred_element_type=jnp.float32) + p["b_in"]
    carry = (state["c"], state["n"], state["h"], state["m"])
    carry = _slstm_cell(cfg, p, carry, gx[:, 0])
    hs = carry[2].reshape(B, 1, d).astype(x.dtype)
    out = jnp.einsum("bld,df->blf", hs, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return x + out, {"c": carry[0], "n": carry[1], "h": carry[2],
                     "m": carry[3]}
