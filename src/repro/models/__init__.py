"""Model zoo: unified init/forward/prefill/decode over all assigned archs."""
from repro.models import layers, moe, ssm, transformer, xlstm
from repro.models.transformer import (
    decode_step,
    forward,
    init,
    init_cache,
    param_shapes,
    prefill,
)

__all__ = [
    "layers", "moe", "ssm", "transformer", "xlstm",
    "init", "forward", "prefill", "decode_step", "init_cache",
    "param_shapes",
]
