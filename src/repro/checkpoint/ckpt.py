"""Checkpointing: atomic, async, content-hashed, and ELASTIC.

Layout: <dir>/step_<N>/
  arrays.npz      — flattened pytree leaves (gathered to host)
  meta.json       — step, tree structure, shapes/dtypes, blake2 digest
  (tmp dir + atomic rename; a crash mid-write never corrupts the latest)

Elastic restore: leaves are saved unsharded (host-gathered) and restored via
``jax.make_array_from_callback`` against ANY target sharding — save on a
256-chip mesh, restore on 512 (or 1 CPU device for tests). For true
multi-host fleets the same layout shards by process: each host writes its
addressable shards; this container is single-process so the gather path is
exercised end-to-end and the per-host path is structured but trivial.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, arrs = [], []
    for path, leaf in leaves:
        names.append(jax.tree_util.keystr(path))
        arrs.append(leaf)
    return names, arrs, treedef


def _digest(arrs) -> str:
    h = hashlib.blake2b(digest_size=16)
    for a in arrs:
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def save(path, step: int, tree, *, blocking: bool = True) -> threading.Thread | None:
    """Write checkpoint for ``step``. blocking=False -> background thread
    (the training loop keeps stepping while the host writes)."""
    host_tree = jax.tree.map(np.asarray, tree)  # device->host (sync point)
    names, orig_arrs, _ = _flatten_with_names(host_tree)
    orig_dtypes = [str(a.dtype) for a in orig_arrs]
    orig_shapes = [list(a.shape) for a in orig_arrs]
    # bf16 arrays can't go through np.savez directly -> view as uint16;
    # meta.json records the ORIGINAL dtypes for decoding.
    arrs = [a.view(np.uint16) if str(a.dtype) == "bfloat16" else a
            for a in orig_arrs]

    def _write():
        base = pathlib.Path(path)
        base.mkdir(parents=True, exist_ok=True)
        final = base / f"step_{step:08d}"
        tmp = base / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz",
                 **{f"a{i}": a for i, a in enumerate(arrs)})
        meta = {
            "step": step,
            "time": time.time(),
            "names": names,
            "dtypes": orig_dtypes,
            "shapes": orig_shapes,
            "digest": _digest(arrs),
        }
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(path) -> int | None:
    base = pathlib.Path(path)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")]
    return max(steps) if steps else None


def restore(path, target, *, step: int | None = None, shardings=None,
            verify: bool = True):
    """Restore into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs). ``shardings``: matching pytree of NamedSharding for
    elastic placement; None -> host arrays.
    """
    base = pathlib.Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = base / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    try:
        with np.load(d / "arrays.npz") as z:
            arrs = [z[f"a{i}"] for i in range(len(meta["names"]))]
    except Exception as e:
        raise IOError(
            f"checkpoint digest/container corrupt at step {step}: {e}"
        ) from e
    # decode bf16 views
    out_arrs = []
    for a, dt, shp in zip(arrs, meta["dtypes"], meta["shapes"]):
        if dt == "bfloat16":
            a = a.view(jnp.bfloat16)
        out_arrs.append(a.reshape(shp))
    if verify:
        enc = [a.view(np.uint16) if a.dtype == jnp.bfloat16 else a
               for a in out_arrs]
        if _digest(enc) != meta["digest"]:
            raise IOError(f"checkpoint digest mismatch at step {step}")

    names, t_leaves, treedef = _flatten_with_names(target)
    by_name = dict(zip(meta["names"], out_arrs))
    missing = [n for n in names if n not in by_name]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")

    if shardings is None:
        leaves = [jnp.asarray(by_name[n]) for n in names]
    else:
        s_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        leaves = []
        for n, s in zip(names, s_leaves):
            host = by_name[n]
            leaves.append(jax.make_array_from_callback(
                host.shape, s, lambda idx, h=host: h[idx]))
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    return restored, step
