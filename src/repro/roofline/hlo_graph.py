"""Trip-count-aware collective-byte accounting over compiled HLO text.

The flat-text parse undercounts collectives inside ``while`` bodies (FSDP
all-gathers inside the layer scan run L times, not once). This module splits
the module into computations, builds the call graph (while/call/fusion/
conditional), extracts while trip counts from the condition computation's
compare-against-constant, and sums collective bytes with multipliers.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->", re.M)
_CALLED = re.compile(
    r"(?:body|to_apply|calls|branch_computations)=\{?%?([\w.\-,%\s]+?)\}?[,)]"
)
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Heuristic: the integer constant compared in the condition."""
    consts = {}
    for ln in cond_lines:
        m = re.match(r"%?([\w.\-]+) = \w+\[\] constant\((\d+)\)", ln)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for ln in cond_lines:
        if " compare(" in ln:
            for name, val in consts.items():
                if re.search(rf"%?{re.escape(name)}\b", ln.split("compare(")[1]):
                    return max(val, 1)
    return max(consts.values(), default=1)


def collective_bytes_weighted(hlo: str) -> dict[str, float]:
    comps = split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else None
    if entry is None:
        return {}

    memo: dict[str, dict[str, float]] = {}

    def walk(name: str, depth=0) -> dict[str, float]:
        if name in memo:
            return memo[name]
        if depth > 64 or name not in comps:
            return {}
        memo[name] = {}  # cycle guard
        total: dict[str, float] = {}
        for ln in comps[name]:
            m = re.match(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(", ln)
            if not m:
                continue
            shape_s, op = m.group(1), m.group(2)
            base = op.split(".")[0]
            if base.endswith("-done"):
                continue
            norm = base.replace("-start", "")
            if norm in _COLLECTIVES:
                total[norm] = total.get(norm, 0.0) + _shape_bytes(shape_s)
            if base == "while":
                bm, cm = _BODY.search(ln), _COND.search(ln)
                if bm:
                    trips = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                    sub = walk(bm.group(1), depth + 1)
                    for k, v in sub.items():
                        total[k] = total.get(k, 0.0) + trips * v
            elif base in ("call", "fusion", "conditional", "async-start"):
                cm2 = _CALLED.search(ln)
                if cm2:
                    for cname in re.split(r"[,\s%]+", cm2.group(1)):
                        if cname:
                            sub = walk(cname, depth + 1)
                            for k, v in sub.items():
                                total[k] = total.get(k, 0.0) + v
        memo[name] = total
        return total

    return walk(entry)
