"""Three-term roofline model from a compiled dry-run artifact.

  t_comp = HLO_FLOPs / (chips * peak)         [cost_analysis]
  t_mem  = HLO_bytes / (chips * HBM_bw)       [cost_analysis]
  t_coll = collective_bytes / (chips * ICI)   [parsed from the HLO text]

cost_analysis() on the SPMD-partitioned module reports *per-device* numbers
in current JAX; we detect whole-program counts (older behaviour) by checking
against the analytic model FLOPs and normalize to per-device. collective
bytes are summed over all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute operand sizes in the compiled module text.
"""
from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[4,128]{...}' -> bytes. Tuple shapes handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind.

    HLO lines look like:
      %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=...
    The lhs shape is the op *result*; for all-reduce result==operand size,
    for all-gather it is the gathered size (the bytes that crossed links up
    to a ring factor — a consistent, conservative proxy).
    """
    out: dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears after '=' ; op kind after the shape
        m = re.match(r"%?[\w.\-]+ = (.+?) (%?[\w\-]+)\(", s)
        if not m:
            continue
        kind = m.group(2).lstrip("%")
        base = kind.split(".")[0]
        # 'all-reduce-start'/'-done' pairs: count only '-start'
        if base.endswith("-done"):
            continue
        norm = base.replace("-start", "")
        if norm in _COLLECTIVES:
            out[norm] = out.get(norm, 0) + _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float            # 6·N_active·D (global, fwd+bwd) or serve
    peak_mem_per_device: float | None = None

    @property
    def t_comp(self) -> float:
        return self.flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def t_mem(self) -> float:
        return self.bytes_per_device / hw.HBM_BW

    @property
    def t_coll(self) -> float:
        return self.coll_bytes_per_device / hw.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap assumption)."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs (remat/redundancy waste)."""
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips / t) / hw.PEAK_FLOPS_BF16

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_comp_s": self.t_comp, "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_frac": self.useful_flops_fraction,
            "mfu": self.mfu,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell: 6·N_active·D for training,
    2·N_active·D(+attn KV reads folded into mem) per decoded token set."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence in the batch
    return 2.0 * n_active * shape.global_batch


def from_compiled(arch, shape_name, mesh_name, chips, cost, hlo_text,
                  model_flops, memory_stats=None) -> Roofline:
    """cost: compiled.cost_analysis() dict; hlo_text: compiled.as_text()."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = sum(collective_bytes(hlo_text).values())
    # detect whole-program counts and normalize to per-device
    if model_flops and flops > 3.0 * model_flops:
        flops /= chips
        byts /= chips
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll / chips,
        model_flops=model_flops,
        peak_mem_per_device=memory_stats,
    )
