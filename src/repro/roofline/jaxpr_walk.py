"""Shared jaxpr visitor: one recursion, many analyses.

Three consumers walk (post-trace) jaxprs in this repo — the roofline
FLOP/byte accounting (:mod:`repro.roofline.jaxpr_cost`), the compile-
flatness pins (``tests/test_compile_flatness.py``), and the jit-discipline
static analyzer (:mod:`repro.analysis.jaxpr_audit`) — and each needs the
same awkward piece: recursing through the call-like primitives
(``scan``/``while``/``cond``/``pjit``/``custom_*``) that hide nested
jaxprs inside their params, with the static trip multiplier that makes a
scan body count ``length`` times.

This module implements that recursion exactly once:

  * :func:`sub_jaxprs` — the ``(jaxpr, trip multiplier)`` pairs hidden in
    one equation's params;
  * :func:`walk` — depth-first ``visit(eqn, mult, path)`` over every
    equation, multiplying trip counts down the call tree; ``path`` is the
    equation-index chain (e.g. ``(3, 0, 7)`` = eqn 7 inside the callee of
    eqn 0 inside eqn 3) so analyses can report a stable location;
  * :func:`iter_eqns` / :func:`primitive_counts` / :func:`count_eqns` —
    multiplicity-free traversal helpers for program-*shape* questions
    ("same primitive multiset at F=2 and F=32?"), where a scan body must
    count once however many times it runs.

Keeping the recursion shared means a new call-like primitive (say a JAX
upgrade renaming ``pjit``) is taught to every analysis in one place.
"""
from __future__ import annotations

from typing import Callable, Iterator, Optional, Tuple

_CALL_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr")


def _inner(maybe_closed):
    """Unwrap a ClosedJaxpr to its Jaxpr (identity for open jaxprs)."""
    return maybe_closed.jaxpr if hasattr(maybe_closed, "jaxpr") else maybe_closed


def sub_jaxprs(eqn) -> Iterator[Tuple[object, int]]:
    """(jaxpr, trip multiplier) pairs for call-like primitives.

    ``scan`` yields its body once with ``length`` as the multiplier;
    ``while`` bodies count once (a conservative static bound — our stacks
    carry no unbounded model loops); every ``cond`` branch counts once
    (both branches are traced and compiled); ``pjit``/``remat``/
    ``custom_vjp`` call primitives pass straight through; a
    ``pallas_call`` yields its kernel jaxpr with the grid size (product
    of grid dims) as the multiplier — the kernel body runs once per grid
    step, so FLOP/byte models see the whole tiled sweep.
    """
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        yield p["jaxpr"], int(p["length"])
        return
    if name == "pallas_call":
        grid = getattr(p.get("grid_mapping"), "grid", ())
        steps = 1
        for g in grid:
            steps *= int(g)
        yield p["jaxpr"], steps
        return
    if name == "while":
        yield p["body_jaxpr"], 1
        yield p["cond_jaxpr"], 1
        return
    if name == "cond":
        for br in p["branches"]:
            yield br, 1
        return
    for key in _CALL_JAXPR_PARAMS:
        if key in p:
            yield p[key], 1


def walk(jaxpr, visit: Callable, *, mult: int = 1,
         path: Tuple[int, ...] = ()) -> None:
    """Depth-first ``visit(eqn, mult, path)`` over every equation.

    ``mult`` is the product of enclosing static trip counts (scan
    lengths); ``path`` the equation-index chain from the root. ``visit``
    may return the string ``"skip"`` to not descend into a call-like
    equation's nested jaxprs (default: always descend).
    """
    for i, eqn in enumerate(jaxpr.eqns):
        here = path + (i,)
        if visit(eqn, mult, here) == "skip":
            continue
        for sub, m in sub_jaxprs(eqn):
            walk(_inner(sub), visit, mult=mult * m, path=here)


def iter_eqns(jaxpr, path: Tuple[int, ...] = ()) -> Iterator[tuple]:
    """Yield ``(eqn, path)`` for every equation, each nested body ONCE.

    The multiplicity-free traversal: a scan body appears a single time
    regardless of its trip count, which is what program-shape comparisons
    (equation counts, primitive multisets) want.
    """
    for i, eqn in enumerate(jaxpr.eqns):
        here = path + (i,)
        yield eqn, here
        for sub, _ in sub_jaxprs(eqn):
            yield from iter_eqns(_inner(sub), here)


def count_eqns(jaxpr) -> int:
    """Total equation count, descending into nested jaxprs (each once)."""
    return sum(1 for _ in iter_eqns(jaxpr))


def primitive_counts(jaxpr, out: Optional[dict] = None) -> dict:
    """``{primitive name: count}`` multiset, each nested body counted once."""
    out = {} if out is None else out
    for eqn, _ in iter_eqns(jaxpr):
        out[eqn.primitive.name] = out.get(eqn.primitive.name, 0) + 1
    return out
