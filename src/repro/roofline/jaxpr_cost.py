"""Exact FLOP / HBM-byte accounting by walking the (post-AD) jaxpr.

XLA's ``compiled.cost_analysis()`` counts ``while`` (scan) bodies ONCE —
off by L x A for scanned-layer models (verified against 6ND: ~16x low for
qwen train_4k). This walker recurses through scan/pjit/remat/custom-vjp
call primitives multiplying by static trip counts, giving trip-accurate
totals. Byte accounting approximates post-fusion HBM traffic: matmul/conv
operands + outputs and gather/scatter traffic are counted in full; everything
else contributes its *output* once (producer->consumer fusion).

Numbers are GLOBAL (pre-SPMD); per-device = global / chips for our even
shardings. Used for the §Roofline compute/memory terms; cost_analysis() is
reported alongside as the raw artifact.

The recursion through call-like primitives lives in the shared visitor
:mod:`repro.roofline.jaxpr_walk` (also behind the jit-discipline
analyzer's jaxpr audit); this module contributes only the per-equation
FLOP/byte model.
"""
from __future__ import annotations

import math
from functools import partial

import jax

from repro.roofline.jaxpr_walk import sub_jaxprs, walk


def _nbytes(aval) -> int:
    try:
        return int(math.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _nelems(aval) -> int:
    try:
        return int(math.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(a.shape[i] for i in lb) if lb else 1
    k = math.prod(a.shape[i] for i in lc) if lc else 1
    m = math.prod(
        a.shape[i] for i in range(a.ndim) if i not in tuple(lc) + tuple(lb))
    n = math.prod(
        b.shape[i] for i in range(b.ndim) if i not in tuple(rc) + tuple(rb))
    return 2 * batch * m * n * k


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    kernel = math.prod(rhs.shape)
    return 2 * _nelems(out) * kernel // max(rhs.shape[-1], 1)


def _visit_cost(eqn, mult: int, acc: dict):
    """Per-equation FLOP/byte model (the shared walker handles recursion)."""
    name = eqn.primitive.name
    if next(sub_jaxprs(eqn), None) is not None:
        if name == "scan":
            # scan carries + stacked ys stream once per iteration
            acc["bytes"] += mult * sum(_nbytes(v.aval) for v in eqn.outvars)
        return  # nested bodies are visited by the walker itself
    out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
    out_e = sum(_nelems(v.aval) for v in eqn.outvars)
    if name == "dot_general":
        f = _dot_flops(eqn)
        acc["flops"] += mult * f
        acc["bytes"] += mult * (
            sum(_nbytes(v.aval) for v in eqn.invars) + out_b)
        acc["matmul_flops"] += mult * f
    elif name in ("conv_general_dilated",):
        acc["flops"] += mult * _conv_flops(eqn)
        acc["bytes"] += mult * (
            sum(_nbytes(v.aval) for v in eqn.invars) + out_b)
    elif name in ("gather", "dynamic_slice"):
        acc["bytes"] += mult * (out_b + out_b)  # read region + write out
        acc["flops"] += mult * out_e
    elif name in ("scatter", "scatter-add", "scatter_add",
                  "dynamic_update_slice"):
        # in-place update (donated buffer): traffic = touched region
        # read-modify-write, NOT a full-operand copy.
        upd_idx = 1 if name == "dynamic_update_slice" else 2
        upd_b = (_nbytes(eqn.invars[upd_idx].aval)
                 if len(eqn.invars) > upd_idx else out_b)
        acc["bytes"] += mult * 2 * upd_b
        acc["flops"] += mult * (upd_b // 4 + 1)
    else:
        acc["flops"] += mult * out_e            # elementwise estimate
        acc["bytes"] += mult * out_b            # fused: write output once


def jaxpr_cost(fn, *args, **kwargs) -> dict:
    """Trip-count-exact {flops, bytes, matmul_flops} (global, pre-SPMD)."""
    closed = jax.make_jaxpr(partial(fn, **kwargs))(*args)
    acc = {"flops": 0, "bytes": 0, "matmul_flops": 0}
    walk(closed.jaxpr, lambda eqn, mult, path: _visit_cost(eqn, mult, acc))
    return acc
