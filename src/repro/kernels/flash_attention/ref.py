"""Pure-jnp oracle for flash_attention (materializes the score matrix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, kv_len=None, *, causal=True, q_offset=0):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd); kv_len: (B,) or None."""
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]
        s = jnp.where(valid[:, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32))
    return out.astype(q.dtype)
