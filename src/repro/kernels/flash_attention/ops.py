"""Public wrapper: layout transform + padding for the flash kernel.

Model code calls with (B, S, H, hd) layout (same as layers.sdpa); the kernel
wants (B, H, S, hd) with block-aligned sequence lengths.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BK,
    DEFAULT_BQ,
    flash_attention_bhsd,
)


def flash_attention(q, k, v, *, causal=True, kv_len=None, q_offset=0,
                    bq=None, bk=None, interpret=True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd) — returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = bq or min(DEFAULT_BQ, Sq)
    bk = bk or min(DEFAULT_BK, Sk)
    Sqp = -(-Sq // bq) * bq
    Skp = -(-Sk // bk) * bk

    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    if kv_len is None:
        kv_len = jnp.full((B,), Sk, jnp.int32)

    out = flash_attention_bhsd(
        qt, kt, vt, kv_len, causal=causal, q_offset=q_offset, bq=bq, bk=bk,
        interpret=interpret)
    return jnp.moveaxis(out[:, :, :Sq], 1, 2)
