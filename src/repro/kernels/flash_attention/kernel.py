"""Blockwise online-softmax attention (FlashAttention) for TPU, with GQA.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the kv dimension is the
innermost (sequential) loop; running max / sum / accumulator live in VMEM
scratch and are rescaled per kv block. Causal blocks above the diagonal are
skipped via the mask (block-level early-out is a perf iteration recorded in
EXPERIMENTS.md §Perf). K/V are indexed at head ``h // group`` for GQA.

VMEM budget per step: q (BQ, hd) + k, v (BK, hd) + acc (BQ, hd) + scores
(BQ, BK), all fp32 — BQ = BK = 128, hd <= 256 keeps this well under 1 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30  # python scalar (pallas cannot capture jnp consts)


def _flash_kernel(qlen_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bq, bk, causal, q_offset,
                  scale):
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (bq, bk)

    qb = pl.program_id(2)
    qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < qlen_ref[0, 0]                     # kv_len bound
    if causal:
        mask &= qpos >= kpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                              # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "bq", "bk", "interpret"))
def flash_attention_bhsd(q, k, v, kv_len, *, causal=True, q_offset=0,
                         bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=True):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd); kv_len: (B,) int32.

    Sq % bq == 0 and Sk % bk == 0 (ops.py pads). Returns (B, H, Sq, hd).
    """
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    grid = (B, H, Sq // bq, Sk // bk)
    scale = hd ** -0.5

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, q_offset=q_offset,
        scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, i, j: (b, 0)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            # fp32 running accumulator / max / sum in VMEM scratch
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.reshape(B, 1).astype(jnp.int32), q, k, v)
