"""Public wrappers for the fused map kernels: pad, call, unpad.

Padding contracts (mirroring how the engine's masked site views present
out-of-site machines, so padded lanes are *semantically* masked
machines):

  * machine lanes -> multiple of 128 (the TPU lane width): start=BIG,
    p_dyn=0, qfree=0, eet columns=BIG;
  * task rows -> multiple of ``BLOCK_N``: pending=0 (padded tasks can
    never nominate, drop, or win a tie-break);
  * EET type rows -> multiple of 8 (f32 sublane): BIG (never gathered —
    padded task rows read type 0);
  * site lanes (``balance_scan``) -> multiple of 128: load=``BIG_INT``
    (never win the least-loaded argmin).

Callers pass the *unpadded* engine arrays; outputs come back unpadded.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.map_fused.kernel import (
    BIG,
    BIG_INT,
    BLOCK_N,
    DROP_KINDS,
    KEY_KINDS,
    NOMINATOR_KINDS,
    balance_scan_padded,
    evict_stats_padded,
    map_decide_padded,
)

_LANE = 128
_SUBLANE = 8


def _pad_up(n: int, mult: int) -> int:
    return max(mult, -(-n // mult) * mult)


def _pad_machine_state(start, p_dyn, qfree, eet):
    """Pad the lane (machine) and sublane (type) dims per the contract."""
    S, M = eet.shape
    Mp = _pad_up(M, _LANE)
    Sp = _pad_up(S, _SUBLANE)
    eet_p = jnp.full((Sp, Mp), BIG, jnp.float32).at[:S, :M].set(eet)
    start_p = jnp.full((Mp,), BIG, jnp.float32).at[:M].set(start)
    qfree_p = jnp.zeros((Mp,), jnp.int32).at[:M].set(
        qfree.astype(jnp.int32))
    pdyn_p = None
    if p_dyn is not None:
        pdyn_p = jnp.zeros((Mp,), jnp.float32).at[:M].set(p_dyn)
    return start_p, pdyn_p, qfree_p, eet_p


def _pad_tasks(deadline, *int_arrays):
    """Pad task arrays to the tile size; integer arrays pad with 0."""
    N = deadline.shape[0]
    Np = _pad_up(N, BLOCK_N)
    dl_p = jnp.zeros((Np,), jnp.float32).at[:N].set(deadline)
    out = [dl_p]
    for a in int_arrays:
        out.append(jnp.zeros((Np,), jnp.int32).at[:N].set(
            a.astype(jnp.int32)))
    return out


def map_decide(now, start, p_dyn, qfree, eet, deadline, pending, task_type,
               suffered_task, *, nominator: str, phase2_key: str,
               drop_rule: str, interpret: bool):
    """One fused pass: drop mask + per-machine Phase-II running argmins.

    Args mirror a :class:`~repro.core.policy.context.SchedContext`:
    ``start`` is the (M,) post-queue start time ``max(avail, now)``,
    ``qfree`` the (M,) free-slot mask, ``eet`` the (S, M) table,
    ``suffered_task`` the (N,) suffered-pending mask (all-False for
    non-fairness policies — the hi pool stays empty and the epilogue
    reduces to plain Phase-II).

    Returns ``(drop (N,) bool, hi_key (M,), hi_task (M,), lo_key (M,),
    lo_task (M,))``; a machine with ``key < BIG`` has a nominee, whose
    task index is the paired entry.
    """
    if nominator not in NOMINATOR_KINDS:
        raise ValueError(f"unsupported nominator kind {nominator!r}")
    if phase2_key not in KEY_KINDS:
        raise ValueError(f"unsupported phase2 key kind {phase2_key!r}")
    if drop_rule not in DROP_KINDS:
        raise ValueError(f"unsupported drop rule kind {drop_rule!r}")
    N = deadline.shape[0]
    M = eet.shape[1]
    start_p, pdyn_p, qfree_p, eet_p = _pad_machine_state(
        start, p_dyn, qfree, eet)
    dl_p, pend_p, tt_p, suff_p = _pad_tasks(
        deadline, pending, task_type, suffered_task)
    drop, hi_key, hi_task, lo_key, lo_task = map_decide_padded(
        jnp.asarray(now, jnp.float32), start_p, pdyn_p, qfree_p, eet_p,
        dl_p, pend_p, tt_p, suff_p, nominator=nominator,
        phase2_key=phase2_key, drop_rule=drop_rule, n_machines=M,
        interpret=interpret)
    return (drop[:N, 0] != 0, hi_key[0, :M], hi_task[0, :M],
            lo_key[0, :M], lo_task[0, :M])


def evict_stats(start, qfree, eet, deadline, pending, task_type, *,
                interpret: bool):
    """Per-task eviction-planner stats over the pre-eviction grid.

    Returns ``(task_feas_now (N,) bool, min_exec (N,) f32)`` — feasible
    right now on some free machine, and the fastest EET of the task's
    type — exactly the two grid reductions
    ``core/policy/fair.py:_plan_eviction`` derives from the (N, M) grid.
    """
    N = deadline.shape[0]
    start_p, _, qfree_p, eet_p = _pad_machine_state(
        start, None, qfree, eet)
    dl_p, pend_p, tt_p = _pad_tasks(deadline, pending, task_type)
    feas, min_exec = evict_stats_padded(
        start_p, qfree_p, eet_p, dl_p, pend_p, tt_p, interpret=interpret)
    return feas[:N, 0] != 0, min_exec[:N, 0]


def balance_scan(load0, unassigned, target, home, *, interpret: bool):
    """The sequential least-loaded dispatch scan as one kernel call.

    Contract matches the lax scan in
    ``core/dispatch/base.py:sequential_balance``: ``load0`` (F,) i32
    initial per-site loads (dead-site penalties already applied),
    ``unassigned``/``target`` (N,) bool, ``home`` (N,) i32. Returns the
    (N,) i32 site choice for every task.
    """
    N = unassigned.shape[0]
    F = load0.shape[0]
    Fp = _pad_up(F, _LANE)
    Np = _pad_up(N, _LANE)
    load_p = jnp.full((Fp,), BIG_INT, jnp.int32).at[:F].set(
        load0.astype(jnp.int32))
    new_p = jnp.zeros((Np,), jnp.int32).at[:N].set(
        unassigned.astype(jnp.int32))
    tgt_p = jnp.zeros((Np,), jnp.int32).at[:N].set(
        target.astype(jnp.int32))
    home_p = jnp.zeros((Np,), jnp.int32).at[:N].set(
        home.astype(jnp.int32))
    sites = balance_scan_padded(load_p, new_p, tgt_p, home_p, n_tasks=N,
                                interpret=interpret)
    return sites[0, :N]
