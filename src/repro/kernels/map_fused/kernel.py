"""Pallas TPU kernels for the fused per-event map decision.

One grid pass over the (tasks x machines) EET grid computes everything a
two-phase mapping event needs: Eq. 1 completion / Eq. 2 energy
feasibility, the Phase-I nomination of each pending task, the drop-rule
mask, and the Phase-II per-machine minimum-key nominee — accumulated
across task tiles into lane-resident (1, Mp) running argmins for the
suffered (hi) and non-suffered (lo) nominee pools, so the FELARE
priority Phase-II is a two-line lax epilogue over the kernel outputs.

Tiling mirrors ``kernels/phase1_map``: tasks are tiled ``BLOCK_N`` per
grid step, the (padded) machine dim stays lane-resident, and the
(padded) EET table rides along whole so task-type rows are gathered
in-kernel with an exact one-hot dot (one 1.0 per row — the sum is a
single product, bit-exact). Padding contracts (see ``ops.py``): padded
machine lanes read start=BIG / qfree=0 / eet=BIG — byte-identical to
how the engine's masked site views already present out-of-site machines
— and padded task rows read pending=0, so neither can nominate, win a
tie-break, or affect a row min.

Every arithmetic expression deliberately matches the lax policy path op
for op (``core/policy/components.py``, ``core/policy/base.py:phase2``,
``core/equations.py``): min/argmin are order-exact, cross-tile
accumulation uses strict ``<`` improvement so the argmin lowest-index
tie-break is preserved, and the energy score ``where(feas, pdyn*e,
BIG)`` equals the masked Eq. 2 because feasibility implies the on-time
branch. Bit-exactness is pinned event-level in
``tests/test_map_fused.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30  # python scalar: jnp constants become captured consts in pallas
BIG_INT = 1 << 30  # int load pad: above any dead-site penalty + queue load
BLOCK_N = 128

#: Nominator / Phase-II key / drop-rule kinds the kernel implements —
#: exactly the builtin composition space (all 8 paper heuristics).
NOMINATOR_KINDS = ("min_energy_feasible", "min_completion",
                   "min_execution", "random_hash")
KEY_KINDS = ("value", "deadline", "urgency", "fcfs")
DROP_KINDS = ("stale", "stale_hopeless")


def _type_rows(ttype, eet):
    """(bn, Mp) EET row of each task's type, via an exact one-hot dot."""
    bn = ttype.shape[0]
    sp = eet.shape[0]
    onehot = (ttype == jax.lax.broadcasted_iota(
        jnp.int32, (bn, sp), 1)).astype(jnp.float32)
    return jnp.dot(onehot, eet, preferred_element_type=jnp.float32)


def _nominate(kind, *, s, e, d, pend, alive, qfree, pdyn, now, gidx,
              n_machines):
    """Phase-I: (best (bn,1) i32, value (bn,1) f32, valid (bn,1) bool).

    Mirrors the lax nominators in ``core/policy/components.py`` op for
    op (same masks, same BIG sentinel, same argmin tie-break).
    """
    if kind == "random_hash":
        h = (gidx.astype(jnp.uint32) * jnp.uint32(2654435761)
             + (now * 1e3).astype(jnp.uint32)) % jnp.uint32(n_machines)
        return h.astype(jnp.int32), gidx.astype(jnp.float32), alive
    if kind == "min_energy_feasible":
        feas = (s + e <= d) & pend & qfree
        score = jnp.where(feas, pdyn * e, BIG)
    elif kind == "min_completion":
        on_time = s + e <= d
        started = s < d
        comp = jnp.where(on_time, s + e,
                         jnp.where(started, jnp.broadcast_to(d, e.shape),
                                   jnp.broadcast_to(s, e.shape)))
        score = jnp.where(alive & qfree, comp, BIG)
    elif kind == "min_execution":
        score = jnp.where(alive & qfree, e, BIG)
    else:  # pragma: no cover - ops.py validates kinds
        raise ValueError(f"unsupported nominator kind {kind!r}")
    value = jnp.min(score, axis=1, keepdims=True)
    best = jnp.argmin(score, axis=1, keepdims=True).astype(jnp.int32)
    return best, value, value < BIG


def _phase2_key(kind, *, value, d, e, best, now, gidx):
    """(bn, 1) Phase-II tie-break key — lower = better, lax-exact."""
    if kind == "value":
        return value
    if kind == "deadline":
        return d + 1e-6 * value
    if kind == "urgency":
        bn, mp = e.shape
        lanes = jax.lax.broadcasted_iota(jnp.int32, (bn, mp), 1)
        e_best = jnp.sum(jnp.where(lanes == best, e, 0.0), axis=1,
                         keepdims=True)
        slack = d - now - e_best
        return -(1.0 / jnp.where(jnp.abs(slack) < 1e-9, 1e-9, slack))
    if kind == "fcfs":
        return gidx.astype(jnp.float32)
    raise ValueError(f"unsupported key kind {kind!r}")  # pragma: no cover


def _map_decide_kernel(now_ref, start_ref, pdyn_ref, qfree_ref, eet_ref,
                       dl_ref, pend_ref, ttype_ref, suff_ref,
                       drop_ref, hikey_ref, hitask_ref, lokey_ref,
                       lotask_ref, *, nominator, phase2_key, drop_rule,
                       n_machines):
    """Block shapes:
    now: (1, 1); start/pdyn/qfree: (1, Mp) VMEM-resident machine state;
    eet: (Sp, Mp) whole padded table; dl/pend/ttype/suff: (BLOCK_N, 1).
    Outputs: drop (BLOCK_N, 1) per tile; hi/lo key+task (1, Mp)
    accumulated across tiles (constant out index map).
    """
    i = pl.program_id(0)
    mp = start_ref.shape[1]

    @pl.when(i == 0)
    def _init():
        hikey_ref[...] = jnp.full((1, mp), BIG, jnp.float32)
        hitask_ref[...] = jnp.zeros((1, mp), jnp.int32)
        lokey_ref[...] = jnp.full((1, mp), BIG, jnp.float32)
        lotask_ref[...] = jnp.zeros((1, mp), jnp.int32)

    now = now_ref[0, 0]
    s = start_ref[...]                        # (1, Mp) broadcast
    pdyn = pdyn_ref[...]
    qfree = qfree_ref[...] != 0
    d = dl_ref[...]                           # (bn, 1)
    pend = pend_ref[...] != 0
    suff = suff_ref[...] != 0
    bn = d.shape[0]
    gidx = (i * BLOCK_N
            + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0))

    e = _type_rows(ttype_ref[...], eet_ref[...])          # (bn, Mp)
    min_exec = jnp.min(e, axis=1, keepdims=True)          # pad lanes = BIG
    stale = pend & (now >= d)
    alive = pend & ~stale

    # -- drop rule (view-independent: identical on pre/post-eviction ctx) --
    if drop_rule == "stale_hopeless":
        drop = stale | (pend & (now + min_exec > d))
    else:
        drop = stale
    drop_ref[...] = drop.astype(jnp.int32)

    # -- Phase-I nomination + Phase-II key --------------------------------
    best, value, valid = _nominate(
        nominator, s=s, e=e, d=d, pend=pend, alive=alive, qfree=qfree,
        pdyn=pdyn, now=now, gidx=gidx, n_machines=n_machines)
    key = _phase2_key(phase2_key, value=value, d=d, e=e, best=best,
                      now=now, gidx=gidx)

    # -- Phase-II tile reduction + cross-tile running argmin --------------
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bn, mp), 1)
    nominee = valid & (best == lanes)
    for pool_suff, key_ref, task_ref in (
            (True, hikey_ref, hitask_ref), (False, lokey_ref, lotask_ref)):
        pool = nominee & (suff if pool_suff else ~suff)
        masked = jnp.where(pool, key, BIG)
        tile_min = jnp.min(masked, axis=0, keepdims=True)       # (1, Mp)
        tile_task = (i * BLOCK_N
                     + jnp.argmin(masked, axis=0, keepdims=True)
                     .astype(jnp.int32))
        # strict < keeps the earliest tile on ties; within-tile argmin
        # keeps the lowest row — together the global lowest-index
        # tie-break of jnp.argmin(axis=0).
        better = tile_min < key_ref[...]
        key_ref[...] = jnp.where(better, tile_min, key_ref[...])
        task_ref[...] = jnp.where(better, tile_task, task_ref[...])


@functools.partial(
    jax.jit,
    static_argnames=("nominator", "phase2_key", "drop_rule", "n_machines",
                     "interpret"))
def map_decide_padded(now, start, p_dyn, qfree, eet, deadline, pending,
                      task_type, suffered_task, *, nominator, phase2_key,
                      drop_rule, n_machines, interpret: bool):
    """Padded entry: N % BLOCK_N == 0, machine/type dims lane/sublane
    padded (start=BIG, qfree=0, eet=BIG, pending=0 in the padding)."""
    N = deadline.shape[0]
    Sp, Mp = eet.shape
    grid = (N // BLOCK_N,)
    machine_row = pl.BlockSpec((1, Mp), lambda i: (0, 0))
    task_col = pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0))
    acc_row = pl.BlockSpec((1, Mp), lambda i: (0, 0))
    kernel = functools.partial(
        _map_decide_kernel, nominator=nominator, phase2_key=phase2_key,
        drop_rule=drop_rule, n_machines=n_machines)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            machine_row, machine_row, machine_row,
            pl.BlockSpec((Sp, Mp), lambda i: (0, 0)),
            task_col, task_col, task_col, task_col,
        ],
        out_specs=[task_col, acc_row, acc_row, acc_row, acc_row],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, Mp), jnp.float32),
            jax.ShapeDtypeStruct((1, Mp), jnp.int32),
            jax.ShapeDtypeStruct((1, Mp), jnp.float32),
            jax.ShapeDtypeStruct((1, Mp), jnp.int32),
        ],
        interpret=interpret,
    )(
        now.reshape(1, 1), start.reshape(1, Mp), p_dyn.reshape(1, Mp),
        qfree.reshape(1, Mp), eet, deadline.reshape(N, 1),
        pending.reshape(N, 1), task_type.reshape(N, 1),
        suffered_task.reshape(N, 1),
    )


def _evict_stats_kernel(start_ref, qfree_ref, eet_ref, dl_ref, pend_ref,
                        ttype_ref, feas_ref, minexec_ref):
    """Per-task grid reductions for the Sec. V eviction planner:
    feasible-now on some free machine (any) and fastest EET (min)."""
    s = start_ref[...]                        # (1, Mp)
    qfree = qfree_ref[...] != 0
    d = dl_ref[...]                           # (bn, 1)
    pend = pend_ref[...] != 0
    e = _type_rows(ttype_ref[...], eet_ref[...])
    feas_now = (s + e <= d) & pend
    feas_ref[...] = jnp.any(feas_now & qfree, axis=1,
                            keepdims=True).astype(jnp.int32)
    minexec_ref[...] = jnp.min(e, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def evict_stats_padded(start, qfree, eet, deadline, pending, task_type, *,
                       interpret: bool):
    """Padded entry for the eviction-stats pass (same contracts as
    :func:`map_decide_padded`, pre-eviction machine state)."""
    N = deadline.shape[0]
    Sp, Mp = eet.shape
    grid = (N // BLOCK_N,)
    machine_row = pl.BlockSpec((1, Mp), lambda i: (0, 0))
    task_col = pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _evict_stats_kernel,
        grid=grid,
        in_specs=[
            machine_row, machine_row,
            pl.BlockSpec((Sp, Mp), lambda i: (0, 0)),
            task_col, task_col, task_col,
        ],
        out_specs=[task_col, task_col],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        start.reshape(1, Mp), qfree.reshape(1, Mp), eet,
        deadline.reshape(N, 1), pending.reshape(N, 1),
        task_type.reshape(N, 1),
    )


def _balance_kernel(load_ref, new_ref, tgt_ref, home_ref, out_ref, *,
                    n_tasks):
    """The dispatcher's sequential least-loaded scan, in-kernel.

    One grid step; the (1, Fp) load vector stays register/VMEM-resident
    across the whole admission walk instead of round-tripping through a
    lax.scan carry. Mirrors ``core/dispatch/base.py:sequential_balance``
    step for step (integer arithmetic, argmin lowest-index ties).
    """
    fp = load_ref.shape[1]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, fp), 1)

    def body(k, load):
        best = jnp.argmin(load).astype(jnp.int32)
        s = jnp.where(tgt_ref[0, k] != 0, best, home_ref[0, k])
        out_ref[0, k] = s
        return load + jnp.where((lanes == s) & (new_ref[0, k] != 0), 1, 0)

    jax.lax.fori_loop(0, n_tasks, body, load_ref[...])


@functools.partial(jax.jit, static_argnames=("n_tasks", "interpret"))
def balance_scan_padded(load0, new, tgt, home, *, n_tasks: int,
                        interpret: bool):
    """Padded entry: site lanes padded with ``BIG_INT`` load (never win
    an argmin); task columns beyond ``n_tasks`` are never visited."""
    Fp = load0.shape[0]
    Np = new.shape[0]
    row = pl.BlockSpec((1, Np), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_balance_kernel, n_tasks=n_tasks),
        grid=(1,),
        in_specs=[pl.BlockSpec((1, Fp), lambda i: (0, 0)), row, row, row],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((1, Np), jnp.int32),
        interpret=interpret,
    )(
        load0.reshape(1, Fp), new.reshape(1, Np), tgt.reshape(1, Np),
        home.reshape(1, Np),
    )
