"""Fused Pallas map-decision kernels: the whole per-event scheduling
decision as one tiled, VMEM-resident pass over the (N x M) EET grid.

Three kernels (see :mod:`repro.kernels.map_fused.kernel`):

  * ``map_decide`` — Eq. 1 completion / Eq. 2 energy feasibility,
    Phase-I nomination, drop rules, and the Phase-II per-machine
    running-argmin accumulation for the suffered/non-suffered nominee
    split, in one grid pass;
  * ``evict_stats`` — the per-task grid reductions the Sec. V fairness
    eviction planner needs (feasible-now-anywhere, fastest EET);
  * ``balance_scan`` — the dispatcher's sequential least-loaded
    assignment scan over simultaneous admissions.

Public wrappers (pad, call, unpad) live in
:mod:`repro.kernels.map_fused.ops`; the policy- and dispatcher-level
entry points are :func:`repro.core.policy.with_pallas_map` and
:func:`repro.core.dispatch.with_pallas_balance`. The lax path remains
the default; kernel-vs-lax bit-exactness is pinned by
``tests/test_map_fused.py``.
"""
from repro.kernels.map_fused.ops import balance_scan, evict_stats, map_decide

__all__ = ["balance_scan", "evict_stats", "map_decide"]
