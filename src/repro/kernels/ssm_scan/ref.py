"""Pure-jnp sequential oracle for the SSD scan kernel."""
from __future__ import annotations

from repro.models.ssm import ssd_ref


def ssm_scan_ref(x, dt, A, Bm, Cm):
    """x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm/Cm: (B, L, N)."""
    return ssd_ref(x, dt, A, Bm, Cm)
