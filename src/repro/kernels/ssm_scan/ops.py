"""Public wrapper for the SSD kernel ((B, L, H, P) model layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssd_scan_bhlp


def ssm_scan(x, dt, A, Bm, Cm, *, chunk=128, interpret=True):
    """Same contract as repro.models.ssm.ssd_chunked.

    x: (B, L, H, P); dt: (B, L, H); A: (H,); Bm/Cm: (B, L, N).
    Returns (y (B, L, H, P), final_state (B, H, N, P)).
    """
    B, L, H, P = x.shape
    Q = min(chunk, L)
    assert L % Q == 0, f"seq {L} not divisible by chunk {Q}"
    xt = jnp.moveaxis(x, 2, 1)                              # (B, H, L, P)
    dtt = jnp.moveaxis(dt, 2, 1)[..., None]                 # (B, H, L, 1)
    loga = dtt * A[None, :, None, None]
    y, S = ssd_scan_bhlp(xt, dtt.astype(jnp.float32),
                         loga.astype(jnp.float32),
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                         Q=Q, interpret=interpret)
    return jnp.moveaxis(y, 1, 2), S
