"""Chunked SSD (Mamba2) scan kernel for TPU.

Grid: (batch, heads, chunks) with the chunk dim innermost (sequential); the
(N, P) state matrix lives in VMEM scratch and carries across chunks. All
intra-chunk work is (Q x Q)/(Q x N)/(N x P) matmuls — MXU-shaped, the
TPU-native reformulation of the GPU selective-scan (DESIGN.md §2).

Block layout per step: x (Q, P), dt/loga (Q, 1), B/C (Q, N); VMEM footprint
~ Q*(P + 2N) + Q*Q + N*P fp32 — Q=128, N=64, P=64 is ~150 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, loga_ref, b_ref, c_ref, y_ref, s_out_ref,
                state_ref, *, Q):
    cb = pl.program_id(2)
    n_cb = pl.num_programs(2)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)           # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)         # (Q, 1)
    loga = loga_ref[0, 0].astype(jnp.float32)     # (Q, 1)
    B = b_ref[0].astype(jnp.float32)              # (Q, N)
    C = c_ref[0].astype(jnp.float32)              # (Q, N)

    cl = jnp.cumsum(loga, axis=0)                 # (Q, 1) inclusive
    seg = cl - cl.T                               # (Q, Q) = cl_i - cl_j
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(causal, jnp.exp(seg), 0.0)

    CB = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = CB * decay * dt.T                         # (Q, Q), weight on j
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    S = state_ref[...]                            # (N, P)
    y += jnp.exp(cl) * jax.lax.dot_general(
        C, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    segl = jnp.exp(cl[-1:] - cl)                  # (Q, 1)
    xw = x * (segl * dt)                          # (Q, P)
    S_new = jnp.exp(cl[-1, 0]) * S + jax.lax.dot_general(
        B, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_ref[...] = S_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(cb == n_cb - 1)
    def _finish():
        s_out_ref[0, 0] = S_new


@functools.partial(jax.jit, static_argnames=("Q", "interpret"))
def ssd_scan_bhlp(x, dt, loga, Bm, Cm, *, Q, interpret=True):
    """x: (B, H, L, P); dt/loga: (B, H, L, 1); Bm/Cm: (B, L, N); L % Q == 0.

    Returns y: (B, H, L, P), final state (B, H, N, P) fp32.
    """
    B, H, L, P = x.shape
    N = Bm.shape[-1]
    grid = (B, H, L // Q)
    kernel = functools.partial(_ssd_kernel, Q=Q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, loga, Bm, Cm)
