"""Pure-jnp oracle for decode_attention."""
from __future__ import annotations

from repro.kernels.flash_attention.ref import flash_attention_ref


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, H, 1, hd); k, v: (B, Hkv, Sk, hd); kv_len: (B,)."""
    return flash_attention_ref(q, k, v, kv_len, causal=False)
