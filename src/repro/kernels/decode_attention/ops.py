"""Public wrapper for the decode kernel ((B, 1, H, hd) model layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    DEFAULT_BK,
    decode_attention_bhd,
)


def decode_attention(q, k, v, kv_len, *, bk=None, interpret=True):
    """q: (B, 1, H, hd); k, v: (B, Sk, Hkv, hd); kv_len: (B,)."""
    B, _, H, hd = q.shape
    Sk = k.shape[1]
    bk = bk or min(DEFAULT_BK, Sk)
    Skp = -(-Sk // bk) * bk
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if Skp != Sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    out = decode_attention_bhd(qt, kt, vt, kv_len, bk=bk, interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
