"""Single-token decode attention: one query row vs a long KV cache.

The decode hot spot is *memory*-bound: the whole KV cache streams from HBM
once per token. Grid: (B, H, kv_blocks); the single query row stays resident
while KV blocks stream through VMEM with an online-softmax running state —
two fp32 scalars + one (1, hd) accumulator per (b, h).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30  # python scalar (pallas cannot capture jnp consts)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, bk, scale):
    kb = pl.program_id(2)
    n_kb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :].astype(jnp.float32) * scale       # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bk)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(kpos < len_ref[0, 0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == n_kb - 1)
    def _finish():
        o_ref[0, 0, :] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_bhd(q, k, v, kv_len, *, bk=DEFAULT_BK, interpret=True):
    """q: (B, H, 1, hd); k, v: (B, Hkv, Sk, hd); kv_len: (B,)."""
    B, H, _, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    grid = (B, H, Sk // bk)
    kernel = functools.partial(_decode_kernel, bk=bk, scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.reshape(B, 1).astype(jnp.int32), q, k, v)
