"""Shared Pallas backend selection: compiled on TPU/GPU, interpret on CPU.

Every Pallas kernel in this repo (``kernels/phase1_map``,
``kernels/map_fused``) takes an ``interpret`` flag. Compiled Mosaic
kernels only exist for accelerator backends; on a CPU-only host the
same kernel body runs under the Pallas interpreter — slower, but
bit-exact and testable anywhere. This module owns the one decision
both kernels share:

  * :func:`default_interpret` — ``True`` on CPU (interpreter),
    ``False`` on TPU/GPU (compiled), overridable with the environment
    variable ``REPRO_PALLAS_INTERPRET`` (``"1"`` forces the
    interpreter, ``"0"`` forces compilation).

The env read happens when the *caller* resolves the flag — policy and
dispatcher wrappers (``with_pallas_map``/``with_pallas_balance``/
``with_pallas_phase1``) resolve it at construction time and bake the
result into a frozen field, so no host effect (``os.environ`` read)
ever runs inside a jitted ``select``/``dispatch`` body (analyzer rule
JD003).
"""
from __future__ import annotations

import os

#: Environment override: "1" forces interpret mode, "0" forces compiled.
ENV_VAR = "REPRO_PALLAS_INTERPRET"


def default_interpret() -> bool:
    """Should Pallas kernels run under the interpreter on this host?

    ``REPRO_PALLAS_INTERPRET`` wins when set to ``"0"`` or ``"1"``
    (anything else raises — a silent typo would silently change which
    program runs). Otherwise autodetect: compiled kernels on TPU/GPU
    default backends, the interpreter everywhere else (CPU).
    """
    env = os.environ.get(ENV_VAR)
    if env is not None:
        if env not in ("0", "1"):
            raise ValueError(
                f"{ENV_VAR} must be '0' or '1', got {env!r}"
            )
        return env == "1"
    import jax

    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
