"""Pure-jnp oracle for the phase1_map kernel (mirrors heuristics Phase-I)."""
from __future__ import annotations

import jax.numpy as jnp

BIG = jnp.float32(1e30)


def phase1_map_ref(avail, p_dyn, qfree, eet_rows, deadline, pending):
    """avail/p_dyn/qfree: (M,); eet_rows: (N, M); deadline/pending: (N,).

    Returns (best_m (N,) int32, best_ec (N,) f32 — BIG when infeasible).
    """
    s = avail[None, :]
    feas = ((s + eet_rows <= deadline[:, None])
            & pending[:, None].astype(bool)
            & qfree[None, :].astype(bool))
    ec = jnp.where(feas, p_dyn[None, :] * eet_rows, BIG)
    return jnp.argmin(ec, axis=1).astype(jnp.int32), jnp.min(ec, axis=1)
