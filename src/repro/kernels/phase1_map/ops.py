"""jit'd public wrapper for phase1_map: pad, call kernel, unpad.

Contract matches repro.core.heuristics.elare_phase1's ``phase1_impl`` hook:
  phase1_map(avail, eet_rows, deadline, p_dyn, pending, qfree)
    -> (best_m (N,), best_ec (N,))

``interpret=None`` (the default) resolves the backend via
:func:`repro.kernels.pallas_backend.default_interpret`: compiled Mosaic
on TPU/GPU, the Pallas interpreter on CPU, overridable with
``REPRO_PALLAS_INTERPRET``. The resolution happens per call here (this
wrapper is invoked from inside a nominator), so callers on the jitted
path should resolve the flag themselves once and pass it explicitly —
``with_pallas_phase1`` does.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.pallas_backend import default_interpret
from repro.kernels.phase1_map.kernel import BLOCK_N, phase1_map_padded

_LANE = 128


def phase1_map(avail, eet_rows, deadline, p_dyn, pending, qfree, *,
               interpret=None):
    if interpret is None:
        interpret = default_interpret()
    N, M = eet_rows.shape
    Np = -(-N // BLOCK_N) * BLOCK_N
    Mp = max(_LANE, -(-M // _LANE) * _LANE)

    eet_p = jnp.zeros((Np, Mp), jnp.float32).at[:N, :M].set(eet_rows)
    avail_p = jnp.zeros((Mp,), jnp.float32).at[:M].set(avail)
    pdyn_p = jnp.zeros((Mp,), jnp.float32).at[:M].set(p_dyn)
    qfree_p = jnp.zeros((Mp,), jnp.int32).at[:M].set(qfree.astype(jnp.int32))
    dl_p = jnp.zeros((Np,), jnp.float32).at[:N].set(deadline)
    pend_p = jnp.zeros((Np,), jnp.int32).at[:N].set(pending.astype(jnp.int32))

    bm, bec = phase1_map_padded(
        avail_p, pdyn_p, qfree_p, eet_p, dl_p, pend_p, interpret=interpret)
    return bm[:N, 0], bec[:N, 0]
