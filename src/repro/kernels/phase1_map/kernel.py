"""Pallas TPU kernel for ELARE Phase-I (Algorithm 2, fused).

One pass over the (tasks x machines) grid computes completion times (Eq. 1),
expected energies (Eq. 2), the feasibility mask, and the per-task masked
argmin over machines — the scheduler's hot loop as a single VMEM-resident
kernel. Tasks are tiled ``BLOCK_N`` per grid step; the (padded) machine dim
stays lane-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30  # python scalar: jnp constants become captured consts in pallas
BLOCK_N = 128


def _phase1_kernel(avail_ref, pdyn_ref, qfree_ref, eet_ref, dl_ref,
                   pend_ref, bestm_ref, bestec_ref):
    """Block shapes:
    avail/pdyn/qfree: (1, Mp) VMEM-resident machine state
    eet: (BLOCK_N, Mp); dl/pend: (BLOCK_N, 1)
    out bestm: (BLOCK_N, 1) int32; bestec: (BLOCK_N, 1) f32
    """
    e = eet_ref[...]                          # (bn, Mp)
    s = avail_ref[...]                        # (1, Mp) broadcast
    d = dl_ref[...]                           # (bn, 1)
    pend = pend_ref[...] != 0                 # (bn, 1)
    qfree = qfree_ref[...] != 0               # (1, Mp)

    feas = (s + e <= d) & pend & qfree        # (bn, Mp)
    ec = pdyn_ref[...] * e                    # Eq. 2 middle row (feasible)
    ec = jnp.where(feas, ec, BIG)
    bestec_ref[...] = jnp.min(ec, axis=1, keepdims=True)
    bestm_ref[...] = jnp.argmin(ec, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def phase1_map_padded(avail, p_dyn, qfree, eet_rows, deadline, pending,
                      *, interpret: bool = True):
    """Padded entry: N % BLOCK_N == 0, M padded to 128 with qfree=0."""
    N, Mp = eet_rows.shape
    grid = (N // BLOCK_N,)
    return pl.pallas_call(
        _phase1_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
            pl.BlockSpec((1, Mp), lambda i: (0, 0)),
            pl.BlockSpec((BLOCK_N, Mp), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        avail.reshape(1, Mp), p_dyn.reshape(1, Mp), qfree.reshape(1, Mp),
        eet_rows, deadline.reshape(N, 1), pending.reshape(N, 1),
    )
