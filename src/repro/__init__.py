"""FELARE reproduction package.

Also installs a small compatibility alias: ``jax.shard_map`` graduated out
of ``jax.experimental`` only in newer JAX releases, while this codebase
(and its tests) use the top-level spelling. On older JAX we alias the
experimental implementation so both spellings work everywhere.

JAX itself is optional at import time: the static analyzer's AST layer
(``repro.analysis``, Layer 1) runs on the JAX-less CI lint runner, so a
missing JAX must not break ``import repro`` — only the subpackages that
actually trace (core, scenarios, experiments, ...) require it.
"""
try:
    import jax as _jax
except ImportError:  # JAX-less lint runner: Layer 1 analysis only
    _jax = None

if _jax is not None and not hasattr(_jax, "shard_map"):  # < 0.4.x graduation
    import functools as _functools

    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    @_functools.wraps(_experimental_shard_map)
    def _shard_map(f, **kwargs):
        # The experimental version's static replication checker rejects
        # replicated out_specs fed by custom collectives; the graduated
        # version dropped that check, so disable it for parity.
        kwargs.setdefault("check_rep", False)
        return _experimental_shard_map(f, **kwargs)

    _jax.shard_map = _shard_map

if _jax is not None and not hasattr(_jax.lax, "pcast"):
    # jax.lax.pcast marks values as varying over manual mesh axes for the
    # graduated shard_map's replication tracking. The experimental shard_map
    # with check_rep=False has no such tracking, so identity is correct.
    def _pcast(x, axes=None, *, to=None):
        del axes, to
        return x

    _jax.lax.pcast = _pcast

del _jax
