"""Analyzer configuration: scan roots, shared excludes, escape hatches.

The exclude list is SHARED with ruff via ``pyproject.toml`` — the frozen
DO-NOT-EDIT snapshots (``tests/_legacy_*.py``) are listed once under
``[tool.repro.analysis] exclude`` and mirrored into ruff's
``extend-exclude``, replacing per-file ``# noqa`` scatter. Python 3.10
has no ``tomllib``, so a minimal line-oriented fallback parser handles
exactly the shapes this repo's pyproject uses (string lists under a
known key).

Escape hatches are source annotations, one per line::

    # repro: allow-<name>[reason]      suppress rule <name> on this line
    # repro: jit-body                  opt a function INTO the jit-body rules

``<name>`` is the check name (``host``, ``prng``, ``branch``, ...); the
bracketed reason is mandatory — an unexplained suppression is itself a
finding.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple

_ALLOW_RE = re.compile(
    r"#\s*repro:\s*allow-(?P<name>[a-z0-9-]+)\s*"
    r"(?:\[(?P<reason>[^\]]*)\])?")
_JIT_BODY_RE = re.compile(r"#\s*repro:\s*jit-body\b")


def find_repo_root(start: Optional[str] = None) -> str:
    """Walk up from ``start`` (default: this file) to the pyproject dir."""
    d = os.path.abspath(start or os.path.dirname(__file__))
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:  # filesystem root: fall back to cwd
            return os.getcwd()
        d = parent


def _parse_toml(text: str) -> dict:
    """pyproject → nested dict; stdlib tomllib when present, else a
    minimal parser covering tables + string/int/bool/string-list values
    (all this repo's pyproject contains)."""
    try:
        import tomllib  # Python 3.11+
        return tomllib.loads(text)
    except ModuleNotFoundError:
        pass
    root: dict = {}
    table = root
    buf: Optional[Tuple[str, str]] = None  # (key, partial value) for
    for raw in text.splitlines():          # multi-line lists
        line = raw.strip()
        if buf is not None:
            buf = (buf[0], buf[1] + " " + line)
            if "]" in line:
                key, val = buf
                table[key] = _parse_value(val)
                buf = None
            continue
        if not line or line.startswith("#"):
            continue
        if line.startswith("[") and line.endswith("]"):
            table = root
            for part in line[1:-1].strip().strip('"').split("."):
                table = table.setdefault(part, {})
            continue
        if "=" in line:
            key, _, val = line.partition("=")
            key, val = key.strip().strip('"'), val.strip()
            if val.startswith("[") and "]" not in val:
                buf = (key, val)
            else:
                table[key] = _parse_value(val)
    return root


def _parse_value(val: str):
    val = val.strip()
    if val.startswith("["):
        inner = val[val.index("[") + 1: val.rindex("]")]
        return [_parse_value(v) for v in _split_items(inner)]
    if val.startswith(("'", '"')):
        return val[1:-1]
    if val in ("true", "false"):
        return val == "true"
    try:
        return int(val)
    except ValueError:
        return val


def _split_items(inner: str) -> List[str]:
    items, depth, cur = [], 0, ""
    in_str: Optional[str] = None
    for ch in inner:
        if in_str:
            cur += ch
            if ch == in_str:
                in_str = None
            continue
        if ch in "'\"":
            in_str = ch
            cur += ch
        elif ch == "[":
            depth += 1
            cur += ch
        elif ch == "]":
            depth -= 1
            cur += ch
        elif ch == "," and depth == 0:
            if cur.strip():
                items.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        items.append(cur.strip())
    return items


@dataclasses.dataclass(frozen=True)
class AnalysisConfig:
    """Resolved config a check receives: where to look, what to skip."""

    root: str                       # repo root (dir holding pyproject)
    exclude: Tuple[str, ...] = ()   # glob patterns, repo-relative

    def is_excluded(self, path: str) -> bool:
        rel = self.relpath(path).replace(os.sep, "/")
        return any(
            fnmatch.fnmatch(rel, pat) or fnmatch.fnmatch(
                os.path.basename(rel), pat)
            for pat in self.exclude)

    def relpath(self, path: str) -> str:
        ap = os.path.abspath(path)
        try:
            return os.path.relpath(ap, self.root)
        except ValueError:
            return ap

    def python_files(self, *rel_dirs: str) -> List[str]:
        """Non-excluded ``.py`` files under repo-relative directories."""
        out: List[str] = []
        for rel in rel_dirs:
            base = os.path.join(self.root, rel)
            for dirpath, dirnames, filenames in os.walk(base):
                dirnames.sort()
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        p = os.path.join(dirpath, fn)
                        if not self.is_excluded(p):
                            out.append(p)
        return out


def load_config(root: Optional[str] = None) -> AnalysisConfig:
    root = root or find_repo_root()
    pyproject = os.path.join(root, "pyproject.toml")
    exclude: Sequence[str] = ()
    if os.path.exists(pyproject):
        with open(pyproject) as fh:
            data = _parse_toml(fh.read())
        tool = data.get("tool", {})
        exclude = tuple(
            tool.get("repro", {}).get("analysis", {}).get("exclude", ()))
    return AnalysisConfig(root=os.path.abspath(root), exclude=tuple(exclude))


def line_markers(source: str) -> Tuple[Dict[int, Dict[str, str]], List[int]]:
    """Scan source for escape-hatch annotations.

    Returns ``(allows, jit_body_lines)`` where ``allows`` maps 1-based
    line number → {rule-name: reason}; an ``allow`` with an empty or
    missing ``[reason]`` maps to the empty string (flagged separately as
    an unexplained suppression).
    """
    allows: Dict[int, Dict[str, str]] = {}
    jit_body: List[int] = []
    for i, line in enumerate(source.splitlines(), start=1):
        if "repro:" not in line:
            continue
        for m in _ALLOW_RE.finditer(line):
            allows.setdefault(i, {})[m.group("name")] = (
                m.group("reason") or "").strip()
        if _JIT_BODY_RE.search(line):
            jit_body.append(i)
    return allows, jit_body
