"""Static analysis of jit discipline: AST lint + jaxpr audit.

The invariants PRs 2-7 pinned by hand — single-jit six-stage loop, CRN
discipline, frozen-hashable registry objects, the float32-mirrored
oracle — machine-checked as a registry of named checks behind one CLI::

    python -m repro.analysis.check [--list-checks] [--json OUT]

Layer 1 (``astlint``, rules JD001-JD005) is pure ``ast`` and imports no
JAX — it runs on the CI lint runner. Layer 2 (``jaxpr_audit``, rules
JX101-JX104) traces representative engine programs and audits the
jaxprs; it imports JAX lazily inside ``run()`` so ``import
repro.analysis`` itself stays JAX-free. See ``docs/analysis.md`` for the
check catalog and the escape-hatch annotation syntax.
"""
from repro.analysis import astlint, jaxpr_audit  # noqa: F401  (register checks)
from repro.analysis.config import AnalysisConfig, find_repo_root, load_config
from repro.analysis.findings import Finding, format_findings, report_dict
from repro.analysis.registry import CHECKS, get, is_registered, names, register

__all__ = [
    "AnalysisConfig",
    "CHECKS",
    "Finding",
    "find_repo_root",
    "format_findings",
    "get",
    "is_registered",
    "load_config",
    "names",
    "register",
    "report_dict",
    "run_checks",
]


def run_checks(check_names=None, *, root=None, layers=(1, 2)):
    """Run checks by name (default: all registered) against ``root``.

    Returns ``(findings, errors)`` — ``errors`` are ``"name: exc"``
    strings for checks that crashed (a crash must fail the gate, not
    silently pass it).
    """
    cfg = load_config(root)
    selected = [get(n) for n in (check_names or names())]
    findings, errors = [], []
    for check in selected:
        if check.layer not in layers:
            continue
        try:
            findings.extend(check.run(cfg))
        except Exception as exc:  # noqa: BLE001 — gate must see the crash
            errors.append(f"{check.name}: {type(exc).__name__}: {exc}")
    return findings, errors
