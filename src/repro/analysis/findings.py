"""Structured findings: what every check emits and what the CLI reports.

A finding pins one violation to one location — ``file:line`` for the AST
layer, a ``jaxpr:<program>:<eqn path>`` pseudo-path for the jaxpr audit —
plus the stable rule id (``JD00x`` AST rules, ``JX10x`` jaxpr rules) CI
logs and tests key on. The JSON report (``check --json``) is the machine
artifact CI uploads; its schema is this module.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List, Optional, Sequence


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One violation: rule id + location + message.

    ``path`` is repo-relative for file findings (``src/repro/...``) and a
    ``jaxpr:`` pseudo-path for traced-program findings; ``line`` is
    1-based (0 = no line, e.g. a whole-program jaxpr finding).
    """

    path: str
    line: int
    rule: str      # stable id, e.g. "JD003"
    check: str     # registered check name, e.g. "host-effects"
    message: str

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule} [{self.check}] {self.message}"

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def from_json_dict(d: dict) -> Finding:
    """Rebuild a finding from its :meth:`Finding.to_json_dict` form."""
    return Finding(path=d["path"], line=int(d["line"]), rule=d["rule"],
                   check=d["check"], message=d["message"])


def report_dict(findings: Sequence[Finding], *, checks: Sequence[str],
                root: str = ".",
                errors: Optional[Sequence[str]] = None) -> dict:
    """The ``--json`` report: findings + which checks ran + verdict.

    ``ok`` is the CI gate: true iff no findings *and* every requested
    check actually ran (``errors`` records checks that crashed — a crash
    is a failure, never a silent pass).
    """
    errors = list(errors or ())
    by_rule: dict = {}
    for f in findings:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "root": str(root),
        "checks": list(checks),
        "errors": errors,
        "n_findings": len(findings),
        "findings_by_rule": dict(sorted(by_rule.items())),
        "findings": [f.to_json_dict() for f in sorted(findings)],
        "ok": not findings and not errors,
    }


def write_json(path, report: dict) -> None:
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=False)
        fh.write("\n")


def load_json(path) -> List[Finding]:
    """Findings back out of a ``--json`` report (round-trip helper)."""
    with open(path) as fh:
        report = json.load(fh)
    return [from_json_dict(d) for d in report["findings"]]


def format_findings(findings: Iterable[Finding]) -> str:
    return "\n".join(f.format() for f in sorted(findings))
