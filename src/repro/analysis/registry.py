"""The check registry: named, frozen check objects behind a NameRegistry.

Mirrors the policy/scenario/dispatcher/faults idiom — checks are frozen
dataclasses registered under case-insensitive names, so ``--checks
host-effects,crn-discipline`` resolves the same way ``--policy FELARE``
does, and the analyzer can enumerate itself for ``--list-checks``.

The registry class itself is ``repro.core.registry.NameRegistry``, but we
must NOT import it through ``repro.core`` — that package's ``__init__``
pulls in the engine and therefore JAX, and Layer 1 is contractually
importable on a JAX-less interpreter (the CI lint job has only ruff).
``core/registry.py`` imports nothing beyond ``typing``, so when
``repro.core.registry`` is not already loaded we side-load the file
directly by path.
"""
from __future__ import annotations

import importlib.util
import os
import sys
from typing import Callable, List, Protocol, runtime_checkable

from repro.analysis.findings import Finding


def _load_name_registry():
    mod = sys.modules.get("repro.core.registry")
    if mod is None:
        here = os.path.dirname(os.path.abspath(__file__))
        path = os.path.join(os.path.dirname(here), "core", "registry.py")
        spec = importlib.util.spec_from_file_location(
            "repro._analysis_core_registry", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
    return mod.NameRegistry


NameRegistry = _load_name_registry()


@runtime_checkable
class Check(Protocol):
    """One named analysis: scans the tree (or traced programs) for one rule.

    ``rule`` is the stable finding id (``JD00x`` / ``JX10x``); ``layer``
    is 1 (AST, no JAX) or 2 (jaxpr audit, needs JAX). ``run(cfg)``
    returns findings — empty means clean.
    """

    name: str
    rule: str
    layer: int

    def run(self, cfg) -> List[Finding]: ...


def _check_check(name, item) -> None:
    for attr in ("name", "rule", "layer", "run"):
        if not hasattr(item, attr):
            raise TypeError(f"check {name!r} lacks .{attr}: {item!r}")
    if item.layer not in (1, 2):
        raise TypeError(f"check {name!r}: layer must be 1 or 2")


CHECKS: "NameRegistry" = NameRegistry(
    "analysis check", case=str.lower, check=_check_check)

register: Callable = CHECKS.register
get: Callable = CHECKS.get
names: Callable = CHECKS.names
is_registered: Callable = CHECKS.is_registered
